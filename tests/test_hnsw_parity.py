"""Batched-frontier HNSW search vs the numpy beam-search reference.

The batched searcher (fixed-shape lax.while_loop + gather-kernel scoring)
must agree with the per-query numpy greedy beam search on a seeded corpus
— identical top-k id sets for packed and unpacked codes — and its recall
must track the exhaustive flat scan within 0.02."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import clustered_corpus
from repro.core import BinarizerConfig, binarize, init_binarizer, pack_codes
from repro.index.hnsw_lite import (
    build_hnsw,
    prepare_batched,
    search_hnsw,
    search_hnsw_batched,
)
from repro.kernels.sdc import ref as R
from repro.kernels.sdc.ops import sdc_search_xla

LEVELS = 4


def _random_graph(n=400, q=8, dim=32, M=8, seed=3, packed=False):
    key = jax.random.PRNGKey(seed)
    cd = np.asarray(jax.random.randint(key, (n, dim), 0, 2**LEVELS), np.int8)
    cq = np.asarray(
        jax.random.randint(jax.random.fold_in(key, 1), (q, dim), 0, 2**LEVELS),
        np.int8,
    )
    inv = np.asarray(R.doc_inv_norms(jnp.asarray(cd), LEVELS))
    index = build_hnsw(cd, inv, n_levels=LEVELS, M=M, ef_construction=32,
                       seed=0, packed=packed)
    return index, cd, cq, inv


@pytest.mark.parametrize("packed", [False, True])
def test_batched_matches_numpy_topk_ids(packed):
    """Same graph, same entry points, generous ef: the batched-frontier
    search returns exactly the numpy beam search's top-k id set."""
    index, _, cq, _ = _random_graph(packed=packed)
    tables = prepare_batched(index)
    k, ef, beam = 10, 128, 32
    _, ids = search_hnsw_batched(
        tables, jnp.asarray(cq), k=k, ef=ef, beam=beam, max_hops=64,
        backend="xla",
    )
    ids = np.asarray(ids)
    for i in range(cq.shape[0]):
        _, ref_ids = search_hnsw(index, cq[i], k=k, ef=ef)
        assert set(ids[i].tolist()) == set(ref_ids.tolist()), f"query {i}"


def test_packed_tables_bit_identical_to_unpacked():
    """int4 nibble-packed neighbor tables change bytes, not scores."""
    index, _, cq, _ = _random_graph()
    kw = dict(k=10, ef=48, beam=12, max_hops=48, backend="xla")
    vu, iu = search_hnsw_batched(
        prepare_batched(index, packed=False), jnp.asarray(cq), **kw
    )
    vp, ip = search_hnsw_batched(
        prepare_batched(index, packed=True), jnp.asarray(cq), **kw
    )
    np.testing.assert_array_equal(np.asarray(iu), np.asarray(ip))
    np.testing.assert_array_equal(np.asarray(vu), np.asarray(vp))


@pytest.mark.parametrize("packed", [False, True])
def test_gather_kernel_backend_matches_xla(packed):
    """The scalar-prefetched gather kernel (interpret mode) and the jnp
    twin walk the graph identically — scores and ids bit-for-bit."""
    index, _, cq, _ = _random_graph(q=4)
    tables = prepare_batched(index, packed=packed)
    kw = dict(k=10, ef=32, beam=8, max_hops=32)
    vx, ix = search_hnsw_batched(tables, jnp.asarray(cq), backend="xla", **kw)
    vi, ii = search_hnsw_batched(
        tables, jnp.asarray(cq), backend="interpret", **kw
    )
    np.testing.assert_array_equal(np.asarray(ix), np.asarray(ii))
    np.testing.assert_array_equal(np.asarray(vx), np.asarray(vi))


def test_recall_within_flat_scan_margin():
    """On a clustered corpus, batched-frontier recall@10 stays within
    0.02 of the exhaustive flat-scan recall."""
    docs, queries, gt = clustered_corpus(0, 2000, 32, 64, n_clusters=16)
    cfg = BinarizerConfig(input_dim=64, code_dim=64, n_levels=LEVELS,
                          hidden_dim=0)
    p, s = init_binarizer(jax.random.PRNGKey(0), cfg)
    d_codes = pack_codes(binarize(p, s, jnp.asarray(docs), cfg)[0])
    q_codes = pack_codes(binarize(p, s, jnp.asarray(queries), cfg)[0])
    inv = R.doc_inv_norms(d_codes, LEVELS)

    _, flat_ids = sdc_search_xla(q_codes, d_codes, inv, n_levels=LEVELS, k=10)
    flat_recall = float(
        jnp.mean(jnp.any(flat_ids == jnp.asarray(gt)[:, None], -1))
    )

    index = build_hnsw(np.asarray(d_codes), np.asarray(inv),
                       n_levels=LEVELS, M=12, ef_construction=48)
    _, hnsw_ids = search_hnsw_batched(
        prepare_batched(index), q_codes, k=10, ef=96, beam=24, max_hops=64,
        backend="xla",
    )
    hnsw_recall = float(
        jnp.mean(jnp.any(hnsw_ids == jnp.asarray(gt)[:, None], -1))
    )
    assert hnsw_recall >= flat_recall - 0.02, (hnsw_recall, flat_recall)


def test_stats_and_empty_slots():
    """with_stats reports hop/candidate counters; k beyond the reachable
    set surfaces as (SDC_NEG_INF, -1) slots, never duplicate ids."""
    index, _, cq, _ = _random_graph(n=64, q=4, M=4)
    tables = prepare_batched(index)
    vals, ids, stats = search_hnsw_batched(
        tables, jnp.asarray(cq), k=80, ef=96, beam=16, max_hops=64,
        backend="xla", with_stats=True,
    )
    assert int(stats["hops"].min()) >= 1
    assert int(stats["scored"].min()) >= 1
    ids = np.asarray(ids)
    for row in ids:
        real = row[row >= 0]
        assert len(set(real.tolist())) == len(real)  # no duplicate ids
    # 64 docs < k=80: every query must carry empty (-1) slots
    assert (ids == -1).any()


def test_nbytes_accounts_for_packed_layout():
    """HNSWLite.nbytes must track the stored layout: nibble-packed codes
    occupy 4 bits/dim however many levels the grid has, so for n_levels=2
    a packed index is *larger* than the ideal 2-bit serialisation and
    nbytes must say so (the old formula reused the ideal-bit math on the
    already-halved packed width, undercounting by 2x)."""
    n, dim = 256, 32
    key = jax.random.PRNGKey(0)
    cd2 = np.asarray(jax.random.randint(key, (n, dim), 0, 4), np.int8)
    inv = np.asarray(R.doc_inv_norms(jnp.asarray(cd2), 2))
    unpacked = build_hnsw(cd2, inv, n_levels=2, M=4, ef_construction=16)
    packed = build_hnsw(cd2, inv, n_levels=2, M=4, ef_construction=16,
                        packed=True)
    graph_bytes = unpacked.neighbors.size * 4
    # unpacked: ideal 2-bit serialisation; packed: 4 bits/dim as stored
    assert unpacked.nbytes() - graph_bytes == n * (dim * 2 // 8 + 4)
    assert packed.nbytes() - graph_bytes == n * (dim // 2 + 4)
    assert packed.nbytes() > unpacked.nbytes()
    # and both searchers still agree on the packed store
    _, ref_ids = search_hnsw(packed, cd2[0], k=5, ef=64)
    _, ids = search_hnsw_batched(
        prepare_batched(packed), jnp.asarray(cd2[:1]), k=5, ef=64, beam=16,
        backend="xla",
    )
    assert set(np.asarray(ids)[0].tolist()) == set(ref_ids.tolist())
