"""ServingPipeline (launch/serving.py): ordering under stage stalls,
admission-queue shed/block, overlapped == sequential bit-identity across
all three index families, and clean shutdown with no leaked threads."""

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.index import ivf as ivf_lib
from repro.index.flat import FlatSDC
from repro.index.hnsw_lite import build_hnsw, prepare_batched, search_hnsw_batched
from repro.kernels.sdc import ref as R
from repro.launch.serving import (
    PipelineClosed,
    RequestShed,
    ServingConfig,
    ServingPipeline,
    serve_batches,
    serve_sequential,
    warmup,
)

LEVELS = 4


def _np_identity_stages(encode_sleep=0.0, scan_sleep=0.0):
    """Trivial numpy stages whose output encodes the input batch."""

    def encode(x):
        if encode_sleep:
            time.sleep(encode_sleep)
        return x

    def search(c):
        if scan_sleep:
            time.sleep(scan_sleep)
        return c * 2, c + 1

    return encode, search


def _batches(n=6, width=4):
    return [np.full((width,), i, dtype=np.int64) for i in range(n)]


# ---------------------------------------------------------------------------
# ordering
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("encode_sleep,scan_sleep", [(0.05, 0.0), (0.0, 0.05)])
def test_ordering_preserved_under_stage_stalls(encode_sleep, scan_sleep):
    """A slow encode (scan starves) or slow scan (encode runs ahead) must
    not reorder replies: FIFO stages, FIFO results."""
    encode, search = _np_identity_stages(encode_sleep, scan_sleep)
    results, _ = serve_batches(
        encode, search, _batches(),
        config=ServingConfig(queue_depth=4, encode_ahead=2, dispatch_ahead=2),
    )
    for i, (vals, ids) in enumerate(results):
        np.testing.assert_array_equal(vals, np.full((4,), 2 * i))
        np.testing.assert_array_equal(ids, np.full((4,), i + 1))


# ---------------------------------------------------------------------------
# admission queue
# ---------------------------------------------------------------------------


def test_shed_policy_rejects_when_full():
    gate = threading.Event()
    started = threading.Event()

    def encode(x):
        started.set()
        gate.wait(timeout=10)
        return x

    _, search = _np_identity_stages()
    pipe = ServingPipeline(
        encode, search, config=ServingConfig(queue_depth=2, policy="shed")
    )
    try:
        t0 = pipe.submit(_batches()[0])  # pulled by the encode thread
        assert started.wait(timeout=5)
        t1 = pipe.submit(_batches()[1])  # queue slot 1
        t2 = pipe.submit(_batches()[2])  # queue slot 2 -> full
        with pytest.raises(RequestShed):
            pipe.submit(_batches()[3])
        assert pipe.shed_count == 1
        gate.set()
        for t in (t0, t1, t2):
            t.result(timeout=10)
    finally:
        gate.set()
        pipe.close()


def test_block_policy_backpressures_until_space():
    gate = threading.Event()
    started = threading.Event()

    def encode(x):
        started.set()
        gate.wait(timeout=10)
        return x

    _, search = _np_identity_stages()
    pipe = ServingPipeline(
        encode, search, config=ServingConfig(queue_depth=1, policy="block")
    )
    try:
        pipe.submit(_batches()[0])
        assert started.wait(timeout=5)
        pipe.submit(_batches()[1])  # fills the single queue slot

        unblocked = threading.Event()

        def blocked_submit():
            pipe.submit(_batches()[2])
            unblocked.set()

        th = threading.Thread(target=blocked_submit, daemon=True)
        th.start()
        # queue full and the encode stage is gated: submit must block
        assert not unblocked.wait(timeout=0.3)
        gate.set()  # pipeline drains -> the blocked submit completes
        assert unblocked.wait(timeout=10)
        th.join(timeout=10)
    finally:
        gate.set()
        pipe.close()


def test_warmup_covers_both_drivers_and_ragged_tail_shape():
    shapes = []

    def encode(x):
        shapes.append(x.shape)
        return x

    _, search = _np_identity_stages()
    warmup(encode, search,
           [np.zeros((4,)), np.zeros((4,)), np.zeros((2,))])
    # sequential driver + pipeline driver each see the lead shape and
    # the distinct ragged tail shape
    assert shapes.count((4,)) == 2
    assert shapes.count((2,)) == 2


def test_latency_accounts_enqueue_to_reply():
    encode, search = _np_identity_stages(encode_sleep=0.05)
    results, stats = serve_batches(encode, search, _batches(3))
    assert len(results) == 3
    # every request waited for at least its own encode
    assert stats["latency_p50_ms"] >= 50.0
    # the last request also queued behind the first two
    assert stats["latency_p99_ms"] >= stats["latency_p50_ms"]
    assert stats["requests"] == 3


# ---------------------------------------------------------------------------
# bit-identity vs the sequential loop, all three index families
# ---------------------------------------------------------------------------


def _code_corpus(n=600, q=24, dim=32, seed=0):
    key = jax.random.PRNGKey(seed)
    cd = jax.random.randint(key, (n, dim), 0, 2**LEVELS).astype(jnp.int8)
    cq = jax.random.randint(
        jax.random.fold_in(key, 1), (q, dim), 0, 2**LEVELS
    ).astype(jnp.int8)
    return cd, cq


@pytest.mark.parametrize("kind", ["flat", "ivf", "hnsw"])
def test_overlapped_bit_identical_to_sequential(kind):
    cd, cq = _code_corpus()
    if kind == "flat":
        index = FlatSDC.build(cd, LEVELS, backend="xla")
        search = lambda q: index.search(q, 10)
    elif kind == "ivf":
        index = ivf_lib.build_ivf(
            jax.random.PRNGKey(1), cd, n_levels=LEVELS, nlist=8,
            kmeans_iters=3,
        )
        search = lambda q: ivf_lib.search(index, q, nprobe=4, k=10,
                                          backend="xla")
    else:
        inv = np.asarray(R.doc_inv_norms(cd, LEVELS))
        graph = build_hnsw(np.asarray(cd), inv, n_levels=LEVELS, M=8,
                           ef_construction=24, seed=0)
        tables = prepare_batched(graph)
        search = lambda q: search_hnsw_batched(
            tables, q, k=10, ef=24, beam=8, backend="xla"
        )

    encode = lambda q: q  # codes in, codes out: isolates the scan stage
    batches = [cq[i : i + 8] for i in range(0, cq.shape[0], 8)]
    seq = serve_sequential(encode, search, batches)
    ovl, stats = serve_batches(
        encode, search, batches,
        config=ServingConfig(encode_ahead=2, dispatch_ahead=2),
    )
    assert stats["requests"] == len(batches)
    for (sv, si), (ov, oi) in zip(seq, ovl):
        np.testing.assert_array_equal(np.asarray(si), np.asarray(oi))
        np.testing.assert_array_equal(np.asarray(sv), np.asarray(ov))


# ---------------------------------------------------------------------------
# shutdown
# ---------------------------------------------------------------------------


def test_close_joins_threads_and_rejects_submit():
    encode, search = _np_identity_stages()
    before = threading.active_count()
    pipe = ServingPipeline(encode, search)
    tickets = [pipe.submit(b) for b in _batches(4)]
    pipe.close()
    for t in tickets:  # drain close finishes admitted work
        t.result(timeout=5)
    assert threading.active_count() == before  # no leaked stage threads
    assert not pipe._encode_thread.is_alive()
    assert not pipe._scan_thread.is_alive()
    with pytest.raises(PipelineClosed):
        pipe.submit(_batches()[0])
    pipe.close()  # idempotent


def test_close_without_drain_fails_queued_tickets():
    gate = threading.Event()
    started = threading.Event()

    def encode(x):
        started.set()
        gate.wait(timeout=10)
        return x

    _, search = _np_identity_stages()
    pipe = ServingPipeline(
        encode, search, config=ServingConfig(queue_depth=4)
    )
    t0 = pipe.submit(_batches()[0])
    assert started.wait(timeout=5)
    queued = [pipe.submit(b) for b in _batches(3)[1:]]
    # close() joins the stage threads, and the encode stage is still
    # gated — run it concurrently; it fails the queued tickets first.
    closer = threading.Thread(target=lambda: pipe.close(drain=False),
                              daemon=True)
    closer.start()
    for t in queued:
        with pytest.raises(PipelineClosed):
            t.result(timeout=5)
    gate.set()
    closer.join(timeout=10)
    assert not closer.is_alive()
    t0.result(timeout=5)  # the in-flight request still completes


def test_stage_errors_surface_on_the_ticket():
    def encode(x):
        raise ValueError("encode boom")

    _, search = _np_identity_stages()
    with ServingPipeline(encode, search) as pipe:
        t = pipe.submit(_batches()[0])
        with pytest.raises(ValueError, match="encode boom"):
            t.result(timeout=5)

    def search_bad(c):
        raise RuntimeError("scan boom")

    with ServingPipeline(lambda x: x, search_bad) as pipe:
        t = pipe.submit(_batches()[0])
        with pytest.raises(RuntimeError, match="scan boom"):
            t.result(timeout=5)
