"""Live index lifecycle (launch/lifecycle.py + the router's health
state machine): a rolling per-replica swap under continuous traffic
loses nothing, reorders nothing, and stays bit-identical to
serve_sequential for all three index families; a transiently-failed
replica is revived by a canary re-probe (manual and periodic); revived
replicas get a fresh stats generation so their counters are not
conflated with the pre-death run; misuse of the state machine fails
loudly."""

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import lifecycle, serving
from repro.launch.clock import FakeClock
from repro.launch.faults import FaultEvent, FaultInjector, FaultPlan
from repro.launch.lifecycle import (
    CorpusSnapshot,
    RollingSwapController,
    SwapFailed,
    builder_version,
    make_builder,
)
from repro.launch.proxy import QueryRouter, ReplicaSet
from repro.launch.serving import (
    RequestShed,
    ServingConfig,
    ServingPipeline,
    serve_sequential,
)

LEVELS = 4

# Small-but-real build params per family (mirrors test_proxy_router's
# bit-identity corpus sizes; every builder is deterministic in these).
BUILDER_PARAMS = {
    "flat": dict(k=10, backend="xla"),
    "ivf": dict(k=10, nlist=8, nprobe=4, kmeans_iters=3, seed=1,
                backend="xla"),
    "hnsw": dict(k=10, M=8, ef_construction=24, ef=24, beam=8, seed=0,
                 backend="xla"),
}


def _code_corpus(n=600, q=24, dim=32, seed=0):
    key = jax.random.PRNGKey(seed)
    cd = jax.random.randint(key, (n, dim), 0, 2**LEVELS).astype(jnp.int8)
    cq = jax.random.randint(
        jax.random.fold_in(key, 1), (q, dim), 0, 2**LEVELS
    ).astype(jnp.int8)
    return cd, cq


def _identity_replica():
    return (lambda x: x), (lambda c: (c * 2, c + 1))


def _batches(n=8, width=4):
    return [np.full((width,), i, dtype=np.int64) for i in range(n)]


# ---------------------------------------------------------------------------
# snapshots + versions
# ---------------------------------------------------------------------------


def test_snapshot_digest_tracks_content():
    cd, _ = _code_corpus()
    a = CorpusSnapshot(codes=np.asarray(cd), n_levels=LEVELS)
    b = CorpusSnapshot(codes=np.asarray(cd).copy(), n_levels=LEVELS)
    assert a.digest == b.digest  # content hash, not object identity
    changed = np.asarray(cd).copy()
    changed[0, 0] = (changed[0, 0] + 1) % (2**LEVELS)
    c = CorpusSnapshot(codes=changed, n_levels=LEVELS)
    assert a.digest != c.digest


def test_snapshot_equality_and_hash_go_through_digest():
    cd, _ = _code_corpus(n=64)
    a = CorpusSnapshot(codes=np.asarray(cd), n_levels=LEVELS)
    b = CorpusSnapshot(codes=np.asarray(cd).copy(), n_levels=LEVELS)
    assert a == b and hash(a) == hash(b)  # content, not identity
    assert a != CorpusSnapshot(codes=np.asarray(cd), n_levels=LEVELS,
                               embedding_version="v1")
    assert len({a, b}) == 1  # usable as a dict/set key


def test_snapshot_digest_is_computed_once():
    cd, _ = _code_corpus(n=64)
    snap = CorpusSnapshot(codes=np.asarray(cd), n_levels=LEVELS)
    d = snap.digest
    # cached_property: a rolling swap consults the digest ~2N+1 times
    # and must not re-hash the whole corpus each time
    assert "digest" in snap.__dict__
    assert snap.digest is d


def test_index_version_carries_kind_embedding_and_params():
    cd, _ = _code_corpus(n=64)
    snap = CorpusSnapshot(codes=np.asarray(cd), n_levels=LEVELS,
                          embedding_version="v3")
    builder = make_builder("ivf", **BUILDER_PARAMS["ivf"])
    v = builder_version(builder, snap)
    assert v.index_kind == "ivf" and v.embedding_version == "v3"
    assert v.corpus_digest == snap.digest
    assert ("nlist", 8) in v.build_params
    assert v.tag.startswith("ivf:v3:")
    # different build params => different version, same corpus digest
    v2 = builder_version(make_builder("ivf", k=10, nlist=4, nprobe=4), snap)
    assert v2 != v and v2.corpus_digest == v.corpus_digest


def test_make_builder_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown index builder"):
        make_builder("pq")


# ---------------------------------------------------------------------------
# rolling swap under live traffic — zero lost/reordered, bit-identical
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["flat", "ivf", "hnsw"])
def test_rolling_swap_under_live_traffic_bit_identical(kind):
    cd, cq = _code_corpus()
    snap = CorpusSnapshot(codes=np.asarray(cd), n_levels=LEVELS)
    builder = make_builder(kind, **BUILDER_PARAMS[kind])
    encode = lambda q: q  # codes in, codes out: isolates the lifecycle
    batches = [cq[i: i + 8] for i in range(0, cq.shape[0], 8)]
    ref = serve_sequential(encode, builder.build(snap), batches)

    router = QueryRouter(ReplicaSet(
        [(encode, builder.build(snap)) for _ in range(2)],
        config=ServingConfig(queue_depth=8),
    ))
    # Fresh builder instance for the controller: the tier builder's
    # digest cache would hand the swap the identical pre-swap SearchFn,
    # leaving the rebuild path untested.
    swap_started = [threading.Event() for _ in range(2)]

    def on_event(msg):
        for i, ev in enumerate(swap_started):
            if msg.startswith(f"replica {i}: draining"):
                ev.set()

    controller = RollingSwapController(
        router, make_builder(kind, **BUILDER_PARAMS[kind]),
        warm_batches=batches[:1], drain_timeout=15.0, probe_timeout=60.0,
        on_event=on_event,
    )
    stream = batches * 8
    tickets = []

    def feeder():
        # Event-gated pacing instead of a per-batch timer sleep: hold a
        # chunk of the stream back until each replica's swap has begun,
        # so traffic provably overlaps BOTH swap windows no matter how
        # fast this host drains the queue.
        for j, b in enumerate(stream):
            if j == len(stream) // 3:
                assert swap_started[0].wait(timeout=30)
            elif j == (2 * len(stream)) // 3:
                assert swap_started[1].wait(timeout=30)
            while True:
                try:
                    tickets.append(router.submit(b))
                    break
                except RequestShed:
                    time.sleep(1e-3)

    try:
        th = threading.Thread(target=feeder)
        th.start()
        report = controller.swap_all(snap)  # swaps BOTH replicas, in turn
        th.join()
        results = [t.result(timeout=60) for t in tickets]
        assert len(results) == len(stream)  # zero lost
        for i, (vals, ids) in enumerate(results):  # zero reorder + identity
            rv, ri = ref[i % len(batches)]
            np.testing.assert_array_equal(np.asarray(ids), np.asarray(ri))
            np.testing.assert_array_equal(np.asarray(vals), np.asarray(rv))
        assert report.swapped == 2
        stats = router.stats()
        assert stats["states"] == {0: "healthy", 1: "healthy"}
        assert [p["generation"] for p in stats["per_replica"]] == [1, 1]
        assert [p["version"] for p in stats["per_replica"]] \
            == [report.version.tag] * 2
    finally:
        router.close()


def test_single_replica_swap_sheds_then_recovers():
    """With one replica the drain window has no survivor: submits shed
    (retryable), never AllReplicasDown, and traffic resumes after."""
    cd, cq = _code_corpus(n=256)
    snap = CorpusSnapshot(codes=np.asarray(cd), n_levels=LEVELS)
    builder = make_builder("flat", **BUILDER_PARAMS["flat"])
    encode = lambda q: q
    batches = [cq[i: i + 8] for i in range(0, cq.shape[0], 8)]
    ref = serve_sequential(encode, builder.build(snap), batches)
    router = QueryRouter(ReplicaSet([(encode, builder.build(snap))],
                                    config=ServingConfig(queue_depth=4)))
    controller = RollingSwapController(
        router, make_builder("flat", **BUILDER_PARAMS["flat"]),
        warm_batches=batches[:1],
    )
    try:
        done = threading.Event()
        shed_seen = []

        def feeder():
            while not done.is_set():
                try:
                    t = router.submit(batches[0])
                    t.result(timeout=30)
                except RequestShed:
                    shed_seen.append(1)
                    time.sleep(1e-3)

        th = threading.Thread(target=feeder)
        th.start()
        report = controller.swap_all(snap)
        done.set()
        th.join()
        assert report.swapped == 1
        vals, ids = router.submit(batches[1]).result(timeout=30)
        np.testing.assert_array_equal(np.asarray(ids), np.asarray(ref[1][1]))
    finally:
        router.close()


def test_swap_all_reclaims_an_unhealthy_replica_in_place():
    """A replica that is already dead when its turn comes must not abort
    the rolling swap: it is rebuilt in place (nothing is routed to it),
    which doubles as its revival."""
    cd, cq = _code_corpus(n=256)
    snap = CorpusSnapshot(codes=np.asarray(cd), n_levels=LEVELS)
    builder = make_builder("flat", **BUILDER_PARAMS["flat"])
    encode = lambda q: q
    built = builder.build(snap)
    fail = [0]

    def flaky(c):
        if fail[0] > 0:
            fail[0] -= 1
            raise RuntimeError("transient")
        return built(c)

    router = QueryRouter(ReplicaSet([(encode, built), (encode, flaky)],
                                    config=ServingConfig(queue_depth=8)))
    try:
        batches = [cq[i: i + 8] for i in range(0, cq.shape[0], 8)]
        ref = serve_sequential(encode, built, batches)
        fail[0] = 1
        for b in batches:  # round-robin: the fault lands on replica 1
            router.submit(b).result(timeout=30)
        assert router.states()[1] == "unhealthy"
        controller = RollingSwapController(
            router, make_builder("flat", **BUILDER_PARAMS["flat"]),
            warm_batches=batches[:1],
        )
        report = controller.swap_all(snap)
        assert report.swapped == 2
        assert router.states() == {0: "healthy", 1: "healthy"}
        # reclaiming a dead replica through the swap counts as a revival
        assert router.revival_count == 1
        vals, ids = router.submit(batches[0]).result(timeout=30)
        np.testing.assert_array_equal(np.asarray(ids), np.asarray(ref[0][1]))
    finally:
        router.close()


def test_run_stream_with_swap_surfaces_build_error_over_tier_down():
    """A failed swap that downs a single-replica tier mid-stream must
    surface the builder's own error, not the AllReplicasDown it caused."""

    class RaisingBuilder:
        kind = "flat"
        params: dict = {}

        def build(self, snapshot, *, replica=0):
            raise RuntimeError("build exploded")

    snap = CorpusSnapshot(codes=np.zeros((8, 4), np.int8), n_levels=LEVELS)
    router = QueryRouter(ReplicaSet([_identity_replica()],
                                    config=ServingConfig(queue_depth=8)))
    controller = RollingSwapController(router, RaisingBuilder(),
                                       canary=_batches(1)[0])
    try:
        with pytest.raises(RuntimeError, match="build exploded"):
            lifecycle.run_stream_with_swap(
                router, _batches(64), controller=controller,
                snapshot=snap, swap_after=2,
            )
        assert router.states()[0] == "unhealthy"
    finally:
        router.close()


def test_run_stream_with_swap_rejects_trigger_past_stream_end():
    snap = CorpusSnapshot(codes=np.zeros((8, 4), np.int8), n_levels=LEVELS)
    router = QueryRouter(ReplicaSet([_identity_replica()],
                                    config=ServingConfig(queue_depth=8)))
    controller = RollingSwapController(
        router, make_builder("flat", **BUILDER_PARAMS["flat"]),
        canary=_batches(1)[0],
    )
    try:
        with pytest.raises(ValueError, match="would never fire"):
            lifecycle.run_stream_with_swap(
                router, _batches(4), controller=controller,
                snapshot=snap, swap_after=100,
            )
    finally:
        router.close()


def test_swap_failed_canary_leaves_replica_unhealthy_but_tier_up():
    class BrokenBuilder:
        kind = "flat"
        params: dict = {}

        def build(self, snapshot, *, replica=0):
            def bad(codes):
                raise RuntimeError("bad rebuilt index")

            return bad

    snap = CorpusSnapshot(codes=np.zeros((8, 4), np.int8), n_levels=LEVELS)
    replicas = [_identity_replica(), _identity_replica()]
    router = QueryRouter(ReplicaSet(replicas,
                                    config=ServingConfig(queue_depth=8)))
    controller = RollingSwapController(router, BrokenBuilder(),
                                       canary=_batches(1)[0])
    try:
        with pytest.raises(SwapFailed, match="canary probe"):
            controller.swap_all(snap)
        assert router.states()[0] == "unhealthy"
        assert router.healthy() == [1]  # survivors keep serving
        vals, ids = router.submit(_batches(2)[1]).result(timeout=10)
        np.testing.assert_array_equal(np.asarray(vals), np.full((4,), 2))
    finally:
        router.close()


def test_aborted_swap_parks_replica_unhealthy_and_reclaimable():
    """A build/warm failure mid-swap must not strand the replica in
    'rebuilding' (no probe targets that state — it would be one-strike-
    forever again): it goes to 'unhealthy', where the canary re-probe
    reclaims it once the cause clears."""

    class RaisingBuilder:
        kind = "flat"
        params: dict = {}

        def build(self, snapshot, *, replica=0):
            raise RuntimeError("build exploded")

    snap = CorpusSnapshot(codes=np.zeros((8, 4), np.int8), n_levels=LEVELS)
    router = QueryRouter(ReplicaSet(
        [_identity_replica(), _identity_replica()],
        config=ServingConfig(queue_depth=8),
    ))
    controller = RollingSwapController(router, RaisingBuilder(),
                                       canary=_batches(1)[0])
    try:
        with pytest.raises(RuntimeError, match="build exploded"):
            controller.swap_replica(0, snap)
        assert router.states()[0] == "unhealthy"  # never stuck 'rebuilding'
        assert router.healthy() == [1]
        # the replica's own pipeline still works: the probe reclaims it
        assert router.probe(0, _batches(1)[0]) is True
        assert router.states()[0] == "healthy"
        assert router.revival_count == 1
    finally:
        router.close()


# ---------------------------------------------------------------------------
# drain semantics
# ---------------------------------------------------------------------------


def test_drain_stops_routing_and_redispatches_stragglers():
    gate = threading.Event()
    started = threading.Event()
    calls = []

    def slow_search(c):
        started.set()
        gate.wait(timeout=10)
        calls.append(("slow", int(np.asarray(c).ravel()[0])))
        return c * 2, c + 1

    def fast_search(c):
        calls.append(("fast", int(np.asarray(c).ravel()[0])))
        return c * 2, c + 1

    router = QueryRouter(ReplicaSet(
        [((lambda x: x), slow_search), ((lambda x: x), fast_search)],
        config=ServingConfig(queue_depth=8),
    ))
    try:
        b = _batches(2)
        t0 = router.submit(b[0])  # round-robin: lands on replica 0
        assert started.wait(timeout=5)
        # Stuck replica: the short drain times out and re-dispatches the
        # in-flight ticket to the survivor (force_block, never dropped).
        router.drain(0, timeout=0.05)
        assert router.states()[0] == "draining"
        vals, ids = t0.result(timeout=10)
        np.testing.assert_array_equal(np.asarray(vals), np.full((4,), 0))
        assert router.failover_count >= 1
        # draining replica receives no new traffic
        router.submit(b[1]).result(timeout=10)
        assert all(tag == "fast" for tag, _ in calls)
    finally:
        gate.set()
        router.close()


def test_state_machine_guards_misuse():
    router = QueryRouter(ReplicaSet([_identity_replica()],
                                    config=ServingConfig(queue_depth=4)))
    try:
        assert router.states() == {0: "healthy"}
        with pytest.raises(ValueError, match="need 'draining'"):
            router.begin_rebuild(0)
        assert router.probe(0, _batches(1)[0]) is True  # healthy: no-op
        router.drain(0, timeout=1.0)
        with pytest.raises(ValueError, match="need 'healthy'"):
            router.drain(0, timeout=0.1)
        with pytest.raises(ValueError, match="draining"):
            router.probe(0, _batches(1)[0])
        router.begin_rebuild(0)
        assert router.states()[0] == "rebuilding"
        # only the swap controller (from_rebuild) may hand a rebuilding
        # replica back — a stray background probe must not re-admit a
        # replica whose stages are mid-mutation
        assert router.probe(0, _batches(1)[0]) is False
        assert router.states()[0] == "rebuilding"
        assert router.probe(0, _batches(1)[0], from_rebuild=True) is True
        assert router.states()[0] == "healthy"
    finally:
        router.close()


# ---------------------------------------------------------------------------
# canary revival + generation-tagged stats
# ---------------------------------------------------------------------------


class _CountdownEvent(FaultEvent):
    """A re-armable fail-N-more-times counter expressed as a custom
    ``FaultEvent``: ``applies`` consumes one charge per firing call,
    and tests mutate the shared ``fail_times`` list to arm/clear it
    mid-run (position-independent, unlike the stock positional
    events)."""

    def applies(self, i, rng=None):
        if self._times[0] > 0:
            self._times[0] -= 1
            return True
        return False


def _flaky_replica(fail_times):
    """Identity replica whose search fails ``fail_times[0]`` more times.

    Built on the shared ``FaultInjector`` (launch/faults.py) so the
    error type, per-stage call counting, and fault log match every
    other injected fault in the suite."""
    ev = _CountdownEvent("fail")
    object.__setattr__(ev, "_times", fail_times)  # frozen dataclass
    return FaultInjector(
        (lambda x: x), (lambda c: (c * 2, c + 1)),
        FaultPlan([ev]), name="flaky",
    ).pair


def test_canary_probe_revives_and_separates_generations():
    fail = [0]
    router = QueryRouter(ReplicaSet(
        [_identity_replica(), _flaky_replica(fail)],
        config=ServingConfig(queue_depth=8),
    ))
    try:
        b = _batches(8)
        # replica 1 serves two batches healthy (round-robin 1,3)...
        for i in range(4):
            router.submit(b[i]).result(timeout=10)
        assert router.stats()["per_replica"][1]["requests"] == 2
        # ...then dies on its next scan; failover re-serves the batch
        # (round-robin: b[4] lands on replica 0, b[5] on replica 1).
        fail[0] = 1
        router.submit(b[4]).result(timeout=10)
        vals, _ = router.submit(b[5]).result(timeout=10)
        np.testing.assert_array_equal(np.asarray(vals), np.full((4,), 10))
        assert router.states()[1] == "unhealthy"
        assert router.healthy() == [0]

        # the transient fault has cleared: the canary revives it
        assert router.probe(1, b[0]) is True
        assert router.states()[1] == "healthy"
        assert router.revival_count == 1
        s = router.stats()
        assert s["revivals"] == 1
        pr = s["per_replica"][1]
        # generation bumped; current-generation counters cover ONLY the
        # post-revival run (here: the canary), lifetime keeps the total.
        assert pr["generation"] == 1
        assert pr["requests"] == 1
        assert pr["lifetime_requests"] == 3
    finally:
        router.close()


def test_periodic_health_probe_thread_revives_when_fault_clears():
    """Runs on FakeClock: each tick hands the probe loop exactly one
    interval, so 'still down after N probes' and 'revives on the first
    probe after the fault clears' are counted, not slept for."""
    clk = FakeClock()
    fail = [10**9]  # persistently down until we clear it
    router = QueryRouter(ReplicaSet(
        [_identity_replica(), _flaky_replica(fail)],
        config=ServingConfig(queue_depth=8),
    ), clock=clk)
    try:
        router.start_health_probe(_batches(1)[0], interval=1.0)
        b = _batches(6)
        for i in range(4):
            router.submit(b[i]).result(timeout=10)
        assert router.states()[1] == "unhealthy"
        for _ in range(3):  # probes fail at t=1, t=2; t=3 backs off
            clk.tick(1.0)
        assert router.states()[1] == "unhealthy"
        assert router.probe_failures().get(1, 0) >= 2
        fail[0] = 0  # fault clears; the next due probe revives
        for _ in range(16):
            clk.tick(1.0)
            if router.states()[1] == "healthy":
                break
        assert router.states()[1] == "healthy"
        assert router.revival_count >= 1
        # revived replica serves real traffic again
        for i in range(4):
            router.submit(b[i]).result(timeout=10)
        assert router.stats()["per_replica"][1]["requests"] >= 1
    finally:
        router.close()


def test_probe_refuses_revival_while_old_generation_scan_is_stuck():
    """The generation bump needs a real quiesce: with an old-generation
    scan still in flight the probe must fail (replica stays unhealthy)
    rather than reset the stats under the straggler."""
    gate = threading.Event()
    fail = [1]

    def search1(c):
        if fail[0] > 0:
            fail[0] -= 1
            raise RuntimeError("die once")
        gate.wait(timeout=10)
        return c * 2, c + 1

    router = QueryRouter(ReplicaSet(
        [_identity_replica(), ((lambda x: x), search1)],
        config=ServingConfig(queue_depth=8),
    ))
    try:
        b = _batches(4)
        router.submit(b[0]).result(timeout=10)  # round-robin: replica 0
        router.submit(b[1]).result(timeout=10)  # replica 1 dies, fails over
        assert router.states()[1] == "unhealthy"
        # plant a stuck old-generation scan directly on the dead pipeline
        straggler = router.replicas.pipelines[1].submit(b[2],
                                                        force_block=True)
        assert router.probe(1, b[3], timeout=1.0) is False
        assert router.states()[1] == "unhealthy"
        gate.set()
        straggler.result(timeout=10)
        assert router.probe(1, b[3]) is True
        assert router.states()[1] == "healthy"
    finally:
        gate.set()
        router.close()


def test_failover_during_drain_parks_ticket_until_revival():
    """A replica failing while the only other one is draining must not
    terminally fail admitted tickets (the tier is transiently
    unroutable, not down): the ticket parks and the next successful
    probe flushes it."""
    fail = [1]

    def search1(c):
        if fail[0] > 0:
            fail[0] -= 1
            raise RuntimeError("die once")
        return c * 2, c + 1

    router = QueryRouter(ReplicaSet(
        [_identity_replica(), ((lambda x: x), search1)],
        config=ServingConfig(queue_depth=8),
    ))
    try:
        router.drain(0, timeout=1.0)  # out of rotation but revivable
        t = router.submit(_batches(1)[0])  # only replica 1 routable; dies
        deadline = time.time() + 10
        while time.time() < deadline and router.states()[1] != "unhealthy":
            time.sleep(0.005)
        assert router.states()[1] == "unhealthy"
        time.sleep(0.05)
        assert not t.done()  # parked, not dropped: replica 0 may return
        assert router.probe(1, _batches(2)[1]) is True  # revival flushes
        vals, ids = t.result(timeout=10)
        np.testing.assert_array_equal(np.asarray(vals), np.full((4,), 0))
        np.testing.assert_array_equal(np.asarray(ids), np.full((4,), 1))
    finally:
        router.close()


def test_probe_canary_mismatch_fails_the_probe():
    router = QueryRouter(ReplicaSet(
        [_identity_replica(), _flaky_replica([1])],
        config=ServingConfig(queue_depth=4),
    ))
    try:
        b = _batches(4)
        router.submit(b[0]).result(timeout=10)
        try:
            router.submit(b[1]).result(timeout=10)
        except RuntimeError:
            pass  # round-robin timing may surface the fault directly
        deadline = time.time() + 10
        while time.time() < deadline and router.states()[1] != "unhealthy":
            try:
                router.submit(b[2]).result(timeout=10)
            except RuntimeError:
                pass
        assert router.states()[1] == "unhealthy"
        wrong = (np.zeros((4,)), np.zeros((4,)))  # not the identity answer
        assert router.probe(1, b[0], expect=wrong) is False
        assert router.states()[1] == "unhealthy"
        good = (b[0] * 2, b[0] + 1)
        assert router.probe(1, b[0], expect=good) is True
        assert router.states()[1] == "healthy"
    finally:
        router.close()


# ---------------------------------------------------------------------------
# pipeline-level drain-without-close (quiesce / swap_fns / new_generation)
# ---------------------------------------------------------------------------


def test_quiesce_swap_fns_and_generation_on_live_pipeline():
    pipe = ServingPipeline((lambda x: x), (lambda c: (c * 2, c + 1)),
                           config=ServingConfig(queue_depth=4))
    try:
        b = _batches(3)
        for i in range(2):
            pipe.submit(b[i]).result(timeout=10)
        assert pipe.quiesce(timeout=10) is True
        s = pipe.stats()
        assert s["generation"] == 0 and s["requests"] == 2
        pipe.swap_fns(search_fn=lambda c: (c * 3, c + 7))
        gen = pipe.new_generation()
        assert gen == 1
        vals, ids = pipe.submit(b[2]).result(timeout=10)
        np.testing.assert_array_equal(np.asarray(vals), np.full((4,), 6))
        np.testing.assert_array_equal(np.asarray(ids), np.full((4,), 9))
        s = pipe.stats()
        assert s["generation"] == 1
        assert s["requests"] == 1  # new generation counts only its own
        assert s["lifetime_requests"] == 3
    finally:
        pipe.close()


def test_quiesce_times_out_while_scan_is_stuck():
    gate = threading.Event()

    def stuck(c):
        gate.wait(timeout=10)
        return c, c

    pipe = ServingPipeline((lambda x: x), stuck,
                           config=ServingConfig(queue_depth=4))
    try:
        t = pipe.submit(_batches(1)[0])
        assert pipe.quiesce(timeout=0.05) is False
        gate.set()
        t.result(timeout=10)
        assert pipe.quiesce(timeout=10) is True
    finally:
        gate.set()
        pipe.close()
