"""Deterministic fault injection (launch/faults.py) + the robustness
knobs it drives: --chaos spec parsing (bad clauses fail loudly), the
four fault kinds on schedule, seeded-probabilistic replay, stuck-call
release semantics, the EffortKnob / probe_backoff primitives, and the
index closures' effort degradation (level 0 bit-identical to the
dedicated closure; level L equal to the closure built with the halved
search params)."""

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.index import hnsw_lite
from repro.index import ivf as ivf_lib
from repro.kernels.sdc import ref as R
from repro.launch.clock import FakeClock
from repro.launch.faults import (
    FaultEvent,
    FaultInjector,
    FaultPlan,
    InjectedFault,
    apply_chaos,
    parse_chaos_spec,
    wrap_replicas,
)
from repro.launch.proxy import EffortKnob, probe_backoff

LEVELS = 4


def _identity_pair():
    return (lambda x: ("enc", x)), (lambda c: ("scan", c))


def _injector(plan):
    enc, scan = _identity_pair()
    return FaultInjector(enc, scan, plan, name="t")


# ---------------------------------------------------------------------------
# spec parsing
# ---------------------------------------------------------------------------


def test_parse_chaos_spec_full_grammar():
    plans = parse_chaos_spec(
        "r1.search.fail@10x3, delay@0x*:0.02, encode.fail~0.25, seed=7"
    )
    assert set(plans) == {0, 1}
    assert all(p.seed == 7 for p in plans.values())
    (f,) = plans[1].events
    assert (f.kind, f.stage, f.at, f.count) == ("fail", "search", 10, 3)
    d, e = plans[0].events
    assert (d.kind, d.at, d.count, d.arg) == ("delay", 0, 0, 0.02)
    assert (e.kind, e.stage, e.prob) == ("fail", "encode", 0.25)


@pytest.mark.parametrize("spec", [
    "bogus",                 # unknown clause shape
    "r1.explode@3",          # unknown kind
    "fail~1.5",              # prob out of range
    "seed=x",                # bad seed
    "delay@3",               # delay without :ARG (seconds)
    "flap@0x4:2",            # flap period < count
    "search.fail@3x2:nope",  # unparseable arg
])
def test_parse_chaos_spec_rejects_bad_clauses(spec):
    with pytest.raises(ValueError):
        parse_chaos_spec(spec)


def test_apply_chaos_none_is_untouched_and_bad_replica_rejected():
    replicas = [_identity_pair(), _identity_pair()]
    out, injectors = apply_chaos(replicas, None)
    assert out == list(replicas) and injectors == {}
    with pytest.raises(ValueError, match="replica 5"):
        wrap_replicas(replicas, {5: FaultPlan.fail_first(1)})


# ---------------------------------------------------------------------------
# fault kinds on schedule
# ---------------------------------------------------------------------------


def test_fail_first_then_recovers_and_logs():
    inj = _injector(FaultPlan.fail_first(2))
    for i in range(2):
        with pytest.raises(InjectedFault, match=f"search call {i}"):
            inj.search(i)
    assert inj.search("x") == ("scan", "x")  # recovered
    assert inj.encode("e") == ("enc", "e")  # other stage untouched
    assert inj.log == [("search", 0, "fail"), ("search", 1, "fail")]
    assert inj.calls == {"encode": 1, "search": 3}


def test_fail_after_fails_forever_and_fail_at_picks_indices():
    inj = _injector(FaultPlan.fail_after(1))
    inj.search(0)
    for _ in range(3):
        with pytest.raises(InjectedFault):
            inj.search(1)

    inj = _injector(FaultPlan.fail_at(1, 3))
    outcomes = []
    for i in range(5):
        try:
            inj.search(i)
            outcomes.append(True)
        except InjectedFault:
            outcomes.append(False)
    assert outcomes == [True, False, True, False, True]


def test_delay_every_sleeps_then_calls_through():
    """Runs on FakeClock via the injector's clock kwarg: the delay is
    proven to park on the clock for the scheduled duration rather than
    measured against a noisy host timer."""
    clk = FakeClock()
    enc, scan = _identity_pair()
    inj = FaultInjector(enc, scan, FaultPlan.delay_every(0.05, at=1),
                        name="t", clock=clk)
    assert inj.search(0) == ("scan", 0)
    assert clk.sleepers == 0  # before `at`: no delay, clock untouched
    out = []
    th = threading.Thread(target=lambda: out.append(inj.search(1)))
    th.start()
    assert clk.wait_for_sleepers(1)  # the delayed call parks on the clock
    assert th.is_alive() and not out
    clk.advance(0.05)  # serve out exactly the scheduled delay
    th.join(timeout=5)
    assert not th.is_alive()
    assert out == [("scan", 1)]


def test_stick_blocks_until_release_then_calls_through():
    inj = _injector(FaultPlan.stick_at(0))
    out = []
    th = threading.Thread(target=lambda: out.append(inj.search("q")))
    th.start()
    deadline = time.time() + 5  # wait on the observable, not a timer
    while time.time() < deadline and inj.stuck_count == 0:
        time.sleep(0.002)
    assert th.is_alive() and inj.stuck_count == 1 and not out
    inj.release()
    th.join(timeout=5)
    assert not th.is_alive()
    assert out == [("scan", "q")]  # a hung scan completes, never raises
    # after release(), later stick events are no-ops
    inj2 = _injector(FaultPlan.stick_at(0))
    inj2.release()
    assert inj2.search("q") == ("scan", "q")


def test_flap_fires_periodically():
    inj = _injector(FaultPlan([
        FaultEvent("flap", at=2, count=1, arg=3.0)  # calls 2, 5, 8, ...
    ]))
    outcomes = []
    for i in range(9):
        try:
            inj.search(i)
            outcomes.append(True)
        except InjectedFault:
            outcomes.append(False)
    assert outcomes == [True, True, False, True, True, False, True, True,
                        False]


def test_probabilistic_schedule_replays_exactly_per_seed():
    def schedule(seed):
        inj = _injector(FaultPlan(
            [FaultEvent("fail", prob=0.4)], seed=seed
        ))
        out = []
        for i in range(40):
            try:
                inj.search(i)
                out.append(True)
            except InjectedFault:
                out.append(False)
        return out

    a, b = schedule(3), schedule(3)
    assert a == b  # same seed -> identical fault schedule
    assert not all(a) and any(a)  # it actually fires sometimes
    assert schedule(4) != a  # and the seed matters


def test_encode_prob_clause_does_not_perturb_search_schedule():
    plan = FaultPlan([FaultEvent("fail", stage="search", prob=0.4)], seed=5)
    both = FaultPlan([FaultEvent("fail", stage="search", prob=0.4),
                      FaultEvent("fail", stage="encode", prob=0.4)], seed=5)

    def search_schedule(p, interleave_encodes):
        inj = _injector(p)
        out = []
        for i in range(30):
            if interleave_encodes:
                try:
                    inj.encode(i)
                except InjectedFault:
                    pass
            try:
                inj.search(i)
                out.append(True)
            except InjectedFault:
                out.append(False)
        return out

    assert search_schedule(plan, False) == search_schedule(both, True)


# ---------------------------------------------------------------------------
# effort knob + probe backoff
# ---------------------------------------------------------------------------


def test_effort_knob_bounds_and_counters():
    knob = EffortKnob(3)
    assert knob.level == 0 and knob.max_level == 2
    assert knob.degrade() and knob.level == 1
    assert knob.degrade() and knob.level == 2
    assert not knob.degrade() and knob.level == 2  # floor
    assert knob.restore() and knob.level == 1
    assert knob.restore() and knob.level == 0
    assert not knob.restore() and knob.level == 0  # ceiling
    knob.degrade()
    knob.reset()
    assert knob.level == 0
    assert not EffortKnob(1).degrade()  # single-level knob: a no-op
    with pytest.raises(ValueError):
        EffortKnob(0)


def test_probe_backoff_doubles_and_caps():
    assert probe_backoff(0.1, 0) == 0.0
    got = [probe_backoff(0.1, n) for n in range(1, 6)]
    np.testing.assert_allclose(got, [0.1, 0.2, 0.4, 0.8, 1.6])
    assert probe_backoff(0.1, 50) == pytest.approx(0.1 * 16.0)  # capped


# ---------------------------------------------------------------------------
# index closures honour the effort knob
# ---------------------------------------------------------------------------


def _code_corpus(n=400, q=16, dim=32, seed=0):
    key = jax.random.PRNGKey(seed)
    cd = jax.random.randint(key, (n, dim), 0, 2**LEVELS).astype(jnp.int8)
    cq = jax.random.randint(
        jax.random.fold_in(key, 1), (q, dim), 0, 2**LEVELS
    ).astype(jnp.int8)
    return cd, cq


def _assert_same(a, b):
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))


def test_ivf_effort_levels_match_dedicated_nprobe_closures():
    cd, cq = _code_corpus()
    kw = dict(k=10, nlist=8, nprobe=4, seed=1, kmeans_iters=3, backend="xla")
    plain = ivf_lib.ivf_search_from_snapshot(cd, LEVELS, **kw)
    knob = EffortKnob(3)
    fn = ivf_lib.ivf_search_from_snapshot(cd, LEVELS, effort=knob, **kw)
    assert fn.effort is knob
    _assert_same(fn(cq), plain(cq))  # level 0: bit-identical
    knob.degrade()  # level 1 == a closure built with nprobe >> 1
    half = ivf_lib.ivf_search_from_snapshot(
        cd, LEVELS, **{**kw, "nprobe": 2}
    )
    _assert_same(fn(cq), half(cq))
    knob.degrade()
    knob.degrade()  # floor: nprobe never drops below 1
    floor = ivf_lib.ivf_search_from_snapshot(
        cd, LEVELS, **{**kw, "nprobe": 1}
    )
    _assert_same(fn(cq), floor(cq))


def test_hnsw_effort_levels_match_dedicated_ef_beam_closures():
    cd, cq = _code_corpus()
    kw = dict(k=10, M=8, ef_construction=24, ef=24, beam=8, seed=0,
              backend="xla")
    plain = hnsw_lite.hnsw_search_from_snapshot(np.asarray(cd), LEVELS, **kw)
    knob = EffortKnob(3)
    fn = hnsw_lite.hnsw_search_from_snapshot(
        np.asarray(cd), LEVELS, effort=knob, **kw
    )
    assert fn.effort is knob
    _assert_same(fn(cq), plain(cq))  # level 0: bit-identical
    knob.degrade()  # level 1 == ef/2, beam/2 (floored at k and 1)
    half = hnsw_lite.hnsw_search_from_snapshot(
        np.asarray(cd), LEVELS, **{**kw, "ef": 12, "beam": 4}
    )
    _assert_same(fn(cq), half(cq))
    knob.degrade()  # ef floors at k=10 (24 >> 2 = 6 < k), beam at 2
    floor = hnsw_lite.hnsw_search_from_snapshot(
        np.asarray(cd), LEVELS, **{**kw, "ef": 10, "beam": 2}
    )
    _assert_same(fn(cq), floor(cq))


def test_effort_level_zero_matches_reference_scan():
    cd, cq = _code_corpus()
    knob = EffortKnob(2)
    fn = ivf_lib.ivf_search_from_snapshot(
        cd, LEVELS, k=10, nlist=1, nprobe=1, seed=1, kmeans_iters=1,
        backend="xla", effort=knob,
    )
    vals, ids = fn(cq)
    ev, ei = jax.lax.top_k(R.sdc_ref(cq, cd, LEVELS), 10)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ei))
    np.testing.assert_allclose(np.asarray(vals), np.asarray(ev), rtol=1e-5)
