"""Bi-granular fine rerank: bit-identity to a restricted flat scan.

The tentpole invariant of the coarse-scan + fine-rerank mode: reranking
the coarse survivors against the full-level codes must be BIT-IDENTICAL
to a full-level flat scan restricted to exactly those ids — packed and
unpacked, Pallas-interpret and jnp-twin backends, the host-gathered
cold-tier path (``np.memmap`` included), and the k' < k degenerate case
where the survivor set cannot even fill the top-k. Plus the snapshot /
rerank-arg validation and the k_coarse-first effort split the serving
tier leans on.
"""

import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.binarize_lib import SDC_NEG_INF, pack_codes_nibbles
from repro.index._snapshot import (
    resolve_rerank_args,
    resolve_snapshot_args,
    split_effort,
)
from repro.index.flat import BiGranularFlat, FlatSDC, flat_search_from_snapshot
from repro.kernels.sdc import ref as R
from repro.kernels.sdc.ops import sdc_search_xla
from repro.kernels.sdc.rerank import (
    fine_inv_norms,
    sdc_rerank,
    sdc_rerank_backend,
    sdc_rerank_gathered,
    sdc_rerank_xla,
)

LEVELS = 4


def _world(seed, n=96, q=3, d=8):
    key = jax.random.PRNGKey(seed)
    cd = jax.random.randint(key, (n, d), 0, 2**LEVELS).astype(jnp.int8)
    cq = jax.random.randint(jax.random.fold_in(key, 1), (q, d), 0,
                            2**LEVELS).astype(jnp.int8)
    return cd, cq, R.doc_inv_norms(cd, LEVELS)


def _candidates(seed, n, q, kp, n_invalid=0):
    """Distinct survivor ids per query, shuffled (NOT pre-sorted — the
    rerank must impose its own ascending-id order), with ``n_invalid``
    trailing -1 slots mixed in."""
    rng = np.random.default_rng(seed)
    cand = np.stack([
        rng.choice(n, size=kp, replace=False) for _ in range(q)
    ]).astype(np.int32)
    if n_invalid:
        for r in range(q):
            cand[r, rng.choice(kp, size=n_invalid, replace=False)] = -1
    return cand


def _restricted_scan(cq, cd, inv, cand, k):
    """Reference: a full-level flat scan over ONLY each query's candidate
    rows (gathered in ascending-id order, the column order of the full
    scan — so top-k tie-breaking matches)."""
    cd_np, inv_np = np.asarray(cd), np.asarray(inv)
    scores = np.full((cq.shape[0], k), SDC_NEG_INF, np.float32)
    ids = np.full((cq.shape[0], k), -1, np.int32)
    for qi in range(cq.shape[0]):
        c = np.asarray(cand[qi])
        c = np.sort(c[c >= 0])
        v, i = sdc_search_xla(
            cq[qi:qi + 1], jnp.asarray(cd_np[c]), jnp.asarray(inv_np[c]),
            n_levels=LEVELS, k=k,
        )
        v, i = np.asarray(v)[0], np.asarray(i)[0]
        scores[qi] = v
        ids[qi] = np.where(i >= 0, c[np.clip(i, 0, len(c) - 1)], -1)
    return scores, ids


def _assert_same(got, want):
    gs, gi = np.asarray(got[0]), np.asarray(got[1])
    np.testing.assert_array_equal(gi, want[1])
    np.testing.assert_array_equal(gs, want[0])


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), kp=st.sampled_from([5, 16]))
def test_rerank_bit_identical_to_restricted_scan(seed, kp):
    """interpret kernel, jnp twin, and host-gather all equal the
    restricted full-level scan exactly — scores AND ids, ties included
    (int8 codes collide constantly at d=8)."""
    cd, cq, inv = _world(seed)
    cand = _candidates(seed, cd.shape[0], cq.shape[0], kp)
    k = 4
    ref = _restricted_scan(cq, cd, inv, cand, k)
    _assert_same(
        sdc_rerank(cq, cd, inv, jnp.asarray(cand), n_levels=LEVELS, k=k,
                   interpret=True), ref)
    _assert_same(
        sdc_rerank_xla(cq, cd, inv, jnp.asarray(cand), n_levels=LEVELS, k=k),
        ref)
    _assert_same(
        sdc_rerank_gathered(cq, np.asarray(cd), np.asarray(inv), cand,
                            n_levels=LEVELS, k=k), ref)


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_packed_rerank_bit_identical_to_unpacked_reference(seed):
    """Nibble-packed fine codes go through the even/odd half-matmul
    decomposition — same integer sums, so bit-identical to the unpacked
    restricted scan (kernel-interpret and twin both)."""
    cd, cq, inv = _world(seed)
    cand = _candidates(seed + 1, cd.shape[0], cq.shape[0], 12)
    k = 4
    ref = _restricted_scan(cq, cd, inv, cand, k)
    pd = pack_codes_nibbles(cd)
    _assert_same(
        sdc_rerank(cq, pd, inv, jnp.asarray(cand), n_levels=LEVELS, k=k,
                   interpret=True, packed=True), ref)
    _assert_same(
        sdc_rerank_xla(cq, pd, inv, jnp.asarray(cand), n_levels=LEVELS, k=k,
                       packed=True), ref)
    _assert_same(
        sdc_rerank_gathered(cq, np.asarray(pd), np.asarray(inv), cand,
                            n_levels=LEVELS, k=k, packed=True), ref)


def test_degenerate_fewer_survivors_than_k():
    """k' < k: the rerank pads with (SDC_NEG_INF, -1) instead of reading
    out of range — and the filled prefix still matches the restricted
    scan."""
    cd, cq, inv = _world(7)
    cand = _candidates(7, cd.shape[0], cq.shape[0], 3)
    k = 10
    ref = _restricted_scan(cq, cd, inv, cand, k)
    out = sdc_rerank_xla(cq, cd, inv, jnp.asarray(cand), n_levels=LEVELS, k=k)
    _assert_same(out, ref)
    ids = np.asarray(out[1])
    assert (ids[:, 3:] == -1).all()
    assert (np.asarray(out[0])[:, 3:] == SDC_NEG_INF).all()


def test_invalid_slots_are_masked_not_clamped():
    """-1 survivor slots must not leak doc 0 (the kernel clamps probes
    into range; only cand_mask/id masking can exclude them)."""
    cd, cq, inv = _world(11)
    cand = _candidates(11, cd.shape[0], cq.shape[0], 8, n_invalid=3)
    k = 6
    ref = _restricted_scan(cq, cd, inv, cand, k)
    _assert_same(
        sdc_rerank(cq, cd, inv, jnp.asarray(cand), n_levels=LEVELS, k=k,
                   interpret=True), ref)
    _assert_same(
        sdc_rerank_gathered(cq, np.asarray(cd), np.asarray(inv), cand,
                            n_levels=LEVELS, k=k), ref)


def test_backend_dispatch_memmap_cold_tier(tmp_path):
    """A memory-mapped fine tier takes the host-gather path and still
    matches the restricted scan bit-for-bit; fine_inv_norms streams the
    cold tier in chunks to the same values as a single-shot compute."""
    cd, cq, inv = _world(3)
    path = tmp_path / "fine.codes"
    mm = np.memmap(path, dtype=np.int8, mode="w+", shape=cd.shape)
    mm[:] = np.asarray(cd)
    mm.flush()
    cold = np.memmap(path, dtype=np.int8, mode="r", shape=cd.shape)
    inv_cold = fine_inv_norms(cold, LEVELS, chunk=17)
    np.testing.assert_array_equal(inv_cold, np.asarray(inv))
    cand = _candidates(3, cd.shape[0], cq.shape[0], 9)
    k = 5
    ref = _restricted_scan(cq, cd, inv, cand, k)
    _assert_same(
        sdc_rerank_backend(cq, cold, inv_cold, cand, n_levels=LEVELS, k=k),
        ref)


def test_bigranular_full_depth_equals_flat_search():
    """k_coarse = N degenerates to the plain full-level flat scan: every
    doc survives the coarse stage, so the rerank IS the flat scan."""
    cd, cq, inv = _world(5, n=128)
    bigr = BiGranularFlat.build(cd, LEVELS, coarse_levels=2,
                                k_coarse=cd.shape[0])
    flat = FlatSDC.build(cd, LEVELS, backend="xla")
    _assert_same(bigr.search(cq, 10),
                 tuple(np.asarray(x) for x in flat.search(cq, 10)))


def test_rerank_recall_never_below_coarse_recall():
    """Any true top-k doc the coarse scan surfaces in its top-k' is
    recovered by the exact fine rerank — rerank recall dominates the
    coarse-only recall it refines."""
    from repro.core.binarize_lib import coarse_codes

    cd, cq, inv = _world(17, n=256, q=8)
    k = 10
    _, gt = sdc_search_xla(cq, cd, inv, n_levels=LEVELS, k=k)
    gt = np.asarray(gt)
    bigr = BiGranularFlat.build(cd, LEVELS, coarse_levels=2, k_coarse=4 * k)
    _, ids_r = bigr.search(cq, k)
    _, ids_c = bigr.coarse.search(coarse_codes(cq, LEVELS, 2), k)

    def recall(ids):
        ids = np.asarray(ids)
        return np.mean([
            len(set(ids[i]) & set(gt[i])) / k for i in range(gt.shape[0])
        ])

    assert recall(ids_r) >= recall(ids_c)


def test_snapshot_closure_carries_rerank_provenance_and_effort():
    """flat_search_from_snapshot(..., rerank=...) marks the closure
    reranked (the serving tier stamps provenance off it); effort level 0
    is bit-identical to no effort, and degradation levels halve k'
    (floored via split_effort)."""
    cd, cq, _ = _world(23, n=128)
    rr = {"coarse_levels": 2, "k_coarse": 32}
    plain = flat_search_from_snapshot(cd, LEVELS, k=5, rerank=rr)
    assert plain.reranked is True
    knob = types.SimpleNamespace(level=0)
    with_knob = flat_search_from_snapshot(cd, LEVELS, k=5, rerank=rr,
                                          effort=knob)
    assert with_knob.reranked is True
    _assert_same(with_knob(cq), tuple(np.asarray(x) for x in plain(cq)))
    # deep degradation: the closure re-reads the knob per call and lands
    # on split_effort's k' floor (32 -> 16 -> 8; 8 // 5 halts halving)
    knob.level = 9
    kc_floor, _ = split_effort(9, k=5, k_coarse=32)
    bigr = BiGranularFlat.build(cd, LEVELS, coarse_levels=2, k_coarse=32)
    _assert_same(
        with_knob(cq),
        tuple(np.asarray(x) for x in bigr.search(cq, 5, k_coarse=kc_floor)))


def test_split_effort_halves_k_coarse_first():
    # level 0: full effort, nothing spent
    assert split_effort(0, k=10, k_coarse=160) == (160, 0)
    # each level halves k'; nothing falls through while k' > k
    assert split_effort(1, k=10, k_coarse=160) == (80, 0)
    assert split_effort(3, k=10, k_coarse=160) == (20, 0)
    # k' floors at k (160 >> 4 = 10); surplus levels fall through to the
    # family's own knobs (nprobe/ef/beam)
    assert split_effort(4, k=10, k_coarse=160) == (10, 0)
    assert split_effort(6, k=10, k_coarse=160) == (10, 2)
    # k' already at the floor: everything falls through
    assert split_effort(2, k=10, k_coarse=10) == (10, 2)


def test_resolve_rerank_args_validation():
    assert resolve_rerank_args(None, 4) is None
    assert resolve_rerank_args({"coarse_levels": 2, "k_coarse": 64}, 4) \
        == (2, 64)
    with pytest.raises(ValueError, match="keys"):
        resolve_rerank_args({"coarse_levels": 2}, 4)
    with pytest.raises(ValueError, match="keys"):
        resolve_rerank_args(
            {"coarse_levels": 2, "k_coarse": 64, "typo": 1}, 4)
    with pytest.raises(ValueError, match="coarse_levels"):
        resolve_rerank_args({"coarse_levels": 4, "k_coarse": 64}, 4)
    with pytest.raises(ValueError, match="coarse_levels"):
        resolve_rerank_args({"coarse_levels": 0, "k_coarse": 64}, 4)
    with pytest.raises(ValueError, match="k_coarse"):
        resolve_rerank_args({"coarse_levels": 2, "k_coarse": 0}, 4)


def test_snapshot_with_codes_but_no_levels_is_rejected():
    """Satellite fix: a malformed snapshot (codes present, n_levels
    None) must raise a clear TypeError instead of blaming the caller
    for omitting n_levels."""
    snap = types.SimpleNamespace(codes=np.zeros((4, 8), np.int8),
                                 n_levels=None)
    with pytest.raises(TypeError, match="n_levels is None"):
        resolve_snapshot_args(snap, None)
