"""Transformer invariants: decode==prefill, chunked==full attention,
int8 cache error bound, MoE capacity behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import (
    TransformerConfig,
    decode_step,
    forward,
    init_kv_cache,
    init_params,
    lm_loss,
    prefill,
)


def _cfg(**kw):
    base = dict(name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                d_ff=64, vocab=64, head_dim=8, dtype=jnp.float32, remat=False,
                attn_chunk=0)
    base.update(kw)
    return TransformerConfig(**base)


def test_chunked_attention_equals_full():
    cfg_f = _cfg()
    cfg_c = _cfg(attn_chunk=4)
    p = init_params(jax.random.PRNGKey(0), cfg_f)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
    lf, _ = forward(p, toks, cfg_f)
    lc, _ = forward(p, toks, cfg_c)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lc), atol=1e-5)


@pytest.mark.parametrize("moe", [False, True])
def test_decode_matches_forward(moe):
    # capacity_factor high enough that full-seq routing drops nothing —
    # otherwise train-time capacity drops are a real (expected) divergence
    # from per-token decode routing.
    kw = dict(n_experts=4, top_k=2, capacity_factor=8.0) if moe else {}
    cfg = _cfg(**kw)
    p = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 64)
    cache = init_kv_cache(cfg, 2, 16, dtype=jnp.float32)
    outs = []
    for t in range(8):
        lg, cache = decode_step(p, toks[:, t], cache, cfg)
        outs.append(lg)
    full, _ = forward(p, toks, cfg)
    # MoE decode routes per-token with tiny capacity => small drift allowed
    atol = 2e-2 if moe else 1e-5
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), atol=atol)


def test_prefill_is_last_position_of_forward():
    cfg = _cfg()
    p = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (3, 12), 0, 64)
    full, _ = forward(p, toks, cfg)
    last = prefill(p, toks, cfg)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, -1, :]),
                               atol=1e-5)


def test_int8_cache_close_to_fp_cache():
    cfg = _cfg()
    p = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, 64)
    c_fp = init_kv_cache(cfg, 2, 16, dtype=jnp.float32)
    c_q = init_kv_cache(cfg, 2, 16, dtype=jnp.int8)
    assert "k_scale" in c_q
    for t in range(10):
        lf, c_fp = decode_step(p, toks[:, t], c_fp, cfg)
        lq, c_q = decode_step(p, toks[:, t], c_q, cfg)
    rel = float(jnp.max(jnp.abs(lf - lq)) / (jnp.max(jnp.abs(lf)) + 1e-9))
    assert rel < 0.05  # int8 cache: small bounded error


def test_moe_capacity_drops_overflow():
    cfg = _cfg(n_experts=2, top_k=1, capacity_factor=0.5)
    p = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
    logits, aux = forward(p, toks, cfg)
    assert not bool(jnp.isnan(logits).any())
    assert float(aux) > 0  # load-balance loss present


def test_loss_differentiable_and_finite():
    for moe in (False, True):
        kw = dict(n_experts=4, top_k=2) if moe else {}
        cfg = _cfg(**kw)
        p = init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 64)
        loss, g = jax.value_and_grad(lm_loss)(p, toks, toks, cfg)
        assert np.isfinite(float(loss))
        gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree_util.tree_leaves(g))
        assert np.isfinite(gn) and gn > 0


def test_microbatch_accumulation_matches_full_batch():
    from repro.train import optim, steps

    cfg1 = _cfg(microbatches=1)
    cfg4 = _cfg(microbatches=4)
    p = init_params(jax.random.PRNGKey(0), cfg1)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 64)
    batch = {"tokens": toks, "labels": toks}
    adam = optim.AdamConfig(lr=1e-2, clip_norm=0.0)
    opt = optim.adam_init(p)
    p1, _, m1 = jax.jit(steps.lm_train_step(cfg1, adam))(p, opt, batch)
    p4, _, m4 = jax.jit(steps.lm_train_step(cfg4, adam))(p, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)
