"""Version-aware serving: SearchRequest/SearchResult, compat routing.

The serving face of compatible training (paper §3.2.3): typed requests
carry an ``embedding_version``, the router prefers native-version
replicas and falls back through a ``CompatibilityMatrix`` encoder, and
a tier with no path to the request's version fails typed
(``IncompatibleVersion``), not hung or silently wrong-versioned.

Encoders here are untrained random-projection binarizers
(``make_encode_fn`` over ``hidden_dim=0`` weights) — routing semantics
and bit-identity do not need recall; ``tests/test_compat.py`` owns the
bc-trained recall floor.
"""

import jax
import numpy as np
import pytest

from repro.core import BinarizerConfig, init_binarizer, make_encode_fn
from repro.launch.lifecycle import (
    CorpusSnapshot,
    FlatBuilder,
    UnknownBuildParam,
    builder_version,
    make_builder,
)
from repro.launch.proxy import (
    AllReplicasDown,
    CompatibilityMatrix,
    QueryRouter,
    ReplicaSet,
)
from repro.launch.serving import (
    IncompatibleVersion,
    RequestShed,
    SearchRequest,
    SearchResult,
    ServingConfig,
    ServingPipeline,
    serve_sequential,
)

DIM, CODE, LEVELS, K = 16, 8, 2, 5
N_DOCS, BATCH = 64, 4


def _encoder(seed: int):
    cfg = BinarizerConfig(input_dim=DIM, code_dim=CODE, n_levels=LEVELS,
                          hidden_dim=0)
    p, s = init_binarizer(jax.random.PRNGKey(seed), cfg)
    return make_encode_fn(p, s, cfg)


@pytest.fixture(scope="module")
def world():
    rng = np.random.default_rng(0)
    docs = rng.normal(size=(N_DOCS, DIM)).astype(np.float32)
    queries = rng.normal(size=(BATCH, DIM)).astype(np.float32)
    enc_v1, enc_v2, enc_compat = _encoder(1), _encoder(2), _encoder(3)
    builder = FlatBuilder(k=K)
    snap_v1 = CorpusSnapshot(codes=np.asarray(enc_v1(docs)),
                             n_levels=LEVELS, embedding_version="v1")
    snap_v2 = CorpusSnapshot(codes=np.asarray(enc_v2(docs)),
                             n_levels=LEVELS, embedding_version="v2")
    return dict(
        docs=docs, queries=queries, builder=builder,
        enc_v1=enc_v1, enc_v2=enc_v2, enc_compat=enc_compat,
        snap_v1=snap_v1, snap_v2=snap_v2,
        search_v1=builder.build(snap_v1), search_v2=builder.build(snap_v2),
        ver_v1=builder_version(builder, snap_v1),
        ver_v2=builder_version(builder, snap_v2),
    )


def _eq(a, b):
    (va, ia), (vb, ib) = (a[0], a[1]), (b[0], b[1])
    return (np.array_equal(np.asarray(ia), np.asarray(ib))
            and np.array_equal(np.asarray(va), np.asarray(vb)))


# ---------------------------------------------------------------------------
# SearchRequest / SearchResult shapes
# ---------------------------------------------------------------------------


def test_search_request_validates():
    with pytest.raises(ValueError):
        SearchRequest()  # neither queries nor codes
    with pytest.raises(ValueError):
        SearchRequest(queries=np.zeros((1, 2)), codes=np.zeros((1, 2)))
    with pytest.raises(ValueError):
        SearchRequest(queries=np.zeros((1, 2)), k=0)
    req = SearchRequest(queries=np.zeros((3, 2)))
    assert req.n_queries == 3


def test_search_result_unpacks_like_tuple():
    r = SearchResult(scores=np.arange(2), ids=np.arange(2) + 10,
                     served_by_version="v1", replica=0, generation=1)
    vals, ids = r
    assert np.array_equal(vals, r.scores) and np.array_equal(ids, r.ids)
    assert np.array_equal(r[0], r.scores) and np.array_equal(r[1], r.ids)
    assert len(r) == 2


def test_error_taxonomy():
    # Terminal like AllReplicasDown, NOT a retryable shed: retry loops
    # keyed on RequestShed must not spin on a version dead-end.
    assert issubclass(IncompatibleVersion, RuntimeError)
    assert not issubclass(IncompatibleVersion, RequestShed)
    assert not issubclass(IncompatibleVersion, AllReplicasDown)


# ---------------------------------------------------------------------------
# pipeline-level: typed path vs legacy shim
# ---------------------------------------------------------------------------


def test_bare_batch_and_request_paths_bit_identical(world):
    w = world
    ref = serve_sequential(w["enc_v1"], w["search_v1"], [w["queries"]])[0]
    with ServingPipeline(w["enc_v1"], w["search_v1"]) as pipe:
        legacy = pipe.submit(w["queries"]).result()
        typed = pipe.submit(SearchRequest(queries=w["queries"])).result()
    assert _eq(legacy, ref) and _eq(typed, ref)


def test_codes_bypass_skips_encode(world):
    w = world
    codes = w["enc_v1"](w["queries"])

    def poisoned_encode(_):
        raise AssertionError("encode stage must be bypassed for codes")

    with ServingPipeline(poisoned_encode, w["search_v1"]) as pipe:
        got = pipe.submit(SearchRequest(codes=codes)).result()
    assert _eq(got, w["search_v1"](codes))


def test_request_k_truncates(world):
    w = world
    ref = serve_sequential(w["enc_v1"], w["search_v1"], [w["queries"]])[0]
    with ServingPipeline(w["enc_v1"], w["search_v1"]) as pipe:
        vals, ids = pipe.submit(
            SearchRequest(queries=w["queries"], k=3)
        ).result()
    assert vals.shape == (BATCH, 3) and ids.shape == (BATCH, 3)
    assert _eq((vals, ids), (ref[0][:, :3], ref[1][:, :3]))


# ---------------------------------------------------------------------------
# router-level: version routing, compat fallback, typed dead-end
# ---------------------------------------------------------------------------


def test_incompatible_version_is_typed_and_terminal(world):
    w = world
    router = QueryRouter(ReplicaSet([(w["enc_v1"], w["search_v1"])]))
    router.set_version(0, w["ver_v1"])
    try:
        with pytest.raises(IncompatibleVersion) as exc:
            router.submit(SearchRequest(queries=w["queries"],
                                        embedding_version="v2"))
        assert "v2" in str(exc.value)
        # Unversioned and native traffic still flow.
        assert _eq(
            router.submit(w["queries"]).result(),
            serve_sequential(w["enc_v1"], w["search_v1"], [w["queries"]])[0],
        )
    finally:
        router.close()


def test_compat_fallback_bit_identical_with_provenance(world):
    w = world
    compat = CompatibilityMatrix()
    compat.register("v2", "v1", w["enc_compat"])
    router = QueryRouter(ReplicaSet([(w["enc_v1"], w["search_v1"])]),
                         compat=compat)
    router.set_version(0, w["ver_v1"])
    try:
        t = router.submit(SearchRequest(queries=w["queries"],
                                        embedding_version="v2"))
        res = t.search_result()
        # The compat hop re-encodes with the registered encoder and
        # serves from the v1 index — bit-identical to that path run
        # sequentially.
        ref = serve_sequential(w["enc_compat"], w["search_v1"],
                               [w["queries"]])[0]
        assert _eq(res, ref)
        assert res.served_by_version == "v1"
        assert res.compat_encoded and res.replica == 0
        stats = router.stats()
        assert stats["compat_dispatches"] == 1
        assert stats["per_replica"][0]["embedding_version"] == "v1"
    finally:
        router.close()


def test_codes_request_cannot_take_compat_hop(world):
    w = world
    compat = CompatibilityMatrix()
    compat.register("v2", "v1", w["enc_compat"])
    router = QueryRouter(ReplicaSet([(w["enc_v1"], w["search_v1"])]),
                         compat=compat)
    router.set_version(0, w["ver_v1"])
    try:
        with pytest.raises(IncompatibleVersion):
            router.submit(SearchRequest(codes=w["enc_v2"](w["queries"]),
                                        embedding_version="v2"))
    finally:
        router.close()


def test_native_replica_preferred_over_compat(world):
    w = world
    compat = CompatibilityMatrix()
    compat.register("v2", "v1", w["enc_compat"])
    router = QueryRouter(
        ReplicaSet([(w["enc_v1"], w["search_v1"]),
                    (w["enc_v2"], w["search_v2"])], share_device=True),
        compat=compat,
    )
    router.set_version(0, w["ver_v1"])
    router.set_version(1, w["ver_v2"])
    try:
        for _ in range(4):  # round-robin must not rotate onto compat
            res = router.submit(SearchRequest(
                queries=w["queries"], embedding_version="v2"
            )).search_result()
            assert res.served_by_version == "v2"
            assert res.replica == 1 and not res.compat_encoded
        assert router.stats()["compat_dispatches"] == 0
    finally:
        router.close()


def test_served_by_version_correct_under_failover_mid_upgrade(world):
    w = world

    def broken_search(codes):
        raise RuntimeError("v2 replica scan fault")

    compat = CompatibilityMatrix()
    compat.register("v2", "v1", w["enc_compat"])
    router = QueryRouter(
        ReplicaSet([(w["enc_v1"], w["search_v1"]),
                    (w["enc_v2"], broken_search)], share_device=True),
        compat=compat,
    )
    router.set_version(0, w["ver_v1"])
    router.set_version(1, w["ver_v2"])
    try:
        # Native v2 replica is preferred, fails, and the ticket fails
        # over THROUGH the compat encoder onto the v1 survivor — the
        # result must carry the surviving replica's version, not the
        # request's, and flag the compat hop.
        t = router.submit(SearchRequest(queries=w["queries"],
                                        embedding_version="v2"))
        res = t.search_result(timeout=30.0)
        ref = serve_sequential(w["enc_compat"], w["search_v1"],
                               [w["queries"]])[0]
        assert _eq(res, ref)
        assert res.served_by_version == "v1"
        assert res.replica == 0 and res.compat_encoded
        assert router.states()[1] == "unhealthy"
        assert router.stats()["failovers"] >= 1
    finally:
        router.close()


def test_failover_dead_end_fails_typed(world):
    w = world

    def broken_search(codes):
        raise RuntimeError("v2 replica scan fault")

    # No compat matrix: once the only v2 replica dies, the v2 ticket has
    # a healthy v1 replica it can never use — it must fail typed, not
    # park forever on a probe that cannot change the version topology.
    router = QueryRouter(
        ReplicaSet([(w["enc_v1"], w["search_v1"]),
                    (w["enc_v2"], broken_search)], share_device=True),
    )
    router.set_version(0, w["ver_v1"])
    router.set_version(1, w["ver_v2"])
    try:
        t = router.submit(SearchRequest(queries=w["queries"],
                                        embedding_version="v2"))
        with pytest.raises(IncompatibleVersion):
            t.result(timeout=30.0)
    finally:
        router.close()


def test_effort_hint_pre_degrades_knob(world):
    from repro.launch.proxy import EffortKnob

    w = world
    knob = EffortKnob(n_levels=3)
    router = QueryRouter(ReplicaSet([(w["enc_v1"], w["search_v1"])]))
    router.enable_degradation(knob)
    try:
        router.submit(SearchRequest(queries=w["queries"],
                                    effort=1)).result()
        assert knob.level >= 1
    finally:
        router.close()


# ---------------------------------------------------------------------------
# compatibility matrix + registry validation
# ---------------------------------------------------------------------------


def test_compat_matrix_validates(world):
    m = CompatibilityMatrix()
    with pytest.raises(ValueError):
        m.register("v1", "v1", world["enc_v1"])
    m.register("v2", "v1", world["enc_compat"])
    assert m.lookup("v2", "v1") is world["enc_compat"]
    assert m.lookup("v1", "v1") is None  # native: no encoder needed
    assert m.lookup("v1", "v2") is None  # unregistered direction
    assert m.compatible("v2", "v1") and m.compatible("v1", "v1")
    assert m.compatible(None, "v1") and not m.compatible("v1", "v2")
    assert m.pairs() == [("v2", "v1")]


def test_make_builder_rejects_unknown_params():
    with pytest.raises(UnknownBuildParam) as exc:
        make_builder("flat", k=5, nprobe=7)
    assert "nprobe" in str(exc.value) and "backend" in str(exc.value)
    assert isinstance(exc.value, TypeError)
    with pytest.raises(ValueError):
        make_builder("no-such-index")
    assert make_builder("ivf", k=5, nlist=8, nprobe=4).params["nlist"] == 8


def test_snapshot_first_entry_point_parity(world):
    from repro.index.flat import flat_search_from_snapshot

    w = world
    snap = w["snap_v1"]
    q = w["enc_v1"](w["queries"])
    via_snap = flat_search_from_snapshot(snap, k=K)(q)
    via_raw = flat_search_from_snapshot(snap.codes, LEVELS, k=K)(q)
    assert _eq(via_snap, via_raw)
    with pytest.raises(ValueError):
        flat_search_from_snapshot(snap, LEVELS + 1, k=K)
    with pytest.raises(TypeError):
        flat_search_from_snapshot(snap.codes, k=K)
