"""Backward-compatible binarizer training (paper §3.2.3, Table 4).

Scenario: a backbone upgrade drifts the float embedding space (v2 encoder
correlated-but-not-identical to v1). The old binary index stays frozen;
phi_new must encode NEW-backbone queries to search it (Eq. 6-8).

Verified ordering (the paper's Table 4 narrative):
  free-trained new model (no constraint)  ~ 0    — incompatible
  warm-start only (no BC training)        < ours — drift uncorrected
  ours (L + L_BC + influence, Eq. 9-10)   ~ baseline(old, old)
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.losses as L
from repro.core import (
    BinarizerConfig,
    TrainConfig,
    bc_train_step,
    binarize_eval,
    init_train_state,
    make_encode_fn,
    train_step,
)
from repro.data.synthetic import backbone_upgrade, clustered_corpus, pair_batches
from repro.launch.lifecycle import (
    COMPAT_RECALL_FLOOR,
    CorpusSnapshot,
    make_builder,
)
from repro.train import optim

DIM, CODE, LEVELS = 64, 32, 3


def _cfg():
    return TrainConfig(
        binarizer=BinarizerConfig(input_dim=DIM, code_dim=CODE,
                                  n_levels=LEVELS, hidden_dim=48),
        queue=L.QueueConfig(length=512, dim=CODE, top_k=16),
        adam=optim.AdamConfig(lr=1e-3, clip_norm=5.0),
        temperature=0.2, bc_weight=1.0, bc_influence_weight=4.0,
    )


def _train(cfg, docs, steps=150, seed=0):
    state = init_train_state(jax.random.PRNGKey(seed), cfg)
    step = jax.jit(functools.partial(train_step, cfg=cfg))
    gen = pair_batches(docs, seed + 1, 64)
    for _ in range(steps):
        a, p = next(gen)
        state, _ = step(state, a, p)
    return state


def _warm_copy(cfg, old, seed):
    st = init_train_state(jax.random.PRNGKey(seed), cfg)
    return st._replace(
        params=jax.tree_util.tree_map(jnp.copy, old.params),
        m_params=jax.tree_util.tree_map(jnp.copy, old.params),
        bn_state=jax.tree_util.tree_map(jnp.copy, old.bn_state),
        m_bn_state=jax.tree_util.tree_map(jnp.copy, old.bn_state),
    )


def _train_bc(cfg, old, old_docs, new_docs, steps=300, seed=7):
    state = _warm_copy(cfg, old, seed)
    step = jax.jit(functools.partial(bc_train_step, cfg=cfg))
    rng = np.random.default_rng(seed + 1)
    for _ in range(steps):
        idx = rng.integers(0, old_docs.shape[0], 128)
        noise = rng.normal(size=(128, DIM)).astype(np.float32) * 0.02
        a = new_docs[idx] + noise
        a /= np.linalg.norm(a, axis=-1, keepdims=True) + 1e-12
        state, _ = step(state, old.params, old.bn_state, jnp.asarray(a),
                        jnp.asarray(old_docs[idx]))
    return state


def _recall_cross(cfg, q_state, d_state, q_emb, d_emb, gt, k=10):
    bq = binarize_eval(q_state.params, q_state.bn_state, jnp.asarray(q_emb),
                       cfg.binarizer)
    bd = binarize_eval(d_state.params, d_state.bn_state, jnp.asarray(d_emb),
                       cfg.binarizer)
    _, idx = jax.lax.top_k(L.cosine(bq, bd), k)
    return float(jnp.mean(jnp.any(idx == jnp.asarray(gt)[:, None], -1)))


@functools.lru_cache(maxsize=1)
def _upgrade_world():
    """Shared backbone-upgrade world: phi_old trained on the old float
    space, phi_bc compatibility-trained for the new one. Cached — both
    the Table 4 ordering test and the serving recall-floor test read it."""
    cfg = _cfg()
    docs, queries, gt = clustered_corpus(0, 3000, 64, DIM, n_clusters=128)
    new_docs = backbone_upgrade(docs, 5)
    new_queries = backbone_upgrade(queries, 5)
    old = _train(cfg, docs, seed=0)
    bc = _train_bc(cfg, old, docs, new_docs)
    return cfg, docs, queries, gt, new_docs, new_queries, old, bc


def test_backward_compatible_upgrade():
    cfg, docs, queries, gt, new_docs, new_queries, old, bc = _upgrade_world()

    baseline = _recall_cross(cfg, old, old, queries, docs, gt)

    # new model trained freely on the new space: incompatible with old index
    free = _train(cfg, new_docs, seed=99)
    incompatible = _recall_cross(cfg, free, old, new_queries, docs, gt)

    # warm start only (deploy phi_old against the new backbone, no training)
    warm_only = _recall_cross(cfg, old, old, new_queries, docs, gt)

    # ours: BC training (Eq. 9-10 + influence)
    compatible = _recall_cross(cfg, bc, old, new_queries, docs, gt)

    assert baseline > 0.8, baseline
    assert incompatible < 0.2, incompatible
    assert compatible > warm_only + 0.05, (warm_only, compatible)
    assert compatible > incompatible + 0.3, (incompatible, compatible)
    assert compatible >= baseline - 0.2, (baseline, compatible)


def test_bc_queries_meet_recall_floor_on_v1_serving_index():
    """The serving-tier contract behind the CompatibilityMatrix hop: a
    bc-trained v2 encoder's queries, scored through the SAME packed-SDC
    flat index the tier serves (not the float-composed cosine of the
    ordering test above), must hold COMPAT_RECALL_FLOOR — the floor the
    upgrade bench row embeds and scripts/check_bench_gate.py enforces."""
    cfg, docs, _, gt, _, new_queries, old, bc = _upgrade_world()

    enc_old = make_encode_fn(old.params, old.bn_state, cfg.binarizer)
    enc_bc = make_encode_fn(bc.params, bc.bn_state, cfg.binarizer)
    snap = CorpusSnapshot(codes=np.asarray(enc_old(docs)), n_levels=LEVELS,
                          embedding_version="v1")
    search_v1 = make_builder("flat", k=10, backend="xla").build(snap)

    _, idx = search_v1(enc_bc(new_queries))
    recall = float(np.mean(np.any(
        np.asarray(idx) == np.asarray(gt)[:, None], -1)))
    assert recall >= COMPAT_RECALL_FLOOR, recall


def test_bc_loss_terms_finite():
    docs, _, _ = clustered_corpus(1, 500, 8, DIM)
    cfg = _cfg()
    old = _train(cfg, docs, steps=5)
    state = init_train_state(jax.random.PRNGKey(3), cfg)
    gen = pair_batches(docs, 5, 32)
    a, p = next(gen)
    state, metrics = jax.jit(functools.partial(bc_train_step, cfg=cfg))(
        state, old.params, old.bn_state, a, p
    )
    assert np.isfinite(float(metrics["loss_self"]))
    assert np.isfinite(float(metrics["loss_bc"]))
