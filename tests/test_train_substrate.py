"""Optimizer, checkpoint (fault tolerance), gradient compression."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ck
from repro.train import compression as comp
from repro.train import optim


# ---------------------------------------------------------------------------
# Adam.
# ---------------------------------------------------------------------------


def test_adam_matches_reference_numpy():
    cfg = optim.AdamConfig(lr=0.1, b1=0.9, b2=0.999, eps=1e-8, clip_norm=0.0)
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    st = optim.adam_init(p)
    g = {"w": jnp.array([0.5, -0.5, 1.0])}

    # two steps in jax
    p1, st1 = optim.adam_update(g, st, p, cfg)
    p2, _ = optim.adam_update(g, st1, p1, cfg)

    # reference numpy implementation
    w = np.array([1.0, -2.0, 3.0])
    m = np.zeros(3)
    v = np.zeros(3)
    gn = np.array([0.5, -0.5, 1.0])
    for t in (1, 2):
        m = 0.9 * m + 0.1 * gn
        v = 0.999 * v + 0.001 * gn**2
        mh = m / (1 - 0.9**t)
        vh = v / (1 - 0.999**t)
        w = w - 0.1 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(p2["w"]), w, rtol=1e-5)


def test_adam_converges_on_quadratic():
    cfg = optim.AdamConfig(lr=0.1, clip_norm=5.0)
    p = {"x": jnp.array([5.0, -3.0])}
    st = optim.adam_init(p)
    loss = lambda p: jnp.sum((p["x"] - jnp.array([1.0, 2.0])) ** 2)
    for _ in range(300):
        g = jax.grad(loss)(p)
        p, st = optim.adam_update(g, st, p, cfg)
    np.testing.assert_allclose(np.asarray(p["x"]), [1.0, 2.0], atol=1e-2)


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 10.0}
    clipped = optim.clip_by_global_norm(g, 1.0)
    assert float(optim.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    small = {"a": jnp.ones((4,)) * 0.01}
    same = optim.clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(same["a"]), 0.01, rtol=1e-5)


def test_schedules():
    s = optim.cosine_schedule(100, warmup=10)
    assert float(s(jnp.array(0))) == 0.0
    assert float(s(jnp.array(10))) == pytest.approx(1.0)
    assert float(s(jnp.array(100))) == pytest.approx(0.0, abs=1e-6)


# ---------------------------------------------------------------------------
# Checkpoint / fault tolerance.
# ---------------------------------------------------------------------------


def _tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.bfloat16),
                   "c": jnp.array(7, jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 3, t)
    restored, step = ck.restore(str(tmp_path), t)
    assert step == 3
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_latest_and_keep_last(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ck.save(str(tmp_path), s, t, keep_last=2)
    assert ck.latest_step(str(tmp_path)) == 5
    dirs = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(dirs) == 2


def test_checkpoint_detects_corruption(tmp_path):
    t = _tree()
    path = ck.save(str(tmp_path), 1, t)
    # corrupt one array file
    target = os.path.join(path, "arr_00000.npy")
    arr = np.load(target)
    arr.flat[0] += 1
    np.save(target, arr)
    with pytest.raises(IOError, match="CRC"):
        ck.restore(str(tmp_path), t)


def test_checkpoint_skips_torn_write(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 1, t)
    # simulate a preempted writer: later step dir without manifest
    os.makedirs(tmp_path / "step_0000000009")
    assert ck.latest_step(str(tmp_path)) == 1
    _, step = ck.restore(str(tmp_path), t)
    assert step == 1


def test_checkpoint_restart_determinism(tmp_path):
    """Kill-and-resume yields the same params as an uninterrupted run."""
    from repro.data.synthetic import lm_batch

    cfg = optim.AdamConfig(lr=0.05)
    p0 = {"w": jnp.ones((4, 4))}

    def run(steps, resume_from=None, start=0):
        p = {"w": jnp.ones((4, 4))}
        st = optim.adam_init(p)
        if resume_from is not None:
            (p, st), start = ck.restore(resume_from, (p, st))
        for i in range(start, steps):
            b = lm_batch(i, 2, 4, 8)["tokens"].astype(jnp.float32)
            g = jax.grad(lambda p: jnp.sum((b[:, :4] @ p["w"]) ** 2))(p)
            p, st = optim.adam_update(g, st, p, cfg)
        return p

    full = run(10)
    # interrupted run: 5 steps, checkpoint, resume to 10
    p = {"w": jnp.ones((4, 4))}
    st = optim.adam_init(p)
    for i in range(5):
        b = lm_batch(i, 2, 4, 8)["tokens"].astype(jnp.float32)
        g = jax.grad(lambda p: jnp.sum((b[:, :4] @ p["w"]) ** 2))(p)
        p, st = optim.adam_update(g, st, p, cfg)
    ck.save(str(tmp_path), 5, (p, st))
    resumed = run(10, resume_from=str(tmp_path))
    np.testing.assert_allclose(np.asarray(full["w"]), np.asarray(resumed["w"]),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# Gradient compression.
# ---------------------------------------------------------------------------


def test_int8_quantize_roundtrip_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    q, s = comp.quantize_int8(x)
    err = jnp.max(jnp.abs(comp.dequantize_int8(q, s) - x))
    assert float(err) <= float(s) * 0.5 + 1e-6


def test_error_feedback_unbiased_over_time():
    """With error feedback, the accumulated compressed signal converges to
    the accumulated true signal (residual stays bounded)."""
    g_true = jax.random.normal(jax.random.PRNGKey(1), (256,)) * 0.1
    e = jnp.zeros((256,))
    total = jnp.zeros((256,))
    for _ in range(50):
        corrected = g_true + e
        q, s = comp.quantize_int8(corrected)
        deq = comp.dequantize_int8(q, s)
        e = corrected - deq
        total = total + deq
    drift = jnp.max(jnp.abs(total - 50 * g_true))
    # residual never exceeds one quantisation bucket
    assert float(drift) <= float(jnp.max(jnp.abs(g_true + e)) / 127.0 * 2 + 1e-4)
