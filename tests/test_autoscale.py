"""Shed-pressure autoscaler (launch/autoscale.py) + the injectable
clock it runs on (launch/clock.py).

Every timing property here — hysteresis, cooldown spacing, backoff
interruption — is proven on a ``FakeClock`` by advancing simulated
time, never by sleeping real time: the only real waits are the fake
clock's millisecond poll quantum and thread joins on work that has
already been released.
"""

import threading
import time
import random

import numpy as np
import pytest

from repro.launch.autoscale import (
    ADMISSION_POLICIES,
    Autoscaler,
    InvalidTierSpec,
    TierSpec,
)
from repro.launch.clock import SYSTEM_CLOCK, Clock, FakeClock, SystemClock
from repro.launch.proxy import QueryRouter, ReplicaSet
from repro.launch.serving import PipelineClosed, RequestShed, ServingConfig

LEVELS = 4


def _identity_pair(calls=None, tag="r"):
    def encode(x):
        return x

    def search(c):
        if calls is not None:
            calls.append((tag, int(np.asarray(c).ravel()[0])))
        return c * 2, c + 1

    return encode, search


def _batches(n=8, width=4):
    return [np.full((width,), i, dtype=np.int64) for i in range(n)]


def _tier(clk, n=1, queue_depth=4, policy="shed"):
    return QueryRouter(
        ReplicaSet([_identity_pair() for _ in range(n)],
                   config=ServingConfig(queue_depth=queue_depth,
                                        policy=policy)),
        clock=clk,
    )


def _scaler(router, spec, clk, pressure, **kw):
    """Autoscaler over identity replicas with a synthetic pressure
    signal; ``pressure`` is a mutable one-element list the test sets."""
    kw.setdefault("replica_factory", lambda slot: _identity_pair())
    kw.setdefault("warm_batches", _batches(1))
    return Autoscaler(router, spec, clock=clk,
                      pressure_fn=lambda: pressure[0], **kw)


# ---------------------------------------------------------------------------
# FakeClock semantics
# ---------------------------------------------------------------------------


def test_clock_protocol_is_satisfied_by_both_implementations():
    assert isinstance(SYSTEM_CLOCK, Clock)
    assert isinstance(SystemClock(), Clock)
    assert isinstance(FakeClock(), Clock)


def test_fake_clock_now_moves_only_on_advance():
    clk = FakeClock(start=100.0)
    assert clk.now() == 100.0
    clk.advance(2.5)
    assert clk.now() == 102.5
    with pytest.raises(ValueError, match="backwards"):
        clk.advance(-0.1)


def test_fake_clock_sleep_parks_until_advance():
    clk = FakeClock()
    woke = []
    th = threading.Thread(target=lambda: (clk.sleep(5.0), woke.append(1)))
    th.start()
    assert clk.wait_for_sleepers(1)
    assert not woke  # simulated time has not moved: still parked
    clk.advance(4.9)
    assert th.is_alive()
    clk.advance(0.1)  # deadline reached exactly
    th.join(timeout=5)
    assert woke == [1]
    assert clk.sleepers == 0


def test_fake_clock_wait_is_level_triggered_on_the_event():
    clk = FakeClock()
    ev = threading.Event()
    ev.set()
    t0 = clk.now()
    assert clk.wait(ev, 60.0) is True  # no advance needed
    assert clk.now() == t0


def test_fake_clock_wait_times_out_on_simulated_time():
    clk = FakeClock()
    ev = threading.Event()
    out = []
    th = threading.Thread(target=lambda: out.append(clk.wait(ev, 3.0)))
    th.start()
    assert clk.wait_for_sleepers(1)
    clk.advance(3.0)
    th.join(timeout=5)
    assert out == [False]  # timed out; the event never fired


def test_fake_clock_wait_wakes_on_event_set_without_advance():
    clk = FakeClock()
    ev = threading.Event()
    out = []
    th = threading.Thread(target=lambda: out.append(clk.wait(ev, 1e9)))
    th.start()
    assert clk.wait_for_sleepers(1)
    ev.set()  # production interrupt path: no clock advance at all
    th.join(timeout=5)
    assert out == [True]


def test_fake_clock_tick_hands_a_loop_exactly_one_interval():
    clk = FakeClock()
    stop = threading.Event()
    iters = []
    th = threading.Thread(
        target=lambda: [iters.append(1)
                        for _ in iter(lambda: clk.wait(stop, 1.0), True)])
    th.start()
    for _ in range(3):
        clk.tick(1.0)
    assert len(iters) == 3  # lockstep: one wake per tick, no more
    stop.set()
    th.join(timeout=5)


# ---------------------------------------------------------------------------
# TierSpec validation
# ---------------------------------------------------------------------------


def test_tier_spec_defaults_validate_and_round_trip():
    spec = TierSpec(min_replicas=1, max_replicas=3,
                    build_params={"k": 5})
    again = TierSpec.from_json(__import__("json").dumps(spec.to_dict()))
    assert again == spec
    assert spec.window_ticks == 3  # 3.0s window / 1.0s tick


def test_tier_spec_window_ticks_rounds_and_floors_at_one():
    assert TierSpec(window_s=0.1, tick_s=0.05).window_ticks == 2
    assert TierSpec(window_s=1.0, tick_s=1.0).window_ticks == 1


@pytest.mark.parametrize("bad", [
    dict(min_replicas=0),
    dict(min_replicas=True),                 # bool is not an int here
    dict(min_replicas=2, max_replicas=1),
    dict(max_replicas=2.0),                  # float replica count
    dict(queue_depth=0),
    dict(policy="drop"),
    dict(router="hash-ring"),
    dict(high_water=0.3, low_water=0.3),     # need low < high
    dict(high_water=1.5),
    dict(low_water=-0.1),
    dict(tick_s=0.0),
    dict(window_s=0.5, tick_s=1.0),          # window shorter than a tick
    dict(cooldown_s=-1.0),
    dict(swap_every_s=-5.0),
    dict(build_params=[("k", 5)]),           # not a dict
    dict(index="pq"),                        # unknown index kind
    dict(index="flat", build_params={"nlist": 8}),  # flat has no nlist
])
def test_tier_spec_rejects_malformed_fields_with_typed_error(bad):
    with pytest.raises(InvalidTierSpec):
        TierSpec(**bad)
    # the typed error still reads as a ValueError for generic handlers
    assert issubclass(InvalidTierSpec, ValueError)


def test_tier_spec_error_names_the_field():
    with pytest.raises(InvalidTierSpec, match="queue_depth"):
        TierSpec(queue_depth=-1)
    with pytest.raises(InvalidTierSpec, match="low_water"):
        TierSpec(high_water=0.2, low_water=0.4)
    with pytest.raises(InvalidTierSpec, match=str(ADMISSION_POLICIES)[1:-1]):
        TierSpec(policy="bogus")


def test_tier_spec_from_dict_rejects_unknown_keys_and_non_objects():
    with pytest.raises(InvalidTierSpec, match="unknown tier spec keys"):
        TierSpec.from_dict({"min_replicas": 1, "replicas": 3})
    with pytest.raises(InvalidTierSpec, match="JSON object"):
        TierSpec.from_dict([1, 2, 3])


def test_tier_spec_from_json_rejects_malformed_json():
    with pytest.raises(InvalidTierSpec, match="not valid JSON"):
        TierSpec.from_json("{min_replicas: 1")


def test_tier_spec_from_file_round_trips(tmp_path):
    spec = TierSpec(min_replicas=1, max_replicas=2, index="flat",
                    build_params={"k": 7}, high_water=0.6, low_water=0.2)
    p = tmp_path / "spec.json"
    p.write_text(__import__("json").dumps(spec.to_dict()))
    assert TierSpec.from_file(str(p)) == spec


# ---------------------------------------------------------------------------
# hysteresis: a noisy trace must not flap the tier
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("low,high", [(0.1, 0.5), (0.2, 0.6), (0.3, 0.7)])
@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_hysteresis_never_flaps_under_an_oscillating_noise_trace(
        low, high, seed):
    """Samples alternate ABOVE high water and BELOW low water — the
    worst case for a per-sample controller, which would scale on every
    tick. The window mean stays inside the deadband, so the windowed
    controller must take zero scaling actions over the whole trace."""
    rng = random.Random(seed)
    mid = (low + high) / 2
    amp = 1.2 * (high - low)
    clk = FakeClock()
    router = _tier(clk, n=2)
    spec = TierSpec(min_replicas=1, max_replicas=3, low_water=low,
                    high_water=high, cooldown_s=0.0, window_s=4.0,
                    tick_s=1.0)
    pressure = [mid]
    scaler = _scaler(router, spec, clk, pressure)
    try:
        sign = 1
        crossings = 0
        for _ in range(60):
            # jittered amplitude, strictly alternating sign: every
            # sample individually crosses a threshold...
            a = amp * (0.8 + 0.4 * rng.random())
            pressure[0] = min(1.0, max(0.0, mid + sign * a))
            crossings += (pressure[0] >= high or pressure[0] <= low)
            sign = -sign
            scaler.tick()
            clk.advance(spec.tick_s)
        assert crossings == 60  # the trace really was threshold-crossing
        # ...yet the windowed mean never left the deadband: no actions
        assert scaler.scale_up_count == 0
        assert scaler.scale_down_count == 0
        assert len(router.active_replicas()) == 2
        decisions = {e["decision"] for e in scaler.events}
        assert decisions <= {"warming", "hold"}
    finally:
        router.close()


def test_sustained_pressure_does_scale_up_with_the_same_thresholds():
    """Companion to the no-flap property: the deadband must not be so
    wide that a REAL sustained burst is ignored."""
    clk = FakeClock()
    router = _tier(clk, n=1)
    spec = TierSpec(min_replicas=1, max_replicas=2, low_water=0.1,
                    high_water=0.5, cooldown_s=0.0, window_s=4.0,
                    tick_s=1.0)
    pressure = [0.9]
    scaler = _scaler(router, spec, clk, pressure)
    try:
        outcomes = []
        for _ in range(4):
            outcomes.append(scaler.tick())
            clk.advance(1.0)
        assert outcomes == ["warming", "warming", "warming", "scale-up"]
        assert len(router.active_replicas()) == 2
    finally:
        router.close()


# ---------------------------------------------------------------------------
# cooldown
# ---------------------------------------------------------------------------


def test_cooldown_spaces_consecutive_scale_ups():
    clk = FakeClock()
    router = _tier(clk, n=1)
    spec = TierSpec(min_replicas=1, max_replicas=3, low_water=0.1,
                    high_water=0.5, cooldown_s=10.0, window_s=1.0,
                    tick_s=1.0)
    pressure = [0.9]
    scaler = _scaler(router, spec, clk, pressure)
    try:
        decisions = []
        for _ in range(12):
            decisions.append(scaler.tick())
            clk.advance(1.0)
        # t=0 scale-up; t=1..9 inside the 10s cooldown; t=10 scale-up
        assert decisions[0] == "scale-up"
        assert decisions[1:10] == ["cooldown"] * 9
        assert decisions[10] == "scale-up"
        assert scaler.scale_up_count == 2
        assert len(router.active_replicas()) == 3
    finally:
        router.close()


def test_window_resets_after_an_action():
    """Post-action decisions must not re-consume the pre-action burst:
    after a scale-up the window refills from scratch (decision goes
    back to 'warming'), even with cooldown disabled."""
    clk = FakeClock()
    router = _tier(clk, n=1)
    spec = TierSpec(min_replicas=1, max_replicas=3, low_water=0.1,
                    high_water=0.5, cooldown_s=0.0, window_s=2.0,
                    tick_s=1.0)
    pressure = [0.9]
    scaler = _scaler(router, spec, clk, pressure)
    try:
        assert scaler.tick() == "warming"
        clk.advance(1.0)
        assert scaler.tick() == "scale-up"
        clk.advance(1.0)
        pressure[0] = 0.3  # burst settles to mid-band right after
        assert scaler.tick() == "warming"  # old samples were discarded
        clk.advance(1.0)
        assert scaler.tick() == "hold"  # full window again, all mid-band
    finally:
        router.close()


# ---------------------------------------------------------------------------
# min/max bounds
# ---------------------------------------------------------------------------


def test_scaling_respects_min_and_max_bounds():
    clk = FakeClock()
    router = _tier(clk, n=1)
    spec = TierSpec(min_replicas=1, max_replicas=2, low_water=0.1,
                    high_water=0.5, cooldown_s=0.0, window_s=1.0,
                    tick_s=1.0)
    pressure = [1.0]
    scaler = _scaler(router, spec, clk, pressure)
    try:
        for _ in range(6):
            scaler.tick()
            clk.advance(1.0)
        # pegged pressure: one scale-up to max, then hold — never above
        assert scaler.scale_up_count == 1
        assert len(router.active_replicas()) == 2
        pressure[0] = 0.0
        for _ in range(6):
            scaler.tick()
            clk.advance(1.0)
        # dead quiet: one scale-down to min, then hold — never below
        assert scaler.scale_down_count == 1
        assert len(router.active_replicas()) == 1
        assert scaler.max_replicas_seen <= spec.max_replicas
        assert scaler.min_replicas_seen >= spec.min_replicas
    finally:
        router.close()


def test_bounds_enforcement_outruns_cooldown():
    """A tier outside its spec bounds is wrong, not noisy: enforcement
    acts immediately even while a cooldown is pending."""
    clk = FakeClock()
    router = _tier(clk, n=3)  # three replicas, spec allows two
    spec = TierSpec(min_replicas=1, max_replicas=2, low_water=0.1,
                    high_water=0.9, cooldown_s=1000.0, window_s=1.0,
                    tick_s=1.0)
    pressure = [0.5]
    scaler = _scaler(router, spec, clk, pressure)
    try:
        assert scaler.tick() == "above-max"
        assert len(router.active_replicas()) == 2
        # in bounds again: ordinary hysteresis (and its cooldown) resume
        clk.advance(1.0)
        assert scaler.tick() == "cooldown"
    finally:
        router.close()


def test_below_min_scales_up_immediately():
    clk = FakeClock()
    router = _tier(clk, n=1)
    spec = TierSpec(min_replicas=2, max_replicas=3, cooldown_s=1000.0,
                    window_s=1.0, tick_s=1.0)
    pressure = [0.0]
    scaler = _scaler(router, spec, clk, pressure)
    try:
        assert scaler.tick() == "below-min"
        assert len(router.active_replicas()) == 2
        assert sorted(router.healthy()) == [0, 1]
    finally:
        router.close()


# ---------------------------------------------------------------------------
# scale-down drains losslessly
# ---------------------------------------------------------------------------


def test_scale_down_drains_in_flight_work_losslessly():
    """Tickets queued on the victim replica when the scale-down lands
    must all resolve with correct answers — drained or re-dispatched,
    never dropped, never reordered."""
    clk = FakeClock()
    gate = threading.Event()
    first_in = threading.Event()

    def slow_pair(tag):
        def encode(x):
            return x

        def search(c):
            first_in.set()
            gate.wait(timeout=30)  # hold scans so work is truly in flight
            return c * 2, c + 1

        return encode, search

    router = QueryRouter(
        ReplicaSet([slow_pair(0), slow_pair(1)],
                   config=ServingConfig(queue_depth=8, policy="block")),
        clock=clk,
    )
    spec = TierSpec(min_replicas=1, max_replicas=2, low_water=0.1,
                    high_water=0.9, cooldown_s=0.0, window_s=1.0,
                    tick_s=1.0)
    pressure = [0.0]
    scaler = _scaler(router, spec, clk, pressure)
    try:
        batches = _batches(8)
        tickets = [router.submit(b) for b in batches]  # spread over both
        assert first_in.wait(timeout=10)
        # scale-down decides while replica 1 still holds queued work;
        # retire_replica drains, so the tick blocks until it is empty
        done = []
        th = threading.Thread(
            target=lambda: done.append(scaler.tick()))
        th.start()
        time.sleep(0.01)  # let the drain begin before releasing scans
        gate.set()
        th.join(timeout=30)
        assert done == ["scale-down"]
        assert router.states()[1] == "retired"
        results = [t.result(timeout=30) for t in tickets]
        for b, (vals, ids) in zip(batches, results):  # zero lost/reordered
            np.testing.assert_array_equal(np.asarray(vals), b * 2)
            np.testing.assert_array_equal(np.asarray(ids), b + 1)
        # the tier keeps serving on the survivor
        vals, ids = router.submit(batches[0]).result(timeout=10)
        np.testing.assert_array_equal(np.asarray(ids), batches[0] + 1)
    finally:
        gate.set()
        router.close()


def test_scale_down_never_retires_the_last_routable_replica():
    clk = FakeClock()
    router = _tier(clk, n=2)
    spec = TierSpec(min_replicas=1, max_replicas=2, low_water=0.1,
                    high_water=0.9, cooldown_s=0.0, window_s=1.0,
                    tick_s=1.0)
    pressure = [0.0]
    scaler = _scaler(router, spec, clk, pressure)
    try:
        assert scaler.tick() == "scale-down"  # 2 -> 1: fine
        clk.advance(1.0)
        # n == min_replicas now: the decision path refuses to go lower
        for _ in range(3):
            assert scaler.tick() == "hold"
            clk.advance(1.0)
        assert len(router.healthy()) == 1
    finally:
        router.close()


# ---------------------------------------------------------------------------
# scale-up admission discipline: warmed + canary-probed before traffic
# ---------------------------------------------------------------------------


def test_scale_up_replica_is_warmed_and_probed_before_traffic():
    clk = FakeClock()
    calls = []  # every batch the NEW replica's stages ever see, in order
    router = _tier(clk, n=1)
    spec = TierSpec(min_replicas=1, max_replicas=2, low_water=0.1,
                    high_water=0.5, cooldown_s=0.0, window_s=1.0,
                    tick_s=1.0)
    warm = [np.full((4,), 100, dtype=np.int64)]
    canary = np.full((4,), 200, dtype=np.int64)

    def factory(slot):
        def encode(x):
            return x

        def search(c):
            calls.append(int(np.asarray(c).ravel()[0]))
            return c * 2, c + 1

        return encode, search

    pressure = [0.9]
    scaler = Autoscaler(router, spec, clock=clk,
                        replica_factory=factory, warm_batches=warm,
                        canary=canary, pressure_fn=lambda: pressure[0])
    try:
        assert scaler.tick() == "scale-up"
        # admission order: warm batches (tag 100) ran on the throwaway
        # pair, then the canary probe (tag 200) went through the
        # pipeline — and NO traffic batch precedes either of them
        assert 200 in calls
        first_canary = calls.index(200)
        assert first_canary >= 1  # warmed at least once before the probe
        assert set(calls[:first_canary]) == {100}
        n_admission = len(calls)
        # now route real traffic until the new replica serves some
        deadline = time.time() + 10
        while time.time() < deadline and len(calls) == n_admission:
            router.submit(_batches(1)[0]).result(timeout=10)
        assert len(calls) > n_admission  # takes traffic — but only after
        assert router.states()[1] == "healthy"
    finally:
        router.close()


def test_failed_canary_retires_the_slot_before_it_ever_serves():
    clk = FakeClock()
    served = []
    router = _tier(clk, n=1)
    spec = TierSpec(min_replicas=1, max_replicas=2, low_water=0.1,
                    high_water=0.5, cooldown_s=0.0, window_s=1.0,
                    tick_s=1.0)

    def broken_factory(slot):
        def encode(x):
            return x

        def search(c):
            served.append(int(np.asarray(c).ravel()[0]))
            raise RuntimeError("bad build")

        return encode, search

    pressure = [0.9]
    scaler = Autoscaler(router, spec, clock=clk,
                        replica_factory=broken_factory,
                        warm_batches=None, canary=_batches(1)[0],
                        pressure_fn=lambda: pressure[0])
    try:
        assert scaler.tick() == "scale-up-failed"
        assert scaler.probe_failures == 1
        assert router.states()[1] == "retired"  # tombstoned, not counted
        assert len(router.active_replicas()) == 1
        n_probe = len(served)  # only the canary ever reached it
        # traffic continues on the original replica; the dead slot is
        # never routed to again
        for b in _batches(4):
            router.submit(b).result(timeout=10)
        assert len(served) == n_probe
    finally:
        router.close()


# ---------------------------------------------------------------------------
# background loop + clock integration
# ---------------------------------------------------------------------------


def test_background_loop_ticks_on_the_clock_and_stops_cleanly():
    clk = FakeClock()
    router = _tier(clk, n=1)
    spec = TierSpec(min_replicas=1, max_replicas=2, low_water=0.1,
                    high_water=0.5, cooldown_s=0.0, window_s=2.0,
                    tick_s=0.5)
    pressure = [0.9]
    scaler = _scaler(router, spec, clk, pressure)
    try:
        scaler.start()
        scaler.start()  # idempotent while alive
        for _ in range(4):
            clk.tick(0.5)
        scaler.stop()
        assert len(scaler.events) == 4  # exactly one decision per tick
        assert scaler.scale_up_count == 1
        assert len(router.active_replicas()) == 2
    finally:
        router.close()


def test_router_close_interrupts_a_parked_retry_backoff():
    """The satellite fix: close() during a retry backoff must wake the
    waiter immediately (PipelineClosed), not wait out the delay — on
    the fake clock, 'immediately' means with NO time advance at all."""
    clk = FakeClock()
    gate = threading.Event()
    started = threading.Event()

    def encode(x):
        started.set()
        gate.wait(timeout=30)
        return x

    router = QueryRouter(
        ReplicaSet([(encode, lambda c: (c * 2, c + 1))],
                   config=ServingConfig(queue_depth=1, policy="shed")),
        clock=clk,
    )
    try:
        b = _batches(3)
        t0 = router.submit(b[0])
        assert started.wait(timeout=5)
        t1 = router.submit(b[1])  # fills the queue
        errs = []

        def work():
            try:
                router.submit_with_retry(b[2], attempts=10,
                                         base_delay_s=3600.0)
            except PipelineClosed as e:
                errs.append(e)
            except RequestShed as e:  # pragma: no cover - wrong path
                errs.append(e)

        th = threading.Thread(target=work)
        th.start()
        assert clk.wait_for_sleepers(1)  # parked on a one-HOUR backoff
        gate.set()
        before = clk.now()
        router.close()
        th.join(timeout=10)
        assert not th.is_alive()
        assert clk.now() == before  # zero simulated seconds were served
        assert len(errs) == 1 and isinstance(errs[0], PipelineClosed)
    finally:
        gate.set()
        router.close()


def test_autoscaler_requires_a_canary_and_a_replica_source():
    clk = FakeClock()
    router = _tier(clk, n=1)
    spec = TierSpec(min_replicas=1, max_replicas=2)
    try:
        with pytest.raises(ValueError, match="canary"):
            Autoscaler(router, spec, clock=clk,
                       replica_factory=lambda s: _identity_pair())
        with pytest.raises(ValueError, match="replica_factory"):
            Autoscaler(router, spec, clock=clk, canary=_batches(1)[0])
    finally:
        router.close()
