"""Neighbor sampler + data pipeline determinism + EmbeddingBag."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data import synthetic
from repro.models.recsys.embedding import (
    embedding_bag,
    embedding_bag_fixed,
    hash_bucket,
)
from repro.models.sampler import CSRGraph, max_sampled_edges, sample_subgraph


def _random_graph(n=200, e=1500, seed=0):
    rng = np.random.default_rng(seed)
    s = rng.integers(0, n, e)
    r = rng.integers(0, n, e)
    return CSRGraph.from_edges(s, r, n), s, r


def test_csr_construction():
    g, s, r = _random_graph()
    assert g.indptr[-1] == len(s)
    # each node's neighbor slice matches the edge list
    for node in (0, 5, 100):
        nbrs = set(g.indices[g.indptr[node]:g.indptr[node + 1]].tolist())
        expected = set(r[s == node].tolist())
        assert nbrs == expected


def test_sampler_respects_fanout_and_shapes():
    g, _, _ = _random_graph()
    rng = np.random.default_rng(1)
    seeds = np.arange(16)
    fanouts = [5, 3]
    nodes, ss, rr, mask, seedpos = sample_subgraph(g, seeds, fanouts, rng)
    assert ss.shape[0] == max_sampled_edges(16, fanouts)
    assert mask.sum() <= max_sampled_edges(16, fanouts)
    # all edge endpoints are valid local ids
    assert ss[mask].max() < len(nodes)
    assert rr[mask].max() < len(nodes)
    # seeds are present with valid positions
    assert (seedpos >= 0).all()
    np.testing.assert_array_equal(nodes[seedpos], seeds)


def test_sampler_deterministic_given_rng_state():
    g, _, _ = _random_graph()
    a = sample_subgraph(g, np.arange(8), [4, 2], np.random.default_rng(7))
    b = sample_subgraph(g, np.arange(8), [4, 2], np.random.default_rng(7))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_synthetic_batches_deterministic():
    b1 = synthetic.lm_batch(5, 2, 8, 100)
    b2 = synthetic.lm_batch(5, 2, 8, 100)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = synthetic.lm_batch(6, 2, 8, 100)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_clustered_corpus_gt_is_nearest():
    docs, queries, gt = synthetic.clustered_corpus(0, 500, 16, 32,
                                                   query_noise=0.05)
    sims = queries @ docs.T
    top1 = sims.argmax(-1)
    assert (top1 == gt).mean() > 0.9


# ---------------------------------------------------------------------------
# EmbeddingBag (jnp.take + segment_sum — the system's torch-EmbeddingBag).
# ---------------------------------------------------------------------------


def test_embedding_bag_matches_manual_loop():
    table = jnp.asarray(np.random.default_rng(0).normal(size=(50, 8)),
                        jnp.float32)
    ids = jnp.array([1, 2, 3, 10, 11, 40], jnp.int32)
    seg = jnp.array([0, 0, 0, 1, 1, 2], jnp.int32)
    out = embedding_bag(table, ids, seg, num_bags=3)
    expected = np.stack([
        np.asarray(table)[[1, 2, 3]].sum(0),
        np.asarray(table)[[10, 11]].sum(0),
        np.asarray(table)[[40]].sum(0),
    ])
    np.testing.assert_allclose(np.asarray(out), expected, rtol=1e-6)


def test_embedding_bag_mean_combiner():
    table = jnp.eye(4, dtype=jnp.float32)
    ids = jnp.array([0, 1, 2, 3], jnp.int32)
    seg = jnp.array([0, 0, 1, 1], jnp.int32)
    out = embedding_bag(table, ids, seg, num_bags=2, combiner="mean")
    np.testing.assert_allclose(np.asarray(out)[0], [0.5, 0.5, 0, 0], rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    bag=st.integers(1, 6),
    batch=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_embedding_bag_fixed_property(bag, batch, seed):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(20, 4)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 20, (batch, bag)), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, (batch, bag)), jnp.float32)
    out = embedding_bag_fixed(table, ids, mask)
    expected = (np.asarray(table)[np.asarray(ids)]
                * np.asarray(mask)[..., None]).sum(1)
    np.testing.assert_allclose(np.asarray(out), expected, rtol=1e-5, atol=1e-6)


def test_hash_bucket_range_and_determinism():
    ids = jnp.arange(10000, dtype=jnp.int32)
    h = hash_bucket(ids, 128)
    assert int(h.min()) >= 0 and int(h.max()) < 128
    np.testing.assert_array_equal(np.asarray(h), np.asarray(hash_bucket(ids, 128)))
    # roughly uniform occupancy
    counts = np.bincount(np.asarray(h), minlength=128)
    assert counts.min() > 20
