"""Fleet-behaviour tests: leaf failover in the search engine, elastic
checkpoint resume across mesh shapes (subprocess with forced devices)."""

import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=500,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_leaf_failover_graceful_degradation():
    stdout = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.index.engine import make_failover_search, engine_input_shardings
        from repro.kernels.sdc import ref as R
        key = jax.random.PRNGKey(0)
        codes = jax.random.randint(key, (4096, 64), 0, 16).astype(jnp.int8)
        q = jax.random.randint(jax.random.fold_in(key,1), (8, 64), 0, 16).astype(jnp.int8)
        inv = R.doc_inv_norms(codes, 4)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        search = make_failover_search(mesh, n_levels=4, k=10)
        qs, ds, vs = engine_input_shardings(mesh)
        with mesh:
            qd = jax.device_put(q, qs); dd = jax.device_put(codes, ds)
            ivd = jax.device_put(inv, vs)
            # all leaves healthy
            alive = jnp.ones((8,), bool)
            v_all, i_all = search(qd, dd, ivd, alive)
            # leaf 3 dies: same compiled fn, mask flip only
            alive = alive.at[3].set(False)
            v_deg, i_deg = search(qd, dd, ivd, alive)
        ev, ei = jax.lax.top_k(R.sdc_ref(q, codes, 4), 10)
        full = np.mean([len(set(np.asarray(i_all[i]))&set(np.asarray(ei[i])))/10 for i in range(8)])
        # degraded results contain no ids from the dead shard
        dead_lo, dead_hi = 3*512, 4*512
        leaked = int(((np.asarray(i_deg) >= dead_lo) & (np.asarray(i_deg) < dead_hi)).sum())
        deg = np.mean([len(set(np.asarray(i_deg[i]))&set(np.asarray(ei[i])))/10 for i in range(8)])
        print("FULL", full, "DEG", deg, "LEAKED", leaked)
        assert full == 1.0 and leaked == 0 and deg >= 0.8
    """)
    assert "FULL 1.0" in stdout


def test_elastic_resume_across_mesh_shapes():
    """Save a sharded train state on a (4,2) mesh, restore it on (2,2) —
    the checkpoint stores logical arrays, so mesh shape is free to change."""
    import tempfile

    ckpt = tempfile.mkdtemp()
    _run(f"""
        import jax, jax.numpy as jnp
        from repro.configs.registry import get_arch
        from repro.models import transformer as tf
        from repro.parallel import sharding as shd
        from repro.train import checkpoint as ck, optim, steps
        from repro.data import synthetic
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh((4, 2), ("data", "model"))
        cfg = get_arch("llama3.2-1b").smoke_config
        params = jax.device_put(tf.init_params(jax.random.PRNGKey(0), cfg),
                                shd.lm_param_sharding(mesh, cfg))
        opt = optim.adam_init(params)
        step = jax.jit(steps.lm_train_step(cfg, optim.AdamConfig(lr=1e-3)))
        with mesh:
            for i in range(3):
                batch = synthetic.lm_batch(i, 8, 16, cfg.vocab)
                params, opt, m = step(params, opt, batch)
        ck.save({ckpt!r}, 3, (params, opt))
        print("SAVED", float(m["loss"]))
    """, devices=8)
    stdout = _run(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import get_arch
        from repro.models import transformer as tf
        from repro.parallel import sharding as shd
        from repro.train import checkpoint as ck, optim, steps
        from repro.data import synthetic
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh((2, 2), ("data", "model"))  # DIFFERENT mesh
        cfg = get_arch("llama3.2-1b").smoke_config
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        opt = optim.adam_init(params)
        shardings = (shd.lm_param_sharding(mesh, cfg),
                     optim.AdamState(step=None, mu=None, nu=None))
        (params, opt), start = ck.restore({ckpt!r}, (params, opt))
        params = jax.device_put(params, shd.lm_param_sharding(mesh, cfg))
        step = jax.jit(steps.lm_train_step(cfg, optim.AdamConfig(lr=1e-3)))
        with mesh:
            batch = synthetic.lm_batch(start, 8, 16, cfg.vocab)
            params, opt, m = step(params, opt, batch)
        print("RESUMED", start, float(m["loss"]))
        assert start == 3 and np.isfinite(float(m["loss"]))
    """, devices=4)
    assert "RESUMED 3" in stdout
