"""The CI bench gate (scripts/check_bench_gate.py) must actually gate:
green on a healthy packed/unpacked byte ratio, red on a regressed one, on
a missing packed row, and on an empty report (deliberate-failure coverage
demanded by the CI satellite — a gate that cannot fail is decoration)."""

import json
import os
import subprocess
import sys

GATE = os.path.join(
    os.path.dirname(__file__), "..", "scripts", "check_bench_gate.py"
)


def _rows(ratio: float):
    return [
        {"variant": "flat", "packed": False, "bytes_scanned": 100_000},
        {"variant": "flat", "packed": True,
         "bytes_scanned": int(100_000 * ratio)},
        {"variant": "ivf", "packed": False, "bytes_scanned": 50_000},
        {"variant": "ivf", "packed": True,
         "bytes_scanned": int(50_000 * ratio)},
    ]


def _run_gate(tmp_path, bench: dict, *extra):
    path = tmp_path / "bench.json"
    path.write_text(json.dumps(bench))
    return subprocess.run(
        [sys.executable, GATE, str(path), *extra],
        capture_output=True, text=True, timeout=60,
    )


def test_gate_passes_healthy_ratio(tmp_path):
    out = _run_gate(tmp_path, {"rows": _rows(0.53)})
    assert out.returncode == 0, out.stderr


def test_gate_fails_regressed_ratio(tmp_path):
    out = _run_gate(tmp_path, {"rows": _rows(0.60)})
    assert out.returncode != 0
    assert "FAIL" in out.stdout


def test_gate_threshold_is_configurable(tmp_path):
    out = _run_gate(tmp_path, {"rows": _rows(0.60)},
                    "--max-packed-ratio", "0.65")
    assert out.returncode == 0, out.stderr


def test_gate_fails_on_missing_packed_row(tmp_path):
    rows = [r for r in _rows(0.5) if not r["packed"]]
    out = _run_gate(tmp_path, {"rows": rows})
    assert out.returncode != 0
    assert "MISSING-PAIR" in out.stdout


def test_gate_fails_on_empty_report(tmp_path):
    out = _run_gate(tmp_path, {"rows": []})
    assert out.returncode != 0


# -- bi-granular + bits-per-dimension sections (scan bench) ------------------


def _bigranular_section(levels=4):
    def row(c, ratio):
        return {"coarse_levels": c, "k_coarse": 40, "packed": True,
                "ms": 1.0, "recall_rerank": 0.95, "recall_coarse": 0.7,
                "coarse_bytes_scanned": int(100_000 * ratio),
                "fine_bytes_scanned": 5_000,
                "full_bytes_scanned": 100_000}
    return [row(levels // 2, 0.53), row(levels - 1, 0.78)]


def _bits_sweep_section():
    return [
        {"n_levels": n, "packed": packed, "ms": 1.0, "recall": 0.5,
         "bytes_scanned": 66_000 if packed else 132_000,
         "index_bytes": 20_000 * n}
        for n in (1, 2, 4) for packed in (False, True)
    ]


def _autotune_section():
    def row(kind, dq, dn, tq, tn, ratio, source):
        return {"kind": kind, "backend": "interpret",
                "block_q_default": dq, "block_n_default": dn,
                "block_q": tq, "block_n": tn, "source": source,
                "default_ms": None if ratio is None else 10.0,
                "tuned_ms": None if ratio is None else 10.0 * ratio,
                "ms_ratio_tuned_vs_default": ratio}
    return [row("scan", 128, 512, 32, 1024, 0.7, "tuned"),
            row("gather", 1, 0, 1, 0, 1.0, "fixed-geometry"),
            row("rerank", 1, 1, 1, 8, 0.5, "tuned")]


def _probe_budget_section(nlist=64, nprobe=8):
    def row(budget, rw, rf, **extra):
        return {"probe_budget": budget,
                "avg_probes_per_query": budget / nlist,
                "recall_weighted": rw, "recall_flat": rf, **extra}
    return [row(nlist // 2, 0.7, 0.5),
            row(nlist + nlist // 2, 0.9, 0.85),
            row(nprobe * nlist, 0.99, 0.99, bit_identical=True)]


def _scan_bench(**overrides):
    bench = {"bench": "sdc_scan", "levels": 4, "nlist": 64, "nprobe": 8,
             "rows": _rows(0.53),
             "bigranular": _bigranular_section(),
             "bits_sweep": _bits_sweep_section(),
             "autotune": _autotune_section(),
             "probe_budget": _probe_budget_section()}
    bench.update(overrides)
    return bench


def test_gate_passes_full_scan_bench(tmp_path):
    out = _run_gate(tmp_path, _scan_bench())
    assert out.returncode == 0, out.stdout + out.stderr


def test_gate_requires_a_bigranular_section(tmp_path):
    """A scan report without the coarse+rerank sweep (emitter regression)
    must not pass green; plain row-only reports without the sdc_scan
    bench tag (e.g. hnsw_scan) stay exempt."""
    out = _run_gate(tmp_path, _scan_bench(bigranular=[]))
    assert out.returncode != 0
    assert "no 'bigranular' section" in out.stderr


def test_gate_fails_on_malformed_bigranular_row(tmp_path):
    bench = _scan_bench()
    del bench["bigranular"][0]["recall_rerank"]
    del bench["bigranular"][0]["coarse_bytes_scanned"]
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "missing keys" in out.stderr
    assert "recall_rerank" in out.stderr
    assert "coarse_bytes_scanned" in out.stderr


def test_gate_fails_when_rerank_loses_recall(tmp_path):
    """The fine rerank refines the coarse scan; a row where rerank recall
    drops below the coarse-only recall means the rerank is broken."""
    bench = _scan_bench()
    bench["bigranular"][0]["recall_rerank"] = 0.6
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "below" in out.stderr and "coarse-only recall" in out.stderr


def test_gate_fails_on_oversized_coarse_tier(tmp_path):
    """At coarse_levels = levels // 2 the hot tier must hold <= 0.6x the
    full-level bytes — the acceptance point of the tiered layout."""
    bench = _scan_bench()
    bench["bigranular"][0]["coarse_bytes_scanned"] = 70_000
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "coarse tier too large" in out.stderr


def test_gate_coarse_ratio_is_configurable(tmp_path):
    bench = _scan_bench()
    bench["bigranular"][0]["coarse_bytes_scanned"] = 70_000
    out = _run_gate(tmp_path, bench, "--max-coarse-ratio", "0.75")
    assert out.returncode == 0, out.stdout + out.stderr


def test_gate_fails_without_the_half_levels_row(tmp_path):
    """The sweep must COVER the gated operating point: dropping the
    coarse_levels = levels // 2 row must not dodge the byte check."""
    bench = _scan_bench()
    bench["bigranular"] = bench["bigranular"][1:]  # only levels-1 row
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "no row at coarse_levels=2" in out.stderr


def test_gate_requires_a_bits_sweep_section(tmp_path):
    out = _run_gate(tmp_path, _scan_bench(bits_sweep=[]))
    assert out.returncode != 0
    assert "no 'bits_sweep' section" in out.stderr


def test_gate_fails_on_malformed_bits_sweep_row(tmp_path):
    bench = _scan_bench()
    del bench["bits_sweep"][0]["index_bytes"]
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "missing keys" in out.stderr and "index_bytes" in out.stderr


def test_gate_fails_on_bits_sweep_missing_packed_row(tmp_path):
    bench = _scan_bench()
    bench["bits_sweep"] = [r for r in bench["bits_sweep"]
                           if not (r["n_levels"] == 2 and r["packed"])]
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "n_levels=2 has no packed row" in out.stderr


def test_gate_fails_on_bits_sweep_packed_ratio(tmp_path):
    bench = _scan_bench()
    for r in bench["bits_sweep"]:
        if r["n_levels"] == 4 and r["packed"]:
            r["bytes_scanned"] = 80_000  # 0.606x unpacked > 0.55
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "packed scan bytes ratio" in out.stderr


def test_gate_fails_on_nonmonotone_index_bytes(tmp_path):
    """Serialized bytes per doc must GROW with the level count — a
    sweep where more levels serialize smaller is measuring the wrong
    thing (or the layout silently dropped levels)."""
    bench = _scan_bench()
    for r in bench["bits_sweep"]:
        if r["n_levels"] == 4:
            r["index_bytes"] = 10_000  # below the 2-level rows
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "not monotone" in out.stderr


# -- autotune + probe-budget sections (scan bench) ---------------------------


def test_gate_requires_an_autotune_section(tmp_path):
    """A scan report without the block-plan autotuner record (emitter
    regression) must not pass green."""
    out = _run_gate(tmp_path, _scan_bench(autotune=[]))
    assert out.returncode != 0
    assert "no 'autotune' section" in out.stderr


def test_gate_fails_on_malformed_autotune_row(tmp_path):
    bench = _scan_bench()
    del bench["autotune"][0]["block_q"]
    del bench["autotune"][0]["source"]
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "missing keys" in out.stderr
    assert "block_q" in out.stderr and "source" in out.stderr


def test_gate_fails_when_tuned_plan_loses_to_default(tmp_path):
    """The sweep times the default as a candidate on the same operands,
    so an honest tuner can never lose — a ratio above 1 means the tuner
    shipped a plan it never beat the default with."""
    bench = _scan_bench()
    bench["autotune"][0]["ms_ratio_tuned_vs_default"] = 1.3
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "LOST to the default" in out.stderr


def test_gate_fails_on_swept_kind_without_timings(tmp_path):
    """Only un-sweepable kinds may skip timings; a swept kind with a
    null ratio is a tuner that cannot show its work."""
    bench = _scan_bench()
    bench["autotune"][0]["ms_ratio_tuned_vs_default"] = None
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "no tuned-vs-default timing ratio" in out.stderr


def test_gate_fails_on_missing_kernel_kind(tmp_path):
    bench = _scan_bench()
    bench["autotune"] = [r for r in bench["autotune"]
                         if r["kind"] != "rerank"]
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "missing kernel kind" in out.stderr and "rerank" in out.stderr


def test_gate_autotune_ratio_is_configurable(tmp_path):
    bench = _scan_bench()
    bench["autotune"][0]["ms_ratio_tuned_vs_default"] = 1.3
    out = _run_gate(tmp_path, bench, "--max-autotune-ratio", "1.5")
    assert out.returncode == 0, out.stdout + out.stderr


def test_gate_requires_a_probe_budget_section(tmp_path):
    out = _run_gate(tmp_path, _scan_bench(probe_budget=[]))
    assert out.returncode != 0
    assert "no 'probe_budget' section" in out.stderr


def test_gate_fails_on_malformed_probe_budget_row(tmp_path):
    bench = _scan_bench()
    del bench["probe_budget"][0]["recall_weighted"]
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "missing keys" in out.stderr and "recall_weighted" in out.stderr


def test_gate_fails_when_weighted_loses_to_flat(tmp_path):
    """Occupancy-weighted allocation must never cost recall at equal
    budget — losing to the flat comparator means the surplus slots went
    to the wrong lists."""
    bench = _scan_bench()
    bench["probe_budget"][0]["recall_weighted"] = 0.4  # flat is 0.5
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "below" in out.stderr and "flat recall" in out.stderr


def test_gate_fails_when_parity_row_is_not_bit_identical(tmp_path):
    """budget == nprobe * nlist must reproduce flat nprobe bit-for-bit
    (same jit program); anything else means the budget path diverged."""
    bench = _scan_bench()
    bench["probe_budget"][-1]["bit_identical"] = False
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "not bit-identical to the flat-nprobe search" in out.stderr


def test_gate_fails_without_the_parity_row(tmp_path):
    """The sweep must COVER the bit-identity operating point: dropping
    the exact-multiple budget row must not dodge the parity check."""
    bench = _scan_bench()
    bench["probe_budget"] = bench["probe_budget"][:-1]
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "no parity row at budget=512" in out.stderr


def test_gate_understands_hnsw_schema(tmp_path):
    """BENCH_hnsw_scan rows carry table_bytes and no variant key; the
    gate must pair them by the bench name and apply the same invariant."""
    def bench(ratio):
        return {"bench": "hnsw_scan", "rows": [
            {"packed": False, "table_bytes": 200_000},
            {"packed": True, "table_bytes": int(200_000 * ratio)},
        ]}

    assert _run_gate(tmp_path, bench(0.53)).returncode == 0
    out = _run_gate(tmp_path, bench(0.60))
    assert out.returncode != 0
    assert "hnsw_scan" in out.stdout


def _replicated_row(replicas=2, paired_ratio=0.95, **overrides):
    row = {
        "mode": "replicated", "replicas": replicas, "router": "round-robin",
        "qps": 950.0, "qps_ratio_vs_single": paired_ratio,
        "ms_per_batch": 1.0, "latency_p50_ms": 5.0, "latency_p99_ms": 9.0,
        "device_idle_frac": 0.1, "shed": 0, "failovers": 0,
        "per_replica": [
            {"replica": i, "requests": 10, "queries": 100, "shed": 0,
             "device_idle_frac": 0.1, "generation": 0}
            for i in range(replicas)
        ],
    }
    row.update(overrides)
    return row


def _swap_row(**overrides):
    row = {
        "mode": "swap", "replicas": 2, "index_kind": "flat",
        "swapped_replicas": 2, "swap_s": 0.5, "queries_during_swap": 128,
        "lost": 0, "reordered": 0, "bit_identical": True, "revivals": 1,
    }
    row.update(overrides)
    return row


def _chaos_row(**overrides):
    row = {
        "mode": "chaos", "replicas": 2, "index_kind": "flat",
        "submitted": 40, "lost": 0, "reordered": 0, "bit_identical": True,
        "deadline_violations": 2, "watchdog_stalls": 1, "failovers": 4,
        "revivals": 1, "time_to_recover_s": 0.1,
        "shed_without_degradation": 30, "shed_with_degradation": 3,
        "degraded_frac": 0.9,
    }
    row.update(overrides)
    return row


def _upgrade_row(**overrides):
    row = {
        "mode": "upgrade", "replicas": 2, "index_kind": "flat",
        "from_version": "v1", "to_version": "v2",
        "swapped_replicas": 2, "swap_s": 0.1, "queries_during_swap": 128,
        "submitted": 20, "lost": 0, "reordered": 0, "bit_identical": True,
        "compat_dispatches": 8, "recall_v1": 0.9, "recall_v2": 0.8,
        "recall_floor": 0.55, "final_versions": ["v2", "v2"],
    }
    row.update(overrides)
    return row


def _bigranular_swap_row(**overrides):
    row = _swap_row(mode="bigranular_swap", reranked=True)
    row.update(overrides)
    return row


def _autoscale_row(**overrides):
    row = {
        "mode": "autoscale", "index_kind": "flat",
        "replicas_min": 1, "replicas_max": 3, "fixed_replicas": 1,
        "steady_state_replicas": 1, "submitted": 500,
        "lost": 0, "reordered": 0, "bit_identical": True,
        "shed_fixed": 200, "shed_autoscaled": 120,
        "shed_rate_fixed": 0.4, "shed_rate_autoscaled": 0.24,
        "scale_ups": 2, "scale_downs": 2,
        "max_replicas_seen": 3, "min_replicas_seen": 1,
    }
    row.update(overrides)
    return row


def _serving_bench(ratio: float, paired_ratio: float = 0.95):
    return {"bench": "serving", "rows": [
        {"mode": "sequential", "qps": 1000.0},
        {"mode": "overlapped", "qps": 1000.0 * ratio},
        _replicated_row(replicas=1, paired_ratio=1.0),
        _replicated_row(paired_ratio=paired_ratio),
        _swap_row(),
        _chaos_row(),
        _upgrade_row(),
        _bigranular_swap_row(),
        _autoscale_row(),
    ]}


def test_serving_gate_passes_when_overlapped_wins(tmp_path):
    out = _run_gate(tmp_path, _serving_bench(1.15))
    assert out.returncode == 0, out.stderr


def test_serving_gate_fails_when_pipeline_loses_throughput(tmp_path):
    out = _run_gate(tmp_path, _serving_bench(0.9))
    assert out.returncode != 0
    assert "FAIL" in out.stdout


def test_serving_gate_ratio_is_configurable(tmp_path):
    out = _run_gate(tmp_path, _serving_bench(0.9),
                    "--min-serving-ratio", "0.85")
    assert out.returncode == 0, out.stderr


def test_serving_gate_fails_on_missing_mode_row(tmp_path):
    bench = _serving_bench(1.2)
    bench["rows"] = bench["rows"][:1]  # no overlapped row
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0


# -- replica sweep (proxy tier) ---------------------------------------------


def test_serving_gate_requires_a_replicated_row(tmp_path):
    """The replica sweep is part of the schema now: a BENCH_serving.json
    without it (e.g. an emitter regression) must not pass green."""
    bench = _serving_bench(1.2)
    bench["rows"] = bench["rows"][:2]  # sequential + overlapped only
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "no 'replicated' rows" in out.stderr


def test_serving_gate_fails_on_missing_replicated_keys(tmp_path):
    bench = _serving_bench(1.2)
    del bench["rows"][3]["latency_p99_ms"]
    del bench["rows"][3]["shed"]
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "missing keys" in out.stderr
    assert "latency_p99_ms" in out.stderr and "shed" in out.stderr


def test_serving_gate_fails_on_missing_failover_count(tmp_path):
    bench = _serving_bench(1.2)
    del bench["rows"][3]["failovers"]
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "failovers" in out.stderr


def test_serving_gate_fails_on_incomplete_per_replica_entry(tmp_path):
    bench = _serving_bench(1.2)
    del bench["rows"][3]["per_replica"][1]["device_idle_frac"]
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "per_replica[1]" in out.stderr


def test_serving_gate_fails_on_wrong_typed_per_replica(tmp_path):
    bench = _serving_bench(1.2)
    bench["rows"][3]["per_replica"] = {}  # present but unparseable
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "expected a list" in out.stderr


def test_serving_gate_fails_on_per_replica_count_mismatch(tmp_path):
    bench = _serving_bench(1.2)
    bench["rows"][3]["per_replica"].pop()  # 1 entry for replicas=2
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "per_replica has 1 entries" in out.stderr


def test_serving_gate_fails_below_replica_floor(tmp_path):
    out = _run_gate(tmp_path, _serving_bench(1.2, paired_ratio=0.8))
    assert out.returncode != 0
    assert "replicated tier lost throughput" in out.stderr


def test_serving_gate_replica_floor_is_configurable(tmp_path):
    out = _run_gate(tmp_path, _serving_bench(1.2, paired_ratio=0.8),
                    "--min-replica-ratio", "0.75")
    assert out.returncode == 0, out.stderr


# -- live index lifecycle (swap row) ----------------------------------------


def test_serving_gate_requires_a_swap_row(tmp_path):
    """The rolling-swap exercise is part of the schema now: a report
    without it (lifecycle emitter regression) must not pass green."""
    bench = _serving_bench(1.2)
    bench["rows"] = bench["rows"][:4]  # drop the swap row
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "no 'swap' row" in out.stderr


def test_serving_gate_fails_on_malformed_swap_row(tmp_path):
    bench = _serving_bench(1.2)
    del bench["rows"][4]["lost"]
    del bench["rows"][4]["revivals"]
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "missing keys" in out.stderr
    assert "lost" in out.stderr and "revivals" in out.stderr


def test_serving_gate_fails_on_lost_results_during_swap(tmp_path):
    bench = _serving_bench(1.2)
    bench["rows"][4] = _swap_row(lost=2)
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "lost 2 result(s)" in out.stderr


def test_serving_gate_fails_on_reordered_results_during_swap(tmp_path):
    bench = _serving_bench(1.2)
    bench["rows"][4] = _swap_row(reordered=1)
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "reordered 1 result(s)" in out.stderr


def test_serving_gate_fails_when_swap_breaks_bit_identity(tmp_path):
    bench = _serving_bench(1.2)
    bench["rows"][4] = _swap_row(bit_identical=False)
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "not bit-identical" in out.stderr


def test_serving_gate_fails_on_incomplete_rolling_swap(tmp_path):
    bench = _serving_bench(1.2)
    bench["rows"][4] = _swap_row(swapped_replicas=1)
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "swapped only 1/2" in out.stderr


def test_serving_gate_fails_without_a_revival(tmp_path):
    bench = _serving_bench(1.2)
    bench["rows"][4] = _swap_row(revivals=0)
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "no canary revival" in out.stderr


def test_serving_gate_fails_on_missing_generation(tmp_path):
    """A per-replica row without the stats generation (revival/swap
    bookkeeping) is an incomplete report."""
    bench = _serving_bench(1.2)
    del bench["rows"][3]["per_replica"][0]["generation"]
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "generation" in out.stderr


# -- chaos drill (fault injection row) ---------------------------------------


def test_serving_gate_requires_a_chaos_row(tmp_path):
    """The fault-injection drill is part of the schema now: a report
    without it (emitter regression) must not pass green."""
    bench = _serving_bench(1.2)
    bench["rows"] = bench["rows"][:5]  # drop the chaos row
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "no 'chaos' row" in out.stderr


def test_serving_gate_fails_on_lost_results_under_chaos(tmp_path):
    bench = _serving_bench(1.2)
    bench["rows"][5] = _chaos_row(lost=3)
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "lost 3 result(s)" in out.stderr


def test_serving_gate_fails_on_missing_deadline_accounting(tmp_path):
    """deadline_violations must be PRESENT even at zero — a report that
    cannot count deadline misses is an accounting hole, not a pass."""
    bench = _serving_bench(1.2)
    del bench["rows"][5]["deadline_violations"]
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "missing keys" in out.stderr
    assert "deadline_violations" in out.stderr


def test_serving_gate_fails_when_watchdog_missed_the_stall(tmp_path):
    bench = _serving_bench(1.2)
    bench["rows"][5] = _chaos_row(watchdog_stalls=0)
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "watchdog never detected" in out.stderr


def test_serving_gate_fails_when_degradation_does_not_help(tmp_path):
    """The A/B at equal load must show strictly fewer sheds with the
    effort knob enabled; equal counts mean the knob is not wired in."""
    bench = _serving_bench(1.2)
    bench["rows"][5] = _chaos_row(shed_with_degradation=30,
                                  shed_without_degradation=30)
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "did not reduce shedding" in out.stderr


# -- live embedding-version migration (upgrade row) ---------------------------


def test_serving_gate_requires_an_upgrade_row(tmp_path):
    """The live v1 -> v2 migration is part of the schema now: a report
    without it (emitter regression) must not pass green."""
    bench = _serving_bench(1.2)
    bench["rows"] = bench["rows"][:6]  # drop the upgrade row
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "no 'upgrade' row" in out.stderr


def test_serving_gate_fails_on_malformed_upgrade_row(tmp_path):
    bench = _serving_bench(1.2)
    del bench["rows"][6]["recall_floor"]
    del bench["rows"][6]["compat_dispatches"]
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "missing keys" in out.stderr
    assert "recall_floor" in out.stderr and "compat_dispatches" in out.stderr


def test_serving_gate_fails_on_lost_results_during_upgrade(tmp_path):
    bench = _serving_bench(1.2)
    bench["rows"][6] = _upgrade_row(lost=2)
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "lost 2 result(s) during the version migration" in out.stderr


def test_serving_gate_fails_on_reordered_results_during_upgrade(tmp_path):
    bench = _serving_bench(1.2)
    bench["rows"][6] = _upgrade_row(reordered=1)
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "reordered 1 result(s)" in out.stderr


def test_serving_gate_fails_when_upgrade_breaks_bit_identity(tmp_path):
    bench = _serving_bench(1.2)
    bench["rows"][6] = _upgrade_row(bit_identical=False)
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "not bit-identical" in out.stderr


def test_serving_gate_fails_below_upgrade_recall_floor(tmp_path):
    """Per-version recall across the migration window is a QUALITY gate:
    degrading by version must not degrade below the row's own floor."""
    bench = _serving_bench(1.2)
    bench["rows"][6] = _upgrade_row(recall_v2=0.4)
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "recall_v2=0.4000 below the recall floor" in out.stderr


def test_serving_gate_upgrade_floor_cannot_be_zeroed_out(tmp_path):
    """An emitter shipping recall_floor=0 must not self-certify: the
    gate floors it at --min-upgrade-recall (default 0.5) — which stays
    configurable for deliberately tiny smoke corpora."""
    bench = _serving_bench(1.2)
    bench["rows"][6] = _upgrade_row(recall_floor=0.0, recall_v1=0.1,
                                    recall_v2=0.1)
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "below the recall floor 0.5" in out.stderr
    out = _run_gate(tmp_path, bench, "--min-upgrade-recall", "0.05")
    assert out.returncode == 0, out.stdout + out.stderr


def test_serving_gate_fails_without_a_compat_dispatch(tmp_path):
    """A 'migration' whose stream never took the cross-version hop
    proves nothing about the compat path — hard fail."""
    bench = _serving_bench(1.2)
    bench["rows"][6] = _upgrade_row(compat_dispatches=0)
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "no compat dispatch" in out.stderr


def test_serving_gate_fails_on_incomplete_version_migration(tmp_path):
    bench = _serving_bench(1.2)
    bench["rows"][6] = _upgrade_row(swapped_replicas=1)
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "migrated only 1/2" in out.stderr


def test_serving_gate_fails_when_a_replica_misses_the_target_version(
        tmp_path):
    bench = _serving_bench(1.2)
    bench["rows"][6] = _upgrade_row(final_versions=["v2", "v1"])
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "final replica versions" in out.stderr


# -- tiered serving drill (bigranular_swap row) -------------------------------


def test_serving_gate_requires_a_bigranular_swap_row(tmp_path):
    """The tiered (coarse+rerank) rolling-swap drill is part of the
    schema now: a report without it must not pass green."""
    bench = _serving_bench(1.2)
    bench["rows"] = bench["rows"][:7]  # drop the bigranular_swap row
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "no 'bigranular_swap' row" in out.stderr


def test_serving_gate_fails_without_rerank_provenance(tmp_path):
    """bit-identical results alone do not prove the tier served the
    bi-granular path — a silent fallback to the flat index would also
    be bit-identical. Every ticket must carry reranked provenance."""
    bench = _serving_bench(1.2)
    bench["rows"][7] = _bigranular_swap_row(reranked=False)
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "did not serve every query through the bi-granular rerank" \
        in out.stderr


def test_serving_gate_fails_on_lost_results_during_bigranular_swap(tmp_path):
    bench = _serving_bench(1.2)
    bench["rows"][7] = _bigranular_swap_row(lost=2)
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "lost 2 result(s) during the rolling swap" in out.stderr


def test_serving_gate_fails_when_bigranular_swap_breaks_bit_identity(
        tmp_path):
    bench = _serving_bench(1.2)
    bench["rows"][7] = _bigranular_swap_row(bit_identical=False)
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "not bit-identical" in out.stderr


# -- shed-pressure autoscaler drill (autoscale row) ---------------------------


def test_serving_gate_requires_an_autoscale_row(tmp_path):
    """The autoscaler drill is part of the schema now: a report without
    it (emitter regression) must not pass green."""
    bench = _serving_bench(1.2)
    bench["rows"] = bench["rows"][:8]  # drop the autoscale row
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "no 'autoscale' row" in out.stderr


def test_serving_gate_fails_on_malformed_autoscale_row(tmp_path):
    bench = _serving_bench(1.2)
    del bench["rows"][8]["shed_rate_autoscaled"]
    del bench["rows"][8]["max_replicas_seen"]
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "missing keys" in out.stderr
    assert "shed_rate_autoscaled" in out.stderr
    assert "max_replicas_seen" in out.stderr


def test_serving_gate_fails_when_autoscaling_does_not_reduce_shed(tmp_path):
    """The row's reason to exist: strictly fewer sheds than the fixed
    tier on the same trace. Equal shed rates also fail — scaling up has
    to buy something."""
    bench = _serving_bench(1.2)
    bench["rows"][8] = _autoscale_row(shed_rate_autoscaled=0.4,
                                      shed_rate_fixed=0.4)
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "did not reduce shedding" in out.stderr


def test_serving_gate_fails_on_lost_results_during_autoscale(tmp_path):
    bench = _serving_bench(1.2)
    bench["rows"][8] = _autoscale_row(lost=3)
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "lost 3 result(s)" in out.stderr


def test_serving_gate_fails_on_reordered_results_during_autoscale(tmp_path):
    bench = _serving_bench(1.2)
    bench["rows"][8] = _autoscale_row(reordered=1)
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "reordered 1 result(s)" in out.stderr


def test_serving_gate_fails_when_replicas_leave_spec_bounds(tmp_path):
    bench = _serving_bench(1.2)
    bench["rows"][8] = _autoscale_row(max_replicas_seen=4)  # spec max is 3
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "left the TierSpec bounds" in out.stderr

    bench["rows"][8] = _autoscale_row(min_replicas_seen=0)  # spec min is 1
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "left the TierSpec bounds" in out.stderr


def test_serving_gate_fails_on_unequal_steady_state_comparison(tmp_path):
    """A tier that never settles back to the fixed tier's size is not a
    fair shed comparison — more steady-state replicas would win on
    capacity alone."""
    bench = _serving_bench(1.2)
    bench["rows"][8] = _autoscale_row(steady_state_replicas=2)
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "equal steady-state capacity" in out.stderr


def test_serving_gate_fails_when_autoscaler_never_scaled_up(tmp_path):
    bench = _serving_bench(1.2)
    bench["rows"][8] = _autoscale_row(scale_ups=0)
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0
    assert "no scale-up" in out.stderr


# -- docs lint (scripts/check_docs_links.py) ---------------------------------

DOCS_LINT = os.path.join(
    os.path.dirname(__file__), "..", "scripts", "check_docs_links.py"
)


def _run_docs_lint(repo):
    return subprocess.run(
        [sys.executable, DOCS_LINT, str(repo)],
        capture_output=True, text=True, timeout=60,
    )


def _docs_lint_repo(tmp_path, readme="# hi\n[ok](docs/GOOD.md)\n",
                    launch_src='"""documented."""\nX = 1\n'):
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "GOOD.md").write_text("# good\n")
    (tmp_path / "README.md").write_text(readme)
    launch = tmp_path / "src" / "repro" / "launch"
    launch.mkdir(parents=True)
    (launch / "mod.py").write_text(launch_src)
    return tmp_path


def test_docs_lint_passes_healthy_repo(tmp_path):
    repo = _docs_lint_repo(tmp_path)
    out = _run_docs_lint(repo)
    assert out.returncode == 0, out.stderr


def test_docs_lint_fails_on_broken_relative_link(tmp_path):
    repo = _docs_lint_repo(tmp_path, readme="[dead](docs/MISSING.md)\n")
    out = _run_docs_lint(repo)
    assert out.returncode != 0
    assert "broken link" in out.stderr and "MISSING.md" in out.stderr


def test_docs_lint_ignores_external_links_and_code_blocks(tmp_path):
    repo = _docs_lint_repo(
        tmp_path,
        readme=("[ext](https://example.com/x) [anchor](#sec)\n"
                "```\n[fake](not/a/file.md)\n```\n"
                "inline `[q](also/fake.md)` span\n"),
    )
    out = _run_docs_lint(repo)
    assert out.returncode == 0, out.stderr


def test_docs_lint_fails_on_undocumented_launch_module(tmp_path):
    repo = _docs_lint_repo(tmp_path, launch_src="X = 1\n")
    out = _run_docs_lint(repo)
    assert out.returncode != 0
    assert "missing module docstring" in out.stderr


def test_docs_lint_passes_this_repo(tmp_path):
    """The real README/docs/launch tree must satisfy its own lint."""
    repo = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    out = _run_docs_lint(repo)
    assert out.returncode == 0, out.stderr


def test_gate_accepts_real_emitter_output(tmp_path, monkeypatch):
    """End-to-end: the actual tiny-corpus emitter satisfies the gate."""
    repo_root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    if repo_root not in sys.path:  # bare `pytest` does not add the cwd
        sys.path.insert(0, repo_root)
    from benchmarks.table5_search_latency import emit_sdc_scan_json

    # keep the emitter's autotune sweep out of the user's real tune cache
    monkeypatch.setenv("REPRO_BEBR_CACHE", str(tmp_path / "tune-cache"))
    path = tmp_path / "BENCH_sdc_scan.json"
    emit_sdc_scan_json(path=str(path), n_docs=1024, queries=4)
    out = subprocess.run(
        [sys.executable, GATE, str(path)],
        capture_output=True, text=True, timeout=120,
    )
    assert out.returncode == 0, out.stdout + out.stderr


def test_serving_gate_accepts_real_emitter_schema(tmp_path):
    """End-to-end: the serving emitter's replica sweep satisfies the
    SCHEMA half of the gate (the QPS floors are waived — a micro corpus
    in a loaded test process is not a throughput measurement)."""
    repo_root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    if repo_root not in sys.path:
        sys.path.insert(0, repo_root)
    from benchmarks.table5_search_latency import emit_serving_json

    path = tmp_path / "BENCH_serving.json"
    # the upgrade row trains its own mini-world (phi_v1 + the bc-trained
    # phi_v2), so this end-to-end run includes a real training loop
    emit_serving_json(path=str(path), n_docs=512, batch=8, n_batches=6,
                      trials=2)
    out = subprocess.run(
        [sys.executable, GATE, str(path),
         "--min-serving-ratio", "0", "--min-replica-ratio", "0"],
        capture_output=True, text=True, timeout=180,
    )
    assert out.returncode == 0, out.stdout + out.stderr
