"""The CI bench gate (scripts/check_bench_gate.py) must actually gate:
green on a healthy packed/unpacked byte ratio, red on a regressed one, on
a missing packed row, and on an empty report (deliberate-failure coverage
demanded by the CI satellite — a gate that cannot fail is decoration)."""

import json
import os
import subprocess
import sys

GATE = os.path.join(
    os.path.dirname(__file__), "..", "scripts", "check_bench_gate.py"
)


def _rows(ratio: float):
    return [
        {"variant": "flat", "packed": False, "bytes_scanned": 100_000},
        {"variant": "flat", "packed": True,
         "bytes_scanned": int(100_000 * ratio)},
        {"variant": "ivf", "packed": False, "bytes_scanned": 50_000},
        {"variant": "ivf", "packed": True,
         "bytes_scanned": int(50_000 * ratio)},
    ]


def _run_gate(tmp_path, bench: dict, *extra):
    path = tmp_path / "bench.json"
    path.write_text(json.dumps(bench))
    return subprocess.run(
        [sys.executable, GATE, str(path), *extra],
        capture_output=True, text=True, timeout=60,
    )


def test_gate_passes_healthy_ratio(tmp_path):
    out = _run_gate(tmp_path, {"rows": _rows(0.53)})
    assert out.returncode == 0, out.stderr


def test_gate_fails_regressed_ratio(tmp_path):
    out = _run_gate(tmp_path, {"rows": _rows(0.60)})
    assert out.returncode != 0
    assert "FAIL" in out.stdout


def test_gate_threshold_is_configurable(tmp_path):
    out = _run_gate(tmp_path, {"rows": _rows(0.60)},
                    "--max-packed-ratio", "0.65")
    assert out.returncode == 0, out.stderr


def test_gate_fails_on_missing_packed_row(tmp_path):
    rows = [r for r in _rows(0.5) if not r["packed"]]
    out = _run_gate(tmp_path, {"rows": rows})
    assert out.returncode != 0
    assert "MISSING-PAIR" in out.stdout


def test_gate_fails_on_empty_report(tmp_path):
    out = _run_gate(tmp_path, {"rows": []})
    assert out.returncode != 0


def test_gate_understands_hnsw_schema(tmp_path):
    """BENCH_hnsw_scan rows carry table_bytes and no variant key; the
    gate must pair them by the bench name and apply the same invariant."""
    def bench(ratio):
        return {"bench": "hnsw_scan", "rows": [
            {"packed": False, "table_bytes": 200_000},
            {"packed": True, "table_bytes": int(200_000 * ratio)},
        ]}

    assert _run_gate(tmp_path, bench(0.53)).returncode == 0
    out = _run_gate(tmp_path, bench(0.60))
    assert out.returncode != 0
    assert "hnsw_scan" in out.stdout


def _serving_bench(ratio: float):
    return {"bench": "serving", "rows": [
        {"mode": "sequential", "qps": 1000.0},
        {"mode": "overlapped", "qps": 1000.0 * ratio},
    ]}


def test_serving_gate_passes_when_overlapped_wins(tmp_path):
    out = _run_gate(tmp_path, _serving_bench(1.15))
    assert out.returncode == 0, out.stderr


def test_serving_gate_fails_when_pipeline_loses_throughput(tmp_path):
    out = _run_gate(tmp_path, _serving_bench(0.9))
    assert out.returncode != 0
    assert "FAIL" in out.stdout


def test_serving_gate_ratio_is_configurable(tmp_path):
    out = _run_gate(tmp_path, _serving_bench(0.9),
                    "--min-serving-ratio", "0.85")
    assert out.returncode == 0, out.stderr


def test_serving_gate_fails_on_missing_mode_row(tmp_path):
    bench = _serving_bench(1.2)
    bench["rows"] = bench["rows"][:1]  # no overlapped row
    out = _run_gate(tmp_path, bench)
    assert out.returncode != 0


def test_gate_accepts_real_emitter_output(tmp_path):
    """End-to-end: the actual tiny-corpus emitter satisfies the gate."""
    repo_root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    if repo_root not in sys.path:  # bare `pytest` does not add the cwd
        sys.path.insert(0, repo_root)
    from benchmarks.table5_search_latency import emit_sdc_scan_json

    path = tmp_path / "BENCH_sdc_scan.json"
    emit_sdc_scan_json(path=str(path), n_docs=1024, queries=4)
    out = subprocess.run(
        [sys.executable, GATE, str(path)],
        capture_output=True, text=True, timeout=120,
    )
    assert out.returncode == 0, out.stdout + out.stderr
