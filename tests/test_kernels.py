"""Per-kernel interpret-mode validation against pure-jnp oracles, with
shape/dtype sweeps and hypothesis property tests (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import pack_bitplanes
from repro.core.binarize_lib import unpack_codes
from repro.kernels.binary_dot.kernel import binary_dot
from repro.kernels.binary_dot.ops import binary_dot_search
from repro.kernels.binary_dot.ref import binary_dot_ref
from repro.kernels.dot_interact.ops import dot_interaction
from repro.kernels.dot_interact.ref import dot_interact_ref
from repro.kernels.sdc import ref as R
from repro.kernels.sdc.ops import sdc_search, sdc_search_ref
from repro.kernels.sdc.sdc import sdc_scores, sdc_topk


# ---------------------------------------------------------------------------
# SDC kernel.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_levels", [1, 2, 3, 4])
@pytest.mark.parametrize("D", [32, 64, 160])
@pytest.mark.parametrize("Q,N", [(8, 64), (16, 128)])
def test_sdc_kernel_matches_oracle(n_levels, D, Q, N):
    key = jax.random.PRNGKey(n_levels * 1000 + D)
    q = jax.random.randint(key, (Q, D), 0, 2**n_levels).astype(jnp.int8)
    d = jax.random.randint(jax.random.fold_in(key, 1), (N, D), 0,
                           2**n_levels).astype(jnp.int8)
    inv = R.doc_inv_norms(d, n_levels)
    exact = R.sdc_ref(q, d, n_levels, inv)
    got = sdc_scores(q, d, inv, n_levels=n_levels, block_q=Q, block_n=N // 2,
                     interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exact), atol=1e-4)


def test_sdc_affine_identity_is_exact():
    key = jax.random.PRNGKey(7)
    q = jax.random.randint(key, (4, 96), 0, 16).astype(jnp.int8)
    d = jax.random.randint(jax.random.fold_in(key, 1), (32, 96), 0, 16
                           ).astype(jnp.int8)
    a = R.sdc_ref(q, d, 4)
    b = R.sdc_ref_affine(q, d, 4)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("n_levels", [2, 4])
def test_sdc_lut_emulation_close_but_quantised(n_levels):
    """The paper's int8-LUT path carries small quantisation error; our MXU
    path must carry none. Verifies both statements."""
    key = jax.random.PRNGKey(3)
    q = jax.random.randint(key, (4, 64), 0, 2**n_levels).astype(jnp.int8)
    d = jax.random.randint(jax.random.fold_in(key, 1), (64, 64), 0,
                           2**n_levels).astype(jnp.int8)
    exact = R.sdc_ref(q, d, n_levels)
    lut = R.sdc_ref_lut(q, d, n_levels)
    rel = float(jnp.max(jnp.abs(exact - lut)) / (jnp.max(jnp.abs(exact)) + 1e-9))
    assert 0 < rel < 0.05  # quantised but close


def test_sdc_fused_topk_matches_unfused():
    key = jax.random.PRNGKey(11)
    q = jax.random.randint(key, (8, 64), 0, 16).astype(jnp.int8)
    d = jax.random.randint(jax.random.fold_in(key, 1), (500, 64), 0, 16
                           ).astype(jnp.int8)
    inv = R.doc_inv_norms(d, 4)
    vf, if_ = sdc_search(q, d, inv, n_levels=4, k=13, block_q=8, block_n=64,
                         interpret=True, fused=True)
    vu, iu = sdc_search(q, d, inv, n_levels=4, k=13, block_q=8, block_n=64,
                        interpret=True, fused=False)
    np.testing.assert_allclose(np.asarray(vf), np.asarray(vu), atol=1e-5)
    ev, ei = sdc_search_ref(q, d, 4, 13)
    np.testing.assert_allclose(np.asarray(vf), np.asarray(ev), atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    n_levels=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_sdc_property_scores_bounded_by_cauchy_schwarz(n_levels, seed):
    """|<v_q, v_d>|/||v_d|| <= ||v_q|| for all codes (exact arithmetic)."""
    key = jax.random.PRNGKey(seed)
    q = jax.random.randint(key, (4, 32), 0, 2**n_levels).astype(jnp.int8)
    d = jax.random.randint(jax.random.fold_in(key, 1), (16, 32), 0,
                           2**n_levels).astype(jnp.int8)
    from repro.core import codes_to_values

    s = R.sdc_ref(q, d, n_levels)
    qn = jnp.linalg.norm(codes_to_values(q, n_levels), axis=-1)
    assert bool(jnp.all(jnp.abs(s) <= qn[:, None] + 1e-3))


# ---------------------------------------------------------------------------
# binary_dot kernel.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_levels", [1, 2, 4])
@pytest.mark.parametrize("m", [32, 64, 128])
def test_binary_dot_matches_oracle(n_levels, m):
    key = jax.random.PRNGKey(m + n_levels)
    cq = jax.random.randint(key, (8, m), 0, 2**n_levels).astype(jnp.int8)
    cd = jax.random.randint(jax.random.fold_in(key, 1), (64, m), 0,
                            2**n_levels).astype(jnp.int8)
    pq = pack_bitplanes(unpack_codes(cq, n_levels))
    pd = pack_bitplanes(unpack_codes(cd, n_levels))
    ref = binary_dot_ref(pq, pd, m)
    got = binary_dot(pq, pd, m=m, block_q=8, block_n=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-4)


def test_binary_dot_equals_sdc_unnormalised():
    """Eq. 11 bitwise dot == grid-value dot == SDC numerator."""
    from repro.core import codes_to_values

    key = jax.random.PRNGKey(5)
    cq = jax.random.randint(key, (4, 64), 0, 16).astype(jnp.int8)
    cd = jax.random.randint(jax.random.fold_in(key, 1), (32, 64), 0, 16
                            ).astype(jnp.int8)
    pq = pack_bitplanes(unpack_codes(cq, 4))
    pd = pack_bitplanes(unpack_codes(cd, 4))
    bd = binary_dot_ref(pq, pd, 64)
    vq = codes_to_values(cq, 4)
    vd = codes_to_values(cd, 4)
    np.testing.assert_allclose(np.asarray(bd), np.asarray(vq @ vd.T), atol=1e-4)


def test_binary_dot_search_padding():
    key = jax.random.PRNGKey(9)
    cq = jax.random.randint(key, (3, 32), 0, 4).astype(jnp.int8)
    cd = jax.random.randint(jax.random.fold_in(key, 1), (77, 32), 0, 4
                            ).astype(jnp.int8)
    pq = pack_bitplanes(unpack_codes(cq, 2))
    pd = pack_bitplanes(unpack_codes(cd, 2))
    vals, idx = binary_dot_search(pq, pd, m=32, k=5, interpret=True)
    assert vals.shape == (3, 5)
    assert bool(jnp.all(idx < 77))


# ---------------------------------------------------------------------------
# dot_interact kernel.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("F,D", [(27, 64), (8, 16), (13, 32)])
@pytest.mark.parametrize("B", [32, 100])
def test_dot_interact_matches_oracle(F, D, B):
    e = jax.random.normal(jax.random.PRNGKey(F * B), (B, F, D))
    ref = dot_interact_ref(e)
    got = dot_interaction(e, block_b=16, interpret=True)
    assert got.shape == (B, F * (F - 1) // 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_dot_interact_symmetry_property(seed):
    """Permuting feature order permutes pairs but preserves the multiset of
    pairwise dots."""
    e = jax.random.normal(jax.random.PRNGKey(seed), (4, 6, 8))
    perm = jax.random.permutation(jax.random.PRNGKey(seed + 1), 6)
    a = np.sort(np.asarray(dot_interact_ref(e)), axis=-1)
    b = np.sort(np.asarray(dot_interact_ref(e[:, perm, :])), axis=-1)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
