"""Prefetch loader (straggler mitigation) + loop-corrected HLO cost model."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import PrefetchLoader
from repro.launch.hlo_cost import hlo_costs


def test_prefetch_preserves_order_and_content():
    loader = PrefetchLoader(lambda step: step * 10, depth=3)
    got = [next(loader) for _ in range(5)]
    loader.close()
    assert got == [0, 10, 20, 30, 40]


def test_prefetch_backup_on_straggler():
    calls = {"n": 0}

    def slow_then_fast(step):
        calls["n"] += 1
        if calls["n"] == 1:
            time.sleep(0.6)  # primary stalls on the first batch
        return step

    loader = PrefetchLoader(slow_then_fast, depth=1, deadline_s=0.15)
    first = next(loader)
    assert first == 0  # backup produced step 0 deterministically
    assert loader.timeouts == 1
    loader.close()


def test_prefetch_propagates_errors():
    def bad(step):
        raise ValueError("boom")

    loader = PrefetchLoader(bad, depth=1)
    with pytest.raises(ValueError, match="boom"):
        next(loader)
    loader.close()


# ---------------------------------------------------------------------------
# hlo_cost: loop-aware FLOPs.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("length", [1, 5, 13])
def test_hlo_cost_multiplies_scan_bodies(length):
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None

        y, _ = jax.lax.scan(body, x, None, length=length)
        return jnp.sum(y)

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    res = hlo_costs(compiled.as_text(), 1)
    expected = length * 2 * 128**3
    assert res["flops"] == pytest.approx(expected, rel=0.01)


def test_hlo_cost_nested_scans_compose():
    def f(x, w):
        def inner(c, _):
            return jnp.tanh(c @ w), None

        def outer(c, _):
            y, _ = jax.lax.scan(inner, c, None, length=3)
            return y, None

        y, _ = jax.lax.scan(outer, x, None, length=4)
        return jnp.sum(y)

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    res = hlo_costs(compiled.as_text(), 1)
    expected = 12 * 2 * 64**3  # 4 x 3 matmuls
    assert res["flops"] == pytest.approx(expected, rel=0.01)


def test_hlo_cost_counts_more_than_xla_for_loops():
    """The whole point: XLA counts bodies once; we don't."""

    def f(x, w):
        def body(c, _):
            return c @ w, None

        y, _ = jax.lax.scan(body, x, None, length=10)
        return jnp.sum(y)

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older JAX: one dict per device
        ca = ca[0]
    xla_flops = ca.get("flops", 0)
    ours = hlo_costs(compiled.as_text(), 1)["flops"]
    assert ours > 5 * xla_flops
