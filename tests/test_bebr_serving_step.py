"""Correctness of the BEBR-optimised retrieval step (§Perf cell A):
the int8 affine-identity scoring inside steps.tt_retrieval_bebr_step must
rank exactly like the SDC reference over the candidate codes."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.kernels.sdc import ref as R
from repro.models.recsys import two_tower as tt
from repro.train import steps


def test_bebr_retrieval_step_matches_sdc_reference():
    cfg = get_arch("two-tower-retrieval").smoke_config
    key = jax.random.PRNGKey(0)
    params = tt.init_params(key, cfg)
    code_dim, n_levels = 16, 4
    emb_out = cfg.tower_mlp[-1]
    ks = jax.random.split(key, 8)
    params = dict(params)
    params["binarizer"] = {
        "W": [jax.random.normal(ks[t], (emb_out, code_dim)) / emb_out**0.5
              for t in range(n_levels)],
        "R": [jax.random.normal(ks[4 + t], (code_dim, emb_out)) / code_dim**0.5
              for t in range(n_levels - 1)],
    }

    N = 500
    cand_codes = jax.random.randint(ks[7], (N, code_dim), 0,
                                    2**n_levels).astype(jnp.int8)
    cand_inv = R.doc_inv_norms(cand_codes, n_levels)
    batch = {
        "hist_ids": jnp.arange(cfg.hist_len)[None, :],
        "hist_mask": jnp.ones((1, cfg.hist_len), jnp.float32),
        "cand_codes": cand_codes,
        "cand_inv": cand_inv,
    }
    step = steps.tt_retrieval_bebr_step(cfg, k=20, code_dim=code_dim,
                                        n_levels=n_levels)
    vals, idx = jax.jit(step)(params, batch)
    assert vals.shape == (1, 20) and bool(jnp.all(idx < N))

    # reproduce the query code independently and compare against sdc_ref
    q = tt.query_embed(params, batch["hist_ids"], batch["hist_mask"], cfg)
    bp = params["binarizer"]
    f = q / jnp.linalg.norm(q, axis=-1, keepdims=True)
    sign = lambda x: jnp.where(x > 0, 1.0, -1.0)
    b = sign(f @ bp["W"][0])
    acc, code = b, (b + 1) * 0.5 * 2 ** (n_levels - 1)
    for t in range(n_levels - 1):
        recon = acc @ bp["R"][t]
        recon = recon / jnp.linalg.norm(recon, axis=-1, keepdims=True)
        r = sign((f - recon) @ bp["W"][t + 1])
        acc = acc + 2.0 ** -(t + 1) * r
        code = code + (r + 1) * 0.5 * 2 ** (n_levels - 2 - t)
    ref_scores = R.sdc_ref(code.astype(jnp.int8), cand_codes, n_levels,
                           cand_inv)
    ev, ei = jax.lax.top_k(ref_scores, 20)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(ev), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ei))
