"""Occupancy-weighted IVF probe budgets (index/ivf.py).

A global ``probe_budget`` of per-centroid rank slots replaces the flat
``nprobe``. The load-bearing invariants: the allocation spends exactly
the budget, exact multiples of ``nlist`` are bit-identical to flat
nprobe (same jit program, not just same answers), surplus slots follow
list occupancy, and the effort knob halves the budget instead of the
probe count.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.binarize_lib import codes_to_values
from repro.index import ivf as ivf_lib
from repro.kernels.sdc import ref as R
from repro.kernels.sdc.ops import sdc_search_xla

M, LEVELS, NLIST = 16, 2, 8


def _clustered_corpus(seed=0, n=512, skew=True):
    """Cluster sizes ~ 1/rank (heaviest first) when skewed, else equal."""
    rng = np.random.default_rng(seed)
    n_clusters = 2 * NLIST
    if skew:
        w = 1.0 / np.arange(1, n_clusters + 1)
    else:
        w = np.ones(n_clusters)
    sizes = np.maximum(1, np.round(n * w / w.sum()).astype(int))
    sizes[0] += n - sizes.sum()
    hi = 2 ** LEVELS
    centers = rng.integers(0, hi, size=(n_clusters, M))
    parts = []
    for c in range(n_clusters):
        rows = np.repeat(centers[c][None, :], sizes[c], 0)
        flip = rng.random(rows.shape) < 0.08
        parts.append(np.where(flip, rng.integers(0, hi, size=rows.shape),
                              rows))
    return jnp.asarray(np.concatenate(parts).astype(np.int8))


def _queries(cd, seed=1, q=8, head=None):
    rng = np.random.default_rng(seed)
    n = cd.shape[0]
    src = rng.integers(0, head or n, size=q)
    base = np.asarray(cd)[src].astype(np.int64)
    flip = rng.random(base.shape) < 0.15
    hi = 2 ** LEVELS
    return jnp.asarray(
        np.where(flip, rng.integers(0, hi, size=base.shape),
                 base).astype(np.int8)
    )


def _index(cd, **kw):
    return ivf_lib.build_ivf(jax.random.PRNGKey(3), cd, n_levels=LEVELS,
                             nlist=NLIST, kmeans_iters=4, **kw)


def test_thresholds_spend_exactly_the_budget():
    occ = np.array([100, 50, 25, 12, 6, 3, 2, 1], np.float64)
    for budget in (1, 3, NLIST, NLIST + 3, 3 * NLIST, 3 * NLIST + 5):
        r = ivf_lib.probe_rank_thresholds(occ, probe_budget=budget,
                                          nlist=NLIST)
        assert r.sum() == budget
        assert r.min() >= budget // NLIST  # uniform floor for every list
        assert r.max() <= NLIST


def test_surplus_goes_to_heavy_lists():
    occ = np.array([100, 50, 25, 12, 6, 3, 2, 1], np.float64)
    r = ivf_lib.probe_rank_thresholds(occ, probe_budget=NLIST + 3,
                                      nlist=NLIST)
    # floor of 1 everywhere; the 3 surplus slots follow the mass by
    # largest remainder: list 0 holds ~half the corpus and earns two.
    assert list(r) == [3, 2, 1, 1, 1, 1, 1, 1]
    assert all(r[i] >= r[i + 1] for i in range(NLIST - 1))
    flat = ivf_lib.probe_rank_thresholds(occ, probe_budget=NLIST + 3,
                                         nlist=NLIST, weighted=False)
    assert flat.sum() == NLIST + 3  # same spend, different placement
    assert list(flat) == [2, 2, 2, 1, 1, 1, 1, 1]  # lowest-index tiebreak


def test_exact_multiple_budget_is_uniform():
    occ = np.array([100, 50, 25, 12, 6, 3, 2, 1], np.float64)
    for nprobe in (1, 2, 4):
        r = ivf_lib.probe_rank_thresholds(occ, probe_budget=nprobe * NLIST,
                                          nlist=NLIST)
        assert list(r) == [nprobe] * NLIST


def test_threshold_validation():
    with pytest.raises(ValueError, match="probe_budget"):
        ivf_lib.probe_rank_thresholds(None, probe_budget=0, nlist=NLIST)
    with pytest.raises(ValueError, match="occupancy"):
        ivf_lib.probe_rank_thresholds(np.ones(3), probe_budget=NLIST + 1,
                                      nlist=NLIST)


def test_build_captures_list_occupancy():
    cd = _clustered_corpus()
    index = _index(cd)
    occ = np.asarray(index.list_occupancy)
    assert occ.shape == (NLIST,)
    assert occ.sum() == cd.shape[0]


def test_exact_multiple_budget_is_bit_identical_to_flat_nprobe():
    cd = _clustered_corpus()
    cq = _queries(cd)
    index = _index(cd)
    for nprobe in (1, 2, 4):
        ref_s, ref_i = ivf_lib.search(index, cq, nprobe=nprobe, k=5,
                                      backend="xla")
        for weighted in (True, False):
            s, i = ivf_lib.search_budget(index, cq,
                                         probe_budget=nprobe * NLIST, k=5,
                                         weighted=weighted, backend="xla")
            np.testing.assert_array_equal(np.asarray(i), np.asarray(ref_i))
            np.testing.assert_array_equal(np.asarray(s), np.asarray(ref_s))


def test_budgeted_search_matches_masked_reference():
    # Non-uniform thresholds: list c is probed iff it ranks within
    # r[c] in the query's coarse ordering. Check against a per-query
    # numpy reconstruction of exactly that probe set.
    cd = _clustered_corpus(seed=2)
    cq = _queries(cd, seed=4, q=4)
    index = _index(cd)
    budget = NLIST + 3
    r = ivf_lib.probe_rank_thresholds(index.list_occupancy,
                                      probe_budget=budget, nlist=NLIST)
    s, ids = ivf_lib.search_budget(index, cq, probe_budget=budget, k=5,
                                   backend="xla")
    vq = np.asarray(codes_to_values(cq, LEVELS))
    cv = np.asarray(index.centroids)
    order = np.argsort(-(vq @ cv.T), axis=1, kind="stable")
    ids = np.asarray(ids)
    lists_ids = np.asarray(index.lists_ids)
    for qi in range(cq.shape[0]):
        probed = {int(c) for rank, c in enumerate(order[qi])
                  if rank < r[c]}
        allowed = {int(d) for c in probed for d in lists_ids[c] if d >= 0}
        found = {int(d) for d in ids[qi] if d >= 0}
        assert found <= allowed


def test_weighted_beats_flat_on_skewed_occupancy():
    cd = _clustered_corpus(seed=6, n=768)
    # queries from the heavy head, where weighted surplus goes
    cq = _queries(cd, seed=7, q=16, head=cd.shape[0] // 4)
    index = _index(cd)
    inv = R.doc_inv_norms(cd, LEVELS)
    gt = np.asarray(sdc_search_xla(cq, cd, inv, n_levels=LEVELS, k=5)[1])

    def recall(weighted):
        _, i = ivf_lib.search_budget(index, cq, probe_budget=NLIST + 4,
                                     k=5, weighted=weighted, backend="xla")
        i = np.asarray(i)
        return np.mean([
            len(set(i[q]) & set(gt[q])) / 5 for q in range(cq.shape[0])
        ])

    assert recall(True) >= recall(False)


def test_snapshot_closure_serves_a_probe_budget():
    cd = _clustered_corpus(seed=8)
    cq = _queries(cd, seed=9, q=4)
    index = _index(cd)
    fn = ivf_lib.ivf_search_from_snapshot(
        cd, LEVELS, k=5, nlist=NLIST, nprobe=1, seed=3, kmeans_iters=4,
        backend="xla", probe_budget=NLIST + 3,
    )
    s, i = fn(cq)
    ref_s, ref_i = ivf_lib.search_budget(index, cq, probe_budget=NLIST + 3,
                                         k=5, backend="xla")
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ref_i))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(ref_s))


def test_effort_knob_halves_the_budget():
    from repro.launch.proxy import EffortKnob

    cd = _clustered_corpus(seed=10)
    cq = _queries(cd, seed=11, q=4)
    index = _index(cd)
    knob = EffortKnob(n_levels=3)
    budget = 4 * NLIST + 3
    fn = ivf_lib.ivf_search_from_snapshot(
        cd, LEVELS, k=5, nlist=NLIST, nprobe=1, seed=3, kmeans_iters=4,
        backend="xla", probe_budget=budget, effort=knob,
    )
    full_s, full_i = fn(cq)
    ref_s, ref_i = ivf_lib.search_budget(index, cq, probe_budget=budget,
                                         k=5, backend="xla")
    np.testing.assert_array_equal(np.asarray(full_i), np.asarray(ref_i))
    np.testing.assert_array_equal(np.asarray(full_s), np.asarray(ref_s))
    assert knob.degrade() and knob.degrade()  # level 2: budget >> 2
    deg_s, deg_i = fn(cq)
    ref_s, ref_i = ivf_lib.search_budget(index, cq,
                                         probe_budget=max(1, budget >> 2),
                                         k=5, backend="xla")
    np.testing.assert_array_equal(np.asarray(deg_i), np.asarray(ref_i))
    np.testing.assert_array_equal(np.asarray(deg_s), np.asarray(ref_s))
