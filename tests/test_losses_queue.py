"""Queue mechanics, hard-negative mining, EMA updates."""

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.losses as L


def test_queue_fifo_and_fill():
    q = L.init_queue(L.QueueConfig(length=8, dim=4, top_k=2))
    b1 = jnp.ones((3, 4)) * 1
    b2 = jnp.ones((3, 4)) * 2
    b3 = jnp.ones((3, 4)) * 3
    q = L.queue_push(q, b1)
    q = L.queue_push(q, b2)
    assert int(q["filled"]) == 6 and int(q["ptr"]) == 6
    q = L.queue_push(q, b3)  # wraps: slots 6,7,0
    assert int(q["filled"]) == 8
    assert float(q["buf"][0, 0]) == 3.0  # oldest overwritten
    assert float(q["buf"][5, 0]) == 2.0


def test_mine_hard_negatives_masks_unfilled():
    q = L.init_queue(L.QueueConfig(length=16, dim=4, top_k=4))
    q = L.queue_push(q, jnp.eye(4))
    anchors = jnp.eye(4)
    negs = L.mine_hard_negatives(q, anchors, 4)
    # only 4 valid rows exist; all returned rows must be from them
    assert negs.shape == (4, 4, 4)
    assert float(jnp.max(jnp.abs(negs))) <= 1.0


def test_mine_hard_negatives_picks_highest_similarity():
    q = L.init_queue(L.QueueConfig(length=8, dim=3, top_k=1))
    entries = jnp.array([[1, 0, 0], [0.9, 0.1, 0], [0, 1, 0], [0, 0, 1.0]],
                        jnp.float32)
    q = L.queue_push(q, entries)
    anchor = jnp.array([[1.0, 0, 0]])
    negs = L.mine_hard_negatives(q, anchor, 1)
    np.testing.assert_allclose(np.asarray(negs[0, 0]), [1, 0, 0], atol=1e-6)


def test_positive_exclusion():
    q = L.init_queue(L.QueueConfig(length=8, dim=3, top_k=1))
    entries = jnp.array([[1, 0, 0], [0.6, 0.8, 0]], jnp.float32)
    q = L.queue_push(q, entries)
    anchor = jnp.array([[1.0, 0, 0]])
    pos = jnp.array([[1.0, 0, 0]])  # identical to queue row 0
    negs = L.mine_hard_negatives(q, anchor, 1, positives=pos)
    np.testing.assert_allclose(np.asarray(negs[0, 0]), [0.6, 0.8, 0], atol=1e-6)


def test_info_nce_prefers_aligned_positive():
    a = jnp.array([[1.0, 0, 0]])
    pos = jnp.array([[1.0, 0, 0]])
    neg = jnp.array([[[0, 1.0, 0], [0, 0, 1.0]]])
    low = L.info_nce(a, pos, neg)
    hard_pos = jnp.array([[0, 1.0, 0]])
    high = L.info_nce(a, hard_pos, neg)
    assert float(low) < float(high)


def test_ema_update_moves_toward_online():
    online = {"w": jnp.ones((3,))}
    momentum = {"w": jnp.zeros((3,))}
    out = L.ema_update(online, momentum, decay=0.9)
    np.testing.assert_allclose(np.asarray(out["w"]), 0.1, rtol=1e-6)
