"""Distributed engine + sharded training, run in a subprocess with 8 forced
host devices (device count locks at first jax init, so the main pytest
process must stay single-device)."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=500,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_distributed_engine_matches_exact():
    stdout = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.index.engine import make_distributed_search, engine_input_shardings
        from repro.kernels.sdc import ref as R
        key = jax.random.PRNGKey(0)
        codes = jax.random.randint(key, (4096, 64), 0, 16).astype(jnp.int8)
        q = jax.random.randint(jax.random.fold_in(key,1), (8, 64), 0, 16).astype(jnp.int8)
        inv = R.doc_inv_norms(codes, 4)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        search = make_distributed_search(mesh, n_levels=4, k=10)
        with mesh:
            qs, ds, vs = engine_input_shardings(mesh)
            mv, mi = search(jax.device_put(q, qs), jax.device_put(codes, ds),
                            jax.device_put(inv, vs))
        ev, ei = jax.lax.top_k(R.sdc_ref(q, codes, 4), 10)
        agree = np.mean([len(set(np.asarray(mi[i])) & set(np.asarray(ei[i])))/10
                         for i in range(8)])
        print("AGREE", agree)
    """)
    assert "AGREE 1.0" in stdout


def test_sharded_lm_train_step_runs():
    stdout = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import get_arch
        from repro.configs.cells import lm_cell
        from repro.launch.mesh import make_host_mesh
        from repro.models import transformer as tf
        from repro.train import optim, steps
        from repro.parallel import sharding as shd
        from repro.data import synthetic

        mesh = make_host_mesh((4, 2), ("data", "model"))
        cfg = get_arch("llama3.2-1b").smoke_config
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        psh = shd.lm_param_sharding(mesh, cfg)
        params = jax.device_put(params, psh)
        opt = optim.adam_init(params)
        batch = synthetic.lm_batch(0, 8, 16, cfg.vocab)
        batch = jax.device_put(batch, {k: shd.lm_batch_sharding(mesh) for k in batch})
        step = jax.jit(steps.lm_train_step(cfg, optim.AdamConfig(lr=1e-3)))
        with mesh:
            params, opt, metrics = step(params, opt, batch)
            params, opt, metrics = step(params, opt, batch)
        loss = float(metrics["loss"])
        assert np.isfinite(loss)
        print("LOSS_OK", loss)
    """)
    assert "LOSS_OK" in stdout


def test_compressed_psum_inside_shard_map():
    stdout = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.train import compression as comp

        mesh = jax.make_mesh((8,), ("data",))
        grads = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
        err = jnp.zeros((8, 64))

        def sync(g, e):
            mean, new_e = comp.compressed_psum({"g": g}, {"g": e}, "data")
            return mean["g"], new_e["g"]

        f = shard_map(sync, mesh=mesh, in_specs=(P("data"), P("data")),
                      out_specs=(P(), P("data")), check_rep=False)
        with mesh:
            mean, new_e = f(grads, err)
        true_mean = jnp.mean(grads, axis=0)
        err_norm = float(jnp.max(jnp.abs(mean[0] - true_mean)))
        scale = float(jnp.max(jnp.abs(grads)) / 127.0)
        assert err_norm <= scale + 1e-5, (err_norm, scale)
        print("COMPRESSED_PSUM_OK", err_norm)
    """)
    assert "COMPRESSED_PSUM_OK" in stdout
