"""Per-architecture smoke tests (deliverable f): reduced config of the same
family, one forward/train step on CPU, asserting shapes + no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import REGISTRY, get_arch
from repro.data import synthetic
from repro.train import optim, steps

ADAM = optim.AdamConfig(lr=1e-3, clip_norm=1.0)

LM_ARCHS = ["llama3-405b", "llama3.2-1b", "mistral-large-123b",
            "llama4-scout-17b-a16e", "grok-1-314b"]


def test_registry_complete():
    assert len(REGISTRY) == 10
    assert sum(len(e.shapes) for e in REGISTRY.values()) == 40


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke_train_and_decode(arch_id):
    from repro.models import transformer as tf

    cfg = get_arch(arch_id).smoke_config
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    batch = synthetic.lm_batch(0, 4, 16, cfg.vocab)
    step = steps.lm_train_step(cfg, ADAM)
    opt = optim.adam_init(params)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0

    # decode one token with a kv cache
    cache = tf.init_kv_cache(cfg, 4, 8, dtype=jnp.float32)
    logits, cache = tf.decode_step(params2, batch["tokens"][:, 0], cache, cfg)
    assert logits.shape == (4, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    assert int(cache["length"]) == 1


def test_gnn_smoke():
    from repro.models import gnn as gnn_lib

    cfg = get_arch("meshgraphnet").smoke_config
    params = gnn_lib.init_params(jax.random.PRNGKey(0), cfg)
    batch = synthetic.gnn_batch(0, 64, 256, cfg)
    step = steps.gnn_train_step(cfg, ADAM)
    opt = optim.adam_init(params)
    _, _, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))


def test_dlrm_smoke():
    from repro.models.recsys import dlrm

    cfg = get_arch("dlrm-rm2").smoke_config
    params = dlrm.init_params(jax.random.PRNGKey(0), cfg)
    batch = synthetic.dlrm_batch(0, 32, cfg)
    step = steps.dlrm_train_step(cfg, ADAM)
    opt = optim.adam_init(params)
    _, _, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    # serve path
    out = jax.jit(steps.dlrm_serve_step(cfg))(params, {
        "dense": batch["dense"], "sparse_ids": batch["sparse_ids"]})
    assert out.shape == (32,)
    assert not bool(jnp.isnan(out).any())


def test_two_tower_smoke():
    from repro.models.recsys import two_tower as tt

    cfg = get_arch("two-tower-retrieval").smoke_config
    params = tt.init_params(jax.random.PRNGKey(0), cfg)
    batch = synthetic.tt_batch(0, 16, cfg)
    step = steps.tt_train_step(cfg, ADAM)
    opt = optim.adam_init(params)
    _, _, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    # retrieval path returns valid top-k
    vals, idx = jax.jit(steps.tt_retrieval_step(cfg, k=7))(params, {
        "hist_ids": batch["hist_ids"][:1], "hist_mask": batch["hist_mask"][:1],
        "cand_ids": jnp.arange(100, dtype=jnp.int32)})
    assert vals.shape == (1, 7) and bool(jnp.all(idx < 100))


def test_mind_smoke():
    from repro.models.recsys import mind

    cfg = get_arch("mind").smoke_config
    params = mind.init_params(jax.random.PRNGKey(0), cfg)
    batch = synthetic.mind_batch(0, 16, cfg)
    step = steps.mind_train_step(cfg, ADAM)
    opt = optim.adam_init(params)
    _, _, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    caps = jax.jit(steps.mind_serve_step(cfg))(params, {
        "hist_ids": batch["hist_ids"], "hist_mask": batch["hist_mask"]})
    assert caps.shape == (16, cfg.n_interests, cfg.embed_dim)
    assert not bool(jnp.isnan(caps).any())


def test_dien_smoke():
    from repro.models.recsys import dien

    cfg = get_arch("dien").smoke_config
    params = dien.init_params(jax.random.PRNGKey(0), cfg)
    batch = synthetic.dien_batch(0, 16, cfg)
    step = steps.dien_train_step(cfg, ADAM)
    opt = optim.adam_init(params)
    _, _, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("arch_id", sorted(REGISTRY))
def test_smoke_training_reduces_loss(arch_id):
    """Five steps of the smoke config must reduce the training loss."""
    entry = get_arch(arch_id)
    cfg = entry.smoke_config
    key = jax.random.PRNGKey(0)
    if entry.family == "lm":
        from repro.models import transformer as tf

        params = tf.init_params(key, cfg)
        step = jax.jit(steps.lm_train_step(cfg, optim.AdamConfig(lr=3e-3)))
        batch_fn = lambda i: synthetic.lm_batch(0, 4, 16, cfg.vocab)  # fixed batch
    elif entry.family == "gnn":
        from repro.models import gnn as gnn_lib

        params = gnn_lib.init_params(key, cfg)
        step = jax.jit(steps.gnn_train_step(cfg, optim.AdamConfig(lr=3e-3)))
        batch_fn = lambda i: synthetic.gnn_batch(0, 64, 256, cfg)
    elif "dlrm" in arch_id:
        from repro.models.recsys import dlrm

        params = dlrm.init_params(key, cfg)
        step = jax.jit(steps.dlrm_train_step(cfg, optim.AdamConfig(lr=3e-3)))
        batch_fn = lambda i: synthetic.dlrm_batch(0, 64, cfg)
    elif "two-tower" in arch_id:
        from repro.models.recsys import two_tower

        params = two_tower.init_params(key, cfg)
        step = jax.jit(steps.tt_train_step(cfg, optim.AdamConfig(lr=3e-3)))
        batch_fn = lambda i: synthetic.tt_batch(0, 32, cfg)
    elif "mind" in arch_id:
        from repro.models.recsys import mind

        params = mind.init_params(key, cfg)
        step = jax.jit(steps.mind_train_step(cfg, optim.AdamConfig(lr=3e-3)))
        batch_fn = lambda i: synthetic.mind_batch(0, 32, cfg)
    else:
        from repro.models.recsys import dien

        params = dien.init_params(key, cfg)
        step = jax.jit(steps.dien_train_step(cfg, optim.AdamConfig(lr=3e-3)))
        batch_fn = lambda i: synthetic.dien_batch(0, 32, cfg)

    opt = optim.adam_init(params)
    losses = []
    for i in range(6):
        params, opt, metrics = step(params, opt, batch_fn(i))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses
