"""Index layer: k-means, IVF, HNSW-lite, flat parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BinarizerConfig, binarize, init_binarizer, pack_codes
from repro.data.synthetic import clustered_corpus
from repro.index import ivf as ivf_lib
from repro.index.flat import FlatBitwise, FlatFloat, FlatSDC
from repro.index.hnsw_lite import build_hnsw, search_hnsw
from repro.index.kmeans import kmeans
from repro.kernels.sdc import ref as R


def _codes_from_corpus(n=2000, q=32, dim=64, n_levels=4, seed=0):
    docs, queries, gt = clustered_corpus(seed, n, q, dim, n_clusters=16)
    cfg = BinarizerConfig(input_dim=dim, code_dim=dim, n_levels=n_levels,
                          hidden_dim=0)
    p, s = init_binarizer(jax.random.PRNGKey(seed), cfg)
    bits_d, _, _ = binarize(p, s, jnp.asarray(docs), cfg)
    bits_q, _, _ = binarize(p, s, jnp.asarray(queries), cfg)
    return pack_codes(bits_d), pack_codes(bits_q), gt


def test_kmeans_reduces_quantisation_error():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (512, 8))
    c1, a1 = kmeans(key, x, k=16, iters=1, pp_init=False)
    c25, a25 = kmeans(key, x, k=16, iters=25, pp_init=False)

    def err(c, a):
        return float(jnp.mean(jnp.sum((x - c[a]) ** 2, -1)))

    assert err(c25, a25) <= err(c1, a1)
    assert int(a25.max()) < 16


def test_ivf_exact_when_probing_all_lists():
    d_codes, q_codes, _ = _codes_from_corpus()
    index = ivf_lib.build_ivf(jax.random.PRNGKey(1), d_codes, n_levels=4,
                              nlist=8)
    vals, ids = ivf_lib.search(index, q_codes, nprobe=8, k=10)
    ev, ei = jax.lax.top_k(R.sdc_ref(q_codes, d_codes, 4), 10)
    # probing every list must equal exhaustive SDC search
    overlap = np.mean([
        len(set(np.asarray(ids[i])) & set(np.asarray(ei[i]))) / 10
        for i in range(ids.shape[0])
    ])
    assert overlap > 0.99


def test_ivf_partial_probe_recall_reasonable():
    d_codes, q_codes, _ = _codes_from_corpus()
    index = ivf_lib.build_ivf(jax.random.PRNGKey(1), d_codes, n_levels=4,
                              nlist=32)
    _, ids = ivf_lib.search(index, q_codes, nprobe=8, k=10)
    ev, ei = jax.lax.top_k(R.sdc_ref(q_codes, d_codes, 4), 10)
    overlap = np.mean([
        len(set(np.asarray(ids[i])) & set(np.asarray(ei[i]))) / 10
        for i in range(ids.shape[0])
    ])
    assert overlap > 0.5  # clustered corpus => coarse layer is informative


def test_flat_sdc_equals_flat_bitwise_ranking():
    d_codes, q_codes, _ = _codes_from_corpus(n=500, q=8)
    sdc = FlatSDC.build(d_codes, 4)
    bitw = FlatBitwise.build(d_codes, 4)
    _, ids_s = sdc.search(q_codes, 5)
    _, ids_b = bitw.search(q_codes, 5)
    # bitwise is unnormalised (no doc-norm divide) => top-1 usually agrees
    # on clustered data; require strong overlap rather than equality.
    overlap = np.mean([
        len(set(np.asarray(ids_s[i])) & set(np.asarray(ids_b[i]))) / 5
        for i in range(ids_s.shape[0])
    ])
    assert overlap > 0.5


def test_index_bytes_compression_vs_float():
    docs, _, _ = clustered_corpus(0, 1000, 8, 256)
    f = FlatFloat.build(jnp.asarray(docs))
    cfg = BinarizerConfig(input_dim=256, code_dim=128, n_levels=4, hidden_dim=0)
    p, s = init_binarizer(jax.random.PRNGKey(0), cfg)
    bits, _, _ = binarize(p, s, jnp.asarray(docs), cfg)
    sdc = FlatSDC.build(pack_codes(bits), 4)
    # 256 f32 dims = 8192 bits -> 512 bits + norm: ~14x smaller
    assert sdc.nbytes() < f.nbytes() / 10


def test_hnsw_recall_vs_exact():
    d_codes, q_codes, _ = _codes_from_corpus(n=600, q=16)
    inv = np.asarray(R.doc_inv_norms(d_codes, 4))
    index = build_hnsw(np.asarray(d_codes), inv, n_levels=4, M=12,
                       ef_construction=48)
    ev, ei = jax.lax.top_k(R.sdc_ref(q_codes, d_codes, 4), 10)
    recs = []
    for i in range(q_codes.shape[0]):
        _, ids = search_hnsw(index, np.asarray(q_codes[i]), k=10, ef=64)
        recs.append(len(set(ids.tolist()) & set(np.asarray(ei[i]).tolist())) / 10)
    assert float(np.mean(recs)) > 0.6
