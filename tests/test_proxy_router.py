"""Replicated serving tier (launch/proxy.py): routing policies, cross-
replica shedding (proxy sheds only when every replica is saturated),
failover (replica death mid-stream re-dispatches in-flight tickets with
no drops and no client-visible reordering), and router bit-identity vs
serve_sequential for all three index families."""

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.index import ivf as ivf_lib
from repro.index.flat import FlatSDC
from repro.index.hnsw_lite import build_hnsw, prepare_batched, search_hnsw_batched
from repro.kernels.sdc import ref as R
from repro.launch.clock import FakeClock
from repro.launch.faults import FaultInjector, FaultPlan
from repro.launch.mesh import make_replica_meshes
from repro.launch.proxy import (
    AllReplicasDown,
    EffortKnob,
    QueryRouter,
    ReplicaSet,
    serve_replicated,
)
from repro.launch.serving import (
    DeadlineExpired,
    RequestShed,
    ScanStalled,
    ServingConfig,
    serve_sequential,
)

LEVELS = 4


def _identity_replica(tag, calls=None, fail_after=None, scan_sleep=0.0):
    """(encode, search) whose output encodes the input batch; optionally
    records which replica served each batch. Fault schedules come from
    the shared chaos vocabulary: ``fail_after=N`` wraps the pair in a
    ``FaultInjector`` whose scans raise from scan call N on."""

    def encode(x):
        return x

    def search(c):
        if scan_sleep:
            time.sleep(scan_sleep)
        if calls is not None:
            calls.append((tag, int(np.asarray(c).ravel()[0])))
        return c * 2, c + 1

    if fail_after is None:
        return encode, search
    return FaultInjector(
        encode, search, FaultPlan.fail_after(fail_after), name=f"r{tag}"
    ).pair


def _batches(n=6, width=4):
    return [np.full((width,), i, dtype=np.int64) for i in range(n)]


def _check_identity(results, n):
    assert len(results) == n
    for i, (vals, ids) in enumerate(results):
        np.testing.assert_array_equal(np.asarray(vals), np.full((4,), 2 * i))
        np.testing.assert_array_equal(np.asarray(ids), np.full((4,), i + 1))


# ---------------------------------------------------------------------------
# routing policies
# ---------------------------------------------------------------------------


def test_round_robin_spreads_batches_evenly():
    calls = []
    replicas = [_identity_replica(t, calls) for t in range(3)]
    results, stats = serve_replicated(replicas, _batches(9),
                                      policy="round-robin")
    _check_identity(results, 9)
    served = {t: [b for (r, b) in calls if r == t] for t in range(3)}
    assert all(len(v) == 3 for v in served.values()), served
    assert stats["requests"] == 9 and stats["queries"] == 36
    assert stats["router"] == "round-robin"


def test_least_outstanding_avoids_the_busy_replica():
    gate = threading.Event()
    started = threading.Event()
    calls = []

    def slow_encode(x):
        started.set()
        gate.wait(timeout=10)
        return x

    _, slow_search = _identity_replica(0, calls)
    fast = _identity_replica(1, calls)
    router = QueryRouter(
        ReplicaSet([(slow_encode, slow_search), fast],
                   config=ServingConfig(queue_depth=8)),
        policy="least-outstanding",
    )
    try:
        t0 = router.submit(_batches()[0])  # ties break to replica 0
        assert started.wait(timeout=5)
        # replica 0 is stuck in encode with 1 outstanding: every new
        # batch (awaited before the next, so replica 1 is drained and
        # its count is back to 0) must route to replica 1.
        for b in _batches(5)[1:]:
            router.submit(b).result(timeout=10)
        assert all(r == 1 for (r, _) in calls)
        gate.set()
        t0.result(timeout=10)
        assert t0.replica == 0
    finally:
        gate.set()
        router.close()


def test_unknown_policy_rejected():
    with pytest.raises(ValueError, match="unknown routing policy"):
        QueryRouter(ReplicaSet([_identity_replica(0)]), policy="random")


# ---------------------------------------------------------------------------
# cross-replica shedding
# ---------------------------------------------------------------------------


def test_proxy_sheds_only_when_every_replica_is_saturated():
    gates = [threading.Event(), threading.Event()]
    started = [threading.Event(), threading.Event()]

    def gated_replica(i):
        def encode(x):
            started[i].set()
            gates[i].wait(timeout=10)
            return x

        def search(c):
            return c * 2, c + 1

        return encode, search

    router = QueryRouter(
        ReplicaSet([gated_replica(0), gated_replica(1)],
                   config=ServingConfig(queue_depth=1, policy="shed")),
        policy="round-robin",
    )
    try:
        tickets = [router.submit(b) for b in _batches(2)]  # one per encode
        assert started[0].wait(timeout=5) and started[1].wait(timeout=5)
        # Both encodes gated; each replica has one free queue slot. The
        # next two submits bounce off one replica but land on the other:
        # NOT proxy sheds.
        tickets += [router.submit(b) for b in _batches(4)[2:]]
        assert router.shed_count == 0
        # Every replica's queue is now full: the proxy finally sheds.
        with pytest.raises(RequestShed, match="healthy replicas saturated"):
            router.submit(_batches(5)[4])
        assert router.shed_count == 1
        stats = router.stats()
        assert stats["shed"] == 1
        assert stats["replica_shed"] >= 2  # the absorbed bounces
        for g in gates:
            g.set()
        for t in tickets:
            t.result(timeout=10)
    finally:
        for g in gates:
            g.set()
        router.close()


# ---------------------------------------------------------------------------
# failover
# ---------------------------------------------------------------------------


def test_replica_death_mid_stream_redispatches_without_loss_or_reorder():
    calls = []
    healthy = _identity_replica(0, calls)
    # replica 1 serves one scan, then dies with tickets still queued on
    # it (slow scan so the stream piles up behind the failure).
    dying = _identity_replica(1, calls, fail_after=1, scan_sleep=0.02)
    router = QueryRouter(
        ReplicaSet([healthy, dying], config=ServingConfig(queue_depth=16)),
        policy="round-robin",
    )
    try:
        tickets = [router.submit(b) for b in _batches(12)]
        results = [t.result(timeout=30) for t in tickets]
        _check_identity(results, 12)  # nothing dropped, nothing reordered
        stats = router.stats()
        assert stats["healthy"] == [0]
        assert stats["failovers"] >= 1
        assert stats["requests"] == 12  # failed-over requests count once
        # the survivor picked up every re-dispatched batch
        assert sum(1 for (r, _) in calls if r == 1) == 1
    finally:
        router.close()


def test_eager_failover_redispatches_before_client_awaits():
    """The router's done-callback re-dispatches the moment a scan fails —
    tickets recover even if the client never touched result() yet."""
    calls = []
    healthy = _identity_replica(0, calls)
    dying = _identity_replica(1, calls, fail_after=0)  # dies on first scan
    router = QueryRouter(
        ReplicaSet([healthy, dying], config=ServingConfig(queue_depth=8)),
        policy="round-robin",
    )
    try:
        tickets = [router.submit(b) for b in _batches(6)]
        deadline = time.time() + 15
        while time.time() < deadline and not all(t.done() for t in tickets):
            time.sleep(0.01)
        assert all(t.done() for t in tickets)  # resolved with no client pull
        _check_identity([t.result() for t in tickets], 6)
        assert router.healthy() == [0]
    finally:
        router.close()


def test_all_replicas_down_surfaces_error_and_rejects_submits():
    replicas = [_identity_replica(i, fail_after=0) for i in range(2)]
    router = QueryRouter(
        ReplicaSet(replicas, config=ServingConfig(queue_depth=8))
    )
    try:
        t = router.submit(_batches(1)[0])
        with pytest.raises(RuntimeError, match="injected fail"):
            t.result(timeout=15)
        assert router.healthy() == []
        with pytest.raises(AllReplicasDown):
            router.submit(_batches(2)[1])
    finally:
        router.close()


# ---------------------------------------------------------------------------
# bit-identity vs the sequential loop, all three index families
# ---------------------------------------------------------------------------


def _code_corpus(n=600, q=24, dim=32, seed=0):
    key = jax.random.PRNGKey(seed)
    cd = jax.random.randint(key, (n, dim), 0, 2**LEVELS).astype(jnp.int8)
    cq = jax.random.randint(
        jax.random.fold_in(key, 1), (q, dim), 0, 2**LEVELS
    ).astype(jnp.int8)
    return cd, cq


@pytest.mark.parametrize("kind", ["flat", "ivf", "hnsw"])
def test_router_bit_identical_to_sequential(kind):
    cd, cq = _code_corpus()
    if kind == "flat":
        index = FlatSDC.build(cd, LEVELS, backend="xla")
        search = lambda q: index.search(q, 10)
    elif kind == "ivf":
        index = ivf_lib.build_ivf(
            jax.random.PRNGKey(1), cd, n_levels=LEVELS, nlist=8,
            kmeans_iters=3,
        )
        search = lambda q: ivf_lib.search(index, q, nprobe=4, k=10,
                                          backend="xla")
    else:
        inv = np.asarray(R.doc_inv_norms(cd, LEVELS))
        graph = build_hnsw(np.asarray(cd), inv, n_levels=LEVELS, M=8,
                           ef_construction=24, seed=0)
        tables = prepare_batched(graph)
        search = lambda q: search_hnsw_batched(
            tables, q, k=10, ef=24, beam=8, backend="xla"
        )

    encode = lambda q: q  # codes in, codes out: isolates routing
    batches = [cq[i : i + 8] for i in range(0, cq.shape[0], 8)]
    seq = serve_sequential(encode, search, batches)
    # Two replicas over the same index closure: every replica must be
    # bit-identical, so routing is invisible to correctness.
    routed, stats = serve_replicated(
        [(encode, search)] * 2, batches, policy="round-robin"
    )
    assert stats["requests"] == len(batches)
    assert stats["replicas"] == 2
    for (sv, si), (rv, ri) in zip(seq, routed):
        np.testing.assert_array_equal(np.asarray(si), np.asarray(ri))
        np.testing.assert_array_equal(np.asarray(sv), np.asarray(rv))


def test_stats_aggregate_per_replica_rows():
    replicas = [_identity_replica(i) for i in range(2)]
    results, stats = serve_replicated(replicas, _batches(8))
    _check_identity(results, 8)
    assert len(stats["per_replica"]) == 2
    assert sum(s["requests"] for s in stats["per_replica"]) == 8
    for s in stats["per_replica"]:
        for key in ("replica", "healthy", "requests", "queries", "shed",
                    "device_idle_frac"):
            assert key in s
    assert stats["latency_p99_ms"] >= stats["latency_p50_ms"]


# ---------------------------------------------------------------------------
# robustness: deadlines, stuck-scan watchdog, retry, degradation
# ---------------------------------------------------------------------------


def test_ticket_result_timeout_then_late_resolution_no_leaks():
    """result(timeout=) raising TimeoutError must not consume the ticket:
    a later resolution still lands, exactly once, and runs each done
    callback exactly once (no leaked callback registrations)."""
    from repro.launch.serving import Ticket

    t = Ticket(0, 4)
    with pytest.raises(TimeoutError, match="not ready"):
        t.result(timeout=0.05)
    assert not t.done()
    fired = []
    t.add_done_callback(lambda tk: fired.append("a"))
    t.add_done_callback(lambda tk: fired.append("b"))
    assert t._resolve(value=("v", "i")) is True
    assert t.result(timeout=1) == ("v", "i")
    # second resolution loses: value not clobbered, callbacks not re-run
    assert t._resolve(error=RuntimeError("late loser")) is False
    assert t.result() == ("v", "i") and t.error() is None
    assert fired == ["a", "b"] and t._callbacks == []
    # post-resolution registration fires immediately, exactly once
    t.add_done_callback(lambda tk: fired.append("c"))
    assert fired == ["a", "b", "c"] and t._callbacks == []


def test_watchdog_fails_over_stuck_scan_without_loss_or_reorder():
    """A scan that HANGS (never raises) must not deadlock the tier: the
    watchdog marks the replica unhealthy (ScanStalled) and failover
    re-dispatches its in-flight tickets to the survivor — every ticket
    resolves, in order, bit-identical."""
    calls = []
    stuck = FaultInjector(*_identity_replica(0, calls),
                          plan=FaultPlan.stick_at(0), name="r0")
    router = QueryRouter(
        ReplicaSet([stuck.pair, _identity_replica(1, calls)],
                   config=ServingConfig(queue_depth=16)),
        policy="round-robin",
    )
    try:
        router.start_watchdogs(0.1)
        tickets = [router.submit(b) for b in _batches(8)]
        results = [t.result(timeout=30) for t in tickets]
        _check_identity(results, 8)  # nothing lost, FIFO per client
        assert router.wait_state(0, ("unhealthy",), timeout=10)
        stats = router.stats()
        assert stats["watchdog_stalls"] >= 1
        assert stats["failovers"] >= 1
        assert isinstance(router._errors[0], ScanStalled)
        # the survivor answered everything; the stuck scan answered none
        assert all(r == 1 for (r, _) in calls)
    finally:
        stuck.release()  # un-wedge the scan thread before close() joins
        router.close()


def test_deadline_expired_sheds_at_dequeue_replica_stays_healthy():
    """Tickets whose deadline passes while queued are shed at dequeue —
    counted as deadline_expired (not queue sheds, not failures), never
    scanned, and the replica stays healthy."""
    calls = []
    router = QueryRouter(
        ReplicaSet([_identity_replica(0, calls, scan_sleep=0.2)],
                   config=ServingConfig(queue_depth=8)),
    )
    try:
        deadline = time.perf_counter() + 0.05
        tickets = [router.submit(b, deadline=deadline) for b in _batches(4)]
        outcomes = []
        for t in tickets:
            try:
                t.result(timeout=30)
                outcomes.append("ok")
            except DeadlineExpired:
                outcomes.append("expired")
        # the first batch was dequeued before the deadline; the ones
        # stuck behind its slow scan expired un-scanned
        assert outcomes[0] == "ok" and outcomes.count("expired") == 3
        assert len(calls) == 1  # expired work never reached the scan
        stats = router.stats()
        assert stats["deadline_expired"] == 3
        assert stats["shed"] == 0 and stats["failovers"] == 0
        assert router.healthy() == [0]  # a missed budget is not a fault
    finally:
        router.close()


def test_submit_rejects_already_expired_deadline():
    router = QueryRouter(ReplicaSet([_identity_replica(0)]))
    try:
        with pytest.raises(DeadlineExpired, match="already expired"):
            router.submit(_batches(1)[0],
                          deadline=time.perf_counter() - 1.0)
        stats = router.stats()
        assert stats["deadline_expired"] == 1
        assert stats["requests"] == 0  # never reached a replica
    finally:
        router.close()


def _gated_tier(n_extra_queued=1, clock=None):
    """One replica whose encode blocks on a gate, with its admission
    queue then filled: the next submit must shed tier-wide."""
    gate = threading.Event()
    started = threading.Event()

    def encode(x):
        started.set()
        gate.wait(timeout=30)
        return x

    def search(c):
        return c * 2, c + 1

    kw = {} if clock is None else {"clock": clock}
    router = QueryRouter(
        ReplicaSet([(encode, search)],
                   config=ServingConfig(queue_depth=n_extra_queued,
                                        policy="shed")),
        **kw,
    )
    head, *rest = _batches(1 + n_extra_queued)
    tickets = [router.submit(head)]
    # only fill the queue once the encode thread holds the head batch,
    # or the filler itself would race the dequeue and shed
    assert started.wait(timeout=5)
    tickets += [router.submit(b) for b in rest]
    return router, gate, tickets


def test_submit_with_retry_succeeds_once_pressure_clears():
    """Runs on FakeClock: the retry parks on the simulated clock, the
    gate opens mid-backoff, and the test hands it time to retry."""
    clk = FakeClock()
    router, gate, tickets = _gated_tier(clock=clk)
    try:
        result = {}

        def work():
            t = router.submit_with_retry(
                _batches(3)[2], attempts=20, base_delay_s=0.5,
                max_delay_s=2.0,
            )
            result["vals"] = t.result(timeout=10)[0]

        w = threading.Thread(target=work)
        w.start()
        # saturated right now -> the first attempt sheds and the retry
        # parks on the clock for its backoff
        clk.wait_for_sleepers(1)
        assert router.shed_count >= 1  # it genuinely shed before landing
        gate.set()  # pressure clears while the retry is backing off
        deadline = time.time() + 10
        while w.is_alive() and time.time() < deadline:
            clk.advance(2.0)  # serve out the current backoff (jitter incl.)
            time.sleep(0.005)
        w.join(timeout=10)
        assert not w.is_alive()
        np.testing.assert_array_equal(np.asarray(result["vals"]),
                                      np.full((4,), 4))
        for tk in tickets:
            tk.result(timeout=10)
    finally:
        gate.set()
        router.close()


def test_submit_with_retry_deadline_cuts_backoff_short():
    clk = FakeClock()
    router, gate, tickets = _gated_tier(clock=clk)
    try:
        t0 = clk.now()
        with pytest.raises(DeadlineExpired, match="retry backoff"):
            router.submit_with_retry(
                _batches(3)[2], deadline=clk.now() + 0.05,
                attempts=50, base_delay_s=0.2, jitter=0.0,
            )
        # failed by deadline MATH: simulated time never moved, so not a
        # single second of the 50 x 0.2s backoff schedule was served
        assert clk.now() == t0
        assert router.stats()["deadline_expired"] >= 1
    finally:
        gate.set()
        router.close()


def test_submit_with_retry_terminal_errors_propagate_immediately():
    router = QueryRouter(
        ReplicaSet([_identity_replica(i, fail_after=0) for i in range(2)],
                   config=ServingConfig(queue_depth=4)),
    )
    try:
        t = router.submit(_batches(1)[0])
        with pytest.raises(RuntimeError, match="injected fail"):
            t.result(timeout=15)
        assert router.healthy() == []
        t0 = time.perf_counter()
        with pytest.raises(AllReplicasDown):
            router.submit_with_retry(_batches(2)[1], attempts=8,
                                     base_delay_s=0.2)
        assert time.perf_counter() - t0 < 1.0  # no backoff on terminal
    finally:
        router.close()


def test_transiently_empty_tier_sheds_retryable_under_deadline_path():
    """RequestShed (retryable) vs AllReplicasDown (terminal) must stay
    distinguishable when submits carry deadlines: a tier that is merely
    draining sheds; a tier that is dead raises AllReplicasDown."""
    router = QueryRouter(ReplicaSet([_identity_replica(0)],
                                    config=ServingConfig(queue_depth=4)))
    try:
        deadline = time.perf_counter() + 30.0
        router.drain(0, timeout=5)  # healthy -> draining: tier empty
        with pytest.raises(RequestShed, match="no routable replica"):
            router.submit(_batches(1)[0], deadline=deadline)
        router.mark_unhealthy(0, RuntimeError("boom"))
        with pytest.raises(AllReplicasDown):
            router.submit(_batches(1)[0], deadline=deadline)
    finally:
        router.close()


def test_stop_health_probe_raises_when_probe_thread_is_wedged():
    """A probe wedged on a stuck canary must make stop_health_probe fail
    LOUDLY (the old silent join timeout leaked a daemon thread that kept
    reviving replicas behind the caller's back)."""
    clk = FakeClock()
    stuck = FaultInjector(*_identity_replica(0),
                          plan=FaultPlan.stick_at(0), name="r0")
    router = QueryRouter(
        ReplicaSet([stuck.pair], config=ServingConfig(queue_depth=4)),
        clock=clk,
    )
    try:
        router.mark_unhealthy(0, RuntimeError("down"))
        router.start_health_probe(_batches(1)[0], interval=1.0,
                                  timeout=30.0)
        clk.wait_for_sleepers(1)
        clk.advance(1.0)  # first tick: the probe dives into the canary
        deadline = time.time() + 10
        while time.time() < deadline and stuck.stuck_count == 0:
            time.sleep(0.005)
        assert stuck.stuck_count == 1  # the probe is wedged in the canary
        with pytest.raises(RuntimeError, match="did not exit"):
            router.stop_health_probe(timeout=0.05)
        # the hang clears: the wedged probe completes, revives the
        # replica, sees the stop flag, and the thread exits for real
        stuck.release()
        assert router.wait_state(0, ("healthy",), timeout=10)
    finally:
        stuck.release()
        router.close()


def test_flap_suppression_backs_off_a_permanently_failing_replica():
    """Runs on FakeClock: the probe loop is handed exactly one simulated
    second per tick, so the backoff schedule is counted, not raced."""
    clk = FakeClock()
    flaky = FaultInjector(*_identity_replica(1),
                          plan=FaultPlan.fail_after(0), name="r1")
    router = QueryRouter(
        ReplicaSet([_identity_replica(0), flaky.pair],
                   config=ServingConfig(queue_depth=8)),
        clock=clk,
    )
    try:
        tickets = [router.submit(b) for b in _batches(4)]
        for t in tickets:
            t.result(timeout=15)  # failover absorbs replica 1's faults
        assert router.wait_state(1, ("unhealthy",), timeout=10)
        router.start_health_probe(_batches(1)[0], interval=1.0,
                                  timeout=2.0)
        for _ in range(16):  # 16 simulated seconds, lockstep with the loop
            clk.tick(1.0)
        fails = router.probe_failures().get(1, 0)
        # without backoff 16 ticks = 16 probes; with 1x,2x,4x... spacing
        # the probe lands at t=1,2,4,8,16 — and it must have actually
        # retried, not given up after the first failure
        assert 2 <= fails <= 6, fails
        assert router.states()[1] == "unhealthy"
    finally:
        router.close()


def test_degradation_steps_down_before_shedding_and_back_up():
    gate = threading.Event()
    started = threading.Event()

    def encode(x):
        started.set()
        gate.wait(timeout=30)
        return x

    def search(c):
        return c * 2, c + 1

    knob = EffortKnob(2)
    router = QueryRouter(
        ReplicaSet([(encode, search)],
                   config=ServingConfig(queue_depth=1, policy="shed")),
    )
    router.enable_degradation(knob, high_water=0.5, low_water=0.0)
    try:
        b = _batches(4)
        t0 = router.submit(b[0])  # encode gated: 1 outstanding
        assert started.wait(timeout=5)
        t1 = router.submit(b[1])  # pressure 1.0 >= 0.5: degrades first
        assert knob.level == 1 and knob.degrade_count == 1
        # queue now full and the knob is at its floor: the shed is real
        with pytest.raises(RequestShed):
            router.submit(b[2])
        assert router.stats()["effort_level"] == 1
        gate.set()
        _check_identity([t0.result(timeout=10), t1.result(timeout=10)], 2)
        # dispatches served while degraded were counted
        assert router.stats()["degraded"] >= 1
        # pressure cleared: the next submit restores full effort
        t3 = router.submit(b[0])
        assert knob.level == 0 and knob.restore_count == 1
        t3.result(timeout=10)
        assert router.stats()["effort_level"] == 0
    finally:
        gate.set()
        router.close()


# ---------------------------------------------------------------------------
# replica submeshes
# ---------------------------------------------------------------------------


def test_make_replica_meshes_partitions_disjoint_devices():
    meshes = make_replica_meshes(1, shape=(1, 1))
    assert len(meshes) == 1 and meshes[0].devices.size == 1
    n = len(jax.devices())
    with pytest.raises(RuntimeError, match="need"):
        make_replica_meshes(n + 1, shape=(1, 1))


def test_engine_replicas_on_submeshes_route_and_fail_over():
    """End-to-end tier over the distributed engine: 2 replicas on
    disjoint (2,1) submeshes of 4 forced host devices, each sharding the
    whole corpus over its own leaves. Routed results must equal the
    exact top-k, and killing one replica mid-stream must lose nothing
    (a replica holds the whole corpus: failover costs a retry, not
    recall)."""
    import os
    import subprocess
    import sys
    import textwrap

    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = src
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.index.engine import (
                engine_input_shardings, make_distributed_search)
            from repro.kernels.sdc import ref as R
            from repro.launch import proxy, serving
            from repro.launch.mesh import make_replica_meshes

            key = jax.random.PRNGKey(0)
            codes = jax.random.randint(key, (2048, 64), 0, 16).astype(jnp.int8)
            q = jax.random.randint(jax.random.fold_in(key, 1), (32, 64), 0,
                                   16).astype(jnp.int8)
            inv = R.doc_inv_norms(codes, 4)

            fail_at = [None]  # scan-call countdown for the dying replica

            def make_replica(mesh, dies=False):
                search = make_distributed_search(mesh, n_levels=4, k=10)
                qspec, *in_specs = engine_input_shardings(mesh)
                ins = [jax.device_put(a, s)
                       for a, s in zip((codes, inv), in_specs)]
                count = [0]
                def search_one(qc):
                    if dies:
                        count[0] += 1
                        if fail_at[0] is not None and count[0] > fail_at[0]:
                            raise RuntimeError("replica leaf crashed")
                    return search(qc, *ins)
                encode = lambda e: jax.device_put(jnp.asarray(e), qspec)
                return encode, search_one

            meshes = make_replica_meshes(2, shape=(2, 1))
            assert not (set(meshes[0].devices.flat)
                        & set(meshes[1].devices.flat))
            replicas = [make_replica(meshes[0]),
                        make_replica(meshes[1], dies=True)]
            batches = [q[i:i+8] for i in range(0, 32, 8)]
            serving.warmup_replicas(replicas, batches)

            ev, ei = jax.lax.top_k(R.sdc_ref(q, codes, 4), 10)

            # healthy tier: routed == exact
            results, stats = proxy.serve_replicated(replicas, batches * 2)
            ids = np.concatenate(
                [np.asarray(i) for _, i in results[:len(batches)]], 0)
            np.testing.assert_array_equal(ids, np.asarray(ei))
            assert stats["healthy"] == [0, 1]

            # replica 1 dies after its first scan of the next stream
            fail_at[0] = 0
            results, stats = proxy.serve_replicated(replicas, batches * 2)
            assert stats["healthy"] == [0], stats["healthy"]
            assert stats["requests"] == 2 * len(batches)
            for r, (bv, bi) in enumerate(results):
                exp = np.asarray(ei)[(r % len(batches)) * 8:
                                     (r % len(batches)) * 8 + 8]
                np.testing.assert_array_equal(np.asarray(bi), exp)
            print("ENGINE-REPLICA-OK")
        """)],
        capture_output=True, text=True, env=env, timeout=500,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ENGINE-REPLICA-OK" in out.stdout
