"""Minimal deterministic stand-in for `hypothesis` (not installed in the
container). Installed into sys.modules by conftest.py only when the real
package is missing, so the property tests still run as seeded multi-example
sweeps instead of erroring at collection.

Supported surface (everything the test suite uses):
  given(**strategies), settings(max_examples=, deadline=),
  strategies.integers(lo, hi), strategies.sampled_from(seq).

Examples are drawn from a PRNG seeded by the test's qualified name, so runs
are reproducible. Example counts are capped (the stub has no shrinking or
coverage guidance, so large example counts buy nothing).
"""

from __future__ import annotations

import random
import sys
import types

_MAX_EXAMPLES_CAP = 10


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def integers(min_value, max_value):
    return _Strategy(lambda rnd: rnd.randint(min_value, max_value))


def sampled_from(elements):
    elems = list(elements)
    return _Strategy(lambda rnd: rnd.choice(elems))


def settings(max_examples: int = 10, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(**strategy_kw):
    def deco(fn):
        # NB: no functools.wraps — pytest must not see the wrapped
        # signature (it would resolve the drawn arguments as fixtures).
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples", 10))
            n = min(n, _MAX_EXAMPLES_CAP)
            rnd = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for _ in range(n):
                drawn = {k: s.draw(rnd) for k, s in strategy_kw.items()}
                fn(*args, **kwargs, **drawn)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco


def install():
    hyp = types.ModuleType("hypothesis")
    strat = types.ModuleType("hypothesis.strategies")
    strat.integers = integers
    strat.sampled_from = sampled_from
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = strat
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strat
