import jax
import pytest

try:  # pragma: no cover - depends on container contents
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import _hypothesis_stub

    _hypothesis_stub.install()

# Tests run on the single host CPU device (the dry-run forces 512 devices
# in its own process only — never here).
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
