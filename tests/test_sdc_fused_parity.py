"""Fused-vs-unfused, packed-vs-unpacked, and gather-kernel parity for the
unified SDC scoring substrate (interpret mode), across the edge cases the
padding logic has to survive: non-multiple Q/N, k > block_n, k > N0,
all-padded tail tiles, and duplicate-score ties."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.binarize_lib import (
    SDC_NEG_INF,
    pack_codes_nibbles,
    unpack_codes_nibbles,
)
from repro.index import ivf as ivf_lib
from repro.kernels.sdc import ref as R
from repro.kernels.sdc.gather import sdc_gather_topk
from repro.kernels.sdc.ops import sdc_search, sdc_search_xla


def _corpus(seed, q, n, d, n_levels=4):
    key = jax.random.PRNGKey(seed)
    cq = jax.random.randint(key, (q, d), 0, 2**n_levels).astype(jnp.int8)
    cd = jax.random.randint(jax.random.fold_in(key, 1), (n, d), 0,
                            2**n_levels).astype(jnp.int8)
    return cq, cd, R.doc_inv_norms(cd, n_levels)


def _assert_topk_consistent(vals, idx, oracle_scores, k):
    """Returned values must equal the oracle top-k, and each returned index
    must point at a doc whose oracle score equals the returned value (the
    tie-robust form of index parity)."""
    ev, _ = jax.lax.top_k(oracle_scores, min(k, oracle_scores.shape[1]))
    n_valid = ev.shape[1]
    np.testing.assert_allclose(np.asarray(vals[:, :n_valid]), np.asarray(ev),
                               atol=1e-4)
    v, i, s = np.asarray(vals), np.asarray(idx), np.asarray(oracle_scores)
    for row in range(v.shape[0]):
        for col in range(n_valid):
            assert 0 <= i[row, col] < s.shape[1]
            np.testing.assert_allclose(s[row, i[row, col]], v[row, col],
                                       atol=1e-4)
    # slots beyond the corpus are explicitly empty
    assert (v[:, n_valid:] < SDC_NEG_INF / 2).all()
    assert (i[:, n_valid:] == -1).all()


@pytest.mark.parametrize(
    "q,n,k,block_q,block_n",
    [
        (5, 333, 7, 8, 64),    # Q, N not multiples of the blocks
        (3, 50, 100, 8, 64),   # k > block_n AND k > N0 (old divisibility bug)
        (8, 64, 13, 8, 64),    # exact single tile
        (2, 65, 4, 8, 64),     # one-doc tail tile (all-padded but one)
    ],
)
def test_fused_matches_unfused_edge_cases(q, n, k, block_q, block_n):
    cq, cd, inv = _corpus(q * 1000 + n, q, n, 64)
    vf, idf = sdc_search(cq, cd, inv, n_levels=4, k=k, block_q=block_q,
                         block_n=block_n, interpret=True, fused=True)
    vu, idu = sdc_search(cq, cd, inv, n_levels=4, k=k, block_q=block_q,
                         block_n=block_n, interpret=True, fused=False)
    np.testing.assert_allclose(np.asarray(vf), np.asarray(vu), atol=1e-5)
    oracle = R.sdc_ref(cq, cd, 4, inv)
    _assert_topk_consistent(vf, idf, oracle, k)
    _assert_topk_consistent(vu, idu, oracle, k)


def test_fused_all_padded_tail_tile():
    # N0 = block_n + 1: the second tile holds one real doc + 63 pads, and
    # with k > 1 some slots must merge across the tile boundary.
    cq, cd, inv = _corpus(7, 4, 65, 64)
    vf, idf = sdc_search(cq, cd, inv, n_levels=4, k=5, block_q=8, block_n=64,
                         interpret=True, fused=True)
    _assert_topk_consistent(vf, idf, R.sdc_ref(cq, cd, 4, inv), 5)


def test_fused_tie_breaking_duplicate_scores():
    # A corpus of repeated code rows => massive score ties across tiles.
    key = jax.random.PRNGKey(3)
    base = jax.random.randint(key, (4, 32), 0, 16).astype(jnp.int8)
    cd = jnp.tile(base, (40, 1))  # 160 docs, every score 40x duplicated
    cq = jax.random.randint(jax.random.fold_in(key, 1), (4, 32), 0,
                            16).astype(jnp.int8)
    inv = R.doc_inv_norms(cd, 4)
    k = 10
    vf, idf = sdc_search(cq, cd, inv, n_levels=4, k=k, block_q=8, block_n=32,
                         interpret=True, fused=True)
    vu, idu = sdc_search(cq, cd, inv, n_levels=4, k=k, block_q=8, block_n=32,
                         interpret=True, fused=False)
    np.testing.assert_allclose(np.asarray(vf), np.asarray(vu), atol=1e-6)
    oracle = R.sdc_ref(cq, cd, 4, inv)
    _assert_topk_consistent(vf, idf, oracle, k)
    # no index returned twice for one query
    for row in np.asarray(idf):
        assert len(set(row.tolist())) == k


def test_nibble_pack_roundtrip():
    codes = jax.random.randint(jax.random.PRNGKey(0), (37, 64), 0,
                               16).astype(jnp.int8)
    packed = pack_codes_nibbles(codes)
    assert packed.shape == (37, 32) and packed.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(unpack_codes_nibbles(packed)),
                                  np.asarray(codes))


@pytest.mark.parametrize("n_levels", [1, 2, 3, 4])
def test_packed_scan_bit_identical(n_levels):
    """int4-packed streaming must produce bit-identical scores to int8."""
    cq, cd, _ = _corpus(n_levels, 5, 150, 64, n_levels)
    inv = R.doc_inv_norms(cd, n_levels)
    dp = pack_codes_nibbles(cd)
    for fused in (True, False):
        v8, _ = sdc_search(cq, cd, inv, n_levels=n_levels, k=9, block_q=8,
                           block_n=64, interpret=True, fused=fused)
        v4, _ = sdc_search(cq, dp, inv, n_levels=n_levels, k=9, block_q=8,
                           block_n=64, interpret=True, fused=fused,
                           packed=True)
        np.testing.assert_array_equal(np.asarray(v8), np.asarray(v4))
    x8, _ = sdc_search_xla(cq, cd, inv, n_levels=n_levels, k=9)
    x4, _ = sdc_search_xla(cq, dp, inv, n_levels=n_levels, k=9, packed=True)
    np.testing.assert_array_equal(np.asarray(x8), np.asarray(x4))
    np.testing.assert_allclose(np.asarray(v8), np.asarray(x8), atol=1e-5)


def test_xla_backend_matches_kernel():
    cq, cd, inv = _corpus(11, 6, 200, 64)
    vk, ik = sdc_search(cq, cd, inv, n_levels=4, k=12, block_q=8, block_n=64,
                        interpret=True, fused=True)
    vx, ix = sdc_search_xla(cq, cd, inv, n_levels=4, k=12)
    np.testing.assert_allclose(np.asarray(vk), np.asarray(vx), atol=1e-5)
    _assert_topk_consistent(vx, ix, R.sdc_ref(cq, cd, 4, inv), 12)


# ---------------------------------------------------------------------------
# IVF: gather-then-scan kernel + build hygiene.
# ---------------------------------------------------------------------------


def _lists(seed, nlist, L, D, n_pad=5):
    key = jax.random.PRNGKey(seed)
    codes = jax.random.randint(key, (nlist, L, D), 0, 16).astype(jnp.int8)
    flat = codes.reshape(-1, D)
    inv = R.doc_inv_norms(flat, 4).reshape(nlist, L)
    ids = jnp.arange(nlist * L, dtype=jnp.int32).reshape(nlist, L)
    if n_pad:
        inv = inv.at[:, -n_pad:].set(0.0)
        ids = ids.at[:, -n_pad:].set(-1)
    return codes, flat, inv, ids


@pytest.mark.parametrize("packed", [False, True])
def test_gather_topk_matches_oracle(packed):
    nlist, L, D, k = 6, 48, 64, 10
    codes, flat, inv, ids = _lists(17, nlist, L, D)
    q = jax.random.randint(jax.random.PRNGKey(1), (5, D), 0, 16).astype(jnp.int8)
    probes = jnp.stack([
        jnp.asarray(np.random.RandomState(i).permutation(nlist)[:3])
        for i in range(5)
    ]).astype(jnp.int32)
    lists_arg = pack_codes_nibbles(codes) if packed else codes
    gv, gi = sdc_gather_topk(q, lists_arg, inv, ids, probes, n_levels=4, k=k,
                             interpret=True, packed=packed)
    for qi in range(5):
        cand = np.concatenate([
            np.asarray(ids[p])[np.asarray(ids[p]) >= 0]
            for p in np.asarray(probes[qi])
        ])
        sc = R.sdc_ref(q[qi:qi + 1], flat[jnp.asarray(cand)], 4)[0]
        ev, ea = jax.lax.top_k(sc, k)
        np.testing.assert_allclose(np.asarray(gv[qi]), np.asarray(ev),
                                   atol=1e-4)
        np.testing.assert_array_equal(np.asarray(gi[qi]),
                                      cand[np.asarray(ea)])


def test_gather_topk_k_exceeds_list_len():
    nlist, L, D = 4, 8, 32
    codes, flat, inv, ids = _lists(23, nlist, L, D, n_pad=2)
    q = jax.random.randint(jax.random.PRNGKey(2), (3, D), 0, 16).astype(jnp.int8)
    probes = jnp.tile(jnp.arange(2, dtype=jnp.int32)[None, :], (3, 1))
    k = 20  # > L, > valid candidates per probe
    gv, gi = sdc_gather_topk(q, codes, inv, ids, probes, n_levels=4, k=k,
                             interpret=True)
    n_valid = 2 * (L - 2)
    assert (np.asarray(gi)[:, n_valid:] == -1).all()
    assert (np.asarray(gv)[:, n_valid:] < SDC_NEG_INF / 2).all()


@pytest.mark.parametrize("packed", [False, True])
def test_ivf_backends_agree(packed):
    key = jax.random.PRNGKey(0)
    codes = jax.random.randint(key, (600, 64), 0, 16).astype(jnp.int8)
    q = jax.random.randint(jax.random.fold_in(key, 1), (8, 64), 0,
                           16).astype(jnp.int8)
    index = ivf_lib.build_ivf(jax.random.PRNGKey(1), codes, n_levels=4,
                              nlist=6, packed=packed)
    vx, ix = ivf_lib.search(index, q, nprobe=4, k=10, backend="xla")
    vp, ip = ivf_lib.search(index, q, nprobe=4, k=10, backend="interpret")
    np.testing.assert_allclose(np.asarray(vx), np.asarray(vp), atol=1e-5)
    # ids agree wherever scores are unique; in general both are valid
    # members of the probed union — check scores-at-ids instead.
    np.testing.assert_array_equal(np.asarray(ix == -1), np.asarray(ip == -1))


def test_ivf_packed_matches_unpacked_exactly():
    key = jax.random.PRNGKey(5)
    codes = jax.random.randint(key, (600, 64), 0, 16).astype(jnp.int8)
    q = jax.random.randint(jax.random.fold_in(key, 1), (8, 64), 0,
                           16).astype(jnp.int8)
    i8 = ivf_lib.build_ivf(jax.random.PRNGKey(1), codes, n_levels=4, nlist=6)
    i4 = ivf_lib.build_ivf(jax.random.PRNGKey(1), codes, n_levels=4, nlist=6,
                           packed=True)
    for backend in ("xla", "interpret"):
        v8, id8 = ivf_lib.search(i8, q, nprobe=4, k=10, backend=backend)
        v4, id4 = ivf_lib.search(i4, q, nprobe=4, k=10, backend=backend)
        np.testing.assert_array_equal(np.asarray(v8), np.asarray(v4))
        np.testing.assert_array_equal(np.asarray(id8), np.asarray(id4))


def test_build_ivf_overflow_warns_and_headroom_prevents():
    key = jax.random.PRNGKey(0)
    codes = jax.random.randint(key, (400, 32), 0, 16).astype(jnp.int8)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        index = ivf_lib.build_ivf(jax.random.PRNGKey(1), codes, n_levels=4,
                                  nlist=4, max_len=30)
        msgs = [str(x.message) for x in w if "dropped" in str(x.message)]
    assert msgs, "expected an overflow warning"
    assert "%" in msgs[0]  # dropped fraction is reported
    kept = int(jnp.sum(index.lists_ids >= 0))
    assert kept < 400  # entries really were dropped
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        index2 = ivf_lib.build_ivf(jax.random.PRNGKey(1), codes, n_levels=4,
                                   nlist=4, max_len=30, headroom=20.0)
        assert not [x for x in w if "dropped" in str(x.message)]
    assert int(jnp.sum(index2.lists_ids >= 0)) == 400
