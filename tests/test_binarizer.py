"""Unit + property tests for the recurrent binarization core."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    BinarizerConfig,
    binarize,
    code_affine_constants,
    codes_to_values,
    init_binarizer,
    pack_bitplanes,
    pack_codes,
    ste_sign,
    unpack_bitplanes,
    unpack_codes,
    values_to_codes,
)


def test_ste_sign_forward():
    x = jnp.array([-2.0, -0.1, 0.0, 0.1, 2.0])
    out = ste_sign(x)
    assert jnp.all(jnp.abs(out) == 1.0)
    np.testing.assert_array_equal(np.asarray(out), [-1, -1, -1, 1, 1])


def test_ste_sign_gradient_window():
    g = jax.grad(lambda x: jnp.sum(ste_sign(x)))(
        jnp.array([-2.0, -0.5, 0.5, 2.0])
    )
    np.testing.assert_array_equal(np.asarray(g), [0.0, 1.0, 1.0, 0.0])


@pytest.mark.parametrize("n_levels", [1, 2, 3, 4])
@pytest.mark.parametrize("hidden", [0, 32])
def test_binarize_shapes_and_grid(n_levels, hidden):
    cfg = BinarizerConfig(input_dim=48, code_dim=32, n_levels=n_levels,
                          hidden_dim=hidden)
    p, s = init_binarizer(jax.random.PRNGKey(0), cfg)
    f = jax.random.normal(jax.random.PRNGKey(1), (6, 48))
    bits, b_u, _ = binarize(p, s, f, cfg)
    assert bits.shape == (6, n_levels, 32)
    assert b_u.shape == (6, 32)
    assert bool(jnp.all(jnp.abs(bits) == 1.0))
    # b_u values lie on the 2^{-u} grid
    a, beta = code_affine_constants(n_levels)
    codes = (b_u - beta) / a
    np.testing.assert_allclose(np.asarray(codes), np.round(np.asarray(codes)),
                               atol=1e-5)


def test_affine_identity_exact_all_codes():
    """v = a*c + beta must hold exactly for every code at every level."""
    for n_levels in range(1, 7):
        codes = jnp.arange(2**n_levels, dtype=jnp.int8)[None, :]
        bits = unpack_codes(codes, n_levels)
        w = 2.0 ** -jnp.arange(n_levels)
        direct = jnp.einsum("qnm,n->qm", bits, w)
        via_affine = codes_to_values(codes, n_levels)
        np.testing.assert_allclose(np.asarray(direct), np.asarray(via_affine),
                                   atol=0)


@settings(max_examples=50, deadline=None)
@given(
    n_levels=st.integers(1, 6),
    batch=st.integers(1, 8),
    m=st.sampled_from([32, 64, 96]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pack_roundtrips(n_levels, batch, m, seed):
    key = jax.random.PRNGKey(seed)
    bits = (jax.random.bernoulli(key, 0.5, (batch, n_levels, m)) * 2 - 1
            ).astype(jnp.float32)
    codes = pack_codes(bits)
    assert codes.dtype == jnp.int8
    assert bool(jnp.all(unpack_codes(codes, n_levels) == bits))
    packed = pack_bitplanes(bits)
    assert bool(jnp.all(unpack_bitplanes(packed, m) == bits))
    vals = codes_to_values(codes, n_levels)
    assert bool(jnp.all(values_to_codes(vals, n_levels) == codes))


def test_gradients_flow_through_all_levels():
    cfg = BinarizerConfig(input_dim=16, code_dim=8, n_levels=3, hidden_dim=12)
    p, s = init_binarizer(jax.random.PRNGKey(0), cfg)
    f = jax.random.normal(jax.random.PRNGKey(1), (4, 16))

    def loss(params):
        _, b_u, _ = binarize(params, s, f, cfg, train=True)
        return jnp.sum(b_u**2)

    g = jax.grad(loss)(p)
    for t in range(cfg.n_levels):
        wnorm = sum(
            float(jnp.abs(v).sum())
            for v in jax.tree_util.tree_leaves(g["W"][t])
        )
        assert wnorm > 0, f"no gradient into W_{t}"


def test_bn_state_updates_in_train_mode():
    cfg = BinarizerConfig(input_dim=16, code_dim=8, n_levels=2, hidden_dim=12)
    p, s = init_binarizer(jax.random.PRNGKey(0), cfg)
    f = jax.random.normal(jax.random.PRNGKey(1), (32, 16)) * 3.0
    _, _, s_train = binarize(p, s, f, cfg, train=True)
    assert not np.allclose(np.asarray(s_train["W"][0]["bn_mean"]),
                           np.asarray(s["W"][0]["bn_mean"]))
    _, _, s_eval = binarize(p, s, f, cfg, train=False)
    assert np.allclose(np.asarray(s_eval["W"][0]["bn_mean"]),
                       np.asarray(s["W"][0]["bn_mean"]))
