"""Distributed engine on the unified kernel substrate, run on a CPU mesh in
a subprocess with 8 forced host devices (same pattern as
test_engine_distributed.py): the fused Pallas leaf path must match the jnp
leaf path bit-for-bit, packed streaming must not change results, and the
failover mask must still exclude dead leaves under the kernel path."""

import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=500,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_fused_leaf_matches_xla_leaf_and_exact():
    stdout = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.binarize_lib import pack_codes_nibbles
        from repro.index.engine import make_distributed_search, engine_input_shardings
        from repro.kernels.sdc import ref as R
        key = jax.random.PRNGKey(0)
        codes = jax.random.randint(key, (4096, 64), 0, 16).astype(jnp.int8)
        q = jax.random.randint(jax.random.fold_in(key,1), (8, 64), 0, 16).astype(jnp.int8)
        inv = R.doc_inv_norms(codes, 4)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        qs, ds, vs = engine_input_shardings(mesh)
        outs = {}
        with mesh:
            qd = jax.device_put(q, qs); ivd = jax.device_put(inv, vs)
            dd = jax.device_put(codes, ds)
            pd = jax.device_put(pack_codes_nibbles(codes), ds)
            for name, backend, d, packed in [
                ("xla", "xla", dd, False),
                ("fused", "interpret", dd, False),       # Pallas kernel leaf
                ("fused_packed", "interpret", pd, True), # int4 streaming leaf
                ("xla_packed", "xla", pd, True),
            ]:
                search = make_distributed_search(
                    mesh, n_levels=4, k=10, backend=backend, packed=packed,
                    block_q=8)
                outs[name] = search(qd, d, ivd)
        base_v, base_i = map(np.asarray, outs["xla"])
        for name in ("fused", "fused_packed", "xla_packed"):
            v, i = map(np.asarray, outs[name])
            np.testing.assert_array_equal(base_v, v)
            np.testing.assert_array_equal(base_i, i)
        ev, ei = jax.lax.top_k(R.sdc_ref(q, codes, 4), 10)
        agree = np.mean([len(set(base_i[i]) & set(np.asarray(ei[i])))/10
                         for i in range(8)])
        print("AGREE", agree)
    """)
    assert "AGREE 1.0" in stdout


def test_hnsw_engine_backends_agree_and_recall():
    """HNSW as a first-class engine index: one NSW graph per leaf searched
    by the batched-frontier walker; gather-kernel (interpret) and jnp
    leaves must agree bit-for-bit, packed included, with global ids and
    near-exact recall at generous ef."""
    stdout = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.index.engine import (make_hnsw_search,
            hnsw_engine_shardings, hnsw_engine_inputs)
        from repro.index.hnsw_lite import build_hnsw_sharded
        from repro.kernels.sdc import ref as R
        key = jax.random.PRNGKey(0)
        codes = np.asarray(jax.random.randint(key, (2048, 64), 0, 16), np.int8)
        q = jax.random.randint(jax.random.fold_in(key,1), (8, 64), 0, 16).astype(jnp.int8)
        inv = np.asarray(R.doc_inv_norms(jnp.asarray(codes), 4))
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        outs = {}
        with mesh:
            shards = hnsw_engine_shardings(mesh)
            qd = jax.device_put(q, shards[0])
            for packed in (False, True):
                sh = build_hnsw_sharded(codes, inv, n_leaves=8, n_levels=4,
                                        M=8, ef_construction=32, seed=0,
                                        packed=packed)
                ins = [jax.device_put(a, s)
                       for a, s in zip(hnsw_engine_inputs(sh), shards[1:])]
                for backend in ("xla", "interpret"):
                    search = make_hnsw_search(mesh, n_levels=4, k=10, ef=64,
                                              beam=16, backend=backend,
                                              packed=packed)
                    outs[(packed, backend)] = search(qd, *ins)
        bv, bi = map(np.asarray, outs[(False, "xla")])
        for key_ in outs:
            v, i = map(np.asarray, outs[key_])
            np.testing.assert_array_equal(bv, v)
            np.testing.assert_array_equal(bi, i)
        ev, ei = jax.lax.top_k(R.sdc_ref(q, jnp.asarray(codes), 4), 10)
        agree = np.mean([len(set(bi[i]) & set(np.asarray(ei[i])))/10
                         for i in range(8)])
        assert (bi >= 0).all() and (bi < 2048).all()
        print("AGREE", agree)
    """)
    agree = float(stdout.split("AGREE")[1].strip())
    assert agree >= 0.9, stdout


def test_failover_excludes_dead_leaf_under_kernel_path():
    stdout = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.index.engine import make_failover_search, engine_input_shardings
        from repro.kernels.sdc import ref as R
        key = jax.random.PRNGKey(0)
        codes = jax.random.randint(key, (4096, 64), 0, 16).astype(jnp.int8)
        q = jax.random.randint(jax.random.fold_in(key,1), (8, 64), 0, 16).astype(jnp.int8)
        inv = R.doc_inv_norms(codes, 4)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        search = make_failover_search(mesh, n_levels=4, k=10,
                                      backend="interpret", block_q=8)
        qs, ds, vs = engine_input_shardings(mesh)
        with mesh:
            qd = jax.device_put(q, qs); dd = jax.device_put(codes, ds)
            ivd = jax.device_put(inv, vs)
            alive = jnp.ones((8,), bool)
            v_all, i_all = search(qd, dd, ivd, alive)
            alive = alive.at[3].set(False)
            v_deg, i_deg = search(qd, dd, ivd, alive)
        ev, ei = jax.lax.top_k(R.sdc_ref(q, codes, 4), 10)
        full = np.mean([len(set(np.asarray(i_all[i]))&set(np.asarray(ei[i])))/10 for i in range(8)])
        dead_lo, dead_hi = 3*512, 4*512
        leaked = int(((np.asarray(i_deg) >= dead_lo) & (np.asarray(i_deg) < dead_hi)).sum())
        deg = np.mean([len(set(np.asarray(i_deg[i]))&set(np.asarray(ei[i])))/10 for i in range(8)])
        print("FULL", full, "DEG", deg, "LEAKED", leaked)
        assert full == 1.0 and leaked == 0 and deg >= 0.8
    """)
    assert "FULL 1.0" in stdout
