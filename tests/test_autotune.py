"""Persistent block-plan autotuner (launch/autotune.py).

The tuner's contract mirrors the binarizer checkpoint cache: a cache
hit reloads exactly the plan the first toucher swept, every signature
knob moves the digest, and a corrupt or stale entry is re-tuned, never
trusted. On top of that sits the one invariant that makes autotuning
safe to ship at all: block plans change LAUNCH GEOMETRY only — any
plan, tuned or not, must produce bit-identical scores and ids.
"""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.index.flat import FlatSDC
from repro.kernels.sdc.defaults import (
    BlockPlan,
    default_plan,
    plan_for,
)
from repro.kernels.sdc.ops import sdc_search_backend
from repro.kernels.sdc.rerank import sdc_rerank_gathered
from repro.launch import autotune

M, N, LEVELS = 16, 64, 2


def _codes(seed=0, n=N, m=M, q=4):
    rng = np.random.default_rng(seed)
    hi = 2 ** LEVELS
    cd = jnp.asarray(rng.integers(0, hi, size=(n, m)).astype(np.int8))
    cq = jnp.asarray(rng.integers(0, hi, size=(q, m)).astype(np.int8))
    return cd, cq


def _tune(kind="scan", cache_dir=None, **kw):
    kw.setdefault("code_dim", M)
    kw.setdefault("n_shard", N)
    kw.setdefault("k", 4)
    kw.setdefault("n_levels", LEVELS)
    kw.setdefault("backend", "interpret")
    kw.setdefault("sample_q", 2)
    kw.setdefault("reps", 1)
    return autotune.tuned_block_plan(kind, cache_dir=cache_dir, **kw)


def test_second_call_is_a_cache_hit(tmp_path):
    first = _tune(cache_dir=str(tmp_path))
    assert first.tuned is True
    assert first.plan.source == "tuned"
    second = _tune(cache_dir=str(tmp_path))
    assert second.tuned is False
    assert second.plan.source == "cache"
    assert second.digest == first.digest
    assert second.path == first.path
    assert second.plan.blocks() == first.plan.blocks()


def test_replicas_sharing_a_cache_dir_share_one_plan(tmp_path):
    # Replica launches differ only in who touched the cache first; all
    # of them must serve with the winner the first sweep persisted.
    plans = [_tune(cache_dir=str(tmp_path)) for _ in range(3)]
    assert [p.tuned for p in plans] == [True, False, False]
    assert len({p.plan.blocks() for p in plans}) == 1
    assert len({p.path for p in plans}) == 1


def test_every_signature_knob_moves_the_digest():
    base = dict(code_dim=M, n_shard=N, packed=False, k=4,
                backend="interpret")
    d0 = autotune.plan_digest("scan", **base)
    assert autotune.plan_digest("scan", **base) == d0
    for var in (
        dict(base, code_dim=2 * M),
        dict(base, n_shard=2 * N),
        dict(base, packed=True),
        dict(base, k=8),
        dict(base, backend="pallas"),
    ):
        assert autotune.plan_digest("scan", **var) != d0
    assert autotune.plan_digest("rerank", **base) != d0


def test_corrupt_plan_is_retuned_not_trusted(tmp_path):
    first = _tune(cache_dir=str(tmp_path))
    with open(first.path, "w") as f:
        f.write("not json {")
    again = _tune(cache_dir=str(tmp_path))
    assert again.tuned is True
    assert again.path == first.path


def test_stale_signature_is_retuned(tmp_path):
    first = _tune(cache_dir=str(tmp_path))
    with open(first.path) as f:
        payload = json.load(f)
    payload["signature"]["n_shard"] = N + 1  # drifted world
    with open(first.path, "w") as f:
        json.dump(payload, f)
    again = _tune(cache_dir=str(tmp_path))
    assert again.tuned is True


def test_corrupt_blocks_are_retuned(tmp_path):
    first = _tune(cache_dir=str(tmp_path))
    with open(first.path) as f:
        payload = json.load(f)
    payload["block_q"] = "wat"
    with open(first.path, "w") as f:
        json.dump(payload, f)
    assert _tune(cache_dir=str(tmp_path)).tuned is True


def test_env_var_override_is_honored(tmp_path, monkeypatch):
    monkeypatch.setenv(autotune.CACHE_ENV, str(tmp_path))
    tp = _tune(cache_dir=None)
    assert tp.path.startswith(str(tmp_path))


def test_explicit_cache_dir_beats_env(tmp_path, monkeypatch):
    env_dir, arg_dir = tmp_path / "env", tmp_path / "arg"
    monkeypatch.setenv(autotune.CACHE_ENV, str(env_dir))
    tp = _tune(cache_dir=str(arg_dir))
    assert tp.path.startswith(str(arg_dir))
    assert not env_dir.exists()


def test_unsweepable_signatures_short_circuit():
    # xla has no kernel tiles; gather's geometry is corpus-fixed.
    inert = _tune("scan", backend="xla")
    assert inert.plan.source == "inert-backend"
    assert inert.path is None and inert.tuned is False
    fixed = _tune("gather", backend="interpret")
    assert fixed.plan.source == "fixed-geometry"
    assert fixed.plan.blocks() == default_plan("gather").blocks()


def test_sweep_payload_records_paired_timings(tmp_path):
    # The bench gate reads default_ms/tuned_ms straight from this
    # payload; tuned is the min over all candidates INCLUDING the
    # default, so it can never exceed default.
    tp = _tune(cache_dir=str(tmp_path))
    with open(tp.path) as f:
        payload = json.load(f)
    assert payload["default_ms"] is not None
    assert payload["tuned_ms"] is not None
    assert payload["tuned_ms"] <= payload["default_ms"]
    assert payload["default_blocks"] == list(default_plan("scan").blocks())


def test_candidate_grid_leads_with_the_default():
    for kind in ("scan", "rerank", "gather"):
        grid = autotune.candidate_grid(kind, code_dim=M, n_shard=N,
                                       packed=False, k=4)
        assert grid[0] == default_plan(kind).blocks()
        assert len(grid) == len(set(grid))


def test_any_plan_is_bit_identical_through_the_scan(tmp_path):
    cd, cq = _codes()
    inv = jnp.ones(N, jnp.float32)
    ref_s, ref_i = sdc_search_backend(cq, cd, inv, n_levels=LEVELS, k=4,
                                      backend="interpret")
    tuned = _tune(cache_dir=str(tmp_path))
    for plan in (tuned.plan, BlockPlan("scan", 8, 256, "tuned")):
        s, i = sdc_search_backend(cq, cd, inv, n_levels=LEVELS, k=4,
                                  backend="interpret", block_plan=plan)
        np.testing.assert_array_equal(np.asarray(s), np.asarray(ref_s))
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ref_i))


def test_plan_is_bit_identical_through_flat_index():
    cd, cq = _codes(seed=3)
    index = FlatSDC.build(cd, n_levels=LEVELS)
    ref_s, ref_i = index.search(cq, 4)
    s, i = index.search(cq, 4,
                        block_plan=BlockPlan("scan", 8, 128, "tuned"))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(ref_s))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ref_i))


def test_rerank_grouping_is_bit_identical():
    cd, cq = _codes(seed=5)
    inv = jnp.ones(N, jnp.float32)
    rng = np.random.default_rng(7)
    cand = np.stack([
        rng.choice(N, size=8, replace=False) for _ in range(cq.shape[0])
    ]).astype(np.int32)
    ref_s, ref_i = sdc_rerank_gathered(cq, np.asarray(cd), np.asarray(inv),
                                       cand, n_levels=LEVELS, k=4, group=1)
    s, i = sdc_rerank_gathered(cq, np.asarray(cd), np.asarray(inv), cand,
                               n_levels=LEVELS, k=4, group=4)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(ref_s))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ref_i))


def test_plan_for_selects_by_kind():
    scan = BlockPlan("scan", 8, 256, "tuned")
    rerank = BlockPlan("rerank", 1, 8, "tuned")
    assert plan_for(None, "scan") is None
    assert plan_for(scan, "scan") is scan
    assert plan_for(scan, "rerank") is None  # single plan, other kind
    mapping = {"scan": scan, "rerank": rerank}
    assert plan_for(mapping, "rerank") is rerank
    assert plan_for(mapping, "gather") is None
    with pytest.raises(ValueError, match="kind"):
        plan_for({"scan": rerank}, "scan")  # mislabeled entry


def test_shape_errors_carry_the_offending_shapes():
    cd, cq = _codes()
    inv = jnp.ones(N, jnp.float32)
    with pytest.raises(ValueError, match=r"code dim"):
        # packed flag promised half-width codes but got full-width ones
        sdc_search_backend(cq, cd, inv, n_levels=LEVELS, k=4,
                           backend="interpret", packed=True)
