"""Digest-cached binarizer checkpoints (launch/binarizer_cache.py).

The serve drivers train their recurrent-MLP binarizer once per
(corpus, config, steps, batch, seed) digest and reload the checkpoint
on every later launch — a hit must be bit-identical to the run that
wrote it, anything that shaped the weights must move the digest, and a
corrupt file must be treated as a miss, never trusted.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BinarizerConfig, TrainConfig
import repro.core.losses as losses_lib
from repro.launch import binarizer_cache
from repro.train import optim

DIM, CODE, LEVELS = 16, 8, 2


def _cfg(hidden=16):
    return TrainConfig(
        binarizer=BinarizerConfig(input_dim=DIM, code_dim=CODE,
                                  n_levels=LEVELS, hidden_dim=hidden),
        queue=losses_lib.QueueConfig(length=64, dim=CODE, top_k=4),
        adam=optim.AdamConfig(lr=2e-3, clip_norm=5.0),
    )


def _docs(seed=0, n=64):
    return np.random.default_rng(seed).normal(size=(n, DIM)).astype(
        np.float32
    )


def _leaves(ckpt):
    return jax.tree_util.tree_flatten((ckpt.params, ckpt.bn_state))[0]


def test_second_call_is_a_bit_identical_cache_hit(tmp_path):
    docs, cfg = _docs(), _cfg()
    first = binarizer_cache.trained_binarizer(
        docs, cfg, steps=3, batch=16, cache_dir=str(tmp_path)
    )
    assert first.trained is True
    second = binarizer_cache.trained_binarizer(
        docs, cfg, steps=3, batch=16, cache_dir=str(tmp_path)
    )
    assert second.trained is False
    assert second.digest == first.digest
    assert second.path == first.path
    for a, b in zip(_leaves(first), _leaves(second)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_encodes_like_the_training_run(tmp_path):
    from repro.core import make_encode_fn

    docs, cfg = _docs(), _cfg()
    binarizer_cache.trained_binarizer(
        docs, cfg, steps=3, batch=16, cache_dir=str(tmp_path)
    )
    loaded = binarizer_cache.trained_binarizer(
        docs, cfg, steps=3, batch=16, cache_dir=str(tmp_path)
    )
    enc = make_encode_fn(loaded.params, loaded.bn_state, cfg.binarizer)
    codes = np.asarray(enc(jnp.asarray(docs[:8])))
    assert codes.shape[0] == 8


def test_every_training_knob_moves_the_digest():
    docs, cfg = _docs(), _cfg()
    base = dict(steps=3, batch=16, seed=0)
    d0 = binarizer_cache.checkpoint_digest(docs, cfg, **base)
    assert binarizer_cache.checkpoint_digest(docs, cfg, **base) == d0
    for var in (
        dict(base, steps=4),
        dict(base, batch=8),
        dict(base, seed=1),
    ):
        assert binarizer_cache.checkpoint_digest(docs, cfg, **var) != d0
    assert binarizer_cache.checkpoint_digest(_docs(1), cfg, **base) != d0
    assert binarizer_cache.checkpoint_digest(docs, _cfg(hidden=8),
                                             **base) != d0


def test_corrupt_checkpoint_is_retrained_not_trusted(tmp_path):
    docs, cfg = _docs(), _cfg()
    first = binarizer_cache.trained_binarizer(
        docs, cfg, steps=3, batch=16, cache_dir=str(tmp_path)
    )
    with open(first.path, "wb") as f:
        f.write(b"not an npz archive")
    again = binarizer_cache.trained_binarizer(
        docs, cfg, steps=3, batch=16, cache_dir=str(tmp_path)
    )
    assert again.trained is True
    for a, b in zip(_leaves(first), _leaves(again)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serve_driver_trainer_routes_through_the_cache(tmp_path):
    from repro.launch import serve

    docs, cfg = _docs(), _cfg()
    state = serve.train_binarizer(docs, cfg, steps=3, batch=16,
                                  cache_dir=str(tmp_path))
    assert state.trained is True
    assert state.path is not None
    again = serve.train_binarizer(docs, cfg, steps=3, batch=16,
                                  cache_dir=str(tmp_path))
    assert again.trained is False
    codes = serve.encode_codes(state, docs[:4], cfg.binarizer)
    assert np.asarray(codes).shape[0] == 4
