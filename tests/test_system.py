"""End-to-end system behaviour: the full BEBR pipeline on synthetic EBR
data — train binarizer (emb2emb, momentum queue), binarize corpus, build
index, search, and beat the 1-bit hash baseline while approaching the
float ceiling (paper Tables 1-2 at test scale)."""

import functools

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.losses as L
from repro.core import (
    BinarizerConfig,
    TrainConfig,
    binarize_lib,
    init_train_state,
    pack_codes,
    train_step,
)
from repro.data.synthetic import clustered_corpus, pair_batches
from repro.index.flat import FlatFloat, FlatSDC

DIM, CODE, LEVELS = 64, 32, 4  # 2048-bit float -> 128-bit code (16x)


def _train_binarizer(docs, steps=300, n_levels=LEVELS, seed=0):
    from repro.train import optim

    # Warmup-decay recipe: the linear warmup spans the queue burn-in (the
    # momentum queue starts zero-filled, so early hard negatives are
    # junk), and the cosine decay sharpens convergence; 300 steps instead
    # of the seed's 150 lets the queue fully turn over. Lifts recall from
    # ~0.84 (below the 0.85*float bar) to ~0.92 on this corpus.
    cfg = TrainConfig(
        binarizer=BinarizerConfig(input_dim=DIM, code_dim=CODE,
                                  n_levels=n_levels, hidden_dim=128),
        queue=L.QueueConfig(length=1024, dim=CODE, top_k=32),
        adam=optim.AdamConfig(
            lr=2e-3, clip_norm=5.0,
            schedule=optim.cosine_schedule(steps, warmup=steps // 10,
                                           floor=0.05),
        ),
    )
    state = init_train_state(jax.random.PRNGKey(seed), cfg)
    step = jax.jit(functools.partial(train_step, cfg=cfg))
    gen = pair_batches(docs, seed + 1, 128)
    for _ in range(steps):
        a, p = next(gen)
        state, _ = step(state, a, p)
    return state, cfg


def _encode(state, cfg, emb):
    bits, _, _ = binarize_lib.binarize(
        state.params, state.bn_state, jnp.asarray(emb), cfg.binarizer
    )
    return pack_codes(bits)


def _recall_at(idx, gt, k):
    return float(jnp.mean(jnp.any(idx[:, :k] == jnp.asarray(gt)[:, None], -1)))


def test_bebr_end_to_end_recall():
    docs, queries, gt = clustered_corpus(0, 4000, 64, DIM, n_clusters=128)

    # float ceiling
    ff = FlatFloat.build(jnp.asarray(docs))
    _, idx_f = ff.search(jnp.asarray(queries), 10)
    r_float = _recall_at(idx_f, gt, 10)

    # recurrent binary (ours)
    state, cfg = _train_binarizer(docs)
    d_codes = _encode(state, cfg, docs)
    q_codes = _encode(state, cfg, queries)
    index = FlatSDC.build(d_codes, LEVELS)
    _, idx_b = index.search(q_codes, 10)
    r_ours = _recall_at(idx_b, gt, 10)

    # 1-bit hash baseline (same trained stack restricted to the base level)
    state1, cfg1 = _train_binarizer(docs, n_levels=1, seed=3)
    d1 = _encode(state1, cfg1, docs)
    q1 = _encode(state1, cfg1, queries)
    index1 = FlatSDC.build(d1, 1)
    _, idx_h = index1.search(q1, 10)
    r_hash = _recall_at(idx_h, gt, 10)

    # paper's ordering: hash <= ours <= float (ours ~ float)
    assert r_ours >= r_hash, (r_hash, r_ours, r_float)
    assert r_ours >= 0.85 * r_float, (r_hash, r_ours, r_float)
    # and the index is drastically smaller than float
    assert index.nbytes() < ff.nbytes() / 8


def test_training_is_restart_reproducible(tmp_path):
    """Binarizer training checkpoints and resumes to identical state."""
    from repro.train import checkpoint as ck

    docs, _, _ = clustered_corpus(1, 800, 8, DIM)
    cfg = TrainConfig(
        binarizer=BinarizerConfig(input_dim=DIM, code_dim=CODE, n_levels=2,
                                  hidden_dim=32),
        queue=L.QueueConfig(length=256, dim=CODE, top_k=8),
    )
    step = jax.jit(functools.partial(train_step, cfg=cfg))

    docs_gen = pair_batches(docs, 42, 32)
    hist = [next(docs_gen) for _ in range(10)]

    # uninterrupted: 10 steps
    st = init_train_state(jax.random.PRNGKey(0), cfg)
    for a, p in hist:
        st, _ = step(st, a, p)

    # interrupted at 5 + checkpoint + resume
    st2 = init_train_state(jax.random.PRNGKey(0), cfg)
    for a, p in hist[:5]:
        st2, _ = step(st2, a, p)
    ck.save(str(tmp_path), 5, st2)
    st3, _ = ck.restore(str(tmp_path), st2)
    for a, p in hist[5:]:
        st3, _ = step(st3, a, p)

    for a, b in zip(jax.tree_util.tree_leaves(st.params),
                    jax.tree_util.tree_leaves(st3.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
