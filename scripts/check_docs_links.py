#!/usr/bin/env python
"""Docs lint (stdlib only): broken relative links + launch docstrings.

Two checks, both hard failures in CI (wired into the lint job of
``.github/workflows/ci.yml`` and ``scripts/ci_dryrun.sh``):

  1. Every relative markdown link in ``README.md`` and ``docs/*.md``
     must resolve to a file or directory in the repo (external
     http(s)/mailto links and pure #anchors are skipped; fenced code
     blocks and inline code spans are stripped first so array shapes
     like ``[N, D]`` never false-positive). Docs whose pointers rot are
     worse than no docs.
  2. Every ``src/repro/launch/*.py`` module must carry a module
     docstring — the serving tier's invariants (FIFO per client,
     bit-identity vs serve_sequential, first-wins ticket resolution)
     live there, not implicitly in test names.

    python scripts/check_docs_links.py [repo_root]
"""

from __future__ import annotations

import ast
import glob
import os
import re
import sys

# [text](target "optional title") — target captured up to ) or whitespace
LINK_RE = re.compile(r"\[[^\]]*\]\(\s*<?([^)\s>]+)>?(?:\s+\"[^\"]*\")?\s*\)")
EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def _strip_code(text: str) -> str:
    """Drop fenced code blocks and inline code spans (links in code are
    examples, not navigation)."""
    out, fenced = [], False
    for line in text.splitlines():
        if line.lstrip().startswith("```"):
            fenced = not fenced
            out.append("")
            continue
        out.append("" if fenced else re.sub(r"`[^`]*`", "", line))
    return "\n".join(out)


def check_links(repo: str) -> list:
    files = [os.path.join(repo, "README.md")]
    files += sorted(glob.glob(os.path.join(repo, "docs", "*.md")))
    errors = []
    for path in files:
        if not os.path.exists(path):
            errors.append(f"{os.path.relpath(path, repo)}: file missing")
            continue
        with open(path) as f:
            text = _strip_code(f.read())
        base = os.path.dirname(path)
        for target in LINK_RE.findall(text):
            if target.startswith(EXTERNAL):
                continue
            rel = target.split("#")[0]
            if not rel:  # pure in-page anchor
                continue
            resolved = os.path.normpath(os.path.join(base, rel))
            if not os.path.exists(resolved):
                errors.append(
                    f"{os.path.relpath(path, repo)}: broken link -> {target}"
                )
    return errors


def check_launch_docstrings(repo: str) -> list:
    errors = []
    pattern = os.path.join(repo, "src", "repro", "launch", "*.py")
    modules = sorted(glob.glob(pattern))
    if not modules:
        return [f"no modules matched {pattern} (layout changed?)"]
    for path in modules:
        with open(path) as f:
            try:
                tree = ast.parse(f.read(), filename=path)
            except SyntaxError as e:
                errors.append(f"{os.path.relpath(path, repo)}: {e}")
                continue
        if not ast.get_docstring(tree):
            errors.append(
                f"{os.path.relpath(path, repo)}: missing module docstring"
            )
    return errors


def main() -> int:
    repo = sys.argv[1] if len(sys.argv) > 1 else \
        os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
    repo = os.path.abspath(repo)
    errors = check_links(repo) + check_launch_docstrings(repo)
    for e in errors:
        print(f"docs lint: {e}", file=sys.stderr)
    if errors:
        print(f"docs lint: {len(errors)} error(s)", file=sys.stderr)
        return 1
    print("docs lint: ok (links resolve, launch modules documented)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
