#!/usr/bin/env bash
# Local mirror of .github/workflows/ci.yml (for machines without `act`):
# runs the same three jobs — lint, tier-1 tests, bench-smoke + gate — in
# order and reports a summary. Run from the repo root:
#
#     bash scripts/ci_dryrun.sh [--skip-tests]
#
# --skip-tests runs only lint + bench-smoke (the tier-1 suite takes
# ~8 min on a laptop CPU).
set -u
cd "$(dirname "$0")/.."

SKIP_TESTS=0
[ "${1:-}" = "--skip-tests" ] && SKIP_TESTS=1

fail=0
note() { printf '\n=== %s ===\n' "$*"; }

note "job: lint (ruff check src tests benchmarks)"
if command -v ruff >/dev/null 2>&1; then
    ruff check src tests benchmarks || fail=1
elif python -m ruff --version >/dev/null 2>&1; then
    python -m ruff check src tests benchmarks || fail=1
else
    echo "ruff not installed locally -- SKIPPED (CI installs it)"
fi

note "job: lint (docs links + launch docstrings)"
python scripts/check_docs_links.py || fail=1

note "job: lint (no tracked Python bytecode)"
if git ls-files | grep -E '(^|/)__pycache__/|\.py[cod]$'; then
    echo "tracked bytecode found -- git rm --cached it (.gitignore covers it)"
    fail=1
else
    echo "ok: no tracked bytecode"
fi

if [ "$SKIP_TESTS" = 0 ]; then
    note "job: tier1 (PYTHONPATH=src python -m pytest -x -q)"
    # mirror CI's coverage run when pytest-cov is installed; plain
    # pytest otherwise (CI always installs it)
    if python -c "import pytest_cov" >/dev/null 2>&1; then
        PYTHONPATH=src python -m pytest -x -q --cov=repro --cov-report=xml --cov-report=term || fail=1
        note "job: tier1 coverage floor for launch/ (>= 70%, serve.py exempt)"
        python -m coverage report --include='src/repro/launch/*' --omit='src/repro/launch/serve.py' --fail-under=70 || fail=1
    else
        echo "pytest-cov not installed locally -- running without coverage"
        PYTHONPATH=src python -m pytest -x -q || fail=1
    fi
else
    note "job: tier1 -- SKIPPED (--skip-tests)"
fi

note "job: bench-smoke (tiny corpus + packed-byte gate + serving gate)"
# mirror CI: workspace-local tune cache so the autotune sweep's plans
# land next to the bench JSONs instead of in ~/.cache
export REPRO_BEBR_CACHE="${REPRO_BEBR_CACHE:-$PWD/.tune-cache}"
PYTHONPATH=src python -m benchmarks.run --fast --only bench_sdc_scan || fail=1
PYTHONPATH=src python -m benchmarks.run --fast --only bench_hnsw_scan || fail=1
PYTHONPATH=src python -m benchmarks.run --fast --only bench_serving_pipeline || fail=1
python scripts/check_bench_gate.py BENCH_sdc_scan.json --max-packed-ratio 0.55 --max-autotune-ratio 1.0 || fail=1
python scripts/check_bench_gate.py BENCH_hnsw_scan.json --max-packed-ratio 0.55 || fail=1
python scripts/check_bench_gate.py BENCH_serving.json --min-serving-ratio 1.0 --min-replica-ratio 0.9 || fail=1

note "summary"
if [ "$fail" = 0 ]; then
    echo "ci dry-run: all jobs green"
else
    echo "ci dry-run: FAILURES (see above)"
fi
exit "$fail"
