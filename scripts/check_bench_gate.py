#!/usr/bin/env python
"""CI bench gate: fail when the packed-scan byte invariant regresses.

ROADMAP invariant: int4 nibble-packed code streaming must keep scan bytes
at <= 0.55x the unpacked scan for every engine variant (0.5x codes + small
per-doc metadata that packing cannot shrink). A PR that silently widens
the packed layout, forgets to pack a new scan path, or inflates per-doc
metadata shows up here as a ratio creep past the threshold.

    python scripts/check_bench_gate.py BENCH_sdc_scan.json \
        [--max-packed-ratio 0.55]

Reads the ``rows`` emitted by ``benchmarks/run.py --only bench_sdc_scan``
(each row: variant, packed, bytes_scanned), pairs packed/unpacked rows per
variant, and exits non-zero if any ratio exceeds the threshold — or if a
variant is missing one side of the pair (a gate that can't see the packed
row must not pass green).

Also understands ``BENCH_hnsw_scan.json`` (rows keyed by ``packed`` only,
bytes in ``table_bytes`` — the device footprint of the neighbor-block
tables), so the graph-search tables are held to the same invariant.

``BENCH_serving.json`` (rows keyed by ``mode``) is gated differently:
the double-buffered pipeline must not lose throughput to the sequential
encode+scan loop it replaced — overlapped QPS >= --min-serving-ratio x
sequential QPS (default 1.0). Both rows must be present; the emitter
reports best-of-N interleaved runs, so the ratio is not noise-driven.

The replica sweep ("replicated" rows, added with launch/proxy.py) is
held to a schema AND a floor: every replicated row must carry the full
routing telemetry (replicas, router, qps, latency percentiles, shed/
failover counts, and a per-replica breakdown — missing keys are a hard
failure, because a report the proxy dashboards cannot parse must not
pass green), at least one replicated row must exist, and every N>1
row's BEST paired-trial QPS ratio vs the replicas=1 tier run (same
trial, same code path — a genuine tier cost fails every paired trial,
while the host's noise phases move even identical-code paired medians
by +-30%) must be >= --min-replica-ratio (default 0.9: on a
shared-core CI host replication cannot scale, but the router must not
COST meaningful throughput either). The per-run median rides along in
the row for the perf record.

The live index lifecycle ("swap" row, added with launch/lifecycle.py)
is gated on CORRECTNESS, not speed: the emitter performs a rolling
per-replica index swap under continuous traffic plus an injected
transient fault + canary revival, and the gate hard-fails when any
result was lost or reordered, when results were not bit-identical to
the sequential loop, when the rolling swap did not cover every replica,
or when no revival was recorded. Per-replica rows must also carry the
stats ``generation`` (bumped on every swap/revival so a revived
replica's counters are not conflated with its previous run).

The chaos drill ("chaos" row, added with launch/faults.py) extends the
same correctness treatment to the robustness machinery: a stuck
(non-raising) scan must be caught by the watchdog and survived with
zero lost results, per-query deadline misses must be *counted*
(``deadline_violations`` present — an accounting hole is a hard fail
even at zero misses), a revival must follow the stall clearing, and
the degradation A/B at equal overload must shed strictly fewer
requests with the effort knob enabled than without it.

The live embedding-version migration ("upgrade" row, added with the
version-aware serving tier) gates the compat-encoded upgrade path: the
emitter runs mixed v1/v2 traffic through a 2-replica tier while a
rolling swap migrates it from the v1 to the v2 index, with the
CompatibilityMatrix covering the cross-version window. The gate
hard-fails when any result was lost or reordered, when answers were not
bit-identical to the sequential reference for their
(query_version, served_by_version) pair, when per-version recall over
the migration window drops below the row's embedded ``recall_floor``
(itself floored by --min-upgrade-recall so an emitter cannot pass by
shipping a zero floor), when no compat dispatch was recorded (a
"migration" that never exercised the cross-version hop proves nothing),
when the swap did not cover every replica, or when any replica does not
finish on the target version.

The bi-granular sweep ("bigranular" section of BENCH_sdc_scan.json,
added with the coarse-scan + fine-rerank mode) is gated on the memory
hierarchy actually paying off: every row must carry the full schema
(coarse_levels, k_coarse, recall_rerank/recall_coarse, and the
coarse/fine/full byte totals), rerank recall must never fall below the
coarse-only recall it refines, and at ``coarse_levels = levels // 2``
the hot coarse tier's bytes must be <= --max-coarse-ratio x the
full-level bytes (default 0.6: half the levels plus the per-doc
metadata packing cannot shrink). A section that is missing, empty, or
missing its half-levels row hard-fails — a tiered mode the bench
cannot see must not pass green.

The bits-per-dimension sweep ("bits_sweep" section, same file) is
gated on schema and byte monotonicity only — recall at a given level
count is a modelling choice, not an invariant: every row carries
n_levels/packed/ms/recall/bytes_scanned/index_bytes, the serialized
``index_bytes`` must grow monotonically with n_levels within each
packed state, and each level's packed scan must hold the same
--max-packed-ratio byte invariant as the main rows.

The block-plan autotuner record ("autotune" section of
BENCH_sdc_scan.json, added with the adaptive query execution PR) is
gated on the tuner never LOSING to the shipped defaults: one row per
kernel kind (scan / gather / rerank) with the default and tuned launch
geometry plus the sweep's own paired timings (the default plan is timed
as a candidate on the same operands as every challenger, so the ratio
is noise-immune by construction). Every kind must be present, and
``ms_ratio_tuned_vs_default`` must be <= --max-autotune-ratio (default
1.0). A swept kind with no timings (ratio null) hard-fails —
un-sweepable kinds (gather's corpus-fixed geometry) must report the
default plan with ratio exactly 1.0 instead.

The probe-budget sweep ("probe_budget" section, same file) gates the
occupancy-weighted IVF probe allocation: per global budget B, recall@k
for the weighted allocation and for the flat comparator (equal weights,
same budget machinery, same total scan work). Weighted recall must
never fall below flat recall at equal budget (both are deterministic
seeded scans, so ties pass and the check cannot flake), and the sweep
must include the exact-multiple parity row ``B = nprobe * nlist`` with
``bit_identical`` true — at exact multiples the per-centroid thresholds
are uniform and the budgeted search must reproduce the flat-nprobe
search bit-for-bit (ids AND scores, weighted and flat alike).

The tiered serving drill ("bigranular_swap" row of BENCH_serving.json)
re-runs the rolling-swap correctness record with a coarse+rerank
lifecycle builder serving the tier: the same lost/reordered/
bit-identity/revival checks apply, plus ``reranked`` must be true —
every ticket must have carried rerank provenance, proving the tier
actually served the bi-granular path (not a silent fallback to the
flat index).
"""

from __future__ import annotations

import argparse
import json
import sys


def _row_bytes(row: dict):
    return row.get("bytes_scanned", row.get("table_bytes"))


# Replica-sweep schema: a replicated row that cannot be parsed into the
# proxy-level report (QPS, latency, shed, per-replica breakdown) must
# fail the gate, not silently pass with holes.
REPLICATED_ROW_KEYS = (
    "replicas", "router", "qps", "qps_ratio_vs_single", "ms_per_batch",
    "latency_p50_ms", "latency_p99_ms", "device_idle_frac",
    "shed", "failovers", "per_replica",
)
PER_REPLICA_KEYS = ("replica", "requests", "queries", "shed",
                    "device_idle_frac", "generation")

# Live index lifecycle row (added with launch/lifecycle.py): a rolling
# per-replica swap under continuous traffic plus a canary revival. The
# row is not a throughput measurement — it is a CORRECTNESS record, so
# the gate hard-fails on any lost or reordered result, any non-bit-
# identical answer, an incomplete rolling swap, or a missing revival.
SWAP_ROW_KEYS = (
    "replicas", "index_kind", "swapped_replicas", "swap_s",
    "queries_during_swap", "lost", "reordered", "bit_identical", "revivals",
)

# Chaos drill row (added with launch/faults.py): a stuck (non-raising)
# scan under traffic + per-query deadlines + the degradation A/B. Like
# the swap row it is a CORRECTNESS record: lost results, a missing
# deadline accounting, an undetected stall, a missing revival, or a
# degradation run that sheds MORE than its baseline all hard-fail.
CHAOS_ROW_KEYS = (
    "replicas", "lost", "reordered", "bit_identical",
    "deadline_violations", "watchdog_stalls", "failovers", "revivals",
    "time_to_recover_s", "shed_without_degradation",
    "shed_with_degradation", "degraded_frac",
)

# Live embedding-version migration row (added with the version-aware
# serving tier): mixed v1/v2 traffic over a rolling v1 -> v2 index swap,
# cross-version requests served through the CompatibilityMatrix. A
# CORRECTNESS record like the swap/chaos rows, plus a QUALITY floor:
# per-version recall across the migration window must hold the row's
# own recall_floor (which --min-upgrade-recall keeps honest).
UPGRADE_ROW_KEYS = (
    "replicas", "index_kind", "from_version", "to_version",
    "swapped_replicas", "swap_s", "queries_during_swap",
    "lost", "reordered", "bit_identical", "compat_dispatches",
    "recall_v1", "recall_v2", "recall_floor", "final_versions",
)

# Bi-granular sweep row (BENCH_sdc_scan.json "bigranular" section,
# added with the coarse-scan + fine-rerank mode): the tiered layout's
# quality/traffic record. recall_rerank must refine (>=) recall_coarse
# and the hot coarse tier must actually be small.
BIGRANULAR_ROW_KEYS = (
    "coarse_levels", "k_coarse", "packed", "ms",
    "recall_rerank", "recall_coarse",
    "coarse_bytes_scanned", "fine_bytes_scanned", "full_bytes_scanned",
)

# Bits-per-dimension sweep row (BENCH_sdc_scan.json "bits_sweep"
# section): schema + byte monotonicity only — recall is recorded, not
# gated (the level count is a quality/cost knob, not an invariant).
BITS_SWEEP_ROW_KEYS = (
    "n_levels", "packed", "ms", "recall", "bytes_scanned", "index_bytes",
)

# Block-plan autotuner row (BENCH_sdc_scan.json "autotune" section):
# one row per kernel kind. The timings come from the tuner's own sweep
# (default timed as a candidate alongside every challenger), so the
# gated ratio is paired-by-construction. default_ms/tuned_ms are
# nullable (un-sweepable kinds), so they are not in the hard-key set —
# a swept kind with a null RATIO still fails below.
AUTOTUNE_ROW_KEYS = (
    "kind", "backend", "block_q_default", "block_n_default",
    "block_q", "block_n", "source",
)
AUTOTUNE_KINDS = ("scan", "gather", "rerank")

# Shed-pressure autoscaler row (added with launch/autoscale.py): the
# same bursty open-loop trace replayed against a fixed single-replica
# tier and an autoscaled tier that is allowed to grow to
# replicas_max but must settle back to the fixed tier's size. A
# CORRECTNESS record (zero lost/reordered, bit-identical answers)
# plus the autoscaler's reason to exist: it must shed strictly less
# than the fixed tier at equal steady-state capacity, and its
# replica count must never leave the TierSpec bounds.
AUTOSCALE_ROW_KEYS = (
    "index_kind", "replicas_min", "replicas_max", "fixed_replicas",
    "steady_state_replicas", "submitted", "lost", "reordered",
    "bit_identical", "shed_fixed", "shed_autoscaled",
    "shed_rate_fixed", "shed_rate_autoscaled",
    "scale_ups", "scale_downs", "max_replicas_seen", "min_replicas_seen",
)

# Probe-budget sweep row (BENCH_sdc_scan.json "probe_budget" section):
# occupancy-weighted vs flat allocation at equal global budget. The
# parity row (budget == nprobe * nlist) additionally carries
# ``bit_identical``.
PROBE_BUDGET_ROW_KEYS = (
    "probe_budget", "avg_probes_per_query", "recall_weighted", "recall_flat",
)


def _check_upgrade_row(row: dict, label: str, min_recall: float) -> int:
    errors = 0
    missing = [k for k in UPGRADE_ROW_KEYS if k not in row or row[k] is None]
    if missing:
        print(f"serving gate: {label} missing keys {missing}",
              file=sys.stderr)
        return errors + 1  # can't judge an incomplete row further
    if row["lost"] != 0:
        print(f"serving gate: {label} lost {row['lost']} result(s) during "
              "the version migration", file=sys.stderr)
        errors += 1
    if row["reordered"] != 0:
        print(f"serving gate: {label} reordered {row['reordered']} "
              "result(s) during the version migration", file=sys.stderr)
        errors += 1
    if row["bit_identical"] is not True:
        print(f"serving gate: {label} answers not bit-identical to the "
              "sequential reference for their (query_version, "
              "served_by_version) pair", file=sys.stderr)
        errors += 1
    if row["swapped_replicas"] != row["replicas"]:
        print(f"serving gate: {label} migrated only "
              f"{row['swapped_replicas']}/{row['replicas']} replicas",
              file=sys.stderr)
        errors += 1
    if row["compat_dispatches"] < 1:
        print(f"serving gate: {label} recorded no compat dispatch — the "
              "cross-version hop was never exercised", file=sys.stderr)
        errors += 1
    floor = max(float(row["recall_floor"]), min_recall)
    for key in ("recall_v1", "recall_v2"):
        if row[key] < floor:
            print(f"serving gate: {label} {key}={row[key]:.4f} below the "
                  f"recall floor {floor}", file=sys.stderr)
            errors += 1
    bad = [v for v in row["final_versions"] if v != row["to_version"]]
    if bad or len(row["final_versions"]) != row["replicas"]:
        print(f"serving gate: {label} final replica versions "
              f"{row['final_versions']} != {row['replicas']} x "
              f"'{row['to_version']}'", file=sys.stderr)
        errors += 1
    return errors


def _check_autoscale_row(row: dict, label: str) -> int:
    errors = 0
    missing = [k for k in AUTOSCALE_ROW_KEYS if k not in row or row[k] is None]
    if missing:
        print(f"serving gate: {label} missing keys {missing}",
              file=sys.stderr)
        return errors + 1  # can't judge an incomplete row further
    if row["lost"] != 0:
        print(f"serving gate: {label} lost {row['lost']} result(s) across "
              "the scale-up/scale-down churn", file=sys.stderr)
        errors += 1
    if row["reordered"] != 0:
        print(f"serving gate: {label} reordered {row['reordered']} "
              "result(s) across the scale-up/scale-down churn",
              file=sys.stderr)
        errors += 1
    if row["bit_identical"] is not True:
        print(f"serving gate: {label} answered results not bit-identical "
              "to the sequential loop", file=sys.stderr)
        errors += 1
    if row["steady_state_replicas"] != row["fixed_replicas"]:
        print(f"serving gate: {label} settled at "
              f"{row['steady_state_replicas']} replica(s), not the fixed "
              f"tier's {row['fixed_replicas']} — the shed comparison is "
              "only fair at equal steady-state capacity", file=sys.stderr)
        errors += 1
    if row["shed_rate_autoscaled"] >= row["shed_rate_fixed"]:
        print(f"serving gate: {label} autoscaling did not reduce shedding "
              f"(shed rate {row['shed_rate_autoscaled']:.4f} autoscaled vs "
              f"{row['shed_rate_fixed']:.4f} fixed on the same trace)",
              file=sys.stderr)
        errors += 1
    if row["scale_ups"] < 1:
        print(f"serving gate: {label} recorded no scale-up — the burst "
              "never triggered the control loop", file=sys.stderr)
        errors += 1
    if not (row["replicas_min"] <= row["min_replicas_seen"]
            <= row["max_replicas_seen"] <= row["replicas_max"]):
        print(f"serving gate: {label} replica count left the TierSpec "
              f"bounds: saw [{row['min_replicas_seen']}, "
              f"{row['max_replicas_seen']}] outside "
              f"[{row['replicas_min']}, {row['replicas_max']}]",
              file=sys.stderr)
        errors += 1
    return errors


def _check_chaos_row(row: dict, label: str) -> int:
    errors = 0
    missing = [k for k in CHAOS_ROW_KEYS if k not in row or row[k] is None]
    if missing:
        print(f"serving gate: {label} missing keys {missing}",
              file=sys.stderr)
        return errors + 1  # can't judge an incomplete row further
    if row["lost"] != 0:
        print(f"serving gate: {label} lost {row['lost']} result(s) — every "
              "request must resolve or be accounted (shed/deadline)",
              file=sys.stderr)
        errors += 1
    if row["reordered"] != 0:
        print(f"serving gate: {label} reordered {row['reordered']} "
              "result(s) across the stall failover", file=sys.stderr)
        errors += 1
    if row["bit_identical"] is not True:
        print(f"serving gate: {label} answered results not bit-identical "
              "to the sequential loop", file=sys.stderr)
        errors += 1
    if row["watchdog_stalls"] < 1:
        print(f"serving gate: {label} watchdog never detected the injected "
              "stuck scan", file=sys.stderr)
        errors += 1
    if row["revivals"] < 1:
        print(f"serving gate: {label} recorded no revival after the stall "
              "cleared", file=sys.stderr)
        errors += 1
    if row["shed_with_degradation"] >= row["shed_without_degradation"]:
        print(f"serving gate: {label} degradation did not reduce shedding "
              f"({row['shed_with_degradation']} with vs "
              f"{row['shed_without_degradation']} without at equal load)",
              file=sys.stderr)
        errors += 1
    return errors


def _check_swap_row(row: dict, label: str) -> int:
    errors = 0
    missing = [k for k in SWAP_ROW_KEYS if k not in row or row[k] is None]
    if missing:
        print(f"serving gate: {label} missing keys {missing}",
              file=sys.stderr)
        return errors + 1  # can't judge an incomplete row further
    if row["lost"] != 0:
        print(f"serving gate: {label} lost {row['lost']} result(s) during "
              "the rolling swap", file=sys.stderr)
        errors += 1
    if row["reordered"] != 0:
        print(f"serving gate: {label} reordered {row['reordered']} "
              "result(s) during the rolling swap", file=sys.stderr)
        errors += 1
    if row["bit_identical"] is not True:
        print(f"serving gate: {label} results not bit-identical to the "
              "sequential loop across the swap", file=sys.stderr)
        errors += 1
    if row["swapped_replicas"] != row["replicas"]:
        print(f"serving gate: {label} swapped only "
              f"{row['swapped_replicas']}/{row['replicas']} replicas",
              file=sys.stderr)
        errors += 1
    if row["revivals"] < 1:
        print(f"serving gate: {label} recorded no canary revival "
              "(re-probe must revive the injected transient fault)",
              file=sys.stderr)
        errors += 1
    return errors


def _check_replicated_schema(row: dict, label: str) -> int:
    """Hard-fail on any missing key in a replicated row (returns #errors)."""
    errors = 0
    missing = [k for k in REPLICATED_ROW_KEYS
               if k not in row or row[k] is None]
    if missing:
        print(f"serving gate: {label} missing keys {missing}",
              file=sys.stderr)
        errors += 1
    per = row.get("per_replica")
    if per is not None and not isinstance(per, list):
        # present-but-unparseable must fail, same as missing
        print(f"serving gate: {label} per_replica is "
              f"{type(per).__name__}, expected a list", file=sys.stderr)
        errors += 1
    elif isinstance(per, list):
        if isinstance(row.get("replicas"), int) and len(per) != row["replicas"]:
            print(f"serving gate: {label} per_replica has {len(per)} "
                  f"entries for replicas={row['replicas']}", file=sys.stderr)
            errors += 1
        for i, pr in enumerate(per):
            pr_missing = [k for k in PER_REPLICA_KEYS
                          if k not in pr or pr[k] is None]
            if pr_missing:
                print(f"serving gate: {label} per_replica[{i}] missing "
                      f"keys {pr_missing}", file=sys.stderr)
                errors += 1
    return errors


def check_serving(bench: dict, min_ratio: float,
                  min_replica_ratio: float,
                  min_upgrade_recall: float = 0.5) -> int:
    """Overlapped QPS >= min_ratio x sequential, replicated QPS >=
    min_replica_ratio x overlapped, replica-sweep schema complete,
    swap/chaos/upgrade correctness rows present and clean."""
    rows = bench.get("rows", [])
    qps = {r.get("mode"): r.get("qps") for r in rows
           if r.get("mode") in ("sequential", "overlapped")}
    seq, ovl = qps.get("sequential"), qps.get("overlapped")
    print("mode,replicas,qps")
    for r in rows:
        if "qps" not in r:
            continue  # lifecycle rows carry swap metrics, not throughput
        print(f"{r.get('mode')},{r.get('replicas', 1)},{r.get('qps')}")
    if seq is None or ovl is None:
        print("serving gate: need both a 'sequential' and an 'overlapped' "
              "row with qps", file=sys.stderr)
        return 1
    if seq <= 0:
        print(f"serving gate: bad sequential qps {seq}", file=sys.stderr)
        return 1
    failures = 0
    # Prefer the emitter's best paired-trial ratio (each trial runs the
    # two modes adjacently, so host-noise phases cancel; a genuinely
    # slower pipeline fails every trial); fall back to the best-of qps
    # ratio for reports that predate it.
    ovl_row = next(r for r in rows if r.get("mode") == "overlapped")
    ratio = ovl_row.get("qps_ratio_vs_sequential")
    if ratio is None:
        ratio = ovl / seq
    ok = ratio >= min_ratio
    print(f"overlapped/sequential,{ratio:.4f},limit>={min_ratio},"
          f"{'ok' if ok else 'FAIL'}")
    if not ok:
        print(f"serving gate: overlapped pipeline lost throughput "
              f"(ratio {ratio:.4f} < {min_ratio})", file=sys.stderr)
        failures += 1

    replicated = [r for r in rows if r.get("mode") == "replicated"]
    if not replicated:
        print("serving gate: no 'replicated' rows — the replica sweep "
              "must be emitted (launch/proxy.py tier)", file=sys.stderr)
        return 1
    swap_rows = [r for r in rows if r.get("mode") == "swap"]
    if not swap_rows:
        print("serving gate: no 'swap' row — the live index lifecycle "
              "(rolling swap + canary revival, launch/lifecycle.py) must "
              "be exercised and emitted", file=sys.stderr)
        return 1
    for r in swap_rows:
        label = f"swap row (index_kind={r.get('index_kind')})"
        failures += _check_swap_row(r, label)
        if "lost" in r:
            print(f"swap({r.get('index_kind')}),lost={r.get('lost')},"
                  f"reordered={r.get('reordered')},"
                  f"bit_identical={r.get('bit_identical')},"
                  f"revivals={r.get('revivals')}")
    chaos_rows = [r for r in rows if r.get("mode") == "chaos"]
    if not chaos_rows:
        print("serving gate: no 'chaos' row — the fault-injection drill "
              "(stuck scan + deadlines + degradation, launch/faults.py) "
              "must be exercised and emitted", file=sys.stderr)
        return 1
    for r in chaos_rows:
        failures += _check_chaos_row(r, "chaos row")
        if "lost" in r:
            print(f"chaos,lost={r.get('lost')},"
                  f"deadline_violations={r.get('deadline_violations')},"
                  f"stalls={r.get('watchdog_stalls')},"
                  f"revivals={r.get('revivals')},"
                  f"shed={r.get('shed_without_degradation')}->"
                  f"{r.get('shed_with_degradation')}")
    upgrade_rows = [r for r in rows if r.get("mode") == "upgrade"]
    if not upgrade_rows:
        print("serving gate: no 'upgrade' row — the live embedding-version "
              "migration (compat-gated rolling v1 -> v2 swap, version-aware "
              "serving tier) must be exercised and emitted", file=sys.stderr)
        return 1
    for r in upgrade_rows:
        label = (f"upgrade row ({r.get('from_version')} -> "
                 f"{r.get('to_version')})")
        failures += _check_upgrade_row(r, label, min_upgrade_recall)
        if "lost" in r:
            print(f"upgrade,lost={r.get('lost')},"
                  f"reordered={r.get('reordered')},"
                  f"bit_identical={r.get('bit_identical')},"
                  f"compat_dispatches={r.get('compat_dispatches')},"
                  f"recall_v1={r.get('recall_v1')},"
                  f"recall_v2={r.get('recall_v2')},"
                  f"final={r.get('final_versions')}")
    bg_rows = [r for r in rows if r.get("mode") == "bigranular_swap"]
    if not bg_rows:
        print("serving gate: no 'bigranular_swap' row — the tiered "
              "(coarse-scan + fine-rerank) serving drill must be exercised "
              "and emitted", file=sys.stderr)
        return 1
    for r in bg_rows:
        label = f"bigranular_swap row (index_kind={r.get('index_kind')})"
        failures += _check_swap_row(r, label)
        # the same correctness record as the plain swap, PLUS proof the
        # tier actually served the rerank path: every resolved ticket
        # must have carried reranked provenance.
        if r.get("reranked") is not True:
            print(f"serving gate: {label} reranked={r.get('reranked')} — "
                  "the tier did not serve every query through the "
                  "bi-granular rerank path", file=sys.stderr)
            failures += 1
        if "lost" in r:
            print(f"bigranular_swap,lost={r.get('lost')},"
                  f"reordered={r.get('reordered')},"
                  f"bit_identical={r.get('bit_identical')},"
                  f"reranked={r.get('reranked')}")
    autoscale_rows = [r for r in rows if r.get("mode") == "autoscale"]
    if not autoscale_rows:
        print("serving gate: no 'autoscale' row — the shed-pressure "
              "autoscaler drill (bursty trace, autoscaled vs fixed tier, "
              "launch/autoscale.py) must be exercised and emitted",
              file=sys.stderr)
        return 1
    for r in autoscale_rows:
        label = f"autoscale row (index_kind={r.get('index_kind')})"
        failures += _check_autoscale_row(r, label)
        if "lost" in r:
            print(f"autoscale,lost={r.get('lost')},"
                  f"reordered={r.get('reordered')},"
                  f"bit_identical={r.get('bit_identical')},"
                  f"shed_rate={r.get('shed_rate_fixed')}->"
                  f"{r.get('shed_rate_autoscaled')},"
                  f"replicas_seen=[{r.get('min_replicas_seen')},"
                  f"{r.get('max_replicas_seen')}],"
                  f"steady={r.get('steady_state_replicas')}")
    for r in replicated:
        label = f"replicated row (replicas={r.get('replicas')})"
        failures += _check_replicated_schema(r, label)
        if r.get("replicas") == 1:
            continue  # the baseline row gates nothing (ratio vs itself)
        # The gated ratio is the emitter's BEST per-interleaved-trial
        # ratio vs the replicas=1 run (same trial, same code path, so
        # host noise cancels; a genuine tier cost fails every paired
        # trial). The per-run median rides along in the row for the
        # perf record.
        rratio = r.get("qps_ratio_vs_single")
        if rratio is None:
            continue  # already counted by the schema check
        rok = rratio >= min_replica_ratio
        print(f"replicated(x{r.get('replicas')})/replicated(x1),{rratio:.4f},"
              f"limit>={min_replica_ratio},{'ok' if rok else 'FAIL'}")
        if not rok:
            print(f"serving gate: replicated tier lost throughput "
                  f"(paired-trial ratio {rratio:.4f} < {min_replica_ratio})",
                  file=sys.stderr)
            failures += 1
    return 1 if failures else 0


def check_bigranular(bench: dict, max_coarse_ratio: float) -> int:
    """Gate the coarse-scan + fine-rerank sweep (returns #failures).

    Three invariants per row: full schema, rerank recall >= the
    coarse-only recall it refines, and (at coarse_levels = levels // 2,
    the acceptance point) coarse bytes <= max_coarse_ratio x full-level
    bytes. The half-levels row must EXIST — a sweep that skips the
    gated operating point must not pass green.
    """
    section = bench.get("bigranular")
    if not section:
        print("bench gate: no 'bigranular' section — the coarse-scan + "
              "fine-rerank sweep must be emitted", file=sys.stderr)
        return 1
    levels = bench.get("levels")
    half = max(1, levels // 2) if isinstance(levels, int) else None
    failures = 0
    saw_half = False
    print("bigranular: coarse_levels,k_coarse,recall_rerank,recall_coarse,"
          "coarse_ratio,status")
    for i, r in enumerate(section):
        missing = [k for k in BIGRANULAR_ROW_KEYS
                   if k not in r or r[k] is None]
        if missing:
            print(f"bench gate: bigranular[{i}] missing keys {missing}",
                  file=sys.stderr)
            failures += 1
            continue
        errs = []
        if r["recall_rerank"] < r["recall_coarse"]:
            errs.append(f"rerank recall {r['recall_rerank']:.4f} below "
                        f"coarse-only recall {r['recall_coarse']:.4f}")
        full = r["full_bytes_scanned"]
        ratio = r["coarse_bytes_scanned"] / full if full > 0 else None
        if ratio is None:
            errs.append("bad full_bytes_scanned")
        elif half is not None and r["coarse_levels"] == half:
            saw_half = True
            if ratio > max_coarse_ratio:
                errs.append(f"coarse tier too large: {ratio:.4f} of "
                            f"full-level bytes > {max_coarse_ratio} at "
                            f"coarse_levels={half}")
        print(f"{r['coarse_levels']},{r['k_coarse']},"
              f"{r['recall_rerank']:.4f},{r['recall_coarse']:.4f},"
              f"{'?' if ratio is None else f'{ratio:.4f}'},"
              f"{'FAIL' if errs else 'ok'}")
        for e in errs:
            print(f"bench gate: bigranular[{i}] {e}", file=sys.stderr)
        failures += len(errs)
    if half is not None and not saw_half:
        print(f"bench gate: bigranular sweep has no row at "
              f"coarse_levels={half} (= levels // 2), the gated operating "
              "point", file=sys.stderr)
        failures += 1
    return failures


def check_bits_sweep(bench: dict, max_ratio: float) -> int:
    """Gate the bits-per-dimension sweep (returns #failures): schema,
    packed-byte invariant per level, and serialized index_bytes
    monotone nondecreasing in n_levels within each packed state."""
    section = bench.get("bits_sweep")
    if not section:
        print("bench gate: no 'bits_sweep' section — the bits-per-"
              "dimension sweep must be emitted", file=sys.stderr)
        return 1
    failures = 0
    by_state: dict = {}
    for i, r in enumerate(section):
        missing = [k for k in BITS_SWEEP_ROW_KEYS
                   if k not in r or r[k] is None]
        if missing:
            print(f"bench gate: bits_sweep[{i}] missing keys {missing}",
                  file=sys.stderr)
            failures += 1
            continue
        by_state.setdefault(bool(r["packed"]), {})[int(r["n_levels"])] = r
    print("bits_sweep: n_levels,packed_bytes,unpacked_bytes,ratio,status")
    for n in sorted(by_state.get(False, {})):
        pair = by_state.get(True, {}).get(n)
        if pair is None:
            print(f"bench gate: bits_sweep n_levels={n} has no packed row",
                  file=sys.stderr)
            failures += 1
            continue
        p, u = pair["bytes_scanned"], by_state[False][n]["bytes_scanned"]
        if u <= 0:
            print(f"bench gate: bits_sweep n_levels={n} bad bytes",
                  file=sys.stderr)
            failures += 1
            continue
        ratio = p / u
        ok = ratio <= max_ratio
        print(f"{n},{p},{u},{ratio:.4f},{'ok' if ok else 'FAIL'}")
        if not ok:
            print(f"bench gate: bits_sweep n_levels={n} packed scan bytes "
                  f"ratio {ratio:.4f} > {max_ratio}", file=sys.stderr)
            failures += 1
    for packed, rows in sorted(by_state.items()):
        ns = sorted(rows)
        for a, b in zip(ns, ns[1:]):
            if rows[b]["index_bytes"] < rows[a]["index_bytes"]:
                print(f"bench gate: bits_sweep index_bytes not monotone in "
                      f"n_levels (packed={packed}): {rows[b]['index_bytes']} "
                      f"at {b} levels < {rows[a]['index_bytes']} at {a}",
                      file=sys.stderr)
                failures += 1
    return failures


def check_autotune(bench: dict, max_autotune_ratio: float) -> int:
    """Gate the block-plan autotuner record (returns #failures): schema,
    every kernel kind present, and the tuned plan never losing to the
    default in the tuner's own paired sweep (ratio <= max ratio; a
    swept kind with no ratio is a hard fail — a tuner that cannot show
    its timings must not pass green)."""
    section = bench.get("autotune")
    if not section:
        print("bench gate: no 'autotune' section — the block-plan "
              "autotuner record must be emitted", file=sys.stderr)
        return 1
    failures = 0
    seen = set()
    print("autotune: kind,default,tuned,ratio,limit,status")
    for i, r in enumerate(section):
        missing = [k for k in AUTOTUNE_ROW_KEYS if k not in r or r[k] is None]
        if missing:
            print(f"bench gate: autotune[{i}] missing keys {missing}",
                  file=sys.stderr)
            failures += 1
            continue
        seen.add(r["kind"])
        ratio = r.get("ms_ratio_tuned_vs_default")
        if ratio is None:
            print(f"bench gate: autotune[{i}] (kind={r['kind']}) has no "
                  "tuned-vs-default timing ratio — the sweep must time the "
                  "default as a candidate", file=sys.stderr)
            failures += 1
            continue
        ok = ratio <= max_autotune_ratio + 1e-9
        print(f"{r['kind']},({r['block_q_default']},{r['block_n_default']}),"
              f"({r['block_q']},{r['block_n']}),{ratio:.4f},"
              f"<={max_autotune_ratio},{'ok' if ok else 'FAIL'}")
        if not ok:
            print(f"bench gate: autotune kind={r['kind']} tuned plan LOST "
                  f"to the default in its own paired sweep (ratio "
                  f"{ratio:.4f} > {max_autotune_ratio})", file=sys.stderr)
            failures += 1
    absent = [k for k in AUTOTUNE_KINDS if k not in seen]
    if absent:
        print(f"bench gate: autotune section missing kernel kind(s) "
              f"{absent}", file=sys.stderr)
        failures += 1
    return failures


def check_probe_budget(bench: dict) -> int:
    """Gate the occupancy-weighted probe-budget sweep (returns
    #failures): schema, weighted recall >= flat recall at every budget,
    and the exact-multiple parity row present with bit_identical true."""
    section = bench.get("probe_budget")
    if not section:
        print("bench gate: no 'probe_budget' section — the occupancy-"
              "weighted probe allocation sweep must be emitted",
              file=sys.stderr)
        return 1
    nlist, nprobe = bench.get("nlist"), bench.get("nprobe")
    parity = (nprobe * nlist
              if isinstance(nlist, int) and isinstance(nprobe, int) else None)
    failures = 0
    saw_parity = False
    print("probe_budget: budget,recall_weighted,recall_flat,status")
    for i, r in enumerate(section):
        missing = [k for k in PROBE_BUDGET_ROW_KEYS
                   if k not in r or r[k] is None]
        if missing:
            print(f"bench gate: probe_budget[{i}] missing keys {missing}",
                  file=sys.stderr)
            failures += 1
            continue
        errs = []
        if r["recall_weighted"] < r["recall_flat"] - 1e-9:
            errs.append(f"weighted recall {r['recall_weighted']:.4f} below "
                        f"flat recall {r['recall_flat']:.4f} at equal "
                        f"budget {r['probe_budget']}")
        if parity is not None and r["probe_budget"] == parity:
            saw_parity = True
            if r.get("bit_identical") is not True:
                errs.append(f"parity row (budget={parity} = nprobe*nlist) "
                            "not bit-identical to the flat-nprobe search")
        print(f"{r['probe_budget']},{r['recall_weighted']:.4f},"
              f"{r['recall_flat']:.4f},{'FAIL' if errs else 'ok'}")
        for e in errs:
            print(f"bench gate: probe_budget[{i}] {e}", file=sys.stderr)
        failures += len(errs)
    if parity is not None and not saw_parity:
        print(f"bench gate: probe_budget sweep has no parity row at "
              f"budget={parity} (= nprobe * nlist), the bit-identity "
              "operating point", file=sys.stderr)
        failures += 1
    return failures


def check(bench: dict, max_ratio: float, max_coarse_ratio: float = 0.6,
          max_autotune_ratio: float = 1.0) -> int:
    rows = bench.get("rows", [])
    by_variant: dict = {}
    for r in rows:
        variant = r.get("variant", bench.get("bench", "default"))
        by_variant.setdefault(variant, {})[bool(r["packed"])] = r

    if not by_variant:
        print("bench gate: no rows found in benchmark JSON", file=sys.stderr)
        return 1

    failures = 0
    print("variant,packed_bytes,unpacked_bytes,ratio,limit,status")
    for variant, pair in sorted(by_variant.items()):
        if True not in pair or False not in pair:
            print(f"{variant},?,?,?,{max_ratio},MISSING-PAIR")
            failures += 1
            continue
        p, u = _row_bytes(pair[True]), _row_bytes(pair[False])
        if p is None or u is None or u <= 0:
            print(f"{variant},{p},{u},?,{max_ratio},BAD-BYTES")
            failures += 1
            continue
        ratio = p / u
        ok = ratio <= max_ratio
        print(f"{variant},{p},{u},{ratio:.4f},{max_ratio},"
              f"{'ok' if ok else 'FAIL'}")
        if not ok:
            failures += 1
    if failures:
        print(f"bench gate: {failures} variant(s) violate the packed-byte "
              f"invariant (ratio <= {max_ratio})", file=sys.stderr)
    # The bi-granular, bits-per-dimension, autotune and probe-budget
    # sections ride on the scan bench specifically; BENCH_hnsw_scan.json
    # flows through the same pairing logic above but carries none of them.
    if bench.get("bench") == "sdc_scan":
        failures += check_bigranular(bench, max_coarse_ratio)
        failures += check_bits_sweep(bench, max_ratio)
        failures += check_autotune(bench, max_autotune_ratio)
        failures += check_probe_budget(bench)
    return 1 if failures else 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("bench_json", help="path to BENCH_sdc_scan.json")
    ap.add_argument("--max-packed-ratio", type=float, default=0.55,
                    help="max allowed packed/unpacked bytes_scanned ratio")
    ap.add_argument("--max-coarse-ratio", type=float, default=0.6,
                    help="max allowed coarse/full-level bytes ratio for the "
                         "bigranular sweep at coarse_levels = levels // 2 "
                         "(BENCH_sdc_scan.json only: half the levels plus "
                         "per-doc metadata packing cannot shrink)")
    ap.add_argument("--max-autotune-ratio", type=float, default=1.0,
                    help="max allowed tuned/default ms ratio in the "
                         "autotune section (BENCH_sdc_scan.json only; the "
                         "sweep times the default as a candidate, so the "
                         "tuned plan can never honestly lose — default 1.0)")
    ap.add_argument("--min-serving-ratio", type=float, default=1.0,
                    help="min allowed overlapped/sequential QPS ratio "
                         "(BENCH_serving.json only)")
    ap.add_argument("--min-replica-ratio", type=float, default=0.9,
                    help="min allowed replicated(N>1)/replicated(1) paired "
                         "QPS ratio (BENCH_serving.json replica sweep; "
                         "< 1.0 because a shared-core host cannot scale "
                         "with replicas, but the router must not cost "
                         "throughput)")
    ap.add_argument("--min-upgrade-recall", type=float, default=0.5,
                    help="floor for the upgrade row's own recall_floor: "
                         "per-version recall over the live migration is "
                         "gated at max(row recall_floor, this), so an "
                         "emitter cannot pass by shipping a zero floor")
    args = ap.parse_args()
    with open(args.bench_json) as f:
        bench = json.load(f)
    if bench.get("bench") == "serving":
        return check_serving(bench, args.min_serving_ratio,
                             args.min_replica_ratio,
                             args.min_upgrade_recall)
    return check(bench, args.max_packed_ratio, args.max_coarse_ratio,
                 args.max_autotune_ratio)


if __name__ == "__main__":
    sys.exit(main())
