#!/usr/bin/env python
"""CI bench gate: fail when the packed-scan byte invariant regresses.

ROADMAP invariant: int4 nibble-packed code streaming must keep scan bytes
at <= 0.55x the unpacked scan for every engine variant (0.5x codes + small
per-doc metadata that packing cannot shrink). A PR that silently widens
the packed layout, forgets to pack a new scan path, or inflates per-doc
metadata shows up here as a ratio creep past the threshold.

    python scripts/check_bench_gate.py BENCH_sdc_scan.json \
        [--max-packed-ratio 0.55]

Reads the ``rows`` emitted by ``benchmarks/run.py --only bench_sdc_scan``
(each row: variant, packed, bytes_scanned), pairs packed/unpacked rows per
variant, and exits non-zero if any ratio exceeds the threshold — or if a
variant is missing one side of the pair (a gate that can't see the packed
row must not pass green).

Also understands ``BENCH_hnsw_scan.json`` (rows keyed by ``packed`` only,
bytes in ``table_bytes`` — the device footprint of the neighbor-block
tables), so the graph-search tables are held to the same invariant.

``BENCH_serving.json`` (rows keyed by ``mode``) is gated differently:
the double-buffered pipeline must not lose throughput to the sequential
encode+scan loop it replaced — overlapped QPS >= --min-serving-ratio x
sequential QPS (default 1.0). Both rows must be present; the emitter
reports best-of-N interleaved runs, so the ratio is not noise-driven.
"""

from __future__ import annotations

import argparse
import json
import sys


def _row_bytes(row: dict):
    return row.get("bytes_scanned", row.get("table_bytes"))


def check_serving(bench: dict, min_ratio: float) -> int:
    """Overlapped pipeline QPS must be >= min_ratio x sequential QPS."""
    qps = {r.get("mode"): r.get("qps") for r in bench.get("rows", [])}
    seq, ovl = qps.get("sequential"), qps.get("overlapped")
    print("mode,qps")
    for mode, q in sorted(qps.items(), key=lambda kv: str(kv[0])):
        print(f"{mode},{q}")
    if seq is None or ovl is None:
        print("serving gate: need both a 'sequential' and an 'overlapped' "
              "row with qps", file=sys.stderr)
        return 1
    if seq <= 0:
        print(f"serving gate: bad sequential qps {seq}", file=sys.stderr)
        return 1
    ratio = ovl / seq
    ok = ratio >= min_ratio
    print(f"overlapped/sequential,{ratio:.4f},limit>={min_ratio},"
          f"{'ok' if ok else 'FAIL'}")
    if not ok:
        print(f"serving gate: overlapped pipeline lost throughput "
              f"(ratio {ratio:.4f} < {min_ratio})", file=sys.stderr)
        return 1
    return 0


def check(bench: dict, max_ratio: float) -> int:
    rows = bench.get("rows", [])
    by_variant: dict = {}
    for r in rows:
        variant = r.get("variant", bench.get("bench", "default"))
        by_variant.setdefault(variant, {})[bool(r["packed"])] = r

    if not by_variant:
        print("bench gate: no rows found in benchmark JSON", file=sys.stderr)
        return 1

    failures = 0
    print("variant,packed_bytes,unpacked_bytes,ratio,limit,status")
    for variant, pair in sorted(by_variant.items()):
        if True not in pair or False not in pair:
            print(f"{variant},?,?,?,{max_ratio},MISSING-PAIR")
            failures += 1
            continue
        p, u = _row_bytes(pair[True]), _row_bytes(pair[False])
        if p is None or u is None or u <= 0:
            print(f"{variant},{p},{u},?,{max_ratio},BAD-BYTES")
            failures += 1
            continue
        ratio = p / u
        ok = ratio <= max_ratio
        print(f"{variant},{p},{u},{ratio:.4f},{max_ratio},"
              f"{'ok' if ok else 'FAIL'}")
        if not ok:
            failures += 1
    if failures:
        print(f"bench gate: {failures} variant(s) violate the packed-byte "
              f"invariant (ratio <= {max_ratio})", file=sys.stderr)
    return 1 if failures else 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("bench_json", help="path to BENCH_sdc_scan.json")
    ap.add_argument("--max-packed-ratio", type=float, default=0.55,
                    help="max allowed packed/unpacked bytes_scanned ratio")
    ap.add_argument("--min-serving-ratio", type=float, default=1.0,
                    help="min allowed overlapped/sequential QPS ratio "
                         "(BENCH_serving.json only)")
    args = ap.parse_args()
    with open(args.bench_json) as f:
        bench = json.load(f)
    if bench.get("bench") == "serving":
        return check_serving(bench, args.min_serving_ratio)
    return check(bench, args.max_packed_ratio)


if __name__ == "__main__":
    sys.exit(main())
