"""Distributed BEBR serving demo (paper Figure 5: proxy -> leaf -> merge).

    PYTHONPATH=src python examples/serve_bebr.py

Forces 8 host devices, shards a binary index across them as "leaves",
broadcasts query batches, and merges per-leaf top-k — the same shard_map
program the 512-chip dry-run compiles, at laptop scale. Compares against
the exact single-host search and reports agreement + index bytes.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BinarizerConfig, binarize_lib, init_binarizer, pack_codes
from repro.data.synthetic import clustered_corpus
from repro.index.engine import engine_input_shardings, make_distributed_search
from repro.kernels.sdc import ref as R


def main():
    dim, code, levels = 128, 64, 4
    docs, queries, gt = clustered_corpus(0, 100_000, 64, dim, n_clusters=256)

    # binarize (random-projection binarizer is enough for the demo)
    bcfg = BinarizerConfig(input_dim=dim, code_dim=code, n_levels=levels,
                           hidden_dim=0)
    p, s = init_binarizer(jax.random.PRNGKey(0), bcfg)
    enc = lambda e: pack_codes(binarize_lib.binarize(
        p, s, jnp.asarray(e), bcfg)[0])
    d_codes, q_codes = enc(docs), enc(queries)
    inv = R.doc_inv_norms(d_codes, levels)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    print(f"mesh: {mesh.shape} — index of {d_codes.shape[0]} codes sharded "
          f"over {mesh.devices.size} leaves")
    search = make_distributed_search(mesh, n_levels=levels, k=10)

    with mesh:
        qs, ds, vs = engine_input_shardings(mesh)
        qd = jax.device_put(q_codes, qs)
        dd = jax.device_put(d_codes, ds)
        vd = jax.device_put(inv, vs)
        # warm up + time
        jax.block_until_ready(search(qd, dd, vd))
        t0 = time.time()
        vals, ids = search(qd, dd, vd)
        jax.block_until_ready(vals)
        dt = time.time() - t0

    ev, ei = jax.lax.top_k(R.sdc_ref(q_codes, d_codes, levels), 10)
    agree = np.mean([
        len(set(np.asarray(ids[i]).tolist()) & set(np.asarray(ei[i]).tolist())) / 10
        for i in range(q_codes.shape[0])
    ])
    recall = float(jnp.mean(jnp.any(ids == jnp.asarray(gt)[:, None], -1)))
    print(f"leaf/merge top-10 vs exact agreement: {agree:.3f}")
    print(f"ground-truth recall@10: {recall:.3f}")
    print(f"batch of {q_codes.shape[0]} queries in {1e3*dt:.1f} ms "
          f"({q_codes.shape[0]/dt:.0f} QPS on 8 host-CPU leaves)")
    packed = (code * levels + 7) // 8 + 4
    print(f"index bytes: {d_codes.shape[0]*packed/2**20:.1f} MiB vs "
          f"float {docs.nbytes/2**20:.1f} MiB")


if __name__ == "__main__":
    main()
