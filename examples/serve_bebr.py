"""Distributed BEBR serving demo (paper Figure 5: proxy -> leaf -> merge).

    PYTHONPATH=src python examples/serve_bebr.py [--index flat|hnsw]

Forces 8 host devices, shards a binary index across them as "leaves",
broadcasts query batches, and merges per-leaf top-k — the same shard_map
program the 512-chip dry-run compiles, at laptop scale. Compares against
the exact single-host search and reports agreement + index bytes.

``--index hnsw`` swaps the exhaustive leaf scan for the batched-frontier
graph search: one NSW graph per leaf (host-side build), each leaf walking
its graph through the gather-then-scan kernel substrate, merged by the
identical proxy. The corpus shrinks to 16k docs because the NSW build is
host-side O(N^2) — the *search* program is the production one.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BinarizerConfig, binarize_lib, init_binarizer, pack_codes
from repro.data.synthetic import clustered_corpus
from repro.index.engine import (
    engine_input_shardings,
    hnsw_engine_inputs,
    hnsw_engine_shardings,
    make_distributed_search,
    make_hnsw_search,
)
from repro.index.hnsw_lite import build_hnsw_sharded
from repro.kernels.sdc import ref as R
from repro.launch import serving


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--index", choices=["flat", "hnsw"], default="flat")
    args = ap.parse_args()

    dim, code, levels = 128, 64, 4
    n_docs = 100_000 if args.index == "flat" else 16_000
    docs, queries, gt = clustered_corpus(0, n_docs, 64, dim, n_clusters=256)

    # binarize (random-projection binarizer is enough for the demo)
    bcfg = BinarizerConfig(input_dim=dim, code_dim=code, n_levels=levels,
                           hidden_dim=0)
    p, s = init_binarizer(jax.random.PRNGKey(0), bcfg)
    enc = lambda e: pack_codes(binarize_lib.binarize(
        p, s, jnp.asarray(e), bcfg)[0])
    d_codes, q_codes = enc(docs), enc(queries)
    inv = R.doc_inv_norms(d_codes, levels)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    print(f"mesh: {mesh.shape} — {args.index} index of {d_codes.shape[0]} "
          f"codes sharded over {mesh.devices.size} leaves")

    if args.index == "hnsw":
        # one NSW graph per leaf; the proxy merge is unchanged
        sharded = build_hnsw_sharded(
            np.asarray(d_codes), np.asarray(inv), n_leaves=8,
            n_levels=levels, M=16, ef_construction=48,
        )
        search = make_hnsw_search(mesh, n_levels=levels, k=10, ef=64, beam=16)
        qspec, *in_specs = hnsw_engine_shardings(mesh)
        inputs = hnsw_engine_inputs(sharded)
    else:
        search = make_distributed_search(mesh, n_levels=levels, k=10)
        qspec, *in_specs = engine_input_shardings(mesh)
        inputs = (d_codes, inv)

    with mesh:
        ins = [jax.device_put(a, s) for a, s in zip(inputs, in_specs)]

        # One ServingPipeline fronts the distributed engine exactly like a
        # single-host index: encode binarizes the float queries on the
        # host (jit'd — the eager path would fight the leaf scan for the
        # GIL), the SearchFn closure broadcasts them to the leaves.
        enc_jit = jax.jit(lambda e: pack_codes(binarize_lib.binarize(
            p, s, e, bcfg)[0]))
        encode = lambda e: jax.device_put(enc_jit(jnp.asarray(e)), qspec)
        search_one = lambda q: search(q, *ins)

        batch = 16
        batches = [queries[i:i + batch]
                   for i in range(0, queries.shape[0], batch)]
        # Compile the encode + engine programs for both drivers outside
        # the timed region (serving.warmup also covers the pipeline's
        # worker threads, whose thread-local jit context doesn't see the
        # mesh scope above).
        serving.warmup(encode, search_one, batches)

        rounds = 4
        stream = batches * rounds
        t0 = time.time()
        serving.serve_sequential(encode, search_one, stream)
        dt_seq = time.time() - t0
        t0 = time.time()
        results, stats = serving.serve_batches(encode, search_one, stream)
        dt = time.time() - t0
        ids = jnp.concatenate([i for _, i in results[: len(batches)]], 0)

    ev, ei = jax.lax.top_k(R.sdc_ref(q_codes, d_codes, levels), 10)
    agree = np.mean([
        len(set(np.asarray(ids[i]).tolist()) & set(np.asarray(ei[i]).tolist())) / 10
        for i in range(q_codes.shape[0])
    ])
    recall = float(jnp.mean(jnp.any(ids == jnp.asarray(gt)[:, None], -1)))
    n_q = queries.shape[0] * rounds
    print(f"leaf/merge top-10 vs exact agreement: {agree:.3f}")
    print(f"ground-truth recall@10: {recall:.3f}")
    print(f"sequential: {n_q/dt_seq:.0f} QPS | pipelined: {n_q/dt:.0f} QPS "
          f"on 8 host-CPU leaves (p50 {stats['latency_p50_ms']:.1f} ms, "
          f"p99 {stats['latency_p99_ms']:.1f} ms, device idle "
          f"{100*stats['device_idle_frac']:.0f}%)")
    packed = (code * levels + 7) // 8 + 4
    print(f"index bytes: {d_codes.shape[0]*packed/2**20:.1f} MiB vs "
          f"float {docs.nbytes/2**20:.1f} MiB")


if __name__ == "__main__":
    main()
