"""Distributed BEBR serving demo (paper Figure 5: proxy -> leaf -> merge).

    PYTHONPATH=src python examples/serve_bebr.py [--index flat|hnsw]
                                                 [--replicas N] [--router P]

Forces 8 host devices and carves them into ``--replicas`` disjoint
submeshes (``mesh.make_replica_meshes``). Each replica shards the whole
binary index over its own leaves and runs the same shard_map
proxy/leaf/merge program the 512-chip dry-run compiles; a ``QueryRouter``
(``launch/proxy.py``) spreads query batches across the replicas —
admission queue -> router -> replica pipelines -> engine leaves, the full
serving tier at laptop scale. Compares against the exact single-host
search and reports agreement + index bytes.

``--index hnsw`` swaps the exhaustive leaf scan for the batched-frontier
graph search: one NSW graph per leaf (host-side build), each leaf walking
its graph through the gather-then-scan kernel substrate, merged by the
identical proxy. The corpus shrinks to 16k docs because the NSW build is
host-side O(N^2) — the *search* program is the production one.
"""

import os

N_DEVICES = 8  # forced host devices; the --replicas submeshes split these

os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={N_DEVICES} "
    + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BinarizerConfig, TrainConfig, binarize_lib
import repro.core.losses as losses_lib
from repro.data.synthetic import clustered_corpus
from repro.kernels.sdc import ref as R
from repro.launch import (
    autoscale,
    binarizer_cache,
    faults,
    lifecycle,
    proxy,
    serving,
)
from repro.launch.mesh import make_replica_meshes
from repro.train import optim


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--index", choices=["flat", "hnsw"], default="flat")
    ap.add_argument("--replicas", type=int, default=2,
                    help="engine replicas; the 8 host devices are split "
                         "into this many disjoint submeshes")
    ap.add_argument("--router", choices=sorted(proxy.ROUTING_POLICIES),
                    default="round-robin", help="replica routing policy")
    ap.add_argument("--tier-spec", default=None, metavar="SPEC.json",
                    help="declarative tier spec (launch/autoscale.py): "
                         "starts the tier at min_replicas and runs the "
                         "shed-pressure autoscaler over the stream. The "
                         "8 host devices are carved into max_replicas "
                         "submeshes up front, so every replica the "
                         "autoscaler may ever add already owns its "
                         "devices; scale-ups build the engine program on "
                         "submesh i via builder.build(snapshot, "
                         "replica=i). Overrides --replicas/--router")
    ap.add_argument("--steps", type=int, default=150,
                    help="binarizer training steps (first run only; the "
                         "checkpoint is cached under a content digest)")
    ap.add_argument("--ckpt-cache", default=None, metavar="DIR",
                    help="binarizer checkpoint cache dir (default: "
                         "$REPRO_BEBR_CACHE, else ~/.cache/repro-bebr)")
    ap.add_argument("--autotune", action="store_true",
                    help="sweep (block_q, block_n) launch shapes for the "
                         "per-leaf scan (and bi-granular rerank) on the "
                         "live shard sizes before serving; winners "
                         "persist in the tune cache "
                         "($REPRO_BEBR_CACHE), so every replica and "
                         "later launch shares one plan; bit-identical "
                         "scores either way (launch/autotune.py)")
    ap.add_argument("--coarse-levels", type=int, default=0, metavar="C",
                    help="bi-granular engine (flat only): per-leaf coarse "
                         "scan over the first C levels, post-merge "
                         "full-level rerank of --k-coarse survivors; "
                         "0 disables")
    ap.add_argument("--k-coarse", type=int, default=0, metavar="K'",
                    help="bi-granular engine: survivors rescored at full "
                         "depth; 0 disables (set with --coarse-levels)")
    ap.add_argument("--swap-after", type=int, default=0, metavar="N",
                    help="after N routed batches, rolling-swap every "
                         "replica's index from a fresh corpus snapshot "
                         "(drain -> rebuild on its submesh -> warm -> "
                         "canary re-probe) under the live stream; "
                         "0 disables")
    ap.add_argument("--probe-every", type=float, default=0.0, metavar="S",
                    help="period (s) of the router's canary health "
                         "re-probe; revives unhealthy replicas; 0 off")
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="deterministic fault injection on the replica "
                         "fns (launch/faults.py grammar), e.g. "
                         "'r0.search.fail@3' — pair with --probe-every "
                         "to watch failover + revival on the sharded "
                         "tier")
    args = ap.parse_args()
    spec = None
    if args.tier_spec:
        try:
            spec = autoscale.TierSpec.from_file(args.tier_spec)
        except autoscale.InvalidTierSpec as e:
            ap.error(f"--tier-spec: {e}")
        args.replicas = spec.min_replicas
        args.router = spec.router
    # The submesh carve is sized for the LARGEST tier the spec allows:
    # scale-up must only instantiate a program on an already-reserved
    # submesh, never re-partition live devices.
    n_slots = spec.max_replicas if spec is not None else args.replicas
    if N_DEVICES % n_slots:
        ap.error(f"replica slots ({n_slots}) must divide {N_DEVICES}")
    if bool(args.coarse_levels) != bool(args.k_coarse):
        ap.error("--coarse-levels and --k-coarse must be set together")
    if args.coarse_levels and args.index != "flat":
        ap.error("--coarse-levels requires --index flat (per-leaf coarse "
                 "scan + post-merge rerank)")
    per = N_DEVICES // n_slots
    shape = (per // 2, 2) if per % 2 == 0 else (per, 1)

    dim, code, levels = 128, 64, 4
    n_docs = 100_000 if args.index == "flat" else 16_000
    docs, queries, gt = clustered_corpus(0, n_docs, 64, dim, n_clusters=256)

    # binarize: a real (small) recurrent-MLP binarizer, trained emb2emb
    # on the corpus and checkpointed under a content digest — only the
    # first launch pays for training; later runs reload the weights
    # (launch/binarizer_cache.py). The old hidden_dim=0 shortcut (an
    # untrained random projection) skipped training but gave away the
    # recall the recurrent residual levels exist to recover.
    bcfg = BinarizerConfig(input_dim=dim, code_dim=code, n_levels=levels,
                           hidden_dim=2 * dim)
    tcfg = TrainConfig(
        binarizer=bcfg,
        queue=losses_lib.QueueConfig(length=2048, dim=code, top_k=32),
        adam=optim.AdamConfig(lr=2e-3, clip_norm=5.0),
    )
    t0 = time.time()
    ckpt = binarizer_cache.trained_binarizer(
        docs, tcfg, steps=args.steps, seed=0, cache_dir=args.ckpt_cache
    )
    verb = "trained" if ckpt.trained else "loaded cached"
    print(f"binarizer: {verb} checkpoint {ckpt.digest} in "
          f"{time.time() - t0:.1f}s (hidden={bcfg.hidden_dim}, "
          f"{args.steps} steps)")
    enc = binarize_lib.make_encode_fn(ckpt.params, ckpt.bn_state, bcfg)
    d_codes, q_codes = enc(docs), enc(queries)

    meshes = make_replica_meshes(n_slots, shape=shape)
    print(f"replica submeshes: {n_slots} x {dict(meshes[0].shape)} — "
          f"{args.index} index of {d_codes.shape[0]} codes sharded over "
          f"{per} leaves per replica, router={args.router}"
          + (f" (serving {args.replicas}, autoscaling up to {n_slots})"
             if spec is not None else ""))

    # jit'd per-batch encode, shared across replicas: the eager path
    # would fight the leaf scans for the GIL. Query device placement
    # happens inside each replica's search closure (the builder emits
    # submesh-aware SearchFns).
    encode = enc

    # The same builder serves the initial tier AND the rolling swap: each
    # replica's index is `builder.build(snapshot, replica=i)` — the
    # shard_map program over ITS submesh, closed over its device-placed
    # corpus shards. For hnsw the host-side sharded graph is built once
    # per snapshot digest and shared across replicas (same leaf layout).
    snapshot = lifecycle.CorpusSnapshot(codes=np.asarray(d_codes),
                                        n_levels=levels)
    # Tuned launch shapes for the per-leaf scan (and the post-merge
    # rerank in bi-granular mode), keyed on the PER-LEAF shard size —
    # that is the corpus each kernel launch actually sees. Plans never
    # change scores; the agreement check below holds either way.
    block_plan = None
    if args.autotune:
        from repro.launch import autotune

        n_shard = -(-d_codes.shape[0] // per)  # rows per leaf, padded up
        block_plan = {}
        for kind in ("scan", "rerank"):
            tp = autotune.tuned_block_plan(
                kind, code_dim=code, n_shard=n_shard,
                k=(args.k_coarse or 10), n_levels=levels,
            )
            block_plan[kind] = tp.plan
            print(f"tune {kind}: block_q={tp.plan.block_q} "
                  f"block_n={tp.plan.block_n} ({tp.plan.source})")
    builder = lifecycle.EngineBuilder(
        meshes, index=args.index, n_levels=levels, k=10,
        M=16, ef_construction=48, ef=64, beam=16,
        coarse_levels=args.coarse_levels or None,
        k_coarse=args.k_coarse or None,
        block_plan=block_plan,
    )
    replica_fns = [(encode, builder.build(snapshot, replica=i))
                   for i in range(args.replicas)]

    batch = 16
    batches = [queries[i:i + batch]
               for i in range(0, queries.shape[0], batch)]
    # Compile every replica's encode + engine program for both drivers
    # outside the timed region (see warmup_replicas: worker threads
    # carry thread-local jit caches, ragged tails are their own shape).
    serving.warmup_replicas(replica_fns, batches)

    rounds = 4
    stream = batches * rounds
    enc0, search0 = replica_fns[0]
    t0 = time.time()
    serving.serve_sequential(enc0, search0, stream)
    dt_seq = time.time() - t0
    # Chaos wrapping AFTER warmup and the sequential baseline: the fault
    # schedule is a function of the call index, so earlier traffic must
    # not consume it — and the faults target the ROUTED tier, not the
    # un-routed reference leg.
    replica_fns, injectors = faults.apply_chaos(replica_fns, args.chaos)
    t0 = time.time()
    # share_device stays False: the submeshes model disjoint production
    # hardware (where replica scans genuinely run in parallel). The 8
    # forced host "devices" actually share this machine's cores, so the
    # demo's QPS numbers carry that contention — agreement, routing,
    # failover and rolling-swap semantics are what this example
    # demonstrates. The router is driven directly (rather than through
    # serve_replicated) so a mid-stream rolling swap / canary probe can
    # run against the live tier.
    router = proxy.QueryRouter(
        proxy.ReplicaSet(replica_fns, config=serving.ServingConfig()),
        policy=args.router,
    )
    controller = None
    if args.swap_after:
        controller = lifecycle.RollingSwapController(
            router, builder, warm_batches=batches[:1], encode_fn=encode
        )
    if args.probe_every:
        router.start_health_probe(batches[0], interval=args.probe_every)
    scaler = None
    if spec is not None:
        # Engine tiers hand the autoscaler a replica factory instead of
        # (snapshot, encode_fn): slot i's search closure is the shard_map
        # program over submesh i, built by the SAME EngineBuilder the
        # rolling swap uses.
        scaler = autoscale.Autoscaler(
            router, spec,
            replica_factory=lambda slot: (
                encode, builder.build(snapshot, replica=slot)
            ),
            warm_batches=batches[:1],
            on_event=lambda msg: print(f"autoscale: {msg}"),
        )
        scaler.start()
    results, swap_report = lifecycle.run_stream_with_swap(
        router, stream, controller=controller, snapshot=snapshot,
        swap_after=args.swap_after,
    )
    if scaler is not None:
        scaler.stop()
    for inj in injectors.values():
        inj.release()  # a still-stuck scan would wedge close()'s joins
    router.close()
    stats = router.stats()
    dt = time.time() - t0
    # host-side concat: replica results live on disjoint device sets
    ids = np.concatenate([np.asarray(i) for _, i in results[: len(batches)]], 0)

    ev, ei = jax.lax.top_k(R.sdc_ref(q_codes, d_codes, levels), 10)
    agree = np.mean([
        len(set(np.asarray(ids[i]).tolist()) & set(np.asarray(ei[i]).tolist())) / 10
        for i in range(q_codes.shape[0])
    ])
    recall = float(jnp.mean(jnp.any(ids == jnp.asarray(gt)[:, None], -1)))
    n_q = queries.shape[0] * rounds
    print(f"leaf/merge top-10 vs exact agreement: {agree:.3f}")
    print(f"ground-truth recall@10: {recall:.3f}")
    print(f"sequential (1 replica): {n_q/dt_seq:.0f} QPS | routed "
          f"({args.replicas} replicas): {n_q/dt:.0f} QPS on {N_DEVICES} "
          f"host-CPU leaves (p50 {stats['latency_p50_ms']:.1f} ms, "
          f"p99 {stats['latency_p99_ms']:.1f} ms, device idle "
          f"{100*stats['device_idle_frac']:.0f}%)")
    for srep in stats["per_replica"]:
        print(f"  replica {srep['replica']}: {srep['requests']} req "
              f"({srep['queries']} queries), device idle "
              f"{100*srep['device_idle_frac']:.0f}%, "
              f"generation {srep['generation']}")
    if swap_report is not None:
        rep = swap_report
        print(f"rolling swap -> {rep.version.tag}: {rep.swapped} replica(s) "
              f"re-indexed under the live stream in {rep.total_s*1e3:.0f} ms")
        for row in rep.replicas:
            print(f"  replica {row['replica']}: drain {row['drain_s']*1e3:.0f}"
                  f" ms, build {row['build_s']*1e3:.0f} ms, warm "
                  f"{row['warm_s']*1e3:.0f} ms, probe {row['probe_s']*1e3:.0f}"
                  f" ms")
    if args.probe_every:
        print(f"canary re-probe every {args.probe_every}s: "
              f"{stats['revivals']} revival(s)")
    if scaler is not None:
        sm = scaler.summary()
        print(f"autoscale [{sm['replicas_min']}, {sm['replicas_max']}]: "
              f"{sm['scale_ups']} up / {sm['scale_downs']} down over "
              f"{sm['decisions']} tick(s); ended at {sm['replicas']} "
              f"replica(s)")
    for i, inj in sorted(injectors.items()):
        fired = ", ".join(f"{s}#{n}:{k}" for s, n, k in inj.log) or "none"
        print(f"chaos replica {i}: {len(inj.log)} fault(s) fired ({fired})")
    packed = (code * levels + 7) // 8 + 4
    print(f"index bytes: {d_codes.shape[0]*packed/2**20:.1f} MiB vs "
          f"float {docs.nbytes/2**20:.1f} MiB")


if __name__ == "__main__":
    main()
