"""Backfill-free model upgrade demo (paper §3.2.3, Figure 2 right).

    PYTHONPATH=src python examples/compat_upgrade.py

A backbone upgrade ships a better encoder whose float space has drifted.
Instead of re-encoding the 10-billion-document index (weeks), BEBR trains
phi_new with the backward-compatible objective: new queries search the OLD
binary index immediately. The finale drives the same models through the
live serving tier: a 2-replica router on the v1 index takes mixed v1/v2
typed ``SearchRequest`` traffic while a rolling swap migrates it to the
v2 index, the ``CompatibilityMatrix`` covering the transition window.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.losses as L
from repro.core import (
    BinarizerConfig,
    TrainConfig,
    bc_train_step,
    binarize_eval,
    init_train_state,
    make_encode_fn,
    train_step,
)
from repro.data.synthetic import pair_batches, upgraded_corpus
from repro.launch import lifecycle, proxy, serving
from repro.train import optim


def main():
    dim, code, levels = 128, 64, 4
    old_docs, old_queries, new_docs, new_queries, gt = upgraded_corpus(
        0, 10_000, 256, dim
    )

    cfg = TrainConfig(
        binarizer=BinarizerConfig(input_dim=dim, code_dim=code,
                                  n_levels=levels, hidden_dim=256),
        queue=L.QueueConfig(length=2048, dim=code, top_k=32),
        adam=optim.AdamConfig(lr=1e-3, clip_norm=5.0),
        temperature=0.2, bc_weight=1.0, bc_influence_weight=4.0,
    )

    def recall(q_state, q_emb, d_state, d_emb, k=10):
        bq = binarize_eval(q_state.params, q_state.bn_state,
                           jnp.asarray(q_emb), cfg.binarizer)
        bd = binarize_eval(d_state.params, d_state.bn_state,
                           jnp.asarray(d_emb), cfg.binarizer)
        _, idx = jax.lax.top_k(L.cosine(bq, bd), k)
        return float(jnp.mean(jnp.any(idx == jnp.asarray(gt)[:, None], -1)))

    print("1) v1 in production: train phi_old, build the binary index")
    old = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(functools.partial(train_step, cfg=cfg))
    gen = pair_batches(old_docs, 1, 128, noise=0.05)
    for _ in range(200):
        a, p = next(gen)
        old, _ = step(old, a, p)
    print(f"   (old q, old index) recall@10 = "
          f"{recall(old, old_queries, old, old_docs):.3f}")

    print("2) v2 backbone ships — naive deploy without compatibility:")
    print(f"   (new q through phi_old, old index) recall@10 = "
          f"{recall(old, new_queries, old, old_docs):.3f}   <- regression!")

    print("3) BEBR-BC: train phi_new against the frozen old index (Eq. 9-10)")
    new = init_train_state(jax.random.PRNGKey(7), cfg)
    new = new._replace(
        params=jax.tree_util.tree_map(jnp.copy, old.params),
        m_params=jax.tree_util.tree_map(jnp.copy, old.params),
        bn_state=jax.tree_util.tree_map(jnp.copy, old.bn_state),
        m_bn_state=jax.tree_util.tree_map(jnp.copy, old.bn_state),
    )
    bstep = jax.jit(functools.partial(bc_train_step, cfg=cfg))
    rng = np.random.default_rng(11)
    for _ in range(300):
        idx = rng.integers(0, old_docs.shape[0], 128)
        new, _ = bstep(new, old.params, old.bn_state,
                       jnp.asarray(new_docs[idx]), jnp.asarray(old_docs[idx]))
    print(f"   (new q through phi_new, OLD index, zero backfill) recall@10 = "
          f"{recall(new, new_queries, old, old_docs):.3f}")
    print("   -> the new model serves immediately; the index refresh "
          "(billions of docs) happens lazily or never.")

    print("4) live tier migration: 2 replicas on the v1 index, mixed "
          "v1/v2 traffic, rolling swap to v2 (compat covers the window)")
    enc_v1 = make_encode_fn(old.params, old.bn_state, cfg.binarizer)
    enc_v2 = make_encode_fn(new.params, new.bn_state, cfg.binarizer)
    snap_v1 = lifecycle.CorpusSnapshot(
        codes=np.asarray(enc_v1(old_docs)), n_levels=levels,
        embedding_version="v1",
    )
    snap_v2 = lifecycle.CorpusSnapshot(
        codes=np.asarray(enc_v2(new_docs)), n_levels=levels,
        embedding_version="v2",
    )
    builder = lifecycle.make_builder("flat", k=10, backend="xla")
    search_v1 = builder.build(snap_v1)

    batch = 64
    v1_batches = [old_queries[i:i + batch]
                  for i in range(0, old_queries.shape[0], batch)]
    v2_batches = [new_queries[i:i + batch]
                  for i in range(0, new_queries.shape[0], batch)]
    serving.warmup_replicas([(enc_v1, search_v1), (enc_v2, search_v1)],
                            v1_batches[:1] + v2_batches[:1])

    # bc-trained encoders work BOTH ways across the anchored output
    # space: v2 floats search the v1 index and v1 floats the v2 index
    compat = proxy.CompatibilityMatrix()
    compat.register("v2", "v1", enc_v2)
    compat.register("v1", "v2", enc_v1)
    router = proxy.QueryRouter(
        proxy.ReplicaSet([(enc_v1, search_v1)] * 2, share_device=True),
        compat=compat,
    )
    for r in (0, 1):
        router.set_version(r, lifecycle.builder_version(builder, snap_v1))

    stream, meta = [], []
    for _ in range(4):
        for i, (b, nb) in enumerate(zip(v1_batches, v2_batches)):
            stream.append(serving.SearchRequest(queries=b,
                                                embedding_version="v1"))
            meta.append(("v1", i))
            stream.append(serving.SearchRequest(queries=nb,
                                                embedding_version="v2"))
            meta.append(("v2", i))

    controller = lifecycle.RollingSwapController(
        router, lifecycle.make_builder("flat", k=10, backend="xla"),
        warm_batches=v2_batches[:1], encode_fn=enc_v2,
    )
    try:
        results, report = lifecycle.run_stream_with_swap(
            router, stream, controller=controller, snapshot=snap_v2,
            swap_after=len(stream) // 3,
        )
        stats = router.stats()
    finally:
        router.close()

    hits = {"v1": [], "v2": []}
    for (ver, i), r in zip(meta, results):
        ids = np.asarray(r[1])
        g = np.asarray(gt)[i * batch : i * batch + ids.shape[0]]
        hits[ver].append(float(np.mean(np.any(ids == g[:, None], -1))))
    finals = [pr["embedding_version"] for pr in stats["per_replica"]]
    print(f"   mixed traffic across the migration: recall@10 "
          f"v1={np.mean(hits['v1']):.3f} v2={np.mean(hits['v2']):.3f}")
    print(f"   -> {report.swapped} replica(s) migrated in "
          f"{report.total_s * 1e3:.0f} ms under live traffic, "
          f"{stats['compat_dispatches']} compat-encoded dispatch(es) "
          f"covered the window, final versions {finals}, "
          "zero results lost.")


if __name__ == "__main__":
    main()
