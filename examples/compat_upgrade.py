"""Backfill-free model upgrade demo (paper §3.2.3, Figure 2 right).

    PYTHONPATH=src python examples/compat_upgrade.py

A backbone upgrade ships a better encoder whose float space has drifted.
Instead of re-encoding the 10-billion-document index (weeks), BEBR trains
phi_new with the backward-compatible objective: new queries search the OLD
binary index immediately.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.losses as L
from repro.core import (
    BinarizerConfig,
    TrainConfig,
    bc_train_step,
    binarize_eval,
    init_train_state,
    train_step,
)
from repro.data.synthetic import pair_batches, upgraded_corpus
from repro.train import optim


def main():
    dim, code, levels = 128, 64, 4
    old_docs, old_queries, new_docs, new_queries, gt = upgraded_corpus(
        0, 10_000, 256, dim
    )

    cfg = TrainConfig(
        binarizer=BinarizerConfig(input_dim=dim, code_dim=code,
                                  n_levels=levels, hidden_dim=256),
        queue=L.QueueConfig(length=2048, dim=code, top_k=32),
        adam=optim.AdamConfig(lr=1e-3, clip_norm=5.0),
        temperature=0.2, bc_weight=1.0, bc_influence_weight=4.0,
    )

    def recall(q_state, q_emb, d_state, d_emb, k=10):
        bq = binarize_eval(q_state.params, q_state.bn_state,
                           jnp.asarray(q_emb), cfg.binarizer)
        bd = binarize_eval(d_state.params, d_state.bn_state,
                           jnp.asarray(d_emb), cfg.binarizer)
        _, idx = jax.lax.top_k(L.cosine(bq, bd), k)
        return float(jnp.mean(jnp.any(idx == jnp.asarray(gt)[:, None], -1)))

    print("1) v1 in production: train phi_old, build the binary index")
    old = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(functools.partial(train_step, cfg=cfg))
    gen = pair_batches(old_docs, 1, 128, noise=0.05)
    for _ in range(200):
        a, p = next(gen)
        old, _ = step(old, a, p)
    print(f"   (old q, old index) recall@10 = "
          f"{recall(old, old_queries, old, old_docs):.3f}")

    print("2) v2 backbone ships — naive deploy without compatibility:")
    print(f"   (new q through phi_old, old index) recall@10 = "
          f"{recall(old, new_queries, old, old_docs):.3f}   <- regression!")

    print("3) BEBR-BC: train phi_new against the frozen old index (Eq. 9-10)")
    new = init_train_state(jax.random.PRNGKey(7), cfg)
    new = new._replace(
        params=jax.tree_util.tree_map(jnp.copy, old.params),
        m_params=jax.tree_util.tree_map(jnp.copy, old.params),
        bn_state=jax.tree_util.tree_map(jnp.copy, old.bn_state),
        m_bn_state=jax.tree_util.tree_map(jnp.copy, old.bn_state),
    )
    bstep = jax.jit(functools.partial(bc_train_step, cfg=cfg))
    rng = np.random.default_rng(11)
    for _ in range(300):
        idx = rng.integers(0, old_docs.shape[0], 128)
        new, _ = bstep(new, old.params, old.bn_state,
                       jnp.asarray(new_docs[idx]), jnp.asarray(old_docs[idx]))
    print(f"   (new q through phi_new, OLD index, zero backfill) recall@10 = "
          f"{recall(new, new_queries, old, old_docs):.3f}")
    print("   -> the new model serves immediately; the index refresh "
          "(billions of docs) happens lazily or never.")


if __name__ == "__main__":
    main()
