"""BEBR quickstart: binarize a float corpus, build an index, search.

    PYTHONPATH=src python examples/quickstart.py

Five minutes end-to-end on CPU: train the recurrent binarizer on float
embeddings (task-agnostic emb2emb — no backbone, no raw data), compress
the index 16x, and search with SDC at near-float recall.
"""

import functools
import time

import jax
import jax.numpy as jnp

import repro.core.losses as L
from repro.core import (
    BinarizerConfig,
    TrainConfig,
    binarize_lib,
    init_train_state,
    pack_codes,
    train_step,
)
from repro.data.synthetic import clustered_corpus, pair_batches
from repro.index.flat import FlatFloat, FlatSDC
from repro.train import optim

DIM, CODE, LEVELS = 256, 128, 4  # 8192-bit float -> 512-bit code (16x)


def main():
    print("1) corpus: 20k docs, 128 queries, 256-dim float embeddings")
    docs, queries, gt = clustered_corpus(0, 20000, 128, DIM, n_clusters=192)

    print("2) train recurrent binarizer (emb2emb, momentum queue; ~2 min)")
    cfg = TrainConfig(
        binarizer=BinarizerConfig(input_dim=DIM, code_dim=CODE,
                                  n_levels=LEVELS, hidden_dim=512),
        queue=L.QueueConfig(length=4096, dim=CODE, top_k=64),
        adam=optim.AdamConfig(lr=2e-3, clip_norm=5.0),
    )
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(functools.partial(train_step, cfg=cfg))
    gen = pair_batches(docs, 1, 256, noise=0.08)
    t0 = time.time()
    for i in range(300):
        a, p = next(gen)
        state, metrics = step(state, a, p)
    print(f"   trained 300 steps in {time.time()-t0:.0f}s, "
          f"loss={float(metrics['loss']):.3f}")

    print("3) encode corpus to recurrent binary codes")
    enc = lambda e: pack_codes(binarize_lib.binarize(
        state.params, state.bn_state, jnp.asarray(e), cfg.binarizer)[0])
    d_codes, q_codes = enc(docs), enc(queries)

    print("4) build indexes + search")
    ff = FlatFloat.build(jnp.asarray(docs))
    sdc = FlatSDC.build(d_codes, LEVELS)
    _, idx_f = ff.search(jnp.asarray(queries), 10)
    _, idx_b = sdc.search(q_codes, 10)

    r = lambda idx: float(jnp.mean(jnp.any(idx == jnp.asarray(gt)[:, None], -1)))
    print(f"   float index: {ff.nbytes()/2**20:6.1f} MiB  recall@10={r(idx_f):.3f}")
    print(f"   BEBR  index: {sdc.nbytes()/2**20:6.1f} MiB  recall@10={r(idx_b):.3f}  "
          f"({100*(1-sdc.nbytes()/ff.nbytes()):.0f}% smaller)")


if __name__ == "__main__":
    main()
