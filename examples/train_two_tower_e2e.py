"""End-to-end driver: train a two-tower retrieval model (the paper's EBR
backbone setting) for a few hundred steps, extract item embeddings,
binarize them with BEBR, and serve retrieval through the SDC engine.

    PYTHONPATH=src python examples/train_two_tower_e2e.py [--steps 300]

This is the full production pipeline of Figure 2: backbone training ->
float embeddings -> task-agnostic binarization -> binary index -> serving,
with checkpointing (kill and re-run to resume).
"""

import argparse
import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.losses as L
from repro.core import (
    BinarizerConfig,
    TrainConfig,
    binarize_lib,
    init_train_state,
    pack_codes,
    train_step,
)
from repro.data import synthetic
from repro.index.flat import FlatSDC
from repro.models.recsys import two_tower as tt
from repro.train import checkpoint as ck
from repro.train import optim, steps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--ckpt", default="/tmp/bebr_two_tower_ckpt")
    args = ap.parse_args()

    # ~100M-param two-tower model (vocab-dominated, as in production)
    cfg = tt.TwoTowerConfig(name="tt-e2e", embed_dim=128,
                            tower_mlp=(256, 128), user_vocab=20_000,
                            item_vocab=20_000, hist_len=16)
    print(f"model: {cfg.param_count()/1e6:.1f}M params")

    params = tt.init_params(jax.random.PRNGKey(0), cfg)
    opt = optim.adam_init(params)
    step = jax.jit(steps.tt_train_step(cfg, optim.AdamConfig(lr=5e-3)))

    # structured interactions: users of taste-group g watch and click
    # items of group g (so the towers learn a real geometry).
    n_groups = 64
    items_per_group = cfg.item_vocab // n_groups

    def make_batch(i, batch=256):
        rng = np.random.default_rng(1000 + i)
        g = rng.integers(0, n_groups, batch)
        hist = (g[:, None] * items_per_group
                + rng.integers(0, items_per_group, (batch, cfg.hist_len))
                ).astype(np.int32)
        pos = (g * items_per_group
               + rng.integers(0, items_per_group, batch)).astype(np.int32)
        return {
            "hist_ids": jnp.asarray(hist),
            "hist_mask": jnp.ones((batch, cfg.hist_len), jnp.float32),
            "pos_items": jnp.asarray(pos),
            "item_logq": jnp.zeros((batch,), jnp.float32),
        }

    start = 0
    if ck.latest_step(args.ckpt) is not None:
        (params, opt), start = ck.restore(args.ckpt, (params, opt))
        print(f"[resume] from step {start}")

    t0 = time.time()
    for i in range(start, args.steps):
        batch = make_batch(i)
        params, opt, metrics = step(params, opt, batch)
        if (i + 1) % 50 == 0:
            print(f"step {i+1}: loss={float(metrics['loss']):.4f} "
                  f"({time.time()-t0:.0f}s)")
            ck.save(args.ckpt, i + 1, (params, opt))

    # ---- extract item-tower embeddings for the whole catalog ----
    print("extracting item embeddings (the float index)...")
    n_items = cfg.item_vocab
    item_emb = []
    for lo in range(0, n_items, 8192):
        ids = jnp.arange(lo, min(lo + 8192, n_items))
        item_emb.append(np.asarray(tt.item_embed(params, ids, cfg)))
    item_emb = np.concatenate(item_emb)

    # ---- BEBR: binarize the catalog (emb2emb, no backbone access) ----
    # The paper's positives are query-document pairs: anchors are QUERY
    # tower embeddings, positives their clicked items' embeddings — the
    # binarizer learns a code space in which both sides rank correctly.
    print("training binarizer on query-item embedding pairs...")
    bcfg = TrainConfig(
        binarizer=BinarizerConfig(input_dim=128, code_dim=64, n_levels=4,
                                  hidden_dim=256),
        queue=L.QueueConfig(length=4096, dim=64, top_k=64),
        adam=optim.AdamConfig(lr=2e-3, clip_norm=5.0),
    )
    bstate = init_train_state(jax.random.PRNGKey(1), bcfg)
    bstep = jax.jit(functools.partial(train_step, cfg=bcfg))
    for i in range(200):
        b = make_batch(5000 + i)
        q = tt.query_embed(params, b["hist_ids"], b["hist_mask"], cfg)
        it = tt.item_embed(params, b["pos_items"], cfg)
        bstate, _ = bstep(bstate, q, it)

    enc = lambda e: pack_codes(binarize_lib.binarize(
        bstate.params, bstate.bn_state, jnp.asarray(e), bcfg.binarizer)[0])
    index = FlatSDC.build(enc(item_emb), 4)
    print(f"binary index: {index.nbytes()/2**20:.1f} MiB "
          f"(float: {item_emb.nbytes/2**20:.1f} MiB)")

    # ---- serve: user queries -> query tower -> binarize -> SDC top-k ----
    batch = make_batch(999, 32)
    q_emb = tt.query_embed(params, batch["hist_ids"], batch["hist_mask"], cfg)
    vals, ids = index.search(enc(np.asarray(q_emb)), 100)
    ids = np.asarray(ids)

    float_scores = np.asarray(q_emb) @ item_emb.T
    float_top = np.argsort(-float_scores, -1)[:, :10]

    # retrieval-quality metrics (items within a taste group are
    # near-interchangeable, so exact top-10 identity is noise — what
    # matters is retrieving the right REGION of the catalog):
    gq = np.asarray(batch["pos_items"]) // items_per_group
    grp_bebr = np.mean([(ids[i, :10] // items_per_group == gq[i]).mean()
                        for i in range(32)])
    grp_float = np.mean([(float_top[i] // items_per_group == gq[i]).mean()
                         for i in range(32)])
    cover = np.mean([
        len(set(float_top[i].tolist()) & set(ids[i].tolist())) / 10
        for i in range(32)
    ])
    print(f"top-10 in the user's taste group: float={grp_float:.2f} "
          f"BEBR={grp_bebr:.2f}")
    print(f"float top-10 covered by BEBR top-100: {cover:.2f}")
    print("done.")


if __name__ == "__main__":
    main()
