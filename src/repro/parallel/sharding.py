"""Sharding rules: logical parameter/activation layouts per model family.

Conventions (DESIGN.md §5):
  * mesh axes: ("data", "model") single-pod, ("pod", "data", "model")
    multi-pod; ``pod`` composes with ``data`` for data parallelism.
  * LM params: FSDP over ``data`` x TP over ``model`` (MaxText-style 2D),
    optimizer state inherits => fully sharded (ZeRO-3-equivalent).
  * MoE experts: EP over ``model`` when divisible, else TP inside experts.
  * recsys embedding tables: row-sharded over (data, model) — the
    embedding analogue of EP, the paper's scale axis.
  * GNN: edge-sharded over dp, node states replicated.
  * activations: batch over dp; optional Megatron-SP (sequence over
    ``model``) for the scan carry between layers.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.transformer import TransformerConfig


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def ns(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


# ---------------------------------------------------------------------------
# LM transformer.
# ---------------------------------------------------------------------------


def lm_param_sharding(mesh: Mesh, cfg: TransformerConfig) -> Dict[str, Any]:
    dp = dp_axes(mesh)
    fsdp = dp  # parameters shard their "reduction" dim over dp (FSDP)
    layer = {
        "attn_norm": ns(mesh, None, None),
        "wq": ns(mesh, None, fsdp, "model"),
        "wk": ns(mesh, None, fsdp, "model"),
        "wv": ns(mesh, None, fsdp, "model"),
        "wo": ns(mesh, None, "model", fsdp),
        "ffn_norm": ns(mesh, None, None),
    }
    if cfg.is_moe:
        ep_ok = cfg.n_experts % mesh.shape["model"] == 0
        if ep_ok:
            layer.update(
                router=ns(mesh, None, fsdp, None),
                w_gate=ns(mesh, None, "model", fsdp, None),
                w_up=ns(mesh, None, "model", fsdp, None),
                w_down=ns(mesh, None, "model", None, fsdp),
            )
        else:
            layer.update(
                router=ns(mesh, None, fsdp, None),
                w_gate=ns(mesh, None, None, fsdp, "model"),
                w_up=ns(mesh, None, None, fsdp, "model"),
                w_down=ns(mesh, None, None, "model", fsdp),
            )
    else:
        layer.update(
            w_gate=ns(mesh, None, fsdp, "model"),
            w_up=ns(mesh, None, fsdp, "model"),
            w_down=ns(mesh, None, "model", fsdp),
        )
    return {
        "embed": ns(mesh, "model", fsdp),
        "final_norm": ns(mesh, None),
        "layers": layer,
    }


def lm_batch_sharding(mesh: Mesh) -> NamedSharding:
    return ns(mesh, dp_axes(mesh), None)  # tokens [B, S]


def lm_activation_constraint(mesh: Mesh, cfg: TransformerConfig):
    """Constraint applied to the residual stream between layers."""
    dp = dp_axes(mesh)
    if cfg.activation_sharding == "seq":
        spec = P(dp, "model", None)  # Megatron-SP: sequence over model
    else:
        spec = P(dp, None, None)

    def constrain(x):
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain


def lm_cache_sharding(mesh: Mesh, cfg: TransformerConfig, *, context_parallel: bool):
    """KV cache [L, B, KV, T, hd]."""
    dp = dp_axes(mesh)
    if context_parallel:
        kv = ns(mesh, None, None, None, dp, None)  # shard the time axis
    else:
        kv = ns(mesh, None, dp, None, None, None)  # shard the batch axis
    return {"k": kv, "v": kv, "length": ns(mesh)}


# ---------------------------------------------------------------------------
# RecSys.
# ---------------------------------------------------------------------------


def replicate_like(mesh: Mesh, tree: Any) -> Any:
    return jax.tree_util.tree_map(lambda _: ns(mesh), tree)


def recsys_table_sharding(mesh: Mesh) -> NamedSharding:
    return ns(mesh, dp_axes(mesh) + ("model",), None)  # [V, D] row-sharded


def recsys_batch_sharding(mesh: Mesh) -> NamedSharding:
    return ns(mesh, dp_axes(mesh))


def fill_param_sharding(mesh: Mesh, params_shape: Any, table_keys: Tuple[str, ...],
                        stacked_table_keys: Tuple[str, ...] = ()) -> Any:
    """Build a sharding pytree for a recsys model: named embedding tables
    row-sharded, everything else replicated."""

    def rule(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        if any(n in table_keys for n in names):
            return recsys_table_sharding(mesh)
        if any(n in stacked_table_keys for n in names):
            return ns(mesh, None, dp_axes(mesh) + ("model",), None)
        return ns(mesh)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


# ---------------------------------------------------------------------------
# GNN.
# ---------------------------------------------------------------------------


def gnn_param_sharding(mesh: Mesh, params_shape: Any) -> Any:
    return replicate_like(mesh, params_shape)  # tiny params, replicate


def gnn_edge_sharding(mesh: Mesh) -> NamedSharding:
    # edges shard over the full mesh (they dominate memory at 61M edges)
    return ns(mesh, dp_axes(mesh) + ("model",))


def gnn_edge_feat_sharding(mesh: Mesh) -> NamedSharding:
    return ns(mesh, dp_axes(mesh) + ("model",), None)


def gnn_node_sharding(mesh: Mesh) -> NamedSharding:
    # node states shard over `model` (edges over dp): 2D graph parallelism.
    return ns(mesh, "model", None)
