"""Step builders: full production train/serve steps per model family.

Each builder returns a pure function suitable for ``jax.jit(...).lower()``
with ShapeDtypeStruct inputs (dry-run) or real arrays (training). Train
steps include gradient accumulation over microbatches, remat (inside the
model), global-norm clipping and the Adam update — so the dry-run's
memory_analysis covers optimizer state and the backward pass.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import gnn as gnn_lib
from repro.models import transformer as tf
from repro.models.recsys import dien as dien_lib
from repro.models.recsys import dlrm as dlrm_lib
from repro.models.recsys import mind as mind_lib
from repro.models.recsys import two_tower as tt_lib
from repro.train import optim


def _accumulate_grads(loss_fn, params, batches, microbatches: int):
    """Scan-based gradient accumulation. ``batches`` is a pytree whose
    leaves have a leading global-batch dim divisible by microbatches."""
    if microbatches <= 1:
        loss, grads = jax.value_and_grad(loss_fn)(params, batches)
        return loss, grads

    split = jax.tree_util.tree_map(
        lambda x: x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:]),
        batches,
    )

    def body(carry, mb):
        loss_acc, grad_acc = carry
        loss, grads = jax.value_and_grad(loss_fn)(params, mb)
        grad_acc = jax.tree_util.tree_map(jnp.add, grad_acc, grads)
        return (loss_acc + loss, grad_acc), None

    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss_sum, grad_sum), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), zeros), split
    )
    inv = 1.0 / microbatches
    grads = jax.tree_util.tree_map(lambda g: g * inv, grad_sum)
    return loss_sum * inv, grads


def make_train_step(loss_fn: Callable, adam_cfg: optim.AdamConfig,
                    microbatches: int = 1):
    """Generic (params, opt_state, batch) -> (params, opt_state, metrics)."""

    def step(params, opt_state, batch):
        loss, grads = _accumulate_grads(loss_fn, params, batch, microbatches)
        new_params, new_opt = optim.adam_update(grads, opt_state, params, adam_cfg)
        metrics = {"loss": loss, "grad_norm": optim.global_norm(grads)}
        return new_params, new_opt, metrics

    return step


# ---------------------------------------------------------------------------
# LM family.
# ---------------------------------------------------------------------------


def lm_train_step(cfg: tf.TransformerConfig, adam_cfg: optim.AdamConfig,
                  constrain=None):
    def loss_fn(params, batch):
        return tf.lm_loss(params, batch["tokens"], batch["labels"], cfg,
                          constrain=constrain)

    return make_train_step(loss_fn, adam_cfg, cfg.microbatches)


def lm_prefill_step(cfg: tf.TransformerConfig):
    def step(params, batch):
        return tf.prefill(params, batch["tokens"], cfg)

    return step


def lm_decode_step(cfg: tf.TransformerConfig):
    def step(params, batch, cache):
        return tf.decode_step(params, batch["token"], cache, cfg)

    return step


# ---------------------------------------------------------------------------
# GNN family.
# ---------------------------------------------------------------------------


def gnn_train_step(cfg: gnn_lib.GNNConfig, adam_cfg: optim.AdamConfig,
                   microbatches: int = 1, node_constrain=None):
    def loss_fn(params, batch):
        return gnn_lib.mse_loss(
            params,
            batch["node_feat"],
            batch["edge_feat"],
            batch["senders"],
            batch["receivers"],
            batch["targets"],
            node_mask=batch.get("node_mask"),
            edge_mask=batch.get("edge_mask"),
            cfg=cfg,
            node_constrain=node_constrain,
        )

    # Graph batches are not microbatch-splittable along edges; accumulate=1.
    return make_train_step(loss_fn, adam_cfg, 1)


def gnn_infer_step(cfg: gnn_lib.GNNConfig):
    def step(params, batch):
        return gnn_lib.forward(
            params, batch["node_feat"], batch["edge_feat"], batch["senders"],
            batch["receivers"], edge_mask=batch.get("edge_mask"), cfg=cfg,
        )

    return step


# ---------------------------------------------------------------------------
# RecSys family.
# ---------------------------------------------------------------------------


def dlrm_train_step(cfg: dlrm_lib.DLRMConfig, adam_cfg, microbatches=1):
    def loss_fn(params, batch):
        return dlrm_lib.bce_loss(params, batch["dense"], batch["sparse_ids"],
                                 batch["labels"], cfg)

    return make_train_step(loss_fn, adam_cfg, microbatches)


def dlrm_serve_step(cfg: dlrm_lib.DLRMConfig):
    def step(params, batch):
        return dlrm_lib.forward(params, batch["dense"], batch["sparse_ids"], cfg)

    return step


def tt_train_step(cfg: tt_lib.TwoTowerConfig, adam_cfg, microbatches=1):
    def loss_fn(params, batch):
        return tt_lib.sampled_softmax_loss(
            params, batch["hist_ids"], batch["hist_mask"], batch["pos_items"],
            batch["item_logq"], cfg,
        )

    return make_train_step(loss_fn, adam_cfg, microbatches)


def tt_serve_step(cfg: tt_lib.TwoTowerConfig):
    def step(params, batch):
        return tt_lib.score_candidates(
            params, batch["hist_ids"], batch["hist_mask"], batch["cand_ids"], cfg
        )

    return step


def tt_retrieval_step(cfg: tt_lib.TwoTowerConfig, k: int = 100):
    """retrieval_cand: embed query, score 1M candidates, return top-k."""

    def step(params, batch):
        scores = tt_lib.score_candidates(
            params, batch["hist_ids"], batch["hist_mask"], batch["cand_ids"], cfg
        )
        return jax.lax.top_k(scores, k)

    return step


def tt_retrieval_bebr_step(cfg: tt_lib.TwoTowerConfig, k: int = 100,
                           code_dim: int = 64, n_levels: int = 4):
    """BEBR-optimised retrieval (the paper's technique as the perf fix):
    the candidate index is precomputed int8 recurrent-binary codes (4 bits
    used of each byte); the query embeds through the tower, binarizes with
    the linear recurrent binarizer, and scores via the affine-identity
    int8 matmul (kernels/sdc math) — 8-64x less index HBM traffic than the
    float path and MXU int8 throughput.

    batch: hist_ids/hist_mask (1 query), cand_codes [N, code] int8,
           cand_inv [N] f32.
    params gains a "binarizer" sub-tree: W [levels] of [emb_out, code] +
    R [levels-1] of [code, emb_out] linear recurrent blocks.
    """
    from repro.core.binarize_lib import code_affine_constants

    a, beta = code_affine_constants(n_levels)

    def binarize_linear(bparams, f):
        # linear recurrent binarization (hidden_dim=0 specialisation)
        def sign(x):
            return jnp.where(x > 0, 1.0, -1.0)

        f = f * jax.lax.rsqrt(jnp.sum(f * f, -1, keepdims=True) + 1e-12)
        b = sign(f @ bparams["W"][0])
        acc = b
        code = (b + 1.0) * 0.5 * (2 ** (n_levels - 1))
        for t in range(n_levels - 1):
            recon = acc @ bparams["R"][t]
            recon = recon * jax.lax.rsqrt(
                jnp.sum(recon * recon, -1, keepdims=True) + 1e-12)
            r = sign((f - recon) @ bparams["W"][t + 1])
            acc = acc + (2.0 ** -(t + 1)) * r
            code = code + (r + 1.0) * 0.5 * (2 ** (n_levels - 2 - t))
        return code  # integer codes as f32 [B, code_dim]

    def step(params, batch):
        q = tt_lib.query_embed(params, batch["hist_ids"], batch["hist_mask"], cfg)
        q_code = binarize_linear(params["binarizer"], q)  # [1, C] f32 codes
        cq8 = q_code.astype(jnp.int8)
        cd8 = batch["cand_codes"]  # [N, C] int8 — streamed at 1 B/dim
        # int8 x int8 -> int32 accumulate: the MXU 8-bit path, no int32
        # materialisation of the index (kernels/sdc does the same tiled).
        dot = jax.lax.dot_general(
            cd8, cq8[0], dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )  # [N]
        sq = jnp.sum(cq8.astype(jnp.int32))
        sd = jax.lax.dot_general(  # row sums via int8 matvec with ones
            cd8, jnp.ones((cd8.shape[1],), jnp.int8),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        scores = (
            (a * a) * dot.astype(jnp.float32)
            + (a * beta) * (sq + sd).astype(jnp.float32)
            + q_code.shape[-1] * beta * beta
        ) * batch["cand_inv"]
        vals, idx = jax.lax.top_k(scores[None, :], k)
        return vals, idx

    return step


def mind_train_step(cfg: mind_lib.MINDConfig, adam_cfg, microbatches=1):
    def loss_fn(params, batch):
        return mind_lib.label_aware_loss(
            params, batch["hist_ids"], batch["hist_mask"], batch["pos_items"],
            batch["neg_items"], cfg,
        )

    return make_train_step(loss_fn, adam_cfg, microbatches)


def mind_serve_step(cfg: mind_lib.MINDConfig):
    def step(params, batch):
        return mind_lib.serve_interests(params, batch["hist_ids"],
                                        batch["hist_mask"], cfg)

    return step


def mind_retrieval_step(cfg: mind_lib.MINDConfig, k: int = 100):
    """Multi-interest retrieval: max-over-interests candidate scoring."""

    def step(params, batch):
        caps = mind_lib.serve_interests(params, batch["hist_ids"],
                                        batch["hist_mask"], cfg)  # [B, K, D]
        cand = jnp.take(params["item_table"], batch["cand_ids"], axis=0)
        scores = jnp.einsum("bkd,nd->bkn", caps, cand).max(axis=1)
        return jax.lax.top_k(scores, k)

    return step


def dien_train_step(cfg: dien_lib.DIENConfig, adam_cfg, microbatches=1):
    def loss_fn(params, batch):
        return dien_lib.bce_loss(
            params, batch["hist_items"], batch["hist_cates"], batch["hist_mask"],
            batch["target_item"], batch["target_cate"], batch["labels"], cfg,
        )

    return make_train_step(loss_fn, adam_cfg, microbatches)


def dien_serve_step(cfg: dien_lib.DIENConfig):
    def step(params, batch):
        return dien_lib.forward(
            params, batch["hist_items"], batch["hist_cates"], batch["hist_mask"],
            batch["target_item"], batch["target_cate"], cfg,
        )

    return step
