"""Pure-JAX optimizers (no optax dependency).

Adam / AdamW with global-norm gradient clipping (the paper clips at 5.0)
and learning-rate schedules. API mirrors optax's (init, update) pair so it
drops into pjit'd train steps; all state is an explicit pytree.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


class AdamState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 0.02  # paper's initial LR for binarizer training
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0  # >0 => AdamW (decoupled)
    clip_norm: float = 5.0  # paper: clip when grad norm exceeds 5
    schedule: Optional[Callable[[jax.Array], jax.Array]] = None


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), tree)


def adam_init(params: PyTree) -> AdamState:
    # f32 accumulators regardless of param dtype (bf16 moments diverge).
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def adam_update(
    grads: PyTree, state: AdamState, params: PyTree, cfg: AdamConfig
) -> tuple[PyTree, AdamState]:
    """Returns (new_params, new_state)."""
    if cfg.clip_norm and cfg.clip_norm > 0:
        grads = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = cfg.lr if cfg.schedule is None else cfg.lr * cfg.schedule(step)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamState(step=step, mu=new_m, nu=new_v)


# ---------------------------------------------------------------------------
# Schedules.
# ---------------------------------------------------------------------------


def cosine_schedule(total_steps: int, warmup: int = 0, floor: float = 0.0):
    def sched(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((s - warmup) / jnp.maximum(total_steps - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)

    return sched


def constant_schedule():
    return lambda step: jnp.ones((), jnp.float32)
