"""Gradient compression for the cross-pod (DCI-bound) all-reduce hop.

int8 stochastic-free deterministic quantization with per-tensor scale and
**error feedback** (Seide et al. 2014; Karimireddy et al. 2019): the
quantization residual is carried to the next step, so compressed SGD/Adam
converges to the uncompressed fixed point. 4x wire-size reduction on the
slowest link of the hierarchy (pod-to-pod), where the collective term of
the roofline actually binds.

Usage inside a shard_map'd grad sync:
    g_q, scale = quantize(g)
    g_sum = psum(g_q.astype(f32) * scale, 'pod')   # wire carries int8
or explicitly with two psums (int32 sum of int8 payloads + scale max).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8. Returns (q [same shape, int8], scale [])."""
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def compress_with_feedback(
    grads: PyTree, error: PyTree
) -> Tuple[PyTree, PyTree, PyTree]:
    """Returns (quantized int8 grads, scales, new error feedback)."""

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        new_e = corrected - dequantize_int8(q, s)
        return q, s, new_e

    qs = jax.tree_util.tree_map(one, grads, error)
    q = jax.tree_util.tree_map(lambda t: t[0], qs, is_leaf=lambda t: isinstance(t, tuple))
    s = jax.tree_util.tree_map(lambda t: t[1], qs, is_leaf=lambda t: isinstance(t, tuple))
    e = jax.tree_util.tree_map(lambda t: t[2], qs, is_leaf=lambda t: isinstance(t, tuple))
    return q, s, e


def compressed_psum(grads: PyTree, error: PyTree, axis_name: str):
    """Error-feedback compressed all-reduce over ``axis_name``.

    The wire payload is int8 (the psum of int8 upcast to int32 is what the
    compiler moves; scales are scalar). Returns (mean grads f32, new error).
    """
    q, s, new_e = compress_with_feedback(grads, error)
    n = jax.lax.psum(1, axis_name)

    def reduce_one(qi, si):
        # sum of per-shard dequantized payloads == dequant of int32 sum only
        # when scales match; scales differ per shard, so psum the dequantized
        # int8 payload (wire: int8-precision values, 1/4 the f32 entropy).
        return jax.lax.psum(dequantize_int8(qi, si), axis_name) / n

    mean = jax.tree_util.tree_map(reduce_one, q, s)
    return mean, new_e
