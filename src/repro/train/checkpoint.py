"""Fault-tolerant checkpointing.

Design constraints for 1000+ node fleets:
  * atomic: a checkpoint is either fully visible or absent (tmp dir +
    rename; rename is atomic on POSIX).
  * self-validating: every array carries a CRC32 in the manifest; restore
    verifies before handing arrays to the trainer, so a torn write from a
    preempted writer can never poison a run.
  * elastic: arrays are stored as *logical* (unsharded) numpy buffers, so a
    job restarted on a different mesh shape (e.g. 256 -> 512 chips) resumes
    by re-sharding at load — checkpoint format is mesh-agnostic.
  * bounded: keep_last trims old steps; a ``latest`` pointer file makes
    discovery O(1).
  * async-capable: save() can run on a background thread (the train loop
    only blocks on jax.device_get, not on disk).

No orbax dependency — plain numpy + json, suitable for any POSIX store.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

PyTree = Any

# numpy can't serialise/compare bfloat16 natively — store as a uint16 view
# and record the logical dtype in the manifest.
_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16)}


def _to_storable(arr: np.ndarray):
    name = arr.dtype.name
    if name in _EXOTIC:
        return arr.view(_EXOTIC[name][1]), name
    return arr, name


def _from_storable(arr: np.ndarray, logical_dtype: str) -> np.ndarray:
    if logical_dtype in _EXOTIC:
        return arr.view(_EXOTIC[logical_dtype][0])
    return arr

_MANIFEST = "manifest.json"
_LATEST = "LATEST"


def _flatten_with_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out.append((key, leaf))
    return out, treedef


def save(ckpt_dir: str, step: int, tree: PyTree, *, keep_last: int = 3,
         blocking: bool = True) -> str:
    """Write checkpoint atomically. Returns the final directory path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat, _ = _flatten_with_paths(tree)
    # device_get on the caller thread (cheap vs disk); disk IO may be async.
    host = [(k, np.asarray(jax.device_get(v))) for k, v in flat]

    def _write():
        tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=f".tmp_step_{step}_")
        manifest = {"step": step, "arrays": {}}
        for i, (k, arr) in enumerate(host):
            fname = f"arr_{i:05d}.npy"
            storable, logical = _to_storable(arr)
            np.save(os.path.join(tmp, fname), storable)
            manifest["arrays"][k] = {
                "file": fname,
                "crc32": zlib.crc32(storable.tobytes()),
                "shape": list(arr.shape),
                "dtype": logical,
            }
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(ckpt_dir, f"step_{step:010d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        # latest pointer (write-then-rename, atomic)
        with tempfile.NamedTemporaryFile(
            "w", dir=ckpt_dir, delete=False
        ) as f:
            f.write(os.path.basename(final))
            tmp_ptr = f.name
        os.replace(tmp_ptr, os.path.join(ckpt_dir, _LATEST))
        _trim(ckpt_dir, keep_last)
        return final

    if blocking:
        return _write()
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return os.path.join(ckpt_dir, f"step_{step:010d}")


def _trim(ckpt_dir: str, keep_last: int):
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and os.path.isdir(os.path.join(ckpt_dir, d))
    )
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Find the newest *valid* checkpoint (skips torn/corrupt ones)."""
    if not os.path.isdir(ckpt_dir):
        return None
    candidates = sorted(
        (d for d in os.listdir(ckpt_dir) if d.startswith("step_")), reverse=True
    )
    for d in candidates:
        path = os.path.join(ckpt_dir, d)
        if os.path.isfile(os.path.join(path, _MANIFEST)):
            try:
                with open(os.path.join(path, _MANIFEST)) as f:
                    return int(json.load(f)["step"])
            except (json.JSONDecodeError, KeyError, ValueError):
                continue
    return None


def restore(
    ckpt_dir: str,
    target: PyTree,
    step: Optional[int] = None,
    *,
    shardings: Optional[PyTree] = None,
) -> Tuple[PyTree, int]:
    """Restore into the structure of ``target``.

    CRC-verifies every array. If ``shardings`` (a pytree of NamedSharding
    matching ``target``) is given, arrays are placed sharded — this is the
    elastic-resume path: the stored logical arrays are laid out for
    whatever mesh the new job runs on.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)

    flat, treedef = _flatten_with_paths(target)
    shard_flat = None
    if shardings is not None:
        shard_flat = [s for _, s in _flatten_with_paths(shardings)[0]]

    leaves = []
    for i, (k, ref_leaf) in enumerate(flat):
        meta = manifest["arrays"].get(k)
        if meta is None:
            raise KeyError(f"checkpoint missing array {k!r}")
        arr = np.load(os.path.join(path, meta["file"]))
        crc = zlib.crc32(arr.tobytes())
        if crc != meta["crc32"]:
            raise IOError(f"CRC mismatch for {k!r}: checkpoint corrupt")
        arr = _from_storable(arr, meta["dtype"])
        if shard_flat is not None:
            arr = jax.device_put(arr, shard_flat[i])
        leaves.append(arr)
    return treedef.unflatten(leaves), step
