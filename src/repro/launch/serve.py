"""BEBR serving launcher (paper Figure 5: query -> phi -> proxy/leaf/merge).

    PYTHONPATH=src python -m repro.launch.serve --docs 20000 --queries 64

End-to-end: train a binarizer on the corpus embeddings (emb2emb, minutes),
binarize + index the corpus, then serve batched queries through
  float backbone emb -> recurrent binarization -> SDC search (flat or IVF)
and report recall vs the float-embedding exhaustive baseline, plus index
bytes (the paper's memory-saving claim) and per-batch latency.
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BinarizerConfig,
    TrainConfig,
    binarize_eval,
    init_train_state,
    pack_codes,
    train_step,
)
from repro.core import binarize_lib
import repro.core.losses as losses_lib
from repro.data import synthetic
from repro.index import hnsw_lite
from repro.index import ivf as ivf_lib
from repro.index.flat import FlatFloat, FlatSDC
from repro.kernels.sdc import ref as sdc_ref


def train_binarizer(docs: np.ndarray, cfg: TrainConfig, steps: int = 300,
                    batch: int = 256, seed: int = 0):
    state = init_train_state(jax.random.PRNGKey(seed), cfg)
    step = jax.jit(functools.partial(train_step, cfg=cfg))
    gen = synthetic.pair_batches(docs, seed + 1, batch)
    for i in range(steps):
        a, p = next(gen)
        state, metrics = step(state, a, p)
    return state


def encode_codes(state, emb: np.ndarray, bcfg: BinarizerConfig, batch=4096):
    outs = []
    for i in range(0, emb.shape[0], batch):
        bits, _, _ = binarize_lib.binarize(
            state.params, state.bn_state, jnp.asarray(emb[i : i + batch]), bcfg
        )
        outs.append(pack_codes(bits))
    return jnp.concatenate(outs, 0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=20000)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--code-dim", type=int, default=128)
    ap.add_argument("--levels", type=int, default=4)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--index", choices=["flat", "ivf", "hnsw"], default="flat")
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--ef", type=int, default=64,
                    help="hnsw: result-list width (and per-hop top-k)")
    ap.add_argument("--beam", type=int, default=8,
                    help="hnsw: frontier nodes expanded per hop")
    ap.add_argument("--packed", action="store_true",
                    help="int4 nibble-packed code storage (2 dims/byte; "
                         "halves scan bandwidth, bit-identical scores)")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "pallas", "interpret", "xla"],
                    help="SDC scoring backend (auto: Pallas kernel on TPU, "
                         "jnp fallback elsewhere)")
    args = ap.parse_args()

    print(f"[data] {args.docs} docs, {args.queries} queries, dim={args.dim}")
    docs, queries, gt = synthetic.clustered_corpus(
        0, args.docs, args.queries, args.dim
    )

    bcfg = BinarizerConfig(
        input_dim=args.dim, code_dim=args.code_dim, n_levels=args.levels,
        hidden_dim=2 * args.dim,
    )
    from repro.train import optim

    tcfg = TrainConfig(
        binarizer=bcfg,
        queue=losses_lib.QueueConfig(length=4096, dim=args.code_dim, top_k=64),
        adam=optim.AdamConfig(lr=2e-3, clip_norm=5.0),
    )
    print(f"[train] binarizer {bcfg.total_bits} bits "
          f"({32 * args.dim // bcfg.total_bits}x compression), "
          f"{args.steps} steps")
    t0 = time.time()
    state = train_binarizer(docs, tcfg, steps=args.steps)
    print(f"[train] done in {time.time() - t0:.1f}s")

    # --- index build ---
    d_codes = encode_codes(state, docs, bcfg)
    q_codes = encode_codes(state, queries, bcfg)

    flat_float = FlatFloat.build(jnp.asarray(docs))
    if args.index == "flat":
        index = FlatSDC.build(
            d_codes, bcfg.n_levels, packed=args.packed, backend=args.backend
        )
        search = lambda q: index.search(q, args.k)
        nbytes = index.nbytes()
    elif args.index == "ivf":
        index = ivf_lib.build_ivf(
            jax.random.PRNGKey(1), d_codes, n_levels=bcfg.n_levels, nlist=64,
            packed=args.packed,
        )
        search = lambda q: ivf_lib.search(
            index, q, nprobe=32, k=args.k, backend=args.backend
        )
        nbytes = index.nbytes()
    else:  # hnsw: batched-frontier graph search on the gather kernel
        inv = np.asarray(sdc_ref.doc_inv_norms(d_codes, bcfg.n_levels))
        print("[index] building NSW graph (host-side, O(N^2) incremental "
              "construction — use --docs <= 20000 for a quick demo)")
        index = hnsw_lite.build_hnsw(
            np.asarray(d_codes), inv, n_levels=bcfg.n_levels, M=16,
            ef_construction=64, packed=args.packed,
        )
        tables = hnsw_lite.prepare_batched(index)
        search = lambda q: hnsw_lite.search_hnsw_batched(
            tables, q, k=args.k, ef=args.ef, beam=args.beam,
            backend=args.backend,
        )
        nbytes = index.nbytes()

    float_bytes = flat_float.nbytes()
    print(f"[index] {args.index}: {nbytes/2**20:.2f} MiB "
          f"(float flat: {float_bytes/2**20:.2f} MiB, "
          f"saving {100*(1-nbytes/float_bytes):.1f}%)")

    # --- serve ---
    _, idx_f = flat_float.search(jnp.asarray(queries), args.k)
    t0 = time.time()
    _, idx_b = search(q_codes)
    idx_b = jax.block_until_ready(idx_b)
    dt = time.time() - t0

    gt_t = jnp.asarray(gt)[:, None]
    r_float = float(jnp.mean(jnp.any(idx_f == gt_t, axis=-1)))
    r_bebr = float(jnp.mean(jnp.any(idx_b == gt_t, axis=-1)))
    print(f"[serve] recall@{args.k}: float={r_float:.4f} BEBR={r_bebr:.4f}")
    print(f"[serve] batch of {args.queries} queries in {dt*1000:.1f} ms "
          f"({args.queries/dt:.0f} QPS single-host CPU)")


if __name__ == "__main__":
    main()
