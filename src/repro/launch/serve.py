"""BEBR serving launcher (paper Figure 5: query -> phi -> proxy/leaf/merge).

    PYTHONPATH=src python -m repro.launch.serve --docs 20000 --queries 64

End-to-end: train a binarizer on the corpus embeddings (emb2emb; the
checkpoint is cached under a content digest, so only the first launch
pays for training — see launch/binarizer_cache.py), binarize + index the
corpus, then serve batched queries through
  float backbone emb -> recurrent binarization -> SDC search (flat or IVF)
and report recall vs the float-embedding exhaustive baseline, plus index
bytes (the paper's memory-saving claim) and per-batch latency.
``--coarse-levels C --k-coarse K'`` switch every index family to the
bi-granular mode: hot coarse scan over the first C levels, cold
full-level rerank of the K' survivors.
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BinarizerConfig,
    TrainConfig,
    bc_train_step,
    binarize_eval,
    init_train_state,
    pack_codes,
)
from repro.core import binarize_lib
import repro.core.losses as losses_lib
from repro.data import synthetic
from repro.index import hnsw_lite
from repro.index import ivf as ivf_lib
from repro.index.flat import FlatFloat, FlatSDC
from repro.kernels.sdc import ref as sdc_ref
from repro.launch import (
    autoscale,
    binarizer_cache,
    faults,
    lifecycle,
    proxy,
    serving,
)


def train_binarizer(docs: np.ndarray, cfg: TrainConfig, steps: int = 300,
                    batch: int = 256, seed: int = 0,
                    cache_dir: str | None = None):
    """Train the binarizer once per (corpus, config, steps, seed) digest.

    Later launches with identical inputs reload the checkpointed
    weights instead of re-running the emb2emb loop; see
    ``launch/binarizer_cache.py``. Returns a ``BinarizerCheckpoint``
    (``.params``/``.bn_state`` drop in for the ``TrainState`` fields).
    """
    return binarizer_cache.trained_binarizer(
        docs, cfg, steps=steps, batch=batch, seed=seed, cache_dir=cache_dir
    )


def encode_codes(state, emb: np.ndarray, bcfg: BinarizerConfig, batch=4096):
    outs = []
    for i in range(0, emb.shape[0], batch):
        bits, _, _ = binarize_lib.binarize(
            state.params, state.bn_state, jnp.asarray(emb[i : i + batch]), bcfg
        )
        outs.append(pack_codes(bits))
    return jnp.concatenate(outs, 0)


def bc_train_binarizer(old, old_docs: np.ndarray, new_docs: np.ndarray,
                       cfg: TrainConfig, steps: int = 300, batch: int = 256,
                       seed: int = 7):
    """Backward-compatible training (paper §3.2.3): warm-start phi_new
    from phi_old and anchor its output space to phi_old's on the shared
    items, so new-backbone queries can search the old binary index."""
    copy = functools.partial(jax.tree_util.tree_map, jnp.copy)
    state = init_train_state(jax.random.PRNGKey(seed), cfg)._replace(
        params=copy(old.params), m_params=copy(old.params),
        bn_state=copy(old.bn_state), m_bn_state=copy(old.bn_state),
    )
    step = jax.jit(functools.partial(bc_train_step, cfg=cfg))
    rng = np.random.default_rng(seed + 1)
    dim = old_docs.shape[-1]
    for _ in range(steps):
        idx = rng.integers(0, old_docs.shape[0], batch)
        noise = rng.normal(size=(batch, dim)).astype(np.float32) * 0.02
        a = new_docs[idx] + noise
        a /= np.linalg.norm(a, axis=-1, keepdims=True) + 1e-12
        state, _ = step(state, old.params, old.bn_state, jnp.asarray(a),
                        jnp.asarray(old_docs[idx]))
    return state


def _next_version(tag: str) -> str:
    if tag.startswith("v") and tag[1:].isdigit():
        return f"v{int(tag[1:]) + 1}"
    return tag + "+1"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=20000)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--code-dim", type=int, default=128)
    ap.add_argument("--levels", type=int, default=4)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-cache", default=None, metavar="DIR",
                    help="binarizer checkpoint cache dir (default: "
                         "$REPRO_BEBR_CACHE, else ~/.cache/repro-bebr); "
                         "training runs once per (corpus, config, steps, "
                         "seed) digest and later launches reload the "
                         "weights")
    ap.add_argument("--index", choices=["flat", "ivf", "hnsw"], default="flat")
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--coarse-levels", type=int, default=0, metavar="C",
                    help="bi-granular mode: coarse-scan the first C "
                         "residual levels (hot tier), then rerank the "
                         "--k-coarse survivors on the full-level codes "
                         "(cold tier); 0 disables (set with --k-coarse)")
    ap.add_argument("--k-coarse", type=int, default=0, metavar="K'",
                    help="bi-granular mode: survivors kept per query by "
                         "the coarse scan and rescored at full depth; "
                         "0 disables (set with --coarse-levels)")
    ap.add_argument("--ef", type=int, default=64,
                    help="hnsw: result-list width (and per-hop top-k)")
    ap.add_argument("--beam", type=int, default=8,
                    help="hnsw: frontier nodes expanded per hop")
    ap.add_argument("--packed", action="store_true",
                    help="int4 nibble-packed code storage (2 dims/byte; "
                         "halves scan bandwidth, bit-identical scores)")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "pallas", "interpret", "xla"],
                    help="SDC scoring backend (auto: Pallas kernel on TPU, "
                         "jnp fallback elsewhere)")
    ap.add_argument("--autotune", action="store_true",
                    help="sweep (block_q, block_n) launch shapes for the "
                         "live corpus/kernel signatures on startup and "
                         "serve with the winners; winners persist in the "
                         "tune cache so replicas and later launches share "
                         "one plan (launch/autotune.py); scores are "
                         "bit-identical with or without this flag")
    ap.add_argument("--tune-cache", default=None, metavar="DIR",
                    help="block-plan tune cache dir (default: "
                         "$REPRO_BEBR_CACHE, else ~/.cache/repro-bebr); "
                         "the first launch to tune a signature pays the "
                         "sweep, everyone else loads its winner")
    ap.add_argument("--probe-budget", type=int, default=0, metavar="B",
                    help="ivf: occupancy-weighted probe allocation — B "
                         "per-centroid rank slots are split across the "
                         "coarse centroids in proportion to list "
                         "occupancy instead of a flat per-query "
                         "--nprobe; B = nprobe*nlist costs the same "
                         "scans as flat nprobe (and is bit-identical at "
                         "exact multiples); 0 disables")
    ap.add_argument("--batch", type=int, default=0,
                    help="serving batch size (0: all queries in one batch)")
    ap.add_argument("--rounds", type=int, default=4,
                    help="times the query stream is replayed for "
                         "steady-state timing")
    ap.add_argument("--queue-depth", type=int, default=8,
                    help="admission-queue depth (requests, per replica)")
    ap.add_argument("--policy", choices=["block", "shed"], default="block",
                    help="admission policy when a replica queue is full "
                         "(the proxy sheds only when EVERY replica is "
                         "saturated)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serving replicas behind the query router (on a "
                         "single host they share the device and index "
                         "arrays; each still gets its own pipeline + "
                         "admission queue)")
    ap.add_argument("--router", choices=sorted(proxy.ROUTING_POLICIES),
                    default="round-robin",
                    help="replica routing policy")
    ap.add_argument("--tier-spec", default=None, metavar="SPEC.json",
                    help="declarative tier spec (launch/autoscale.py "
                         "TierSpec JSON): replica min/max, index kind + "
                         "build params, router policy, admission policy/"
                         "queue depth, swap cadence, and scale thresholds. "
                         "Overrides --replicas/--router/--queue-depth/"
                         "--policy/--index, starts the tier at "
                         "min_replicas, and runs the shed-pressure "
                         "autoscaler over the stream (scale-up replicas "
                         "are built from the spec's index params, warmed, "
                         "and canary-probed before taking traffic; "
                         "scale-down drains losslessly). swap_every_s > 0 "
                         "schedules one rolling swap mid-stream when "
                         "--swap-after/--upgrade-after are unset")
    ap.add_argument("--embedding-version", default="v1",
                    help="embedding-version tag for the trained binarizer, "
                         "the corpus snapshot, and the tier's replicas; "
                         "typed SearchRequests are routed by this tag")
    ap.add_argument("--upgrade-after", type=int, default=0, metavar="N",
                    help="after N batches, run a LIVE embedding-version "
                         "migration: bc-train the next-version binarizer "
                         "against a drifted backbone "
                         "(data/synthetic.backbone_upgrade), register "
                         "cross-version compat encoders, and rolling-swap "
                         "every replica to the new index while the stream "
                         "mixes old- and new-version queries; 0 disables "
                         "(mutually exclusive with --swap-after)")
    ap.add_argument("--swap-after", type=int, default=0, metavar="N",
                    help="after N batches of the routed stream, run a "
                         "rolling index swap (drain -> rebuild -> warm -> "
                         "canary re-probe, one replica at a time) under "
                         "the live traffic; 0 disables")
    ap.add_argument("--probe-every", type=float, default=0.0, metavar="S",
                    help="period (s) of the router's canary health "
                         "re-probe loop — unhealthy replicas that answer "
                         "the canary are revived; 0 disables")
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="deterministic fault injection on the serving "
                         "fns: comma-joined clauses "
                         "'[rN.][stage.]kind[@AT][xCOUNT][~PROB][:ARG]' "
                         "with kind in fail|delay|stick|flap (see "
                         "launch/faults.py). e.g. "
                         "'r0.search.fail@3,r1.search.delay~0.5:0.01' — "
                         "pair with --probe-every / --scan-budget-ms to "
                         "watch the tier heal")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-batch deadline (ms) enforced through the "
                         "tier: expired work is shed at dequeue (counted, "
                         "never scanned) and lands as a None result; "
                         "0 disables")
    ap.add_argument("--scan-budget-ms", type=float, default=0.0,
                    help="stuck-scan watchdog budget (ms): a scan running "
                         "past it marks its replica unhealthy and fails "
                         "its in-flight work over to the survivors; "
                         "0 disables")
    args = ap.parse_args()
    if args.swap_after and args.upgrade_after:
        ap.error("--swap-after and --upgrade-after are mutually exclusive "
                 "(the upgrade IS a rolling swap, to the next-version index)")
    if bool(args.coarse_levels) != bool(args.k_coarse):
        ap.error("--coarse-levels and --k-coarse must be set together")
    if args.coarse_levels and not 0 < args.coarse_levels < args.levels:
        ap.error(f"--coarse-levels must be in [1, {args.levels - 1}] "
                 f"(got {args.coarse_levels} of --levels {args.levels})")
    if args.probe_budget and args.index != "ivf":
        ap.error("--probe-budget only applies to --index ivf")

    # Declarative tier spec: ONE artifact describes the tier's desired
    # state; the flags it covers are overridden so an operator cannot
    # half-apply it. The autoscaler re-applies the same spec as it
    # resizes — scale-up replicas are built from spec.build_params, not
    # from whatever flags happened to be on the command line.
    spec = None
    if args.tier_spec:
        try:
            spec = autoscale.TierSpec.from_file(args.tier_spec)
        except autoscale.InvalidTierSpec as e:
            ap.error(f"--tier-spec: {e}")
        args.index = spec.index
        args.replicas = spec.min_replicas
        args.router = spec.router
        args.queue_depth = spec.queue_depth
        args.policy = spec.policy
        print(f"[tier-spec] {args.tier_spec}: index={spec.index} "
              f"replicas=[{spec.min_replicas}, {spec.max_replicas}] "
              f"router={spec.router} policy={spec.policy} "
              f"water=({spec.low_water}, {spec.high_water}) "
              f"cooldown={spec.cooldown_s}s window={spec.window_s}s")

    print(f"[data] {args.docs} docs, {args.queries} queries, dim={args.dim}")
    docs, queries, gt = synthetic.clustered_corpus(
        0, args.docs, args.queries, args.dim
    )

    bcfg = BinarizerConfig(
        input_dim=args.dim, code_dim=args.code_dim, n_levels=args.levels,
        hidden_dim=2 * args.dim,
    )
    from repro.train import optim

    tcfg = TrainConfig(
        binarizer=bcfg,
        queue=losses_lib.QueueConfig(length=4096, dim=args.code_dim, top_k=64),
        adam=optim.AdamConfig(lr=2e-3, clip_norm=5.0),
    )
    print(f"[train] binarizer {bcfg.total_bits} bits "
          f"({32 * args.dim // bcfg.total_bits}x compression), "
          f"{args.steps} steps")
    t0 = time.time()
    state = train_binarizer(docs, tcfg, steps=args.steps,
                            cache_dir=args.ckpt_cache)
    verb = "trained" if state.trained else "loaded cached checkpoint"
    print(f"[train] {verb} ({state.digest}) in {time.time() - t0:.1f}s")

    # --- index build ---
    d_codes = encode_codes(state, docs, bcfg)

    # The lifecycle builder is the single source of build params: the
    # initial index below consumes builder.params, so a mid-stream
    # rolling swap (--swap-after) provably rebuilds the SAME index and
    # the demo's bit-identity claim cannot drift out from under it.
    flat_float = FlatFloat.build(jnp.asarray(docs))
    cl = args.coarse_levels or None
    kc = args.k_coarse or None

    # Adaptive execution: tune (or reload) a block plan per kernel kind
    # for the live corpus shapes. Plans only move launch geometry —
    # every score below is bit-identical with block_plan=None.
    block_plan = None
    if args.autotune:
        from repro.launch import autotune

        block_plan = {}
        for kind in ("scan", "rerank"):
            tp = autotune.tuned_block_plan(
                kind, code_dim=args.code_dim, n_shard=args.docs,
                packed=args.packed, k=(kc or args.k), n_levels=args.levels,
                backend=args.backend, cache_dir=args.tune_cache,
            )
            block_plan[kind] = tp.plan
            print(f"[tune] {kind}: block_q={tp.plan.block_q} "
                  f"block_n={tp.plan.block_n} ({tp.plan.source}"
                  f"{', swept now' if tp.tuned else ''})")

    if spec is not None:
        # The spec's build params are the single source of truth; the
        # per-family branches below consume builder.params so the
        # initial index, every swap, and every autoscaler scale-up all
        # build the SAME index.
        builder = spec.make_index_builder()
    elif args.index == "flat":
        builder = lifecycle.FlatBuilder(
            k=args.k, packed=args.packed, backend=args.backend,
            coarse_levels=cl, k_coarse=kc, block_plan=block_plan,
        )
    elif args.index == "ivf":
        builder = lifecycle.IVFBuilder(
            k=args.k, nlist=64, nprobe=32, seed=1, packed=args.packed,
            backend=args.backend, coarse_levels=cl, k_coarse=kc,
            probe_budget=args.probe_budget or None, block_plan=block_plan,
        )
    else:
        builder = lifecycle.HNSWBuilder(
            k=args.k, M=16, ef_construction=64, ef=args.ef, beam=args.beam,
            packed=args.packed, backend=args.backend,
            coarse_levels=cl, k_coarse=kc, block_plan=block_plan,
        )
    p = builder.params

    if args.index == "hnsw":
        print("[index] building NSW graph (host-side, O(N^2) incremental "
              "construction — use --docs <= 20000 for a quick demo)")
    if cl is not None:
        # Bi-granular mode serves through the lifecycle builder from the
        # first query: it is the same fn a rolling swap of the identical
        # snapshot would install (digest-cached), so the swap demo's
        # bit-identity claim holds with rerank on.
        snapshot0 = lifecycle.CorpusSnapshot(
            codes=np.asarray(d_codes), n_levels=bcfg.n_levels,
            embedding_version=args.embedding_version,
        )
        search = builder.build(snapshot0)
        per_doc = lambda lv: (args.code_dim * lv + 7) // 8 + 4
        coarse_b = args.docs * per_doc(cl)
        fine_b = args.docs * per_doc(args.levels)
        nbytes = coarse_b + fine_b
        print(f"[index] bi-granular tiers (serialized): "
              f"coarse {coarse_b/2**20:.2f} MiB (hot, {cl}/{args.levels} "
              f"levels), fine {fine_b/2**20:.2f} MiB (cold), "
              f"rerank k'={kc}")
    elif args.index == "flat":
        from repro.kernels.sdc.defaults import plan_for

        index = FlatSDC.build(
            d_codes, bcfg.n_levels, packed=p["packed"], backend=p["backend"]
        )
        scan_plan = plan_for(block_plan, "scan")
        search = lambda q: index.search(q, p["k"], block_plan=scan_plan)
        nbytes = index.nbytes()
    elif args.index == "ivf":
        index = ivf_lib.build_ivf(
            jax.random.PRNGKey(p["seed"]), d_codes, n_levels=bcfg.n_levels,
            nlist=p["nlist"], kmeans_iters=p["kmeans_iters"],
            packed=p["packed"],
        )
        if p["probe_budget"]:
            search = lambda q: ivf_lib.search_budget(
                index, q, probe_budget=p["probe_budget"], k=p["k"],
                backend=p["backend"],
            )
        else:
            search = lambda q: ivf_lib.search(
                index, q, nprobe=p["nprobe"], k=p["k"], backend=p["backend"]
            )
        nbytes = index.nbytes()
    else:  # hnsw: batched-frontier graph search on the gather kernel
        inv = np.asarray(sdc_ref.doc_inv_norms(d_codes, bcfg.n_levels))
        index = hnsw_lite.build_hnsw(
            np.asarray(d_codes), inv, n_levels=bcfg.n_levels, M=p["M"],
            ef_construction=p["ef_construction"], seed=p["seed"],
            packed=p["packed"],
        )
        tables = hnsw_lite.prepare_batched(index)
        search = lambda q: hnsw_lite.search_hnsw_batched(
            tables, q, k=p["k"], ef=p["ef"], beam=p["beam"],
            backend=p["backend"],
        )
        nbytes = index.nbytes()

    float_bytes = flat_float.nbytes()
    print(f"[index] {args.index}: {nbytes/2**20:.2f} MiB "
          f"(float flat: {float_bytes/2**20:.2f} MiB, "
          f"saving {100*(1-nbytes/float_bytes):.1f}%)")

    # --- serve: replicated pipelines behind the query router ---
    _, idx_f = flat_float.search(jnp.asarray(queries), args.k)

    # jit'd per-batch encode: the eager path dispatches dozens of small
    # ops per batch and would fight the scan threads for the GIL.
    encode = binarize_lib.make_encode_fn(state.params, state.bn_state, bcfg)
    batch = args.batch or args.queries
    batches = [queries[i:i + batch] for i in range(0, args.queries, batch)]
    stream = batches * args.rounds
    n_q = args.queries * args.rounds

    # Single-host replicas share the index closure: N pipelines (each
    # its own admission queue + worker threads) over the same arrays.
    replica_fns = [(encode, search)] * args.replicas
    serving.warmup_replicas(replica_fns, batches)
    # Chaos wrapping AFTER warmup: the fault schedule is a function of
    # the call index, and warmup traffic must not consume (or trip) it.
    replica_fns, injectors = faults.apply_chaos(replica_fns, args.chaos)

    t0 = time.time()
    serving.serve_sequential(encode, search, stream)
    dt_seq = time.time() - t0

    # Drive the router directly so --policy is honoured: submits that
    # shed off EVERY replica's full admission queue are retried after a
    # short pause (observable in stats["shed"]); block policy
    # back-pressures inside submit.
    pcfg = serving.ServingConfig(queue_depth=args.queue_depth,
                                 policy=args.policy)
    # share_device: single-host replicas sit on one device; their scan
    # stages take turns instead of oversubscribing the host cores.
    compat = proxy.CompatibilityMatrix()
    # share_device also when a tier spec may scale up later: added
    # replicas land on the same host device as the originals.
    share = args.replicas > 1 or (spec is not None and spec.max_replicas > 1)
    router = proxy.QueryRouter(
        proxy.ReplicaSet(replica_fns, config=pcfg, share_device=share),
        policy=args.router, compat=compat,
    )
    from_version = args.embedding_version
    for r in range(args.replicas):
        router.set_version(r, from_version)

    # Live index lifecycle: a rolling swap mid-stream rebuilds each
    # replica's index from a fresh corpus snapshot (here: the same codes,
    # so results stay bit-identical and recall is unchanged — the point
    # of the demo is that the traffic never stops), and the periodic
    # canary probe revives replicas whose transient faults clear.
    controller = snapshot = None
    to_version = None
    stream_meta = None
    if spec is not None and spec.swap_every_s > 0 \
            and not (args.swap_after or args.upgrade_after):
        # The spec's declared swap cadence, mapped onto this
        # finite-stream demo driver: one rolling swap at mid-stream.
        args.swap_after = max(1, len(stream) // 2)
    if args.swap_after:
        snapshot = lifecycle.CorpusSnapshot(
            codes=np.asarray(d_codes), n_levels=bcfg.n_levels,
            embedding_version=from_version,
        )
        controller = lifecycle.RollingSwapController(
            router, builder, warm_batches=batches[:1], encode_fn=encode
        )
    elif args.upgrade_after:
        # Live embedding-version migration: bc-train the next-version
        # binarizer against a drifted backbone, register cross-version
        # compat encoders (v_new queries search the v_old index and vice
        # versa through the bc-anchored output space), then rolling-swap
        # the tier to the new index under mixed-version traffic.
        to_version = _next_version(from_version)
        print(f"[upgrade] backbone drift + bc-training {to_version} "
              f"binarizer ({args.steps} steps)")
        new_docs = synthetic.backbone_upgrade(docs, 5)
        new_queries = synthetic.backbone_upgrade(queries, 5)
        new_state = bc_train_binarizer(state, docs, new_docs, tcfg,
                                       steps=args.steps)
        enc_new = binarize_lib.make_encode_fn(
            new_state.params, new_state.bn_state, bcfg
        )
        compat.register(to_version, from_version, enc_new)
        compat.register(from_version, to_version, encode)
        snapshot = lifecycle.CorpusSnapshot(
            codes=np.asarray(encode_codes(new_state, new_docs, bcfg)),
            n_levels=bcfg.n_levels, embedding_version=to_version,
        )
        controller = lifecycle.RollingSwapController(
            router, builder, warm_batches=batches[:1], encode_fn=enc_new
        )
        # the compat hop runs enc_new on the still-v_old replicas before
        # the swap reaches them: pre-compile it like every other stage
        serving.warmup_replicas([(enc_new, search)], batches[:1])
        new_batches = [new_queries[i:i + batch]
                       for i in range(0, args.queries, batch)]
        # mixed-version stream: each round alternates an old-version and
        # a new-version request per batch index
        stream, stream_meta = [], []
        for _ in range(args.rounds):
            for i, (b, nb) in enumerate(zip(batches, new_batches)):
                stream.append(serving.SearchRequest(
                    queries=b, embedding_version=from_version))
                stream_meta.append((from_version, i))
                stream.append(serving.SearchRequest(
                    queries=nb, embedding_version=to_version))
                stream_meta.append((to_version, i))
    if args.probe_every:
        router.start_health_probe(batches[0], interval=args.probe_every)
    if args.scan_budget_ms:
        router.start_watchdogs(args.scan_budget_ms / 1e3)

    scaler = None
    if spec is not None:
        as_snapshot = snapshot if snapshot is not None else \
            lifecycle.CorpusSnapshot(
                codes=np.asarray(d_codes), n_levels=bcfg.n_levels,
                embedding_version=from_version,
            )
        scaler = autoscale.Autoscaler(
            router, spec, snapshot=as_snapshot, encode_fn=encode,
            warm_batches=batches[:1],
            on_event=lambda msg: print(f"[autoscale] {msg}"),
        )
        scaler.start()

    t0 = time.time()
    results, swap_report = lifecycle.run_stream_with_swap(
        router, stream, controller=controller, snapshot=snapshot,
        swap_after=args.swap_after or args.upgrade_after,
        deadline_s=(args.deadline_ms / 1e3) if args.deadline_ms else None,
    )
    dt_pipe = time.time() - t0
    if scaler is not None:
        scaler.stop()
    for inj in injectors.values():
        inj.release()  # a still-stuck scan would wedge close()'s joins
    router.close()
    stats = router.stats()

    gt_t = jnp.asarray(gt)[:, None]
    r_float = float(jnp.mean(jnp.any(idx_f == gt_t, axis=-1)))
    if stream_meta is not None:
        # mixed-version stream: per-version recall over every answered
        # request across the whole migration window
        hits = {from_version: [], to_version: []}
        for (ver, i), r in zip(stream_meta, results):
            if r is None:
                continue
            ids = np.asarray(r[1])
            g = np.asarray(gt)[i * batch : i * batch + ids.shape[0]]
            hits[ver].append(float(np.mean(np.any(ids == g[:, None], -1))))
        per_ver = " ".join(
            f"{v}={np.mean(h):.4f}" if h else f"{v}=n/a"
            for v, h in hits.items()
        )
        print(f"[serve] recall@{args.k}: float={r_float:.4f} "
              f"BEBR[{per_ver}] (across the live migration)")
    elif all(r is not None for r in results[: len(batches)]):
        first = results[: len(batches)]
        idx_b = jnp.concatenate([ids for _, ids in first], 0)
        r_bebr = float(jnp.mean(jnp.any(idx_b == gt_t, axis=-1)))
        print(f"[serve] recall@{args.k}: float={r_float:.4f} "
              f"BEBR={r_bebr:.4f}")
    else:
        # Deadline sheds are accounted answers, but recall needs the
        # full first replay of the stream.
        first = results[: len(batches)]
        print(f"[serve] recall@{args.k}: float={r_float:.4f} BEBR=n/a "
              f"({sum(r is None for r in first)}/{len(first)} first-round "
              "batches missed their deadline)")
    print(f"[serve] sequential: {1e3 * dt_seq / (len(batches) * args.rounds):.1f} "
          f"ms/batch ({n_q / dt_seq:.0f} QPS single-host CPU, warmed)")
    n_q_routed = sum(
        getattr(b, "n_queries", None) or b.shape[0] for b in stream
    )
    shed = f", {stats['shed']} shed" if stats["shed"] else ""
    print(f"[serve] routed ({args.replicas} replica(s), {args.router}): "
          f"{1e3 * dt_pipe / len(stream):.1f} ms/batch "
          f"({n_q_routed / dt_pipe:.0f} QPS; "
          f"p50={stats['latency_p50_ms']:.1f} ms "
          f"p99={stats['latency_p99_ms']:.1f} ms, device idle "
          f"{100 * stats['device_idle_frac']:.0f}%{shed})")
    if args.replicas > 1:
        for s in stats["per_replica"]:
            print(f"[serve]   replica {s['replica']}: {s['requests']} req "
                  f"({s['queries']} queries), shed {s['shed']}, device idle "
                  f"{100 * s['device_idle_frac']:.0f}%")
    if swap_report is not None:
        rep = swap_report
        print(f"[swap] rolling swap -> {rep.version.tag}: {rep.swapped} "
              f"replica(s) re-indexed in {rep.total_s * 1e3:.0f} ms under "
              f"live traffic (zero results lost)")
        for row in rep.replicas:
            print(f"[swap]   replica {row['replica']}: "
                  f"drain {row['drain_s'] * 1e3:.0f} ms, "
                  f"build {row['build_s'] * 1e3:.0f} ms, "
                  f"warm {row['warm_s'] * 1e3:.0f} ms, "
                  f"probe {row['probe_s'] * 1e3:.0f} ms "
                  f"(generation {row['generation']})")
    if to_version is not None and swap_report is not None:
        finals = [pr["embedding_version"] for pr in stats["per_replica"]]
        print(f"[upgrade] {from_version} -> {to_version} migration: "
              f"{stats['compat_dispatches']} compat-encoded dispatch(es) "
              f"covered the transition window; final replica versions "
              f"{finals}")
    if args.probe_every:
        print(f"[probe] canary re-probe every {args.probe_every}s: "
              f"{stats['revivals']} revival(s), states {stats['states']}")
    if args.deadline_ms:
        print(f"[deadline] {args.deadline_ms:.0f} ms budget: "
              f"{stats['deadline_expired']} expired "
              f"({sum(r is None for r in results)}/{len(results)} batches "
              "unanswered)")
    if args.scan_budget_ms:
        print(f"[watchdog] {args.scan_budget_ms:.0f} ms scan budget: "
              f"{stats['watchdog_stalls']} stall(s), "
              f"{stats['failovers']} failover(s)")
    if scaler is not None:
        sm = scaler.summary()
        print(f"[autoscale] spec [{sm['replicas_min']}, "
              f"{sm['replicas_max']}]: {sm['scale_ups']} scale-up(s), "
              f"{sm['scale_downs']} scale-down(s) over {sm['decisions']} "
              f"tick(s); replicas ended at {sm['replicas']} "
              f"(seen [{sm['min_replicas_seen']}, "
              f"{sm['max_replicas_seen']}])")
    for i, inj in sorted(injectors.items()):
        fired = ", ".join(f"{s}#{n}:{k}" for s, n, k in inj.log) or "none"
        print(f"[chaos] replica {i}: {len(inj.log)} fault(s) fired "
              f"({fired})")


if __name__ == "__main__":
    main()
