"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module touches no jax device state — required because the dry-run forces
512 host devices while tests/benches run single-device.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    Axes: (data, model) or (pod, data, model). ``pod`` composes with
    ``data`` for hierarchical data parallelism (gradient reduction:
    reduce-scatter intra-pod, all-reduce over DCI, all-gather intra-pod —
    GSPMD emits this decomposition for the nested axes).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512"
        )
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev_array, axes)


def make_host_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Small mesh for tests on whatever devices exist."""
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_replica_meshes(
    n_replicas: int, shape=(2, 2), axes=("data", "model")
) -> list:
    """Partition the host's devices into ``n_replicas`` disjoint submeshes.

    Each submesh is a full serving replica: the corpus is sharded over
    *its* devices ("leaves") by an ``engine.make_*_search`` program, and
    the proxy tier (``launch/proxy.py``) routes query streams across the
    replicas. Disjointness is the point — replicas share no devices, so
    one replica's failure or saturation leaves the others' capacity
    untouched.
    """
    per = int(np.prod(shape))
    need = n_replicas * per
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"need {need} devices ({n_replicas} replicas x {per}), "
            f"have {len(devices)}"
        )
    return [
        Mesh(np.asarray(devices[i * per:(i + 1) * per]).reshape(shape), axes)
        for i in range(n_replicas)
    ]
