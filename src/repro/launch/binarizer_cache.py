"""Train-once binarizer checkpoints, cached under a content digest.

The serve drivers each need a recurrent-MLP binarizer before they can
build an index, and training one is deterministic for a fixed (corpus,
config, steps, batch, seed) tuple: re-running the emb2emb loop on every
launch buys nothing but wall clock, and skipping it entirely (the old
``hidden_dim=0`` random-projection shortcut in the demo) costs recall.
This module gives both drivers the same middle path — train the real
binarizer once, checkpoint it keyed by a digest of everything that
shaped it, and reload on every later launch with the identical inputs.

The digest covers the corpus bytes plus the full ``TrainConfig`` repr
(it is a frozen dataclass of scalars, so the repr is stable) plus the
loop knobs; any change to any of them lands on a different cache file,
so a hit is always safe to trust. Checkpoints are plain ``np.savez``
archives of the flattened (params, bn_state) pytree — no pickle — and
are written atomically (tmp + rename) so a crashed run never leaves a
half-written file that a later launch would try to load.

Cache location: ``--ckpt-cache`` / the ``cache_dir`` argument, else the
``REPRO_BEBR_CACHE`` environment variable, else ``~/.cache/repro-bebr``.
"""

from __future__ import annotations

import functools
import hashlib
import os
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    TrainConfig,
    init_binarizer,
    init_train_state,
    train_step,
)
from repro.data import synthetic

CACHE_ENV = "REPRO_BEBR_CACHE"
_DEFAULT_CACHE = os.path.join("~", ".cache", "repro-bebr")


class BinarizerCheckpoint(NamedTuple):
    """A trained binarizer, plus where it came from.

    ``params``/``bn_state`` are drop-in for the same fields of a full
    ``TrainState`` — ``encode_codes``, ``make_encode_fn`` and the
    ``old`` argument of ``bc_train_binarizer`` read nothing else.
    ``trained`` is False when the checkpoint was loaded from cache.
    """

    params: Any
    bn_state: Any
    digest: str
    path: str | None
    trained: bool


def resolve_cache_dir(cache_dir: str | None = None) -> str:
    """Explicit argument, else $REPRO_BEBR_CACHE, else ~/.cache."""
    if cache_dir:
        return os.path.expanduser(cache_dir)
    return os.path.expanduser(os.environ.get(CACHE_ENV) or _DEFAULT_CACHE)


def checkpoint_digest(
    docs: np.ndarray, cfg: TrainConfig, *, steps: int, batch: int, seed: int
) -> str:
    """Digest of everything that determines the trained weights."""
    h = hashlib.sha1()
    arr = np.ascontiguousarray(np.asarray(docs))
    h.update(str((arr.shape, str(arr.dtype))).encode())
    h.update(arr.tobytes())
    h.update(repr(cfg).encode())
    h.update(str((steps, batch, seed)).encode())
    return h.hexdigest()[:20]


def _template(cfg: TrainConfig, seed: int):
    params, bn_state = init_binarizer(jax.random.PRNGKey(seed), cfg.binarizer)
    return jax.tree_util.tree_flatten((params, bn_state))


def _load(path: str, cfg: TrainConfig, seed: int):
    tpl_leaves, treedef = _template(cfg, seed)
    with np.load(path) as z:
        if len(z.files) != len(tpl_leaves):
            raise ValueError("leaf count mismatch")
        leaves = []
        for i, tpl in enumerate(tpl_leaves):
            leaf = z[f"leaf_{i:03d}"]
            if leaf.shape != tpl.shape:
                raise ValueError("leaf shape mismatch")
            leaves.append(jnp.asarray(leaf))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _save(path: str, params, bn_state) -> None:
    leaves, _ = jax.tree_util.tree_flatten((params, bn_state))
    # np.savez appends ".npz" to names missing it — keep it on the tmp
    # file so the rename target is what was actually written.
    tmp = f"{path}.{os.getpid()}.tmp.npz"
    np.savez(
        tmp, **{f"leaf_{i:03d}": np.asarray(x) for i, x in enumerate(leaves)}
    )
    os.replace(tmp, path)


def trained_binarizer(
    docs: np.ndarray,
    cfg: TrainConfig,
    *,
    steps: int = 300,
    batch: int = 256,
    seed: int = 0,
    cache_dir: str | None = None,
) -> BinarizerCheckpoint:
    """Train a recurrent-MLP binarizer, or reload the cached weights.

    On a cache hit the returned params are bit-identical to the run
    that wrote the checkpoint; a stale or corrupt file (wrong leaf
    count/shape after a config drift that somehow digested equal, or a
    truncated archive) is treated as a miss and overwritten.
    """
    digest = checkpoint_digest(docs, cfg, steps=steps, batch=batch, seed=seed)
    root = resolve_cache_dir(cache_dir)
    path = os.path.join(root, f"binarizer-{digest}.npz")
    if os.path.exists(path):
        try:
            params, bn_state = _load(path, cfg, seed)
            return BinarizerCheckpoint(params, bn_state, digest, path, False)
        except Exception:
            pass  # fall through to retrain

    state = init_train_state(jax.random.PRNGKey(seed), cfg)
    step = jax.jit(functools.partial(train_step, cfg=cfg))
    gen = synthetic.pair_batches(docs, seed + 1, batch)
    for _ in range(steps):
        a, p = next(gen)
        state, _ = step(state, a, p)

    os.makedirs(root, exist_ok=True)
    _save(path, state.params, state.bn_state)
    return BinarizerCheckpoint(state.params, state.bn_state, digest, path, True)
