"""Injectable time source for the serving tier's control loops.

Every control-loop behavior in the tier — probe backoff, retry backoff,
watchdog polls, autoscaler hysteresis and cooldowns — is a function of
*time*, and for years of wall-clock-tested control systems the lesson is
the same: testing them against the real clock makes every property slow
(sleep long enough to observe it) and flaky (the host decides how long a
"sleep" really was). This module makes time a dependency you inject:

  * ``Clock`` — the protocol: ``now()`` (monotonic seconds), ``sleep()``,
    and ``wait(event, timeout)`` — an *interruptible* sleep that returns
    the moment ``event`` is set. Loops must use ``wait`` with their stop
    event rather than ``sleep``, so a ``close()`` mid-backoff interrupts
    the wait instead of waiting out the full delay.
  * ``SystemClock`` — the production implementation: ``time.perf_counter``
    + ``time.sleep`` + ``threading.Event.wait``. A module singleton
    ``SYSTEM_CLOCK`` is the default everywhere, so threading a clock
    through a code path changes nothing until a test injects a fake.
  * ``FakeClock`` — simulated time under manual control: ``advance(dt)``
    moves the clock and wakes every thread blocked in ``sleep``/``wait``
    whose deadline has passed. ``wait_for_sleepers(n)`` blocks (briefly,
    in real time) until ``n`` threads are parked on the clock, which is
    how a test hands control back and forth with a loop deterministically:
    wait for the loop to park, advance exactly one interval, observe.

Invariants:

  * ``SystemClock.now`` IS ``time.perf_counter`` — deadlines computed
    from ``clock.now()`` stay comparable with the tier's existing
    ``perf_counter``-based ticket timestamps.
  * ``FakeClock`` never busy-waits and never sleeps real time longer
    than its poll quantum (default 5 ms, far under the suite's 50 ms
    real-sleep budget); time moves only when ``advance`` is called.
  * ``wait`` is level-triggered on the event: an event already set
    returns True immediately, on both implementations.
"""

from __future__ import annotations

import threading
import time
from typing import Optional, Protocol, runtime_checkable


@runtime_checkable
class Clock(Protocol):
    """What the tier's control loops need from a time source."""

    def now(self) -> float:
        """Monotonic seconds (comparable with ``time.perf_counter`` on
        the system implementation)."""
        ...

    def sleep(self, seconds: float) -> None:
        """Block this thread for ``seconds`` of clock time."""
        ...

    def wait(self, event: threading.Event, timeout: Optional[float]) -> bool:
        """Interruptible sleep: block until ``event`` is set (True) or
        ``timeout`` clock-seconds pass (False). ``None`` waits forever."""
        ...


class SystemClock:
    """The real clock: ``perf_counter`` / ``sleep`` / ``Event.wait``."""

    def now(self) -> float:
        return time.perf_counter()

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds)

    def wait(self, event: threading.Event, timeout: Optional[float]) -> bool:
        return event.wait(timeout)


#: Default clock for every control loop; inject a ``FakeClock`` in tests.
SYSTEM_CLOCK = SystemClock()


class FakeClock:
    """Manually advanced simulated time with waiter wakeup.

    ``now()`` returns the simulated instant; ``advance(dt)`` moves it
    forward and wakes every parked ``sleep``/``wait`` whose deadline has
    passed. Threads blocked in ``wait(event, ...)`` also notice the
    event being set from any thread within one poll quantum (a short
    *real* condition wait re-checks it), so production code that
    interrupts a backoff via ``event.set()`` works unmodified under the
    fake — no test hook needed at the set site.

    ``start`` deliberately defaults to a large offset rather than 0.0:
    code that mixes ``clock.now()`` deadlines with unconverted
    ``time.perf_counter()`` reads would "work" at small fake times and
    only break on long-lived processes; starting high makes that class
    of bug loud in tests instead.
    """

    def __init__(self, start: float = 1_000_000.0, poll_s: float = 0.005):
        self._t = float(start)
        self._cond = threading.Condition()
        self._poll_s = poll_s
        self._sleepers = 0
        self._parks = 0

    def now(self) -> float:
        with self._cond:
            return self._t

    def advance(self, dt: float) -> None:
        """Move simulated time forward by ``dt`` and wake all waiters."""
        if dt < 0:
            raise ValueError(f"cannot advance time backwards (dt={dt})")
        with self._cond:
            self._t += dt
            self._cond.notify_all()

    @property
    def sleepers(self) -> int:
        """Number of threads currently parked in ``sleep``/``wait``."""
        with self._cond:
            return self._sleepers

    def wait_for_sleepers(self, n: int, *, timeout: float = 10.0) -> bool:
        """Block (real time) until >= ``n`` threads are parked on this
        clock; the test-side handshake that makes advance() race-free."""
        with self._cond:
            return self._cond.wait_for(lambda: self._sleepers >= n, timeout)

    def tick(self, dt: float, *, timeout: float = 10.0) -> None:
        """Lockstep advance for driving ONE control loop: wait for a
        thread to park on the clock, move time forward by ``dt``, then
        block (real time) until some thread parks again — i.e. the loop
        woke, did one iteration's work, and came back to its wait. With
        a single loop on the clock this hands it exactly one tick; with
        several, use ``wait_for_sleepers`` + ``advance`` by hand.

        Raises ``TimeoutError`` if no thread parks within ``timeout``
        real seconds on either side of the advance (loop dead/wedged).
        """
        with self._cond:
            if not self._cond.wait_for(lambda: self._sleepers >= 1, timeout):
                raise TimeoutError(
                    f"tick({dt}): no thread parked on the clock within "
                    f"{timeout}s")
            before = self._parks
            self._t += max(0.0, dt)
            self._cond.notify_all()
            if not self._cond.wait_for(lambda: self._parks > before, timeout):
                raise TimeoutError(
                    f"tick({dt}): no thread re-parked within {timeout}s "
                    "after the advance (loop exited or wedged?)")

    def sleep(self, seconds: float) -> None:
        with self._cond:
            deadline = self._t + max(0.0, seconds)
            self._sleepers += 1
            self._parks += 1
            self._cond.notify_all()  # wake wait_for_sleepers watchers
            try:
                while self._t < deadline:
                    # Poll quantum only as a lost-wakeup safety net;
                    # advance() notifies, so the common path never waits
                    # out the quantum.
                    self._cond.wait(self._poll_s)
            finally:
                self._sleepers -= 1
                self._cond.notify_all()

    def wait(self, event: threading.Event, timeout: Optional[float]) -> bool:
        with self._cond:
            deadline = None if timeout is None else self._t + max(0.0, timeout)
            self._sleepers += 1
            self._parks += 1
            self._cond.notify_all()
            try:
                while True:
                    if event.is_set():
                        return True
                    if deadline is not None and self._t >= deadline:
                        return False
                    # Short real wait: re-checks the event (set() does
                    # not notify this condition) and is cut short by
                    # advance()'s notify.
                    self._cond.wait(self._poll_s)
            finally:
                self._sleepers -= 1
                self._cond.notify_all()
