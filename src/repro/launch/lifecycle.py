"""Live index lifecycle: versioned snapshots, rolling swap, canary revival.

The paper's production engine re-indexes continuously and serves
"multiple embedding versions within a unified system" (compatible
training, §4); a frozen corpus is a reproduction artifact, not a design
property. This module turns the replicated serving tier
(``launch/proxy.py``) into a system whose corpus — and embedding
version — can change under live traffic:

  * ``CorpusSnapshot`` — an immutable corpus capture (unpacked codes +
    level count + embedding-version tag) with a content ``digest``, the
    unit the offline indexing pipeline hands to the serving tier.
  * ``IndexVersion`` — what a replica is actually serving: corpus
    digest + embedding-version tag + index kind + build params. Two
    replicas with equal ``IndexVersion``s are bit-identical by
    construction (every builder is deterministic in its params), which
    is what keeps routing invisible to correctness mid-swap.
  * ``IndexBuilder`` protocol — ``build(snapshot, replica=i) ->
    SearchFn``; one protocol fronts every index family via the
    rebuild-from-snapshot entry points (``flat.flat_search_from_
    snapshot``, ``ivf.ivf_search_from_snapshot``, ``hnsw_lite.hnsw_
    search_from_snapshot``, ``engine.*_search_from_snapshot`` for
    replicas on their own submeshes).
  * ``RollingSwapController`` — re-indexes a live tier one replica at a
    time: drain (the router stops routing there; in-flight tickets
    finish or re-dispatch through the existing failover path), quiesce
    the pipeline, rebuild from the snapshot, warm the fresh program
    (``serving.warmup_replicas`` — worker threads carry thread-local
    jit caches), hot-swap it in, bump the stats generation, and canary-
    probe the replica back into rotation. The surviving replicas serve
    the whole stream meanwhile.

Invariants (``tests/test_lifecycle.py``):

  * **Zero loss, zero reorder** — a rolling swap under continuous
    traffic completes with every submitted batch answered, in
    submission order (FIFO per client), for flat, IVF, and HNSW.
  * **Bit-identity across the swap** — while old and new indexes are
    version-equivalent (same snapshot digest + params), every result
    equals ``serve_sequential``'s, before, during, and after the swap;
    when versions genuinely differ, each batch is served entirely by
    one version (``ServingPipeline.swap_fns`` swaps between batches,
    never inside one).
  * **First-wins ticket resolution** — drain re-dispatch reuses the
    failover path, so a late result from the draining replica and the
    re-dispatched copy race safely: exactly one resolution sticks.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import inspect
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Protocol, Tuple

import numpy as np

from repro.launch import serving
from repro.launch.proxy import AllReplicasDown, IncompatibleVersion, QueryRouter
from repro.launch.serving import (
    DeadlineExpired,
    EncodeFn,
    RequestShed,
    SearchFn,
)

#: Minimum acceptable recall@k for cross-version traffic served through a
#: bc-trained compat encoder (the serving-tier face of the offline floor
#: ``tests/test_compat.py`` asserts). The upgrade bench row records it and
#: ``scripts/check_bench_gate.py`` enforces per-version recall >= floor
#: throughout a live migration.
COMPAT_RECALL_FLOOR = 0.55


# ---------------------------------------------------------------------------
# snapshots + versions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class CorpusSnapshot:
    """One immutable corpus capture handed to the serving tier.

    ``codes`` are the UNPACKED recurrent-binary codes ([N, D] int8) of
    the whole corpus under one embedding version — builders derive
    everything else (inverse norms, nibble packing, cluster/graph
    structure) deterministically from here. Equality/hash go through
    the content ``digest`` (the dataclass-generated ones would trip
    over the ndarray field), so "same digest == same corpus" holds for
    ``==`` and dict keys too.
    """

    codes: Any  # [N, D] int8 (np or jax array, or np.memmap for cold tiers)
    n_levels: int
    embedding_version: str = "v0"

    def __eq__(self, other) -> bool:
        return (isinstance(other, CorpusSnapshot)
                and self.n_levels == other.n_levels
                and self.embedding_version == other.embedding_version
                and self.digest == other.digest)

    def __hash__(self) -> int:
        return hash((self.digest, self.n_levels, self.embedding_version))

    def spilled(self, path) -> "CorpusSnapshot":
        """A content-equal snapshot whose codes live in a read-only
        ``np.memmap`` at ``path``.

        This is the cold-tier handoff for bi-granular serving: builders
        keep numpy fine codes host-side and read only the per-query
        survivor rows, so a spilled snapshot lets the full-level tier
        exceed RAM while the packed coarse tier stays hot. Same bytes,
        same ``digest`` — swapping a replica between the in-memory and
        spilled forms of one corpus is version-equivalent, so the
        rolling swap's bit-identity guarantee carries over.
        """
        arr = np.ascontiguousarray(np.asarray(self.codes))
        mm = np.memmap(path, dtype=arr.dtype, mode="w+", shape=arr.shape)
        mm[:] = arr
        mm.flush()
        ro = np.memmap(path, dtype=arr.dtype, mode="r", shape=arr.shape)
        return CorpusSnapshot(codes=ro, n_levels=self.n_levels,
                              embedding_version=self.embedding_version)

    @functools.cached_property
    def digest(self) -> str:
        """Content hash of the codes (shape + bytes): the corpus half of
        an ``IndexVersion``. Same digest == same corpus, so a swap to an
        equal-version snapshot is provably bit-identical. Cached — a
        rolling swap consults it ~2N+1 times and a production corpus is
        big; the snapshot is immutable, so one hash is the right number
        (cached_property bypasses the frozen-dataclass setattr)."""
        arr = np.ascontiguousarray(np.asarray(self.codes))
        h = hashlib.sha1()
        h.update(str(arr.shape).encode())
        h.update(str(arr.dtype).encode())
        h.update(arr.tobytes())
        return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class IndexVersion:
    """What a replica serves: corpus digest + embedding version + build
    params. Hashable and comparable — the router's per-replica stats
    carry ``tag`` so dashboards can watch a swap roll through the tier."""

    corpus_digest: str
    embedding_version: str
    index_kind: str
    build_params: Tuple[Tuple[str, Any], ...]

    @property
    def tag(self) -> str:
        return (f"{self.index_kind}:{self.embedding_version}"
                f":{self.corpus_digest[:12]}")


# ---------------------------------------------------------------------------
# index builders (one protocol, every index family)
# ---------------------------------------------------------------------------


class IndexBuilder(Protocol):
    """Rebuild a serving ``SearchFn`` from a corpus snapshot.

    ``replica`` lets placement-aware builders (the distributed engine,
    one submesh per replica) target the replica being swapped; plain
    single-host builders ignore it. Builders must be deterministic in
    (snapshot, params): the rolling swap's bit-identity guarantee for
    equal versions rests on it.
    """

    kind: str
    params: Dict[str, Any]

    def build(self, snapshot: CorpusSnapshot, *,
              replica: int = 0) -> SearchFn: ...


def builder_version(builder: "IndexBuilder",
                    snapshot: CorpusSnapshot) -> IndexVersion:
    """The ``IndexVersion`` that ``builder.build(snapshot)`` serves."""
    return IndexVersion(
        corpus_digest=snapshot.digest,
        embedding_version=snapshot.embedding_version,
        index_kind=builder.kind,
        build_params=tuple(sorted(
            (k, v) for k, v in builder.params.items()
            if isinstance(v, (int, float, str, bool, type(None)))
        )),
    )


def _rerank_params(coarse_levels, k_coarse):
    """Validate a builder's scalar bi-granular knobs; dict-or-None.

    Builders take the two scalars (not the ``rerank={...}`` dict) so the
    knobs flow through ``builder_version``'s scalar filter and show up
    in the ``IndexVersion`` — a tiered and a single-tier build of the
    same snapshot must never be considered version-equivalent. Range
    checks against ``n_levels`` happen in the entry points
    (``_snapshot.resolve_rerank_args``); here only the pairing is
    enforced, at construction time.
    """
    if (coarse_levels is None) != (k_coarse is None):
        raise ValueError(
            "coarse_levels and k_coarse must be set together "
            f"(got coarse_levels={coarse_levels}, k_coarse={k_coarse})"
        )
    if coarse_levels is None:
        return None
    return {"coarse_levels": int(coarse_levels), "k_coarse": int(k_coarse)}


class _SnapshotCachingBuilder:
    """Digest-keyed one-entry build cache shared by the single-host
    builders: replicas on one host share index arrays (exactly like the
    pre-swap ``[(encode, search)] * N`` tier), so a rolling swap over N
    replicas rebuilds the identical index ONCE — not N times, and not N
    device copies — and each subsequent replica's swap window shrinks to
    warm + probe. Subclasses implement ``_build(snapshot)``."""

    def __init__(self):
        self._cache: Dict[str, SearchFn] = {}

    def build(self, snapshot: CorpusSnapshot, *, replica: int = 0) -> SearchFn:
        key = snapshot.digest
        if key not in self._cache:
            self._cache.clear()  # hold at most one snapshot's index
            self._cache[key] = self._build(snapshot)
        return self._cache[key]


class FlatBuilder(_SnapshotCachingBuilder):
    """Exhaustive flat index (``flat.flat_search_from_snapshot``).

    ``coarse_levels``/``k_coarse`` (set together) switch the build to
    bi-granular mode: packed hot coarse scan + cold fine rerank — same
    convention on every builder; see the entry point's docstring.

    ``block_plan`` (a ``BlockPlan`` or ``{kind: plan}`` mapping from
    ``launch/autotune``) sets tuned launch shapes on every builder that
    takes it. Plans never change scores, and being non-scalar they stay
    out of ``builder_version`` — a tuned and an untuned build of the
    same snapshot ARE version-equivalent, by design.
    """

    kind = "flat"

    def __init__(self, *, k: int = 10, packed: bool = False,
                 backend: str = "xla", block_n: int = 512,
                 coarse_levels: int = None, k_coarse: int = None,
                 block_plan=None):
        super().__init__()
        self._rerank = _rerank_params(coarse_levels, k_coarse)
        self.params = dict(k=k, packed=packed, backend=backend,
                           block_n=block_n, coarse_levels=coarse_levels,
                           k_coarse=k_coarse, block_plan=block_plan)

    def _build(self, snapshot: CorpusSnapshot) -> SearchFn:
        from repro.index.flat import flat_search_from_snapshot

        p = {k: v for k, v in self.params.items()
             if k not in ("coarse_levels", "k_coarse")}
        return flat_search_from_snapshot(snapshot, rerank=self._rerank, **p)


class IVFBuilder(_SnapshotCachingBuilder):
    """IVF index, re-clustered per snapshot (``ivf_search_from_snapshot``).

    ``probe_budget`` switches the served closure to occupancy-weighted
    probe allocation (a global budget of per-centroid rank slots instead
    of a flat per-query ``nprobe``; see ``index.ivf.search_budget``).
    It is a scalar, so it flows through ``builder_version`` — a budgeted
    and a flat-nprobe build are never version-equivalent.
    """

    kind = "ivf"

    def __init__(self, *, k: int = 10, nlist: int = 64, nprobe: int = 32,
                 seed: int = 0, kmeans_iters: int = 20,
                 packed: bool = False, backend: str = "xla",
                 coarse_levels: int = None, k_coarse: int = None,
                 probe_budget: int = None, block_plan=None):
        super().__init__()
        self._rerank = _rerank_params(coarse_levels, k_coarse)
        self.params = dict(k=k, nlist=nlist, nprobe=nprobe, seed=seed,
                           kmeans_iters=kmeans_iters, packed=packed,
                           backend=backend, coarse_levels=coarse_levels,
                           k_coarse=k_coarse, probe_budget=probe_budget,
                           block_plan=block_plan)

    def _build(self, snapshot: CorpusSnapshot) -> SearchFn:
        from repro.index.ivf import ivf_search_from_snapshot

        p = {k: v for k, v in self.params.items()
             if k not in ("coarse_levels", "k_coarse")}
        return ivf_search_from_snapshot(snapshot, rerank=self._rerank, **p)


class HNSWBuilder(_SnapshotCachingBuilder):
    """NSW graph, rebuilt per snapshot (``hnsw_search_from_snapshot``).

    The host-side graph build is O(N^2), which makes the digest cache
    matter most here."""

    kind = "hnsw"

    def __init__(self, *, k: int = 10, M: int = 16,
                 ef_construction: int = 64, ef: int = 64, beam: int = 8,
                 max_hops: int = 64, seed: int = 0, packed: bool = False,
                 backend: str = "xla",
                 coarse_levels: int = None, k_coarse: int = None,
                 block_plan=None):
        super().__init__()
        self._rerank = _rerank_params(coarse_levels, k_coarse)
        self.params = dict(k=k, M=M, ef_construction=ef_construction,
                           ef=ef, beam=beam, max_hops=max_hops, seed=seed,
                           packed=packed, backend=backend,
                           coarse_levels=coarse_levels, k_coarse=k_coarse,
                           block_plan=block_plan)

    def _build(self, snapshot: CorpusSnapshot) -> SearchFn:
        from repro.index.hnsw_lite import hnsw_search_from_snapshot

        p = {k: v for k, v in self.params.items()
             if k not in ("coarse_levels", "k_coarse")}
        return hnsw_search_from_snapshot(snapshot, rerank=self._rerank, **p)


class EngineBuilder:
    """Distributed engine replicas, one submesh per replica.

    ``meshes[i]`` is replica i's submesh (``mesh.make_replica_meshes``);
    ``build`` shards the snapshot over THAT replica's leaves and returns
    the shard_map program closed over its device-placed inputs. ``index``
    picks the leaf algorithm: "flat" (exhaustive leaf scan) or "hnsw"
    (batched-frontier graph per leaf; the host-side sharded graph is
    built once per snapshot digest and shared by every replica — the
    leaf layout is identical, only device placement differs).
    """

    def __init__(self, meshes: List[Any], *, index: str = "flat",
                 n_levels: int, k: int = 10, backend: str = "auto",
                 packed: bool = False, shard_axes=("data", "model"),
                 M: int = 16, ef_construction: int = 48, ef: int = 64,
                 beam: int = 16, max_hops: int = 64, seed: int = 0,
                 coarse_levels: int = None, k_coarse: int = None,
                 block_plan=None):
        if index not in ("flat", "hnsw"):
            raise ValueError(f"EngineBuilder index must be flat|hnsw, "
                             f"got {index!r}")
        self._rerank = _rerank_params(coarse_levels, k_coarse)
        if self._rerank is not None and index != "flat":
            raise ValueError(
                "bi-granular rerank is only supported for the flat "
                "engine (per-leaf coarse scan + post-merge fine rerank); "
                f"got index={index!r}"
            )
        self.meshes = list(meshes)
        self.kind = f"engine-{index}"
        self.index = index
        self.params = dict(n_levels=n_levels, k=k, backend=backend,
                           packed=packed, M=M,
                           ef_construction=ef_construction, ef=ef,
                           beam=beam, max_hops=max_hops, seed=seed,
                           coarse_levels=coarse_levels, k_coarse=k_coarse)
        self.block_plan = block_plan
        self.shard_axes = tuple(shard_axes)
        # Digest-keyed host-side artifacts shared by every replica: the
        # per-leaf NSW graphs (hnsw) / packed codes + inv norms (flat).
        # Only device placement differs per replica.
        self._graph_cache: Dict[str, Any] = {}
        self._flat_cache: Dict[str, Any] = {}

    def _sharded_graph(self, snapshot: CorpusSnapshot, n_leaves: int):
        from repro.index.engine import sharded_graph_from_snapshot

        key = f"{snapshot.digest}:{n_leaves}"
        if key not in self._graph_cache:
            self._graph_cache.clear()
            self._graph_cache[key] = sharded_graph_from_snapshot(
                snapshot.codes, snapshot.n_levels, n_leaves=n_leaves,
                M=self.params["M"],
                ef_construction=self.params["ef_construction"],
                seed=self.params["seed"], packed=self.params["packed"],
            )
        return self._graph_cache[key]

    def _flat_inputs(self, snapshot: CorpusSnapshot):
        from repro.index.engine import flat_engine_inputs_from_snapshot

        c = self._rerank["coarse_levels"] if self._rerank else None
        packed = self.params["packed"] and (c is None or c <= 4)
        key = f"{snapshot.digest}:{c}"
        if key not in self._flat_cache:
            self._flat_cache.clear()
            self._flat_cache[key] = flat_engine_inputs_from_snapshot(
                snapshot.codes, snapshot.n_levels,
                packed=packed, coarse_levels=c,
            )
        return self._flat_cache[key]

    def build(self, snapshot: CorpusSnapshot, *, replica: int = 0) -> SearchFn:
        from repro.index import engine

        mesh = self.meshes[replica]
        p = self.params
        if self.index == "flat":
            return engine.engine_search_from_snapshot(
                mesh, snapshot, k=p["k"],
                shard_axes=self.shard_axes, backend=p["backend"],
                packed=p["packed"], prepared=self._flat_inputs(snapshot),
                rerank=self._rerank, block_plan=self.block_plan,
            )
        n_leaves = 1
        for ax in self.shard_axes:
            n_leaves *= mesh.shape[ax]
        return engine.hnsw_engine_search_from_snapshot(
            mesh, snapshot, k=p["k"],
            ef=p["ef"], beam=p["beam"], max_hops=p["max_hops"],
            shard_axes=self.shard_axes, backend=p["backend"],
            packed=p["packed"],
            sharded=self._sharded_graph(snapshot, n_leaves),
        )


#: Single-host builder registry (the engine builder needs meshes and is
#: constructed explicitly).
INDEX_BUILDERS = {
    FlatBuilder.kind: FlatBuilder,
    IVFBuilder.kind: IVFBuilder,
    HNSWBuilder.kind: HNSWBuilder,
}


class UnknownBuildParam(TypeError):
    """``make_builder`` was handed a kwarg its builder does not take.

    Typed (and raised at the registry boundary, naming the builder and
    its real parameters) instead of the bare ``TypeError`` the
    constructor would throw deep in the stack — an operator's
    ``--index ivf`` with an HNSW-only knob fails with the fix in the
    message."""


def make_builder(kind: str, **params) -> IndexBuilder:
    """Construct a single-host builder from the registry, kwargs checked.

    Unknown kwargs raise ``UnknownBuildParam`` listing the builder's
    accepted parameters — the registry is the API boundary CLI flags and
    config files funnel through, so a typo'd knob must fail here, not as
    a bare ``TypeError`` inside the constructor.
    """
    try:
        cls = INDEX_BUILDERS[kind]
    except KeyError:
        raise ValueError(
            f"unknown index builder {kind!r}; known: {sorted(INDEX_BUILDERS)}"
        ) from None
    known = [p for p in inspect.signature(cls.__init__).parameters
             if p != "self"]
    unknown = sorted(set(params) - set(known))
    if unknown:
        raise UnknownBuildParam(
            f"{cls.__name__} does not take {unknown} "
            f"(accepted: {sorted(known)})"
        )
    return cls(**params)


# ---------------------------------------------------------------------------
# rolling swap controller
# ---------------------------------------------------------------------------


class SwapFailed(RuntimeError):
    """A replica's post-rebuild canary probe failed; the replica is left
    ``unhealthy`` (the periodic re-probe may still revive it) and the
    rolling swap stops before touching the next replica."""


@dataclasses.dataclass
class SwapReport:
    """What a rolling swap did, per replica (timings in seconds)."""

    version: IndexVersion
    replicas: List[dict] = dataclasses.field(default_factory=list)
    total_s: float = 0.0

    @property
    def swapped(self) -> int:
        return len(self.replicas)


class RollingSwapController:
    """Re-index a live ``QueryRouter`` tier one replica at a time.

    Per replica: drain -> quiesce -> rebuild (``builder.build``) -> warm
    (``serving.warmup_replicas``) -> hot-swap + new stats generation ->
    canary probe -> back in rotation. Traffic keeps flowing to the
    survivors throughout; with a single-replica tier the router sheds
    (retryable ``RequestShed``) for the rebuild window instead.

    ``encode_fn``: the encode stage for the NEW embedding version; None
    keeps each replica's current encode (a corpus-only refresh).
    ``canary``: the health-probe batch (defaults to ``warm_batches[0]``).
    """

    def __init__(
        self,
        router: QueryRouter,
        builder: IndexBuilder,
        *,
        warm_batches: Optional[List[Any]] = None,
        canary: Any = None,
        encode_fn: Optional[EncodeFn] = None,
        drain_timeout: float = 30.0,
        quiesce_timeout: float = 30.0,
        probe_timeout: float = 60.0,
        on_event: Optional[Callable[[str], None]] = None,
    ):
        if canary is None and not warm_batches:
            raise ValueError("need a canary batch (or warm_batches)")
        self.router = router
        self.builder = builder
        self.warm_batches = warm_batches
        self.canary = canary if canary is not None else warm_batches[0]
        self.encode_fn = encode_fn
        self.drain_timeout = drain_timeout
        self.quiesce_timeout = quiesce_timeout
        self.probe_timeout = probe_timeout
        self._log = on_event or (lambda msg: None)

    def _claim(self, replica: int) -> None:
        """Move ``replica`` into 'rebuilding' from whatever lifecycle
        state it is in: drain it when healthy, claim it directly when
        dead (the swap then doubles as its revival — nothing is routed
        there), and wait out an in-flight canary probe (the background
        probe loop and the swap race over unhealthy replicas). Once
        'rebuilding', the probe loop cannot touch the replica, so the
        hand-off is atomic."""
        router = self.router
        deadline = time.perf_counter() + self.drain_timeout
        while True:
            st = router.states()[replica]
            try:
                if st == "rebuilding":
                    raise SwapFailed(
                        f"replica {replica} is already rebuilding "
                        "(another controller owns it)"
                    )
                if st == "probing":
                    # The probe resolves to healthy or unhealthy shortly:
                    # condition-wait on the state machine (woken by the
                    # transition itself) instead of sleep-polling.
                    remaining = deadline - time.perf_counter()
                    if remaining <= 0 or not router.wait_state(
                        replica, ("healthy", "unhealthy"),
                        timeout=remaining,
                    ):
                        raise SwapFailed(
                            f"replica {replica} still probing after "
                            f"{self.drain_timeout}s"
                        )
                    continue
                if st == "healthy":
                    router.drain(replica, timeout=self.drain_timeout)
                router.begin_rebuild(replica)  # draining|unhealthy
                return
            except ValueError:
                # state changed under us (a probe revived/parked the
                # replica between the read and the transition): re-read
                if time.perf_counter() >= deadline:
                    raise SwapFailed(
                        f"replica {replica} lifecycle state kept "
                        "changing; could not claim it for rebuild"
                    ) from None
                continue

    def swap_replica(self, replica: int, snapshot: CorpusSnapshot) -> dict:
        """Swap one replica to ``snapshot``; returns its report row."""
        router, log = self.router, self._log
        pipe = router.replicas.pipelines[replica]
        version = builder_version(self.builder, snapshot)

        t0 = time.perf_counter()
        log(f"replica {replica}: draining")
        self._claim(replica)  # ends with the replica in 'rebuilding'
        try:
            if not pipe.quiesce(timeout=self.quiesce_timeout):
                # Proxy tickets are gone (drained/re-dispatched) but an
                # inner batch is stuck on the pipeline; swapping under it
                # would race the scan stage.
                raise SwapFailed(
                    f"replica {replica} pipeline did not quiesce within "
                    f"{self.quiesce_timeout}s"
                )
            t_drain = time.perf_counter()

            log(f"replica {replica}: rebuilding ({version.tag})")
            search_fn = self.builder.build(snapshot, replica=replica)
            t_build = time.perf_counter()

            encode_fn = self.encode_fn or pipe.encode_fn
            if self.warm_batches:
                # Throwaway-pipeline warmup: worker threads carry
                # thread-local jit caches, so warming on this thread
                # alone is not enough.
                serving.warmup_replicas([(encode_fn, search_fn)],
                                        self.warm_batches)
            t_warm = time.perf_counter()

            pipe.swap_fns(encode_fn=encode_fn, search_fn=search_fn)
            generation = pipe.new_generation()
            router.set_version(replica, version)
        except BaseException as e:
            # An aborted swap must not strand the replica in a transient
            # state no probe targets (draining/rebuilding would be
            # one-strike-forever all over again) — park it unhealthy so
            # the canary re-probe can reclaim it once the cause clears.
            router.mark_unhealthy(replica, e)
            raise

        log(f"replica {replica}: probing")
        if not router.probe(replica, self.canary, timeout=self.probe_timeout,
                            from_rebuild=True):
            raise SwapFailed(
                f"replica {replica} failed its post-swap canary probe "
                f"(left unhealthy; version {version.tag})"
            )
        t_end = time.perf_counter()
        log(f"replica {replica}: healthy (generation {generation})")
        return {
            "replica": replica,
            "version": version.tag,
            "generation": generation,
            "drain_s": t_drain - t0,
            "build_s": t_build - t_drain,
            "warm_s": t_warm - t_build,
            "probe_s": t_end - t_warm,
            "total_s": t_end - t0,
        }

    def swap_all(self, snapshot: CorpusSnapshot) -> SwapReport:
        """Rolling swap of every replica, one at a time, under traffic."""
        report = SwapReport(version=builder_version(self.builder, snapshot))
        t0 = time.perf_counter()
        for replica in range(len(self.router.replicas)):
            report.replicas.append(self.swap_replica(replica, snapshot))
        report.total_s = time.perf_counter() - t0
        return report


def run_stream_with_swap(
    router: QueryRouter,
    stream: List[Any],
    *,
    controller: Optional[RollingSwapController] = None,
    snapshot: Optional[CorpusSnapshot] = None,
    swap_after: int = 0,
    shed_retry_s: float = 1e-3,
    deadline_s: Optional[float] = None,
) -> Tuple[List[Any], Optional[SwapReport]]:
    """Drive a query stream through the tier, optionally swapping mid-way.

    The shared driver loop of ``launch/serve.py`` and
    ``examples/serve_bebr.py``: submits every batch (retrying retryable
    ``RequestShed`` — a burst, or a swap/probe holding the tier for an
    instant), kicks ``controller.swap_all(snapshot)`` on a helper thread
    after ``swap_after`` submissions, awaits every ticket in submission
    order, and re-raises a failed swap only after the stream has
    resolved. A failed swap that downs the tier mid-stream surfaces the
    swap's own error (the root cause), not the ``AllReplicasDown`` /
    ticket errors it triggered. Returns ``(results, SwapReport | None)``.

    ``deadline_s`` gives every batch a per-query deadline that many
    seconds after its first submit attempt; a batch the tier sheds as
    expired lands as ``None`` in the results (the stream keeps going —
    a missed budget is an answer, not a tier failure).

    The shed-retry pause runs on the router's injected clock and is
    interruptible by ``router.close()`` — a teardown mid-stream no
    longer waits out ``shed_retry_s`` (and a ``FakeClock`` tier
    advances through it without real sleeping). A close that lands
    during the pause surfaces as ``PipelineClosed`` from the next
    submit.
    """
    if controller is not None and swap_after and swap_after >= len(stream):
        # Misconfiguration, not a quiet no-op — and caught BEFORE the
        # workload runs, not after minutes of serving.
        raise ValueError(
            f"swap_after={swap_after} would never fire: the stream has "
            f"only {len(stream)} batches"
        )
    swap_state: dict = {}
    swap_thread: Optional[threading.Thread] = None

    def run_swap():
        try:
            swap_state["report"] = controller.swap_all(snapshot)
        except BaseException as e:  # surfaced after the stream
            swap_state["error"] = e

    tickets = []
    downstream_error: Optional[BaseException] = None
    for n_submitted, batch in enumerate(stream):
        if controller is not None and swap_after \
                and n_submitted == swap_after:
            swap_thread = threading.Thread(target=run_swap, daemon=True)
            swap_thread.start()
        deadline = (
            None if deadline_s is None
            else router.clock.now() + deadline_s
        )
        while downstream_error is None:
            try:
                tickets.append(router.submit(batch, deadline=deadline))
                break
            except DeadlineExpired:
                tickets.append(None)  # budget spent waiting out sheds
                break
            except RequestShed:
                # Interruptible: router.close() sets _close_event, so a
                # teardown mid-pause wakes immediately (the next submit
                # raises PipelineClosed); a FakeClock advances through
                # it without real sleeping.
                router.clock.wait(router._close_event, shed_retry_s)
            except (AllReplicasDown, IncompatibleVersion) as e:
                # Tier down, or a versioned batch no replica can ever
                # serve: terminal either way — stop submitting.
                downstream_error = e
        if downstream_error is not None:
            break
    results = []
    try:
        for t in tickets:
            if t is None:
                results.append(None)
                continue
            try:
                results.append(t.result())
            except DeadlineExpired:
                if deadline_s is None:
                    raise  # caller-provided deadlines surface as errors
                results.append(None)  # a missed budget, not a failure
    except BaseException as e:
        downstream_error = downstream_error or e
    if swap_thread is not None:
        swap_thread.join()
    if "error" in swap_state:
        raise swap_state["error"]
    if downstream_error is not None:
        raise downstream_error
    return results, swap_state.get("report")
