"""Double-buffered async serving pipeline (paper Fig. 5's proxy stage).

``launch/serve.py`` historically trained, encoded, and scored one batch
at a time on one thread: the device scan sat idle while the host
binarized the next query batch. This module closes that gap with a
two-stage pipeline plus a bounded admission queue:

  * **admission queue** (``AdmissionQueue``) — a bounded FIFO in front
    of the pipeline. ``policy="block"`` back-pressures the caller when
    full (batch clients); ``policy="shed"`` rejects instead (interactive
    traffic keeps bounded latency under bursts — the paper's proxy sheds
    rather than queueing unboundedly). Every admitted request carries
    its enqueue timestamp, so the reported latency is enqueue→reply, not
    just device time.
  * **encode stage** — a background thread pulls admitted requests and
    runs ``encode_fn`` (float embedding -> packed recurrent-binary
    codes, a host/jit binarize). This is the same
    thread-plus-bounded-queue machinery as ``data.pipeline
    .PrefetchLoader``: the hand-off queue holds ``encode_ahead``
    batches, so encode of batch t+1 overlaps the scan of batch t.
  * **scan stage** — a second thread pulls encoded batches and calls
    ``search_fn``. JAX dispatch is asynchronous, so the next scan is
    dispatched as soon as the in-flight window (``dispatch_ahead``
    scans at once) allows, and only then is the oldest awaited
    (``block_until_ready``) and its ticket resolved — the device never
    drains between batches.

Single encode thread, single scan thread, FIFO queues throughout:
results come back in submission order and are bit-identical to a
sequential encode+search loop (no cross-batch state anywhere).

``SearchFn`` is any ``codes -> (scores [Q, k], ids [Q, k])`` callable —
``FlatSDC.search`` closures, ``ivf.search`` closures,
``hnsw_lite.search_hnsw_batched`` closures, and the distributed
``engine.make_*_search`` functions all qualify, so one pipeline fronts
every index family.

The admission machinery (``AdmissionQueue``, ``Ticket``,
``LatencyStats``) is deliberately separable from the stage threads: a
``ServingPipeline`` is *one replica* — the replicated tier in
``launch/proxy.py`` composes N of them behind a ``QueryRouter`` and
reuses the same queue/policy/ticket semantics at the proxy level.

Invariants (the tests in ``tests/test_serving_pipeline.py``,
``tests/test_proxy_router.py`` and ``tests/test_lifecycle.py`` rely on
these; do not weaken them in a refactor):

  * **FIFO per client** — a client that awaits its tickets in
    submission order observes results in submission order. Both stages
    are single threads fed by FIFO queues, so there is no internal
    reordering to begin with.
  * **Bit-identity vs ``serve_sequential``** — the pipeline reorders
    *time*, never *math*: for the same (encode_fn, search_fn) and the
    same batches, every resolved ticket carries exactly the
    (scores, ids) the sequential encode->scan loop produces. No
    cross-batch state exists anywhere in the stages.
  * **First-wins ticket resolution** — ``Ticket._resolve`` is atomic
    and idempotent: the scan thread, a shutdown sweep, and a proxy
    failover re-dispatch may race to resolve one ticket, but exactly
    one value/error ever sticks and completion stats are recorded
    exactly once.
  * **Quiesce means quiet** — after ``quiesce()`` returns True, every
    admitted request has resolved and the stage threads are blocked on
    empty queues, so ``swap_fns``/``new_generation`` (the live index
    lifecycle in ``launch/lifecycle.py``) mutate nothing a stage is
    reading.
"""

from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from typing import Any, Callable, List, Optional, Protocol, Sequence, Tuple

import jax

Array = Any


class SearchFn(Protocol):
    """codes [Q, D(/2)] -> (scores [Q, k], ids [Q, k])."""

    def __call__(self, q_codes: Array) -> Tuple[Array, Array]: ...


EncodeFn = Callable[[Any], Array]


class RequestShed(RuntimeError):
    """Raised by ``submit`` when the admission queue is full (shed policy)."""


class PipelineClosed(RuntimeError):
    """Raised by ``submit`` after ``close`` — and surfaced by tickets whose
    request was still queued when a non-draining close tore the stage
    threads down."""


class DeadlineExpired(RuntimeError):
    """Surfaced by a ticket whose per-query deadline passed before its
    batch reached a stage (expired work is shed at dequeue, never
    scanned). NOT retryable: the deadline is the client's, and retrying
    against the same deadline cannot succeed."""


class ScanStalled(RuntimeError):
    """A dispatched scan exceeded the watchdog's budget without
    completing (a hung — not raising — search). The proxy tier treats
    it like a replica failure: mark unhealthy, re-dispatch in-flight
    work to the survivors."""


class IncompatibleVersion(RuntimeError):
    """A versioned request reached a tier with healthy replicas but no
    replica serving the request's embedding version — natively or
    through a registered compat encoder. NOT retryable: unlike
    ``RequestShed`` (queue pressure, transient) this is a configuration
    gap; retrying against the same tier cannot succeed until an index
    swap or a ``CompatibilityMatrix.register`` changes what is
    reachable."""


@dataclasses.dataclass(frozen=True)
class SearchRequest:
    """Typed request: what to search, under which embedding version.

    Exactly one of ``queries`` (float embeddings [B, dim] — encoded by
    the serving replica) or ``codes`` (pre-packed int codes [B, D] —
    the encode stage is bypassed) must be set.

    embedding_version — version tag of the model that produced the
        queries (None = unversioned: routes anywhere, today's default).
        The router matches it against each replica's
        ``IndexVersion.embedding_version`` and falls back to a
        ``CompatibilityMatrix`` encoder when no native replica is
        routable — degrading by version before shedding.
    k               — optional per-request truncation of the index's
        configured top-k (k <= index k; None = index default, and the
        bit-identity invariant vs ``serve_sequential`` holds only then).
    deadline        — absolute ``time.perf_counter()`` instant, same
        semantics as the ``submit(..., deadline=)`` kwarg (which wins
        when both are given).
    effort          — optional advisory effort-level hint (see
        ``proxy.EffortKnob``): the router degrades the shared knob at
        least this far before dispatch. Coarse: the knob is shared by
        the whole tier, so a hint can speed up neighbours too.
    encode_override — replica-internal: the compat encoder chosen by the
        router for a cross-version dispatch. Clients leave it None.
    """

    queries: Any = None
    codes: Any = None
    embedding_version: Optional[str] = None
    k: Optional[int] = None
    deadline: Optional[float] = None
    effort: Optional[int] = None
    encode_override: Optional[EncodeFn] = None

    def __post_init__(self):
        if (self.queries is None) == (self.codes is None):
            raise ValueError(
                "SearchRequest takes exactly one of queries= or codes="
            )
        if self.k is not None and self.k < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")

    @property
    def payload(self) -> Any:
        return self.queries if self.queries is not None else self.codes

    @property
    def n_queries(self) -> int:
        return int(getattr(self.payload, "shape", (1,))[0])


def as_search_request(batch: Any, *,
                      deadline: Optional[float] = None) -> SearchRequest:
    """Normalize a bare query batch to a ``SearchRequest``.

    The back-compat shim: every ``submit`` accepts either form, so
    pre-existing callers (and the bit-identity tests) keep passing
    arrays. An explicit ``deadline=`` kwarg wins over the request's own
    field; a bare batch becomes an unversioned float-query request.
    """
    if isinstance(batch, SearchRequest):
        if deadline is not None and deadline != batch.deadline:
            return dataclasses.replace(batch, deadline=deadline)
        return batch
    return SearchRequest(queries=batch, deadline=deadline)


@dataclasses.dataclass(frozen=True)
class SearchResult:
    """Typed result: scores/ids plus serving provenance.

    Unpacks like the legacy ``(scores, ids)`` tuple (``vals, ids =
    result`` and ``result[0]``/``result[1]`` both work), so drivers
    written against ``Ticket.result()`` need no changes.

    served_by_version — embedding version of the index that actually
        answered (may differ from the request's version during a compat
        window); None when the tier is unversioned.
    replica     — replica id that answered (None below the proxy tier).
    generation  — that replica's index generation at dispatch.
    compat_encoded — True when the query crossed versions through a
        ``CompatibilityMatrix`` encoder rather than a native replica.
    reranked    — True when the answering index served in bi-granular
        mode (coarse scan + fine rerank) rather than a single-tier scan.
    """

    scores: Array
    ids: Array
    served_by_version: Optional[str] = None
    replica: Optional[int] = None
    generation: Optional[int] = None
    compat_encoded: bool = False
    reranked: bool = False

    def __iter__(self):
        return iter((self.scores, self.ids))

    def __getitem__(self, i):
        return (self.scores, self.ids)[i]

    def __len__(self):
        return 2


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Knobs for ``ServingPipeline`` (see module docstring).

    queue_depth    — admission-queue capacity (requests, not batches).
    policy         — "block": submit back-pressures when full;
                     "shed": submit raises ``RequestShed`` instead.
    encode_ahead   — encoded batches buffered between the stages (>= 1;
                     1 is classic double buffering).
    dispatch_ahead — scans in flight on the device at once (>= 1).
                     1 keeps device work strictly serial (encode still
                     overlaps); >1 dispatches ahead of the oldest await,
                     which hides dispatch latency on devices with a
                     command queue but can cache-thrash a shared-core
                     CPU when the corpus is bigger than cache.
    """

    queue_depth: int = 8
    policy: str = "block"
    encode_ahead: int = 1
    dispatch_ahead: int = 1

    def __post_init__(self):
        if self.policy not in ("block", "shed"):
            raise ValueError(f"policy must be block|shed, got {self.policy!r}")
        if self.queue_depth < 1 or self.encode_ahead < 1 or self.dispatch_ahead < 1:
            raise ValueError("queue_depth/encode_ahead/dispatch_ahead must be >= 1")


class Ticket:
    """Handle for one submitted batch; resolves to (scores, ids).

    ``deadline`` is an absolute ``time.perf_counter()`` instant (None =
    no deadline): a stage that dequeues the batch after it has passed
    sheds the ticket with ``DeadlineExpired`` instead of scanning it.
    """

    def __init__(self, seq: int, n_queries: int,
                 deadline: Optional[float] = None):
        self.seq = seq
        self.n_queries = n_queries
        self.deadline = deadline
        self.t_enqueue = time.perf_counter()
        self.t_reply: Optional[float] = None
        # The typed request this ticket was admitted with (None for a
        # bare-batch shim admit); cleared on resolve so a retained
        # ticket does not pin the query arrays.
        self.request: Optional[SearchRequest] = None
        # Serving provenance, populated at dispatch (replica tier) or
        # via the resolve's provenance argument (proxy tier, where
        # racing failover re-dispatches mean only the winning resolve
        # may write them).
        self.served_by_version: Optional[str] = None
        self.served_by_replica: Optional[int] = None
        self.served_by_generation: Optional[int] = None
        self.compat_encoded = False
        self.reranked = False
        self._done = threading.Event()
        self._value: Any = None
        self._error: Optional[BaseException] = None
        self._resolve_lock = threading.Lock()
        self._callbacks: List[Callable[["Ticket"], None]] = []

    def _resolve(self, value=None, error: Optional[BaseException] = None,
                 provenance: Optional[tuple] = None) -> bool:
        # Atomic first-wins: the scan thread and a shutdown sweep may
        # race to resolve the same ticket; it never resolves twice and
        # a stored value is never clobbered. Returns True to the winner
        # (so completion stats are recorded exactly once).
        # ``provenance`` = (replica, version, generation, compat,
        # reranked): the proxy tier passes it here, under the same lock,
        # because two racing inner resolutions (failover re-dispatch)
        # must not let the loser overwrite the winner's serving
        # provenance.
        with self._resolve_lock:
            if self._done.is_set():
                return False
            if provenance is not None:
                (self.served_by_replica, self.served_by_version,
                 self.served_by_generation, self.compat_encoded,
                 self.reranked) = provenance
            self.t_reply = time.perf_counter()
            self._value, self._error = value, error
            self.request = None
            self._done.set()
            callbacks, self._callbacks = self._callbacks, []
        # Outside the lock: a callback may re-enter ticket/router state
        # (the proxy's failover re-dispatch does). Shielded: _resolve
        # runs on stage threads, and a raising callback would otherwise
        # kill the scan loop and strand every queued ticket behind it.
        for cb in callbacks:
            try:
                cb(self)
            except BaseException:
                pass
        return True

    def add_done_callback(self, fn: Callable[["Ticket"], None]) -> None:
        """Run ``fn(ticket)`` when the ticket resolves (immediately if it
        already has). The proxy tier uses this for eager failover: a
        replica's scan error is observed the moment the ticket fails,
        not when the client gets around to ``result()``."""
        with self._resolve_lock:
            if not self._done.is_set():
                self._callbacks.append(fn)
                return
        fn(self)

    def done(self) -> bool:
        return self._done.is_set()

    def expired(self, now: Optional[float] = None) -> bool:
        """Has this ticket's deadline passed? (False when no deadline.)"""
        if self.deadline is None:
            return False
        return (time.perf_counter() if now is None else now) >= self.deadline

    def error(self) -> Optional[BaseException]:
        """The resolving error, or None (also None while unresolved)."""
        return self._error if self._done.is_set() else None

    def result(self, timeout: Optional[float] = None) -> Tuple[Array, Array]:
        if not self._done.wait(timeout):
            raise TimeoutError(f"ticket {self.seq} not ready after {timeout}s")
        if self._error is not None:
            raise self._error
        return self._value

    def search_result(self, timeout: Optional[float] = None) -> SearchResult:
        """``result()`` plus serving provenance, as a ``SearchResult``.

        The typed face of the same resolution: identical arrays (the
        raw tuple path stays bit-identical for legacy callers), wrapped
        with which version/replica/generation actually answered.
        """
        vals, ids = self.result(timeout)
        return SearchResult(
            scores=vals, ids=ids,
            served_by_version=self.served_by_version,
            replica=self.served_by_replica,
            generation=self.served_by_generation,
            compat_encoded=self.compat_encoded,
            reranked=self.reranked,
        )

    @property
    def latency_s(self) -> float:
        """Enqueue -> reply wall time (admission wait included)."""
        if self.t_reply is None:
            raise RuntimeError("ticket not resolved yet")
        return self.t_reply - self.t_enqueue


_SENTINEL = object()


class AdmissionQueue:
    """Bounded admission front: FIFO + block/shed policy + ticket minting.

    The reusable half of the serving stack — ``ServingPipeline`` puts one
    in front of its stage threads (one queue per replica), and the proxy
    tier reuses the same policy semantics across replicas (a proxy sheds
    only when *every* replica's AdmissionQueue is full).

    ``admit`` mints a ``Ticket`` (seq number, enqueue timestamp) and
    enqueues ``(ticket, payload)``. Consumers drain with ``get`` /
    ``get_nowait``; ``close`` marks the queue closed and pushes a
    sentinel so a consumer loop can terminate; ``sweep`` fails every
    still-queued ticket with ``PipelineClosed``.
    """

    def __init__(self, *, depth: int, policy: str):
        if policy not in ("block", "shed"):
            raise ValueError(f"policy must be block|shed, got {policy!r}")
        self.depth = depth
        self.policy = policy
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._lock = threading.Lock()
        self._seq = 0
        self._closed = False
        self.shed_count = 0

    @property
    def closed(self) -> bool:
        return self._closed

    def admit(self, payload: Any, *, force_block: bool = False,
              deadline: Optional[float] = None) -> Ticket:
        """Admit one payload; returns its ``Ticket``.

        block policy: waits for queue space (back-pressure).
        shed policy: raises ``RequestShed`` when the queue is full —
        unless ``force_block`` (the proxy's failover re-dispatch must
        not drop a ticket that was already admitted once).
        ``deadline``: absolute perf_counter instant after which the
        stages shed the batch at dequeue instead of serving it.
        """
        with self._lock:
            if self._closed:
                raise PipelineClosed("submit after close")
            seq = self._seq
            self._seq += 1
        if isinstance(payload, SearchRequest):
            n = payload.n_queries
        else:
            n = int(getattr(payload, "shape", (1,))[0])
        ticket = Ticket(seq, n, deadline=deadline)
        if isinstance(payload, SearchRequest):
            ticket.request = payload
        item = (ticket, payload)
        if self.policy == "shed" and not force_block:
            try:
                self._q.put_nowait(item)
            except queue.Full:
                with self._lock:
                    self.shed_count += 1
                raise RequestShed(
                    f"admission queue full (depth={self.depth})"
                ) from None
        else:
            self._q.put(item)
        return ticket

    def get(self):
        return self._q.get()

    def get_nowait(self):
        return self._q.get_nowait()

    def take_shed(self) -> int:
        """Return and zero the shed counter (generation rollover: the
        new generation's sheds must not be conflated with the old)."""
        with self._lock:
            n, self.shed_count = self.shed_count, 0
            return n

    def close(self) -> bool:
        """Mark closed; returns True on the first call only."""
        with self._lock:
            if self._closed:
                return False
            self._closed = True
            return True

    def push_sentinel(self):
        self._q.put(_SENTINEL)

    def sweep(self):
        """Drain the queue, failing every unconsumed ticket."""
        try:
            while True:
                item = self._q.get_nowait()
                if item is not _SENTINEL:
                    item[0]._resolve(error=PipelineClosed("pipeline closed"))
        except queue.Empty:
            pass


class LatencyStats:
    """Bounded completion accounting: exact totals + a latency window.

    Retaining whole tickets (and their result arrays) would grow without
    bound on a long-running pipeline, so completions are folded into
    running counters plus a sliding window of recent latencies for
    percentiles. ``window()`` exposes the raw window so the proxy tier
    can merge replicas into one report.
    """

    def __init__(self, window: int = 4096):
        self._lock = threading.Lock()
        self.n_completed = 0
        self.n_queries = 0
        self._latencies: "collections.deque" = collections.deque(maxlen=window)

    def record(self, ticket: Ticket):
        with self._lock:
            self.n_completed += 1
            self.n_queries += ticket.n_queries
            self._latencies.append(ticket.latency_s)

    def snapshot(self) -> Tuple[int, int, List[float]]:
        with self._lock:
            return self.n_completed, self.n_queries, list(self._latencies)

    def window(self) -> List[float]:
        with self._lock:
            return list(self._latencies)


class ServingPipeline:
    """Bounded-admission, double-buffered encode->scan serving pipeline.

    One pipeline is one *replica*: ``launch/proxy.py`` composes N of
    them behind a ``QueryRouter`` for the replicated tier.
    """

    def __init__(
        self,
        encode_fn: EncodeFn,
        search_fn: SearchFn,
        *,
        config: ServingConfig = ServingConfig(),
        scan_gate: Optional[threading.Lock] = None,
    ):
        """``scan_gate``: optional lock shared by co-located replicas.

        A real accelerator's command queue executes one program at a
        time, so N replicas on one device serialise naturally. XLA CPU
        does not — concurrent scans oversubscribe the host cores and
        thrash shared caches — so a ``ReplicaSet`` whose replicas share
        a device passes one lock to all pipelines and the scan stages
        take turns dispatching (encode still overlaps freely).
        """
        self.encode_fn = encode_fn
        self.search_fn = search_fn
        self.config = config
        # Embedding version of the index this replica currently serves
        # (provenance only — the ROUTING decision lives in the proxy's
        # version map). Set by ``QueryRouter.set_version`` / the rolling
        # swap; None = unversioned.
        self.embedding_version: Optional[str] = None
        self._scan_gate = scan_gate
        self._admission = AdmissionQueue(
            depth=config.queue_depth, policy=config.policy
        )
        self._encoded: "queue.Queue" = queue.Queue(maxsize=config.encode_ahead)
        self._stats = LatencyStats()
        # Index generation (bumped by new_generation on a rolling swap or
        # a canary revival): stats are scoped to the current generation
        # so a revived/re-indexed replica's counters are not conflated
        # with its previous run; lifetime totals accumulate separately.
        self.generation = 0
        self._lifetime_requests = 0
        self._lifetime_queries = 0
        self._lifetime_shed = 0
        # In-flight accounting for quiesce(): tickets admitted but not
        # yet resolved (by result, error, or sweep).
        self._idle_cond = threading.Condition()
        self._inflight_n = 0
        # Orders resolve+record against a generation rollover: quiesce()
        # wakes on the resolve (inside this lock), so new_generation()
        # cannot swap the stats out between a ticket's resolve and its
        # record — the last pre-swap completion lands in its own
        # generation, never the next one's.
        self._record_lock = threading.Lock()
        # Deadline sheds (expired tickets dropped at stage dequeue):
        # counted apart from admission-queue sheds — one is the tier
        # saturated, the other is the client's budget already spent.
        self._deadline_expired = 0
        self._lifetime_deadline_expired = 0
        # Stuck-scan watchdog state: dispatch times of in-flight scans
        # (seq -> perf_counter at dispatch), oldest first. The scan
        # thread cannot police itself — a hung ``search_fn`` blocks it —
        # so ``start_watchdog`` runs a monitor thread over this map.
        self._watch_lock = threading.Lock()
        self._scan_started: "collections.OrderedDict" = (
            collections.OrderedDict()
        )
        self._watchdog_thread: Optional[threading.Thread] = None
        self._watchdog_stop = threading.Event()
        self.watchdog_stalls = 0
        # device-idle accounting (scan thread): time spent waiting for an
        # encoded batch = the device had nothing to do.
        self._scan_idle_s = 0.0
        self._scan_busy_s = 0.0
        self._encode_thread = threading.Thread(
            target=self._encode_loop, name="serving-encode", daemon=True
        )
        self._scan_thread = threading.Thread(
            target=self._scan_loop, name="serving-scan", daemon=True
        )
        self._encode_thread.start()
        self._scan_thread.start()

    # ------------------------------------------------------------------
    # client side
    # ------------------------------------------------------------------

    @property
    def shed_count(self) -> int:
        return self._admission.shed_count

    def submit(self, queries: Any, *, force_block: bool = False,
               deadline: Optional[float] = None) -> Ticket:
        """Admit one query batch; returns a ``Ticket``.

        block policy: waits for queue space (back-pressure).
        shed policy: raises ``RequestShed`` when the queue is full.
        ``force_block`` overrides a shed policy with back-pressure (used
        by the proxy's failover re-dispatch, which must never drop an
        already-admitted ticket).
        ``deadline``: absolute perf_counter instant; a batch still
        queued when it passes is shed at dequeue with
        ``DeadlineExpired``, never scanned.

        ``queries`` may be a bare batch (legacy shim: encoded by
        ``encode_fn``, full index top-k — bit-identical to the
        pre-``SearchRequest`` path) or a ``SearchRequest`` (typed path:
        codes bypass the encode stage, ``k`` truncates, the request's
        own deadline applies when the kwarg is None).
        """
        if isinstance(queries, SearchRequest) and deadline is None:
            deadline = queries.deadline
        # Reserve the in-flight slot BEFORE admission: once admit() has
        # enqueued the ticket, a concurrent quiesce() must already see
        # it, or "quiesce means quiet" has a window where an admitted
        # batch is invisible and a swap mutates the stages under it.
        with self._idle_cond:
            self._inflight_n += 1
        try:
            ticket = self._admission.admit(
                queries, force_block=force_block, deadline=deadline
            )
        except BaseException:
            with self._idle_cond:
                self._inflight_n -= 1
                if self._inflight_n == 0:
                    self._idle_cond.notify_all()
            raise
        ticket.add_done_callback(self._on_ticket_resolved)
        # A close() racing this submit may have fully shut the stages
        # down with this item still unconsumed (it landed after close()'s
        # own post-join sweep). Sweep whatever remains: only unconsumed
        # items are failed — an item the stages picked up resolves with
        # its real result, and never from here. While any stage thread
        # still lives, either the item precedes the shutdown sentinel
        # (it will be served) or close()'s post-join sweep catches it.
        if self._admission.closed and not self._scan_thread.is_alive():
            self._admission.sweep()
        return ticket

    def _on_ticket_resolved(self, _ticket: Ticket):
        with self._idle_cond:
            self._inflight_n -= 1
            if self._inflight_n == 0:
                self._idle_cond.notify_all()

    def _shed_expired(self, ticket: Ticket) -> None:
        """Fail a ticket whose deadline passed while it sat queued.

        Resolve + count share ``_record_lock`` for the same reason the
        scan loop's resolve+record do: a generation rollover must not
        slip between them and book the expiry in the wrong generation.
        """
        with self._record_lock:
            if ticket._resolve(error=DeadlineExpired(
                f"ticket {ticket.seq} expired "
                f"{time.perf_counter() - ticket.deadline:.4f}s past its "
                "deadline before it was scanned"
            )):
                self._deadline_expired += 1

    def quiesce(self, timeout: Optional[float] = None) -> bool:
        """Drain WITHOUT closing: wait until every admitted request has
        resolved, then return True (False on timeout, with the pipeline
        untouched and still serving).

        The stage threads stay up and ``submit`` keeps working — callers
        that need exclusive access (the rolling index swap) must stop
        routing traffic here first (``QueryRouter.drain``). Once True is
        returned, both stages are blocked on empty queues, so
        ``swap_fns``/``new_generation`` are safe.
        """
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._idle_cond:
            while self._inflight_n > 0:
                wait = None if deadline is None \
                    else deadline - time.perf_counter()
                if wait is not None and wait <= 0:
                    return False
                self._idle_cond.wait(wait)
        return True

    def swap_fns(self, *, encode_fn: Optional[EncodeFn] = None,
                 search_fn: Optional[SearchFn] = None):
        """Hot-swap the encode/search stages on a live pipeline.

        The stages read ``self.encode_fn``/``self.search_fn`` afresh for
        every item, so on a quiesced pipeline (``quiesce() == True`` and
        no traffic being routed here) the swap is atomic per batch: a
        request is served entirely by the old program or entirely by the
        new one, never a mix. Used by the rolling index swap
        (``launch/lifecycle.py``); warm the new program first
        (``warmup_replicas``) or the first post-swap batch pays a jit
        compile on the worker threads.
        """
        if encode_fn is not None:
            self.encode_fn = encode_fn
        if search_fn is not None:
            self.search_fn = search_fn

    def new_generation(self) -> int:
        """Start a fresh stats generation (rolling swap / canary revival).

        A revived replica's throughput and latency must not be conflated
        with its pre-death run — completed counters fold into lifetime
        totals and the window/idle accounting resets. Call only on a
        quiesced pipeline (the scan thread also writes the idle/busy
        clocks). Returns the new generation number.
        """
        with self._record_lock:
            n_req, n_q, _ = self._stats.snapshot()
            self._lifetime_requests += n_req
            self._lifetime_queries += n_q
            self._lifetime_shed += self._admission.take_shed()
            self._lifetime_deadline_expired += self._deadline_expired
            self._deadline_expired = 0
            self._stats = LatencyStats()
            self._scan_idle_s = 0.0
            self._scan_busy_s = 0.0
            self.generation += 1
            return self.generation

    # ------------------------------------------------------------------
    # stuck-scan watchdog
    # ------------------------------------------------------------------

    def scan_oldest_age(self) -> Optional[float]:
        """Seconds the oldest in-flight scan has been running (None when
        no scan is in flight). The watchdog's probe — also usable by an
        external monitor."""
        with self._watch_lock:
            if not self._scan_started:
                return None
            t0 = next(iter(self._scan_started.values()))
        return time.perf_counter() - t0

    def _watch_begin(self, seq: int) -> None:
        with self._watch_lock:
            self._scan_started[seq] = time.perf_counter()

    def _watch_end(self, seq: int) -> None:
        with self._watch_lock:
            self._scan_started.pop(seq, None)

    def start_watchdog(
        self,
        budget_s: float,
        on_stall: Callable[["ServingPipeline", int, float], None],
        *,
        poll: Optional[float] = None,
        clock: Optional[Any] = None,
    ) -> None:
        """Watch for scans that hang past ``budget_s`` without completing.

        A hung ``search_fn`` blocks the scan thread itself, so a
        separate monitor thread checks the oldest in-flight scan's age
        every ``poll`` seconds (default ``budget_s / 4``) and calls
        ``on_stall(pipeline, seq, age)`` ONCE per stalled scan — the
        proxy tier wires this to ``QueryRouter.mark_unhealthy`` so the
        existing failover path re-dispatches the replica's in-flight
        work. The stalled scan itself is left alone: there is no safe
        way to kill it, and first-wins resolution discards its result
        if it ever completes. Idempotent while the watchdog is alive.

        ``clock`` (a ``launch.clock.Clock``) drives only the poll
        cadence; the stall-age math stays on ``time.perf_counter``
        because ``_scan_started`` records real dispatch instants.
        """
        if budget_s <= 0:
            raise ValueError(f"watchdog budget must be > 0, got {budget_s}")
        if self._watchdog_thread is not None \
                and self._watchdog_thread.is_alive():
            return
        stop = threading.Event()
        self._watchdog_stop = stop
        tick = poll if poll is not None else budget_s / 4.0
        wait_tick = (
            stop.wait if clock is None
            else (lambda t: clock.wait(stop, t))
        )

        def loop():
            last_fired = -1  # seqs are monotonic; FIFO scans never return
            while not wait_tick(tick):
                with self._watch_lock:
                    if not self._scan_started:
                        continue
                    seq, t0 = next(iter(self._scan_started.items()))
                age = time.perf_counter() - t0
                if age <= budget_s or seq <= last_fired:
                    continue
                last_fired = seq
                with self._record_lock:
                    self.watchdog_stalls += 1
                try:
                    on_stall(self, seq, age)
                except BaseException:
                    pass  # a raising handler must not kill the monitor

        self._watchdog_thread = threading.Thread(
            target=loop, name="serving-watchdog", daemon=True
        )
        self._watchdog_thread.start()

    def stop_watchdog(self) -> None:
        self._watchdog_stop.set()
        t = self._watchdog_thread
        self._watchdog_thread = None
        if t is not None and t.is_alive():
            t.join(timeout=5.0)

    def close(self, drain: bool = True):
        """Shut the pipeline down; joins both stage threads.

        drain=True finishes every admitted request first; drain=False
        resolves still-queued tickets with ``PipelineClosed``.
        """
        self.stop_watchdog()
        if not self._admission.close():
            return
        if not drain:
            # Pull whatever has not reached the encode stage yet and fail
            # it; in-flight batches still complete (FIFO, bounded).
            self._admission.sweep()
        self._admission.push_sentinel()
        self._encode_thread.join()
        self._scan_thread.join()
        # Post-join sweep: a submit racing this close may have enqueued
        # after the sentinel; its item sits in the dead queue. Fail those
        # tickets (atomic first-wins _resolve keeps real results intact).
        self._admission.sweep()

    def __enter__(self) -> "ServingPipeline":
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------------
    # stage threads
    # ------------------------------------------------------------------

    def _encode_loop(self):
        while True:
            item = self._admission.get()
            if item is _SENTINEL:
                self._encoded.put(_SENTINEL)
                return
            ticket, queries = item
            if ticket.expired():
                # Shed at dequeue: an expired batch is never encoded —
                # the client's budget is spent, and the stage time would
                # only delay still-live work behind it.
                self._shed_expired(ticket)
                continue
            req = ticket.request
            try:
                if req is not None and req.codes is not None:
                    codes = req.codes  # pre-encoded: bypass the stage
                else:
                    enc = self.encode_fn
                    src = queries
                    if req is not None:
                        # Compat hop: the router re-encodes a cross-
                        # version query with the bc-trained encoder it
                        # chose for THIS replica's index version.
                        if req.encode_override is not None:
                            enc = req.encode_override
                        src = req.queries
                    codes = enc(src)
            except BaseException as e:  # surfaced on the ticket
                ticket._resolve(error=e)
                continue
            self._encoded.put((ticket, codes))

    def _scan_loop(self):
        inflight: "collections.deque" = collections.deque()

        def await_oldest():
            ticket, vals, ids = inflight.popleft()
            t0 = time.perf_counter()
            try:
                vals, ids = jax.block_until_ready((vals, ids))
            except BaseException as e:
                self._watch_end(ticket.seq)
                # Busy-clock write BEFORE the resolve and inside the
                # lock: the resolve wakes quiesce(), and a generation
                # rollover must not reset the clock between them.
                with self._record_lock:
                    self._scan_busy_s += time.perf_counter() - t0
                    ticket._resolve(error=e)
                return
            self._watch_end(ticket.seq)
            self._scan_busy_s += time.perf_counter() - t0
            # One critical section for resolve + record: the resolve is
            # what wakes quiesce(), so a generation rollover waiting on
            # _record_lock cannot slip in before the record.
            with self._record_lock:
                if ticket._resolve(value=(vals, ids)):
                    self._stats.record(ticket)

        while True:
            try:
                item = self._encoded.get_nowait()
            except queue.Empty:
                # No encoded batch ready: drain an in-flight scan (the
                # device is busy, not idle) before blocking for input —
                # tail batches must resolve without waiting for close().
                if inflight:
                    await_oldest()
                    continue
                t0 = time.perf_counter()
                gen0 = self.generation
                item = self._encoded.get()
                # An idle wait that spans a new_generation() (the blocked
                # get sat through a drain/rebuild window) belongs to no
                # generation: adding it would book the whole swap as the
                # NEW generation's device idle time.
                if self.generation == gen0:
                    self._scan_idle_s += time.perf_counter() - t0
            if item is _SENTINEL:
                break
            ticket, codes = item
            if ticket.expired():
                # Shed at dequeue (same as the encode stage): the scan
                # is the expensive step — expired work must never reach
                # the device.
                self._shed_expired(ticket)
                continue
            # Provenance at dispatch (single scan thread; the only
            # racing resolvers for a replica-level ticket are error
            # paths, where provenance is moot).
            req = ticket.request
            ticket.served_by_generation = self.generation
            ticket.served_by_version = self.embedding_version
            ticket.reranked = bool(getattr(self.search_fn, "reranked",
                                           False))
            if req is not None and req.encode_override is not None:
                ticket.compat_encoded = True
            # Bound device concurrency BEFORE dispatching: at most
            # dispatch_ahead scans run at once (1 = strictly serial
            # device — on shared-core CPU, concurrent full-corpus scans
            # thrash the cache; on TPU the device queue serialises
            # anyway and a deeper window just hides dispatch latency).
            while len(inflight) >= self.config.dispatch_ahead:
                await_oldest()
            # Watchdog clock starts at dispatch: a hung search_fn blocks
            # right here, where this thread can no longer observe it.
            self._watch_begin(ticket.seq)
            try:
                t0 = time.perf_counter()
                if self._scan_gate is not None:
                    # Co-located replicas take turns. JAX dispatch is
                    # async, so serialising the dispatch alone would
                    # still let N scans execute concurrently — hold the
                    # gate through completion so device work really is
                    # one replica at a time.
                    with self._scan_gate:
                        vals, ids = self.search_fn(codes)
                        vals, ids = jax.block_until_ready((vals, ids))
                else:
                    vals, ids = self.search_fn(codes)  # async dispatch
                self._scan_busy_s += time.perf_counter() - t0
            except BaseException as e:
                self._watch_end(ticket.seq)
                ticket._resolve(error=e)
                continue
            if req is not None and req.k is not None:
                # Per-request truncation of the index's top-k (a lazy
                # slice on the async result — no extra device sync).
                vals, ids = vals[:, : req.k], ids[:, : req.k]
            inflight.append((ticket, vals, ids))
        while inflight:
            await_oldest()

    # ------------------------------------------------------------------
    # monitoring
    # ------------------------------------------------------------------

    def latency_window(self) -> List[float]:
        """Recent enqueue->reply latencies (seconds, bounded window) —
        raw material for cross-replica percentile aggregation."""
        return self._stats.window()

    def stats(self) -> dict:
        """Throughput/latency/idle summary over completed requests.

        Percentiles come from a sliding window of the most recent
        completions (the counters are exact totals) so a long-running
        pipeline's accounting stays O(1) in memory.
        """
        with self._record_lock:  # one snapshot: a concurrent generation
            # rollover must not fold the window we just read into
            # lifetime_* (it would double-count a whole generation)
            n_req, n_q, lat = self._stats.snapshot()
            lifetime_req = self._lifetime_requests + n_req
            lifetime_q = self._lifetime_queries + n_q
            shed = self.shed_count
            lifetime_shed = self._lifetime_shed + shed
            deadline_expired = self._deadline_expired
            lifetime_deadline = (
                self._lifetime_deadline_expired + deadline_expired
            )
            watchdog_stalls = self.watchdog_stalls
            generation = self.generation
            wall = self._scan_idle_s + self._scan_busy_s
            idle = self._scan_idle_s
        lat = sorted(lat)
        return {
            # Scoped to the CURRENT index generation (post last swap or
            # revival); pre-swap totals live under lifetime_*.
            "generation": generation,
            "requests": n_req,
            "queries": n_q,
            "lifetime_requests": lifetime_req,
            "lifetime_queries": lifetime_q,
            "shed": shed,
            "lifetime_shed": lifetime_shed,
            # Deadline sheds are not queue sheds: the queue had room,
            # the client's time budget did not.
            "deadline_expired": deadline_expired,
            "lifetime_deadline_expired": lifetime_deadline,
            "watchdog_stalls": watchdog_stalls,
            "latency_p50_ms": 1e3 * _percentile(lat, 0.50),
            "latency_p99_ms": 1e3 * _percentile(lat, 0.99),
            "device_idle_frac": idle / wall if wall > 0 else 0.0,
        }


def _percentile(sorted_vals: List[float], p: float) -> float:
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, int(round(p * (len(sorted_vals) - 1))))
    return sorted_vals[i]


def serve_batches(
    encode_fn: EncodeFn,
    search_fn: SearchFn,
    batches: List[Any],
    *,
    config: ServingConfig = ServingConfig(),
) -> Tuple[List[Tuple[Array, Array]], dict]:
    """Run ``batches`` through a fresh pipeline; returns (results, stats).

    Results are in submission order. The admission policy is forced to
    "block" — an offline driver should back-pressure, not shed.
    """
    config = dataclasses.replace(config, policy="block")
    pipe = ServingPipeline(encode_fn, search_fn, config=config)
    try:
        tickets = [pipe.submit(b) for b in batches]
        results = [t.result() for t in tickets]
    finally:
        pipe.close()
    return results, pipe.stats()


def warmup(
    encode_fn: EncodeFn,
    search_fn: SearchFn,
    batches: List[Any],
) -> None:
    """Compile the encode + search programs for BOTH drivers.

    Runs the first batch (plus the last, when its shape differs — a
    ragged tail batch is its own program shape) through the sequential
    loop and through a throwaway pipeline. The pipeline pass matters
    because jit caches are keyed on thread-local context: a program
    compiled on the caller's thread (e.g. under a `with mesh:` scope)
    recompiles on first use from the pipeline's worker threads. Call
    this before timing anything.
    """
    warm = batches[:1]
    if len(batches) > 1 and _batch_shape(batches[-1]) != _batch_shape(warm[0]):
        warm = warm + batches[-1:]
    serve_sequential(encode_fn, search_fn, warm)
    serve_batches(encode_fn, search_fn, warm)


def warmup_replicas(
    replicas: Sequence[Tuple[EncodeFn, SearchFn]],
    batches: List[Any],
) -> None:
    """``warmup`` for a replica set: every (encode, search) pair, both
    drivers, lead + ragged-tail shapes.

    One helper instead of per-driver copies because the pitfalls are
    easy to drop on a rewrite: worker threads carry **thread-local jit
    caches** (a program compiled on the caller's thread — e.g. under a
    ``with mesh:`` scope — recompiles on first call from a pipeline
    worker thread), and a **ragged tail batch is its own program
    shape**; both drivers and both shapes must be warmed or the first
    timed batch pays a jit compile. Distinct replicas (own submesh, own
    program) each need their own pass; a replica set that repeats one
    (encode, search) pair is warmed once — the jit cache is shared by
    every worker thread with the same (default) thread-local context,
    so N identical passes would just burn N-1 warmup streams.
    """
    seen = set()
    for encode_fn, search_fn in replicas:
        key = (id(encode_fn), id(search_fn))
        if key in seen:
            continue
        seen.add(key)
        warmup(encode_fn, search_fn, batches)


def _batch_shape(b: Any):
    return getattr(b, "shape", None)


def serve_sequential(
    encode_fn: EncodeFn,
    search_fn: SearchFn,
    batches: List[Any],
) -> List[Tuple[Array, Array]]:
    """The pre-pipeline serving loop: encode, scan, await, repeat.

    The benchmark baseline the overlapped pipeline is gated against
    (same math, no overlap).
    """
    out = []
    for b in batches:
        codes = encode_fn(b)
        vals, ids = search_fn(codes)
        out.append(jax.block_until_ready((vals, ids)))
    return out
