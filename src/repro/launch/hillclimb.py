"""Perf hillclimbing driver (§Perf): lower named variants of the three
target cells, compare roofline terms against the baseline, log every
hypothesis -> change -> measure iteration to perf_results.json.

    PYTHONPATH=src python -m repro.launch.hillclimb --cell tt_retrieval \
        --variant bebr_sdc [--multi-pod]

Cells and variants are defined in VARIANTS below; the baselines are the
same builders launch/dryrun.py uses, so deltas are apples-to-apples.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402
import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

# Roofline constants shared with the block-plan autotuner: one table
# (kernels/sdc/defaults.py) prices kernels for both the cost model here
# and the launch-shape sweeps.
from repro.kernels.sdc.defaults import HBM_BW, LINK_BW, N_LINKS, PEAK_FLOPS


def _measure(fn, in_shardings, args, mesh, n_dev):
    from repro.launch.hlo_cost import hlo_costs

    t0 = time.time()
    with mesh:
        compiled = jax.jit(fn, in_shardings=in_shardings).lower(*args).compile()
    dt = time.time() - t0
    ma = compiled.memory_analysis()
    costs = hlo_costs(compiled.as_text(), n_dev)
    wire = sum(costs["collectives"].values())
    return {
        "compile_s": round(dt, 1),
        "flops": costs["flops"],
        "bytes": costs["bytes"],
        "wire_bytes": wire,
        "collectives": costs["collectives"],
        "compute_ms": 1e3 * costs["flops"] / PEAK_FLOPS,
        "memory_ms": 1e3 * costs["bytes"] / HBM_BW,
        "collective_ms": 1e3 * wire / (N_LINKS * LINK_BW),
        "peak_gib": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                     + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30,
    }


# ---------------------------------------------------------------------------
# Cell: two-tower retrieval_cand (paper-representative).
# ---------------------------------------------------------------------------


def tt_retrieval_baseline(mesh):
    from repro.configs.registry import build_cell

    cell = build_cell("two-tower-retrieval", "retrieval_cand", mesh)
    return cell.fn, cell.in_shardings, cell.abstract_args


def tt_retrieval_float_index(mesh):
    """Production float baseline: candidates as a precomputed f32 embedding
    index (no per-query tower recompute) — the paper's 'float flat' row."""
    from repro.configs.registry import get_arch
    from repro.models.recsys import two_tower as tt
    from repro.parallel import sharding as shd
    from repro.train import steps as _steps

    cfg = get_arch("two-tower-retrieval").config
    dp = shd.dp_axes(mesh)
    params_s = jax.eval_shape(lambda: tt.init_params(jax.random.PRNGKey(0), cfg))
    param_sh = shd.fill_param_sharding(mesh, params_s,
                                       ("user_table", "item_table"))
    Nc, D = 1_000_000, cfg.tower_mlp[-1]
    batch_s = {
        "hist_ids": jax.ShapeDtypeStruct((1, cfg.hist_len), jnp.int32),
        "hist_mask": jax.ShapeDtypeStruct((1, cfg.hist_len), jnp.float32),
        "cand_emb": jax.ShapeDtypeStruct((Nc, D), jnp.float32),
    }
    batch_sh = {
        "hist_ids": NamedSharding(mesh, P(None, None)),
        "hist_mask": NamedSharding(mesh, P(None, None)),
        "cand_emb": NamedSharding(mesh, P(dp, None)),
    }

    def step(params, batch):
        from repro.models.recsys.two_tower import query_embed

        q = query_embed(params, batch["hist_ids"], batch["hist_mask"], cfg)
        scores = (batch["cand_emb"] @ q[0])[None, :]
        return jax.lax.top_k(scores, 100)

    return step, (param_sh, batch_sh), (params_s, batch_s)


def tt_retrieval_bebr(mesh, code_dim=64, n_levels=4):
    """The paper's technique AS the optimisation: int8 SDC index scan."""
    from repro.configs.registry import get_arch
    from repro.models.recsys import two_tower as tt
    from repro.parallel import sharding as shd
    from repro.train import steps

    cfg = get_arch("two-tower-retrieval").config
    dp = shd.dp_axes(mesh)
    params_s = jax.eval_shape(lambda: tt.init_params(jax.random.PRNGKey(0), cfg))
    emb_out = cfg.tower_mlp[-1]
    params_s = dict(params_s)
    params_s["binarizer"] = {
        "W": [jax.ShapeDtypeStruct((emb_out, code_dim), jnp.float32)
              for _ in range(n_levels)],
        "R": [jax.ShapeDtypeStruct((code_dim, emb_out), jnp.float32)
              for _ in range(n_levels - 1)],
    }
    param_sh = shd.fill_param_sharding(mesh, params_s,
                                       ("user_table", "item_table"))
    Nc = 1_000_000
    batch_s = {
        "hist_ids": jax.ShapeDtypeStruct((1, cfg.hist_len), jnp.int32),
        "hist_mask": jax.ShapeDtypeStruct((1, cfg.hist_len), jnp.float32),
        "cand_codes": jax.ShapeDtypeStruct((Nc, code_dim), jnp.int8),
        "cand_inv": jax.ShapeDtypeStruct((Nc,), jnp.float32),
    }
    batch_sh = {
        "hist_ids": NamedSharding(mesh, P(None, None)),
        "hist_mask": NamedSharding(mesh, P(None, None)),
        "cand_codes": NamedSharding(mesh, P(dp, None)),
        "cand_inv": NamedSharding(mesh, P(dp)),
    }
    fn = steps.tt_retrieval_bebr_step(cfg, k=100, code_dim=code_dim,
                                      n_levels=n_levels)
    return fn, (param_sh, batch_sh), (params_s, batch_s)


def tt_retrieval_bebr_full(mesh):
    """BEBR + candidates sharded over the full mesh (dp x model)."""
    from repro.configs.registry import get_arch
    from repro.models.recsys import two_tower as tt
    from repro.parallel import sharding as shd
    from repro.train import steps

    fn, (param_sh, batch_sh), (params_s, batch_s) = tt_retrieval_bebr(mesh)
    dp = shd.dp_axes(mesh)
    # 1e6 doesn't divide dp*model; pad to the next multiple
    n_all = mesh.devices.size
    Nc = 1_000_000 + (-1_000_000) % n_all
    batch_s = dict(batch_s)
    batch_s["cand_codes"] = jax.ShapeDtypeStruct((Nc, 64), jnp.int8)
    batch_s["cand_inv"] = jax.ShapeDtypeStruct((Nc,), jnp.float32)
    batch_sh = dict(batch_sh)
    batch_sh["cand_codes"] = NamedSharding(mesh, P(dp + ("model",), None))
    batch_sh["cand_inv"] = NamedSharding(mesh, P(dp + ("model",)))
    return fn, (param_sh, batch_sh), (params_s, batch_s)


def tt_retrieval_bebr_merge(mesh, code_dim=64, n_levels=4):
    """BEBR + the paper's selection merge: per-leaf top-k under shard_map,
    all-gather only k results (wire: scores array -> k entries/leaf)."""
    from jax.experimental.shard_map import shard_map

    from repro.core.binarize_lib import code_affine_constants
    from repro.configs.registry import get_arch
    from repro.models.recsys import two_tower as tt
    from repro.parallel import sharding as shd

    cfg = get_arch("two-tower-retrieval").config
    fn_base, (param_sh, batch_sh), (params_s, batch_s) = tt_retrieval_bebr(mesh)
    dp = shd.dp_axes(mesh)
    a, beta = code_affine_constants(n_levels)
    k = 100

    def leaf(q_code8, cand_codes, cand_inv):
        dot = jax.lax.dot_general(
            cand_codes, q_code8[0],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        sq = jnp.sum(q_code8.astype(jnp.int32))
        sd = jax.lax.dot_general(
            cand_codes, jnp.ones((cand_codes.shape[1],), jnp.int8),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        scores = ((a * a) * dot.astype(jnp.float32)
                  + (a * beta) * (sq + sd).astype(jnp.float32)
                  + code_dim * beta * beta) * cand_inv
        vals, idx = jax.lax.top_k(scores, k)
        rank = jax.lax.axis_index(dp[0]) if len(dp) == 1 else (
            jax.lax.axis_index(dp[0]) * mesh.shape[dp[1]]
            + jax.lax.axis_index(dp[1]))
        gidx = idx + rank * cand_codes.shape[0]
        av = jax.lax.all_gather(vals, dp, axis=0, tiled=True)
        ai = jax.lax.all_gather(gidx, dp, axis=0, tiled=True)
        bv, pos = jax.lax.top_k(av, k)
        return bv[None], jnp.take(ai, pos)[None]

    leaf_sharded = shard_map(
        leaf, mesh=mesh,
        in_specs=(P(None, None), P(dp, None), P(dp)),
        out_specs=(P(), P()), check_rep=False)

    def step(params, batch):
        q = tt.query_embed(params, batch["hist_ids"], batch["hist_mask"], cfg)

        def sign(x):
            return jnp.where(x > 0, 1.0, -1.0)

        bp = params["binarizer"]
        f = q * jax.lax.rsqrt(jnp.sum(q * q, -1, keepdims=True) + 1e-12)
        b = sign(f @ bp["W"][0])
        acc = b
        code = (b + 1.0) * 0.5 * (2 ** (n_levels - 1))
        for t in range(n_levels - 1):
            recon = acc @ bp["R"][t]
            recon = recon * jax.lax.rsqrt(
                jnp.sum(recon * recon, -1, keepdims=True) + 1e-12)
            r = sign((f - recon) @ bp["W"][t + 1])
            acc = acc + (2.0 ** -(t + 1)) * r
            code = code + (r + 1.0) * 0.5 * (2 ** (n_levels - 2 - t))
        return leaf_sharded(code.astype(jnp.int8), batch["cand_codes"],
                            batch["cand_inv"])

    return step, (param_sh, batch_sh), (params_s, batch_s)


# ---------------------------------------------------------------------------
# Cell: meshgraphnet ogb_products (most collective-bound).
# ---------------------------------------------------------------------------


def gnn_ogb_baseline(mesh):
    from repro.configs.registry import build_cell

    cell = build_cell("meshgraphnet", "ogb_products", mesh)
    return cell.fn, cell.in_shardings, cell.abstract_args


def gnn_ogb_node_constrained(mesh):
    """Constrain aggregates/states to the node partition: all-reduce ->
    reduce-scatter + all-gather, node MLP runs sharded."""
    from repro.configs import cells as cells_mod
    from repro.configs.registry import get_arch
    from repro.train import steps

    cell = cells_mod.gnn_cell(get_arch("meshgraphnet").config, "ogb_products",
                              mesh)

    def node_constrain(x):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P("model", None)))

    import repro.models.gnn as gnn_lib

    # rebuild the step with the constraint (same cfg the cell used)
    cfg = dataclasses.replace(
        get_arch("meshgraphnet").config,
        d_node_in=cells_mod.GNN_SHAPES["ogb_products"]["d_feat"], d_edge_in=8)
    fn = steps.gnn_train_step(cfg, cells_mod.ADAM,
                              node_constrain=node_constrain)
    return fn, cell.in_shardings, cell.abstract_args


def gnn_ogb_bf16_edges(mesh):
    """node constraint + bf16 message/aggregate arithmetic (halves both
    the HBM and wire bytes of the edge pipeline)."""
    from repro.configs import cells as cells_mod
    from repro.configs.registry import get_arch
    from repro.train import steps

    cell = cells_mod.gnn_cell(get_arch("meshgraphnet").config, "ogb_products",
                              mesh)

    def node_constrain(x):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P("model", None)))

    cfg = dataclasses.replace(
        get_arch("meshgraphnet").config, dtype=jnp.bfloat16,
        d_node_in=cells_mod.GNN_SHAPES["ogb_products"]["d_feat"], d_edge_in=8)
    fn = steps.gnn_train_step(cfg, cells_mod.ADAM,
                              node_constrain=node_constrain)

    # params/opt in bf16-aware shapes
    import repro.models.gnn as gnn_lib
    from repro.train import optim

    params_s = jax.eval_shape(lambda: gnn_lib.init_params(jax.random.PRNGKey(0), cfg))
    opt_s = jax.eval_shape(lambda: optim.adam_init(params_s))
    batch_s = cell.abstract_args[2]
    rep = jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), params_s)
    opt_sh = optim.AdamState(step=NamedSharding(mesh, P()),
                             mu=jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), params_s),
                             nu=jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), params_s))
    return fn, (rep, opt_sh, cell.in_shardings[2]), (params_s, opt_s, batch_s)


def gnn_ogb_partitioned(mesh, gather_dtype=None):
    """Receiver-partitioned message passing (shard_map).

    Data contract: the host pipeline sorts edges so edge e lives on the
    device owning receiver[e] (standard partition-aware graph loading).
    Then: one all-gather of node states per layer (senders may be remote),
    segment_sum is fully local (NO all-reduce), node MLP runs on the local
    node shard. Baseline: ~3 all-gathers + 2 all-reduces of the full
    [2.45M, 128] array per layer; here: 1 all-gather (+ its reduce-scatter
    transpose in backward).
    """
    from jax.experimental.shard_map import shard_map

    import repro.models.gnn as gnn_lib
    from repro.configs import cells as cells_mod
    from repro.configs.registry import get_arch
    from repro.train import optim as optim_mod

    info = cells_mod.GNN_SHAPES["ogb_products"]
    cfg = dataclasses.replace(get_arch("meshgraphnet").config,
                              d_node_in=info["d_feat"], d_edge_in=8)
    n_all = mesh.devices.size
    N = info["nodes"] + (-info["nodes"]) % n_all
    E = info["edges"] + (-info["edges"]) % n_all
    axes = tuple(mesh.axis_names)  # shard over the whole mesh

    params_s = jax.eval_shape(lambda: gnn_lib.init_params(jax.random.PRNGKey(0), cfg))
    opt_s = jax.eval_shape(lambda: optim_mod.adam_init(params_s))
    rep = jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), params_s)
    opt_sh = optim_mod.AdamState(
        step=NamedSharding(mesh, P()),
        mu=jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), params_s),
        nu=jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), params_s))
    batch_s = {
        "node_feat": jax.ShapeDtypeStruct((N, info["d_feat"]), jnp.float32),
        "edge_feat": jax.ShapeDtypeStruct((E, 8), jnp.float32),
        "senders": jax.ShapeDtypeStruct((E,), jnp.int32),
        "receivers": jax.ShapeDtypeStruct((E,), jnp.int32),
        "edge_mask": jax.ShapeDtypeStruct((E,), jnp.bool_),
        "targets": jax.ShapeDtypeStruct((N, cfg.d_out), jnp.float32),
    }
    batch_sh = {
        "node_feat": NamedSharding(mesh, P(axes, None)),
        "edge_feat": NamedSharding(mesh, P(axes, None)),
        "senders": NamedSharding(mesh, P(axes)),
        "receivers": NamedSharding(mesh, P(axes)),
        "edge_mask": NamedSharding(mesh, P(axes)),
        "targets": NamedSharding(mesh, P(axes, None)),
    }
    n_loc = N // n_all

    def local_loss(params, nf, ef, snd, rcv, msk, tgt):
        rank = jax.lax.axis_index(axes[0])
        for ax in axes[1:]:
            rank = rank * mesh.shape[ax] + jax.lax.axis_index(ax)
        base = rank * n_loc
        v = gnn_lib._mlp(params["node_enc"], nf)  # [n_loc, h]
        e = gnn_lib._mlp(params["edge_enc"], ef) * msk[:, None]

        def layer_fn(lp, v, e):
            vg = v.astype(gather_dtype) if gather_dtype else v
            v_full = jax.lax.all_gather(vg, axes, axis=0, tiled=True)  # [N, h]
            vs = jnp.take(v_full, snd, axis=0).astype(v.dtype)
            vr = jnp.take(v_full, rcv, axis=0).astype(v.dtype)
            e_new = gnn_lib._mlp(lp["edge_mlp"],
                                 jnp.concatenate([e, vs, vr], -1))
            e = e + e_new * msk[:, None]
            # receivers are LOCAL by the partitioning contract
            agg = jax.ops.segment_sum(e, rcv - base, num_segments=n_loc)
            v = v + gnn_lib._mlp(lp["node_mlp"], jnp.concatenate([v, agg], -1))
            return v, e

        layer_fn = jax.checkpoint(layer_fn)
        for lp in params["layers"]:
            v, e = layer_fn(lp, v, e)
        out = gnn_lib._mlp(params["decoder"], v)
        sq = jnp.sum(jnp.square(out - tgt))
        return jax.lax.psum(sq, axes) / (N * cfg.d_out)

    def sharded_grads(params, nf, ef, snd, rcv, msk, tgt):
        loss, grads = jax.value_and_grad(local_loss)(params, nf, ef, snd,
                                                     rcv, msk, tgt)
        grads = jax.lax.pmean(grads, axes)  # params replicated
        return loss, grads

    gfn = shard_map(
        sharded_grads, mesh=mesh,
        in_specs=(P(), P(axes, None), P(axes, None), P(axes), P(axes),
                  P(axes), P(axes, None)),
        out_specs=(P(), P()), check_rep=False)

    from repro.configs.cells import ADAM as _ADAM

    def step(params, opt_state, batch):
        loss, grads = gfn(params, batch["node_feat"], batch["edge_feat"],
                          batch["senders"], batch["receivers"],
                          batch["edge_mask"], batch["targets"])
        new_params, new_opt = optim_mod.adam_update(grads, opt_state, params,
                                                    _ADAM)
        return new_params, new_opt, {"loss": loss}

    return step, (rep, opt_sh, batch_sh), (params_s, opt_s, batch_s)




def gnn_ogb_halo(mesh, slack: float = 2.0):
    """Halo exchange: instead of all-gathering the full node array, each
    device requests exactly the sender rows its local edges touch via a
    request/response all-to-all pair. Wire per layer ~ 2 * E_loc * h * 4B
    (~250 MB) vs the 1.25 GB all-gather — and it improves further with
    partition quality (METIS cut), unlike all-gather.

    Static shapes: per-destination request buckets are padded to
    slack * E_loc / n_shards (uniform senders => Poisson tails; slack=2
    bounds overflow far beyond 6 sigma at these sizes).
    """
    from jax.experimental.shard_map import shard_map

    import repro.models.gnn as gnn_lib
    from repro.configs import cells as cells_mod
    from repro.configs.registry import get_arch
    from repro.train import optim as optim_mod
    from repro.configs.cells import ADAM as _ADAM

    info = cells_mod.GNN_SHAPES["ogb_products"]
    cfg = dataclasses.replace(get_arch("meshgraphnet").config,
                              d_node_in=info["d_feat"], d_edge_in=8)
    n_all = mesh.devices.size
    N = info["nodes"] + (-info["nodes"]) % n_all
    E = info["edges"] + (-info["edges"]) % n_all
    axes = tuple(mesh.axis_names)
    n_loc = N // n_all
    e_loc = E // n_all
    bucket = int(slack * e_loc / n_all) + 1  # per-peer request capacity

    params_s = jax.eval_shape(lambda: gnn_lib.init_params(jax.random.PRNGKey(0), cfg))
    opt_s = jax.eval_shape(lambda: optim_mod.adam_init(params_s))
    rep = jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), params_s)
    opt_sh = optim_mod.AdamState(
        step=NamedSharding(mesh, P()),
        mu=jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), params_s),
        nu=jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), params_s))
    batch_s = {
        "node_feat": jax.ShapeDtypeStruct((N, info["d_feat"]), jnp.float32),
        "edge_feat": jax.ShapeDtypeStruct((E, 8), jnp.float32),
        "senders": jax.ShapeDtypeStruct((E,), jnp.int32),
        "receivers": jax.ShapeDtypeStruct((E,), jnp.int32),
        "edge_mask": jax.ShapeDtypeStruct((E,), jnp.bool_),
        "targets": jax.ShapeDtypeStruct((N, cfg.d_out), jnp.float32),
    }
    batch_sh = {
        "node_feat": NamedSharding(mesh, P(axes, None)),
        "edge_feat": NamedSharding(mesh, P(axes, None)),
        "senders": NamedSharding(mesh, P(axes)),
        "receivers": NamedSharding(mesh, P(axes)),
        "edge_mask": NamedSharding(mesh, P(axes)),
        "targets": NamedSharding(mesh, P(axes, None)),
    }

    def local_loss(params, nf, ef, snd, rcv, msk, tgt):
        rank = jax.lax.axis_index(axes[0])
        for ax in axes[1:]:
            rank = rank * mesh.shape[ax] + jax.lax.axis_index(ax)
        base = rank * n_loc
        v = gnn_lib._mlp(params["node_enc"], nf)
        e = gnn_lib._mlp(params["edge_enc"], ef) * msk[:, None]

        # --- static routing plan (independent of layer, computed once) ---
        owner = snd // n_loc  # [e_loc]
        order = jnp.argsort(owner)  # edges grouped by owner
        snd_sorted = snd[order]
        own_sorted = owner[order]
        # owners are sorted: position within the owner's group is
        # index - group_start (searchsorted: no [e_loc, n_all] one-hot)
        group_start = jnp.searchsorted(own_sorted, jnp.arange(n_all),
                                       side="left")
        pos_in_bucket = jnp.arange(e_loc) - group_start[own_sorted]
        keep = pos_in_bucket < bucket
        slot = jnp.clip(pos_in_bucket, 0, bucket - 1)
        req = jnp.full((n_all, bucket), -1, jnp.int32)
        req = req.at[own_sorted, slot].set(
            jnp.where(keep, snd_sorted % n_loc, -1))
        req_recv = jax.lax.all_to_all(
            req.reshape(n_all, 1, bucket), axes, split_axis=0,
            concat_axis=1, tiled=False).reshape(n_all, bucket)

        def fetch(v):
            rows = jnp.take(v, jnp.maximum(req_recv, 0).reshape(-1), axis=0)
            rows = jnp.where((req_recv >= 0).reshape(-1, 1), rows, 0.0)
            rows = rows.reshape(n_all, bucket, -1)
            resp = jax.lax.all_to_all(
                rows.reshape(n_all, 1, bucket, rows.shape[-1]), axes,
                split_axis=0, concat_axis=1, tiled=False
            ).reshape(n_all * bucket, rows.shape[-1])
            return resp  # row for request (owner o, slot s) at o*bucket+s

        def layer_fn(lp, v, e):
            resp = fetch(v)
            flat_idx = own_sorted * bucket + slot
            vs_sorted = jnp.take(resp, flat_idx, axis=0)
            vs_sorted = jnp.where(keep[:, None], vs_sorted, 0.0)
            vs = jnp.zeros_like(vs_sorted).at[order].set(vs_sorted)
            vr = jnp.take(v, rcv - base, axis=0)  # receivers are local
            e_new = gnn_lib._mlp(lp["edge_mlp"],
                                 jnp.concatenate([e, vs, vr], -1))
            e = e + e_new * msk[:, None]
            agg = jax.ops.segment_sum(e, rcv - base, num_segments=n_loc)
            v = v + gnn_lib._mlp(lp["node_mlp"], jnp.concatenate([v, agg], -1))
            return v, e

        layer_fn = jax.checkpoint(layer_fn)
        for lp in params["layers"]:
            v, e = layer_fn(lp, v, e)
        out = gnn_lib._mlp(params["decoder"], v)
        sq = jnp.sum(jnp.square(out - tgt))
        return jax.lax.psum(sq, axes) / (N * cfg.d_out)

    def sharded_grads(params, nf, ef, snd, rcv, msk, tgt):
        loss, grads = jax.value_and_grad(local_loss)(params, nf, ef, snd,
                                                     rcv, msk, tgt)
        grads = jax.lax.pmean(grads, axes)
        return loss, grads

    gfn = shard_map(
        sharded_grads, mesh=mesh,
        in_specs=(P(), P(axes, None), P(axes, None), P(axes), P(axes),
                  P(axes), P(axes, None)),
        out_specs=(P(), P()), check_rep=False)

    def step(params, opt_state, batch):
        loss, grads = gfn(params, batch["node_feat"], batch["edge_feat"],
                          batch["senders"], batch["receivers"],
                          batch["edge_mask"], batch["targets"])
        new_params, new_opt = optim_mod.adam_update(grads, opt_state, params,
                                                    _ADAM)
        return new_params, new_opt, {"loss": loss}

    return step, (rep, opt_sh, batch_sh), (params_s, opt_s, batch_s)




def gnn_ogb_halo_hostplan(mesh, slack: float = 2.0):
    """Halo exchange with the routing plan precomputed by the data
    pipeline (it is static per graph, exactly like the receiver
    partitioning): the device step receives request tables and unsort
    indices as inputs, so the in-graph work is just the two all-to-alls
    plus gathers — no sorting/scattering on the accelerator.
    """
    from jax.experimental.shard_map import shard_map

    import repro.models.gnn as gnn_lib
    from repro.configs import cells as cells_mod
    from repro.configs.registry import get_arch
    from repro.train import optim as optim_mod
    from repro.configs.cells import ADAM as _ADAM

    info = cells_mod.GNN_SHAPES["ogb_products"]
    cfg = dataclasses.replace(get_arch("meshgraphnet").config,
                              d_node_in=info["d_feat"], d_edge_in=8)
    n_all = mesh.devices.size
    N = info["nodes"] + (-info["nodes"]) % n_all
    E = info["edges"] + (-info["edges"]) % n_all
    axes = tuple(mesh.axis_names)
    n_loc = N // n_all
    e_loc = E // n_all
    bucket = int(slack * e_loc / n_all) + 1

    params_s = jax.eval_shape(lambda: gnn_lib.init_params(jax.random.PRNGKey(0), cfg))
    opt_s = jax.eval_shape(lambda: optim_mod.adam_init(params_s))
    rep = jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), params_s)
    opt_sh = optim_mod.AdamState(
        step=NamedSharding(mesh, P()),
        mu=jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), params_s),
        nu=jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), params_s))
    batch_s = {
        "node_feat": jax.ShapeDtypeStruct((N, info["d_feat"]), jnp.float32),
        "edge_feat": jax.ShapeDtypeStruct((E, 8), jnp.float32),
        "receivers": jax.ShapeDtypeStruct((E,), jnp.int32),
        "edge_mask": jax.ShapeDtypeStruct((E,), jnp.bool_),
        "targets": jax.ShapeDtypeStruct((N, cfg.d_out), jnp.float32),
        # host-prepared halo routing plan (per-device tables, see below)
        "fetch_idx": jax.ShapeDtypeStruct((E,), jnp.int32),
        "fetch_valid": jax.ShapeDtypeStruct((E,), jnp.bool_),
    }
    batch_sh = {
        "node_feat": NamedSharding(mesh, P(axes, None)),
        "edge_feat": NamedSharding(mesh, P(axes, None)),
        "receivers": NamedSharding(mesh, P(axes)),
        "edge_mask": NamedSharding(mesh, P(axes)),
        "targets": NamedSharding(mesh, P(axes, None)),
        "fetch_idx": NamedSharding(mesh, P(axes)),
        "fetch_valid": NamedSharding(mesh, P(axes)),
    }
    # req is per-device data: leading device axis, sharded over the mesh.
    batch_s["req"] = jax.ShapeDtypeStruct((n_all, n_all * bucket), jnp.int32)
    batch_sh["req"] = NamedSharding(mesh, P(axes, None))

    def local_loss(params, nf, ef, rcv, msk, tgt, req, fidx, fvalid):
        rank = jax.lax.axis_index(axes[0])
        for ax in axes[1:]:
            rank = rank * mesh.shape[ax] + jax.lax.axis_index(ax)
        base = rank * n_loc
        v = gnn_lib._mlp(params["node_enc"], nf)
        e = gnn_lib._mlp(params["edge_enc"], ef) * msk[:, None]
        req = req.reshape(n_all, bucket)  # [peer, slot] local node ids, -1 pad
        req_recv = jax.lax.all_to_all(
            req.reshape(n_all, 1, bucket), axes, split_axis=0,
            concat_axis=1, tiled=False).reshape(n_all, bucket)

        def fetch(v):
            rows = jnp.take(v, jnp.maximum(req_recv, 0).reshape(-1), axis=0)
            rows = rows * (req_recv >= 0).reshape(-1, 1)
            rows = rows.reshape(n_all, bucket, -1)
            resp = jax.lax.all_to_all(
                rows.reshape(n_all, 1, bucket, rows.shape[-1]), axes,
                split_axis=0, concat_axis=1, tiled=False
            ).reshape(n_all * bucket, rows.shape[-1])
            return resp

        def layer_fn(lp, v, e):
            resp = fetch(v)
            vs = jnp.take(resp, fidx, axis=0) * fvalid[:, None]
            vr = jnp.take(v, rcv - base, axis=0)
            e_new = gnn_lib._mlp(lp["edge_mlp"],
                                 jnp.concatenate([e, vs, vr], -1))
            e = e + e_new * msk[:, None]
            agg = jax.ops.segment_sum(e, rcv - base, num_segments=n_loc)
            v = v + gnn_lib._mlp(lp["node_mlp"], jnp.concatenate([v, agg], -1))
            return v, e

        layer_fn = jax.checkpoint(layer_fn)
        for lp in params["layers"]:
            v, e = layer_fn(lp, v, e)
        out = gnn_lib._mlp(params["decoder"], v)
        sq = jnp.sum(jnp.square(out - tgt))
        return jax.lax.psum(sq, axes) / (N * cfg.d_out)

    def sharded_grads(params, nf, ef, rcv, msk, tgt, req, fidx, fvalid):
        loss, grads = jax.value_and_grad(local_loss)(
            params, nf, ef, rcv, msk, tgt, req, fidx, fvalid)
        grads = jax.lax.pmean(grads, axes)
        return loss, grads

    gfn = shard_map(
        sharded_grads, mesh=mesh,
        in_specs=(P(), P(axes, None), P(axes, None), P(axes), P(axes),
                  P(axes, None), P(axes, None), P(axes), P(axes)),
        out_specs=(P(), P()), check_rep=False)

    def step(params, opt_state, batch):
        fvalid = batch["fetch_valid"].astype(jnp.float32)
        loss, grads = gfn(params, batch["node_feat"], batch["edge_feat"],
                          batch["receivers"], batch["edge_mask"],
                          batch["targets"], batch["req"],
                          batch["fetch_idx"], fvalid)
        new_params, new_opt = optim_mod.adam_update(grads, opt_state, params,
                                                    _ADAM)
        return new_params, new_opt, {"loss": loss}

    return step, (rep, opt_sh, batch_sh), (params_s, opt_s, batch_s)


# ---------------------------------------------------------------------------
# Cell: llama3-405b train_4k (biggest model, memory+collective heavy).
# ---------------------------------------------------------------------------


def _llama_variant(mesh, **overrides):
    from repro.configs import cells as cells_mod
    from repro.configs.archs.llama3_405b import CONFIG

    cfg = dataclasses.replace(CONFIG, **overrides)
    cell = cells_mod.lm_cell(cfg, "train_4k", mesh)
    return cell.fn, cell.in_shardings, cell.abstract_args


def llama_baseline(mesh):
    return _llama_variant(mesh)


def llama_no_sp(mesh):
    return _llama_variant(mesh, activation_sharding=None)


def llama_mb16(mesh):
    return _llama_variant(mesh, microbatches=16)


def llama_mb4(mesh):
    return _llama_variant(mesh, microbatches=4)


def llama_mb4_no_sp(mesh):
    return _llama_variant(mesh, microbatches=4, activation_sharding=None)


def llama_mb2_no_sp(mesh):
    return _llama_variant(mesh, microbatches=2, activation_sharding=None)


def llama_sp_residual(mesh):
    return _llama_variant(mesh, activation_sharding="seq_residual")


def llama_sp_residual_mb4(mesh):
    return _llama_variant(mesh, activation_sharding="seq_residual",
                          microbatches=4)


def llama_mb4_chunk1024(mesh):
    return _llama_variant(mesh, microbatches=4, attn_chunk=1024)


def llama_mb2_chunk1024(mesh):
    return _llama_variant(mesh, microbatches=2, attn_chunk=1024)


def llama_chunk256(mesh):
    return _llama_variant(mesh, attn_chunk=256)


def llama_chunk1024(mesh):
    return _llama_variant(mesh, attn_chunk=1024)


def grok_prefill_baseline(mesh):
    from repro.configs import cells as cells_mod
    from repro.configs.archs.grok_1_314b import CONFIG

    cell = cells_mod.lm_cell(CONFIG, "prefill_32k", mesh)
    return cell.fn, cell.in_shardings, cell.abstract_args


def grok_prefill_grouped(mesh):
    """Bonus iteration: fixed-size MoE routing groups bound the GShard
    dispatch one-hot linearly in S (654 GiB cell -> expected ~1/16)."""
    from repro.configs import cells as cells_mod
    from repro.configs.archs.grok_1_314b import CONFIG

    cfg = dataclasses.replace(CONFIG, moe_group=2048)
    cell = cells_mod.lm_cell(cfg, "prefill_32k", mesh)
    return cell.fn, cell.in_shardings, cell.abstract_args


VARIANTS = {
    "tt_retrieval": {
        "baseline": tt_retrieval_baseline,
        "float_index": tt_retrieval_float_index,
        "bebr_sdc": tt_retrieval_bebr,
        "bebr_sdc_fullmesh": tt_retrieval_bebr_full,
        "bebr_sdc_merge": tt_retrieval_bebr_merge,
    },
    "gnn_ogb": {
        "baseline": gnn_ogb_baseline,
        "node_constrained": gnn_ogb_node_constrained,
        "node_constrained_bf16": gnn_ogb_bf16_edges,
        "partitioned": gnn_ogb_partitioned,
        "partitioned_bf16gather": lambda mesh: gnn_ogb_partitioned(
            mesh, gather_dtype=jnp.bfloat16),
        "halo_exchange": gnn_ogb_halo,
        "halo_hostplan": gnn_ogb_halo_hostplan,
    },
    "grok_prefill": {
        "baseline": grok_prefill_baseline,
        "routing_groups": grok_prefill_grouped,
    },
    "llama405b_train": {
        "baseline": llama_baseline,
        "no_seq_sharding": llama_no_sp,
        "microbatch16": llama_mb16,
        "microbatch4": llama_mb4,
        "mb4_no_sp": llama_mb4_no_sp,
        "mb2_no_sp": llama_mb2_no_sp,
        "sp_residual": llama_sp_residual,
        "sp_residual_mb4": llama_sp_residual_mb4,
        "mb4_chunk1024": llama_mb4_chunk1024,
        "mb2_chunk1024": llama_mb2_chunk1024,
        "attn_chunk256": llama_chunk256,
        "attn_chunk1024": llama_chunk1024,
    },
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=sorted(VARIANTS))
    ap.add_argument("--variant", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="perf_results.json")
    args = ap.parse_args()

    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    build = VARIANTS[args.cell][args.variant]
    fn, shardings, abstract = build(mesh)
    res = _measure(fn, shardings, abstract, mesh, mesh.devices.size)

    key = f"{args.cell}|{args.variant}|{'2x16x16' if args.multi_pod else '16x16'}"
    print(f"{key}: compute={res['compute_ms']:.2f}ms "
          f"memory={res['memory_ms']:.2f}ms coll={res['collective_ms']:.2f}ms "
          f"peak={res['peak_gib']:.2f}GiB compile={res['compile_s']}s")

    log = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            log = json.load(f)
    log[key] = res
    with open(args.out, "w") as f:
        json.dump(log, f, indent=1)


if __name__ == "__main__":
    main()
