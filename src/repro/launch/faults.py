"""Deterministic fault injection for the serving tier (chaos harness).

The replicated tier (``launch/proxy.py``) recovers from replicas that
*raise*; this module supplies the faults that prove it — and the ones
PR 6 adds machinery for (hung scans, latency spikes, flapping revivals)
— as one shared vocabulary instead of per-test hand-rolled wrappers:

  * ``FaultPlan`` — a seeded, deterministic schedule of ``FaultEvent``s
    keyed on per-stage call index. Same plan + same call sequence =>
    same faults, every run (probabilistic clauses draw from a
    ``random.Random(seed)``, so even those replay exactly).
  * ``FaultInjector`` — wraps one ``(encode_fn, search_fn)`` replica
    pair; ``injector.encode`` / ``injector.search`` are drop-in stage
    callables that consult the plan on every call and fault on
    schedule. Stuck scans block until ``release()`` — call it before
    tearing the pipeline down or ``close()`` joins a thread that is
    waiting on you.
  * ``parse_chaos_spec`` — the ``--chaos SPEC`` string shared by
    ``launch/serve.py`` and ``examples/serve_bebr.py`` (syntax below),
    mapping clauses onto per-replica ``FaultPlan``s.

Fault kinds (``FaultEvent.kind``):

  fail   raise ``InjectedFault`` instead of calling through
  delay  sleep ``arg`` seconds, then call through (latency spike)
  stick  block until ``FaultInjector.release()``, then call through
         (a hung scan: the stage thread wedges, nothing raises)
  flap   periodic ``fail``: starting at ``at``, fail ``count`` calls
         out of every ``arg`` (a replica that dies, revives under the
         canary probe, and dies again)

``--chaos`` spec syntax — comma-separated clauses::

  [rN.][stage.]kind[@AT][xCOUNT][~PROB][:ARG]   or   seed=N

  rN.     replica index the clause applies to (default r0)
  stage.  encode | search (default search)
  @AT     first affected 0-based call index (default 0)
  xCOUNT  consecutive calls affected; ``x*`` = every call from AT on
  ~PROB   probabilistic instead of positional: each call >= AT faults
          with probability PROB under the plan's seeded RNG
  :ARG    seconds for delay, period (calls) for flap

Examples: ``stick@40`` (scan 40 hangs), ``r1.fail@10x3`` (replica 1's
scans 10-12 raise), ``delay@0x*:0.02`` (every scan +20 ms),
``encode.fail~0.05,seed=7`` (5% of encodes raise, deterministically).
"""

from __future__ import annotations

import dataclasses
import random
import re
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple


class InjectedFault(RuntimeError):
    """The error a scheduled ``fail``/``flap`` fault raises. A distinct
    type so tests and drivers can tell injected chaos from real bugs."""


FAULT_KINDS = ("fail", "delay", "stick", "flap")
FAULT_STAGES = ("encode", "search")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault (see module docstring for the semantics).

    ``count=0`` means "every call from ``at`` on" (the spec's ``x*``).
    ``prob > 0`` makes the event probabilistic (per-call coin flip from
    the plan's seeded RNG) instead of positional.
    """

    kind: str
    stage: str = "search"
    at: int = 0
    count: int = 1
    arg: float = 0.0
    prob: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"fault kind must be one of {FAULT_KINDS}, got {self.kind!r}"
            )
        if self.stage not in FAULT_STAGES:
            raise ValueError(
                f"fault stage must be one of {FAULT_STAGES}, "
                f"got {self.stage!r}"
            )
        if self.at < 0 or self.count < 0:
            raise ValueError("fault at/count must be >= 0")
        if not (0.0 <= self.prob <= 1.0):
            raise ValueError(f"fault prob must be in [0, 1], got {self.prob}")
        if self.kind == "delay" and self.arg <= 0.0:
            raise ValueError("delay fault needs arg > 0 (seconds)")
        if self.kind == "flap" and self.arg and self.arg < max(1, self.count):
            raise ValueError("flap period (arg) must be >= count")

    def applies(self, i: int, rng: Optional[random.Random] = None) -> bool:
        """Does this event fire on call ``i`` of its stage?"""
        if i < self.at:
            return False
        if self.prob > 0.0:
            # rng is consulted for EVERY eligible call (hit or miss), so
            # the draw sequence — and therefore the fault schedule — is
            # a pure function of (seed, call index).
            return rng is not None and rng.random() < self.prob
        if self.kind == "flap":
            period = int(self.arg) if self.arg else 2 * max(1, self.count)
            return (i - self.at) % period < self.count
        if self.count == 0:
            return True
        return i < self.at + self.count


class FaultPlan:
    """A deterministic schedule of fault events for one replica."""

    def __init__(self, events: Sequence[FaultEvent] = (), *, seed: int = 0):
        self.events = tuple(events)
        self.seed = seed

    def __repr__(self) -> str:
        return f"FaultPlan({list(self.events)!r}, seed={self.seed})"

    def merged(self, other: "FaultPlan") -> "FaultPlan":
        return FaultPlan(self.events + other.events, seed=self.seed)

    # -- convenience constructors (the shapes tests hand-rolled before) --

    @classmethod
    def fail_after(cls, n: int, *, stage: str = "search") -> "FaultPlan":
        """Calls 0..n-1 succeed; every call >= n raises."""
        return cls([FaultEvent("fail", stage=stage, at=n, count=0)])

    @classmethod
    def fail_first(cls, n: int, *, stage: str = "search") -> "FaultPlan":
        """The first ``n`` calls raise, then the stage recovers — the
        transient fault a canary probe revives a replica from."""
        return cls([FaultEvent("fail", stage=stage, at=0, count=n)])

    @classmethod
    def fail_at(cls, *indices: int, stage: str = "search") -> "FaultPlan":
        return cls([FaultEvent("fail", stage=stage, at=i) for i in indices])

    @classmethod
    def stick_at(cls, n: int, *, stage: str = "search") -> "FaultPlan":
        """Call ``n`` blocks until ``FaultInjector.release()`` — the
        hung-scan fault the watchdog exists for."""
        return cls([FaultEvent("stick", stage=stage, at=n)])

    @classmethod
    def delay_every(cls, seconds: float, *, stage: str = "search",
                    at: int = 0) -> "FaultPlan":
        """Every call from ``at`` on sleeps ``seconds`` first (a slow
        replica, not a broken one)."""
        return cls([FaultEvent("delay", stage=stage, at=at, count=0,
                               arg=seconds)])


class FaultInjector:
    """Wrap one replica's ``(encode_fn, search_fn)`` with a fault plan.

    ``injector.encode`` / ``injector.search`` (or the ``pair`` tuple)
    drop into any place a replica pair goes — ``ReplicaSet``, a builder
    closure, the bench emitter. Call counting is per stage and
    thread-safe; every fault fired is appended to ``log`` as
    ``(stage, call_index, kind)`` so tests can assert the schedule ran.
    """

    def __init__(self, encode_fn: Callable, search_fn: Callable,
                 plan: FaultPlan, *, name: str = "replica",
                 clock: Any = None):
        # ``clock`` (launch.clock.Clock) times delay events; default is
        # the real clock. Tests on a FakeClock make an injected latency
        # spike a simulated-time event instead of a real sleep.
        self.plan = plan
        self.name = name
        self._clock = clock
        self._fns = {"encode": encode_fn, "search": search_fn}
        self._lock = threading.Lock()
        self.calls = {"encode": 0, "search": 0}
        self.log: List[Tuple[str, int, str]] = []
        self._release = threading.Event()
        self.stuck_count = 0
        # One RNG per stage, both derived from the plan seed: a
        # probabilistic encode clause must not perturb the search
        # stage's draw sequence (or vice versa).
        self._rng = {
            "encode": random.Random(plan.seed * 2 + 1),
            "search": random.Random(plan.seed * 2 + 2),
        }
        self.encode = self._wrap("encode")
        self.search = self._wrap("search")

    def _wrap(self, stage: str) -> Callable:
        inner = self._fns[stage]

        def call(x: Any):
            self._enter(stage)
            return inner(x)

        # The serving tier reads metadata off the stage callable itself
        # (``search_fn.reranked`` for result provenance, the shared
        # ``search_fn.effort`` knob for degradation) — injecting faults
        # must not strip it. The effort knob is copied by REFERENCE so
        # the proxy's level changes reach the wrapped closure.
        for attr in ("reranked", "effort"):
            if hasattr(inner, attr):
                setattr(call, attr, getattr(inner, attr))
        return call

    @property
    def pair(self) -> Tuple[Callable, Callable]:
        return self.encode, self.search

    def release(self) -> None:
        """Unblock every stuck stage call (past and future ``stick``
        events become no-ops). Call before closing a pipeline whose
        scan you wedged, or ``close()`` joins a thread waiting on you."""
        self._release.set()

    def _enter(self, stage: str) -> None:
        with self._lock:
            i = self.calls[stage]
            self.calls[stage] += 1
            fired = [
                ev for ev in self.plan.events
                if ev.stage == stage and ev.applies(i, self._rng[stage])
            ]
            for ev in fired:
                self.log.append((stage, i, ev.kind))
            if any(ev.kind == "stick" for ev in fired):
                self.stuck_count += 1
        # Apply OUTSIDE the lock: a stuck scan must not wedge the other
        # stage's (or another thread's) call counting.
        for ev in fired:
            if ev.kind == "delay":
                if self._clock is None:
                    time.sleep(ev.arg)
                else:
                    self._clock.sleep(ev.arg)
            elif ev.kind == "stick":
                self._release.wait()
            else:  # fail | flap
                raise InjectedFault(
                    f"injected {ev.kind} ({self.name}.{stage} call {i})"
                )


def wrap_replicas(
    replicas: Sequence[Tuple[Callable, Callable]],
    plans: Dict[int, FaultPlan],
) -> Tuple[List[Tuple[Callable, Callable]], Dict[int, FaultInjector]]:
    """Wrap ``replicas[i]`` with ``plans[i]`` where present.

    Returns (new replica list, {replica index: injector}) — the driver
    keeps the injectors to ``release()`` stuck scans at shutdown.
    """
    out = list(replicas)
    injectors: Dict[int, FaultInjector] = {}
    for i, plan in sorted(plans.items()):
        if not 0 <= i < len(out):
            raise ValueError(
                f"chaos spec targets replica {i} but the tier has "
                f"{len(out)} replicas"
            )
        inj = FaultInjector(out[i][0], out[i][1], plan, name=f"r{i}")
        injectors[i] = inj
        out[i] = inj.pair
    return out, injectors


# ---------------------------------------------------------------------------
# --chaos spec parsing
# ---------------------------------------------------------------------------

_CLAUSE_RE = re.compile(
    r"^(?:r(?P<replica>\d+)\.)?"
    r"(?:(?P<stage>encode|search)\.)?"
    r"(?P<kind>fail|delay|stick|flap)"
    r"(?:@(?P<at>\d+))?"
    r"(?:x(?P<count>\d+|\*))?"
    r"(?:~(?P<prob>[0-9.]+))?"
    r"(?::(?P<arg>[0-9.]+))?$"
)


def parse_chaos_spec(spec: str) -> Dict[int, FaultPlan]:
    """Parse a ``--chaos`` spec into per-replica ``FaultPlan``s.

    See the module docstring for the grammar. Raises ``ValueError`` on
    anything it does not recognise — a chaos run with a silently
    dropped clause would "pass" by testing nothing.
    """
    seed = 0
    events: Dict[int, List[FaultEvent]] = {}
    for raw in spec.split(","):
        clause = raw.strip()
        if not clause:
            continue
        if clause.startswith("seed="):
            try:
                seed = int(clause[5:])
            except ValueError:
                raise ValueError(f"bad chaos seed clause {clause!r}") from None
            continue
        m = _CLAUSE_RE.match(clause)
        if m is None:
            raise ValueError(
                f"bad chaos clause {clause!r} (expected "
                "[rN.][stage.]kind[@AT][xCOUNT][~PROB][:ARG] or seed=N)"
            )
        replica = int(m.group("replica") or 0)
        count_s = m.group("count")
        count = 0 if count_s == "*" else int(count_s) if count_s else 1
        ev = FaultEvent(
            kind=m.group("kind"),
            stage=m.group("stage") or "search",
            at=int(m.group("at") or 0),
            count=count,
            arg=float(m.group("arg") or 0.0),
            prob=float(m.group("prob") or 0.0),
        )
        events.setdefault(replica, []).append(ev)
    return {i: FaultPlan(evs, seed=seed) for i, evs in events.items()}


def apply_chaos(
    replicas: Sequence[Tuple[Callable, Callable]],
    spec: Optional[str],
) -> Tuple[List[Tuple[Callable, Callable]], Dict[int, FaultInjector]]:
    """Driver entry point: parse ``spec`` and wrap the targeted replicas.

    ``spec=None``/empty returns the replicas untouched (no injectors).
    """
    if not spec:
        return list(replicas), {}
    return wrap_replicas(replicas, parse_chaos_spec(spec))
