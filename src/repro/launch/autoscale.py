"""Shed-pressure autoscaler and declarative tier spec.

PRs 4-9 gave the tier routing, degradation, swaps, chaos, and
migrations across N replicas — but N itself was frozen at construction.
Production EBR systems (Huang et al., arXiv:2006.11632) treat capacity
as part of the retrieval system: index cost and replica count must
track load. This module closes that loop:

  * ``TierSpec`` — the declarative desired state of a serving tier:
    replica bounds, index kind + build params, router policy, admission
    policy and queue depth, swap cadence, and the scaling thresholds
    (high/low-water hysteresis, cooldown, sliding window). One frozen,
    eagerly-validated record that ``serve.py --tier-spec spec.json``
    applies at startup and the ``Autoscaler`` re-applies as it resizes,
    so an operator edits ONE artifact, not a flag soup. Malformed specs
    fail with ``InvalidTierSpec`` naming the field and the fix.

  * ``Autoscaler`` — the control loop: every ``tick_s`` it reads
    ``QueryRouter.stats()`` (shed deltas) and ``outstanding()`` (queue
    occupancy) into a pressure signal in [0, 1], averages it over a
    sliding window, and scales through the EXISTING lifecycle paths —
    nothing here touches a pipeline directly:

      scale-up    build via ``IndexBuilder.build(snapshot, replica=i)``,
                  warm the jit caches (``serving.warmup_replicas``),
                  enter the tier in ``rebuilding`` via
                  ``QueryRouter.add_replica``, and canary-probe
                  (``probe(..., from_rebuild=True)``) BEFORE the slot
                  takes traffic — the same admission discipline as an
                  index swap. A failed canary retires the slot; it
                  never serves.
      scale-down  ``QueryRouter.retire_replica``: the proxy's ordinary
                  drain path, so in-flight tickets finish or re-dispatch
                  losslessly, then the slot is tombstoned ``retired``.

    Hysteresis (act only when the window MEAN crosses high/low water,
    two separated thresholds) plus a post-action cooldown keep a noisy
    trace from flapping the tier; the window clears after every action
    so a decision is never made on pre-action pressure.

All timing runs on an injected ``Clock`` (``launch.clock``): production
uses the default ``SYSTEM_CLOCK``; tests drive a ``FakeClock`` and
prove every hysteresis/cooldown/bounds property by advancing simulated
time, never by sleeping real time.
"""

from __future__ import annotations

import dataclasses
import json
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.launch import serving
from repro.launch.clock import SYSTEM_CLOCK, Clock
from repro.launch.lifecycle import (
    CorpusSnapshot,
    IndexBuilder,
    builder_version,
    make_builder,
)
from repro.launch.proxy import ROUTING_POLICIES, QueryRouter
from repro.launch.serving import EncodeFn, SearchFn


class InvalidTierSpec(ValueError):
    """A ``TierSpec`` (or its JSON form) failed validation.

    Typed so operators and tests can distinguish a malformed spec from
    the generic ``ValueError`` soup; the message always names the bad
    field and the accepted range."""


#: Admission policies a spec may ask of the per-replica queues.
ADMISSION_POLICIES = ("block", "shed")


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise InvalidTierSpec(msg)


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """Declarative desired state of one serving tier.

    Scaling semantics: the autoscaler samples tier pressure every
    ``tick_s`` seconds, averages the last ``window_s`` worth of samples,
    and scales up when the mean is >= ``high_water`` (below
    ``max_replicas``) or down when it is <= ``low_water`` (above
    ``min_replicas``). ``cooldown_s`` is the minimum spacing between
    consecutive scaling actions; the sample window resets after every
    action. ``swap_every_s`` is the declared index-swap cadence (0 =
    no periodic swap) — consumed by the serve drivers, recorded here so
    the whole tier shape lives in one artifact.
    """

    min_replicas: int = 1
    max_replicas: int = 1
    index: str = "flat"
    build_params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    router: str = "round-robin"
    policy: str = "shed"
    queue_depth: int = 4
    swap_every_s: float = 0.0
    high_water: float = 0.5
    low_water: float = 0.1
    cooldown_s: float = 5.0
    window_s: float = 3.0
    tick_s: float = 1.0

    def __post_init__(self):
        _require(isinstance(self.min_replicas, int)
                 and not isinstance(self.min_replicas, bool)
                 and self.min_replicas >= 1,
                 f"min_replicas must be an int >= 1, got "
                 f"{self.min_replicas!r}")
        _require(isinstance(self.max_replicas, int)
                 and not isinstance(self.max_replicas, bool)
                 and self.max_replicas >= self.min_replicas,
                 f"max_replicas must be an int >= min_replicas "
                 f"({self.min_replicas}), got {self.max_replicas!r}")
        _require(isinstance(self.queue_depth, int)
                 and not isinstance(self.queue_depth, bool)
                 and self.queue_depth >= 1,
                 f"queue_depth must be an int >= 1, got "
                 f"{self.queue_depth!r}")
        _require(self.policy in ADMISSION_POLICIES,
                 f"policy must be one of {ADMISSION_POLICIES}, got "
                 f"{self.policy!r}")
        _require(self.router in ROUTING_POLICIES,
                 f"router must be one of {sorted(ROUTING_POLICIES)}, "
                 f"got {self.router!r}")
        for name in ("swap_every_s", "high_water", "low_water",
                     "cooldown_s", "window_s", "tick_s"):
            v = getattr(self, name)
            _require(isinstance(v, (int, float))
                     and not isinstance(v, bool),
                     f"{name} must be a number, got {v!r}")
        _require(0.0 <= self.low_water < self.high_water <= 1.0,
                 f"need 0 <= low_water < high_water <= 1, got "
                 f"low_water={self.low_water} high_water={self.high_water}")
        _require(self.cooldown_s >= 0.0,
                 f"cooldown_s must be >= 0, got {self.cooldown_s}")
        _require(self.swap_every_s >= 0.0,
                 f"swap_every_s must be >= 0, got {self.swap_every_s}")
        _require(self.tick_s > 0.0,
                 f"tick_s must be > 0, got {self.tick_s}")
        _require(self.window_s >= self.tick_s,
                 f"window_s must be >= tick_s ({self.tick_s}), got "
                 f"{self.window_s}")
        _require(isinstance(self.build_params, dict),
                 f"build_params must be a dict, got "
                 f"{type(self.build_params).__name__}")
        # The registry is the source of truth for index kinds and their
        # knobs — a typo'd build param must die at spec load, not after
        # the tier has been serving for an hour and tries to scale up.
        try:
            self.make_index_builder()
        except (ValueError, TypeError) as e:
            raise InvalidTierSpec(f"index/build_params rejected: {e}") from e

    def make_index_builder(self) -> IndexBuilder:
        """A fresh ``IndexBuilder`` for this spec's index kind/params."""
        return make_builder(self.index, **self.build_params)

    @property
    def window_ticks(self) -> int:
        """Samples in a full decision window (>= 1)."""
        return max(1, round(self.window_s / self.tick_s))

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, data: Dict[str, Any]) -> "TierSpec":
        if not isinstance(data, dict):
            raise InvalidTierSpec(
                f"tier spec must be a JSON object, got "
                f"{type(data).__name__}"
            )
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(data) - known)
        if unknown:
            raise InvalidTierSpec(
                f"unknown tier spec keys {unknown}; known: {sorted(known)}"
            )
        return cls(**data)

    @classmethod
    def from_json(cls, text: str) -> "TierSpec":
        try:
            data = json.loads(text)
        except json.JSONDecodeError as e:
            raise InvalidTierSpec(f"tier spec is not valid JSON: {e}") from e
        return cls.from_dict(data)

    @classmethod
    def from_file(cls, path: str) -> "TierSpec":
        with open(path, "r", encoding="utf-8") as f:
            return cls.from_json(f.read())


class Autoscaler:
    """Scale a live ``QueryRouter`` tier to track shed pressure.

    ``spec`` bounds and parameterises every decision (see ``TierSpec``).
    New replicas come from ``replica_factory(slot) -> (encode_fn,
    search_fn)`` when given (engine tiers hand one that closes over the
    slot's submesh); otherwise from the spec's own index builder over
    ``snapshot`` with ``encode_fn`` — ``IndexBuilder.build(snapshot,
    replica=slot)``, the same constructor the swap path uses.

    ``canary`` (default ``warm_batches[0]``) is the admission probe
    batch; ``expect`` optionally pins its (scores, ids). ``pressure_fn``
    replaces the stats-derived pressure signal — tests use it to feed
    synthetic traces; production leaves it None.

    The loop never acts on a partial window, never acts twice within
    ``cooldown_s``, and clears its window after acting; bounds
    violations (a tier below ``min_replicas`` after a failed probe, or
    above ``max_replicas`` after a spec edit) are corrected immediately,
    cooldown notwithstanding — the spec is desired state, not advice.
    """

    def __init__(
        self,
        router: QueryRouter,
        spec: TierSpec,
        *,
        snapshot: Optional[CorpusSnapshot] = None,
        encode_fn: Optional[EncodeFn] = None,
        replica_factory: Optional[
            Callable[[int], Tuple[EncodeFn, SearchFn]]
        ] = None,
        warm_batches: Optional[List[Any]] = None,
        canary: Any = None,
        expect: Any = None,
        pressure_fn: Optional[Callable[[], float]] = None,
        clock: Clock = SYSTEM_CLOCK,
        probe_timeout: float = 30.0,
        drain_timeout: float = 30.0,
        on_event: Optional[Callable[[str], None]] = None,
    ):
        if canary is None and warm_batches:
            canary = warm_batches[0]
        if canary is None:
            raise ValueError("need a canary batch (or warm_batches)")
        self.router = router
        self.spec = spec
        self.clock = clock
        self.snapshot = snapshot
        self._warm = warm_batches
        self._canary = canary
        self._expect = expect
        self._pressure_fn = pressure_fn
        self._probe_timeout = probe_timeout
        self._drain_timeout = drain_timeout
        self._log = on_event or (lambda msg: None)

        self._builder: Optional[IndexBuilder] = None
        if replica_factory is None:
            if snapshot is None or encode_fn is None:
                raise ValueError(
                    "need snapshot + encode_fn (to build replicas from "
                    "the spec) or an explicit replica_factory"
                )
            self._builder = spec.make_index_builder()

            def replica_factory(slot: int) -> Tuple[EncodeFn, SearchFn]:
                return encode_fn, self._builder.build(snapshot, replica=slot)

        self._factory = replica_factory

        self._window: List[float] = []
        self._prev_totals: Optional[Tuple[int, int]] = None
        self._last_action_t: Optional[float] = None
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

        self.scale_up_count = 0
        self.scale_down_count = 0
        self.probe_failures = 0
        n = len(router.active_replicas())
        self.max_replicas_seen = n
        self.min_replicas_seen = n
        #: Every decision, in order: dicts with t / decision / pressure
        #: / replicas (and replica index for scaling actions).
        self.events: List[Dict[str, Any]] = []

    # -- pressure signal -----------------------------------------------

    def pressure(self) -> float:
        """Instantaneous tier pressure in [0, 1].

        ``max`` of two signals: queue occupancy (outstanding tickets
        over routable queue capacity — rises BEFORE sheds start) and
        the shed fraction since the previous sample (sheds per
        admission attempt — catches saturation a deep queue hides).
        """
        if self._pressure_fn is not None:
            return min(1.0, max(0.0, float(self._pressure_fn())))
        stats = self.router.stats()
        healthy = stats["healthy"]
        depth = max(1, self.router.replicas.config.queue_depth)
        out = self.router.outstanding()
        queue_frac = (
            sum(out.get(i, 0) for i in healthy) / (len(healthy) * depth)
            if healthy else 1.0
        )
        shed, req = stats["shed"], stats["requests"]
        if self._prev_totals is None:
            shed_frac = 0.0
        else:
            d_shed = shed - self._prev_totals[0]
            d_req = req - self._prev_totals[1]
            attempts = d_shed + d_req
            shed_frac = d_shed / attempts if attempts > 0 else 0.0
        self._prev_totals = (shed, req)
        return min(1.0, max(queue_frac, shed_frac))

    # -- actuation ------------------------------------------------------

    def _scale_up(self) -> bool:
        """Add one replica; True once it is warmed, probed, and routable."""
        slot = len(self.router.replicas.pipelines)
        encode_fn, search_fn = self._factory(slot)
        if self._warm:
            # Warm the throwaway pair first: stage threads carry
            # thread-local jit caches, and an un-warmed replica would
            # serve its first real batches through a compile stall —
            # the exact latency spike a scale-up is meant to relieve.
            serving.warmup_replicas([(encode_fn, search_fn)], self._warm)
        slot = self.router.add_replica(encode_fn, search_fn)
        if self._builder is not None and self.snapshot is not None:
            self.router.set_version(
                slot, builder_version(self._builder, self.snapshot)
            )
        if self.router.probe(slot, self._canary, expect=self._expect,
                             timeout=self._probe_timeout,
                             from_rebuild=True):
            self.scale_up_count += 1
            self._log(f"scale-up: replica {slot} admitted")
            return True
        # Failed canary: the slot is unhealthy and has never served —
        # retire it so capacity accounting (and the next decision) do
        # not count a replica that cannot take traffic.
        self.probe_failures += 1
        self.router.retire_replica(slot)
        self._log(f"scale-up: replica {slot} failed its canary; retired")
        return False

    def _scale_down(self) -> Optional[int]:
        """Drain + retire one replica (newest slot first); its index."""
        healthy = self.router.healthy()
        if len(healthy) <= 1:
            return None  # never retire the last routable replica
        victim = max(healthy)
        self.router.retire_replica(victim, timeout=self._drain_timeout)
        self.scale_down_count += 1
        self._log(f"scale-down: replica {victim} drained and retired")
        return victim

    # -- the decision loop ---------------------------------------------

    def tick(self) -> str:
        """One control-loop step; returns the decision taken.

        One of ``"scale-up"``, ``"scale-down"``, ``"hold"``,
        ``"warming"`` (window not yet full), ``"cooldown"``,
        ``"below-min"`` / ``"above-max"`` (bounds enforcement), or
        ``"scale-up-failed"``.
        """
        with self._lock:
            now = self.clock.now()
            p = self.pressure()
            n = len(self.router.active_replicas())
            decision = self._decide(now, p, n)
            n = len(self.router.active_replicas())
            self.max_replicas_seen = max(self.max_replicas_seen, n)
            self.min_replicas_seen = min(self.min_replicas_seen, n)
            self.events.append({
                "t": now, "decision": decision, "pressure": p,
                "replicas": n,
            })
            return decision

    def _decide(self, now: float, p: float, n: int) -> str:
        spec = self.spec
        # Desired-state enforcement outruns hysteresis AND cooldown: a
        # tier outside its bounds is wrong, not noisy.
        if n < spec.min_replicas:
            ok = self._scale_up()
            self._after_action(now)
            return "below-min" if ok else "scale-up-failed"
        if n > spec.max_replicas:
            self._scale_down()
            self._after_action(now)
            return "above-max"
        self._window.append(p)
        if len(self._window) > spec.window_ticks:
            self._window.pop(0)
        if len(self._window) < spec.window_ticks:
            return "warming"
        if self._last_action_t is not None \
                and now - self._last_action_t < spec.cooldown_s:
            return "cooldown"
        mean = sum(self._window) / len(self._window)
        if mean >= spec.high_water and n < spec.max_replicas:
            ok = self._scale_up()
            self._after_action(now)
            return "scale-up" if ok else "scale-up-failed"
        if mean <= spec.low_water and n > spec.min_replicas:
            self._scale_down()
            self._after_action(now)
            return "scale-down"
        return "hold"

    def _after_action(self, now: float) -> None:
        # Pre-action samples describe a tier shape that no longer
        # exists; deciding on them would double-count one burst.
        self._window.clear()
        self._last_action_t = now

    # -- background loop ------------------------------------------------

    def run(self, stop: threading.Event) -> None:
        """Tick every ``spec.tick_s`` until ``stop`` is set (the wait
        is clock-driven and interruptible — a FakeClock test advances
        through it; ``stop.set()`` wakes it immediately)."""
        while not self.clock.wait(stop, self.spec.tick_s):
            self.tick()

    def start(self) -> None:
        """Run the loop on a daemon thread; idempotent while alive."""
        if self._thread is not None and self._thread.is_alive():
            return
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self.run, args=(self._stop,),
            name="tier-autoscaler", daemon=True,
        )
        self._thread.start()

    def stop(self, *, timeout: float = 10.0) -> None:
        self._stop.set()
        t = self._thread
        self._thread = None
        if t is not None and t.is_alive():
            t.join(timeout=timeout)
            if t.is_alive():
                raise RuntimeError(
                    f"autoscaler thread did not exit within {timeout}s"
                )

    def summary(self) -> Dict[str, Any]:
        """Counters + bounds telemetry for the bench emitter / gate."""
        n = len(self.router.active_replicas())
        return {
            "replicas": n,
            "replicas_min": self.spec.min_replicas,
            "replicas_max": self.spec.max_replicas,
            "scale_ups": self.scale_up_count,
            "scale_downs": self.scale_down_count,
            "probe_failures": self.probe_failures,
            "max_replicas_seen": self.max_replicas_seen,
            "min_replicas_seen": self.min_replicas_seen,
            "decisions": len(self.events),
        }
