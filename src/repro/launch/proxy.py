"""Replicated serving tier: query router + proxy admission over N replicas.

The paper's production engine (Fig. 5) does not serve from one pipeline:
a proxy tier spreads high-concurrency query streams over *replicas* of
the whole index and degrades gracefully when one goes down (cf. the
proxy/replica designs in *Embedding-based Retrieval in Facebook Search*
and *Recurrent Binary Embedding*). This module is that tier at library
scale, built entirely from ``launch/serving.py``'s admission machinery:

  * ``ReplicaSet`` — N ``ServingPipeline`` replicas. Each replica is a
    full copy of the serving path (encode + ``SearchFn``): a single-host
    flat/IVF/HNSW closure, or a distributed ``engine.make_*_search``
    program over its own replica submesh (``mesh.make_replica_meshes``
    partitions the host's devices into disjoint submeshes — each replica
    shards the whole corpus over *its* leaves).
  * ``QueryRouter`` — routes each submitted batch to one replica under a
    pluggable policy (``round-robin`` | ``least-outstanding``), with

      - **cross-replica shedding**: under a shed policy, a batch that
        bounces off one replica's full admission queue is offered to the
        others; the proxy sheds only when *every* healthy replica is
        saturated (a single hot replica must not bounce traffic the
        tier has capacity for);
      - **failover**: a replica whose encode/scan raises is marked
        unhealthy and every ticket in flight on it is re-dispatched to
        the survivors — the proxy-level analogue of
        ``engine.make_failover_search``'s ``leaf_alive`` mask, except a
        replica holds the *whole* corpus, so failover costs a retry, not
        recall. Re-dispatch back-pressures instead of shedding (an
        admitted ticket is never dropped) and results stay bit-identical
        to single-replica serving, so a client awaiting its tickets in
        submission order sees an unchanged FIFO stream.

Every replica scores through the same kernels and every replica returns
bit-identical (scores, ids) for the same batch, which is what makes
routing and failover invisible to correctness: only latency and
throughput change.

Replica health is a five-state machine (per replica, owned by the
router; ``launch/lifecycle.py`` drives the swap transitions)::

            failure                     drain()
  healthy ─────────► unhealthy   healthy ─────► draining
     ▲                   │                          │ begin_rebuild()
     │ canary ok         │ probe()                  ▼
  probing ◄──────────────┘◄──────────────────── rebuilding

Only ``healthy`` replicas are routable. ``unhealthy`` is no longer
forever: a canary probe (``probe`` / the ``start_health_probe`` thread)
re-admits a replica whose transient fault has cleared — and every
re-admission bumps the replica pipeline's ``generation`` so its stats
are not conflated with the previous run.

Invariants (relied on by ``tests/test_proxy_router.py`` and
``tests/test_lifecycle.py``):

  * **FIFO per client** — a client awaiting its proxy tickets in
    submission order sees results in submission order, across routing,
    failover re-dispatch, and rolling swaps.
  * **Bit-identity vs ``serve_sequential``** — every replica serves the
    same math, so routed results equal the single-threaded loop's
    exactly, before, during, and after a swap to an equivalent index.
  * **First-wins ticket resolution** — a ``ProxyTicket`` is resolved
    exactly once (the router is the only resolver); a failover or drain
    re-dispatch racing a late success never clobbers a stored result.
  * **Admitted is never dropped** — failover and drain re-dispatch with
    ``force_block``; only ``submit`` itself may shed (or a deadline
    expire — the client's budget, not the tier's choice).

On top of routing and failover sits the robustness layer:

  * **deadlines** — ``submit(..., deadline=...)`` threads a per-query
    budget down to the replica stages, which shed expired batches at
    dequeue (``DeadlineExpired``, counted apart from queue sheds, and
    never treated as a replica failure);
  * **stuck-scan watchdogs** — ``start_watchdogs(budget_s)`` puts a
    monitor on every replica pipeline; a scan that hangs (instead of
    raising) past its budget marks the replica unhealthy with
    ``ScanStalled`` and the ordinary failover path re-dispatches its
    in-flight work — a hung replica no longer deadlocks the tier;
  * **graceful degradation** — ``enable_degradation(knob)`` steps a
    shared ``EffortKnob`` down (HNSW ef/beam, IVF nprobe) under queue
    pressure or near-deadline *before* any query is shed, and back up
    when pressure clears; degraded dispatches are counted per replica;
  * **retry + flap suppression** — ``submit_with_retry`` backs off
    (exponential + seeded jitter) on retryable ``RequestShed``; the
    health-probe loop backs off probing a replica whose revivals keep
    failing (``probe_backoff``) so a flapper cannot monopolise it.
"""

from __future__ import annotations

import dataclasses
import logging
import random
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.launch.clock import SYSTEM_CLOCK, Clock
from repro.launch.serving import (
    Array,
    DeadlineExpired,
    EncodeFn,
    IncompatibleVersion,
    LatencyStats,
    PipelineClosed,
    RequestShed,
    ScanStalled,
    SearchFn,
    SearchRequest,
    ServingConfig,
    ServingPipeline,
    Ticket,
    as_search_request,
    _percentile,
)

logger = logging.getLogger(__name__)


class AllReplicasDown(RuntimeError):
    """Raised by ``QueryRouter.submit`` when every replica is unhealthy
    (a transiently out-of-service tier — drain/rebuild/probe in flight —
    raises the retryable ``RequestShed`` instead)."""


#: Per-replica health states (see the module docstring's diagram).
#: "retired" (added with the autoscaler) is terminal: a scaled-down
#: replica's slot — drained losslessly, pipeline closed, never probed
#: or routed again. Slots are never renumbered (every per-replica dict
#: is keyed by index), so retirement tombstones instead of deleting.
REPLICA_STATES = ("healthy", "draining", "rebuilding", "probing",
                  "unhealthy", "retired")

#: States a replica can never leave / serve from again. For routability
#: math ("is the tier transiently empty or genuinely down?") retired
#: slots count like unhealthy ones — except no probe will ever revive
#: them.
_GONE_STATES = ("unhealthy", "retired")


# ---------------------------------------------------------------------------
# routing policies
# ---------------------------------------------------------------------------


class RoundRobin:
    """Cycle over healthy replicas; ties traffic evenly by arrival."""

    name = "round-robin"

    def __init__(self):
        self._next = 0

    def order(self, healthy: List[int], outstanding: Dict[int, int]) -> List[int]:
        k = self._next % len(healthy)
        self._next += 1
        return healthy[k:] + healthy[:k]


class LeastOutstanding:
    """Prefer the replica with the fewest un-replied tickets — adapts to
    replicas of unequal speed (a straggler accumulates outstanding work
    and stops receiving new batches until it drains)."""

    name = "least-outstanding"

    def order(self, healthy: List[int], outstanding: Dict[int, int]) -> List[int]:
        return sorted(healthy, key=lambda i: (outstanding.get(i, 0), i))


ROUTING_POLICIES = {
    RoundRobin.name: RoundRobin,
    LeastOutstanding.name: LeastOutstanding,
}


# ---------------------------------------------------------------------------
# graceful degradation
# ---------------------------------------------------------------------------


class EffortKnob:
    """Shared mutable search-effort level: 0 = full effort, each step up
    trades recall for latency.

    The index closures read ``knob.level`` per call (``ivf_search_from_
    snapshot(..., effort=knob)`` halves nprobe per level;
    ``hnsw_search_from_snapshot`` halves ef and beam), and the router
    steps the SAME knob object down under pressure and back up when it
    clears — degrade-before-shed. Bi-granular closures (``rerank=``)
    spend levels on a cheaper axis first: each level halves ``k_coarse``
    (floored at k — narrowing the fine rerank costs far less recall
    than shrinking the candidate pool) and only the residual levels fall
    through to nprobe/ef/beam (``index._snapshot.split_effort``).
    Thread-safe; reads are a bare int load so the hot search path pays
    nothing.

    Each effort level is its own jit program shape (nprobe/ef/beam are
    static), so the first batch served at a fresh level pays a compile;
    keep ``n_levels`` small (2-3 steps is plenty).
    """

    def __init__(self, n_levels: int = 3):
        if n_levels < 1:
            raise ValueError(f"EffortKnob needs n_levels >= 1, got {n_levels}")
        self.max_level = n_levels - 1
        self._lock = threading.Lock()
        self._level = 0
        self.degrade_count = 0
        self.restore_count = 0

    @property
    def level(self) -> int:
        return self._level

    def degrade(self) -> bool:
        """Step effort down one level; False when already at the floor."""
        with self._lock:
            if self._level >= self.max_level:
                return False
            self._level += 1
            self.degrade_count += 1
            return True

    def restore(self) -> bool:
        """Step effort back up one level; False when already at full."""
        with self._lock:
            if self._level <= 0:
                return False
            self._level -= 1
            self.restore_count += 1
            return True

    def reset(self) -> None:
        with self._lock:
            self._level = 0


# ---------------------------------------------------------------------------
# embedding-version compatibility
# ---------------------------------------------------------------------------


def _embedding_version(v: Any) -> Optional[str]:
    """Embedding version of a replica's recorded index version.

    ``set_version`` stores whatever the lifecycle hands it — an
    ``IndexVersion`` (which carries ``.embedding_version``) or a bare
    string tag. None = unversioned (routes any traffic)."""
    return getattr(v, "embedding_version", v)


class CompatibilityMatrix:
    """(query_version, index_version) -> compat encoder.

    The serving face of backward-compatible training (paper §3.2.3):
    ``bc_train_step`` anchors a new binarizer's output space to the old
    one's, so a query from either model can be encoded INTO the other's
    binary index without re-encoding the corpus. Registering
    ``(qv, iv) -> enc`` declares: a version-``qv`` float query, encoded
    by ``enc``, searches a version-``iv`` index at the bc recall floor.

    The router consults this at dispatch: a v2 query preferring a v2
    replica falls back to a v1 replica *through* the registered encoder
    when no native replica is routable — degrade by version before
    shedding, the version-axis analogue of the ``EffortKnob`` ladder.

    Same-version and unversioned pairs never need (or get) an entry:
    ``lookup`` returns None for them and the replica's own encoder runs.
    Thread-safe; ``register`` is how a live tier learns a new upgrade
    path mid-flight.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._enc: Dict[Tuple[str, str], EncodeFn] = {}

    def register(self, query_version: str, index_version: str,
                 encode_fn: EncodeFn) -> None:
        if query_version is None or index_version is None:
            raise ValueError("compat pair versions must be non-None")
        if query_version == index_version:
            raise ValueError(
                f"same-version pair {query_version!r} needs no compat encoder"
            )
        with self._lock:
            self._enc[(query_version, index_version)] = encode_fn

    def lookup(self, query_version: Optional[str],
               index_version: Optional[str]) -> Optional[EncodeFn]:
        """The compat encoder for a cross-version hop, else None.

        None also for native pairs (same version, or either side
        unversioned) — "no encoder needed", not "unreachable"; use
        ``compatible`` to distinguish."""
        if query_version is None or index_version is None \
                or query_version == index_version:
            return None
        with self._lock:
            return self._enc.get((query_version, index_version))

    def compatible(self, query_version: Optional[str],
                   index_version: Optional[str]) -> bool:
        if query_version is None or index_version is None:
            return True
        return (query_version == index_version
                or self.lookup(query_version, index_version) is not None)

    def pairs(self) -> List[Tuple[str, str]]:
        with self._lock:
            return sorted(self._enc)


def probe_backoff(interval: float, consecutive_failures: int,
                  *, cap_factor: float = 16.0) -> float:
    """Extra wait before re-probing a replica that failed its last
    ``consecutive_failures`` revival probes: ``interval * 2^(n-1)``,
    capped at ``cap_factor * interval``.

    Flap suppression: a replica that keeps failing its canary gets
    probed at 1x, 2x, 4x, ... the base interval instead of every tick —
    a permanently dead (or flapping) replica stops monopolising the
    probe loop, while the first retry is as fast as ever.
    """
    if consecutive_failures <= 0:
        return 0.0
    return interval * min(cap_factor,
                          2.0 ** (consecutive_failures - 1))


# ---------------------------------------------------------------------------
# replica set
# ---------------------------------------------------------------------------


class ReplicaSet:
    """N serving replicas, each its own ``ServingPipeline``.

    ``replicas`` is a sequence of (encode_fn, search_fn) pairs — one per
    replica. Engine replicas close over their own submesh program (see
    ``mesh.make_replica_meshes``); single-host replicas may simply share
    one index closure N times (N pipelines over the same arrays).
    """

    def __init__(
        self,
        replicas: Sequence[Tuple[EncodeFn, SearchFn]],
        *,
        config: ServingConfig = ServingConfig(),
        share_device: bool = False,
    ):
        """``share_device=True`` when the replicas are co-located on one
        device (e.g. N admission fronts over one CPU/TPU): their scan
        stages then share a lock and take turns dispatching, the way a
        real device command queue serialises programs — without it,
        concurrent XLA CPU scans oversubscribe the shared cores and
        every replica gets slower. Replicas on disjoint submeshes
        (``mesh.make_replica_meshes``) should keep the default False."""
        if not replicas:
            raise ValueError("ReplicaSet needs at least one replica")
        self.config = config
        # Kept so replicas added later (autoscaler scale-up) join the
        # same device command queue as the originals.
        self._scan_gate = threading.Lock() if share_device else None
        self.pipelines = [
            ServingPipeline(enc, srch, config=config, scan_gate=self._scan_gate)
            for enc, srch in replicas
        ]

    def add(self, encode_fn: EncodeFn, search_fn: SearchFn) -> int:
        """Append one more replica pipeline; returns its slot index.

        The new pipeline inherits the set's config and scan gate. The
        caller (``QueryRouter.add_replica``) is responsible for health
        bookkeeping — a bare ``add`` leaves the pipeline running but
        unknown to any router.
        """
        pipe = ServingPipeline(encode_fn, search_fn, config=self.config,
                               scan_gate=self._scan_gate)
        self.pipelines.append(pipe)
        return len(self.pipelines) - 1

    @classmethod
    def from_factory(
        cls,
        n_replicas: int,
        factory: Callable[[int], Tuple[EncodeFn, SearchFn]],
        *,
        config: ServingConfig = ServingConfig(),
        share_device: bool = False,
    ) -> "ReplicaSet":
        """Build N replicas from ``factory(i) -> (encode_fn, search_fn)``."""
        return cls([factory(i) for i in range(n_replicas)], config=config,
                   share_device=share_device)

    def __len__(self) -> int:
        return len(self.pipelines)

    def close(self, drain: bool = True):
        for p in self.pipelines:
            p.close(drain=drain)

    def stats(self) -> List[dict]:
        return [p.stats() for p in self.pipelines]


# ---------------------------------------------------------------------------
# proxy tickets + router
# ---------------------------------------------------------------------------


class ProxyTicket(Ticket):
    """Client handle for one routed batch; survives replica failover.

    A ``Ticket`` with its own resolution event: the **router** resolves
    it — with the replica's result, or with an error only once no
    healthy replica could serve the batch. Clients never observe an
    intermediate replica failure; ``result()`` simply waits across
    re-dispatches. ``t_enqueue``→``t_reply`` therefore spans the whole
    proxy path, failover retries included.
    """

    def __init__(self, seq: int, request: SearchRequest,
                 deadline: Optional[float] = None):
        super().__init__(seq, request.n_queries, deadline=deadline)
        # The typed request is retained for failover re-dispatch (and
        # cleared by Ticket._resolve: a resolved ticket held by a
        # long-running client must not pin its input alongside the
        # result for the rest of the run).
        self.request = request

        self._route_lock = threading.Lock()
        self._inner: Optional[Ticket] = None
        self._replica: Optional[int] = None
        self.redispatches = 0

    @property
    def queries(self) -> Any:
        """Legacy accessor: the raw submitted batch (None once resolved)."""
        return None if self.request is None else self.request.payload

    def _point_at(self, replica: int, inner: Ticket):
        with self._route_lock:
            if self._inner is not None:
                self.redispatches += 1
            self._inner, self._replica = inner, replica

    @property
    def replica(self) -> Optional[int]:
        """Index of the replica that last held the batch."""
        return self._replica


class QueryRouter:
    """Route query batches across a ``ReplicaSet`` (see module docstring).

    ``policy`` is ``"round-robin"``, ``"least-outstanding"``, or any
    object with ``.name`` and ``.order(healthy, outstanding) -> [int]``
    (the order in which replicas are offered a batch; under a shed
    policy, later entries are fallbacks when earlier queues are full).
    """

    def __init__(
        self,
        replicas: ReplicaSet,
        *,
        policy: Union[str, Any] = "round-robin",
        compat: Optional[CompatibilityMatrix] = None,
        clock: Clock = SYSTEM_CLOCK,
    ):
        """``compat``: the tier's embedding-version compatibility matrix
        (bc-trained cross-version encoders). Defaults to an empty one —
        versioned traffic then routes only to native-version replicas
        and raises ``IncompatibleVersion`` when none exists.

        ``clock``: time source for every control loop the router owns
        (retry backoff, probe scheduling, deadline checks). Production
        keeps the default ``SYSTEM_CLOCK``; tests inject a ``FakeClock``
        and advance simulated time instead of sleeping real time."""
        self.replicas = replicas
        self.clock = clock
        self.compat = compat if compat is not None else CompatibilityMatrix()
        if isinstance(policy, str):
            try:
                policy = ROUTING_POLICIES[policy]()
            except KeyError:
                raise ValueError(
                    f"unknown routing policy {policy!r}; "
                    f"known: {sorted(ROUTING_POLICIES)}"
                ) from None
        self.policy = policy
        self._lock = threading.Lock()
        # Wakes drain()/wait_state() waiters: notified on every health-
        # state transition and whenever a replica's outstanding set
        # shrinks — drains complete the instant the last ticket lands,
        # not on the next poll tick.
        self._cond = threading.Condition(self._lock)
        self._seq = 0
        self._closed = False
        # Set first thing in close(): any clock.wait parked on a retry
        # backoff (submit_with_retry, run_stream_with_swap's shed retry)
        # wakes immediately instead of waiting out its full delay.
        self._close_event = threading.Event()
        # _healthy is the ROUTABLE set; _state carries the full health
        # state machine (a draining replica is out of _healthy but not
        # unhealthy — see REPLICA_STATES).
        self._healthy = set(range(len(replicas)))
        self._state: Dict[int, str] = {
            i: "healthy" for i in range(len(replicas))
        }
        self._versions: Dict[int, Any] = {i: None for i in range(len(replicas))}
        self._outstanding: Dict[int, set] = {
            i: set() for i in range(len(replicas))
        }
        self.shed_count = 0  # proxy-level: every healthy replica was full
        self.failover_count = 0  # tickets re-dispatched off a dead replica
        self.revival_count = 0  # unhealthy replicas re-admitted by a probe
        # Deadline sheds observed at the proxy (expired before dispatch);
        # the per-replica pipelines count their own dequeue-time sheds.
        self._deadline_expired = 0
        # Graceful degradation (enable_degradation): a shared EffortKnob
        # the index closures read per call, stepped down under pressure
        # before any shed, back up when pressure clears.
        self._effort: Optional[EffortKnob] = None
        self._degrade_hi = 0.75
        self._degrade_lo = 0.25
        self._near_deadline_s = 0.0
        self._degraded: Dict[int, int] = {
            i: 0 for i in range(len(replicas))
        }
        # Dispatches that crossed embedding versions through a compat
        # encoder (per replica) — the version-axis degradation counter.
        self._compat_served: Dict[int, int] = {
            i: 0 for i in range(len(replicas))
        }
        # Consecutive failed revival probes per replica (flap
        # suppression state; reset on a successful probe).
        self._probe_failures: Dict[int, int] = {}
        # Failover tickets caught while the tier is transiently
        # unroutable (a drain/rebuild/probe holds every replica): parked
        # here, flushed by the next successful probe. Never spun on —
        # _redispatch runs on stage-thread callbacks, and busy-waiting
        # there can block the very scan thread a revival probe needs.
        self._parked: List[Tuple[ProxyTicket, BaseException]] = []
        # Replicas whose current rebuild started from 'unhealthy': their
        # post-rebuild probe success counts as a revival too (the swap
        # reclaimed a dead replica in place).
        self._rebuild_from_dead: set = set()
        self._errors: Dict[int, BaseException] = {}
        self._probe_thread: Optional[threading.Thread] = None
        self._probe_stop = threading.Event()
        # Proxy-level completion accounting: enqueue->reply across the
        # whole tier (admission wait + any failover re-dispatches).
        self._stats = LatencyStats()

    # -- dispatch ------------------------------------------------------

    def _order(self) -> List[int]:
        healthy = sorted(self._healthy)
        counts = {i: len(self._outstanding[i]) for i in healthy}
        return self.policy.order(healthy, counts)

    def _route_version(self, replica: int) -> Optional[str]:
        """Embedding version ``replica`` currently serves (lock held)."""
        return _embedding_version(self._versions.get(replica))

    def _order_for_locked(self, req: SearchRequest) -> List[int]:
        """Policy order, filtered and re-ranked by embedding version
        (lock held): native-version replicas first (policy order within
        the group), then compat-reachable ones — degrade by version only
        when no native replica is routable. Unversioned requests (and
        unversioned replicas) see the plain policy order.

        A codes request cannot take the compat hop (there are no floats
        left to re-encode), so it is native-only.
        """
        order = self._order()
        qv = req.embedding_version
        if qv is None:
            return order
        native = [i for i in order
                  if self._route_version(i) in (None, qv)]
        if req.queries is None:
            return native
        compat = [i for i in order
                  if i not in native
                  and self.compat.lookup(qv, self._route_version(i))
                  is not None]
        return native + compat

    def submit(self, queries: Any, *,
               deadline: Optional[float] = None) -> ProxyTicket:
        """Admit one batch into the tier; returns a ``ProxyTicket``.

        Replicas are tried in policy order. Under ``policy="block"``
        pipelines the first choice back-pressures (no fallback — the
        caller asked for back-pressure); under ``policy="shed"`` a full
        replica queue falls through to the next, and ``RequestShed`` is
        raised only when **every** healthy replica is saturated — after
        one degrade-and-retry pass when degradation is enabled
        (effort steps down BEFORE any query is shed).

        ``deadline`` (absolute ``time.perf_counter()`` instant) rides
        the ticket down to the replica stages, which shed it at dequeue
        once expired. An already-expired deadline raises
        ``DeadlineExpired`` here — terminal, not retryable.

        ``queries`` may be a bare batch (legacy shim — unversioned,
        routes anywhere) or a ``SearchRequest``. A versioned request is
        offered native-version replicas first, then compat-reachable
        ones (through the tier's ``CompatibilityMatrix`` encoder);
        healthy replicas that serve the wrong version with no compat
        path raise ``IncompatibleVersion`` — terminal, like
        ``AllReplicasDown``, unlike ``RequestShed``.
        """
        req = as_search_request(queries, deadline=deadline)
        deadline = req.deadline
        if deadline is not None and self.clock.now() >= deadline:
            with self._lock:
                self._deadline_expired += 1
            raise DeadlineExpired("deadline already expired at submit")
        with self._lock:
            if self._closed:
                raise PipelineClosed("submit after close")
            if not self._healthy:
                if all(s in _GONE_STATES for s in self._state.values()):
                    raise AllReplicasDown(
                        f"all {len(self.replicas)} replica slots "
                        "unhealthy or retired"
                    )
                # Transiently empty tier (drain/rebuild/probe in flight):
                # retryable, unlike AllReplicasDown.
                raise RequestShed(
                    "no routable replica (index swap or probe in progress)"
                )
            self._adjust_effort_locked(deadline)
            if req.effort is not None and self._effort is not None:
                # Advisory effort hint: pre-degrade the shared knob at
                # least this far (coarse — the knob is tier-wide).
                while self._effort.level < req.effort \
                        and self._effort.degrade():
                    pass
            order = self._order_for_locked(req)
            if not order:
                raise IncompatibleVersion(
                    f"no routable replica serves embedding version "
                    f"{req.embedding_version!r} and no compat encoder "
                    f"reaches one (healthy replica versions: "
                    f"{sorted(str(self._route_version(i)) for i in self._healthy)}, "
                    f"compat pairs: {self.compat.pairs()})"
                )
            seq = self._seq
            self._seq += 1
        ticket = ProxyTicket(seq, req, deadline=deadline)
        shed_error: Optional[RequestShed] = None
        for attempt in (0, 1):
            for replica in order:
                try:
                    self._dispatch(ticket, replica)
                    return ticket
                except RequestShed as e:
                    shed_error = e
                    continue
                except PipelineClosed:
                    continue  # replica torn down under us; try the next
            if shed_error is None:
                raise PipelineClosed("every healthy replica is closed")
            # Every healthy replica is saturated: degrade-before-shed.
            # Step the knob down once and retry — cheaper scans drain
            # the queues; the shed only happens when the knob is already
            # at its floor (or degradation is off).
            if attempt == 0 and self._effort is not None \
                    and self._effort.degrade():
                with self._lock:
                    order = self._order_for_locked(req) \
                        if self._healthy else []
                if order:
                    continue
            break
        with self._lock:
            self.shed_count += 1
        raise RequestShed(
            "all healthy replicas saturated"
        ) from shed_error

    def _adjust_effort_locked(self, deadline: Optional[float]) -> None:
        """Step the effort knob against current pressure (lock held).

        Pressure = outstanding tickets / tier queue capacity over the
        routable replicas. >= high water (or a near-deadline submit):
        degrade one level. <= low water: restore one level — hysteresis,
        so the knob does not thrash around a single threshold.
        """
        if self._effort is None or not self._healthy:
            return
        cap = len(self._healthy) * max(1, self.replicas.config.queue_depth)
        load = sum(len(self._outstanding[i]) for i in self._healthy)
        pressure = load / cap
        near = (
            deadline is not None
            and self._near_deadline_s > 0.0
            and deadline - self.clock.now() < self._near_deadline_s
        )
        if pressure >= self._degrade_hi or near:
            self._effort.degrade()
        elif pressure <= self._degrade_lo:
            self._effort.restore()

    def enable_degradation(self, effort: EffortKnob, *,
                           high_water: float = 0.75,
                           low_water: float = 0.25,
                           near_deadline_s: float = 0.0) -> None:
        """Turn on degrade-before-shed with ``effort`` (the SAME knob
        object the replica search closures were built over).

        Every submit re-evaluates queue pressure: >= ``high_water`` of
        tier capacity (or a deadline within ``near_deadline_s``) steps
        effort down; <= ``low_water`` steps it back up. A submit that
        would otherwise shed (every queue full) also degrades once and
        retries before giving up. Dispatches served at level > 0 are
        counted per replica (``degraded`` in stats).
        """
        if not 0.0 <= low_water < high_water <= 1.0:
            raise ValueError(
                f"need 0 <= low_water < high_water <= 1, got "
                f"{low_water}/{high_water}"
            )
        with self._lock:
            self._effort = effort
            self._degrade_hi = high_water
            self._degrade_lo = low_water
            self._near_deadline_s = near_deadline_s

    def submit_with_retry(
        self,
        queries: Any,
        *,
        deadline: Optional[float] = None,
        attempts: int = 6,
        base_delay_s: float = 0.005,
        max_delay_s: float = 0.25,
        jitter: float = 0.5,
        rng: Optional[random.Random] = None,
    ) -> ProxyTicket:
        """``submit`` with exponential backoff + jitter on retryable
        ``RequestShed`` (saturated tier, or a swap/probe transiently
        holding every replica).

        Terminal errors — ``AllReplicasDown``, ``PipelineClosed``,
        ``DeadlineExpired`` — propagate immediately; a deadline that
        expires *between* attempts cuts the retry loop short the same
        way. ``rng`` seeds the jitter (defaults to a fresh
        ``random.Random(0)``: deterministic, but pass a shared seeded
        instance when many clients retry in lockstep — identical jitter
        defeats its purpose).
        """
        if attempts < 1:
            raise ValueError(f"attempts must be >= 1, got {attempts}")
        rng = rng if rng is not None else random.Random(0)
        last: Optional[RequestShed] = None
        for attempt in range(attempts):
            try:
                return self.submit(queries, deadline=deadline)
            except RequestShed as e:
                last = e
                if attempt == attempts - 1:
                    break
                delay = min(max_delay_s, base_delay_s * (2.0 ** attempt))
                delay *= 1.0 + jitter * rng.random()
                if deadline is not None \
                        and self.clock.now() + delay >= deadline:
                    with self._lock:
                        self._deadline_expired += 1
                    raise DeadlineExpired(
                        f"deadline would expire during retry backoff "
                        f"(attempt {attempt + 1}/{attempts})"
                    ) from e
                # Interruptible backoff: close() sets _close_event, so a
                # teardown mid-backoff wakes immediately instead of
                # waiting out the full delay (the old uninterruptible
                # time.sleep here made close() block on stragglers).
                if self.clock.wait(self._close_event, delay):
                    raise PipelineClosed(
                        "router closed during retry backoff"
                    ) from e
        raise last

    def _dispatch(self, ticket: ProxyTicket, replica: int, *, force: bool = False):
        req = ticket.request
        if req is None:
            # Resolved (and its batch released) after the caller's
            # done() check: a re-dispatch racing a success. Submitting
            # the cleared payload would poison a healthy replica with a
            # fake encode error — skip instead.
            return
        pipe = self.replicas.pipelines[replica]
        # Register in _outstanding BEFORE pipe.submit, re-checking
        # routability under the same lock: submit() picked this replica
        # from an earlier snapshot, and a drain() landing in the gap
        # would otherwise see an empty outstanding set, declare the
        # replica quiet, and let the swap mutate the pipeline while this
        # batch is still dispatching onto it. The compat encoder is
        # resolved under the SAME lock: the replica's version may have
        # rolled (mid-upgrade swap) since submit() ranked it.
        with self._lock:
            if replica not in self._healthy:
                raise RequestShed(
                    f"replica {replica} left rotation "
                    f"({self._state[replica]}) before dispatch"
                )
            compat_enc: Optional[EncodeFn] = None
            rv = self._route_version(replica)
            if req.embedding_version is not None and rv is not None \
                    and rv != req.embedding_version:
                compat_enc = None if req.queries is None \
                    else self.compat.lookup(req.embedding_version, rv)
                if compat_enc is None:
                    # Retryable at this level: submit/redispatch fall
                    # through to the next replica in version order.
                    raise RequestShed(
                        f"replica {replica} serves version {rv!r}; no "
                        f"compat encoder from {req.embedding_version!r}"
                    )
            self._outstanding[replica].add(ticket)
            degraded = self._effort is not None and self._effort.level > 0
        inner_req = req if compat_enc is None else dataclasses.replace(
            req, encode_override=compat_enc
        )
        try:
            inner = pipe.submit(inner_req, force_block=force,
                                deadline=ticket.deadline)  # may shed
        except BaseException:
            with self._lock:
                self._outstanding[replica].discard(ticket)
                self._cond.notify_all()
            raise
        if degraded:
            with self._lock:
                self._degraded[replica] += 1
        if compat_enc is not None:
            with self._lock:
                self._compat_served[replica] += 1
        ticket._point_at(replica, inner)
        inner.add_done_callback(
            lambda t, tk=ticket, r=replica, ce=compat_enc is not None:
                self._on_inner_done(tk, r, t, compat=ce)
        )

    # -- failover ------------------------------------------------------

    def _on_inner_done(self, ticket: ProxyTicket, replica: int, inner: Ticket,
                       *, compat: bool = False):
        """Replica-ticket completion: the single place proxy tickets are
        resolved (clients only ever wait on the proxy ticket, so they
        never observe an intermediate replica failure)."""
        err = inner.error()
        if err is None:
            with self._lock:
                self._outstanding[replica].discard(ticket)
                served_v = self._route_version(replica)
                self._cond.notify_all()
            if inner.served_by_version is not None:
                served_v = inner.served_by_version
            # Provenance rides the resolve (same first-wins lock): two
            # racing inner successes (failover straggler + re-dispatch)
            # must not let the loser stamp the winner's result.
            if ticket._resolve(
                value=inner.result(),
                provenance=(replica, served_v,
                            inner.served_by_generation, compat,
                            inner.reranked),
            ):
                self._stats.record(ticket)
            return
        if isinstance(err, DeadlineExpired):
            # The client's budget ran out while the batch sat queued —
            # the replica is fine. No failover (re-dispatching expired
            # work wastes a survivor's time), no health transition; the
            # pipeline already counted it.
            with self._lock:
                self._outstanding[replica].discard(ticket)
                self._cond.notify_all()
            ticket._resolve(error=err)
            return
        if isinstance(err, PipelineClosed):
            # Torn down by close(), not a scan failure: propagate.
            with self._lock:
                self._outstanding[replica].discard(ticket)
                self._cond.notify_all()
            ticket._resolve(error=err)
            return
        # Encode/scan failure: eager failover — the moment the replica
        # ticket fails, not when the client calls result(). First caller
        # marks the replica unhealthy and sweeps ALL its in-flight
        # tickets; this ticket may have landed after that sweep (dispatch
        # raced the failure), so re-dispatch it individually if so.
        self._on_replica_failure(replica, err)
        with self._lock:
            straggler = ticket in self._outstanding[replica]
            if straggler:
                self._outstanding[replica].discard(ticket)
                self._cond.notify_all()
                self.failover_count += 1  # missed the sweep, same fate
        if straggler:
            self._redispatch(ticket, err)

    def _on_replica_failure(self, replica: int, error: BaseException):
        """Mark ``replica`` unhealthy (first caller wins) and re-dispatch
        every ticket in flight on it, oldest first."""
        with self._lock:
            if replica not in self._healthy:
                return  # already handled (or draining/rebuilding/probing:
                # the drain path and probe own those transitions)
            self._healthy.discard(replica)
            self._state[replica] = "unhealthy"
            self._errors[replica] = error
            victims = sorted(self._outstanding[replica], key=lambda t: t.seq)
            self._outstanding[replica] = set()
            self.failover_count += len(victims)
            self._cond.notify_all()
        self._fail_parked_if_tier_down()
        for ticket in victims:
            self._redispatch(ticket, error)

    def _redispatch(self, ticket: ProxyTicket, error: BaseException):
        if ticket.done():
            return  # raced a resolve (first-wins); nothing to recover
        req = ticket.request
        if req is None:
            return  # resolved between done() and here; nothing to recover
        while True:
            with self._lock:
                order = self._order_for_locked(req) if self._healthy else []
                if not order and self._healthy and not self._closed:
                    # Healthy replicas exist but none serves (or compat-
                    # reaches) the request's embedding version: a
                    # version dead-end, not a transient outage. Parking
                    # would hang the client on a probe that cannot
                    # change the version topology — fail typed instead.
                    error = IncompatibleVersion(
                        f"failover: no routable replica serves embedding "
                        f"version {req.embedding_version!r} and no compat "
                        f"encoder reaches one"
                    )
                elif not order and not self._closed and any(
                    s not in _GONE_STATES for s in self._state.values()
                ):
                    # Transiently unroutable (a drain/rebuild/probe owns
                    # every replica this instant): an admitted ticket is
                    # never dropped, so park it for the next successful
                    # probe to flush instead of failing work a swap will
                    # outlive by milliseconds.
                    self._parked.append((ticket, error))
                    return
            if not order:
                # Closed, every replica unhealthy, or a version
                # dead-end: genuinely unservable.
                ticket._resolve(error=error)
                return
            try:
                # force=True: back-pressure rather than shed — an
                # admitted ticket is never dropped by failover.
                self._dispatch(ticket, order[0], force=True)
                return
            except RequestShed:
                continue  # replica left rotation between order and dispatch
            except PipelineClosed:
                with self._lock:
                    self._healthy.discard(order[0])
                    self._state[order[0]] = "unhealthy"
                    self._cond.notify_all()
                self._fail_parked_if_tier_down()
                continue

    def _fail_parked_if_tier_down(self):
        """Terminally fail parked failover tickets once no replica can
        ever take them (router closed / every replica unhealthy with no
        transient state left to wait out) — a client awaiting result()
        must not hang on a tier that has nothing left to revive it."""
        with self._lock:
            if not self._closed and any(
                s not in _GONE_STATES for s in self._state.values()
            ):
                return
            parked, self._parked = self._parked, []
        for ticket, err in parked:
            ticket._resolve(error=err)

    def _flush_parked(self):
        """Re-dispatch parked failover tickets (a replica just returned
        to rotation), oldest first."""
        with self._lock:
            parked, self._parked = self._parked, []
        for ticket, err in sorted(parked, key=lambda p: p[0].seq):
            self._redispatch(ticket, err)

    # -- lifecycle / monitoring ---------------------------------------

    def healthy(self) -> List[int]:
        """Routable replicas (state == "healthy")."""
        with self._lock:
            return sorted(self._healthy)

    def states(self) -> Dict[int, str]:
        """Per-replica health state (see REPLICA_STATES)."""
        with self._lock:
            return dict(self._state)

    def wait_state(self, replica: int, states: Sequence[str], *,
                   timeout: Optional[float] = None) -> bool:
        """Block until ``replica``'s health state is one of ``states``
        (condition wait, woken by every transition — no polling).
        Returns False on timeout. The swap controller uses this to wait
        out an in-flight canary probe instead of sleep-polling."""
        states = tuple(states)
        for s in states:
            if s not in REPLICA_STATES:
                raise ValueError(f"unknown replica state {s!r}")
        with self._cond:
            return self._cond.wait_for(
                lambda: self._state[replica] in states, timeout
            )

    def probe_failures(self) -> Dict[int, int]:
        """Consecutive failed revival probes per replica (flap
        suppression state of the background probe loop)."""
        with self._lock:
            return dict(self._probe_failures)

    def outstanding(self) -> Dict[int, int]:
        with self._lock:
            return {i: len(s) for i, s in self._outstanding.items()}

    def set_version(self, replica: int, version: Any) -> None:
        """Record the index version a replica serves.

        ``RollingSwapController`` calls this on swap. Beyond stats, the
        version's ``embedding_version`` now drives routing: versioned
        requests prefer native replicas and fall back through the
        ``CompatibilityMatrix``. The embedding version is also pushed
        into the replica pipeline so replica-level tickets carry it as
        provenance."""
        with self._lock:
            self._versions[replica] = version
        self.replicas.pipelines[replica].embedding_version = (
            _embedding_version(version)
        )

    def versions(self) -> Dict[int, Any]:
        with self._lock:
            return dict(self._versions)

    # -- live index lifecycle (drain / rebuild / probe / revive) -------

    def drain(self, replica: int, *, timeout: float = 30.0,
              poll: Optional[float] = None) -> None:
        """healthy -> draining: stop routing to ``replica`` and wait for
        its in-flight proxy tickets to finish.

        In-flight work completes normally (the routable survivors absorb
        new traffic meanwhile); the wait is a condition-variable sleep
        woken by each completion (mirrors ``ServingPipeline.quiesce``),
        so the drain returns the instant the last ticket lands.
        Tickets still unresolved at ``timeout`` are re-dispatched to the
        survivors via the failover path (force_block — an admitted
        ticket is never dropped), so a stuck replica cannot stall the
        swap. On return the replica holds no proxy tickets; pair with
        ``ServingPipeline.quiesce`` before touching its stages.
        ``poll`` is dead (kept for call compatibility): there is no
        polling loop any more.
        """
        del poll
        with self._cond:
            st = self._state[replica]
            if st != "healthy":
                raise ValueError(
                    f"drain: replica {replica} is {st!r}, need 'healthy'"
                )
            self._state[replica] = "draining"
            self._healthy.discard(replica)
            self._cond.notify_all()
            if self._cond.wait_for(
                lambda: not self._outstanding[replica], timeout
            ):
                return
            # Timed out: sweep the stragglers onto the survivors, oldest
            # first (their inner tickets may still resolve on the
            # draining replica — first-wins keeps whichever result lands
            # first).
            victims = sorted(self._outstanding[replica], key=lambda t: t.seq)
            self._outstanding[replica] = set()
            self.failover_count += len(victims)
            self._cond.notify_all()
        err = RuntimeError(
            f"replica {replica} did not drain within {timeout}s"
        )
        for ticket in victims:
            self._redispatch(ticket, err)

    def begin_rebuild(self, replica: int) -> None:
        """draining|unhealthy -> rebuilding: the caller owns the replica
        until it hands it back through ``probe``."""
        with self._lock:
            st = self._state[replica]
            if st not in ("draining", "unhealthy"):
                raise ValueError(
                    f"begin_rebuild: replica {replica} is {st!r}, need "
                    "'draining' or 'unhealthy'"
                )
            if st == "unhealthy":
                self._rebuild_from_dead.add(replica)
            else:
                self._rebuild_from_dead.discard(replica)
            self._state[replica] = "rebuilding"
            self._cond.notify_all()

    # -- elastic capacity (autoscaler scale-up / scale-down) -----------

    def add_replica(self, encode_fn: EncodeFn, search_fn: SearchFn) -> int:
        """Grow the tier by one replica slot; returns the new index.

        The slot enters in ``rebuilding`` — OUT of rotation, owned by
        the caller exactly like a swap-controller rebuild. It receives
        no traffic until a canary ``probe(slot, ..., from_rebuild=True)``
        succeeds, so the scale-up path gets the same warmed-and-probed
        admission discipline as an index swap. Deliberately not
        ``unhealthy``: admitting a brand-new replica is not a revival
        and must not inflate ``revival_count``.
        """
        with self._lock:
            if self._closed:
                raise PipelineClosed("add_replica after close")
            slot = self.replicas.add(encode_fn, search_fn)
            self._state[slot] = "rebuilding"
            self._versions[slot] = None
            self._outstanding[slot] = set()
            self._degraded[slot] = 0
            self._compat_served[slot] = 0
            self._rebuild_from_dead.discard(slot)
            self._cond.notify_all()
        return slot

    def retire_replica(self, replica: int, *, timeout: float = 30.0) -> None:
        """Shrink the tier: drain ``replica`` losslessly, then tombstone
        its slot as ``retired`` and close its pipeline.

        The drain is the proxy's ordinary drain path — in-flight proxy
        tickets finish (or re-dispatch to the survivors at ``timeout``),
        so scale-down never loses or reorders admitted work. Slots are
        never renumbered: the retired index stays in every per-replica
        dict, excluded from routing, probing, and the ``replicas``
        count. Idempotent on an already-retired slot. An ``unhealthy``
        replica retires without a drain (it holds no tickets); the
        transient states raise — their current owner (swap controller /
        probe) must finish first.
        """
        with self._lock:
            st = self._state[replica]
        if st == "retired":
            return
        if st == "healthy":
            self.drain(replica, timeout=timeout)
        elif st != "unhealthy":
            raise ValueError(
                f"retire_replica: replica {replica} is {st!r}; finish "
                "the in-flight drain/rebuild/probe first"
            )
        with self._lock:
            self._state[replica] = "retired"
            self._healthy.discard(replica)
            self._errors.pop(replica, None)
            self._probe_failures.pop(replica, None)
            self._cond.notify_all()
        # Unreachable by routing from here on; safe to tear down.
        self.replicas.pipelines[replica].close(drain=True)
        self._fail_parked_if_tier_down()

    def active_replicas(self) -> List[int]:
        """Slots not retired (healthy or recoverable) — the tier's
        current size as the autoscaler and bench gate count it."""
        with self._lock:
            return sorted(i for i, s in self._state.items()
                          if s != "retired")

    def mark_unhealthy(self, replica: int,
                       error: Optional[BaseException] = None) -> None:
        """Force a replica out of service (any state -> unhealthy).

        From ``healthy`` this is the normal failover path (in-flight
        tickets re-dispatch to the survivors). From the transient states
        it parks the replica where the canary re-probe can reclaim it —
        the swap controller uses this when an aborted swap would
        otherwise strand a replica in ``draining``/``rebuilding``
        forever (no probe targets those states)."""
        with self._lock:
            in_rotation = replica in self._healthy
            if error is not None:
                self._errors[replica] = error
        if in_rotation:
            self._on_replica_failure(
                replica, error or RuntimeError(
                    f"replica {replica} marked unhealthy"
                )
            )
        else:
            with self._lock:
                self._state[replica] = "unhealthy"
                self._cond.notify_all()
            self._fail_parked_if_tier_down()

    def probe(self, replica: int, canary: Any, *, expect=None,
              timeout: float = 30.0, from_rebuild: bool = False) -> bool:
        """Canary-query an out-of-service replica; success re-admits it.

        The paper-style health re-probe: a real query batch is pushed
        through the replica's own pipeline (encode + scan, force_block).
        If it resolves — and matches ``expect``'s (scores, ids) when
        given — the replica returns to the routable set. A probe of an
        ``unhealthy`` replica that succeeds is a **revival** (counted in
        ``revival_count``) and starts a fresh stats generation, ending
        the old one-strike-forever behavior. Failure parks the replica
        back in ``unhealthy`` for the next probe.

        ``from_rebuild`` is the swap controller's hand-back: only it may
        probe a ``rebuilding`` replica. Without the flag a probe of a
        replica in ``rebuilding`` or ``probing`` returns False untouched
        — the background probe loop must never re-admit a replica whose
        stages another thread is mid-mutation (its target snapshot can
        go stale between listing and probing).
        """
        with self._lock:
            st = self._state[replica]
            if st == "healthy":
                return True
            if st == "draining":
                raise ValueError(
                    f"probe: replica {replica} is draining (finish the "
                    "drain/rebuild first)"
                )
            if st == "rebuilding" and not from_rebuild:
                return False  # the swap controller owns it
            if st == "probing":
                return False  # another probe is already in flight
            # A rebuild that reclaimed a dead replica counts as a
            # revival too; its generation was already bumped by the
            # swap controller, so only the direct unhealthy->probing
            # path needs a fresh one here.
            revival = st == "unhealthy" or (
                st == "rebuilding" and replica in self._rebuild_from_dead
            )
            fresh_generation = st == "unhealthy"
            self._rebuild_from_dead.discard(replica)
            self._state[replica] = "probing"
            self._cond.notify_all()
        pipe = self.replicas.pipelines[replica]
        if fresh_generation:
            # Separate the revived run's stats from the dead run's. The
            # quiesce must actually succeed: bumping the generation with
            # an old-generation scan still in flight would let its
            # completion race the stats reset — the exact conflation the
            # generation exists to prevent. A still-stuck replica goes
            # back to unhealthy for the next probe.
            if not pipe.quiesce(timeout=min(timeout, 5.0)):
                with self._lock:
                    self._state[replica] = "unhealthy"
                    self._cond.notify_all()
                self._fail_parked_if_tier_down()
                return False
            pipe.new_generation()
        try:
            ticket = pipe.submit(canary, force_block=True)
            vals, ids = ticket.result(timeout=timeout)
            if expect is not None:
                ev, ei = expect
                if not (np.array_equal(np.asarray(ids), np.asarray(ei))
                        and np.array_equal(np.asarray(vals),
                                           np.asarray(ev))):
                    raise RuntimeError(
                        f"replica {replica} canary mismatch vs expected "
                        "(scores, ids)"
                    )
        except BaseException as e:
            with self._lock:
                self._state[replica] = "unhealthy"
                self._errors[replica] = e
                self._cond.notify_all()
            self._fail_parked_if_tier_down()
            return False
        with self._lock:
            self._state[replica] = "healthy"
            self._healthy.add(replica)
            self._errors.pop(replica, None)
            self._probe_failures.pop(replica, None)
            if revival:
                self.revival_count += 1
            self._cond.notify_all()
        # A replica is back: failover tickets parked while the tier was
        # transiently unroutable can flow again.
        self._flush_parked()
        return True

    def start_health_probe(self, canary: Any, *, interval: float = 1.0,
                           expect=None, timeout: float = 30.0) -> None:
        """Start the periodic re-probe loop: every ``interval`` seconds,
        canary-probe each ``unhealthy`` replica and revive the ones that
        answer. Idempotent; ``stop_health_probe``/``close`` stops it.

        Flap suppression: a replica whose revival probes keep failing is
        probed at ``probe_backoff(interval, n_failures)`` spacing
        (1x, 2x, 4x, ... the interval, capped) instead of every tick —
        a flapping or permanently dead replica cannot monopolise the
        loop while healthy work waits. The counter resets the moment a
        probe succeeds; ``probe_failures()`` exposes it.
        """
        with self._lock:
            if self._probe_thread is not None and self._probe_thread.is_alive():
                return
            self._probe_stop = threading.Event()
            stop = self._probe_stop

            def loop():
                next_due: Dict[int, float] = {}
                while not self.clock.wait(stop, interval):
                    with self._lock:
                        targets = [i for i, s in self._state.items()
                                   if s == "unhealthy"]
                    for i in targets:
                        if stop.is_set():
                            return
                        if self.clock.now() < next_due.get(i, 0.0):
                            continue  # backing off a flapper
                        if self.probe(i, canary, expect=expect,
                                      timeout=timeout):
                            next_due.pop(i, None)
                            continue
                        with self._lock:
                            fails = self._probe_failures.get(i, 0) + 1
                            self._probe_failures[i] = fails
                        next_due[i] = self.clock.now() + probe_backoff(
                            interval, fails
                        )

            self._probe_thread = threading.Thread(
                target=loop, name="router-health-probe", daemon=True
            )
            self._probe_thread.start()

    def stop_health_probe(self, *, timeout: float = 30.0) -> None:
        """Stop the probe loop and join its thread.

        Raises ``RuntimeError`` if the thread fails to exit within
        ``timeout`` — e.g. wedged inside ``probe`` on a stuck canary
        ticket. The old behaviour (silent join timeout) leaked a daemon
        thread that could revive replicas long after the caller believed
        probing had stopped; now the leak is loud and attributable.
        """
        self._probe_stop.set()
        t = self._probe_thread
        self._probe_thread = None
        if t is not None and t.is_alive():
            t.join(timeout=timeout)
            if t.is_alive():
                raise RuntimeError(
                    f"health-probe thread did not exit within {timeout}s "
                    "(wedged on a stuck probe ticket?); a daemon thread "
                    "has leaked and may still revive replicas"
                )

    # -- stuck-scan watchdogs ------------------------------------------

    def start_watchdogs(self, budget_s: float, *,
                        poll: Optional[float] = None) -> None:
        """Arm a stuck-scan watchdog on every replica pipeline.

        A scan that runs past ``budget_s`` without completing marks its
        replica unhealthy with ``ScanStalled``; the ordinary failover
        path then re-dispatches the replica's in-flight tickets to the
        survivors — a hung (non-raising) scan no longer deadlocks the
        tier. The canary probe loop can revive the replica later if the
        hang clears; until then it is out of rotation.
        """
        for i, pipe in enumerate(self.replicas.pipelines):
            pipe.start_watchdog(
                budget_s, self._make_stall_handler(i), poll=poll,
                clock=self.clock,
            )

    def _make_stall_handler(self, replica: int):
        def on_stall(pipe: ServingPipeline, seq: int, age: float):
            self.mark_unhealthy(replica, ScanStalled(
                f"replica {replica} scan (inner ticket {seq}) still "
                f"running after {age:.3f}s (budget exceeded)"
            ))
        return on_stall

    def stop_watchdogs(self) -> None:
        for pipe in self.replicas.pipelines:
            pipe.stop_watchdog()

    def close(self, drain: bool = True):
        with self._lock:
            if self._closed:
                return
            self._closed = True
        # First: wake every clock.wait parked on a retry backoff so
        # teardown is not gated on waiting out backoff delays.
        self._close_event.set()
        self._fail_parked_if_tier_down()  # closed: parked tickets fail
        try:
            self.stop_health_probe(timeout=5.0)
        except RuntimeError as e:
            # close() must complete even with a wedged probe thread; the
            # leak is logged instead of raised (the direct
            # stop_health_probe caller gets the exception).
            logger.error("close(): %s", e)
        self.stop_watchdogs()
        self.replicas.close(drain=drain)

    def __enter__(self) -> "QueryRouter":
        return self

    def __exit__(self, *exc):
        self.close()

    def stats(self) -> dict:
        """One proxy-level report over the whole tier.

        Aggregates each replica's totals and merges their latency
        windows for tier-wide percentiles; per-replica breakdowns ride
        along under ``per_replica``.
        """
        with self._lock:  # one snapshot: per-replica flags must agree
            shed_proxy = self.shed_count
            failovers = self.failover_count
            revivals = self.revival_count
            deadline_proxy = self._deadline_expired
            degraded = dict(self._degraded)
            compat_served = dict(self._compat_served)
            effort_level = (
                self._effort.level if self._effort is not None else None
            )
            healthy = sorted(self._healthy)
            states = dict(self._state)
            versions = dict(self._versions)
        per = []
        for i, pipe in enumerate(self.replicas.pipelines):
            if i not in states:
                continue  # add_replica raced the snapshot above
            s = pipe.stats()  # carries "generation" (bumped per revival/swap)
            s["replica"] = i
            s["healthy"] = i in healthy
            s["state"] = states[i]
            s["degraded"] = degraded[i]
            s["compat_served"] = compat_served[i]
            v = versions[i]
            s["version"] = getattr(v, "tag", v)
            s["embedding_version"] = _embedding_version(v)
            per.append(s)
        n_req, n_q, lat = self._stats.snapshot()
        lat.sort()
        # Averages (idle) and the headline count cover only live slots;
        # retired pipelines are closed and would skew both.
        live = [s for s in per if s["state"] != "retired"]
        idle = (
            sum(s["device_idle_frac"] for s in live) / len(live)
            if live else 0.0
        )
        return {
            "replicas": len(live),
            "retired_replicas": len(per) - len(live),
            "router": getattr(self.policy, "name", type(self.policy).__name__),
            "healthy": healthy,
            # proxy-level completions: a failed-over request counts once
            # here even though two replicas saw it.
            "requests": n_req,
            "queries": n_q,
            # proxy-level sheds only: a replica-level bounce that another
            # replica absorbed is routing, not shedding.
            "shed": shed_proxy,
            "replica_shed": sum(s["shed"] for s in per),
            # Deadline sheds across the tier: expired-at-submit (proxy)
            # plus expired-at-dequeue (per-replica stages).
            "deadline_expired": deadline_proxy + sum(
                s["deadline_expired"] for s in per
            ),
            # Dispatches served at reduced effort + the knob's position.
            "degraded": sum(degraded.values()),
            "effort_level": effort_level,
            # Dispatches that crossed embedding versions through a
            # compat encoder (version-axis degradation).
            "compat_dispatches": sum(compat_served.values()),
            "watchdog_stalls": sum(s["watchdog_stalls"] for s in per),
            "failovers": failovers,
            "revivals": revivals,
            "states": states,
            # tier-wide percentiles over proxy enqueue->reply (admission
            # wait + failover re-dispatches included).
            "latency_p50_ms": 1e3 * _percentile(lat, 0.50),
            "latency_p99_ms": 1e3 * _percentile(lat, 0.99),
            "device_idle_frac": idle,
            "per_replica": per,
        }


# ---------------------------------------------------------------------------
# offline driver
# ---------------------------------------------------------------------------


def serve_replicated(
    replicas: Sequence[Tuple[EncodeFn, SearchFn]],
    batches: List[Any],
    *,
    policy: Union[str, Any] = "round-robin",
    config: ServingConfig = ServingConfig(),
    share_device: bool = False,
) -> Tuple[List[Tuple[Array, Array]], dict]:
    """Run ``batches`` through a fresh replicated tier; (results, stats).

    The replicated twin of ``serving.serve_batches``: results come back
    in submission order and are bit-identical to ``serve_sequential``
    on any single replica. Admission is forced to "block" per replica —
    an offline driver should back-pressure, not shed. See ``ReplicaSet``
    for ``share_device``.
    """
    config = dataclasses.replace(config, policy="block")
    router = QueryRouter(
        ReplicaSet(replicas, config=config, share_device=share_device),
        policy=policy,
    )
    try:
        tickets = [router.submit(b) for b in batches]
        results = [t.result() for t in tickets]
    finally:
        # stats() only after close(): the join guarantees every scan
        # thread has run its completion callbacks (exact counters).
        router.close()
    return results, router.stats()
