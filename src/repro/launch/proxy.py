"""Replicated serving tier: query router + proxy admission over N replicas.

The paper's production engine (Fig. 5) does not serve from one pipeline:
a proxy tier spreads high-concurrency query streams over *replicas* of
the whole index and degrades gracefully when one goes down (cf. the
proxy/replica designs in *Embedding-based Retrieval in Facebook Search*
and *Recurrent Binary Embedding*). This module is that tier at library
scale, built entirely from ``launch/serving.py``'s admission machinery:

  * ``ReplicaSet`` — N ``ServingPipeline`` replicas. Each replica is a
    full copy of the serving path (encode + ``SearchFn``): a single-host
    flat/IVF/HNSW closure, or a distributed ``engine.make_*_search``
    program over its own replica submesh (``mesh.make_replica_meshes``
    partitions the host's devices into disjoint submeshes — each replica
    shards the whole corpus over *its* leaves).
  * ``QueryRouter`` — routes each submitted batch to one replica under a
    pluggable policy (``round-robin`` | ``least-outstanding``), with

      - **cross-replica shedding**: under a shed policy, a batch that
        bounces off one replica's full admission queue is offered to the
        others; the proxy sheds only when *every* healthy replica is
        saturated (a single hot replica must not bounce traffic the
        tier has capacity for);
      - **failover**: a replica whose encode/scan raises is marked
        unhealthy and every ticket in flight on it is re-dispatched to
        the survivors — the proxy-level analogue of
        ``engine.make_failover_search``'s ``leaf_alive`` mask, except a
        replica holds the *whole* corpus, so failover costs a retry, not
        recall. Re-dispatch back-pressures instead of shedding (an
        admitted ticket is never dropped) and results stay bit-identical
        to single-replica serving, so a client awaiting its tickets in
        submission order sees an unchanged FIFO stream.

Every replica scores through the same kernels and every replica returns
bit-identical (scores, ids) for the same batch, which is what makes
routing and failover invisible to correctness: only latency and
throughput change.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from repro.launch.serving import (
    Array,
    EncodeFn,
    LatencyStats,
    PipelineClosed,
    RequestShed,
    SearchFn,
    ServingConfig,
    ServingPipeline,
    Ticket,
    _percentile,
)


class AllReplicasDown(RuntimeError):
    """Raised by ``QueryRouter.submit`` when no healthy replica remains."""


# ---------------------------------------------------------------------------
# routing policies
# ---------------------------------------------------------------------------


class RoundRobin:
    """Cycle over healthy replicas; ties traffic evenly by arrival."""

    name = "round-robin"

    def __init__(self):
        self._next = 0

    def order(self, healthy: List[int], outstanding: Dict[int, int]) -> List[int]:
        k = self._next % len(healthy)
        self._next += 1
        return healthy[k:] + healthy[:k]


class LeastOutstanding:
    """Prefer the replica with the fewest un-replied tickets — adapts to
    replicas of unequal speed (a straggler accumulates outstanding work
    and stops receiving new batches until it drains)."""

    name = "least-outstanding"

    def order(self, healthy: List[int], outstanding: Dict[int, int]) -> List[int]:
        return sorted(healthy, key=lambda i: (outstanding.get(i, 0), i))


ROUTING_POLICIES = {
    RoundRobin.name: RoundRobin,
    LeastOutstanding.name: LeastOutstanding,
}


# ---------------------------------------------------------------------------
# replica set
# ---------------------------------------------------------------------------


class ReplicaSet:
    """N serving replicas, each its own ``ServingPipeline``.

    ``replicas`` is a sequence of (encode_fn, search_fn) pairs — one per
    replica. Engine replicas close over their own submesh program (see
    ``mesh.make_replica_meshes``); single-host replicas may simply share
    one index closure N times (N pipelines over the same arrays).
    """

    def __init__(
        self,
        replicas: Sequence[Tuple[EncodeFn, SearchFn]],
        *,
        config: ServingConfig = ServingConfig(),
        share_device: bool = False,
    ):
        """``share_device=True`` when the replicas are co-located on one
        device (e.g. N admission fronts over one CPU/TPU): their scan
        stages then share a lock and take turns dispatching, the way a
        real device command queue serialises programs — without it,
        concurrent XLA CPU scans oversubscribe the shared cores and
        every replica gets slower. Replicas on disjoint submeshes
        (``mesh.make_replica_meshes``) should keep the default False."""
        if not replicas:
            raise ValueError("ReplicaSet needs at least one replica")
        self.config = config
        gate = threading.Lock() if share_device else None
        self.pipelines = [
            ServingPipeline(enc, srch, config=config, scan_gate=gate)
            for enc, srch in replicas
        ]

    @classmethod
    def from_factory(
        cls,
        n_replicas: int,
        factory: Callable[[int], Tuple[EncodeFn, SearchFn]],
        *,
        config: ServingConfig = ServingConfig(),
        share_device: bool = False,
    ) -> "ReplicaSet":
        """Build N replicas from ``factory(i) -> (encode_fn, search_fn)``."""
        return cls([factory(i) for i in range(n_replicas)], config=config,
                   share_device=share_device)

    def __len__(self) -> int:
        return len(self.pipelines)

    def close(self, drain: bool = True):
        for p in self.pipelines:
            p.close(drain=drain)

    def stats(self) -> List[dict]:
        return [p.stats() for p in self.pipelines]


# ---------------------------------------------------------------------------
# proxy tickets + router
# ---------------------------------------------------------------------------


class ProxyTicket(Ticket):
    """Client handle for one routed batch; survives replica failover.

    A ``Ticket`` with its own resolution event: the **router** resolves
    it — with the replica's result, or with an error only once no
    healthy replica could serve the batch. Clients never observe an
    intermediate replica failure; ``result()`` simply waits across
    re-dispatches. ``t_enqueue``→``t_reply`` therefore spans the whole
    proxy path, failover retries included.
    """

    def __init__(self, seq: int, queries: Any):
        super().__init__(seq, int(getattr(queries, "shape", (1,))[0]))
        self.queries = queries  # retained for failover re-dispatch
        self._route_lock = threading.Lock()
        self._inner: Optional[Ticket] = None
        self._replica: Optional[int] = None
        self.redispatches = 0

    def _resolve(self, value=None, error=None) -> bool:
        won = super()._resolve(value=value, error=error)
        # The batch was retained only so failover could re-submit it; a
        # resolved ticket held by a long-running client must not pin its
        # input alongside the result for the rest of the run.
        self.queries = None
        return won

    def _point_at(self, replica: int, inner: Ticket):
        with self._route_lock:
            if self._inner is not None:
                self.redispatches += 1
            self._inner, self._replica = inner, replica

    @property
    def replica(self) -> Optional[int]:
        """Index of the replica that last held the batch."""
        return self._replica


class QueryRouter:
    """Route query batches across a ``ReplicaSet`` (see module docstring).

    ``policy`` is ``"round-robin"``, ``"least-outstanding"``, or any
    object with ``.name`` and ``.order(healthy, outstanding) -> [int]``
    (the order in which replicas are offered a batch; under a shed
    policy, later entries are fallbacks when earlier queues are full).
    """

    def __init__(
        self,
        replicas: ReplicaSet,
        *,
        policy: Union[str, Any] = "round-robin",
    ):
        self.replicas = replicas
        if isinstance(policy, str):
            try:
                policy = ROUTING_POLICIES[policy]()
            except KeyError:
                raise ValueError(
                    f"unknown routing policy {policy!r}; "
                    f"known: {sorted(ROUTING_POLICIES)}"
                ) from None
        self.policy = policy
        self._lock = threading.Lock()
        self._seq = 0
        self._closed = False
        self._healthy = set(range(len(replicas)))
        self._outstanding: Dict[int, set] = {
            i: set() for i in range(len(replicas))
        }
        self.shed_count = 0  # proxy-level: every healthy replica was full
        self.failover_count = 0  # tickets re-dispatched off a dead replica
        self._errors: Dict[int, BaseException] = {}
        # Proxy-level completion accounting: enqueue->reply across the
        # whole tier (admission wait + any failover re-dispatches).
        self._stats = LatencyStats()

    # -- dispatch ------------------------------------------------------

    def _order(self) -> List[int]:
        healthy = sorted(self._healthy)
        counts = {i: len(self._outstanding[i]) for i in healthy}
        return self.policy.order(healthy, counts)

    def submit(self, queries: Any) -> ProxyTicket:
        """Admit one batch into the tier; returns a ``ProxyTicket``.

        Replicas are tried in policy order. Under ``policy="block"``
        pipelines the first choice back-pressures (no fallback — the
        caller asked for back-pressure); under ``policy="shed"`` a full
        replica queue falls through to the next, and ``RequestShed`` is
        raised only when **every** healthy replica is saturated.
        """
        with self._lock:
            if self._closed:
                raise PipelineClosed("submit after close")
            if not self._healthy:
                raise AllReplicasDown(
                    f"all {len(self.replicas)} replicas unhealthy"
                )
            order = self._order()
            seq = self._seq
            self._seq += 1
        ticket = ProxyTicket(seq, queries)
        shed_error: Optional[RequestShed] = None
        for replica in order:
            try:
                self._dispatch(ticket, replica)
                return ticket
            except RequestShed as e:
                shed_error = e
                continue
            except PipelineClosed:
                continue  # replica torn down under us; try the next
        if shed_error is None:
            raise PipelineClosed("every healthy replica is closed")
        with self._lock:
            self.shed_count += 1
        raise RequestShed(
            f"all {len(order)} healthy replicas saturated"
        ) from shed_error

    def _dispatch(self, ticket: ProxyTicket, replica: int, *, force: bool = False):
        queries = ticket.queries
        if queries is None:
            # Resolved (and its batch released) after the caller's
            # done() check: a re-dispatch racing a success. Submitting
            # the cleared payload would poison a healthy replica with a
            # fake encode error — skip instead.
            return
        pipe = self.replicas.pipelines[replica]
        inner = pipe.submit(queries, force_block=force)  # may shed
        ticket._point_at(replica, inner)
        with self._lock:
            self._outstanding[replica].add(ticket)
        inner.add_done_callback(
            lambda t, tk=ticket, r=replica: self._on_inner_done(tk, r, t)
        )

    # -- failover ------------------------------------------------------

    def _on_inner_done(self, ticket: ProxyTicket, replica: int, inner: Ticket):
        """Replica-ticket completion: the single place proxy tickets are
        resolved (clients only ever wait on the proxy ticket, so they
        never observe an intermediate replica failure)."""
        err = inner.error()
        if err is None:
            with self._lock:
                self._outstanding[replica].discard(ticket)
            if ticket._resolve(value=inner.result()):
                self._stats.record(ticket)
            return
        if isinstance(err, PipelineClosed):
            # Torn down by close(), not a scan failure: propagate.
            with self._lock:
                self._outstanding[replica].discard(ticket)
            ticket._resolve(error=err)
            return
        # Encode/scan failure: eager failover — the moment the replica
        # ticket fails, not when the client calls result(). First caller
        # marks the replica unhealthy and sweeps ALL its in-flight
        # tickets; this ticket may have landed after that sweep (dispatch
        # raced the failure), so re-dispatch it individually if so.
        self._on_replica_failure(replica, err)
        with self._lock:
            straggler = ticket in self._outstanding[replica]
            if straggler:
                self._outstanding[replica].discard(ticket)
                self.failover_count += 1  # missed the sweep, same fate
        if straggler:
            self._redispatch(ticket, err)

    def _on_replica_failure(self, replica: int, error: BaseException):
        """Mark ``replica`` unhealthy (first caller wins) and re-dispatch
        every ticket in flight on it, oldest first."""
        with self._lock:
            if replica not in self._healthy:
                return  # already handled
            self._healthy.discard(replica)
            self._errors[replica] = error
            victims = sorted(self._outstanding[replica], key=lambda t: t.seq)
            self._outstanding[replica] = set()
            self.failover_count += len(victims)
        for ticket in victims:
            self._redispatch(ticket, error)

    def _redispatch(self, ticket: ProxyTicket, error: BaseException):
        if ticket.done():
            return  # raced a resolve (first-wins); nothing to recover
        while True:
            with self._lock:
                order = self._order() if self._healthy else []
            if not order:
                # No healthy replica can take the batch: the tier is
                # down and the ticket fails terminally.
                ticket._resolve(error=error)
                return
            try:
                # force=True: back-pressure rather than shed — an
                # admitted ticket is never dropped by failover.
                self._dispatch(ticket, order[0], force=True)
                return
            except PipelineClosed:
                with self._lock:
                    self._healthy.discard(order[0])
                continue

    # -- lifecycle / monitoring ---------------------------------------

    def healthy(self) -> List[int]:
        with self._lock:
            return sorted(self._healthy)

    def outstanding(self) -> Dict[int, int]:
        with self._lock:
            return {i: len(s) for i, s in self._outstanding.items()}

    def close(self, drain: bool = True):
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self.replicas.close(drain=drain)

    def __enter__(self) -> "QueryRouter":
        return self

    def __exit__(self, *exc):
        self.close()

    def stats(self) -> dict:
        """One proxy-level report over the whole tier.

        Aggregates each replica's totals and merges their latency
        windows for tier-wide percentiles; per-replica breakdowns ride
        along under ``per_replica``.
        """
        with self._lock:  # one snapshot: per-replica flags must agree
            shed_proxy = self.shed_count
            failovers = self.failover_count
            healthy = sorted(self._healthy)
        per = []
        for i, pipe in enumerate(self.replicas.pipelines):
            s = pipe.stats()
            s["replica"] = i
            s["healthy"] = i in healthy
            per.append(s)
        n_req, n_q, lat = self._stats.snapshot()
        lat.sort()
        idle = (
            sum(s["device_idle_frac"] for s in per) / len(per) if per else 0.0
        )
        return {
            "replicas": len(self.replicas),
            "router": getattr(self.policy, "name", type(self.policy).__name__),
            "healthy": healthy,
            # proxy-level completions: a failed-over request counts once
            # here even though two replicas saw it.
            "requests": n_req,
            "queries": n_q,
            # proxy-level sheds only: a replica-level bounce that another
            # replica absorbed is routing, not shedding.
            "shed": shed_proxy,
            "replica_shed": sum(s["shed"] for s in per),
            "failovers": failovers,
            # tier-wide percentiles over proxy enqueue->reply (admission
            # wait + failover re-dispatches included).
            "latency_p50_ms": 1e3 * _percentile(lat, 0.50),
            "latency_p99_ms": 1e3 * _percentile(lat, 0.99),
            "device_idle_frac": idle,
            "per_replica": per,
        }


# ---------------------------------------------------------------------------
# offline driver
# ---------------------------------------------------------------------------


def serve_replicated(
    replicas: Sequence[Tuple[EncodeFn, SearchFn]],
    batches: List[Any],
    *,
    policy: Union[str, Any] = "round-robin",
    config: ServingConfig = ServingConfig(),
    share_device: bool = False,
) -> Tuple[List[Tuple[Array, Array]], dict]:
    """Run ``batches`` through a fresh replicated tier; (results, stats).

    The replicated twin of ``serving.serve_batches``: results come back
    in submission order and are bit-identical to ``serve_sequential``
    on any single replica. Admission is forced to "block" per replica —
    an offline driver should back-pressure, not shed. See ``ReplicaSet``
    for ``share_device``.
    """
    import dataclasses

    config = dataclasses.replace(config, policy="block")
    router = QueryRouter(
        ReplicaSet(replicas, config=config, share_device=share_device),
        policy=policy,
    )
    try:
        tickets = [router.submit(b) for b in batches]
        results = [t.result() for t in tickets]
    finally:
        # stats() only after close(): the join guarantees every scan
        # thread has run its completion callbacks (exact counters).
        router.close()
    return results, router.stats()
