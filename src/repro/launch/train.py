"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --steps 100 --ckpt-dir /tmp/ckpt [--resume] [--smoke]

Runs the arch's train step on whatever mesh fits the local devices (the
production mesh shape comes from launch/mesh.py on a real fleet), with:
  * synthetic data pipeline (deterministic per step — restart-safe),
  * periodic atomic checkpoints + automatic resume from the latest valid
    one (fault tolerance: kill -9 at any point and relaunch),
  * loss/throughput logging.
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.data import synthetic
from repro.train import checkpoint as ckpt_lib
from repro.train import optim, steps


def _build(arch_id: str, smoke: bool, batch: int):
    entry = get_arch(arch_id)
    cfg = entry.smoke_config if smoke else entry.config
    key = jax.random.PRNGKey(0)
    adam = optim.AdamConfig(lr=3e-4, clip_norm=1.0)

    if entry.family == "lm":
        from repro.models import transformer as tf

        params = tf.init_params(key, cfg)
        step_fn = steps.lm_train_step(cfg, adam)
        batch_fn = lambda i: synthetic.lm_batch(i, batch, 128, cfg.vocab)
    elif entry.family == "gnn":
        from repro.models import gnn as gnn_lib

        params = gnn_lib.init_params(key, cfg)
        step_fn = steps.gnn_train_step(cfg, adam)
        batch_fn = lambda i: synthetic.gnn_batch(i, 256, 1024, cfg)
    else:
        model = entry.config.name.split("-")[0]
        if "dlrm" in arch_id:
            from repro.models.recsys import dlrm

            params = dlrm.init_params(key, cfg)
            step_fn = steps.dlrm_train_step(cfg, adam)
            batch_fn = lambda i: synthetic.dlrm_batch(i, batch, cfg)
        elif "two-tower" in arch_id:
            from repro.models.recsys import two_tower

            params = two_tower.init_params(key, cfg)
            step_fn = steps.tt_train_step(cfg, adam)
            batch_fn = lambda i: synthetic.tt_batch(i, batch, cfg)
        elif "mind" in arch_id:
            from repro.models.recsys import mind

            params = mind.init_params(key, cfg)
            step_fn = steps.mind_train_step(cfg, adam)
            batch_fn = lambda i: synthetic.mind_batch(i, batch, cfg)
        else:
            from repro.models.recsys import dien

            params = dien.init_params(key, cfg)
            step_fn = steps.dien_train_step(cfg, adam)
            batch_fn = lambda i: synthetic.dien_batch(i, batch, cfg)
    return params, step_fn, batch_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    args = ap.parse_args()

    params, step_fn, batch_fn = _build(args.arch, args.smoke, args.batch)
    opt_state = optim.adam_init(params)
    start = 0
    if args.resume and args.ckpt_dir:
        latest = ckpt_lib.latest_step(args.ckpt_dir)
        if latest is not None:
            (params, opt_state), start = ckpt_lib.restore(
                args.ckpt_dir, (params, opt_state), latest
            )
            print(f"[resume] from step {start}")

    jit_step = jax.jit(step_fn)
    t0 = time.time()
    for i in range(start, args.steps):
        batch = batch_fn(i)
        params, opt_state, metrics = jit_step(params, opt_state, batch)
        if (i + 1) % 10 == 0 or i == start:
            loss = float(metrics["loss"])
            dt = time.time() - t0
            print(f"step {i+1}: loss={loss:.4f} ({dt:.1f}s)", flush=True)
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            ckpt_lib.save(args.ckpt_dir, i + 1, (params, opt_state))
            print(f"[ckpt] step {i+1}", flush=True)
    print("done.")


if __name__ == "__main__":
    main()
