"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the process entry point (device count locks at first jax init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b \
        --shape train_4k [--multi-pod] [--all] [--out dryrun_results.json]

Per cell it records: per-device memory analysis, HLO FLOPs/bytes from
cost_analysis, collective wire bytes parsed from the post-SPMD HLO, and
timing — the inputs to EXPERIMENTS.md §Dry-run and §Roofline.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402
import argparse
import json
import re
import time
import traceback

import jax


COLLECTIVE_RE = re.compile(
    r"=\s*(?:\(.*?\)|\S+)\s*(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\("
)
SHAPE_RE = re.compile(r"=\s*((?:\(?[a-z0-9]+\[[0-9,]*\][^ ]*)+)\s")
DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _shape_bytes(shape_str: str) -> int:
    """bytes of one 'bf16[128,1024]' (or tuple '(f32[..], f32[..])')."""
    total = 0
    for m in re.finditer(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str):
        dt, dims = m.group(1), m.group(2)
        nbytes = DTYPE_BYTES.get(dt)
        if nbytes is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nbytes
    return total


def _group_size(line: str, default: int) -> int:
    """Parse replica group size from an HLO collective line."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)  # iota form
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return default


def parse_collectives(hlo_text: str, n_devices: int):
    """Per-device wire-byte model per collective op (ring algorithms):
      all-reduce: 2*B*(g-1)/g   all-gather: B_out*(g-1)/g
      reduce-scatter: B_in*(g-1)/g ~= B_out*(g-1)
      all-to-all: B*(g-1)/g     collective-permute: B
    Shapes in post-SPMD HLO are already per-device shards.
    """
    totals = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
              "all-to-all": 0.0, "collective-permute": 0.0}
    counts = dict.fromkeys(totals, 0)
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        eq = line.split("=", 1)
        if len(eq) != 2:
            continue
        out_bytes = _shape_bytes(eq[1].split(op)[0])
        g = _group_size(line, n_devices)
        if g <= 1:
            continue
        if op == "all-reduce":
            wire = 2.0 * out_bytes * (g - 1) / g
        elif op == "all-gather":
            wire = out_bytes * (g - 1) / g
        elif op == "reduce-scatter":
            wire = out_bytes * (g - 1)
        elif op == "all-to-all":
            wire = out_bytes * (g - 1) / g
        else:  # collective-permute
            wire = float(out_bytes)
        totals[op] += wire
        counts[op] += 1
    return totals, counts


def run_cell(arch_id: str, shape_id: str, multi_pod: bool):
    from repro.configs.registry import build_cell
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    cell = build_cell(arch_id, shape_id, mesh)

    t0 = time.time()
    with mesh:
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings)
        lowered = jitted.lower(*cell.abstract_args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    from repro.launch.hlo_cost import hlo_costs

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll, coll_counts = parse_collectives(hlo, n_dev)
    # loop-corrected structural cost model (XLA's cost_analysis counts
    # while bodies once — scan-over-layers under-reports by ~n_layers)
    corrected = hlo_costs(hlo, n_dev)

    result = {
        "arch": arch_id,
        "shape": shape_id,
        "kind": cell.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_per_device": (
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes
            ),
        },
        "cost": {
            "flops_per_device": corrected["flops"],
            "bytes_per_device": corrected["bytes"],
            "xla_body_once_flops": ca.get("flops", 0.0),
            "xla_body_once_bytes": ca.get("bytes accessed", 0.0),
            "transcendentals": ca.get("transcendentals", 0.0),
        },
        "collectives": {
            "wire_bytes_per_device": corrected["collectives"],
            "body_once_wire_bytes": coll,
            "counts": coll_counts,
        },
        "meta": cell.meta,
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    args = ap.parse_args()

    from repro.configs.registry import all_cells

    if args.all:
        cells_list = list(all_cells())
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells_list = [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    # incremental: merge into existing results file
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for arch_id, shape_id in cells_list:
        for mp in meshes:
            key = f"{arch_id}|{shape_id}|{'2x16x16' if mp else '16x16'}"
            if results.get(key, {}).get("ok"):
                print(f"[skip] {key} (cached)")
                continue
            print(f"[run ] {key}", flush=True)
            try:
                res = run_cell(arch_id, shape_id, mp)
                gib = res["memory"]["peak_bytes_per_device"] / 2**30
                print(
                    f"[ ok ] {key}: compile={res['compile_s']}s "
                    f"peak={gib:.2f} GiB/dev "
                    f"flops={res['cost']['flops_per_device']:.3e}",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001 — record and continue
                res = {
                    "arch": arch_id, "shape": shape_id,
                    "mesh": "2x16x16" if mp else "16x16",
                    "ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:],
                }
                print(f"[FAIL] {key}: {res['error']}", flush=True)
            results[key] = res
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)

    n_ok = sum(1 for r in results.values() if r.get("ok"))
    print(f"\n{n_ok}/{len(results)} cells OK -> {args.out}")


if __name__ == "__main__":
    main()
