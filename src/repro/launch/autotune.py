"""Persistent block-plan autotuner for the SDC kernel family.

Every Pallas scan/gather/rerank launch used to run with the hand-picked
tiles from ``kernels/sdc/defaults.py`` regardless of the live corpus
shape. This module closes that gap: on first use of a kernel signature
``(kind, code_dim, n_shard, packed, k, backend)`` it sweeps a small
candidate grid of ``(block_q, block_n)`` launch shapes, times each one
on synthetic operands of the live shapes (block choices never change
scores — only launch geometry — so random codes time exactly like real
ones), and persists the winner in a digest-keyed cache file.

The cache follows ``launch/binarizer_cache.py`` exactly: one file per
digest under ``--tune-cache`` / ``$REPRO_BEBR_CACHE`` /
``~/.cache/repro-bebr``, written atomically (tmp + rename) so a crashed
run never leaves a half-written plan, and validated on load — a corrupt
or stale entry (unreadable JSON, signature drift, non-integer blocks)
is re-tuned, never trusted. Replicas and repeat launches that share a
cache directory therefore share one tuned plan: the first toucher pays
the sweep, everyone else loads its winner.

Two signatures are never swept:

  * the "xla" backend has no tiles — blocks are inert, so the default
    plan comes back immediately (source "inert-backend");
  * kind "gather" has corpus-fixed geometry (one probed list per grid
    step; the list length is the tile), so there is nothing to sweep
    (source "fixed-geometry").

The sweep always times the default plan alongside the candidates and
keeps it unless a candidate is strictly faster, so a tuned plan can
only tie or beat the table on the shapes it was tuned for.
"""

from __future__ import annotations

import hashlib
import json
import os
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.sdc.defaults import BlockPlan, default_plan
from repro.kernels.sdc.ops import resolve_backend, sdc_search_backend
from repro.kernels.sdc.rerank import sdc_rerank_gathered
from repro.launch.binarizer_cache import CACHE_ENV, resolve_cache_dir

__all__ = [
    "CACHE_ENV",
    "TunedPlan",
    "candidate_grid",
    "plan_digest",
    "resolve_cache_dir",
    "tuned_block_plan",
]

_SCHEMA = 1


class TunedPlan(NamedTuple):
    """A block plan, plus where it came from.

    ``tuned`` is False when the plan was loaded from the cache (or the
    signature is un-sweepable); mirrors
    ``binarizer_cache.BinarizerCheckpoint.trained``.
    """

    plan: BlockPlan
    digest: str
    path: str | None
    tuned: bool


def plan_digest(
    kind: str, *, code_dim: int, n_shard: int, packed: bool, k: int,
    backend: str,
) -> str:
    """Digest of everything that determines the winning plan."""
    h = hashlib.sha1()
    h.update(str(("tuneplan", _SCHEMA)).encode())
    h.update(str((kind, code_dim, n_shard, bool(packed), k, backend)).encode())
    return h.hexdigest()[:20]


def candidate_grid(
    kind: str, *, code_dim: int, n_shard: int, packed: bool, k: int,
) -> list[tuple[int, int]]:
    """The (block_q, block_n) sweep for a signature, default plan first.

    Deliberately small — the sweep runs on a live serving path. Scan
    candidates stay sublane/lane aligned (block_q multiple of 8,
    block_n multiple of 128) and never exceed the padded corpus, so
    every candidate is a legal launch. Rerank candidates are survivor
    group sizes for the host-gather path.
    """
    base = default_plan(kind)
    if kind == "gather":
        return [base.blocks()]
    if kind == "rerank":
        groups = [g for g in (1, 8, 32) if g <= max(1, k)]
        return [(base.block_q, g) for g in (groups or [1])]
    # kind == "scan"
    cands: list[tuple[int, int]] = [base.blocks()]
    for bq in (8, 32, 128):
        for bn in (256, 512, 1024):
            if bn < 128 or (bq, bn) in cands:
                continue
            cands.append((bq, bn))
    return cands


def _time_call(fn, *, reps: int) -> float:
    """Median wall-clock seconds of ``fn`` after one untimed warmup."""
    jax.block_until_ready(fn())  # compile + warm caches
    samples = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples))


def _sweep_operands(
    kind: str, *, code_dim: int, n_shard: int, packed: bool, k: int,
    sample_q: int, n_levels: int, seed: int = 0,
):
    """Synthetic live-shape operands (values are irrelevant to timing)."""
    rng = np.random.default_rng(seed)
    hi = 2 ** n_levels
    q = jnp.asarray(
        rng.integers(0, hi, size=(sample_q, code_dim)).astype(np.int8)
    )
    if packed:
        d = rng.integers(0, 256, size=(n_shard, code_dim // 2))
        d = jnp.asarray(d.astype(np.uint8))
    else:
        d = jnp.asarray(
            rng.integers(0, hi, size=(n_shard, code_dim)).astype(np.int8)
        )
    inv = jnp.asarray(rng.uniform(0.5, 1.0, size=n_shard).astype(np.float32))
    return q, d, inv


def _candidate_timer(
    kind: str, blocks: tuple[int, int], operands, *, n_levels: int, k: int,
    packed: bool, backend: str,
):
    """A zero-arg callable running one launch with the given blocks."""
    q, d, inv = operands
    if kind == "rerank":
        # Host-gather rerank of k survivors per query against the shard.
        n = int(d.shape[0])
        fine = np.asarray(d)
        fine_inv = np.asarray(inv)
        rng = np.random.default_rng(1)
        cand = np.stack([
            rng.choice(n, size=min(k, n), replace=False)
            for _ in range(q.shape[0])
        ]).astype(np.int32)
        return lambda: sdc_rerank_gathered(
            q, fine, fine_inv, cand, n_levels=n_levels, k=min(k, n),
            packed=packed, group=blocks[1], backend=backend,
        )
    bq, bn = blocks
    return lambda: sdc_search_backend(
        q, d, inv, n_levels=n_levels, k=k, backend=backend,
        block_q=bq, block_n=bn, packed=packed,
    )


def _plan_path(root: str, digest: str) -> str:
    return os.path.join(root, f"tuneplan-{digest}.json")


def _save_plan(path: str, payload: dict) -> None:
    tmp = f"{path}.{os.getpid()}.tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def _load_plan(path: str, kind: str, signature: dict) -> BlockPlan:
    """Load and validate a cached plan; any defect raises ValueError."""
    with open(path) as f:
        payload = json.load(f)
    if payload.get("schema") != _SCHEMA:
        raise ValueError("tune-cache schema mismatch")
    if payload.get("signature") != signature:
        raise ValueError("tune-cache signature drift (stale entry)")
    bq, bn = payload["block_q"], payload["block_n"]
    if not (isinstance(bq, int) and isinstance(bn, int) and bq >= 1 and bn >= 0):
        raise ValueError(f"tune-cache blocks corrupt: {(bq, bn)!r}")
    return BlockPlan(kind, bq, bn, "cache")


def tuned_block_plan(
    kind: str,
    *,
    code_dim: int,
    n_shard: int,
    packed: bool = False,
    k: int = 10,
    n_levels: int = 4,
    backend: str = "auto",
    cache_dir: str | None = None,
    sample_q: int = 8,
    reps: int = 2,
) -> TunedPlan:
    """Tune (or reload) the block plan for one kernel signature.

    Returns a ``TunedPlan``; ``plan`` is always safe to thread through
    the search paths (blocks never change scores). ``tuned`` is True
    only when this call actually ran the sweep; a cache hit reloads the
    winner the first toucher persisted, so all replicas launch with one
    shared plan.
    """
    resolved = resolve_backend(backend)
    base = default_plan(kind)
    if resolved == "xla" and kind != "rerank":
        # No kernel tiles on the jnp path; nothing to sweep or cache.
        return TunedPlan(base._replace(source="inert-backend"), "", None, False)
    if kind == "gather":
        return TunedPlan(base._replace(source="fixed-geometry"), "", None, False)

    signature = {
        "kind": kind, "code_dim": int(code_dim), "n_shard": int(n_shard),
        "packed": bool(packed), "k": int(k), "backend": resolved,
    }
    digest = plan_digest(
        kind, code_dim=code_dim, n_shard=n_shard, packed=packed, k=k,
        backend=resolved,
    )
    root = resolve_cache_dir(cache_dir)
    path = _plan_path(root, digest)
    if os.path.exists(path):
        try:
            return TunedPlan(_load_plan(path, kind, signature), digest, path, False)
        except Exception:
            pass  # fall through to re-tune

    operands = _sweep_operands(
        kind, code_dim=code_dim, n_shard=n_shard, packed=packed, k=k,
        sample_q=sample_q, n_levels=n_levels,
    )
    best_blocks, best_t, default_t = base.blocks(), None, None
    for blocks in candidate_grid(
        kind, code_dim=code_dim, n_shard=n_shard, packed=packed, k=k
    ):
        try:
            fn = _candidate_timer(
                kind, blocks, operands, n_levels=n_levels, k=k,
                packed=packed, backend=resolved,
            )
            t = _time_call(fn, reps=reps)
        except Exception:
            continue  # illegal candidate for these shapes: skip, never fatal
        if blocks == base.blocks():
            default_t = t
        # Strict improvement required to displace the default plan.
        if best_t is None or t < best_t:
            best_blocks, best_t = blocks, t
    if default_t is not None and best_t is not None and default_t <= best_t:
        best_blocks = base.blocks()

    plan = BlockPlan(kind, int(best_blocks[0]), int(best_blocks[1]), "tuned")
    os.makedirs(root, exist_ok=True)
    _save_plan(path, {
        "schema": _SCHEMA,
        "signature": signature,
        "block_q": plan.block_q,
        "block_n": plan.block_n,
        "default_blocks": list(base.blocks()),
        "default_ms": None if default_t is None else default_t * 1e3,
        "tuned_ms": None if best_t is None else best_t * 1e3,
    })
    return TunedPlan(plan, digest, path, True)
