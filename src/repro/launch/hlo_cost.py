"""Structural HLO cost model: loop-aware FLOPs / bytes / collective wire.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE,
regardless of trip count — scan-over-layers and grad-accumulation scans
therefore under-report by orders of magnitude. This module re-derives the
three roofline inputs by walking the post-SPMD HLO text:

  * computations are parsed with brace matching; a per-computation symbol
    table maps op names to shapes;
  * ``while`` ops multiply their body's cost by ``known_trip_count``
    (emitted by XLA in backend_config); nested loops compose;
  * ``fusion``/``call``/``conditional`` recurse for FLOPs (a fused dot is
    still a dot) but count only their own operands/results for bytes
    (fusion intermediates never touch HBM — matching XLA's semantics);
  * dot FLOPs = 2 x batch x M x N x K from the dimension numbers;
  * collective wire bytes use ring cost models on per-device shard shapes.

Not XLA's exact cost model, but loop-correct — which matters far more at
126-layer scale than per-op constants.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"^\s*((?:\([^)]*\)|[a-z0-9\[\],{}\s]+?))\s*([\w\-]+)\(")
_CALLED_RE = re.compile(r"(?:calls|body|to_apply|branch_computations)=\{?(%[\w\.\-]+(?:,\s*%[\w\.\-]+)*)\}?")
_TRIP_RE = re.compile(r'known_trip_count[\"\\:{\s]+induction_var_step[^}]*|known_trip_count\\?":\s*\\?{\\?"n\\?":\\?"?(\d+)')
_TRIP_RE2 = re.compile(r'known_trip_count[^0-9]*(\d+)')
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    """(elements, bytes) over all array shapes in the string."""
    elems = 0
    nbytes = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        b = DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * b
    return elems, nbytes


def _first_shape_dims(shape_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})
    # (callee, multiplier, flops_only)
    calls: List[Tuple[str, float, bool]] = dataclasses.field(default_factory=list)


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur_name, cur_lines, depth = None, [], 0
    for line in text.splitlines():
        if cur_name is None:
            m = re.match(r"^\s*(?:ENTRY\s+)?(%[\w\.\-]+)\s*\(.*->.*\{", line)
            if m:
                cur_name = m.group(1)
                cur_lines = []
                depth = line.count("{") - line.count("}")
                if depth <= 0:
                    comps[cur_name] = cur_lines
                    cur_name = None
        else:
            depth += line.count("{") - line.count("}")
            if depth <= 0:
                comps[cur_name] = cur_lines
                cur_name = None
            else:
                cur_lines.append(line)
    return comps


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return default


def _dot_flops(shapes: Dict[str, str], result_shape: str, rest: str) -> float:
    """2 * result_elems * contracted_elems for a dot line.

    Handles both operand spellings XLA emits: the bare ``dot(%a, %b)`` of
    older dumps and the typed ``dot(f32[128,128]{1,0} %a, ...)`` of
    current ones (each operand prefixed by its full shape).
    """
    m = re.search(r"\bdot\(([^)]*)\)", rest)
    if not m:
        return 0.0
    arglist = m.group(1)
    names = re.findall(r"%[\w\.\-]+", arglist)
    if not names:
        return 0.0
    # lhs shape: inline type annotation first (typed format), else the
    # per-computation symbol table (bare format).
    lhs = None
    tm = re.search(r"([a-z0-9]+\[[0-9,]*\])(?:\{[^}]*\})?\s+"
                   + re.escape(names[0]), arglist)
    if tm:
        lhs = tm.group(1)
    if lhs is None:
        lhs = shapes.get(names[0])
    if lhs is None:
        return 0.0
    lhs_dims = _first_shape_dims(lhs) or []
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rest)
    contract = 1
    if cm and cm.group(1):
        for d in cm.group(1).split(","):
            if int(d) < len(lhs_dims):
                contract *= lhs_dims[int(d)]
    res_elems, _ = _shape_elems_bytes(result_shape)
    return 2.0 * res_elems * contract


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
    # contiguous reshapes lower to bitcasts on TPU (layout assignment);
    # counting them double-charges every reshape-heavy pipeline
    "reshape", "copy-start", "copy-done",
}


def analyse_computation(name: str, lines: List[str], n_devices: int) -> CompCost:
    cost = CompCost()
    shapes: Dict[str, str] = {}
    for line in lines:
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        op_name, rest = dm.group(1), dm.group(2)
        # result shape = everything before the op token
        om = re.match(r"((?:\([^)]*\)|[^ ]+))\s+([\w\-]+)", rest)
        if not om:
            continue
        result_shape, op = om.group(1), om.group(2)
        shapes[op_name] = result_shape

        if op == "while":
            tm = _TRIP_RE2.search(line)
            trips = float(tm.group(1)) if tm else 1.0
            bm = re.search(r"body=(%[\w\.\-]+)", line)
            if bm:
                cost.calls.append((bm.group(1), trips, False))
            continue
        if op in ("fusion", "call", "conditional", "map"):
            # bytes: own operands + result; flops: recurse (fused dots count)
            _, rb = _shape_elems_bytes(result_shape)
            opb = sum(
                _shape_elems_bytes(shapes.get(o, ""))[1]
                for o in re.findall(r"%[\w\.\-]+", rest.split("),", 1)[0])
            )
            cost.bytes += rb + opb
            cm = _CALLED_RE.search(line)
            if cm:
                for callee in re.findall(r"%[\w\.\-]+", cm.group(1)):
                    cost.calls.append((callee, 1.0, True))
            continue

        base_op = op.replace("-start", "")
        if base_op in _COLLECTIVES:
            _, out_b = _shape_elems_bytes(result_shape)
            g = _group_size(line, n_devices)
            if g > 1:
                if base_op == "all-reduce":
                    wire = 2.0 * out_b * (g - 1) / g
                elif base_op == "all-gather":
                    wire = out_b * (g - 1) / g
                elif base_op == "reduce-scatter":
                    wire = out_b * (g - 1)
                elif base_op == "all-to-all":
                    wire = out_b * (g - 1) / g
                else:
                    wire = float(out_b)
                cost.coll[base_op] += wire
            # fall through: collectives also move HBM bytes

        if op == "dot":
            cost.flops += _dot_flops(shapes, result_shape, rest)
        elif op == "convolution":
            # rare here; approximate as result_elems * kernel_elems * 2
            res_e, _ = _shape_elems_bytes(result_shape)
            cost.flops += 2.0 * res_e  # lower bound
        if op not in _SKIP_BYTES_OPS:
            _, rb = _shape_elems_bytes(result_shape)
            args = re.findall(r"%[\w\.\-]+", rest[rest.find("(") + 1: rest.find(")")])
            opb = sum(_shape_elems_bytes(shapes.get(o, ""))[1] for o in args)
            cost.bytes += rb + opb
    return cost


def hlo_costs(text: str, n_devices: int) -> Dict[str, float]:
    """Loop-corrected per-device totals from post-SPMD HLO text."""
    comps = _split_computations(text)
    costs = {name: analyse_computation(name, lines, n_devices)
             for name, lines in comps.items()}

    memo: Dict[Tuple[str, bool], Tuple[float, float, Dict[str, float]]] = {}

    def resolve(name: str, flops_only: bool, depth=0):
        key = (name, flops_only)
        if key in memo:
            return memo[key]
        if name not in costs or depth > 64:
            return 0.0, 0.0, {c: 0.0 for c in _COLLECTIVES}
        c = costs[name]
        f = c.flops
        b = 0.0 if flops_only else c.bytes
        coll = dict(c.coll) if not flops_only else {k: 0.0 for k in c.coll}
        for callee, mult, f_only in c.calls:
            cf, cb, cc = resolve(callee, flops_only or f_only, depth + 1)
            f += mult * cf
            b += mult * cb
            for k in coll:
                coll[k] += mult * cc[k]
        memo[key] = (f, b, coll)
        return memo[key]

    entry = None
    for name in comps:
        if "main" in name or "entry" in name.lower():
            entry = name
            break
    if entry is None:  # fall back to the largest computation
        entry = max(comps, key=lambda n: len(comps[n]))
    f, b, coll = resolve(entry, False)
    return {
        "flops": f,
        "bytes": b,
        "collectives": coll,
        "entry": entry,
        "n_computations": len(comps),
    }
