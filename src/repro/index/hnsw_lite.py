"""HNSW-lite: a navigable-small-world graph with SDC distances (Figure 6).

The paper plugs SDC into off-the-shelf HNSW; here we implement a compact
single-layer NSW (the HNSW fine layer) in numpy for index build, with the
query-time distance evaluated through the same affine-identity integer
math as the SDC kernel. Build is host-side (graph construction is
pointer-chasing and belongs on CPU even in production). Two searchers:

  * ``search_hnsw`` — the numpy greedy best-first beam search (reference
    semantics, per-query, per-hop host scoring).
  * ``search_hnsw_batched`` — the production path: a **batched-frontier
    beam search** over fixed-shape device arrays. Each hop expands the
    whole beam's fixed-width neighbor table ([Q, beam, M] ids) into one
    candidate block, dedupes it against a per-query visited bitmap, and
    scores the block in a single ``kernels/sdc`` gather-then-scan call
    (``backend="pallas"/"interpret"``) or its jnp twin (``"xla"``) — so
    graph search rides the same scoring substrate as the flat and IVF
    indexes, including the int4 nibble-packed code layout.

The batched searcher runs as a ``lax.while_loop`` over a fixed hop
budget: pointer-chasing becomes a fixed-shape device pipeline (gather ids
-> dedupe -> score block -> merge running top-ef -> pick next beam), so
it jits, vmaps over the query batch for free, and drops into the
distributed engine's shard_map leaves unchanged (index/engine.py).
"""

from __future__ import annotations

import dataclasses
import functools
import heapq
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.binarize_lib import (
    SDC_NEG_INF,
    pack_codes_nibbles,
    sdc_affine_epilogue,
)
from repro.kernels.sdc.gather import sdc_gather_topk, sdc_gather_topk_xla
from repro.kernels.sdc.ops import resolve_backend, sdc_search_xla


def _unpack_rows_np(packed: np.ndarray) -> np.ndarray:
    """Nibble-packed uint8 [..., D//2] -> int8 codes [..., D] (numpy).

    Host-side inverse of ``binarize_lib.pack_codes_nibbles`` (byte j =
    dim 2j | dim 2j+1 << 4) for the numpy build/search paths.
    """
    p = packed.astype(np.uint8)
    out = np.empty((*p.shape[:-1], p.shape[-1] * 2), np.int8)
    out[..., 0::2] = (p & 0x0F).astype(np.int8)
    out[..., 1::2] = (p >> 4).astype(np.int8)
    return out


@dataclasses.dataclass
class HNSWLite:
    codes: np.ndarray  # [N, D] int8, or nibble-packed uint8 [N, D//2]
    inv_norm: np.ndarray  # [N] f32
    neighbors: np.ndarray  # [N, M] int32 (-1 padded)
    entry: int
    n_levels: int
    packed: bool = False  # int4 nibble-packed code storage

    @property
    def code_dim(self) -> int:
        m = self.codes.shape[1]
        return 2 * m if self.packed else m

    def unpacked_codes(self) -> np.ndarray:
        return _unpack_rows_np(self.codes) if self.packed else self.codes

    def nbytes(self) -> int:
        """Index bytes as stored: codes + 4B norm per doc + the graph.

        The code term is layout-aware: nibble-packed storage holds 4 bits
        per dim regardless of n_levels, while unpacked storage is counted
        at the ideal n_levels-bits-per-dim serialisation (matching
        FlatSDC.nbytes). The previous formula applied the bit-packing math
        to ``codes.shape[1]`` blindly, undercounting packed indexes by 2x
        (packed rows are already D//2 wide) and ignoring the norms.
        """
        if self.packed:
            code_bytes = self.code_dim // 2  # 2 dims/byte in memory
        else:
            code_bytes = (self.code_dim * self.n_levels + 7) // 8
        return (
            self.codes.shape[0] * (code_bytes + 4) + self.neighbors.size * 4
        )


def _sdc_scores_np(q_code: np.ndarray, codes: np.ndarray, inv_norm: np.ndarray, n_levels: int):
    D = codes.shape[-1]
    dot = codes.astype(np.int32) @ q_code.astype(np.int32)
    sq = int(q_code.astype(np.int32).sum())
    sd = codes.astype(np.int32).sum(-1)
    # shared epilogue is pure arithmetic — stays in numpy on this hot path
    return sdc_affine_epilogue(dot, sq + sd, dim=D, n_levels=n_levels,
                               inv_norm=inv_norm)


def build_hnsw(
    codes: np.ndarray,
    inv_norm: np.ndarray,
    *,
    n_levels: int,
    M: int = 16,
    ef_construction: int = 64,
    seed: int = 0,
    packed: bool = False,
) -> HNSWLite:
    """Incremental NSW build: each point is connected to the M best results
    of a beam search among previously inserted points.

    With ``packed=True`` (n_levels <= 4) the built index stores its codes
    nibble-packed — the graph itself is identical; only storage changes.
    """
    if packed and n_levels > 4:
        raise ValueError(
            f"packed HNSW codes need n_levels <= 4, got {n_levels}"
        )
    rng = np.random.default_rng(seed)
    n = codes.shape[0]
    neighbors = -np.ones((n, M), np.int32)
    order = rng.permutation(n)
    inserted: List[int] = []

    def knn_beam(q_idx: int, ef: int) -> List[int]:
        if not inserted:
            return []
        sub = np.asarray(inserted)
        scores = _sdc_scores_np(codes[q_idx], codes[sub], inv_norm[sub], n_levels)
        top = np.argsort(-scores)[:ef]
        return [int(sub[t]) for t in top]

    for step, idx in enumerate(order):
        if step <= M:
            cands = list(inserted)
        else:
            cands = knn_beam(idx, ef_construction)
        best = cands[:M]
        neighbors[idx, : len(best)] = best
        # Backlinks. The first M//2 slots are immutable once set — they were
        # created while the graph was sparse and act as the long-range
        # "navigable" edges (pruning them to a pure kNN graph traps greedy
        # search inside clusters); only the tail slots are re-ranked.
        for b in best:
            row = neighbors[b]
            free = np.where(row < 0)[0]
            if free.size:
                row[free[0]] = idx
            else:
                head, tail = row[: M // 2], row[M // 2:]
                cand = np.append(tail, idx)
                sc = _sdc_scores_np(codes[b], codes[cand], inv_norm[cand], n_levels)
                keep = np.argsort(-sc)[: len(tail)]
                neighbors[b] = np.concatenate([head, cand[keep]])
        inserted.append(int(idx))

    entry = int(order[0])
    store = codes
    if packed:
        store = np.asarray(pack_codes_nibbles(jnp.asarray(codes)))
    return HNSWLite(
        codes=store, inv_norm=inv_norm, neighbors=neighbors, entry=entry,
        n_levels=n_levels, packed=packed,
    )


def _entry_points(n: int, entry: int, n_entries: int, seed: int) -> np.ndarray:
    """Shared entry-point selection: graph entry + seeded random restarts.

    Both searchers draw from here so the batched-frontier search explores
    from exactly the entry set the numpy reference uses (parity tests
    compare their top-k directly).
    """
    rng = np.random.default_rng(seed)
    return np.unique(
        np.concatenate([[entry], rng.integers(0, n, max(n_entries - 1, 0))])
    ).astype(np.int64)


def search_hnsw(
    index: HNSWLite, q_code: np.ndarray, *, k: int, ef: int = 64,
    n_entries: int = 8, seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Greedy best-first beam search from multiple entry points (numpy
    reference; per-query, host-side scoring).

    Returns (scores [k], ids [k])."""
    codes = index.unpacked_codes()
    n = codes.shape[0]
    entries = _entry_points(n, index.entry, n_entries, seed)
    e_scores = _sdc_scores_np(
        q_code, codes[entries], index.inv_norm[entries], index.n_levels
    )
    visited = set(int(e) for e in entries)
    # max-heap by score via negation
    frontier = [(-float(s), int(e)) for s, e in zip(e_scores, entries)]
    heapq.heapify(frontier)
    results = [(float(s), int(e)) for s, e in zip(e_scores, entries)]

    while frontier:
        neg, node = heapq.heappop(frontier)
        worst = min(results)[0] if len(results) >= ef else -np.inf
        if -neg < worst and len(results) >= ef:
            break
        neigh = index.neighbors[node]
        neigh = neigh[neigh >= 0]
        fresh = [int(x) for x in neigh if int(x) not in visited]
        if not fresh:
            continue
        visited.update(fresh)
        sub = np.asarray(fresh)
        scores = _sdc_scores_np(q_code, codes[sub], index.inv_norm[sub], index.n_levels)
        for s, i in zip(scores, sub):
            if len(results) < ef or s > min(results)[0]:
                heapq.heappush(frontier, (-float(s), int(i)))
                results.append((float(s), int(i)))
                if len(results) > ef:
                    results.remove(min(results))

    results.sort(reverse=True)
    top = results[:k]
    return (
        np.asarray([s for s, _ in top], np.float32),
        np.asarray([i for _, i in top], np.int32),
    )


# ---------------------------------------------------------------------------
# Batched-frontier search on the fused SDC substrate.
#
# The graph is re-laid-out as fixed-width *neighbor blocks*: node i's block
# holds the codes/norms/ids of its M neighbors ([N, M, D], [N, M], [N, M]).
# A search hop then is a block-gather — exactly the access pattern of the
# scalar-prefetched gather-then-scan kernel the IVF fine layer uses, with
# the beam as the probe table. The M-fold code duplication trades HBM bytes
# for DMA-streamable locality (one contiguous block per expanded node
# instead of M scattered row reads); packed int4 storage claws half back.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BatchedHNSW:
    """Device-resident, fixed-shape HNSW tables for the batched searcher."""

    codes: jax.Array  # [N, D] int8 (uint8 [N, D//2] packed) — entry scoring
    inv_norm: jax.Array  # [N] f32
    nbr_codes: jax.Array  # [N, M, D] int8 (uint8 [N, M, D//2] packed)
    nbr_inv: jax.Array  # [N, M] f32 (0 for -1 neighbor slots)
    nbr_ids: jax.Array  # [N, M] int32 (-1 padded)
    entry: int
    n_levels: int
    packed: bool = False

    @property
    def n(self) -> int:
        return self.nbr_ids.shape[0]

    @property
    def m(self) -> int:
        return self.nbr_ids.shape[1]

    def nbytes(self) -> int:
        """Device bytes of the search tables (includes the M-fold
        neighbor-block code duplication — this is the serving footprint,
        distinct from HNSWLite.nbytes which counts the stored index)."""
        return sum(
            int(a.size) * a.dtype.itemsize
            for a in (self.codes, self.inv_norm, self.nbr_codes,
                      self.nbr_inv, self.nbr_ids)
        )


def prepare_batched(
    index: HNSWLite, *, packed: Optional[bool] = None
) -> BatchedHNSW:
    """Expand an HNSWLite graph into gather-kernel-ready neighbor blocks.

    ``packed`` overrides the index's storage layout for the device tables
    (None: inherit). Packing requires n_levels <= 4.
    """
    packed = index.packed if packed is None else packed
    if packed and index.n_levels > 4:
        raise ValueError(
            f"packed HNSW tables need n_levels <= 4, got {index.n_levels}"
        )
    codes = index.unpacked_codes()
    nbr = index.neighbors.astype(np.int32)
    safe = np.where(nbr >= 0, nbr, 0)
    nbr_codes = codes[safe]  # [N, M, D]
    nbr_inv = np.where(
        nbr >= 0, index.inv_norm[safe], 0.0
    ).astype(np.float32)
    flat = codes
    if packed:
        nbr_codes = np.asarray(pack_codes_nibbles(jnp.asarray(nbr_codes)))
        flat = np.asarray(pack_codes_nibbles(jnp.asarray(flat)))
    return BatchedHNSW(
        codes=jnp.asarray(flat),
        inv_norm=jnp.asarray(index.inv_norm, jnp.float32),
        nbr_codes=jnp.asarray(nbr_codes),
        nbr_inv=jnp.asarray(nbr_inv),
        nbr_ids=jnp.asarray(nbr),
        entry=index.entry,
        n_levels=index.n_levels,
        packed=packed,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_levels", "k", "ef", "beam", "max_hops", "backend", "packed",
    ),
)
def hnsw_frontier_search(
    q_codes: jax.Array,
    codes: jax.Array,
    inv_norm: jax.Array,
    nbr_codes: jax.Array,
    nbr_inv: jax.Array,
    nbr_ids: jax.Array,
    entries: jax.Array,
    *,
    n_levels: int,
    k: int,
    ef: int,
    beam: int,
    max_hops: int,
    backend: str,
    packed: bool,
) -> Tuple[jax.Array, jax.Array, Dict[str, jax.Array]]:
    """Batched-frontier beam search over fixed-shape HNSW tables.

    State per query: a running top-``ef`` result list, a visited bitmap
    (scored-once dedupe) and an expanded bitmap (each node's neighbor
    block is streamed at most once). Each ``lax.while_loop`` hop:

      1. beam <- the ``beam`` best unexpanded entries of the result list;
      2. candidate block <- the beam's neighbor tables ([Q, beam, M] ids);
      3. dedupe within the block and against the visited bitmap;
      4. score the whole block in one gather-kernel (or jnp twin) call,
         folding fresh candidates into a per-hop top-ef;
      5. merge into the running results.

    Terminates when every surviving result is expanded (the batched
    analogue of an exhausted best-first frontier) or at ``max_hops``.

    Args:
      q_codes: [Q, D] int8 query codes (unpacked, even when ``packed``).
      codes / inv_norm: flat corpus tables (entry-point scoring only).
      nbr_codes / nbr_inv / nbr_ids: neighbor-block tables ([N, M, ...]).
      entries: [E] int32 entry node ids, -1 padded.

    Returns:
      (scores [Q, k], ids [Q, k], stats) with empty slots (SDC_NEG_INF,
      -1); stats carries per-query ``hops`` and ``scored`` counters.
    """
    Q, D = q_codes.shape
    N, M = nbr_ids.shape
    E = entries.shape[0]
    rows = jnp.arange(Q)[:, None]

    # --- entry scoring (tiny: E docs per query, plain jnp) ---
    e_valid = entries >= 0
    e_ids = jnp.where(e_valid, entries, 0)
    e_inv = jnp.where(e_valid, inv_norm[e_ids], 0.0)
    res_vals, e_pos = sdc_search_xla(
        q_codes, codes[e_ids], e_inv, n_levels=n_levels, k=ef, packed=packed
    )
    res_ids = jnp.where(
        e_pos >= 0, entries[jnp.clip(e_pos, 0, E - 1)], -1
    ).astype(jnp.int32)

    visited = jnp.zeros((Q, N), jnp.uint8)
    visited = visited.at[:, e_ids].max(
        jnp.broadcast_to(e_valid.astype(jnp.uint8)[None, :], (Q, E))
    )
    expanded = jnp.zeros((Q, N), jnp.uint8)

    def cond(state):
        hop, active, *_ = state
        return (hop < max_hops) & jnp.any(active)

    def body(state):
        hop, active, res_vals, res_ids, visited, expanded, hops, scored = state

        # 1. Beam: best unexpanded results.
        rid_ok = res_ids >= 0
        rid = jnp.where(rid_ok, res_ids, 0)
        already = jnp.take_along_axis(expanded, rid, axis=1) > 0
        frontier = jnp.where(rid_ok & ~already, res_vals, SDC_NEG_INF)
        bvals, bpos = jax.lax.top_k(frontier, beam)
        beam_ids = jnp.where(
            bvals > SDC_NEG_INF / 2,
            jnp.take_along_axis(res_ids, bpos, axis=1),
            -1,
        )
        active = active & jnp.any(beam_ids >= 0, axis=-1)
        beam_ok = (beam_ids >= 0) & active[:, None]
        bclamp = jnp.where(beam_ok, beam_ids, 0)
        expanded = expanded.at[rows, bclamp].max(beam_ok.astype(jnp.uint8))

        # 2. Candidate block: the beam's neighbor ids (codes stay in HBM —
        # only the gather kernel / its jnp twin touches them).
        cand = jnp.where(beam_ok[..., None], nbr_ids[bclamp], -1)  # [Q,B,M]
        flat = cand.reshape(Q, beam * M)
        valid = flat >= 0
        fclamp = jnp.where(valid, flat, 0)

        # 3. Dedupe: first occurrence within the block, then the visited
        # bitmap (sort-based so shapes stay static).
        order = jnp.argsort(flat, axis=-1)
        sorted_ids = jnp.take_along_axis(flat, order, axis=-1)
        first = jnp.concatenate(
            [
                jnp.ones((Q, 1), bool),
                sorted_ids[:, 1:] != sorted_ids[:, :-1],
            ],
            axis=-1,
        )
        keep = jnp.take_along_axis(first, jnp.argsort(order, axis=-1), axis=-1)
        seen = jnp.take_along_axis(visited, fclamp, axis=1) > 0
        fresh = valid & keep & ~seen
        visited = visited.at[rows, fclamp].max(fresh.astype(jnp.uint8))

        # 4. Score the block through the shared SDC substrate.
        mask = fresh.reshape(Q, beam, M).astype(jnp.float32)
        if backend in ("pallas", "interpret"):
            hop_vals, hop_ids = sdc_gather_topk(
                q_codes, nbr_codes, nbr_inv, nbr_ids, bclamp,
                n_levels=n_levels, k=ef,
                interpret=(backend == "interpret"), packed=packed,
                cand_mask=mask,
            )
        else:
            hop_vals, hop_ids = sdc_gather_topk_xla(
                q_codes, nbr_codes, nbr_inv, nbr_ids, bclamp,
                n_levels=n_levels, k=ef, packed=packed, cand_mask=mask,
            )

        # 5. Merge into the running top-ef (fresh-only scoring guarantees
        # no id appears twice across hops).
        cat_v = jnp.concatenate([res_vals, hop_vals], axis=-1)
        cat_i = jnp.concatenate([res_ids, hop_ids], axis=-1)
        res_vals, pos = jax.lax.top_k(cat_v, ef)
        res_ids = jnp.take_along_axis(cat_i, pos, axis=-1)

        hops = hops + active.astype(jnp.int32)
        scored = scored + jnp.sum(fresh, axis=-1).astype(jnp.int32)
        return (
            hop + 1, active, res_vals, res_ids, visited, expanded, hops,
            scored,
        )

    state = (
        jnp.zeros((), jnp.int32),
        jnp.ones((Q,), bool),
        res_vals,
        res_ids,
        visited,
        expanded,
        jnp.zeros((Q,), jnp.int32),
        jnp.zeros((Q,), jnp.int32),
    )
    _, _, res_vals, res_ids, _, _, hops, scored = jax.lax.while_loop(
        cond, body, state
    )
    stats = {"hops": hops, "scored": scored}
    return res_vals[:, :k], res_ids[:, :k], stats


def search_hnsw_batched(
    index: BatchedHNSW,
    q_codes: jax.Array,
    *,
    k: int,
    ef: int = 64,
    beam: int = 8,
    max_hops: int = 64,
    n_entries: int = 8,
    seed: int = 0,
    backend: str = "auto",
    with_stats: bool = False,
):
    """Multi-query HNSW search on the fused SDC substrate.

    Entry points match ``search_hnsw`` for the same (n_entries, seed), so
    the two searchers are directly comparable. ``backend`` follows the
    other indexes: pallas / interpret -> the scalar-prefetched
    gather-then-scan kernel, xla -> jnp twin, auto -> pallas on TPU.

    Returns (scores [Q, k], ids [Q, k]) — plus a stats dict of per-query
    ``hops`` and ``scored`` (candidates folded into the running top-k)
    when ``with_stats`` is set.
    """
    backend = resolve_backend(backend)
    ef = max(ef, k)
    beam = max(1, min(beam, ef))
    ents = _entry_points(index.n, index.entry, n_entries, seed)
    padded = np.full((max(n_entries, 1),), -1, np.int32)
    padded[: len(ents)] = ents[: len(padded)]
    vals, ids, stats = hnsw_frontier_search(
        q_codes,
        index.codes,
        index.inv_norm,
        index.nbr_codes,
        index.nbr_inv,
        index.nbr_ids,
        jnp.asarray(padded),
        n_levels=index.n_levels,
        k=k,
        ef=ef,
        beam=beam,
        max_hops=max_hops,
        backend=backend,
        packed=index.packed,
    )
    if with_stats:
        return vals, ids, stats
    return vals, ids


def hnsw_search_from_snapshot(
    codes,
    n_levels: int = None,
    *,
    k: int,
    M: int = 16,
    ef_construction: int = 64,
    ef: int = 64,
    beam: int = 8,
    max_hops: int = 64,
    seed: int = 0,
    packed: bool = False,
    backend: str = "xla",
    effort=None,
    rerank: dict | None = None,
    block_plan=None,
):
    """Rebuild-from-snapshot entry point (live index lifecycle).

    Rebuilds the NSW graph from a corpus snapshot's unpacked codes
    (host-side, O(N^2) — size swap corpora accordingly) and returns a
    serving ``SearchFn`` closure over the batched-frontier search, for
    the rolling swap (``launch/lifecycle.RollingSwapController``).
    Deterministic: the insertion order derives from ``seed``, so the
    same snapshot + params rebuild bit-identically.

    ``effort`` is an optional shared knob (any object with an int
    ``level`` attribute, 0 = full effort — ``launch.proxy.EffortKnob``)
    read per call: level L serves with ``max(k, ef >> L)`` /
    ``max(1, beam >> L)``, the graph search's cost knobs, so the router
    can degrade recall gracefully under pressure. Level 0 is
    bit-identical to ``effort=None``; each level is its own jit program
    shape (ef/beam are static), so warm the degraded levels too.

    First argument: a ``CorpusSnapshot`` (preferred — carries its own
    ``n_levels``) or raw unpacked codes plus an explicit ``n_levels``
    (legacy form); one convention across every
    ``*_search_from_snapshot`` entry point.

    ``rerank={"coarse_levels": c, "k_coarse": k'}`` switches to
    bi-granular mode: the NSW graph is built and walked over the
    level-prefix codes at ``c`` levels (hot tier, cheaper neighbor
    tables), its top-k' survivors are reranked against the full-level
    codes (cold tier — a numpy / memmapped snapshot stays host-side,
    only survivor rows are read). The closure carries
    ``fn.reranked = True``. Under pressure, ``effort`` first halves
    ``k_coarse`` (floored at k) and only residual levels halve ef/beam.

    ``block_plan`` — a single ``BlockPlan`` or a ``{kind: plan}``
    mapping (``launch/autotune``) — only the "rerank" plan applies here
    (the survivor group size of the bi-granular rerank); the graph
    walk's gather geometry is fixed by the beam/neighborhood layout, so
    scan/gather plans are inert. Plans never change scores.
    """
    from repro.index._snapshot import (
        resolve_rerank_args,
        resolve_snapshot_args,
        split_effort,
    )
    from repro.kernels.sdc import ref as _ref  # lazy: ref is build-time only
    from repro.kernels.sdc.defaults import plan_for
    from repro.kernels.sdc.rerank import fine_inv_norms, sdc_rerank_backend

    codes, n_levels = resolve_snapshot_args(codes, n_levels)
    rr = resolve_rerank_args(rerank, n_levels)
    rerank_plan = plan_for(block_plan, "rerank")
    if rr is None:
        codes = np.asarray(codes)
        inv = np.asarray(_ref.doc_inv_norms(jnp.asarray(codes), n_levels))
        graph = build_hnsw(
            codes, inv, n_levels=n_levels, M=M,
            ef_construction=ef_construction, seed=seed, packed=packed,
        )
        tables = prepare_batched(graph)
        if effort is None:
            return lambda q: search_hnsw_batched(
                tables, q, k=k, ef=ef, beam=beam, max_hops=max_hops,
                backend=backend,
            )

        def fn(q):
            level = max(0, int(effort.level))
            return search_hnsw_batched(
                tables, q, k=k, ef=max(k, ef >> level),
                beam=max(1, beam >> level), max_hops=max_hops,
                backend=backend,
            )

        fn.effort = effort
        return fn

    from repro.core.binarize_lib import coarse_codes

    c_levels, k_coarse = rr
    fine_codes = codes  # numpy (possibly memmapped) stays host-side
    codes_c = np.asarray(
        coarse_codes(jnp.asarray(np.asarray(codes)), n_levels, c_levels)
    )
    inv_c = np.asarray(_ref.doc_inv_norms(jnp.asarray(codes_c), c_levels))
    graph = build_hnsw(
        codes_c, inv_c, n_levels=c_levels, M=M,
        ef_construction=ef_construction, seed=seed,
        packed=packed and c_levels <= 4,
    )
    tables = prepare_batched(graph)
    fine_inv = fine_inv_norms(fine_codes, n_levels)
    k_coarse = min(k_coarse, codes_c.shape[0])

    def fn(q):
        kc_eff, residual = (
            split_effort(effort.level, k=k, k_coarse=k_coarse)
            if effort is not None else (k_coarse, 0)
        )
        q = jnp.asarray(q)
        qc = coarse_codes(q, n_levels, c_levels)
        _, cand = search_hnsw_batched(
            tables, qc, k=kc_eff, ef=max(kc_eff, ef >> residual),
            beam=max(1, beam >> residual), max_hops=max_hops,
            backend=backend,
        )
        return sdc_rerank_backend(
            q, fine_codes, fine_inv, cand, n_levels=n_levels, k=k,
            backend=backend, block_plan=rerank_plan,
        )

    if effort is not None:
        fn.effort = effort
    fn.reranked = True
    return fn


# ---------------------------------------------------------------------------
# Sharded build for the distributed engine (index/engine.py): one NSW graph
# per leaf over that leaf's rows; searched leaf-locally under shard_map and
# selection-merged exactly like the flat/IVF engine paths.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ShardedHNSW:
    """Per-leaf HNSW tables stacked into global arrays (axis 0 shards)."""

    codes: jax.Array  # [N, D(/2)]
    inv_norm: jax.Array  # [N]
    nbr_codes: jax.Array  # [N, M, D(/2)]
    nbr_inv: jax.Array  # [N, M]
    nbr_ids: jax.Array  # [N, M] int32, leaf-local ids
    entries: jax.Array  # [n_leaves, E] int32, leaf-local ids (-1 padded)
    n_levels: int
    packed: bool = False


def build_hnsw_sharded(
    codes: np.ndarray,
    inv_norm: np.ndarray,
    *,
    n_leaves: int,
    n_levels: int,
    M: int = 16,
    ef_construction: int = 64,
    n_entries: int = 8,
    seed: int = 0,
    packed: bool = False,
) -> ShardedHNSW:
    """Build one NSW graph per leaf shard (host-side, embarrassingly
    parallel across leaves) and stack the batched tables for shard_map.

    Neighbor ids stay leaf-local; the engine adds each leaf's shard base
    to returned ids, mirroring ``engine._leaf_scan``.
    """
    n = codes.shape[0]
    if n % n_leaves != 0:
        raise ValueError(f"corpus size {n} not divisible by {n_leaves} leaves")
    shard_n = n // n_leaves
    parts = []
    entries = np.full((n_leaves, n_entries), -1, np.int32)
    for leaf in range(n_leaves):
        lo = leaf * shard_n
        idx = build_hnsw(
            codes[lo : lo + shard_n],
            inv_norm[lo : lo + shard_n],
            n_levels=n_levels,
            M=M,
            ef_construction=ef_construction,
            seed=seed + leaf,
        )
        parts.append(prepare_batched(idx, packed=packed))
        ents = _entry_points(shard_n, idx.entry, n_entries, seed + leaf)
        entries[leaf, : min(len(ents), n_entries)] = ents[:n_entries]
    return ShardedHNSW(
        codes=jnp.concatenate([p.codes for p in parts], axis=0),
        inv_norm=jnp.concatenate([p.inv_norm for p in parts], axis=0),
        nbr_codes=jnp.concatenate([p.nbr_codes for p in parts], axis=0),
        nbr_inv=jnp.concatenate([p.nbr_inv for p in parts], axis=0),
        nbr_ids=jnp.concatenate([p.nbr_ids for p in parts], axis=0),
        entries=jnp.asarray(entries),
        n_levels=n_levels,
        packed=packed,
    )
