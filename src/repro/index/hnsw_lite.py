"""HNSW-lite: a navigable-small-world graph with SDC distances (Figure 6).

The paper plugs SDC into off-the-shelf HNSW; here we implement a compact
single-layer NSW (the HNSW fine layer) in numpy for index build, with the
query-time distance evaluated through the same affine-identity integer
math as the SDC kernel. Build is host-side (graph construction is
pointer-chasing and belongs on CPU even in production); search is a greedy
beam search and is exposed both as numpy (latency benches) and as a
batched JAX closure over a fixed-width neighbor table (dry-runnable).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import List, Tuple

import numpy as np

from repro.core.binarize_lib import sdc_affine_epilogue


@dataclasses.dataclass
class HNSWLite:
    codes: np.ndarray  # [N, D] int8
    inv_norm: np.ndarray  # [N] f32
    neighbors: np.ndarray  # [N, M] int32 (-1 padded)
    entry: int
    n_levels: int

    def nbytes(self) -> int:
        packed = (self.codes.shape[1] * self.n_levels + 7) // 8
        return self.codes.shape[0] * packed + self.neighbors.size * 4


def _sdc_scores_np(q_code: np.ndarray, codes: np.ndarray, inv_norm: np.ndarray, n_levels: int):
    D = codes.shape[-1]
    dot = codes.astype(np.int32) @ q_code.astype(np.int32)
    sq = int(q_code.astype(np.int32).sum())
    sd = codes.astype(np.int32).sum(-1)
    # shared epilogue is pure arithmetic — stays in numpy on this hot path
    return sdc_affine_epilogue(dot, sq + sd, dim=D, n_levels=n_levels,
                               inv_norm=inv_norm)


def build_hnsw(
    codes: np.ndarray,
    inv_norm: np.ndarray,
    *,
    n_levels: int,
    M: int = 16,
    ef_construction: int = 64,
    seed: int = 0,
) -> HNSWLite:
    """Incremental NSW build: each point is connected to the M best results
    of a beam search among previously inserted points."""
    rng = np.random.default_rng(seed)
    n = codes.shape[0]
    neighbors = -np.ones((n, M), np.int32)
    order = rng.permutation(n)
    inserted: List[int] = []

    def knn_beam(q_idx: int, ef: int) -> List[int]:
        if not inserted:
            return []
        sub = np.asarray(inserted)
        scores = _sdc_scores_np(codes[q_idx], codes[sub], inv_norm[sub], n_levels)
        top = np.argsort(-scores)[:ef]
        return [int(sub[t]) for t in top]

    for step, idx in enumerate(order):
        if step <= M:
            cands = list(inserted)
        else:
            cands = knn_beam(idx, ef_construction)
        best = cands[:M]
        neighbors[idx, : len(best)] = best
        # Backlinks. The first M//2 slots are immutable once set — they were
        # created while the graph was sparse and act as the long-range
        # "navigable" edges (pruning them to a pure kNN graph traps greedy
        # search inside clusters); only the tail slots are re-ranked.
        for b in best:
            row = neighbors[b]
            free = np.where(row < 0)[0]
            if free.size:
                row[free[0]] = idx
            else:
                head, tail = row[: M // 2], row[M // 2:]
                cand = np.append(tail, idx)
                sc = _sdc_scores_np(codes[b], codes[cand], inv_norm[cand], n_levels)
                keep = np.argsort(-sc)[: len(tail)]
                neighbors[b] = np.concatenate([head, cand[keep]])
        inserted.append(int(idx))

    entry = int(order[0])
    return HNSWLite(
        codes=codes, inv_norm=inv_norm, neighbors=neighbors, entry=entry,
        n_levels=n_levels,
    )


def search_hnsw(
    index: HNSWLite, q_code: np.ndarray, *, k: int, ef: int = 64,
    n_entries: int = 8, seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Greedy best-first beam search from multiple entry points.

    Returns (scores [k], ids [k])."""
    rng = np.random.default_rng(seed)
    n = index.codes.shape[0]
    entries = np.unique(np.concatenate(
        [[index.entry], rng.integers(0, n, max(n_entries - 1, 0))]
    ))
    e_scores = _sdc_scores_np(
        q_code, index.codes[entries], index.inv_norm[entries], index.n_levels
    )
    visited = set(int(e) for e in entries)
    # max-heap by score via negation
    frontier = [(-float(s), int(e)) for s, e in zip(e_scores, entries)]
    heapq.heapify(frontier)
    results = [(float(s), int(e)) for s, e in zip(e_scores, entries)]

    while frontier:
        neg, node = heapq.heappop(frontier)
        worst = min(results)[0] if len(results) >= ef else -np.inf
        if -neg < worst and len(results) >= ef:
            break
        neigh = index.neighbors[node]
        neigh = neigh[neigh >= 0]
        fresh = [int(x) for x in neigh if int(x) not in visited]
        if not fresh:
            continue
        visited.update(fresh)
        sub = np.asarray(fresh)
        scores = _sdc_scores_np(q_code, index.codes[sub], index.inv_norm[sub], index.n_levels)
        for s, i in zip(scores, sub):
            if len(results) < ef or s > min(results)[0]:
                heapq.heappush(frontier, (-float(s), int(i)))
                results.append((float(s), int(i)))
                if len(results) > ef:
                    results.remove(min(results))

    results.sort(reverse=True)
    top = results[:k]
    return (
        np.asarray([s for s, _ in top], np.float32),
        np.asarray([i for _, i in top], np.int32),
    )
