"""Shared argument resolver for the ``*_search_from_snapshot`` family.

Every index family exposes one rebuild-from-snapshot entry point with
the same convention::

    <kind>_search_from_snapshot(snapshot, *, k, packed, backend, ...)

where ``snapshot`` is anything snapshot-shaped (``launch.lifecycle
.CorpusSnapshot`` — duck-typed here as "has ``.codes`` and
``.n_levels``", so this package never imports the serving layer). The
legacy two-argument form ``(codes, n_levels, *, ...)`` keeps working
through the same resolver, so pre-existing callers and tests are
untouched.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple


def resolve_snapshot_args(codes: Any,
                          n_levels: Optional[int]) -> Tuple[Any, int]:
    """Normalize ``(snapshot, None)`` / ``(codes, n_levels)`` to
    ``(codes, n_levels)``.

    A snapshot-shaped first argument (has ``.codes`` and ``.n_levels``)
    supplies both; passing an explicit ``n_levels`` alongside one that
    disagrees is an error (silently preferring either side would build
    an index that scores garbage). Raw codes require ``n_levels``.
    """
    snap_codes = getattr(codes, "codes", None)
    snap_levels = getattr(codes, "n_levels", None)
    if snap_codes is not None and snap_levels is not None:
        if n_levels is not None and int(n_levels) != int(snap_levels):
            raise ValueError(
                f"n_levels={n_levels} disagrees with the snapshot's "
                f"n_levels={snap_levels}"
            )
        return snap_codes, int(snap_levels)
    if n_levels is None:
        raise TypeError(
            "n_levels is required when passing raw codes (or pass a "
            "CorpusSnapshot, which carries it)"
        )
    return codes, int(n_levels)
