"""Shared argument resolvers for the ``*_search_from_snapshot`` family.

Every index family exposes one rebuild-from-snapshot entry point with
the same convention::

    <kind>_search_from_snapshot(snapshot, *, k, packed, backend, ...,
                                rerank=None)

where ``snapshot`` is anything snapshot-shaped (``launch.lifecycle
.CorpusSnapshot`` — duck-typed here as "has ``.codes`` and
``.n_levels``", so this package never imports the serving layer). The
legacy two-argument form ``(codes, n_levels, *, ...)`` keeps working
through the same resolver, so pre-existing callers and tests are
untouched.

``rerank`` opts the entry point into bi-granular mode: a coarse scan
over the first ``coarse_levels`` residual levels generates ``k_coarse``
survivors, then the full-level codes rerank them to the final top-k.
``resolve_rerank_args`` is the one validator for that dict, shared by
all four families and the lifecycle builders.
"""

from __future__ import annotations

from typing import Any, Mapping, Optional, Tuple

_RERANK_KEYS = frozenset({"coarse_levels", "k_coarse"})


def resolve_snapshot_args(codes: Any,
                          n_levels: Optional[int]) -> Tuple[Any, int]:
    """Normalize ``(snapshot, None)`` / ``(codes, n_levels)`` to
    ``(codes, n_levels)``.

    A snapshot-shaped first argument (has ``.codes`` and ``.n_levels``)
    supplies both; passing an explicit ``n_levels`` alongside one that
    disagrees is an error (silently preferring either side would build
    an index that scores garbage). Raw codes require ``n_levels``; a
    snapshot whose ``.n_levels`` is ``None`` is rejected as the
    malformed snapshot it is, rather than blaming the caller for the
    missing argument.
    """
    snap_codes = getattr(codes, "codes", None)
    snap_levels = getattr(codes, "n_levels", None)
    if snap_codes is not None:
        if snap_levels is None:
            raise TypeError(
                f"snapshot {type(codes).__name__} carries .codes but its "
                ".n_levels is None — a snapshot must record the level "
                "count its codes were packed at"
            )
        if n_levels is not None and int(n_levels) != int(snap_levels):
            raise ValueError(
                f"n_levels={n_levels} disagrees with the snapshot's "
                f"n_levels={snap_levels}"
            )
        return snap_codes, int(snap_levels)
    if n_levels is None:
        raise TypeError(
            "n_levels is required when passing raw codes (or pass a "
            "CorpusSnapshot, which carries it)"
        )
    return codes, int(n_levels)


def resolve_rerank_args(
    rerank: Optional[Mapping[str, Any]],
    n_levels: int,
) -> Optional[Tuple[int, int]]:
    """Validate a ``rerank={"coarse_levels": c, "k_coarse": k'}`` dict.

    Returns ``(coarse_levels, k_coarse)``, or ``None`` when rerank is
    disabled. Constraints:

    - exactly the two keys above (typos would otherwise silently run
      single-tier);
    - ``1 <= coarse_levels < n_levels`` — equality would make the
      coarse tier the fine tier and the rerank a no-op;
    - ``k_coarse >= 1``. ``k_coarse < k`` is legal (the rerank pads the
      missing slots), as is ``k_coarse >= n_docs`` (the coarse scan
      clamps).
    """
    if rerank is None:
        return None
    keys = set(rerank)
    if keys != _RERANK_KEYS:
        raise ValueError(
            f"rerank must have exactly keys {sorted(_RERANK_KEYS)}, "
            f"got {sorted(keys)}"
        )
    coarse_levels = int(rerank["coarse_levels"])
    k_coarse = int(rerank["k_coarse"])
    if not 1 <= coarse_levels < int(n_levels):
        raise ValueError(
            f"rerank coarse_levels must be in [1, {int(n_levels) - 1}] "
            f"(strictly fewer levels than the fine tier's {n_levels}), "
            f"got {coarse_levels}"
        )
    if k_coarse < 1:
        raise ValueError(f"rerank k_coarse must be >= 1, got {k_coarse}")
    return coarse_levels, k_coarse


def split_effort(level: int, *, k: int, k_coarse: int) -> Tuple[int, int]:
    """Allocate an ``EffortKnob`` level across the bigranular axes.

    Under pressure the cheapest recall to give up is rerank depth:
    halving ``k_coarse`` only narrows the fine gather (k' rows per
    query), whereas halving nprobe/ef/beam shrinks the candidate pool
    itself. So a degradation level first halves ``k_coarse`` (floored
    at ``k`` — reranking fewer than k survivors can only lose results)
    and hands whatever is left of the level to the family's own knobs.

    Returns ``(k_coarse_effective, residual_level)`` where
    ``residual_level`` is the part of ``level`` not absorbed by
    ``k_coarse`` (to be applied to nprobe / ef / beam as before).
    Level 0 is always ``(k_coarse, 0)`` — bit-identical to no knob.
    """
    level = max(0, int(level))
    kc_halvings = max(0, (k_coarse // max(k, 1)).bit_length() - 1)
    used = min(level, kc_halvings)
    return max(k, k_coarse >> used), level - used
