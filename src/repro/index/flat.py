"""Flat (exhaustive) indexes over three embedding forms.

Mirrors the paper's Table 5 contenders:
  * FlatFloat   — full-precision cosine (the "float / flat" row).
  * FlatBitwise — recurrent binary, xor+popcount (Shan et al. [44] on CPU).
  * FlatSDC     — recurrent binary, SDC kernel (ours).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.binarize_lib import (
    pack_bitplanes,
    pack_codes_nibbles,
    unpack_codes,
)
from repro.kernels.binary_dot.ops import binary_dot_search
from repro.kernels.sdc import ref as sdc_ref
from repro.kernels.sdc.ops import resolve_backend, sdc_search_backend


@dataclasses.dataclass
class FlatFloat:
    emb: jax.Array  # [N, D] float, L2-normalised at build

    @staticmethod
    def build(emb: jax.Array) -> "FlatFloat":
        emb = emb * jax.lax.rsqrt(jnp.sum(emb * emb, -1, keepdims=True) + 1e-12)
        return FlatFloat(emb=emb)

    def search(self, q: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
        q = q * jax.lax.rsqrt(jnp.sum(q * q, -1, keepdims=True) + 1e-12)
        scores = q @ self.emb.T
        return jax.lax.top_k(scores, k)

    def nbytes(self) -> int:
        return self.emb.size * self.emb.dtype.itemsize


@dataclasses.dataclass
class FlatSDC:
    codes: jax.Array  # [N, m] int8; nibble-packed uint8 [N, m//2] if packed
    inv_norm: jax.Array  # [N] f32
    n_levels: int
    interpret: bool = True  # legacy flag: Pallas interpreter on CPU
    packed: bool = False  # int4 code streaming (2 dims/byte in HBM)
    backend: str | None = None  # overrides `interpret` when set

    @staticmethod
    def build(
        codes: jax.Array, n_levels: int, interpret: bool = True,
        packed: bool = False, backend: str | None = None,
    ) -> "FlatSDC":
        inv = sdc_ref.doc_inv_norms(codes, n_levels)
        if packed:
            if n_levels > 4:
                raise ValueError(
                    f"packed codes need n_levels <= 4, got {n_levels}"
                )
            codes = pack_codes_nibbles(codes)
        return FlatSDC(codes=codes, inv_norm=inv, n_levels=n_levels,
                       interpret=interpret, packed=packed, backend=backend)

    @property
    def code_dim(self) -> int:
        m = self.codes.shape[1]
        return m * 2 if self.packed else m

    def search(self, q_codes: jax.Array, k: int, block_n: int = 512):
        backend = self.backend or ("interpret" if self.interpret else "pallas")
        return sdc_search_backend(
            q_codes,
            self.codes,
            self.inv_norm,
            n_levels=self.n_levels,
            k=k,
            backend=resolve_backend(backend),
            block_q=8,
            block_n=block_n,
            packed=self.packed,
        )

    def nbytes(self) -> int:
        # 4-bit codes pack two dims per byte on disk; +4B quantised norm.
        packed_codes = (self.code_dim * self.n_levels + 7) // 8
        return self.codes.shape[0] * (packed_codes + 4)


def flat_search_from_snapshot(
    codes,
    n_levels: int = None,
    *,
    k: int,
    packed: bool = False,
    backend: str = "xla",
    block_n: int = 512,
):
    """Rebuild-from-snapshot entry point (live index lifecycle).

    Builds a fresh exhaustive index from a corpus snapshot's unpacked
    codes and returns a serving ``SearchFn`` closure
    (``codes -> (scores, ids)``), ready to be hot-swapped into a
    drained replica by ``launch/lifecycle.RollingSwapController``.
    Deterministic: the same snapshot + params always yields a
    bit-identical index.

    First argument: a ``CorpusSnapshot`` (preferred — carries its own
    ``n_levels``) or raw unpacked codes plus an explicit ``n_levels``
    (legacy form). Same convention across every
    ``*_search_from_snapshot`` entry point.
    """
    from repro.index._snapshot import resolve_snapshot_args

    codes, n_levels = resolve_snapshot_args(codes, n_levels)
    index = FlatSDC.build(
        jnp.asarray(codes), n_levels, packed=packed, backend=backend
    )
    return lambda q: index.search(q, k, block_n=block_n)


@dataclasses.dataclass
class FlatBitwise:
    packed: jax.Array  # [N, n_levels, m/32] uint32
    m: int
    n_levels: int
    interpret: bool = True

    @staticmethod
    def build(codes: jax.Array, n_levels: int, interpret: bool = True) -> "FlatBitwise":
        bits = unpack_codes(codes, n_levels)
        return FlatBitwise(
            packed=pack_bitplanes(bits), m=codes.shape[1], n_levels=n_levels,
            interpret=interpret,
        )

    def search(self, q_codes: jax.Array, k: int):
        q_bits = unpack_codes(q_codes, self.n_levels)
        q_packed = pack_bitplanes(q_bits)
        return binary_dot_search(
            q_packed, self.packed, m=self.m, k=k, interpret=self.interpret
        )

    def nbytes(self) -> int:
        return self.packed.size * 4
