"""Flat (exhaustive) indexes over three embedding forms.

Mirrors the paper's Table 5 contenders:
  * FlatFloat   — full-precision cosine (the "float / flat" row).
  * FlatBitwise — recurrent binary, xor+popcount (Shan et al. [44] on CPU).
  * FlatSDC     — recurrent binary, SDC kernel (ours).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.binarize_lib import (
    coarse_codes,
    pack_bitplanes,
    pack_codes_nibbles,
    unpack_codes,
)
from repro.kernels.binary_dot.ops import binary_dot_search
from repro.kernels.sdc import ref as sdc_ref
from repro.kernels.sdc.defaults import BLOCK_N, FLAT_BLOCK_Q, BlockPlan, plan_for
from repro.kernels.sdc.ops import resolve_backend, sdc_search_backend
from repro.kernels.sdc.rerank import fine_inv_norms, sdc_rerank_backend


@dataclasses.dataclass
class FlatFloat:
    emb: jax.Array  # [N, D] float, L2-normalised at build

    @staticmethod
    def build(emb: jax.Array) -> "FlatFloat":
        emb = emb * jax.lax.rsqrt(jnp.sum(emb * emb, -1, keepdims=True) + 1e-12)
        return FlatFloat(emb=emb)

    def search(self, q: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
        q = q * jax.lax.rsqrt(jnp.sum(q * q, -1, keepdims=True) + 1e-12)
        scores = q @ self.emb.T
        return jax.lax.top_k(scores, k)

    def nbytes(self) -> int:
        return self.emb.size * self.emb.dtype.itemsize


@dataclasses.dataclass
class FlatSDC:
    codes: jax.Array  # [N, m] int8; nibble-packed uint8 [N, m//2] if packed
    inv_norm: jax.Array  # [N] f32
    n_levels: int
    interpret: bool = True  # legacy flag: Pallas interpreter on CPU
    packed: bool = False  # int4 code streaming (2 dims/byte in HBM)
    backend: str | None = None  # overrides `interpret` when set

    @staticmethod
    def build(
        codes: jax.Array, n_levels: int, interpret: bool = True,
        packed: bool = False, backend: str | None = None,
    ) -> "FlatSDC":
        inv = sdc_ref.doc_inv_norms(codes, n_levels)
        if packed:
            if n_levels > 4:
                raise ValueError(
                    f"packed codes need n_levels <= 4, got {n_levels}"
                )
            codes = pack_codes_nibbles(codes)
        return FlatSDC(codes=codes, inv_norm=inv, n_levels=n_levels,
                       interpret=interpret, packed=packed, backend=backend)

    @property
    def code_dim(self) -> int:
        m = self.codes.shape[1]
        return m * 2 if self.packed else m

    def search(
        self, q_codes: jax.Array, k: int, block_n: int = BLOCK_N,
        block_q: int = FLAT_BLOCK_Q, block_plan: BlockPlan | None = None,
    ):
        backend = self.backend or ("interpret" if self.interpret else "pallas")
        return sdc_search_backend(
            q_codes,
            self.codes,
            self.inv_norm,
            n_levels=self.n_levels,
            k=k,
            backend=resolve_backend(backend),
            block_q=block_q,
            block_n=block_n,
            packed=self.packed,
            block_plan=block_plan,
        )

    def nbytes(self) -> int:
        # 4-bit codes pack two dims per byte on disk; +4B quantised norm.
        packed_codes = (self.code_dim * self.n_levels + 7) // 8
        return self.codes.shape[0] * (packed_codes + 4)


@dataclasses.dataclass
class BiGranularFlat:
    """Two-tier exhaustive index: hot coarse scan, cold fine rerank.

    The coarse tier is a plain ``FlatSDC`` over the level-prefix codes
    (first ``coarse_levels`` residual levels — a right shift, no
    retraining; nibble-packed when ``coarse_levels <= 4`` and
    ``packed``). The fine tier keeps the full-level codes exactly as
    given: a numpy array (including ``np.memmap``) stays host-side and
    only the per-query top-``k_coarse`` survivor rows are ever read
    from it, so the fine tier may exceed RAM.

    The rerank is bit-identical to a full-level flat scan restricted to
    the survivors (``kernels/sdc/rerank``), so ``k_coarse >= N``
    degenerates to exactly ``FlatSDC.search`` at full levels.
    """

    coarse: FlatSDC
    fine_codes: Any  # [N, D] int8 full-level codes; numpy stays host-side
    fine_inv_norm: Any  # [N] f32
    n_levels: int
    coarse_levels: int
    k_coarse: int
    backend: str = "xla"

    @staticmethod
    def build(
        codes: Any,
        n_levels: int,
        *,
        coarse_levels: int,
        k_coarse: int,
        packed: bool = False,
        backend: str = "xla",
    ) -> "BiGranularFlat":
        host = isinstance(codes, np.ndarray)
        c_src = jnp.asarray(np.asarray(codes)) if host else codes
        coarse = FlatSDC.build(
            coarse_codes(c_src, n_levels, coarse_levels), coarse_levels,
            packed=packed and coarse_levels <= 4, backend=backend,
        )
        fine_inv = fine_inv_norms(codes, n_levels)
        return BiGranularFlat(
            coarse=coarse, fine_codes=codes, fine_inv_norm=fine_inv,
            n_levels=n_levels, coarse_levels=coarse_levels,
            k_coarse=k_coarse, backend=backend,
        )

    def search(
        self, q_codes: jax.Array, k: int, block_n: int = BLOCK_N,
        k_coarse: int | None = None,
        scan_plan: BlockPlan | None = None,
        rerank_plan: BlockPlan | None = None,
    ) -> Tuple[jax.Array, jax.Array]:
        kc = self.k_coarse if k_coarse is None else k_coarse
        kc = min(kc, self.fine_codes.shape[0])
        q = jnp.asarray(q_codes)
        qc = coarse_codes(q, self.n_levels, self.coarse_levels)
        _, cand = self.coarse.search(qc, kc, block_n=block_n,
                                     block_plan=scan_plan)
        return sdc_rerank_backend(
            q, self.fine_codes, self.fine_inv_norm, cand,
            n_levels=self.n_levels, k=k, backend=self.backend,
            block_plan=rerank_plan,
        )

    def coarse_nbytes(self) -> int:
        return self.coarse.nbytes()

    def nbytes(self) -> int:
        fine = self.fine_codes.shape[0] * (
            (self.fine_codes.shape[1] * self.n_levels + 7) // 8 + 4
        )
        return self.coarse.nbytes() + fine


def flat_search_from_snapshot(
    codes,
    n_levels: int = None,
    *,
    k: int,
    packed: bool = False,
    backend: str = "xla",
    block_n: int = BLOCK_N,
    rerank: dict | None = None,
    effort=None,
    block_plan=None,
):
    """Rebuild-from-snapshot entry point (live index lifecycle).

    Builds a fresh exhaustive index from a corpus snapshot's unpacked
    codes and returns a serving ``SearchFn`` closure
    (``codes -> (scores, ids)``), ready to be hot-swapped into a
    drained replica by ``launch/lifecycle.RollingSwapController``.
    Deterministic: the same snapshot + params always yields a
    bit-identical index.

    First argument: a ``CorpusSnapshot`` (preferred — carries its own
    ``n_levels``) or raw unpacked codes plus an explicit ``n_levels``
    (legacy form). Same convention across every
    ``*_search_from_snapshot`` entry point.

    ``rerank={"coarse_levels": c, "k_coarse": k'}`` switches the
    closure to bi-granular mode (``BiGranularFlat``): packed hot coarse
    scan at ``c`` levels for k' survivors, full-level fine rerank of
    exactly those rows. The closure carries ``fn.reranked = True`` so
    the serving tier can stamp result provenance. A numpy / memmapped
    snapshot keeps its fine tier host-side (cold). ``effort`` (any
    object with an int ``level`` attribute, 0 = full —
    ``launch.proxy.EffortKnob``) is read per call and shrinks
    ``k_coarse`` by halving (floored at k); level 0 is bit-identical to
    ``effort=None``. A flat index has no other cost knob, so ``effort``
    without ``rerank`` is ignored.

    ``block_plan`` — a single ``BlockPlan`` or a ``{kind: plan}``
    mapping (``launch/autotune``) — sets the scan tiles and, in
    bi-granular mode, the rerank group size. Plans never change scores,
    only launch shapes.
    """
    from repro.index._snapshot import (
        resolve_rerank_args,
        resolve_snapshot_args,
        split_effort,
    )

    codes, n_levels = resolve_snapshot_args(codes, n_levels)
    rr = resolve_rerank_args(rerank, n_levels)
    scan_plan = plan_for(block_plan, "scan")
    rerank_plan = plan_for(block_plan, "rerank")
    if rr is None:
        index = FlatSDC.build(
            jnp.asarray(codes), n_levels, packed=packed, backend=backend
        )
        return lambda q: index.search(q, k, block_n=block_n,
                                      block_plan=scan_plan)

    c_levels, k_coarse = rr
    bigr = BiGranularFlat.build(
        codes, n_levels, coarse_levels=c_levels, k_coarse=k_coarse,
        packed=packed, backend=backend,
    )
    if effort is None:
        fn = lambda q: bigr.search(  # noqa: E731
            q, k, block_n=block_n, scan_plan=scan_plan,
            rerank_plan=rerank_plan,
        )
    else:
        def fn(q):
            kc_eff, _ = split_effort(effort.level, k=k, k_coarse=k_coarse)
            return bigr.search(
                q, k, block_n=block_n, k_coarse=kc_eff,
                scan_plan=scan_plan, rerank_plan=rerank_plan,
            )

        fn.effort = effort
    fn.reranked = True
    return fn


@dataclasses.dataclass
class FlatBitwise:
    packed: jax.Array  # [N, n_levels, m/32] uint32
    m: int
    n_levels: int
    interpret: bool = True

    @staticmethod
    def build(codes: jax.Array, n_levels: int, interpret: bool = True) -> "FlatBitwise":
        bits = unpack_codes(codes, n_levels)
        return FlatBitwise(
            packed=pack_bitplanes(bits), m=codes.shape[1], n_levels=n_levels,
            interpret=interpret,
        )

    def search(self, q_codes: jax.Array, k: int):
        q_bits = unpack_codes(q_codes, self.n_levels)
        q_packed = pack_bitplanes(q_bits)
        return binary_dot_search(
            q_packed, self.packed, m=self.m, k=k, interpret=self.interpret
        )

    def nbytes(self) -> int:
        return self.packed.size * 4
