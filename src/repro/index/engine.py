"""Distributed BEBR search engine (paper Figure 5: proxy -> leaf -> merge).

The corpus codes are sharded across every device of the mesh ("leaves");
queries are replicated ("proxy dispatch"); each leaf runs a local SDC scan
+ top-k; a single all_gather of the per-leaf top-k (k << shard size) plus a
local merge yields the global top-k ("selection merge").

Communication = Q * k * 8 bytes * n_leaves — independent of corpus size,
which is what lets one engine span tens of billions of documents. Built on
shard_map so the same code drives the 256-chip pod and the 512-chip
multi-pod mesh in launch/dryrun.py.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.binarize_lib import code_affine_constants


def _leaf_scan(
    q_codes: jax.Array,
    shard_codes: jax.Array,
    shard_inv: jax.Array,
    shard_base: jax.Array,
    *,
    n_levels: int,
    k: int,
) -> Tuple[jax.Array, jax.Array]:
    """Local exhaustive SDC scan on one leaf (affine-identity math,
    jnp form — XLA fuses this into one int32 matmul + epilogue; the Pallas
    kernel is used on real TPU via ops.sdc_search inside the leaf)."""
    a, beta = code_affine_constants(n_levels)
    D = q_codes.shape[-1]
    dot = q_codes.astype(jnp.int32) @ shard_codes.astype(jnp.int32).T
    sq = jnp.sum(q_codes.astype(jnp.int32), -1, keepdims=True)
    sd = jnp.sum(shard_codes.astype(jnp.int32), -1)[None, :]
    scores = (
        (a * a) * dot.astype(jnp.float32)
        + (a * beta) * (sq + sd).astype(jnp.float32)
        + D * beta * beta
    ) * shard_inv[None, :]
    scores = jnp.where(shard_inv[None, :] > 0, scores, -jnp.inf)
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx + shard_base


def make_distributed_search(
    mesh: Mesh,
    *,
    n_levels: int,
    k: int,
    shard_axes: Tuple[str, ...] = ("data", "model"),
):
    """Build a pjit-able global search fn over a mesh.

    Inputs (global shapes):
      q_codes [Q, D] int8 (replicated), d_codes [N, D] int8 (sharded on
      axis 0 across shard_axes), d_inv [N] f32 (same sharding).
    Output: (scores [Q, k], global ids [Q, k]) replicated.
    """
    axes = shard_axes

    def search(q_codes, d_codes, d_inv):
        n_shards = 1
        for ax in axes:
            n_shards *= mesh.shape[ax]
        shard_n = d_codes.shape[0]  # per-leaf rows under shard_map
        # Leaf rank: linearised index over the sharded axes.
        rank = jnp.zeros((), jnp.int32)
        for ax in axes:
            rank = rank * mesh.shape[ax] + jax.lax.axis_index(ax)
        base = rank * shard_n
        vals, ids = _leaf_scan(
            q_codes, d_codes, d_inv, shard_base=base, n_levels=n_levels, k=k
        )
        #

        # selection merge: gather every leaf's top-k, re-rank locally.
        all_vals = vals
        all_ids = ids
        for ax in axes:
            all_vals = jax.lax.all_gather(all_vals, ax, axis=1, tiled=True)
            all_ids = jax.lax.all_gather(all_ids, ax, axis=1, tiled=True)
        merged_vals, pos = jax.lax.top_k(all_vals, k)
        merged_ids = jnp.take_along_axis(all_ids, pos, axis=-1)
        return merged_vals, merged_ids

    in_specs = (
        P(),  # queries replicated
        P(axes),  # codes sharded along N over (data, model)
        P(axes),
    )
    out_specs = (P(), P())
    fn = shard_map(
        search, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False,
    )
    return jax.jit(fn)


def engine_input_shardings(mesh: Mesh, shard_axes=("data", "model")):
    """NamedShardings matching make_distributed_search's expectations."""
    return (
        NamedSharding(mesh, P()),
        NamedSharding(mesh, P(shard_axes)),
        NamedSharding(mesh, P(shard_axes)),
    )


def make_failover_search(
    mesh: Mesh,
    *,
    n_levels: int,
    k: int,
    shard_axes: Tuple[str, ...] = ("data", "model"),
):
    """Distributed search with leaf failover (straggler/failure tolerance).

    Production leaves time out (paper §3.3.3's proxy drops late leaves and
    merges what arrived). SPMD can't drop a device mid-step, so the same
    contract is expressed as a ``leaf_alive`` mask: a dead/drained leaf
    contributes -inf scores and the merge proceeds from the survivors.
    The orchestrator flips the mask between steps (no recompile — the mask
    is a runtime input), giving graceful degradation instead of a stalled
    query: recall drops by ~|dead|/|leaves| of the corpus, latency does not.
    """
    axes = shard_axes

    def search(q_codes, d_codes, d_inv, leaf_alive):
        n_shards = 1
        for ax in axes:
            n_shards *= mesh.shape[ax]
        shard_n = d_codes.shape[0]
        rank = jnp.zeros((), jnp.int32)
        for ax in axes:
            rank = rank * mesh.shape[ax] + jax.lax.axis_index(ax)
        base = rank * shard_n
        vals, ids = _leaf_scan(
            q_codes, d_codes, d_inv, shard_base=base, n_levels=n_levels, k=k
        )
        alive = leaf_alive[rank]  # [n_shards] bool, replicated input
        vals = jnp.where(alive, vals, -jnp.inf)
        all_vals, all_ids = vals, ids
        for ax in axes:
            all_vals = jax.lax.all_gather(all_vals, ax, axis=1, tiled=True)
            all_ids = jax.lax.all_gather(all_ids, ax, axis=1, tiled=True)
        merged_vals, pos = jax.lax.top_k(all_vals, k)
        merged_ids = jnp.take_along_axis(all_ids, pos, axis=-1)
        return merged_vals, merged_ids

    fn = shard_map(
        search, mesh=mesh,
        in_specs=(P(), P(axes), P(axes), P()),
        out_specs=(P(), P()), check_rep=False,
    )
    return jax.jit(fn)
