"""Distributed BEBR search engine (paper Figure 5: proxy -> leaf -> merge).

The corpus codes are sharded across every device of the mesh ("leaves");
queries are replicated ("proxy dispatch"); each leaf runs a local SDC scan
+ top-k; a single all_gather of the per-leaf top-k (k << shard size) plus a
local merge yields the global top-k ("selection merge").

Communication = Q * k * 8 bytes * n_leaves — independent of corpus size,
which is what lets one engine span tens of billions of documents. Built on
shard_map so the same code drives the 256-chip pod and the 512-chip
multi-pod mesh in launch/dryrun.py.

Leaves score through ``kernels.sdc.ops`` — the same substrate as FlatSDC
and IVF. ``backend="pallas"`` runs the fused scan+top-k Pallas kernel on
each leaf (no [Q, shard_N] score matrix in HBM); ``backend="xla"`` is the
jnp fallback for CPU meshes (identical scores, shared epilogue);
``backend="interpret"`` exercises the kernel under the Pallas interpreter
in tests. ``packed=True`` shards a nibble-packed uint8 [N, D//2] corpus,
halving per-leaf scan bandwidth.

Three first-class leaf index types share the proxy/merge skeleton:
  * flat  — exhaustive leaf scan (``make_distributed_search``);
  * flat + failover mask (``make_failover_search``);
  * hnsw  — batched-frontier graph search per leaf
    (``make_hnsw_search``), one NSW graph per shard built host-side by
    ``hnsw_lite.build_hnsw_sharded``; each leaf walks its local graph
    with the same gather-kernel scoring, so sublinear leaf scans ride
    the identical selection-merge.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.index.hnsw_lite import ShardedHNSW, hnsw_frontier_search
from repro.kernels.sdc.defaults import BLOCK_N, BLOCK_Q, plan_for
from repro.kernels.sdc.ops import resolve_backend, sdc_search, sdc_search_xla


def _leaf_scan(
    q_codes: jax.Array,
    shard_codes: jax.Array,
    shard_inv: jax.Array,
    shard_base: jax.Array,
    *,
    n_levels: int,
    k: int,
    backend: str = "xla",
    packed: bool = False,
    block_q: int = BLOCK_Q,
    block_n: int = BLOCK_N,
) -> Tuple[jax.Array, jax.Array]:
    """Local exhaustive SDC scan + top-k on one leaf.

    Dispatches to the fused Pallas kernel (no [Q, shard_N] score matrix
    materialised) or the jnp fallback; both treat shard_inv == 0 entries
    as excluded (drained docs) and surface empty slots as -inf.
    """
    if backend in ("pallas", "interpret"):
        vals, idx = sdc_search(
            q_codes,
            shard_codes,
            shard_inv,
            n_levels=n_levels,
            k=k,
            block_q=block_q,
            block_n=block_n,
            interpret=(backend == "interpret"),
            fused=True,
            packed=packed,
        )
    else:
        vals, idx = sdc_search_xla(
            q_codes, shard_codes, shard_inv, n_levels=n_levels, k=k,
            packed=packed,
        )
    # Downstream merges expect strict -inf for empty slots, and global ids;
    # the -1 empty-slot sentinel must not be shifted into a neighbour
    # shard's id range.
    vals = jnp.where(idx >= 0, vals, -jnp.inf)
    return vals, jnp.where(idx >= 0, idx + shard_base, -1)


def _make_search(
    mesh: Mesh,
    *,
    n_levels: int,
    k: int,
    shard_axes: Tuple[str, ...],
    backend: str,
    packed: bool,
    block_q: int,
    block_n: int,
    failover: bool,
):
    """Common builder for the plain and failover engines."""
    axes = shard_axes
    backend = resolve_backend(backend)

    def search(q_codes, d_codes, d_inv, *rest):
        shard_n = d_codes.shape[0]  # per-leaf rows under shard_map
        # Leaf rank: linearised index over the sharded axes.
        rank = jnp.zeros((), jnp.int32)
        for ax in axes:
            rank = rank * mesh.shape[ax] + jax.lax.axis_index(ax)
        base = rank * shard_n
        vals, ids = _leaf_scan(
            q_codes, d_codes, d_inv, shard_base=base,
            n_levels=n_levels, k=k, backend=backend, packed=packed,
            block_q=block_q, block_n=block_n,
        )
        if failover:
            (leaf_alive,) = rest
            # A dead/drained leaf contributes -inf scores; the merge
            # proceeds from the survivors (paper §3.3.3 proxy timeout).
            vals = jnp.where(leaf_alive[rank], vals, -jnp.inf)

        # selection merge: gather every leaf's top-k, re-rank locally.
        all_vals, all_ids = vals, ids
        for ax in axes:
            all_vals = jax.lax.all_gather(all_vals, ax, axis=1, tiled=True)
            all_ids = jax.lax.all_gather(all_ids, ax, axis=1, tiled=True)
        merged_vals, pos = jax.lax.top_k(all_vals, k)
        merged_ids = jnp.take_along_axis(all_ids, pos, axis=-1)
        return merged_vals, merged_ids

    in_specs = (P(), P(axes), P(axes)) + ((P(),) if failover else ())
    fn = shard_map(
        search, mesh=mesh, in_specs=in_specs, out_specs=(P(), P()),
        check_rep=False,
    )
    return jax.jit(fn)


def make_distributed_search(
    mesh: Mesh,
    *,
    n_levels: int,
    k: int,
    shard_axes: Tuple[str, ...] = ("data", "model"),
    backend: str = "auto",
    packed: bool = False,
    block_q: int = BLOCK_Q,
    block_n: int = BLOCK_N,
    block_plan=None,
):
    """Build a pjit-able global search fn over a mesh.

    Inputs (global shapes):
      q_codes [Q, D] int8 (replicated), d_codes [N, D] int8 — or
      nibble-packed uint8 [N, D//2] with ``packed=True`` — sharded on
      axis 0 across shard_axes, d_inv [N] f32 (same sharding).
    Output: (scores [Q, k], global ids [Q, k]) replicated.

    ``block_plan`` (kind "scan", from ``launch/autotune``) overrides
    ``block_q``/``block_n`` for every leaf's fused scan — tuned once
    for the per-leaf shard size, applied mesh-wide.
    """
    plan = plan_for(block_plan, "scan")
    if plan is not None:
        block_q, block_n = plan.block_q, plan.block_n
    return _make_search(
        mesh, n_levels=n_levels, k=k, shard_axes=shard_axes,
        backend=backend, packed=packed, block_q=block_q, block_n=block_n,
        failover=False,
    )


def engine_input_shardings(mesh: Mesh, shard_axes=("data", "model")):
    """NamedShardings matching make_distributed_search's expectations."""
    return (
        NamedSharding(mesh, P()),
        NamedSharding(mesh, P(shard_axes)),
        NamedSharding(mesh, P(shard_axes)),
    )


def make_failover_search(
    mesh: Mesh,
    *,
    n_levels: int,
    k: int,
    shard_axes: Tuple[str, ...] = ("data", "model"),
    backend: str = "auto",
    packed: bool = False,
    block_q: int = BLOCK_Q,
    block_n: int = BLOCK_N,
    block_plan=None,
):
    """Distributed search with leaf failover (straggler/failure tolerance).

    Production leaves time out (paper §3.3.3's proxy drops late leaves and
    merges what arrived). SPMD can't drop a device mid-step, so the same
    contract is expressed as a ``leaf_alive`` mask: a dead/drained leaf
    contributes -inf scores and the merge proceeds from the survivors.
    The orchestrator flips the mask between steps (no recompile — the mask
    is a runtime input), giving graceful degradation instead of a stalled
    query: recall drops by ~|dead|/|leaves| of the corpus, latency does not.
    """
    plan = plan_for(block_plan, "scan")
    if plan is not None:
        block_q, block_n = plan.block_q, plan.block_n
    return _make_search(
        mesh, n_levels=n_levels, k=k, shard_axes=shard_axes,
        backend=backend, packed=packed, block_q=block_q, block_n=block_n,
        failover=True,
    )


def make_hnsw_search(
    mesh: Mesh,
    *,
    n_levels: int,
    k: int,
    ef: int = 64,
    beam: int = 8,
    max_hops: int = 64,
    shard_axes: Tuple[str, ...] = ("data", "model"),
    backend: str = "auto",
    packed: bool = False,
):
    """Distributed HNSW engine: batched-frontier graph search per leaf.

    Same proxy/leaf/merge skeleton as ``make_distributed_search``, but each
    leaf walks its local NSW graph (built by ``build_hnsw_sharded``)
    instead of scanning its whole shard — the leaf cost is
    O(hops * beam * M) candidates instead of O(shard_n), scored through
    the identical gather-kernel substrate.

    Inputs (global shapes, see ``hnsw_engine_shardings``):
      q_codes [Q, D] replicated; codes [N, D(/2)], inv_norm [N],
      nbr_codes [N, M, D(/2)], nbr_inv [N, M], nbr_ids [N, M] (leaf-local
      ids) and entries [n_leaves, E] (leaf-local ids) sharded on axis 0.
    Output: (scores [Q, k], global ids [Q, k]) replicated.
    """
    axes = shard_axes
    backend = resolve_backend(backend)
    ef_eff = max(ef, k)
    beam_eff = max(1, min(beam, ef_eff))

    def search(q_codes, codes, inv, nbr_codes, nbr_inv, nbr_ids, entries):
        shard_n = codes.shape[0]
        # One graph per leaf: a build_hnsw_sharded(n_leaves=...) that
        # doesn't match the mesh would alias leaf-local neighbor ids
        # across sub-graphs and silently corrupt global ids — fail loudly
        # at trace time instead.
        if entries.shape[0] != 1:
            raise ValueError(
                f"build_hnsw_sharded n_leaves must equal the mesh's "
                f"sharded device count (each leaf got {entries.shape[0]} "
                "entry rows, expected 1)"
            )
        rank = jnp.zeros((), jnp.int32)
        for ax in axes:
            rank = rank * mesh.shape[ax] + jax.lax.axis_index(ax)
        base = rank * shard_n
        vals, ids, _ = hnsw_frontier_search(
            q_codes, codes, inv, nbr_codes, nbr_inv, nbr_ids,
            entries.reshape(-1),
            n_levels=n_levels, k=k, ef=ef_eff, beam=beam_eff,
            max_hops=max_hops, backend=backend, packed=packed,
        )
        vals = jnp.where(ids >= 0, vals, -jnp.inf)
        all_vals = vals
        all_ids = jnp.where(ids >= 0, ids + base, -1)
        for ax in axes:
            all_vals = jax.lax.all_gather(all_vals, ax, axis=1, tiled=True)
            all_ids = jax.lax.all_gather(all_ids, ax, axis=1, tiled=True)
        merged_vals, pos = jax.lax.top_k(all_vals, k)
        merged_ids = jnp.take_along_axis(all_ids, pos, axis=-1)
        return merged_vals, merged_ids

    in_specs = (P(),) + (P(axes),) * 6
    fn = shard_map(
        search, mesh=mesh, in_specs=in_specs, out_specs=(P(), P()),
        check_rep=False,
    )
    return jax.jit(fn)


def hnsw_engine_shardings(mesh: Mesh, shard_axes=("data", "model")):
    """NamedShardings for ``make_hnsw_search``'s seven inputs (queries
    replicated, every table sharded on axis 0)."""
    rep = NamedSharding(mesh, P())
    sh = NamedSharding(mesh, P(shard_axes))
    return (rep,) + (sh,) * 6


def hnsw_engine_inputs(index: ShardedHNSW):
    """The sharded input arrays of ``make_hnsw_search``, in order."""
    return (
        index.codes, index.inv_norm, index.nbr_codes, index.nbr_inv,
        index.nbr_ids, index.entries,
    )


# ---------------------------------------------------------------------------
# Rebuild-from-snapshot entry points (live index lifecycle).
#
# The engine's normal API hands back a bare shard_map program and leaves
# device placement to the caller; the rolling swap wants the whole thing —
# "here is a corpus snapshot, give me a serving SearchFn over this
# replica's submesh" — so these wrap program construction + device_put
# into one closure a drained replica can hot-swap in
# (launch/lifecycle.RollingSwapController).
# ---------------------------------------------------------------------------


def flat_engine_inputs_from_snapshot(
    codes: jax.Array,
    n_levels: int,
    *,
    packed: bool = False,
    coarse_levels: int = None,
) -> Tuple[jax.Array, jax.Array]:
    """Host-side shared flat-engine inputs from a snapshot's unpacked
    codes: (codes [nibble-packed when ``packed``], inverse doc norms).
    Replica-independent, so a rolling swap computes them once per
    snapshot and reuses them for every replica's device placement
    (``launch/lifecycle.EngineBuilder``). With ``coarse_levels`` the
    inputs are the hot coarse tier of a bi-granular engine: level-prefix
    codes and their inverse norms at ``coarse_levels`` levels."""
    from repro.core.binarize_lib import coarse_codes, pack_codes_nibbles
    from repro.kernels.sdc import ref as _ref

    codes = jnp.asarray(codes)
    if coarse_levels is not None:
        codes = coarse_codes(codes, n_levels, coarse_levels)
        n_levels = coarse_levels
    inv = _ref.doc_inv_norms(codes, n_levels)
    if packed:
        codes = pack_codes_nibbles(codes)
    return codes, inv


def engine_search_from_snapshot(
    mesh: Mesh,
    codes,
    n_levels: int = None,
    *,
    k: int,
    shard_axes: Tuple[str, ...] = ("data", "model"),
    backend: str = "auto",
    packed: bool = False,
    block_q: int = BLOCK_Q,
    block_n: int = BLOCK_N,
    prepared: Tuple[jax.Array, jax.Array] = None,
    rerank: dict | None = None,
    effort=None,
    block_plan=None,
):
    """Fresh flat engine over ``mesh`` from a snapshot's unpacked codes.

    Shards the codes (nibble-packing them first when ``packed``) and
    inverse norms over the mesh's leaves and returns
    ``q_codes -> (scores, ids)`` — queries are placed replicated inside
    the closure, so it is a drop-in serving ``SearchFn``. Pass
    ``prepared`` (from ``flat_engine_inputs_from_snapshot``) to skip the
    per-replica host recompute.

    ``codes`` may be a ``CorpusSnapshot`` (preferred — carries its own
    ``n_levels``) or raw unpacked codes plus an explicit ``n_levels``
    (legacy form); one convention across every
    ``*_search_from_snapshot`` entry point.

    ``rerank={"coarse_levels": c, "k_coarse": k'}`` switches to
    bi-granular mode: the engine leaves scan the level-prefix codes at
    ``c`` levels and the cross-leaf merge produces the global coarse
    top-k' survivors, which are then reranked *post-merge* against the
    full-level codes (one fine gather over the whole corpus's cold tier
    — a numpy / memmapped snapshot stays host-side, only survivor rows
    are read). ``prepared`` must then come from
    ``flat_engine_inputs_from_snapshot(..., coarse_levels=c)``. The
    closure carries ``fn.reranked = True``. ``effort`` (int ``level``
    attribute, 0 = full) narrows the rerank by slicing the merged
    top-k' down to its top-``k_coarse >> level`` prefix (floored at k)
    — an exact prefix of a sorted top-k, so no re-jit per level.

    ``block_plan`` — a single ``BlockPlan`` or a ``{kind: plan}``
    mapping (``launch/autotune``) — sets the per-leaf scan tiles
    (kind "scan" overrides ``block_q``/``block_n``) and, in bi-granular
    mode, the post-merge rerank group size (kind "rerank"). Plans never
    change scores, only launch shapes.
    """
    from repro.index._snapshot import (
        resolve_rerank_args,
        resolve_snapshot_args,
        split_effort,
    )

    codes, n_levels = resolve_snapshot_args(codes, n_levels)
    rr = resolve_rerank_args(rerank, n_levels)
    scan_plan = plan_for(block_plan, "scan")
    if scan_plan is not None:
        block_q, block_n = scan_plan.block_q, scan_plan.block_n
    rerank_plan = plan_for(block_plan, "rerank")
    if rr is None:
        if prepared is None:
            prepared = flat_engine_inputs_from_snapshot(codes, n_levels,
                                                        packed=packed)
        search = make_distributed_search(
            mesh, n_levels=n_levels, k=k, shard_axes=shard_axes,
            backend=backend, packed=packed, block_q=block_q, block_n=block_n,
        )
        qspec, *in_specs = engine_input_shardings(mesh, shard_axes)
        ins = [jax.device_put(a, s) for a, s in zip(prepared, in_specs)]

        def snapshot_search(q_codes):
            return search(jax.device_put(q_codes, qspec), *ins)

        return snapshot_search

    import numpy as np

    from repro.core.binarize_lib import coarse_codes
    from repro.kernels.sdc.rerank import fine_inv_norms, sdc_rerank_backend

    c_levels, k_coarse = rr
    k_coarse = min(k_coarse, codes.shape[0])
    packed_c = packed and c_levels <= 4
    if prepared is None:
        prepared = flat_engine_inputs_from_snapshot(
            codes, n_levels, packed=packed_c, coarse_levels=c_levels,
        )
    search = make_distributed_search(
        mesh, n_levels=c_levels, k=k_coarse, shard_axes=shard_axes,
        backend=backend, packed=packed_c, block_q=block_q, block_n=block_n,
    )
    qspec, *in_specs = engine_input_shardings(mesh, shard_axes)
    ins = [jax.device_put(a, s) for a, s in zip(prepared, in_specs)]
    fine_codes = codes if isinstance(codes, np.ndarray) else jnp.asarray(codes)
    fine_inv = fine_inv_norms(fine_codes, n_levels)

    def snapshot_search(q_codes):
        q = jnp.asarray(q_codes)
        qc = coarse_codes(q, n_levels, c_levels)
        _, cand = search(jax.device_put(qc, qspec), *ins)
        if effort is not None:
            kc_eff, _ = split_effort(effort.level, k=k, k_coarse=k_coarse)
            cand = cand[:, :kc_eff]
        return sdc_rerank_backend(
            q, fine_codes, fine_inv, cand, n_levels=n_levels, k=k,
            backend=backend, block_plan=rerank_plan,
        )

    if effort is not None:
        snapshot_search.effort = effort
    snapshot_search.reranked = True
    return snapshot_search


def sharded_graph_from_snapshot(
    codes,
    n_levels: int,
    *,
    n_leaves: int,
    M: int = 16,
    ef_construction: int = 64,
    seed: int = 0,
    packed: bool = False,
) -> ShardedHNSW:
    """Host-side per-leaf NSW graphs from a snapshot's unpacked codes:
    the single copy of the inv-norms + ``build_hnsw_sharded`` recipe,
    shared by ``hnsw_engine_search_from_snapshot`` and the lifecycle
    ``EngineBuilder``'s per-digest cache (any drift between two copies
    would silently break the swap's bit-identity guarantee)."""
    import numpy as np

    from repro.index.hnsw_lite import build_hnsw_sharded
    from repro.kernels.sdc import ref as _ref

    codes = np.asarray(codes)
    inv = np.asarray(_ref.doc_inv_norms(jnp.asarray(codes), n_levels))
    return build_hnsw_sharded(
        codes, inv, n_leaves=n_leaves, n_levels=n_levels, M=M,
        ef_construction=ef_construction, seed=seed, packed=packed,
    )


def hnsw_engine_search_from_snapshot(
    mesh: Mesh,
    codes,
    n_levels: int = None,
    *,
    k: int,
    M: int = 16,
    ef_construction: int = 64,
    ef: int = 64,
    beam: int = 8,
    max_hops: int = 64,
    seed: int = 0,
    shard_axes: Tuple[str, ...] = ("data", "model"),
    backend: str = "auto",
    packed: bool = False,
    sharded: ShardedHNSW = None,
    block_plan=None,
):
    """Fresh HNSW engine over ``mesh`` from a snapshot's unpacked codes.

    Rebuilds one NSW graph per leaf (``sharded_graph_from_snapshot``,
    deterministic for the same snapshot + seed) unless a prebuilt
    ``sharded`` graph is passed — replicas share the leaf layout, so a
    rolling swap builds the graph once and reuses it for every replica's
    device placement (see ``launch/lifecycle.EngineBuilder``).

    ``codes`` may be a ``CorpusSnapshot`` (preferred — carries its own
    ``n_levels``) or raw unpacked codes plus an explicit ``n_levels``
    (legacy form); one convention across every
    ``*_search_from_snapshot`` entry point.

    ``block_plan`` is accepted for signature parity with the other
    entry points but inert here: the graph walk's gather geometry is
    fixed by the beam/neighborhood layout (kind "gather"), so there is
    no tunable tile. A mapping containing only inert kinds is fine; a
    plan is never an error.
    """
    plan_for(block_plan, "gather")  # validate mapping keys early
    from repro.index._snapshot import resolve_snapshot_args

    codes, n_levels = resolve_snapshot_args(codes, n_levels)
    n_leaves = 1
    for ax in shard_axes:
        n_leaves *= mesh.shape[ax]
    if sharded is None:
        sharded = sharded_graph_from_snapshot(
            codes, n_levels, n_leaves=n_leaves, M=M,
            ef_construction=ef_construction, seed=seed, packed=packed,
        )
    if sharded.entries.shape[0] != n_leaves:
        raise ValueError(
            f"prebuilt sharded graph has {sharded.entries.shape[0]} leaves, "
            f"mesh wants {n_leaves}"
        )
    search = make_hnsw_search(
        mesh, n_levels=n_levels, k=k, ef=ef, beam=beam, max_hops=max_hops,
        shard_axes=shard_axes, backend=backend, packed=packed,
    )
    qspec, *in_specs = hnsw_engine_shardings(mesh, shard_axes)
    ins = [jax.device_put(a, s)
           for a, s in zip(hnsw_engine_inputs(sharded), in_specs)]

    def snapshot_search(q_codes):
        return search(jax.device_put(q_codes, qspec), *ins)

    return snapshot_search
