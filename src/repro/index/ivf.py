"""IVF index over recurrent-binary codes with SDC fine scoring (§3.3.3).

Build: k-means over grid values -> inverted lists, padded to a fixed list
length so search is a static-shape gather + masked SDC scan (TPU/XLA
friendly: no ragged shapes at search time).

Both layers use SDC-compatible arithmetic: the coarse layer can score
centroids either in float or through their grid-quantised codes; the fine
layer scores through the shared affine epilogue — either the
gather-then-scan Pallas kernel (``backend="pallas"/"interpret"``), which
streams each probed list through VMEM with a running top-k, or a jnp
fallback (``backend="xla"``) for CPU meshes. Lists can be stored
nibble-packed (``packed=True``, n_levels <= 4) at 2 dims/byte, halving
scan bandwidth with bit-identical scores.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.binarize_lib import (
    codes_to_values,
    pack_codes_nibbles,
    values_to_codes,
)
from repro.index.kmeans import kmeans
from repro.kernels.sdc import ref as sdc_ref
from repro.kernels.sdc.defaults import plan_for
from repro.kernels.sdc.gather import sdc_gather_topk, sdc_gather_topk_xla
from repro.kernels.sdc.ops import resolve_backend


@dataclasses.dataclass
class IVFIndex:
    centroids: jax.Array  # [nlist, D] float grid-space centroids
    centroid_codes: jax.Array  # [nlist, D] int8 grid-quantised centroids
    lists_codes: jax.Array  # [nlist, max_len, D] int8 (uint8 [.., D//2] packed)
    lists_inv_norm: jax.Array  # [nlist, max_len] f32 (0 for padding)
    lists_ids: jax.Array  # [nlist, max_len] int32 (-1 for padding)
    n_levels: int
    packed: bool = False  # nibble-packed list storage (2 dims/byte)
    # [nlist] int32 stored entries per list, captured at build time — the
    # occupancy stats the budgeted probe allocator spends against. None on
    # indexes built before this field existed (allocation then degrades to
    # uniform; it is also recoverable as (lists_ids >= 0).sum(-1)).
    list_occupancy: object = None

    @property
    def nlist(self) -> int:
        return self.centroids.shape[0]

    @property
    def code_dim(self) -> int:
        D = self.lists_codes.shape[-1]
        return D * 2 if self.packed else D

    def nbytes(self) -> int:
        packed = (self.code_dim * self.n_levels + 7) // 8
        n_eff = int(jnp.sum(self.lists_ids >= 0))
        return n_eff * (packed + 4 + 4) + self.centroids.size * 4


def build_ivf(
    key: jax.Array,
    codes: jax.Array,
    *,
    n_levels: int,
    nlist: int,
    kmeans_iters: int = 20,
    max_len: int | None = None,
    headroom: float = 1.0,
    packed: bool = False,
) -> IVFIndex:
    """Cluster grid values, bucket codes into padded inverted lists.

    Args:
      max_len: fixed inverted-list capacity. Default (None) is the largest
        cluster size, which never drops an entry.
      headroom: multiplier applied to max_len (use > 1 with an explicit
        max_len — e.g. one sized for the *average* list — so balanced
        corpora keep every entry while bounding worst-case padding).
      packed: store lists nibble-packed (requires n_levels <= 4).

    Entries beyond a full list are dropped (they simply lose recall);
    any drop is counted and reported through ``warnings.warn`` with the
    dropped fraction, since a silent drop is invisible at search time.
    """
    if packed and n_levels > 4:
        raise ValueError(
            f"packed IVF lists need codes < 16 (n_levels <= 4), got {n_levels}"
        )

    values = codes_to_values(codes, n_levels)
    cents, assign = kmeans(key, values, k=nlist, iters=kmeans_iters)
    assign = np.asarray(assign)
    n = codes.shape[0]
    counts = np.bincount(assign, minlength=nlist)
    if max_len is None:
        max_len = int(counts.max())
    max_len = max(1, int(np.ceil(max_len * headroom)))
    D = codes.shape[1]

    dropped = int(np.maximum(counts - max_len, 0).sum())
    if dropped:
        warnings.warn(
            f"build_ivf: {dropped}/{n} entries ({dropped / n:.2%}) dropped by "
            f"list overflow (max_len={max_len}, largest list={counts.max()}); "
            "raise max_len or headroom to keep them",
            stacklevel=2,
        )

    lc = np.zeros((nlist, max_len, D), np.int8)
    ln = np.zeros((nlist, max_len), np.float32)
    li = -np.ones((nlist, max_len), np.int32)
    inv = np.asarray(sdc_ref.doc_inv_norms(codes, n_levels))
    codes_np = np.asarray(codes)
    fill = np.zeros(nlist, np.int64)
    for i in range(n):
        c = assign[i]
        p = fill[c]
        if p < max_len:
            lc[c, p] = codes_np[i]
            ln[c, p] = inv[i]
            li[c, p] = i
            fill[c] += 1

    lists_codes = jnp.asarray(lc)
    if packed:
        lists_codes = pack_codes_nibbles(lists_codes)

    return IVFIndex(
        centroids=cents,
        centroid_codes=values_to_codes(jnp.clip(cents, -2.0, 2.0), n_levels),
        lists_codes=lists_codes,
        lists_inv_norm=jnp.asarray(ln),
        lists_ids=jnp.asarray(li),
        n_levels=n_levels,
        packed=packed,
        list_occupancy=np.asarray(fill, np.int32),
    )


@functools.partial(
    jax.jit,
    static_argnames=("nprobe", "k", "n_levels", "coarse_sdc", "backend", "packed"),
)
def ivf_search(
    index_centroids: jax.Array,
    index_centroid_codes: jax.Array,
    lists_codes: jax.Array,
    lists_inv_norm: jax.Array,
    lists_ids: jax.Array,
    q_codes: jax.Array,
    *,
    nprobe: int,
    k: int,
    n_levels: int,
    coarse_sdc: bool = False,
    backend: str = "xla",
    packed: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Search [Q] queries; returns (scores [Q, k], doc ids [Q, k])."""
    vq = codes_to_values(q_codes, n_levels)  # [Q, D]

    # --- coarse layer ---
    if coarse_sdc:
        cv = codes_to_values(index_centroid_codes, n_levels)
    else:
        cv = index_centroids
    coarse = vq @ cv.T  # [Q, nlist]
    _, probes = jax.lax.top_k(coarse, nprobe)  # [Q, nprobe]

    # --- fine layer ---
    if backend in ("pallas", "interpret"):
        # Gather-then-scan kernel: probed lists stream HBM -> VMEM one at a
        # time with a running top-k; nothing [Q, nprobe, L, D]-sized exists.
        return sdc_gather_topk(
            q_codes,
            lists_codes,
            lists_inv_norm,
            lists_ids,
            probes,
            n_levels=n_levels,
            k=k,
            interpret=(backend == "interpret"),
            packed=packed,
        )

    # jnp fallback: gather candidate lists, score via the shared epilogue
    # (one implementation shared with HNSW's batched-frontier hop scoring).
    return sdc_gather_topk_xla(
        q_codes,
        lists_codes,
        lists_inv_norm,
        lists_ids,
        probes,
        n_levels=n_levels,
        k=k,
        packed=packed,
    )


def probe_rank_thresholds(
    occupancy,
    *,
    probe_budget: int,
    nlist: int,
    weighted: bool = True,
):
    """Per-centroid coarse-rank thresholds spending ``probe_budget``.

    The budget is a total of per-centroid rank slots: a query probes
    list ``c`` iff ``c`` sits within that query's top-``r[c]`` coarse
    scores, so ``sum(r) == probe_budget`` and the *average* number of
    lists scanned per query is ``probe_budget / nlist`` (the coarse
    ranking is a permutation). Flat nprobe is the uniform special case
    ``r[c] == nprobe`` for all c, i.e. ``probe_budget == nprobe *
    nlist``.

    Allocation: every centroid gets the uniform floor ``probe_budget //
    nlist`` (the flat part), and the surplus ``probe_budget % nlist``
    rank slots are apportioned by largest remainder — proportional to
    list occupancy when ``weighted`` (heavy lists stay probed deeper
    into the coarse ranking, where the corpus mass actually sits), over
    equal weights otherwise (+1 to the lowest-index centroids: the
    equal-budget flat comparator). A budget that is an exact multiple
    of ``nlist`` therefore has zero surplus and reproduces flat nprobe
    exactly, occupancy-weighted or not — that is the parity case the
    tests pin. Thresholds are clipped to ``nlist`` (a rank past the end
    of the ranking buys nothing), which can strand surplus only when a
    single list's share exceeds the whole rank range.
    """
    B = int(probe_budget)
    n = int(nlist)
    if B < 1:
        raise ValueError(f"probe_budget must be >= 1, got {probe_budget}")
    base, surplus = divmod(B, n)
    r = np.full(n, min(base, n), np.int64)
    if surplus and base < n:
        if weighted and occupancy is not None:
            occ = np.asarray(occupancy, np.float64).reshape(-1)
            if occ.shape[0] != n:
                raise ValueError(
                    f"occupancy has {occ.shape[0]} entries for nlist={n}"
                )
            if occ.sum() <= 0:
                occ = np.ones(n)
        else:
            occ = np.ones(n)
        quota = surplus * occ / occ.sum()
        fl = np.floor(quota).astype(np.int64)
        r += fl
        rem = surplus - int(fl.sum())
        if rem > 0:
            # Largest fractional part first; ties break to the lower index
            # so the allocation is deterministic across replicas.
            order = np.lexsort((np.arange(n), -(quota - fl)))
            r[order[:rem]] += 1
    return np.minimum(r, n).astype(np.int32)


@functools.partial(
    jax.jit,
    static_argnames=(
        "nprobe_max", "k", "n_levels", "coarse_sdc", "backend", "packed",
    ),
)
def ivf_search_budget(
    index_centroids: jax.Array,
    index_centroid_codes: jax.Array,
    lists_codes: jax.Array,
    lists_inv_norm: jax.Array,
    lists_ids: jax.Array,
    rank_limits: jax.Array,
    q_codes: jax.Array,
    *,
    nprobe_max: int,
    k: int,
    n_levels: int,
    coarse_sdc: bool = False,
    backend: str = "xla",
    packed: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Budgeted probe search: per-centroid coarse-rank thresholds.

    ``rank_limits`` is the [nlist] int32 threshold vector from
    ``probe_rank_thresholds``; ``nprobe_max`` must equal its max (it
    sizes the static probe table). Probe column j of query q is live
    iff ``j < rank_limits[probes[q, j]]`` — dead columns ride the
    gather kernel's candidate mask, exactly like HNSW's visited-set
    exclusion, so their lists never enter the running top-k.
    """
    vq = codes_to_values(q_codes, n_levels)
    if coarse_sdc:
        cv = codes_to_values(index_centroid_codes, n_levels)
    else:
        cv = index_centroids
    coarse = vq @ cv.T
    _, probes = jax.lax.top_k(coarse, nprobe_max)  # [Q, nprobe_max]
    limits = jnp.asarray(rank_limits, jnp.int32)
    live = jnp.arange(nprobe_max, dtype=jnp.int32)[None, :] < limits[probes]
    L = lists_ids.shape[1]
    mask = jnp.broadcast_to(
        live[:, :, None], probes.shape + (L,)
    ).astype(jnp.float32)
    if backend in ("pallas", "interpret"):
        return sdc_gather_topk(
            q_codes, lists_codes, lists_inv_norm, lists_ids, probes,
            n_levels=n_levels, k=k, interpret=(backend == "interpret"),
            packed=packed, cand_mask=mask,
        )
    return sdc_gather_topk_xla(
        q_codes, lists_codes, lists_inv_norm, lists_ids, probes,
        n_levels=n_levels, k=k, packed=packed, cand_mask=mask,
    )


def search_budget(
    index: IVFIndex,
    q_codes: jax.Array,
    *,
    probe_budget: int,
    k: int,
    weighted: bool = True,
    coarse_sdc: bool = False,
    backend: str = "auto",
):
    """Search under a global probe budget instead of a flat nprobe.

    Uniform thresholds (every exact-multiple budget, or uniform
    occupancy) delegate to the flat ``search`` path with ``nprobe =
    probe_budget // nlist`` — the same jit program, so ``probe_budget
    == nprobe * nlist`` is bit-identical to flat nprobe by
    construction. Non-uniform thresholds take the masked-probe path.
    """
    r = probe_rank_thresholds(
        index.list_occupancy if weighted else None,
        probe_budget=probe_budget, nlist=index.nlist, weighted=weighted,
    )
    lo, hi = int(r.min()), int(r.max())
    if lo == hi:
        return search(
            index, q_codes, nprobe=max(1, lo), k=k, coarse_sdc=coarse_sdc,
            backend=backend,
        )
    return ivf_search_budget(
        index.centroids,
        index.centroid_codes,
        index.lists_codes,
        index.lists_inv_norm,
        index.lists_ids,
        jnp.asarray(r),
        q_codes,
        nprobe_max=hi,
        k=k,
        n_levels=index.n_levels,
        coarse_sdc=coarse_sdc,
        backend=resolve_backend(backend),
        packed=index.packed,
    )


def ivf_search_from_snapshot(
    codes,
    n_levels: int = None,
    *,
    k: int,
    nlist: int,
    nprobe: int,
    seed: int = 0,
    kmeans_iters: int = 20,
    max_len: int | None = None,
    headroom: float = 1.0,
    packed: bool = False,
    backend: str = "xla",
    coarse_sdc: bool = False,
    effort=None,
    rerank: dict | None = None,
    probe_budget: int | None = None,
    block_plan=None,
):
    """Rebuild-from-snapshot entry point (live index lifecycle).

    Re-clusters a corpus snapshot's codes into a fresh IVF index and
    returns a serving ``SearchFn`` closure for the rolling swap
    (``launch/lifecycle.RollingSwapController``). Deterministic: the
    k-means key derives from ``seed``, so the same snapshot + params
    rebuild bit-identically.

    First argument: a ``CorpusSnapshot`` (preferred — carries its own
    ``n_levels``) or raw unpacked codes plus an explicit ``n_levels``
    (legacy form); one convention across every
    ``*_search_from_snapshot`` entry point.

    ``effort`` is an optional shared knob (any object with an int
    ``level`` attribute, 0 = full effort — ``launch.proxy.EffortKnob``)
    read per call: level L serves with ``max(1, nprobe >> L)`` probes,
    so the router can trade recall for latency under pressure without
    touching the closure. Level 0 is bit-identical to ``effort=None``.
    Each distinct effective nprobe is its own jit program (nprobe is
    static): warm the degraded levels or the first degraded batch pays
    a compile.

    ``rerank={"coarse_levels": c, "k_coarse": k'}`` switches to
    bi-granular mode: the IVF is clustered and scanned over the
    level-prefix codes at ``c`` levels (hot tier), its top-k' survivors
    are reranked against the full-level codes (cold tier — a numpy /
    memmapped snapshot stays host-side and only survivor rows are
    read). The closure carries ``fn.reranked = True``. Under pressure,
    ``effort`` first halves ``k_coarse`` (floored at k — the cheap
    axis) and only residual levels halve nprobe.

    ``probe_budget`` switches probe selection from flat nprobe to the
    occupancy-weighted budget allocator (``search_budget``): the
    build-time list-occupancy stats decide how deep into each query's
    coarse ranking every centroid stays probed, spending ``probe_budget
    / nlist`` lists per query on average. ``effort`` then halves the
    *budget* per level (``max(1, probe_budget >> level)``) instead of
    per-level nprobe; ``probe_budget == nprobe * nlist`` serves
    bit-identically to the flat path it replaces. ``nprobe`` is ignored
    while a budget is set.

    ``block_plan`` (``kernels.sdc.defaults.BlockPlan``, e.g. from
    ``launch/autotune``) reaches the bi-granular rerank stage; the IVF
    scan itself runs on the gather substrate, whose geometry is fixed
    by the list layout.
    """
    from repro.index._snapshot import (
        resolve_rerank_args,
        resolve_snapshot_args,
        split_effort,
    )
    from repro.kernels.sdc.rerank import fine_inv_norms, sdc_rerank_backend

    codes, n_levels = resolve_snapshot_args(codes, n_levels)
    rr = resolve_rerank_args(rerank, n_levels)
    if rr is None:
        index = build_ivf(
            jax.random.PRNGKey(seed), jnp.asarray(codes), n_levels=n_levels,
            nlist=nlist, kmeans_iters=kmeans_iters, max_len=max_len,
            headroom=headroom, packed=packed,
        )
        if probe_budget is not None:
            if effort is None:
                return lambda q: search_budget(
                    index, q, probe_budget=probe_budget, k=k,
                    coarse_sdc=coarse_sdc, backend=backend,
                )

            def fn(q):
                level = max(0, int(effort.level))
                return search_budget(
                    index, q, probe_budget=max(1, probe_budget >> level),
                    k=k, coarse_sdc=coarse_sdc, backend=backend,
                )

            fn.effort = effort
            return fn
        if effort is None:
            return lambda q: search(
                index, q, nprobe=nprobe, k=k, coarse_sdc=coarse_sdc,
                backend=backend,
            )

        def fn(q):
            level = max(0, int(effort.level))
            return search(
                index, q, nprobe=max(1, nprobe >> level), k=k,
                coarse_sdc=coarse_sdc, backend=backend,
            )

        fn.effort = effort
        return fn

    from repro.core.binarize_lib import coarse_codes

    c_levels, k_coarse = rr
    host = isinstance(codes, np.ndarray)
    c_src = jnp.asarray(np.asarray(codes)) if host else codes
    index = build_ivf(
        jax.random.PRNGKey(seed), coarse_codes(c_src, n_levels, c_levels),
        n_levels=c_levels, nlist=nlist, kmeans_iters=kmeans_iters,
        max_len=max_len, headroom=headroom,
        packed=packed and c_levels <= 4,
    )
    fine_inv = fine_inv_norms(codes, n_levels)
    k_coarse = min(k_coarse, c_src.shape[0])

    def fn(q):
        kc_eff, residual = (
            split_effort(effort.level, k=k, k_coarse=k_coarse)
            if effort is not None else (k_coarse, 0)
        )
        q = jnp.asarray(q)
        qc = coarse_codes(q, n_levels, c_levels)
        if probe_budget is not None:
            _, cand = search_budget(
                index, qc, probe_budget=max(1, probe_budget >> residual),
                k=kc_eff, coarse_sdc=coarse_sdc, backend=backend,
            )
        else:
            _, cand = search(
                index, qc, nprobe=max(1, nprobe >> residual), k=kc_eff,
                coarse_sdc=coarse_sdc, backend=backend,
            )
        return sdc_rerank_backend(
            q, codes, fine_inv, cand, n_levels=n_levels, k=k,
            backend=backend, block_plan=plan_for(block_plan, "rerank"),
        )

    if effort is not None:
        fn.effort = effort
    fn.reranked = True
    return fn


def search(
    index: IVFIndex,
    q_codes: jax.Array,
    *,
    nprobe: int,
    k: int,
    coarse_sdc=False,
    backend: str = "auto",
):
    return ivf_search(
        index.centroids,
        index.centroid_codes,
        index.lists_codes,
        index.lists_inv_norm,
        index.lists_ids,
        q_codes,
        nprobe=nprobe,
        k=k,
        n_levels=index.n_levels,
        coarse_sdc=coarse_sdc,
        backend=resolve_backend(backend),
        packed=index.packed,
    )
