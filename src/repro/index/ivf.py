"""IVF index over recurrent-binary codes with SDC fine scoring (§3.3.3).

Build: k-means over grid values -> inverted lists, padded to a fixed list
length so search is a static-shape gather + masked SDC scan (TPU/XLA
friendly: no ragged shapes at search time).

Both layers use SDC-compatible arithmetic: the coarse layer can score
centroids either in float or through their grid-quantised codes; the fine
layer scores codes with the affine-identity integer math (identical to the
Pallas kernel, evaluated over the gathered lists).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.binarize_lib import (
    code_affine_constants,
    codes_to_values,
    values_to_codes,
)
from repro.index.kmeans import kmeans
from repro.kernels.sdc import ref as sdc_ref


@dataclasses.dataclass
class IVFIndex:
    centroids: jax.Array  # [nlist, D] float grid-space centroids
    centroid_codes: jax.Array  # [nlist, D] int8 grid-quantised centroids
    lists_codes: jax.Array  # [nlist, max_len, D] int8
    lists_inv_norm: jax.Array  # [nlist, max_len] f32 (0 for padding)
    lists_ids: jax.Array  # [nlist, max_len] int32 (-1 for padding)
    n_levels: int

    @property
    def nlist(self) -> int:
        return self.centroids.shape[0]

    def nbytes(self) -> int:
        packed = (self.lists_codes.shape[-1] * self.n_levels + 7) // 8
        n_eff = int(jnp.sum(self.lists_ids >= 0))
        return n_eff * (packed + 4 + 4) + self.centroids.size * 4


def build_ivf(
    key: jax.Array,
    codes: jax.Array,
    *,
    n_levels: int,
    nlist: int,
    kmeans_iters: int = 20,
    max_len: int | None = None,
) -> IVFIndex:
    """Cluster grid values, bucket codes into padded inverted lists."""
    import numpy as np

    values = codes_to_values(codes, n_levels)
    cents, assign = kmeans(key, values, k=nlist, iters=kmeans_iters)
    assign = np.asarray(assign)
    n = codes.shape[0]
    counts = np.bincount(assign, minlength=nlist)
    if max_len is None:
        max_len = int(counts.max())
    D = codes.shape[1]

    lc = np.zeros((nlist, max_len, D), np.int8)
    ln = np.zeros((nlist, max_len), np.float32)
    li = -np.ones((nlist, max_len), np.int32)
    inv = np.asarray(sdc_ref.doc_inv_norms(codes, n_levels))
    codes_np = np.asarray(codes)
    fill = np.zeros(nlist, np.int64)
    for i in range(n):
        c = assign[i]
        p = fill[c]
        if p < max_len:  # overflow entries dropped (cap rare with balanced k-means)
            lc[c, p] = codes_np[i]
            ln[c, p] = inv[i]
            li[c, p] = i
            fill[c] += 1

    return IVFIndex(
        centroids=cents,
        centroid_codes=values_to_codes(jnp.clip(cents, -2.0, 2.0), n_levels),
        lists_codes=jnp.asarray(lc),
        lists_inv_norm=jnp.asarray(ln),
        lists_ids=jnp.asarray(li),
        n_levels=n_levels,
    )


@functools.partial(jax.jit, static_argnames=("nprobe", "k", "n_levels", "coarse_sdc"))
def ivf_search(
    index_centroids: jax.Array,
    index_centroid_codes: jax.Array,
    lists_codes: jax.Array,
    lists_inv_norm: jax.Array,
    lists_ids: jax.Array,
    q_codes: jax.Array,
    *,
    nprobe: int,
    k: int,
    n_levels: int,
    coarse_sdc: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Search [Q] queries; returns (scores [Q, k], doc ids [Q, k])."""
    a, beta = code_affine_constants(n_levels)
    D = q_codes.shape[-1]
    vq = codes_to_values(q_codes, n_levels)  # [Q, D]

    # --- coarse layer ---
    if coarse_sdc:
        cv = codes_to_values(index_centroid_codes, n_levels)
    else:
        cv = index_centroids
    coarse = vq @ cv.T  # [Q, nlist]
    _, probes = jax.lax.top_k(coarse, nprobe)  # [Q, nprobe]

    # --- fine layer: gather candidate lists, SDC affine scoring ---
    cand_codes = lists_codes[probes]  # [Q, nprobe, L, D]
    cand_inv = lists_inv_norm[probes]  # [Q, nprobe, L]
    cand_ids = lists_ids[probes]  # [Q, nprobe, L]

    cq = q_codes.astype(jnp.int32)
    cd = cand_codes.astype(jnp.int32)
    dot = jnp.einsum("qd,qpld->qpl", cq, cd)
    sq = jnp.sum(cq, -1)[:, None, None]
    sd = jnp.sum(cd, -1)
    scores = (
        (a * a) * dot.astype(jnp.float32)
        + (a * beta) * (sq + sd).astype(jnp.float32)
        + D * beta * beta
    ) * cand_inv
    scores = jnp.where(cand_ids >= 0, scores, -jnp.inf)

    Q = q_codes.shape[0]
    flat_scores = scores.reshape(Q, -1)
    flat_ids = cand_ids.reshape(Q, -1)
    vals, pos = jax.lax.top_k(flat_scores, k)
    return vals, jnp.take_along_axis(flat_ids, pos, axis=-1)


def search(index: IVFIndex, q_codes: jax.Array, *, nprobe: int, k: int, coarse_sdc=False):
    return ivf_search(
        index.centroids,
        index.centroid_codes,
        index.lists_codes,
        index.lists_inv_norm,
        index.lists_ids,
        q_codes,
        nprobe=nprobe,
        k=k,
        n_levels=index.n_levels,
        coarse_sdc=coarse_sdc,
    )
