"""jit-compiled k-means (Lloyd) with k-means++ seeding.

Used as the IVF coarse quantiser (paper §3.3.3: "the coarse layer quantizes
embedding vectors into the coarse cluster typically through the K-means
algorithm"). Operates on float vectors or on recurrent-binary grid values.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def _pairwise_sqdist(x: jax.Array, c: jax.Array) -> jax.Array:
    """[N, D] x [K, D] -> [N, K] squared euclidean distances."""
    x2 = jnp.sum(x * x, -1, keepdims=True)
    c2 = jnp.sum(c * c, -1)
    return x2 + c2[None, :] - 2.0 * (x @ c.T)


def kmeans_pp_init(key: jax.Array, x: jax.Array, k: int) -> jax.Array:
    """k-means++ seeding (sequential, scan over k picks)."""
    n = x.shape[0]
    k0, key = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, n)
    centroids0 = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(x[first])
    d0 = jnp.sum((x - x[first]) ** 2, -1)

    def pick(carry, i):
        cents, mind, key = carry
        key, kk = jax.random.split(key)
        probs = mind / (jnp.sum(mind) + 1e-12)
        idx = jax.random.choice(kk, n, p=probs)
        cents = cents.at[i].set(x[idx])
        mind = jnp.minimum(mind, jnp.sum((x - x[idx]) ** 2, -1))
        return (cents, mind, key), None

    (cents, _, _), _ = jax.lax.scan(
        pick, (centroids0, d0, key), jnp.arange(1, k)
    )
    return cents


@functools.partial(jax.jit, static_argnames=("k", "iters", "pp_init"))
def kmeans(
    key: jax.Array, x: jax.Array, *, k: int, iters: int = 25, pp_init: bool = True
) -> Tuple[jax.Array, jax.Array]:
    """Returns (centroids [K, D], assignments [N])."""
    if pp_init:
        cents = kmeans_pp_init(key, x, k)
    else:
        idx = jax.random.choice(key, x.shape[0], (k,), replace=False)
        cents = x[idx]

    def step(cents, _):
        assign = jnp.argmin(_pairwise_sqdist(x, cents), axis=-1)  # [N]
        sums = jax.ops.segment_sum(x, assign, num_segments=k)
        counts = jax.ops.segment_sum(
            jnp.ones((x.shape[0],), x.dtype), assign, num_segments=k
        )
        new = sums / jnp.maximum(counts, 1.0)[:, None]
        # Keep empty clusters where they were (avoids NaN drift).
        new = jnp.where(counts[:, None] > 0, new, cents)
        return new, None

    cents, _ = jax.lax.scan(step, cents, None, length=iters)
    assign = jnp.argmin(_pairwise_sqdist(x, cents), axis=-1)
    return cents, assign
