"""Public wrapper: pads batch and dispatches to the fused kernel, with a
pure-XLA fallback for shapes where the kernel is not profitable."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.dot_interact.kernel import dot_interact
from repro.kernels.dot_interact.ref import dot_interact_ref


@functools.partial(jax.jit, static_argnames=("block_b", "interpret", "use_kernel"))
def dot_interaction(
    emb: jax.Array,
    *,
    block_b: int = 128,
    interpret: bool = False,
    use_kernel: bool = True,
) -> jax.Array:
    """Fused DLRM feature interaction with batch padding."""
    if not use_kernel:
        return dot_interact_ref(emb)
    B = emb.shape[0]
    pad = (-B) % block_b
    if pad:
        emb = jnp.pad(emb, ((0, pad), (0, 0), (0, 0)))
    out = dot_interact(emb, block_b=block_b, interpret=interpret)
    return out[:B]
