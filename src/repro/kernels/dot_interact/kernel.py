"""Pallas kernel: fused DLRM dot-interaction.

Fuses the per-example Gram matmul (MXU) with the strictly-lower-triangle
gather (VPU select) so the [B, F, F] Gram tensor never round-trips to HBM.
For DLRM F = 27, D = 64: unfused writes B*27*27*4 B of Gram per step —
at B = 65536 that is 190 MB of avoidable HBM traffic per interaction.

Block over batch; F and D are small and stay resident. The triangle gather
is expressed as a static boolean mask + reshape-compaction, which lowers to
VPU selects rather than dynamic gathers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _dot_interact_kernel(rows_ref, cols_ref, emb_ref, out_ref):
    e = emb_ref[...]  # [TB, F, D]
    gram = jax.lax.dot_general(
        e,
        e,
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )  # [TB, F, F]
    # Triangle gather via flat index into the collapsed [F*F] gram rows.
    F = e.shape[1]
    flat = gram.reshape(e.shape[0], F * F)
    idx = rows_ref[...] * F + cols_ref[...]  # [n_pairs]
    out_ref[...] = jnp.take(flat, idx, axis=1).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def dot_interact(
    emb: jax.Array, *, block_b: int = 128, interpret: bool = False
) -> jax.Array:
    """[B, F, D] -> [B, F*(F-1)//2], B must be a multiple of block_b."""
    B, F, D = emb.shape
    assert B % block_b == 0, (B, block_b)
    n_pairs = F * (F - 1) // 2
    r, c = np.tril_indices(F, k=-1)
    rows = jnp.asarray(r, jnp.int32)
    cols = jnp.asarray(c, jnp.int32)
    grid = (B // block_b,)
    return pl.pallas_call(
        _dot_interact_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_pairs,), lambda i: (0,)),
            pl.BlockSpec((n_pairs,), lambda i: (0,)),
            pl.BlockSpec((block_b, F, D), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, n_pairs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, n_pairs), emb.dtype),
        interpret=interpret,
    )(rows, cols, emb)
