"""Oracle for DLRM dot-interaction (arXiv:1906.00091 §2).

Given per-example feature embeddings E in [B, F, D] (dense-bottom output +
sparse embedding-bag outputs stacked), the interaction op is the strictly
lower triangle of the Gram matrix E @ E^T, flattened per example and
concatenated with the dense feature.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tril_indices(num_feat: int):
    """Strictly-lower-triangle (i > j) index pair arrays, static."""
    rows, cols = np.tril_indices(num_feat, k=-1)
    return jnp.asarray(rows), jnp.asarray(cols)


def dot_interact_ref(emb: jax.Array) -> jax.Array:
    """[B, F, D] -> [B, F*(F-1)//2] pairwise dots (i > j)."""
    gram = jnp.einsum("bfd,bgd->bfg", emb, emb)
    rows, cols = tril_indices(emb.shape[1])
    return gram[:, rows, cols]
