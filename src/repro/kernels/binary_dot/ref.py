"""Oracle for the bitwise recurrent-binary dot product (paper Eq. 11-12).

Ground truth: unpack the +-1 bit planes and evaluate

  <b_u^q, b_u^d> = sum_{s,t} 2^{-(s+t)} (bits_s^q . bits_t^d)

which equals the dot of the grid values (checked against sdc ref).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.binarize_lib import unpack_bitplanes


def binary_dot_ref(q_packed: jax.Array, d_packed: jax.Array, m: int) -> jax.Array:
    """Scores [Q, N] from packed uint32 bit planes.

    Args:
      q_packed: [Q, n_levels, W] uint32, W = m // 32.
      d_packed: [N, n_levels, W] uint32.
      m: code dimension.
    """
    qb = unpack_bitplanes(q_packed, m)  # [Q, n, m] in {-1, +1}
    db = unpack_bitplanes(d_packed, m)
    n = qb.shape[1]
    w_q = 2.0 ** -jnp.arange(n)  # level weights
    vq = jnp.einsum("qnm,n->qm", qb, w_q)
    vd = jnp.einsum("dnm,n->dm", db, w_q)
    return vq @ vd.T
