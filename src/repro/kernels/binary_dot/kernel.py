"""Pallas kernel for the paper's xor+popcount baseline (Eq. 11-12).

For +-1 vectors packed as uint32 words:  x . y = m - 2 * popc(x XOR y).
The recurrent dot is the level-weighted sum over all (s, t) plane pairs:

  <b_u^q, b_u^d> = sum_{s,t} 2^{-(s+t)} (m - 2 popc(x_s ^ y_t))

This is the [44]-style GPU/CPU scheme the paper replaces with SDC; we keep
it as the measurable baseline. Its cost grows as n_levels^2 popcount passes
(the paper's Table 5 shows exactly this blow-up), whereas SDC is one int8
matmul — the Table 5 comparison reproduces on roofline terms.

VPU kernel (no MXU use): xor + population_count are elementwise; the
reduction over words stays in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _binary_dot_kernel(q_ref, d_ref, out_ref, *, m: int, n_levels: int):
    """q_ref [TQ, n, W] uint32; d_ref [TN, n, W] uint32; out [TQ, TN] f32."""
    q = q_ref[...]
    d = d_ref[...]
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for s in range(n_levels):
        for t in range(n_levels):
            x = q[:, s, :]  # [TQ, W]
            y = d[:, t, :]  # [TN, W]
            xors = jnp.bitwise_xor(x[:, None, :], y[None, :, :])  # [TQ, TN, W]
            pop = jax.lax.population_count(xors).astype(jnp.int32)
            ham = jnp.sum(pop, axis=-1)  # [TQ, TN]
            dot = (m - 2 * ham).astype(jnp.float32)
            acc = acc + (2.0 ** -(s + t)) * dot
    out_ref[...] = acc


@functools.partial(
    jax.jit, static_argnames=("m", "block_q", "block_n", "interpret")
)
def binary_dot(
    q_packed: jax.Array,
    d_packed: jax.Array,
    *,
    m: int,
    block_q: int = 8,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Scores [Q, N] from packed bit planes (uint32)."""
    Q, n_levels, W = q_packed.shape
    N, n2, W2 = d_packed.shape
    assert (n_levels, W) == (n2, W2)
    assert Q % block_q == 0 and N % block_n == 0
    grid = (Q // block_q, N // block_n)
    return pl.pallas_call(
        functools.partial(_binary_dot_kernel, m=m, n_levels=n_levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, n_levels, W), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((block_n, n_levels, W), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Q, N), jnp.float32),
        interpret=interpret,
    )(q_packed, d_packed)
