"""Public wrapper for the bitwise baseline: padding + top-k search."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.binary_dot.kernel import binary_dot

NEG_INF = -1e30


@functools.partial(
    jax.jit, static_argnames=("m", "k", "block_q", "block_n", "interpret")
)
def binary_dot_search(
    q_packed: jax.Array,
    d_packed: jax.Array,
    *,
    m: int,
    k: int,
    block_q: int = 8,
    block_n: int = 128,
    interpret: bool = False,
):
    """Top-k exhaustive search with the xor+popcount distance."""
    Q0, N0 = q_packed.shape[0], d_packed.shape[0]
    pq = (-Q0) % block_q
    pn = (-N0) % block_n
    if pq:
        q_packed = jnp.pad(q_packed, ((0, pq), (0, 0), (0, 0)))
    if pn:
        d_packed = jnp.pad(d_packed, ((0, pn), (0, 0), (0, 0)))
    scores = binary_dot(
        q_packed, d_packed, m=m, block_q=block_q, block_n=block_n,
        interpret=interpret,
    )
    valid = jnp.arange(scores.shape[1]) < N0
    scores = jnp.where(valid[None, :], scores, NEG_INF)
    vals, idx = jax.lax.top_k(scores, k)
    return vals[:Q0], idx[:Q0]
