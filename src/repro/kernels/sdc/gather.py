"""Gather-then-scan Pallas kernel for the IVF fine layer (§3.3.3).

The jnp IVF fine path gathers every probed inverted list into one
[Q, nprobe, L, D] HBM tensor before scoring — for Q=256, nprobe=32,
L=4096, D=128 that is 4 GiB of traffic for 32 MiB of useful codes. This
kernel instead streams the probed lists through VMEM one (query, probe)
step at a time with a running top-k accumulator, so nothing bigger than
one inverted list ever leaves HBM.

Mechanics: the probe table [Q, nprobe] is a scalar-prefetch argument
(``pltpu.PrefetchScalarGridSpec``) so the BlockSpec index maps can DMA
list ``probes[q, p]`` directly from the [nlist, L, ...] list arrays —
a data-dependent gather performed by the DMA engine, not by a giant
XLA gather. The output blocks for all ``p`` map to the same (q, 0) slot,
giving the same VMEM-resident running-top-k pattern as ``sdc_topk``.

Supports the nibble-packed int4 list layout (``packed=True``) with the
same bit-identical guarantee as the flat kernels: scores come from the
shared ``sdc_affine_epilogue`` over exact integer partial sums.

Beyond IVF, the same kernel scores HNSW neighbor blocks (index/hnsw_lite):
there "lists" are per-node fixed-width neighbor tables [N, M, ...] and
"probes" are the search beam. Graph search needs one extra ingredient the
IVF path does not: a per-(query, probe, slot) candidate mask
(``cand_mask``) so already-visited nodes can be excluded from the running
top-k without touching the streamed tables. The mask is a small [Q,
nprobe, L] input streamed alongside each block; masked slots score
SDC_NEG_INF exactly like list padding.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.binarize_lib import (
    SDC_NEG_INF,
    sdc_affine_epilogue,
    unpack_nibble_planes,
)
from repro.kernels.sdc.sdc import (
    _check_code_dim,
    _merge_running_topk,
    _split_queries,
    _tile_scores,
    _tile_scores_packed,
)


def _pad_cols(x: jax.Array, k: int, fill):
    """Right-pad [1, L] to [1, max(L, k)] so lax.top_k(_, k) is legal."""
    L = x.shape[1]
    if k <= L:
        return x
    return jnp.concatenate(
        [x, jnp.full((1, k - L), fill, dtype=x.dtype)], axis=1
    )


def _gather_topk_step(
    scores, ids, vals_ref, out_ids_ref, *, p, k: int, mask=None
):
    """Common tail of a (query, probe) step: mask pads, fold into top-k."""
    # List padding carries ids == -1 (and inv == 0, already NEG_INF).
    scores = jnp.where(ids[None, :] >= 0, scores, SDC_NEG_INF)
    if mask is not None:
        # Caller-supplied per-slot exclusion (e.g. HNSW visited bitmap).
        scores = jnp.where(mask[None, :] > 0, scores, SDC_NEG_INF)
    scores = _pad_cols(scores, k, SDC_NEG_INF)
    tile_vals, tile_arg = jax.lax.top_k(scores, k)  # [1, k]
    padded_ids = _pad_cols(ids[None, :], k, -1)
    tile_ids = jnp.take_along_axis(padded_ids, tile_arg, axis=1)
    _merge_running_topk(vals_ref, out_ids_ref, tile_vals, tile_ids, j=p, k=k)


@functools.partial(
    jax.jit, static_argnames=("n_levels", "k", "interpret", "packed")
)
def sdc_gather_topk(
    q_codes: jax.Array,
    lists_codes: jax.Array,
    lists_inv_norm: jax.Array,
    lists_ids: jax.Array,
    probes: jax.Array,
    *,
    n_levels: int,
    k: int,
    interpret: bool = False,
    packed: bool = False,
    cand_mask: jax.Array | None = None,
):
    """Block-gather search: stream probed blocks, running top-k per query.

    Args:
      q_codes: [Q, D] int8 query codes (unpacked, even with packed lists).
      lists_codes: [nlist, L, D] int8, or [nlist, L, D//2] uint8 if packed.
      lists_inv_norm: [nlist, L] f32 reciprocal doc norms (0 for padding).
      lists_ids: [nlist, L] int32 global doc ids (-1 for padding).
      probes: [Q, nprobe] int32 list ids to scan per query (clamped into
        range, so callers with invalid slots must also zero ``cand_mask``).
      cand_mask: optional [Q, nprobe, L] per-slot inclusion mask (> 0 keeps
        the slot). Used by HNSW's batched-frontier search to drop visited
        nodes without touching the streamed tables; IVF leaves it None.

    Returns:
      (scores [Q, k], doc ids [Q, k]); empty slots are (SDC_NEG_INF, -1).
    """
    Q, D = q_codes.shape
    nlist, L = lists_ids.shape
    nprobe = probes.shape[1]
    Dc = lists_codes.shape[-1]
    _check_code_dim(lists_codes, D, packed)
    probes = jnp.clip(probes.astype(jnp.int32), 0, nlist - 1)
    has_mask = cand_mask is not None

    if packed:
        qe, qo = _split_queries(q_codes)
        q_args = (qe, qo)
        q_specs = [
            pl.BlockSpec((1, D // 2), lambda q, p, pr: (q, 0)),
            pl.BlockSpec((1, D // 2), lambda q, p, pr: (q, 0)),
        ]
    else:
        q_args = (q_codes,)
        q_specs = [pl.BlockSpec((1, D), lambda q, p, pr: (q, 0))]

    mask_args = ()
    mask_specs = []
    if has_mask:
        mask_args = (cand_mask.astype(jnp.float32),)
        mask_specs = [pl.BlockSpec((1, 1, L), lambda q, p, pr: (q, p, 0))]

    def kernel(probes_ref, *refs):
        del probes_ref  # consumed by the BlockSpec index maps
        p = pl.program_id(1)
        if packed:
            qe_ref, qo_ref, codes_ref, inv_ref, ids_ref, *rest = refs
            scores = _tile_scores_packed(
                qe_ref[...], qo_ref[...], codes_ref[0], inv_ref[0],
                n_levels=n_levels, dim=D,
            )  # [1, L]
        else:
            q_ref, codes_ref, inv_ref, ids_ref, *rest = refs
            scores = _tile_scores(
                q_ref[...], codes_ref[0], inv_ref[0], n_levels=n_levels, dim=D
            )
        if has_mask:
            mask_ref, vals_ref, ids_out = rest
            mask = mask_ref[0, 0]
        else:
            vals_ref, ids_out = rest
            mask = None
        _gather_topk_step(
            scores, ids_ref[0], vals_ref, ids_out, p=p, k=k, mask=mask
        )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Q, nprobe),
        in_specs=[
            *q_specs,
            pl.BlockSpec((1, L, Dc), lambda q, p, pr: (pr[q, p], 0, 0)),
            pl.BlockSpec((1, L), lambda q, p, pr: (pr[q, p], 0)),
            pl.BlockSpec((1, L), lambda q, p, pr: (pr[q, p], 0)),
            *mask_specs,
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda q, p, pr: (q, 0)),
            pl.BlockSpec((1, k), lambda q, p, pr: (q, 0)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        interpret=interpret,
    )(
        probes, *q_args, lists_codes, lists_inv_norm, lists_ids, *mask_args
    )


@functools.partial(jax.jit, static_argnames=("n_levels", "k", "packed"))
def sdc_gather_topk_xla(
    q_codes: jax.Array,
    lists_codes: jax.Array,
    lists_inv_norm: jax.Array,
    lists_ids: jax.Array,
    probes: jax.Array,
    *,
    n_levels: int,
    k: int,
    packed: bool = False,
    cand_mask: jax.Array | None = None,
):
    """jnp twin of ``sdc_gather_topk`` (the "xla" backend).

    Gathers every probed block into one [Q, nprobe, L, D] tensor and scores
    it through the shared epilogue — fine on CPU meshes, where the kernel's
    HBM-streaming argument does not apply. Same contract, same scores
    (bit-identical: identical integer partial sums and float op order).
    Shared by the IVF fine layer and HNSW's batched-frontier hop scoring.
    """
    D = q_codes.shape[-1]
    nlist = lists_ids.shape[0]
    probes = jnp.clip(probes.astype(jnp.int32), 0, nlist - 1)
    cand_codes = lists_codes[probes]  # [Q, nprobe, L, D(/2)]
    cand_inv = lists_inv_norm[probes]  # [Q, nprobe, L]
    cand_ids = lists_ids[probes]  # [Q, nprobe, L]

    cq = q_codes.astype(jnp.int32)
    if packed:
        lo, hi = unpack_nibble_planes(cand_codes)
        lo, hi = lo.astype(jnp.int32), hi.astype(jnp.int32)
        dot = jnp.einsum("qd,qpld->qpl", cq[:, 0::2], lo) + jnp.einsum(
            "qd,qpld->qpl", cq[:, 1::2], hi
        )
        sd = jnp.sum(lo, -1) + jnp.sum(hi, -1)
    else:
        cd = cand_codes.astype(jnp.int32)
        dot = jnp.einsum("qd,qpld->qpl", cq, cd)
        sd = jnp.sum(cd, -1)
    sq = jnp.sum(cq, -1)[:, None, None]
    scores = sdc_affine_epilogue(
        dot, sq + sd, dim=D, n_levels=n_levels, inv_norm=cand_inv
    )
    scores = jnp.where(cand_ids >= 0, scores, SDC_NEG_INF)
    if cand_mask is not None:
        scores = jnp.where(cand_mask > 0, scores, SDC_NEG_INF)

    Q = q_codes.shape[0]
    flat_scores = scores.reshape(Q, -1)
    flat_ids = cand_ids.reshape(Q, -1)
    if k > flat_scores.shape[1]:
        pad = jnp.full(
            (Q, k - flat_scores.shape[1]), SDC_NEG_INF, flat_scores.dtype
        )
        flat_scores = jnp.concatenate([flat_scores, pad], axis=1)
        flat_ids = jnp.concatenate(
            [flat_ids, jnp.full((Q, k - flat_ids.shape[1]), -1, jnp.int32)],
            axis=1,
        )
    vals, pos = jax.lax.top_k(flat_scores, k)
    ids = jnp.take_along_axis(flat_ids, pos, axis=-1)
    return vals, jnp.where(vals > SDC_NEG_INF / 2, ids, -1)
