"""Gather-then-scan Pallas kernel for the IVF fine layer (§3.3.3).

The jnp IVF fine path gathers every probed inverted list into one
[Q, nprobe, L, D] HBM tensor before scoring — for Q=256, nprobe=32,
L=4096, D=128 that is 4 GiB of traffic for 32 MiB of useful codes. This
kernel instead streams the probed lists through VMEM one (query, probe)
step at a time with a running top-k accumulator, so nothing bigger than
one inverted list ever leaves HBM.

Mechanics: the probe table [Q, nprobe] is a scalar-prefetch argument
(``pltpu.PrefetchScalarGridSpec``) so the BlockSpec index maps can DMA
list ``probes[q, p]`` directly from the [nlist, L, ...] list arrays —
a data-dependent gather performed by the DMA engine, not by a giant
XLA gather. The output blocks for all ``p`` map to the same (q, 0) slot,
giving the same VMEM-resident running-top-k pattern as ``sdc_topk``.

Supports the nibble-packed int4 list layout (``packed=True``) with the
same bit-identical guarantee as the flat kernels: scores come from the
shared ``sdc_affine_epilogue`` over exact integer partial sums.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.binarize_lib import SDC_NEG_INF
from repro.kernels.sdc.sdc import (
    _merge_running_topk,
    _split_queries,
    _tile_scores,
    _tile_scores_packed,
)


def _pad_cols(x: jax.Array, k: int, fill):
    """Right-pad [1, L] to [1, max(L, k)] so lax.top_k(_, k) is legal."""
    L = x.shape[1]
    if k <= L:
        return x
    return jnp.concatenate(
        [x, jnp.full((1, k - L), fill, dtype=x.dtype)], axis=1
    )


def _gather_topk_step(
    scores, ids, vals_ref, out_ids_ref, *, p, k: int
):
    """Common tail of a (query, probe) step: mask pads, fold into top-k."""
    # List padding carries ids == -1 (and inv == 0, already NEG_INF).
    scores = jnp.where(ids[None, :] >= 0, scores, SDC_NEG_INF)
    scores = _pad_cols(scores, k, SDC_NEG_INF)
    tile_vals, tile_arg = jax.lax.top_k(scores, k)  # [1, k]
    padded_ids = _pad_cols(ids[None, :], k, -1)
    tile_ids = jnp.take_along_axis(padded_ids, tile_arg, axis=1)
    _merge_running_topk(vals_ref, out_ids_ref, tile_vals, tile_ids, j=p, k=k)


@functools.partial(
    jax.jit, static_argnames=("n_levels", "k", "interpret", "packed")
)
def sdc_gather_topk(
    q_codes: jax.Array,
    lists_codes: jax.Array,
    lists_inv_norm: jax.Array,
    lists_ids: jax.Array,
    probes: jax.Array,
    *,
    n_levels: int,
    k: int,
    interpret: bool = False,
    packed: bool = False,
):
    """Fine-layer IVF search: stream probed lists, running top-k per query.

    Args:
      q_codes: [Q, D] int8 query codes (unpacked, even with packed lists).
      lists_codes: [nlist, L, D] int8, or [nlist, L, D//2] uint8 if packed.
      lists_inv_norm: [nlist, L] f32 reciprocal doc norms (0 for padding).
      lists_ids: [nlist, L] int32 global doc ids (-1 for padding).
      probes: [Q, nprobe] int32 list ids to scan per query.

    Returns:
      (scores [Q, k], doc ids [Q, k]); empty slots are (SDC_NEG_INF, -1).
    """
    Q, D = q_codes.shape
    nlist, L = lists_ids.shape
    nprobe = probes.shape[1]
    Dc = lists_codes.shape[-1]
    assert Dc == (D // 2 if packed else D), (lists_codes.shape, D, packed)

    if packed:
        qe, qo = _split_queries(q_codes)
        q_args = (qe, qo)
        q_specs = [
            pl.BlockSpec((1, D // 2), lambda q, p, pr: (q, 0)),
            pl.BlockSpec((1, D // 2), lambda q, p, pr: (q, 0)),
        ]
    else:
        q_args = (q_codes,)
        q_specs = [pl.BlockSpec((1, D), lambda q, p, pr: (q, 0))]

    def kernel(probes_ref, *refs):
        del probes_ref  # consumed by the BlockSpec index maps
        p = pl.program_id(1)
        if packed:
            qe_ref, qo_ref, codes_ref, inv_ref, ids_ref, vals_ref, ids_out = refs
            scores = _tile_scores_packed(
                qe_ref[...], qo_ref[...], codes_ref[0], inv_ref[0],
                n_levels=n_levels, dim=D,
            )  # [1, L]
        else:
            q_ref, codes_ref, inv_ref, ids_ref, vals_ref, ids_out = refs
            scores = _tile_scores(
                q_ref[...], codes_ref[0], inv_ref[0], n_levels=n_levels, dim=D
            )
        _gather_topk_step(scores, ids_ref[0], vals_ref, ids_out, p=p, k=k)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Q, nprobe),
        in_specs=[
            *q_specs,
            pl.BlockSpec((1, L, Dc), lambda q, p, pr: (pr[q, p], 0, 0)),
            pl.BlockSpec((1, L), lambda q, p, pr: (pr[q, p], 0)),
            pl.BlockSpec((1, L), lambda q, p, pr: (pr[q, p], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda q, p, pr: (q, 0)),
            pl.BlockSpec((1, k), lambda q, p, pr: (q, 0)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        interpret=interpret,
    )(probes.astype(jnp.int32), *q_args, lists_codes, lists_inv_norm, lists_ids)
