"""Single source of truth for SDC kernel launch-shape defaults.

Before this table existed the block sizes had quietly diverged:
``sdc.py`` scanned with ``block_n=512`` while the fused ``sdc_topk``
defaulted to 1024, and ``FlatSDC`` hard-coded a ``block_q=8`` query
tile. Every un-tuned path now reads the same constants from here, and
the block-plan autotuner (``launch/autotune.py``) uses this table as
its fallback plan — a kernel signature that has never been tuned runs
with exactly these shapes.

``BlockPlan`` lives here (not in ``launch/``) so the kernel layer can
accept plans without importing the launch layer. A plan is a plain
NamedTuple of scalars; ``kind`` selects which knobs apply:

  * ``scan``   — ``block_q``/``block_n`` are the tile shapes of the
    fused scan+top-k kernel (``ops.sdc_search``).
  * ``gather`` — the gather-then-scan kernel's geometry is fixed by the
    index layout (one probed list per grid step, the list length is the
    tile); a plan records provenance but pins the defaults.
  * ``rerank`` — ``block_n`` is the candidate *group* size of the
    host-gather rerank path (``rerank.sdc_rerank_gathered``): survivor
    rows are regrouped into lists of ``block_n`` entries so the gather
    substrate runs ``k'/block_n`` steps per query instead of ``k'``.
    ``block_q`` is recorded but inert (the gather kernel scores one
    query row per step).

Roofline constants for the hillclimb cost model (``launch/hillclimb.py``)
live here too, so the tt_retrieval variants and the autotuner price
kernels off one table.
"""

from __future__ import annotations

from typing import NamedTuple


class BlockPlan(NamedTuple):
    """Launch shapes for one kernel kind, plus where they came from.

    ``source`` is provenance only (never part of equality-for-execution):
    "default" (this table), "tuned" (fresh sweep), "cache" (reloaded from
    the tune cache), "inert-backend" (xla — blocks don't reach the
    kernel), "fixed-geometry" (gather — nothing to sweep).
    """

    kind: str
    block_q: int
    block_n: int
    source: str = "default"

    def blocks(self) -> tuple[int, int]:
        return (self.block_q, self.block_n)


# Canonical scan tiles: MXU-aligned (multiples of (8, 128) f32 / int8
# lanes); TQ=128, TN=512 keeps a (TN, D<=2048) int8 tile under 1 MiB of
# VMEM. The fused top-k kernel historically defaulted to TN=1024 — that
# divergence is gone; anything wanting 1024 now asks the autotuner.
BLOCK_Q = 128
BLOCK_N = 512

# FlatSDC serves small online query batches; a full 128-row query tile
# would be >90% padding at serving batch sizes, so its per-call default
# query tile is one f32 sublane.
FLAT_BLOCK_Q = 8

# Host-gather rerank: one survivor row per gather step (the pre-plan
# behavior; grouping is strictly a tuned upgrade).
RERANK_GROUP = 1

DEFAULT_PLANS = {
    "scan": BlockPlan("scan", BLOCK_Q, BLOCK_N, "default"),
    "gather": BlockPlan("gather", 1, 0, "default"),
    "rerank": BlockPlan("rerank", 1, RERANK_GROUP, "default"),
}

KERNEL_KINDS = tuple(DEFAULT_PLANS)


def default_plan(kind: str) -> BlockPlan:
    """The fallback plan for a kernel kind (KeyError on unknown kinds)."""
    if kind not in DEFAULT_PLANS:
        raise KeyError(f"unknown kernel kind {kind!r}; want one of {KERNEL_KINDS}")
    return DEFAULT_PLANS[kind]


def plan_for(block_plan, kind: str) -> BlockPlan | None:
    """Select the plan for one kernel kind from a caller-supplied plan.

    The ``*_search_from_snapshot`` entry points accept either a single
    ``BlockPlan`` (applied only where its ``kind`` matches) or a
    ``{kind: BlockPlan}`` mapping (one tuned plan per kernel kind, the
    shape ``launch/autotune`` produces for a whole serving tier).
    Returns None when no plan targets ``kind`` — the defaults then
    apply.
    """
    if block_plan is None:
        return None
    if isinstance(block_plan, BlockPlan):
        return block_plan if block_plan.kind == kind else None
    plan = block_plan.get(kind)
    if plan is not None and plan.kind != kind:
        raise ValueError(f"plan under key {kind!r} has kind {plan.kind!r}")
    return plan


# Roofline constants (single TPU v5e-class core) for the hillclimb cost
# model. launch/hillclimb.py used to carry its own copies.
PEAK_FLOPS = 197e12  # int8 MXU peak, ops/s
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s per ICI link
N_LINKS = 4
