"""Pallas TPU kernel for Symmetric Distance Calculation (SDC).

TPU-native adaptation of the paper's SIMD-LUT scan (DESIGN.md §2): the
recurrent-binary grid value is affine in the packed integer code
(v = a*c + beta), so the whole scan becomes an int8 x int8 -> int32 MXU
matmul over the code matrices plus rank-1 affine corrections and a
reciprocal-norm epilogue on the VPU. The epilogue itself lives in
``repro.core.binarize_lib.sdc_affine_epilogue`` — the single copy shared
with every jnp fallback, so all scoring paths are bit-identical.

Layout/tiling:
  * codes stream HBM -> VMEM at 8 bits/dim (4 meaningful), documents tiled
    along N, queries tiled along Q; the code dim D stays whole (D <= 2048
    in all BEBR deployments => a (512, D) int8 tile is <= 1 MiB of VMEM).
  * MXU tiles want multiples of (128, 128); defaults TQ=128, TN=512.
  * int32 accumulation is exact — unlike the paper's saturating int8/16
    adds, the TPU path introduces zero quantisation error.
  * documents with a zero reciprocal norm are "excluded" (padding, drained
    shards): every kernel masks them to SDC_NEG_INF before any top-k.

int4 packed code streaming (``packed=True``):
  * for n_levels <= 4 each code is 4 bits, so document codes are stored
    nibble-packed (2 dims/byte; byte j = dim 2j | dim 2j+1 << 4, see
    ``binarize_lib.pack_codes_nibbles``), halving HBM traffic per scanned
    document — the scan is memory-bound, so this is ~2x effective speedup.
  * in-kernel unpack is shift+mask on the VPU; queries (tiny) stay
    unpacked and are pre-split into even/odd dim halves so the scan is two
    half-width int8 MXU matmuls (same MAC count as one full-width one):
        c_q . c_d = q_even . lo(d_packed) + q_odd . hi(d_packed).
  * integer partial sums are identical to the int8 path, so packed scores
    are bit-identical to unpacked scores.

Backend selection lives one level up (``ops.resolve_backend``): "pallas"
(compiled kernel, real TPU), "interpret" (this kernel under the Pallas
interpreter — tests), "xla" (pure-jnp fallback for CPU meshes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.binarize_lib import (
    SDC_NEG_INF,
    sdc_affine_epilogue,
    unpack_nibble_planes,
)
from repro.kernels.sdc.defaults import BLOCK_N, BLOCK_Q


def _check_code_dim(d_codes, D: int, packed: bool) -> None:
    want = D // 2 if packed else D
    if d_codes.shape[-1] != want:
        raise ValueError(
            f"document code dim {d_codes.shape[-1]} (shape {d_codes.shape}) "
            f"!= expected {want} for query dim D={D}, packed={packed}"
        )


def _check_block_tiling(Q: int, N: int, block_q: int, block_n: int) -> None:
    if Q % block_q != 0 or N % block_n != 0:
        raise ValueError(
            f"grid does not tile: Q={Q} % block_q={block_q} = {Q % block_q}, "
            f"N={N} % block_n={block_n} = {N % block_n}; pad Q/N to the "
            "block multiples (ops.sdc_search does) or pick dividing blocks"
        )


def _unpack_nibbles_tile(p: jax.Array):
    """uint8 tile [TN, D//2] -> (lo, hi) int8 tiles holding even/odd dims."""
    lo, hi = unpack_nibble_planes(p)
    return lo.astype(jnp.int8), hi.astype(jnp.int8)


def _int8_dot(x: jax.Array, y: jax.Array) -> jax.Array:
    """[TQ, D] x [TN, D] -> [TQ, TN] int32 (MXU int8 path, exact)."""
    return jax.lax.dot_general(
        x,
        y,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def _tile_scores(q, d, inv, *, n_levels: int, dim: int) -> jax.Array:
    """SDC scores for one (TQ, TN) tile of unpacked int8 codes.

    Excluded documents (inv == 0) come out as SDC_NEG_INF.
    """
    dot = _int8_dot(q, d)
    sq = jnp.sum(q.astype(jnp.int32), axis=-1, keepdims=True)  # [TQ, 1]
    sd = jnp.sum(d.astype(jnp.int32), axis=-1, keepdims=True).T  # [1, TN]
    scores = sdc_affine_epilogue(
        dot, sq + sd, dim=dim, n_levels=n_levels, inv_norm=inv[None, :]
    )
    return jnp.where(inv[None, :] > 0, scores, SDC_NEG_INF)


def _tile_scores_packed(qe, qo, p, inv, *, n_levels: int, dim: int) -> jax.Array:
    """Same as _tile_scores but for nibble-packed document codes.

    qe/qo: [TQ, D//2] int8 query codes at even/odd dims.
    p:     [TN, D//2] uint8 packed document codes.
    The integer partial sums equal the unpacked ones exactly, so scores are
    bit-identical to the int8 path.
    """
    lo, hi = _unpack_nibbles_tile(p)
    dot = _int8_dot(qe, lo) + _int8_dot(qo, hi)
    sq = jnp.sum(qe.astype(jnp.int32), -1, keepdims=True) + jnp.sum(
        qo.astype(jnp.int32), -1, keepdims=True
    )
    sd = (
        jnp.sum(lo.astype(jnp.int32), -1, keepdims=True)
        + jnp.sum(hi.astype(jnp.int32), -1, keepdims=True)
    ).T
    scores = sdc_affine_epilogue(
        dot, sq + sd, dim=dim, n_levels=n_levels, inv_norm=inv[None, :]
    )
    return jnp.where(inv[None, :] > 0, scores, SDC_NEG_INF)


def _sdc_kernel(q_ref, d_ref, dnorm_ref, out_ref, *, n_levels: int, dim: int):
    """One (TQ, TN) score tile (unpacked int8 codes)."""
    out_ref[...] = _tile_scores(
        q_ref[...], d_ref[...], dnorm_ref[...], n_levels=n_levels, dim=dim
    )


def _sdc_kernel_packed(
    qe_ref, qo_ref, d_ref, dnorm_ref, out_ref, *, n_levels: int, dim: int
):
    """One (TQ, TN) score tile (nibble-packed document codes)."""
    out_ref[...] = _tile_scores_packed(
        qe_ref[...], qo_ref[...], d_ref[...], dnorm_ref[...],
        n_levels=n_levels, dim=dim,
    )


def _split_queries(q_codes: jax.Array):
    """[Q, D] int8 -> even/odd dim halves matching the nibble layout."""
    return q_codes[:, 0::2], q_codes[:, 1::2]


@functools.partial(
    jax.jit, static_argnames=("n_levels", "block_q", "block_n", "interpret", "packed")
)
def sdc_scores(
    q_codes: jax.Array,
    d_codes: jax.Array,
    d_inv_norm: jax.Array,
    *,
    n_levels: int,
    block_q: int = BLOCK_Q,
    block_n: int = BLOCK_N,
    interpret: bool = False,
    packed: bool = False,
) -> jax.Array:
    """SDC score matrix [Q, N] = <v(q), v(d)> / ||v(d)||.

    Q and N must be multiples of block_q / block_n (callers pad; see
    ops.sdc_search which handles padding + top-k). With ``packed=True``,
    d_codes is the nibble-packed uint8 [N, D//2] corpus. Documents with
    d_inv_norm == 0 score SDC_NEG_INF (excluded).
    """
    Q, D = q_codes.shape
    N = d_codes.shape[0]
    _check_code_dim(d_codes, D, packed)
    _check_block_tiling(Q, N, block_q, block_n)

    grid = (Q // block_q, N // block_n)
    Dc = d_codes.shape[1]
    out_spec = pl.BlockSpec((block_q, block_n), lambda i, j: (i, j))
    out_shape = jax.ShapeDtypeStruct((Q, N), jnp.float32)
    d_specs = [
        pl.BlockSpec((block_n, Dc), lambda i, j: (j, 0)),
        pl.BlockSpec((block_n,), lambda i, j: (j,)),
    ]
    if packed:
        qe, qo = _split_queries(q_codes)
        return pl.pallas_call(
            functools.partial(_sdc_kernel_packed, n_levels=n_levels, dim=D),
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_q, D // 2), lambda i, j: (i, 0)),
                pl.BlockSpec((block_q, D // 2), lambda i, j: (i, 0)),
                *d_specs,
            ],
            out_specs=out_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(qe, qo, d_codes, d_inv_norm)
    return pl.pallas_call(
        functools.partial(_sdc_kernel, n_levels=n_levels, dim=D),
        grid=grid,
        in_specs=[pl.BlockSpec((block_q, D), lambda i, j: (i, 0)), *d_specs],
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(q_codes, d_codes, d_inv_norm)


def _merge_running_topk(vals_ref, idx_ref, tile_vals, tile_idx, *, j, k):
    """Streaming top-k accumulator shared by the fused scan kernels.

    Out blocks map to the same (i, 0) slot for every inner grid step, so
    they persist in VMEM across the reduction. The running entries are
    concatenated first so ties keep the earliest (lowest-index) document,
    matching a stable top-k over the full score row.
    """

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = tile_vals
        idx_ref[...] = tile_idx

    @pl.when(j > 0)
    def _merge():
        cat_v = jnp.concatenate([vals_ref[...], tile_vals], axis=-1)
        cat_i = jnp.concatenate([idx_ref[...], tile_idx], axis=-1)
        best_v, best_a = jax.lax.top_k(cat_v, k)
        vals_ref[...] = best_v
        idx_ref[...] = jnp.take_along_axis(cat_i, best_a, axis=-1)


def _sdc_topk_kernel(
    q_ref, d_ref, dnorm_ref, vals_ref, idx_ref, *, n_levels, dim, k, block_n
):
    """Fused scan + per-tile top-k (streaming reduction over the N grid)."""
    j = pl.program_id(1)
    scores = _tile_scores(
        q_ref[...], d_ref[...], dnorm_ref[...], n_levels=n_levels, dim=dim
    )
    tile_vals, tile_arg = jax.lax.top_k(scores, k)  # [TQ, k]
    tile_idx = (j * block_n + tile_arg).astype(jnp.int32)
    _merge_running_topk(vals_ref, idx_ref, tile_vals, tile_idx, j=j, k=k)


def _sdc_topk_kernel_packed(
    qe_ref, qo_ref, d_ref, dnorm_ref, vals_ref, idx_ref,
    *, n_levels, dim, k, block_n,
):
    """Packed-int4 variant of the fused scan+top-k kernel."""
    j = pl.program_id(1)
    scores = _tile_scores_packed(
        qe_ref[...], qo_ref[...], d_ref[...], dnorm_ref[...],
        n_levels=n_levels, dim=dim,
    )
    tile_vals, tile_arg = jax.lax.top_k(scores, k)
    tile_idx = (j * block_n + tile_arg).astype(jnp.int32)
    _merge_running_topk(vals_ref, idx_ref, tile_vals, tile_idx, j=j, k=k)


@functools.partial(
    jax.jit,
    static_argnames=("n_levels", "k", "block_q", "block_n", "interpret", "packed"),
)
def sdc_topk(
    q_codes: jax.Array,
    d_codes: jax.Array,
    d_inv_norm: jax.Array,
    *,
    n_levels: int,
    k: int,
    block_q: int = BLOCK_Q,
    block_n: int = BLOCK_N,
    interpret: bool = False,
    packed: bool = False,
):
    """Fused SDC scan + top-k: returns (values [Q, k], indices [Q, k]).

    Avoids materialising the [Q, N] score matrix in HBM — the dominant
    memory term of the naive pipeline (hillclimbed in EXPERIMENTS.md §Perf).
    Excluded documents (inv norm 0) surface as SDC_NEG_INF values.
    """
    Q, D = q_codes.shape
    N = d_codes.shape[0]
    _check_block_tiling(Q, N, block_q, block_n)
    if k > block_n:
        raise ValueError(
            f"fused top-k needs k <= block_n, got k={k}, block_n={block_n} "
            "(ops.sdc_search widens the effective block for large k)"
        )
    grid = (Q // block_q, N // block_n)
    Dc = d_codes.shape[1]
    _check_code_dim(d_codes, D, packed)
    d_specs = [
        pl.BlockSpec((block_n, Dc), lambda i, j: (j, 0)),
        pl.BlockSpec((block_n,), lambda i, j: (j,)),
    ]
    out_specs = [
        pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
        pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((Q, k), jnp.float32),
        jax.ShapeDtypeStruct((Q, k), jnp.int32),
    ]
    if packed:
        qe, qo = _split_queries(q_codes)
        return pl.pallas_call(
            functools.partial(
                _sdc_topk_kernel_packed, n_levels=n_levels, dim=D, k=k,
                block_n=block_n,
            ),
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_q, D // 2), lambda i, j: (i, 0)),
                pl.BlockSpec((block_q, D // 2), lambda i, j: (i, 0)),
                *d_specs,
            ],
            out_specs=out_specs,
            out_shape=out_shape,
            interpret=interpret,
        )(qe, qo, d_codes, d_inv_norm)
    return pl.pallas_call(
        functools.partial(
            _sdc_topk_kernel, n_levels=n_levels, dim=D, k=k, block_n=block_n
        ),
        grid=grid,
        in_specs=[pl.BlockSpec((block_q, D), lambda i, j: (i, 0)), *d_specs],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(q_codes, d_codes, d_inv_norm)
