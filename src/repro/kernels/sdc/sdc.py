"""Pallas TPU kernel for Symmetric Distance Calculation (SDC).

TPU-native adaptation of the paper's SIMD-LUT scan (DESIGN.md §2): the
recurrent-binary grid value is affine in the packed integer code
(v = a*c + beta), so the whole scan becomes an int8 x int8 -> int32 MXU
matmul over the code matrices plus rank-1 affine corrections and a
reciprocal-norm epilogue on the VPU.

Layout/tiling:
  * codes stream HBM -> VMEM at 8 bits/dim (4 meaningful), documents tiled
    along N, queries tiled along Q; the code dim D stays whole (D <= 2048
    in all BEBR deployments => a (512, D) int8 tile is <= 1 MiB of VMEM).
  * MXU tiles want multiples of (128, 128); defaults TQ=128, TN=512.
  * int32 accumulation is exact — unlike the paper's saturating int8/16
    adds, the TPU path introduces zero quantisation error.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.binarize_lib import code_affine_constants


def _sdc_kernel(q_ref, d_ref, dnorm_ref, out_ref, *, a: float, beta: float, dim: int):
    """One (TQ, TN) output tile.

    q_ref:    [TQ, D] int8 query codes
    d_ref:    [TN, D] int8 document codes
    dnorm_ref:[TN]    f32 reciprocal document norms
    out_ref:  [TQ, TN] f32 scores
    """
    q = q_ref[...]
    d = d_ref[...]
    # MXU int8 path: accumulate in int32 (exact).
    dot = jax.lax.dot_general(
        q,
        d,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )  # [TQ, TN]
    sq = jnp.sum(q.astype(jnp.int32), axis=-1, keepdims=True)  # [TQ, 1]
    sd = jnp.sum(d.astype(jnp.int32), axis=-1, keepdims=True).T  # [1, TN]
    scores = (
        (a * a) * dot.astype(jnp.float32)
        + (a * beta) * (sq + sd).astype(jnp.float32)
        + (dim * beta * beta)
    )
    out_ref[...] = scores * dnorm_ref[...][None, :]


@functools.partial(
    jax.jit, static_argnames=("n_levels", "block_q", "block_n", "interpret")
)
def sdc_scores(
    q_codes: jax.Array,
    d_codes: jax.Array,
    d_inv_norm: jax.Array,
    *,
    n_levels: int,
    block_q: int = 128,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """SDC score matrix [Q, N] = <v(q), v(d)> / ||v(d)||.

    Q and N must be multiples of block_q / block_n (callers pad; see
    ops.sdc_search which handles padding + top-k).
    """
    Q, D = q_codes.shape
    N, D2 = d_codes.shape
    assert D == D2, (D, D2)
    assert Q % block_q == 0 and N % block_n == 0, (Q, N, block_q, block_n)
    a, beta = code_affine_constants(n_levels)

    grid = (Q // block_q, N // block_n)
    return pl.pallas_call(
        functools.partial(_sdc_kernel, a=a, beta=beta, dim=D),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, D), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, D), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Q, N), jnp.float32),
        interpret=interpret,
    )(q_codes, d_codes, d_inv_norm)


def _sdc_topk_kernel(
    q_ref, d_ref, dnorm_ref, vals_ref, idx_ref, *, a, beta, dim, k, block_n
):
    """Fused scan + per-tile top-k (streaming reduction over the N grid).

    Grid is (Q_tiles, N_tiles) with N innermost; for each query tile we keep
    a running top-k merged across N tiles in the output refs (VMEM-resident
    accumulator pattern — out blocks map to the same (i, 0) slot for all j,
    so they persist across the inner grid dimension).
    """
    j = pl.program_id(1)
    q = q_ref[...]
    d = d_ref[...]
    dot = jax.lax.dot_general(
        q, d, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    sq = jnp.sum(q.astype(jnp.int32), axis=-1, keepdims=True)
    sd = jnp.sum(d.astype(jnp.int32), axis=-1, keepdims=True).T
    scores = (
        (a * a) * dot.astype(jnp.float32)
        + (a * beta) * (sq + sd).astype(jnp.float32)
        + (dim * beta * beta)
    ) * dnorm_ref[...][None, :]

    tile_vals, tile_arg = jax.lax.top_k(scores, k)  # [TQ, k]
    tile_idx = (j * block_n + tile_arg).astype(jnp.int32)

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = tile_vals
        idx_ref[...] = tile_idx

    @pl.when(j > 0)
    def _merge():
        cat_v = jnp.concatenate([vals_ref[...], tile_vals], axis=-1)
        cat_i = jnp.concatenate([idx_ref[...], tile_idx], axis=-1)
        best_v, best_a = jax.lax.top_k(cat_v, k)
        vals_ref[...] = best_v
        idx_ref[...] = jnp.take_along_axis(cat_i, best_a, axis=-1)


@functools.partial(
    jax.jit, static_argnames=("n_levels", "k", "block_q", "block_n", "interpret")
)
def sdc_topk(
    q_codes: jax.Array,
    d_codes: jax.Array,
    d_inv_norm: jax.Array,
    *,
    n_levels: int,
    k: int,
    block_q: int = 128,
    block_n: int = 1024,
    interpret: bool = False,
):
    """Fused SDC scan + top-k: returns (values [Q, k], indices [Q, k]).

    Avoids materialising the [Q, N] score matrix in HBM — the dominant
    memory term of the naive pipeline (hillclimbed in EXPERIMENTS.md §Perf).
    """
    Q, D = q_codes.shape
    N, _ = d_codes.shape
    assert Q % block_q == 0 and N % block_n == 0 and k <= block_n
    a, beta = code_affine_constants(n_levels)
    grid = (Q // block_q, N // block_n)
    return pl.pallas_call(
        functools.partial(
            _sdc_topk_kernel, a=a, beta=beta, dim=D, k=k, block_n=block_n
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, D), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, D), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        interpret=interpret,
    )(q_codes, d_codes, d_inv_norm)
