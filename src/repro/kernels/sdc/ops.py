"""jit'd public wrappers around the SDC kernels: padding, top-k search,
backend selection.

Every index type (FlatSDC, IVFIndex, the distributed engine) scores
through this module, so the affine epilogue and its exclusion semantics
live in exactly one place. Backends:

  * "pallas"    — compiled Pallas kernel (real TPU).
  * "interpret" — the same kernel under the Pallas interpreter (tests).
  * "xla"       — pure-jnp fallback for CPU meshes; same shared epilogue,
                  so scores are bit-identical to the kernel path.
  * "auto"      — "pallas" on TPU, "xla" otherwise.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.binarize_lib import (
    SDC_NEG_INF,
    sdc_affine_epilogue,
    unpack_nibble_planes,
)
from repro.kernels.sdc import ref as sdc_ref_mod
from repro.kernels.sdc.defaults import BLOCK_N, BLOCK_Q, BlockPlan
from repro.kernels.sdc.sdc import sdc_scores, sdc_topk

NEG_INF = SDC_NEG_INF


def resolve_backend(backend: str = "auto") -> str:
    """Resolve the scoring backend flag to a concrete implementation."""
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend not in ("pallas", "interpret", "xla"):
        raise ValueError(f"unknown SDC backend {backend!r}")
    return backend


def _pad_to(x: jax.Array, axis: int, multiple: int, value=0):
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), n


def _ceil_mult(n: int, m: int) -> int:
    return -(-n // m) * m


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_levels", "k", "block_q", "block_n", "interpret", "fused", "packed",
    ),
)
def sdc_search(
    q_codes: jax.Array,
    d_codes: jax.Array,
    d_inv_norm: jax.Array,
    *,
    n_levels: int,
    k: int,
    block_q: int = BLOCK_Q,
    block_n: int = BLOCK_N,
    interpret: bool = False,
    fused: bool = True,
    packed: bool = False,
):
    """Top-k SDC search of queries against a code corpus.

    Args:
      q_codes: [Q, D] int8 recurrent-binary codes of queries.
      d_codes: [N, D] int8 codes of documents, or nibble-packed uint8
        [N, D//2] when ``packed=True``.
      d_inv_norm: [N] f32 reciprocal doc-value norms (0 => excluded).
      fused: use the fused scan+top-k kernel (no [Q, N] materialisation).

    Returns:
      (scores [Q, k], indices [Q, k]); slots with no valid candidate
      (padding, excluded docs, k > N) come back as (SDC_NEG_INF, -1).
    """
    Q0 = q_codes.shape[0]
    # The fused kernel tiles the running top-k against its N block, so the
    # effective block must hold k entries; keep it a multiple of block_n so
    # lane alignment survives. N is padded against the same effective block
    # (this also guarantees padded N >= k for the final top_k).
    eff_bn = _ceil_mult(max(k, block_n), block_n)
    q_codes, _ = _pad_to(q_codes, 0, block_q)
    d_codes, N0 = _pad_to(d_codes, 0, eff_bn)
    d_inv_norm, _ = _pad_to(d_inv_norm, 0, eff_bn)
    # Force padded docs out of the ranking (kernels treat inv 0 as excluded).
    valid = jnp.arange(d_codes.shape[0]) < N0
    d_inv_norm = jnp.where(valid, d_inv_norm, 0.0)

    if fused:
        vals, idx = sdc_topk(
            q_codes,
            d_codes,
            d_inv_norm,
            n_levels=n_levels,
            k=k,
            block_q=block_q,
            block_n=eff_bn,
            interpret=interpret,
            packed=packed,
        )
    else:
        scores = sdc_scores(
            q_codes,
            d_codes,
            d_inv_norm,
            n_levels=n_levels,
            block_q=block_q,
            block_n=block_n,
            interpret=interpret,
            packed=packed,
        )
        vals, idx = jax.lax.top_k(scores, k)
    # Normalise empty slots: excluded/padded docs surface as NEG_INF values
    # whose indices are meaningless — report them as -1.
    idx = jnp.where(vals > NEG_INF / 2, idx, -1)
    return vals[:Q0], idx[:Q0]


@functools.partial(jax.jit, static_argnames=("n_levels", "k", "packed"))
def sdc_search_xla(
    q_codes: jax.Array,
    d_codes: jax.Array,
    d_inv_norm: jax.Array,
    *,
    n_levels: int,
    k: int,
    packed: bool = False,
):
    """Pure-jnp top-k SDC search (the "xla" backend).

    Same contract as ``sdc_search``; XLA fuses the affine epilogue into the
    int32 matmul so CPU meshes get one matmul + top-k without the Pallas
    interpreter's Python overhead. Packed corpora are scored through the
    same even/odd half-matmul decomposition as the kernel, so scores stay
    bit-identical to the unpacked path.
    """
    D = q_codes.shape[-1]
    cq = q_codes.astype(jnp.int32)
    if packed:
        lo, hi = unpack_nibble_planes(d_codes)
        lo, hi = lo.astype(jnp.int32), hi.astype(jnp.int32)
        dot = cq[:, 0::2] @ lo.T + cq[:, 1::2] @ hi.T
        sd = (jnp.sum(lo, -1) + jnp.sum(hi, -1))[None, :]
    else:
        cd = d_codes.astype(jnp.int32)
        dot = cq @ cd.T
        sd = jnp.sum(cd, -1)[None, :]
    sq = jnp.sum(cq, -1, keepdims=True)
    scores = sdc_affine_epilogue(
        dot, sq + sd, dim=D, n_levels=n_levels, inv_norm=d_inv_norm[None, :]
    )
    scores = jnp.where(d_inv_norm[None, :] > 0, scores, NEG_INF)
    if k > scores.shape[1]:
        pad = jnp.full((scores.shape[0], k - scores.shape[1]), NEG_INF,
                       scores.dtype)
        scores = jnp.concatenate([scores, pad], axis=1)
    vals, idx = jax.lax.top_k(scores, k)
    idx = jnp.where(vals > NEG_INF / 2, idx, -1)
    return vals, idx


def sdc_search_backend(
    q_codes, d_codes, d_inv_norm, *, n_levels, k, backend="auto",
    block_q=BLOCK_Q, block_n=BLOCK_N, packed=False,
    block_plan: BlockPlan | None = None,
):
    """Dispatch a top-k SDC search to the resolved backend.

    ``block_plan`` (a ``defaults.BlockPlan``, e.g. from the
    ``launch/autotune`` sweep) overrides ``block_q``/``block_n`` when
    given. Blocks only shape the kernel launch — scores and ids are
    bit-identical across every block choice — so a plan is always safe
    to apply. The "xla" backend has no tiles; plans are inert there.
    """
    backend = resolve_backend(backend)
    if block_plan is not None:
        block_q, block_n = block_plan.block_q, block_plan.block_n
    if backend == "xla":
        return sdc_search_xla(
            q_codes, d_codes, d_inv_norm, n_levels=n_levels, k=k, packed=packed
        )
    return sdc_search(
        q_codes, d_codes, d_inv_norm, n_levels=n_levels, k=k,
        block_q=block_q, block_n=block_n,
        interpret=(backend == "interpret"), fused=True, packed=packed,
    )


def sdc_search_ref(q_codes, d_codes, n_levels: int, k: int):
    """Oracle top-k via the exact reference (for tests/benchmarks)."""
    scores = sdc_ref_mod.sdc_ref(q_codes, d_codes, n_levels)
    return jax.lax.top_k(scores, k)
