"""jit'd public wrappers around the SDC kernel: padding, top-k search."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.sdc import ref as sdc_ref_mod
from repro.kernels.sdc.sdc import sdc_scores, sdc_topk

NEG_INF = -1e30


def _pad_to(x: jax.Array, axis: int, multiple: int, value=0):
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), n


@functools.partial(
    jax.jit,
    static_argnames=("n_levels", "k", "block_q", "block_n", "interpret", "fused"),
)
def sdc_search(
    q_codes: jax.Array,
    d_codes: jax.Array,
    d_inv_norm: jax.Array,
    *,
    n_levels: int,
    k: int,
    block_q: int = 128,
    block_n: int = 512,
    interpret: bool = False,
    fused: bool = True,
):
    """Top-k SDC search of queries against a code corpus.

    Args:
      q_codes: [Q, D] int8 recurrent-binary codes of queries.
      d_codes: [N, D] int8 codes of documents.
      d_inv_norm: [N] f32 reciprocal doc-value norms.
      fused: use the fused scan+top-k kernel (no [Q, N] materialisation).

    Returns:
      (scores [Q, k], indices [Q, k]); padded docs never appear (their
      inv-norm is forced to 0 and score to -inf).
    """
    Q0 = q_codes.shape[0]
    q_codes, _ = _pad_to(q_codes, 0, block_q)
    d_codes, N0 = _pad_to(d_codes, 0, block_n)
    d_inv_norm, _ = _pad_to(d_inv_norm, 0, block_n)
    # Force padded docs out of the ranking.
    valid = jnp.arange(d_codes.shape[0]) < N0
    d_inv_norm = jnp.where(valid, d_inv_norm, 0.0)

    if fused:
        vals, idx = sdc_topk(
            q_codes,
            d_codes,
            d_inv_norm,
            n_levels=n_levels,
            k=k,
            block_q=block_q,
            block_n=max(block_n, k),
            interpret=interpret,
        )
        pad_score = jnp.where(idx < N0, vals, NEG_INF)
        # Re-sort in case padded entries (score D*beta^2*0 = 0) leaked in.
        vals2, order = jax.lax.top_k(pad_score, k)
        idx2 = jnp.take_along_axis(idx, order, axis=-1)
        return vals2[:Q0], idx2[:Q0]

    scores = sdc_scores(
        q_codes,
        d_codes,
        d_inv_norm,
        n_levels=n_levels,
        block_q=block_q,
        block_n=block_n,
        interpret=interpret,
    )
    scores = jnp.where(valid[None, :], scores, NEG_INF)
    vals, idx = jax.lax.top_k(scores, k)
    return vals[:Q0], idx[:Q0]


def sdc_search_ref(q_codes, d_codes, n_levels: int, k: int):
    """Oracle top-k via the exact reference (for tests/benchmarks)."""
    scores = sdc_ref_mod.sdc_ref(q_codes, d_codes, n_levels)
    return jax.lax.top_k(scores, k)
