"""Gather-then-rerank: score coarse-scan survivors on full-level codes.

The bi-granular search mode (PAPERS.md, Xiao et al. 2201.05409) splits a
query into a cheap coarse scan over level-prefix codes (hot tier) and a
sparse fine rerank of the top-k' survivors against the full-level codes
(cold tier). This module is the fine half: given survivor doc ids, score
exactly those rows of the full corpus through the shared
``sdc_affine_epilogue`` and return the true top-k.

Both implementations reuse the gather-then-scan substrate
(``kernels/sdc/gather``) by viewing the fine corpus as N inverted lists
of length 1 and the survivor ids as the probe table — the same
scalar-prefetched DMA gather that serves the IVF fine layer streams each
survivor's code row through VMEM, and the jnp twin mirrors it for CPU
meshes. Because every path folds the identical integer partial sums
through the one shared epilogue, a rerank is **bit-identical to a
full-level flat scan restricted to the same candidate ids** (including
top-k tie-breaking: candidates are presented in ascending-id order, the
column order of a flat scan).

The cold tier may live on disk: when ``fine_codes`` is a numpy array
(including ``np.memmap``), ``sdc_rerank_backend`` gathers only the
survivor rows host-side — per query, k' rows leave the cold tier, never
the corpus — before scoring the gathered block on device.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.sdc.defaults import RERANK_GROUP, BlockPlan
from repro.kernels.sdc.gather import sdc_gather_topk, sdc_gather_topk_xla
from repro.kernels.sdc.ops import resolve_backend

_INT32_MAX = np.iinfo(np.int32).max


def fine_inv_norms(codes, n_levels: int, chunk: int = 65536):
    """Full-level reciprocal doc norms for a (possibly cold) fine tier.

    Numpy fine codes — including ``np.memmap`` — are streamed in chunks
    so the build never materialises the whole cold tier on device; each
    chunk goes through the same ``doc_inv_norms`` the hot paths use, so
    the values are bit-identical to a single-shot computation. Device
    arrays pass straight through.
    """
    from repro.kernels.sdc.ref import doc_inv_norms

    if not isinstance(codes, np.ndarray):
        return doc_inv_norms(codes, n_levels)
    out = np.empty(codes.shape[0], np.float32)
    for i in range(0, codes.shape[0], chunk):
        block = jnp.asarray(np.asarray(codes[i:i + chunk]))
        out[i:i + chunk] = np.asarray(doc_inv_norms(block, n_levels))
    return out


def _sort_candidates(cand_ids: jax.Array) -> jax.Array:
    """Ascending-id candidate order, invalid (< 0) slots pushed last.

    A flat scan scores documents in id order, so ``lax.top_k`` breaks
    score ties toward the smaller id; presenting rerank candidates in
    the same order is what makes the rerank bit-identical to a
    restricted flat scan even through ties. Candidate ids must be
    distinct (coarse top-k' guarantees it); invalid slots come back -1.
    """
    ids = jnp.asarray(cand_ids, jnp.int32)
    key = jnp.where(ids < 0, _INT32_MAX, ids)
    key = jnp.sort(key, axis=-1)
    return jnp.where(key == _INT32_MAX, -1, key)


@functools.partial(
    jax.jit, static_argnames=("n_levels", "k", "interpret", "packed")
)
def sdc_rerank(
    q_codes: jax.Array,
    fine_codes: jax.Array,
    fine_inv_norm: jax.Array,
    cand_ids: jax.Array,
    *,
    n_levels: int,
    k: int,
    interpret: bool = False,
    packed: bool = False,
):
    """Rerank survivor ids against full-level codes (Pallas kernel path).

    Args:
      q_codes: [Q, D] int8 full-level query codes (unpacked).
      fine_codes: [N, D] int8 full-level corpus codes, or nibble-packed
        uint8 [N, D//2] when ``packed`` (n_levels <= 4).
      fine_inv_norm: [N] f32 reciprocal doc norms at ``n_levels``.
      cand_ids: [Q, k'] int32 survivor doc ids from the coarse scan
        (distinct per query; -1 marks an empty slot). k' may be < k.

    Returns:
      (scores [Q, k], ids [Q, k]); slots beyond the valid survivors are
      (SDC_NEG_INF, -1) — the k' < k degenerate case pads, never reads
      out of range.

    The fine corpus is presented to the gather kernel as N lists of
    length 1 with the (sorted) survivors as the probe table, so the DMA
    engine fetches exactly k' code rows per query from HBM. Invalid
    slots must ride ``cand_mask`` (the kernel clamps probes into range,
    so id masking alone cannot exclude them).
    """
    N = fine_codes.shape[0]
    cand = _sort_candidates(cand_ids)
    lists_codes = fine_codes.reshape(N, 1, fine_codes.shape[-1])
    lists_inv = fine_inv_norm.reshape(N, 1)
    lists_ids = jnp.arange(N, dtype=jnp.int32).reshape(N, 1)
    mask = (cand >= 0).astype(jnp.float32)[..., None]  # [Q, k', 1]
    return sdc_gather_topk(
        q_codes, lists_codes, lists_inv, lists_ids, cand,
        n_levels=n_levels, k=k, interpret=interpret, packed=packed,
        cand_mask=mask,
    )


@functools.partial(jax.jit, static_argnames=("n_levels", "k", "packed"))
def sdc_rerank_xla(
    q_codes: jax.Array,
    fine_codes: jax.Array,
    fine_inv_norm: jax.Array,
    cand_ids: jax.Array,
    *,
    n_levels: int,
    k: int,
    packed: bool = False,
):
    """jnp twin of ``sdc_rerank`` (the "xla" backend fallback).

    Same contract, same scores: identical integer partial sums through
    the shared epilogue, identical ascending-id candidate order.
    """
    N = fine_codes.shape[0]
    cand = _sort_candidates(cand_ids)
    lists_codes = fine_codes.reshape(N, 1, fine_codes.shape[-1])
    lists_inv = fine_inv_norm.reshape(N, 1)
    lists_ids = jnp.arange(N, dtype=jnp.int32).reshape(N, 1)
    mask = (cand >= 0).astype(jnp.float32)[..., None]
    return sdc_gather_topk_xla(
        q_codes, lists_codes, lists_inv, lists_ids, cand,
        n_levels=n_levels, k=k, packed=packed, cand_mask=mask,
    )


def sdc_rerank_gathered(
    q_codes,
    fine_codes: np.ndarray,
    fine_inv_norm: np.ndarray,
    cand_ids,
    *,
    n_levels: int,
    k: int,
    packed: bool = False,
    group: int = RERANK_GROUP,
    backend: str = "xla",
):
    """Cold-tier rerank: host-gather the survivor rows, score on device.

    For a memory-mapped fine tier (``np.memmap``), this is the only
    path that touches k' rows per query instead of paging the whole
    corpus through ``jnp.asarray``. The gathered block is scored as
    fixed-width candidate lists with an identity probe table, so the
    float op order — and therefore every score and tie-break — matches
    ``sdc_rerank`` / ``sdc_rerank_xla`` exactly.

    ``group`` (the rerank axis of a ``BlockPlan``; default 1) is the
    number of gathered survivor rows per list: the gather substrate
    then runs ceil(k'/group) steps per query instead of k'. Because
    scores are elementwise per (query, candidate) and the running
    top-k merge is a stable selection over ascending-id candidates,
    every group size returns bit-identical results — the knob only
    moves launch overhead, which is what the autotuner sweeps.
    Grouping also gives the kernel backends sublane-aligned tiles, so
    ``backend="pallas"/"interpret"`` routes the grouped layout through
    ``sdc_gather_topk`` instead of the jnp twin.
    """
    cand = np.asarray(cand_ids, np.int32)
    key = np.sort(np.where(cand < 0, _INT32_MAX, cand), axis=-1)
    cand = np.where(key == _INT32_MAX, -1, key)
    Q, kp = cand.shape
    g = max(1, min(int(group), kp))
    pad = (-kp) % g
    if pad:
        cand = np.concatenate([cand, -np.ones((Q, pad), np.int32)], axis=1)
        kp += pad
    N = fine_codes.shape[0]
    safe = np.clip(cand, 0, N - 1)
    g_codes = np.asarray(fine_codes)[safe]  # [Q, k', D(/2)] cold-tier reads
    g_inv = np.where(
        cand >= 0, np.asarray(fine_inv_norm)[safe], 0.0
    ).astype(np.float32)
    n_lists = Q * kp // g
    lists_codes = g_codes.reshape(n_lists, g, g_codes.shape[-1])
    lists_inv = g_inv.reshape(n_lists, g)
    lists_ids = cand.reshape(n_lists, g)
    probes = np.arange(n_lists, dtype=np.int32).reshape(Q, kp // g)
    backend = resolve_backend(backend)
    args = (
        jnp.asarray(q_codes), jnp.asarray(lists_codes),
        jnp.asarray(lists_inv), jnp.asarray(lists_ids), jnp.asarray(probes),
    )
    if backend in ("pallas", "interpret"):
        return sdc_gather_topk(
            *args, n_levels=n_levels, k=k,
            interpret=(backend == "interpret"), packed=packed,
        )
    return sdc_gather_topk_xla(*args, n_levels=n_levels, k=k, packed=packed)


def sdc_rerank_backend(
    q_codes,
    fine_codes,
    fine_inv_norm,
    cand_ids,
    *,
    n_levels: int,
    k: int,
    backend: str = "auto",
    packed: bool = False,
    block_plan: BlockPlan | None = None,
):
    """Dispatch a fine rerank to the resolved backend.

    A numpy fine tier (the cold, possibly memory-mapped layout) always
    takes the host-gather path regardless of backend — moving the whole
    corpus on device would defeat the tiering. Device-resident fine
    codes go through the Pallas gather kernel or its jnp twin.

    ``block_plan`` (kind "rerank") sets the host-gather candidate group
    size; results are bit-identical across plans (see
    ``sdc_rerank_gathered``). Device-resident fine tiers gather by DMA
    index map — there is no regrouping to tune — so the plan is inert
    for them.
    """
    backend = resolve_backend(backend)
    if isinstance(fine_codes, np.ndarray):
        group = (
            block_plan.block_n
            if block_plan is not None and block_plan.kind == "rerank"
            else RERANK_GROUP
        )
        return sdc_rerank_gathered(
            q_codes, fine_codes, fine_inv_norm, cand_ids,
            n_levels=n_levels, k=k, packed=packed, group=group,
            backend=backend,
        )
    if backend == "xla":
        return sdc_rerank_xla(
            q_codes, fine_codes, fine_inv_norm, cand_ids,
            n_levels=n_levels, k=k, packed=packed,
        )
    return sdc_rerank(
        q_codes, fine_codes, fine_inv_norm, cand_ids,
        n_levels=n_levels, k=k, interpret=(backend == "interpret"),
        packed=packed,
    )
