"""Pure-jnp oracles for Symmetric Distance Calculation (SDC).

Three references:
  * sdc_ref          — exact: reconstruct grid values, scaled dot product.
                       This is the ground truth the Pallas kernel must match
                       bit-exactly (all arithmetic is exact in int32/f32).
  * sdc_ref_affine   — the affine-identity formulation (DESIGN.md §2) in
                       plain jnp; proves the identity the kernel exploits.
  * sdc_ref_lut      — faithful emulation of the paper's CPU algorithm:
                       per-query int8-quantized 16-entry lookup tables per
                       dimension, gathered by 4-bit code, saturating adds.
                       Used by benchmarks to quantify the extra error the
                       paper's int8 LUTs introduce (our MXU path has none).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.binarize_lib import (
    code_affine_constants,
    codes_to_values,
    sdc_affine_epilogue,
)


def doc_inv_norms(d_codes: jax.Array, n_levels: int) -> jax.Array:
    """Reciprocal L2 norms of document grid values (paper stores these
    quantized alongside each inverted-list entry)."""
    v = codes_to_values(d_codes, n_levels)
    return jax.lax.rsqrt(jnp.sum(v * v, axis=-1) + 1e-12)


def sdc_ref(
    q_codes: jax.Array,
    d_codes: jax.Array,
    n_levels: int,
    d_inv_norm: jax.Array | None = None,
) -> jax.Array:
    """Exact SDC scores [Q, N]: <v(q), v(d)> / ||v(d)||.

    The query norm is constant per query, so it does not affect ranking;
    following the paper we normalise by the document magnitude only.
    """
    vq = codes_to_values(q_codes, n_levels)  # [Q, D]
    vd = codes_to_values(d_codes, n_levels)  # [N, D]
    if d_inv_norm is None:
        d_inv_norm = doc_inv_norms(d_codes, n_levels)
    return (vq @ vd.T) * d_inv_norm[None, :]


def sdc_ref_affine(
    q_codes: jax.Array,
    d_codes: jax.Array,
    n_levels: int,
    d_inv_norm: jax.Array | None = None,
) -> jax.Array:
    """Affine-identity formulation: integer code matmul + rank-1 terms.

      <v(q), v(d)> = a^2 (c_q . c_d) + a*beta*(sum c_q + sum c_d) + D*beta^2
    """
    D = q_codes.shape[-1]
    cq = q_codes.astype(jnp.int32)
    cd = d_codes.astype(jnp.int32)
    dot = cq @ cd.T  # exact in int32
    sq = jnp.sum(cq, axis=-1, keepdims=True)  # [Q, 1]
    sd = jnp.sum(cd, axis=-1, keepdims=True).T  # [1, N]
    if d_inv_norm is None:
        d_inv_norm = doc_inv_norms(d_codes, n_levels)
    return sdc_affine_epilogue(
        dot, sq + sd, dim=D, n_levels=n_levels, inv_norm=d_inv_norm[None, :]
    )


def sdc_ref_lut(
    q_codes: jax.Array,
    d_codes: jax.Array,
    n_levels: int,
    d_inv_norm: jax.Array | None = None,
) -> jax.Array:
    """Paper-faithful SIMD-LUT emulation (int8 tables, 4-bit subcodes).

    Per query, per dimension-group, a 16-entry int8 table holds the partial
    inner product between the query's grid value(s) and every possible
    4-bit document code. Distances are the gathered sums. Matches the
    paper's u=4 layout when n_levels == 4 (one dim per 4-bit code) and the
    u=2 layout when n_levels == 2 (two dims per code, tables pre-summed).
    """
    assert n_levels in (2, 4), "paper layout packs 4-bit subcodes"
    vq = codes_to_values(q_codes, n_levels)  # [Q, D]
    centroids = codes_to_values(
        jnp.arange(2**n_levels, dtype=jnp.int8), n_levels
    )  # [2**n_levels]

    if n_levels == 4:
        # LUT[q, d, c] = vq[q, d] * centroid[c], quantised to int8.
        lut_f = vq[:, :, None] * centroids[None, None, :]  # [Q, D, 16]
        groups = d_codes.astype(jnp.int32)  # [N, D]
    else:
        # Two adjacent 2-bit dims form one 4-bit code; the table entry is
        # the sum of both dims' partial products.
        Q, D = vq.shape
        assert D % 2 == 0
        c_hi = centroids[(jnp.arange(16) >> 2)]
        c_lo = centroids[(jnp.arange(16) & 3)]
        vq2 = vq.reshape(Q, D // 2, 2)
        lut_f = vq2[..., 0:1] * c_hi[None, None, :] + vq2[..., 1:2] * c_lo[None, None, :]
        d2 = d_codes.astype(jnp.int32).reshape(d_codes.shape[0], D // 2, 2)
        groups = d2[..., 0] * 4 + d2[..., 1]  # [N, D//2]

    # Quantise tables to int8 the way the paper does (scale to +-127 by the
    # per-query max |entry|).
    scale = jnp.max(jnp.abs(lut_f), axis=(1, 2), keepdims=True) + 1e-12
    lut_i8 = jnp.clip(jnp.round(lut_f / scale * 127.0), -128, 127)

    # Gather + accumulate (int32 here; the CPU version saturates in int16).
    gathered = jnp.take_along_axis(
        lut_i8[:, None, :, :],  # [Q, 1, G, 16]
        groups[None, :, :, None],  # [1, N, G, 1]
        axis=-1,
    )[..., 0]  # [Q, N, G]
    acc = jnp.sum(gathered, axis=-1)  # [Q, N]
    scores = acc * (scale[:, :, 0] / 127.0)
    if d_inv_norm is None:
        d_inv_norm = doc_inv_norms(d_codes, n_levels)
    return scores * d_inv_norm[None, :]
