"""SDC (Symmetric Distance Calculation) kernels — the single scoring
substrate for every BEBR index type (FlatSDC, IVF, the distributed
engine, HNSW-lite's numpy walker all score through the same shared
affine epilogue in ``repro.core.binarize_lib.sdc_affine_epilogue``).

Modules:
  * ``sdc``    — fused Pallas scan (+top-k) kernels over flat corpora.
  * ``gather`` — gather-then-scan Pallas kernel for the IVF fine layer
                 (scalar-prefetched probe table; probed lists stream
                 through VMEM with a running top-k).
  * ``ops``    — jit'd public wrappers: padding, top-k search, and the
                 backend-selection flag.
  * ``ref``    — pure-jnp oracles (exact / affine-identity / paper LUT).

Backend-selection flag (``backend=`` on ops, index types, and the
engine):
  * ``"pallas"``    — compiled Pallas kernel; the production TPU path.
  * ``"interpret"`` — same kernels under the Pallas interpreter; used by
                      CPU tests to exercise the real kernel logic.
  * ``"xla"``       — pure-jnp fallback (CPU meshes, debugging); scores
                      are bit-identical because it shares the epilogue.
  * ``"auto"``      — "pallas" when ``jax.default_backend() == "tpu"``,
                      else "xla".

int4 packed code layout (``packed=True``, requires ``n_levels <= 4``):
  document codes are stored nibble-packed at 2 dims/byte — byte ``j``
  holds dim ``2j`` in its low nibble and dim ``2j+1`` in its high nibble
  (``binarize_lib.pack_codes_nibbles``). Kernels unpack with shift+mask
  on the VPU and score via two half-width int8 MXU matmuls
  (q_even . lo + q_odd . hi), so HBM traffic per scanned document halves
  while integer partial sums — and therefore scores — stay bit-identical
  to the int8 path. Queries stay unpacked (they are tiny and replicated).
"""
