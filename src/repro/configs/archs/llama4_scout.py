"""llama4-scout-17b-a16e: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 [hf:meta-llama/Llama-4-Scout-17B-16E]."""
import jax.numpy as jnp
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="llama4-scout-17b-a16e", n_layers=48, d_model=5120, n_heads=40,
    n_kv_heads=8, d_ff=8192, vocab=202048, head_dim=128,
    n_experts=16, top_k=1, capacity_factor=1.25,
    rope_theta=500000.0, dtype=jnp.bfloat16, microbatches=4,
    remat=True, attn_chunk=512, kv_cache_dtype=jnp.bfloat16,
    moe_group=2048,
)

SMOKE = TransformerConfig(
    name="llama4-scout-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=512, head_dim=16,
    n_experts=4, top_k=1, dtype=jnp.float32, microbatches=1,
    remat=False, attn_chunk=0,
)
