"""two-tower-retrieval: embed 256, towers 1024-512-256, dot similarity,
sampled softmax [RecSys'19 YouTube]. The paper's native EBR architecture."""
from repro.models.recsys.two_tower import TwoTowerConfig

CONFIG = TwoTowerConfig(
    name="two-tower-retrieval", embed_dim=256, tower_mlp=(1024, 512, 256),
    user_vocab=2_097_152, item_vocab=2_097_152, hist_len=32,
)

SMOKE = TwoTowerConfig(
    name="two-tower-smoke", embed_dim=32, tower_mlp=(64, 32),
    user_vocab=1000, item_vocab=1000, hist_len=8,
)
