"""dien: embed 18, seq 100, gru 108, MLP 200-80, AUGRU [arXiv:1809.03672]."""
from repro.models.recsys.dien import DIENConfig

CONFIG = DIENConfig(
    name="dien", embed_dim=18, seq_len=100, gru_dim=108, mlp=(200, 80),
    item_vocab=524_288, cate_vocab=8_192,
)

SMOKE = DIENConfig(
    name="dien-smoke", embed_dim=8, seq_len=20, gru_dim=24, mlp=(32, 16),
    item_vocab=500, cate_vocab=20,
)
