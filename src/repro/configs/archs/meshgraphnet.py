"""meshgraphnet: 15 layers, d_hidden=128, sum aggregator, 2-layer MLPs
[arXiv:2010.03409]. Per-shape input dims come from configs/cells.py."""
from repro.models.gnn import GNNConfig

CONFIG = GNNConfig(
    name="meshgraphnet", n_layers=15, d_hidden=128, mlp_layers=2,
    aggregator="sum", d_out=3,
)

SMOKE = GNNConfig(
    name="meshgraphnet-smoke", n_layers=3, d_hidden=16, mlp_layers=2,
    aggregator="sum", d_node_in=8, d_edge_in=4, d_out=3,
)
