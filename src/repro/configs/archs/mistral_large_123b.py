"""mistral-large-123b: 88L d_model=12288 96H (GQA kv=8) d_ff=28672
vocab=32768 [hf:mistralai/Mistral-Large-Instruct-2407]."""
import jax.numpy as jnp
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="mistral-large-123b", n_layers=88, d_model=12288, n_heads=96,
    n_kv_heads=8, d_ff=28672, vocab=32768, head_dim=128,
    rope_theta=1000000.0, dtype=jnp.bfloat16, microbatches=4,
    remat=True, attn_chunk=512, kv_cache_dtype=jnp.int8,
)

SMOKE = TransformerConfig(
    name="mistral-large-123b-smoke", n_layers=2, d_model=96, n_heads=6,
    n_kv_heads=2, d_ff=192, vocab=512, head_dim=16,
    dtype=jnp.float32, microbatches=1, remat=False, attn_chunk=0,
)
