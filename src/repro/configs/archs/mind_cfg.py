"""mind: embed_dim=64, 4 interests, 3 capsule iters [arXiv:1904.08030]."""
from repro.models.recsys.mind import MINDConfig

CONFIG = MINDConfig(
    name="mind", embed_dim=64, n_interests=4, capsule_iters=3,
    item_vocab=1_048_576, hist_len=50,
)

SMOKE = MINDConfig(
    name="mind-smoke", embed_dim=16, n_interests=2, capsule_iters=2,
    item_vocab=1000, hist_len=10,
)
