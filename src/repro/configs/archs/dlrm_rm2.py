"""dlrm-rm2: 13 dense, 26 sparse, embed 64, bot 13-512-256-64,
top 512-512-256-1, dot interaction [arXiv:1906.00091]."""
from repro.models.recsys.dlrm import DLRMConfig

CONFIG = DLRMConfig(
    name="dlrm-rm2", n_dense=13, n_sparse=26, embed_dim=64,
    bot_mlp=(13, 512, 256, 64), top_mlp_hidden=(512, 512, 256, 1),
    table_vocab=1_048_576,
)

SMOKE = DLRMConfig(
    name="dlrm-rm2-smoke", n_dense=13, n_sparse=26, embed_dim=16,
    bot_mlp=(13, 64, 32, 16), top_mlp_hidden=(64, 32, 1),
    table_vocab=1000,
)
