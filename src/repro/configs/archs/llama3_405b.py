"""llama3-405b: 126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256
[arXiv:2407.21783]."""
import jax.numpy as jnp
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="llama3-405b", n_layers=126, d_model=16384, n_heads=128,
    n_kv_heads=8, d_ff=53248, vocab=128256, head_dim=128,
    rope_theta=500000.0, dtype=jnp.bfloat16, microbatches=8,
    remat=True, attn_chunk=512, kv_cache_dtype=jnp.int8,
)

SMOKE = TransformerConfig(
    name="llama3-405b-smoke", n_layers=2, d_model=128, n_heads=8,
    n_kv_heads=2, d_ff=256, vocab=512, head_dim=16,
    dtype=jnp.float32, microbatches=1, remat=False, attn_chunk=0,
)
