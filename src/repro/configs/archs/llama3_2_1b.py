"""llama3.2-1b: 16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256
[hf:meta-llama/Llama-3.2-1B]."""
import jax.numpy as jnp
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="llama3.2-1b", n_layers=16, d_model=2048, n_heads=32,
    n_kv_heads=8, d_ff=8192, vocab=128256, head_dim=64,
    rope_theta=500000.0, dtype=jnp.bfloat16, microbatches=1,
    remat=True, attn_chunk=1024, kv_cache_dtype=jnp.bfloat16,
)

SMOKE = TransformerConfig(
    name="llama3.2-1b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
    dtype=jnp.float32, microbatches=1, remat=False, attn_chunk=0,
)
