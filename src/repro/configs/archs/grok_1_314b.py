"""grok-1-314b: 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
MoE 8 experts top-2 [hf:xai-org/grok-1]."""
import jax.numpy as jnp
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="grok-1-314b", n_layers=64, d_model=6144, n_heads=48,
    n_kv_heads=8, d_ff=32768, vocab=131072, head_dim=128,
    n_experts=8, top_k=2, capacity_factor=1.25,
    rope_theta=10000.0, dtype=jnp.bfloat16, microbatches=4,
    remat=True, attn_chunk=512, kv_cache_dtype=jnp.bfloat16,
    moe_group=2048,
)

SMOKE = TransformerConfig(
    name="grok-1-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=512, head_dim=16,
    n_experts=4, top_k=2, dtype=jnp.float32, microbatches=1,
    remat=False, attn_chunk=0,
)
