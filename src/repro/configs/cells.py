"""Cell abstraction: one (architecture x input shape) dry-run unit.

A CellSpec carries everything launch/dryrun.py needs to lower + compile a
production step on a mesh: the step function, abstract (ShapeDtypeStruct)
arguments, input shardings, and metadata for the roofline analysis
(parameter counts, tokens/examples per step).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import gnn as gnn_lib
from repro.models import transformer as tf
from repro.models.recsys import dien as dien_lib
from repro.models.recsys import dlrm as dlrm_lib
from repro.models.recsys import mind as mind_lib
from repro.models.recsys import two_tower as tt_lib
from repro.parallel import sharding as shd
from repro.train import optim, steps


@dataclasses.dataclass
class CellSpec:
    arch: str
    shape: str
    kind: str  # train | prefill | decode | serve | retrieval
    fn: Callable
    abstract_args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    meta: Dict[str, Any]  # params, active_params, tokens/examples, notes


def _abstract(tree):
    """ShapeDtypeStruct pytree from an init closure — no allocation."""
    return jax.eval_shape(tree)


def _ns(mesh, *spec):
    return NamedSharding(mesh, P(*spec))


def _rep(mesh, tree):
    return jax.tree_util.tree_map(lambda _: _ns(mesh), tree)


ADAM = optim.AdamConfig(lr=3e-4, clip_norm=1.0)


# ---------------------------------------------------------------------------
# LM family.
# ---------------------------------------------------------------------------

LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1, context_parallel=True),
}


def lm_cell(cfg: tf.TransformerConfig, shape_id: str, mesh: Mesh) -> CellSpec:
    info = LM_SHAPES[shape_id]
    dp = shd.dp_axes(mesh)
    params_s = _abstract(lambda: tf.init_params(jax.random.PRNGKey(0), cfg))
    param_sh = shd.lm_param_sharding(mesh, cfg)
    meta = {
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "family": "lm",
        "n_layers": cfg.n_layers,
        "d_model": cfg.d_model,
        "n_heads": cfg.n_heads,
        "n_kv_heads": cfg.n_kv_heads,
        "head_dim": cfg.head_dim,
        "seq": info["seq"],
        "batch": info["batch"],
    }

    if info["kind"] == "train":
        opt_s = _abstract(lambda: optim.adam_init(params_s))
        opt_sh = optim.AdamState(
            step=_ns(mesh),
            mu=jax.tree_util.tree_map(lambda s: s, param_sh),
            nu=jax.tree_util.tree_map(lambda s: s, param_sh),
        )
        batch_s = {
            "tokens": jax.ShapeDtypeStruct((info["batch"], info["seq"]), jnp.int32),
            "labels": jax.ShapeDtypeStruct((info["batch"], info["seq"]), jnp.int32),
        }
        batch_sh = {"tokens": shd.lm_batch_sharding(mesh),
                    "labels": shd.lm_batch_sharding(mesh)}
        constrain = shd.lm_activation_constraint(mesh, cfg)
        fn = steps.lm_train_step(cfg, ADAM, constrain=constrain)
        meta["tokens_per_step"] = info["batch"] * info["seq"]
        return CellSpec(cfg.name, shape_id, "train", fn,
                        (params_s, opt_s, batch_s),
                        (param_sh, opt_sh, batch_sh), meta)

    if info["kind"] == "prefill":
        batch_s = {"tokens": jax.ShapeDtypeStruct((info["batch"], info["seq"]), jnp.int32)}
        batch_sh = {"tokens": shd.lm_batch_sharding(mesh)}
        fn = steps.lm_prefill_step(cfg)
        meta["tokens_per_step"] = info["batch"] * info["seq"]
        return CellSpec(cfg.name, shape_id, "prefill", fn,
                        (params_s, batch_s), (param_sh, batch_sh), meta)

    # decode
    cp = info.get("context_parallel", False)
    cache_s = _abstract(
        lambda: tf.init_kv_cache(cfg, info["batch"], info["seq"])
    )
    cache_sh_kv = shd.lm_cache_sharding(mesh, cfg, context_parallel=cp)
    if cp:
        kv_spec = _ns(mesh, None, None, None, dp, None)
    else:
        kv_spec = _ns(mesh, None, dp, None, "model", None)
    cache_sh = {k: kv_spec for k in cache_s if k != "length"}
    cache_sh["length"] = _ns(mesh)
    batch_s = {"token": jax.ShapeDtypeStruct((info["batch"],), jnp.int32)}
    batch_sh = {"token": _ns(mesh, dp) if info["batch"] > 1 else _ns(mesh, None)}
    fn = steps.lm_decode_step(cfg)
    meta["tokens_per_step"] = info["batch"]
    meta["cache_len"] = info["seq"]
    meta["context_parallel"] = cp
    return CellSpec(cfg.name, shape_id, "decode", fn,
                    (params_s, batch_s, cache_s),
                    (param_sh, batch_sh, cache_sh), meta)


# ---------------------------------------------------------------------------
# GNN family (meshgraphnet).
# ---------------------------------------------------------------------------

GNN_SHAPES = {
    "full_graph_sm": dict(nodes=2708, edges=10556, d_feat=1433),
    "minibatch_lg": dict(nodes=169_984, edges=168_960, d_feat=100, sampled=True),
    "ogb_products": dict(nodes=2_449_029, edges=61_859_140, d_feat=100),
    "molecule": dict(nodes=3840, edges=8192, d_feat=16, batched=128),
}


def gnn_cell(base_cfg: gnn_lib.GNNConfig, shape_id: str, mesh: Mesh) -> CellSpec:
    info = GNN_SHAPES[shape_id]
    dp = shd.dp_axes(mesh)
    cfg = dataclasses.replace(base_cfg, d_node_in=info["d_feat"], d_edge_in=8)
    params_s = _abstract(lambda: gnn_lib.init_params(jax.random.PRNGKey(0), cfg))
    param_sh = _rep(mesh, params_s)
    N, E = info["nodes"], info["edges"]
    # pad N/E so nodes shard over `model` and edges over dp (any mesh)
    pad_to = mesh.devices.size
    N = N + (-N) % pad_to
    E = E + (-E) % pad_to
    batch_s = {
        "node_feat": jax.ShapeDtypeStruct((N, info["d_feat"]), jnp.float32),
        "edge_feat": jax.ShapeDtypeStruct((E, 8), jnp.float32),
        "senders": jax.ShapeDtypeStruct((E,), jnp.int32),
        "receivers": jax.ShapeDtypeStruct((E,), jnp.int32),
        "edge_mask": jax.ShapeDtypeStruct((E,), jnp.bool_),
        "targets": jax.ShapeDtypeStruct((N, cfg.d_out), jnp.float32),
    }
    batch_sh = {
        "node_feat": shd.gnn_node_sharding(mesh),
        "edge_feat": shd.gnn_edge_feat_sharding(mesh),
        "senders": shd.gnn_edge_sharding(mesh),
        "receivers": shd.gnn_edge_sharding(mesh),
        "edge_mask": shd.gnn_edge_sharding(mesh),
        "targets": shd.gnn_node_sharding(mesh),
    }
    opt_s = _abstract(lambda: optim.adam_init(params_s))
    opt_sh = optim.AdamState(step=_ns(mesh), mu=_rep(mesh, params_s),
                             nu=_rep(mesh, params_s))
    fn = steps.gnn_train_step(cfg, ADAM)
    meta = {
        "params": cfg.param_count(), "active_params": cfg.param_count(),
        "family": "gnn", "nodes": N, "edges": E, "d_hidden": cfg.d_hidden,
        "n_layers": cfg.n_layers,
    }
    return CellSpec(base_cfg.name, shape_id, "train", fn,
                    (params_s, opt_s, batch_s),
                    (param_sh, opt_sh, batch_sh), meta)


# ---------------------------------------------------------------------------
# RecSys family.
# ---------------------------------------------------------------------------

RS_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1, candidates=1_000_000),
}


def _recsys_common(mesh, model_init, table_keys, stacked_table_keys=()):
    params_s = _abstract(model_init)
    param_sh = shd.fill_param_sharding(mesh, params_s, table_keys,
                                       stacked_table_keys)
    return params_s, param_sh


def _opt_for(mesh, params_s, param_sh):
    opt_s = _abstract(lambda: optim.adam_init(params_s))
    opt_sh = optim.AdamState(
        step=_ns(mesh),
        mu=jax.tree_util.tree_map(lambda s: s, param_sh),
        nu=jax.tree_util.tree_map(lambda s: s, param_sh),
    )
    return opt_s, opt_sh


def dlrm_cell(cfg: dlrm_lib.DLRMConfig, shape_id: str, mesh: Mesh) -> CellSpec:
    info = RS_SHAPES[shape_id]
    dp = shd.dp_axes(mesh)
    params_s, param_sh = _recsys_common(
        mesh, lambda: dlrm_lib.init_params(jax.random.PRNGKey(0), cfg),
        table_keys=(), stacked_table_keys=("tables",),
    )
    meta = {"params": cfg.param_count(), "active_params": cfg.param_count(),
            "family": "recsys", "model": "dlrm", "batch": info["batch"],
            "embed_dim": cfg.embed_dim, "n_sparse": cfg.n_sparse}

    B = info["batch"] if info["kind"] != "retrieval" else info["candidates"]
    batch_s = {
        "dense": jax.ShapeDtypeStruct((B, cfg.n_dense), jnp.float32),
        "sparse_ids": jax.ShapeDtypeStruct((B, cfg.n_sparse), jnp.int32),
    }
    # candidate rows shard over dp (1e6 divides dp extents, not dp*model)
    row_ax = dp
    batch_sh = {"dense": _ns(mesh, row_ax, None),
                "sparse_ids": _ns(mesh, row_ax, None)}
    meta["examples_per_step"] = B

    if info["kind"] == "train":
        batch_s["labels"] = jax.ShapeDtypeStruct((B,), jnp.float32)
        batch_sh["labels"] = _ns(mesh, dp)
        opt_s, opt_sh = _opt_for(mesh, params_s, param_sh)
        fn = steps.dlrm_train_step(cfg, ADAM)
        return CellSpec(cfg.name, shape_id, "train", fn,
                        (params_s, opt_s, batch_s),
                        (param_sh, opt_sh, batch_sh), meta)
    fn = steps.dlrm_serve_step(cfg)
    return CellSpec(cfg.name, shape_id, info["kind"], fn,
                    (params_s, batch_s), (param_sh, batch_sh), meta)


def tt_cell(cfg: tt_lib.TwoTowerConfig, shape_id: str, mesh: Mesh) -> CellSpec:
    info = RS_SHAPES[shape_id]
    dp = shd.dp_axes(mesh)
    params_s, param_sh = _recsys_common(
        mesh, lambda: tt_lib.init_params(jax.random.PRNGKey(0), cfg),
        table_keys=("user_table", "item_table"),
    )
    meta = {"params": cfg.param_count(), "active_params": cfg.param_count(),
            "family": "recsys", "model": "two_tower", "batch": info["batch"],
            "embed_dim": cfg.embed_dim}
    L = cfg.hist_len

    if info["kind"] == "train":
        B = info["batch"]
        batch_s = {
            "hist_ids": jax.ShapeDtypeStruct((B, L), jnp.int32),
            "hist_mask": jax.ShapeDtypeStruct((B, L), jnp.float32),
            "pos_items": jax.ShapeDtypeStruct((B,), jnp.int32),
            "item_logq": jax.ShapeDtypeStruct((B,), jnp.float32),
        }
        batch_sh = {"hist_ids": _ns(mesh, dp, None), "hist_mask": _ns(mesh, dp, None),
                    "pos_items": _ns(mesh, dp), "item_logq": _ns(mesh, dp)}
        opt_s, opt_sh = _opt_for(mesh, params_s, param_sh)
        fn = steps.tt_train_step(cfg, ADAM)
        meta["examples_per_step"] = B
        return CellSpec(cfg.name, shape_id, "train", fn,
                        (params_s, opt_s, batch_s),
                        (param_sh, opt_sh, batch_sh), meta)

    if info["kind"] == "retrieval":
        Bq, Nc = info["batch"], info["candidates"]
        batch_s = {
            "hist_ids": jax.ShapeDtypeStruct((Bq, L), jnp.int32),
            "hist_mask": jax.ShapeDtypeStruct((Bq, L), jnp.float32),
            "cand_ids": jax.ShapeDtypeStruct((Nc,), jnp.int32),
        }
        batch_sh = {"hist_ids": _ns(mesh, None, None),
                    "hist_mask": _ns(mesh, None, None),
                    "cand_ids": _ns(mesh, dp)}
        fn = steps.tt_retrieval_step(cfg, k=100)
        meta["examples_per_step"] = Nc
        meta["candidates"] = Nc
        return CellSpec(cfg.name, shape_id, "retrieval", fn,
                        (params_s, batch_s), (param_sh, batch_sh), meta)

    B = info["batch"]
    batch_s = {
        "hist_ids": jax.ShapeDtypeStruct((B, L), jnp.int32),
        "hist_mask": jax.ShapeDtypeStruct((B, L), jnp.float32),
        "cand_ids": jax.ShapeDtypeStruct((256,), jnp.int32),
    }
    batch_sh = {"hist_ids": _ns(mesh, dp, None), "hist_mask": _ns(mesh, dp, None),
                "cand_ids": _ns(mesh, None)}
    fn = steps.tt_serve_step(cfg)
    meta["examples_per_step"] = B
    return CellSpec(cfg.name, shape_id, "serve", fn,
                    (params_s, batch_s), (param_sh, batch_sh), meta)


def mind_cell(cfg: mind_lib.MINDConfig, shape_id: str, mesh: Mesh) -> CellSpec:
    info = RS_SHAPES[shape_id]
    dp = shd.dp_axes(mesh)
    params_s, param_sh = _recsys_common(
        mesh, lambda: mind_lib.init_params(jax.random.PRNGKey(0), cfg),
        table_keys=("item_table",),
    )
    meta = {"params": cfg.param_count(), "active_params": cfg.param_count(),
            "family": "recsys", "model": "mind", "batch": info["batch"],
            "embed_dim": cfg.embed_dim}
    L = cfg.hist_len

    if info["kind"] == "train":
        B = info["batch"]
        batch_s = {
            "hist_ids": jax.ShapeDtypeStruct((B, L), jnp.int32),
            "hist_mask": jax.ShapeDtypeStruct((B, L), jnp.float32),
            "pos_items": jax.ShapeDtypeStruct((B,), jnp.int32),
            "neg_items": jax.ShapeDtypeStruct((B, 8), jnp.int32),
        }
        batch_sh = {k: _ns(mesh, dp, None) if batch_s[k].ndim == 2 else _ns(mesh, dp)
                    for k in batch_s}
        opt_s, opt_sh = _opt_for(mesh, params_s, param_sh)
        fn = steps.mind_train_step(cfg, ADAM)
        meta["examples_per_step"] = B
        return CellSpec(cfg.name, shape_id, "train", fn,
                        (params_s, opt_s, batch_s),
                        (param_sh, opt_sh, batch_sh), meta)

    if info["kind"] == "retrieval":
        Bq, Nc = info["batch"], info["candidates"]
        batch_s = {
            "hist_ids": jax.ShapeDtypeStruct((Bq, L), jnp.int32),
            "hist_mask": jax.ShapeDtypeStruct((Bq, L), jnp.float32),
            "cand_ids": jax.ShapeDtypeStruct((Nc,), jnp.int32),
        }
        batch_sh = {"hist_ids": _ns(mesh, None, None),
                    "hist_mask": _ns(mesh, None, None),
                    "cand_ids": _ns(mesh, dp)}
        fn = steps.mind_retrieval_step(cfg, k=100)
        meta["examples_per_step"] = Nc
        return CellSpec(cfg.name, shape_id, "retrieval", fn,
                        (params_s, batch_s), (param_sh, batch_sh), meta)

    B = info["batch"]
    batch_s = {
        "hist_ids": jax.ShapeDtypeStruct((B, L), jnp.int32),
        "hist_mask": jax.ShapeDtypeStruct((B, L), jnp.float32),
    }
    batch_sh = {k: _ns(mesh, dp, None) for k in batch_s}
    fn = steps.mind_serve_step(cfg)
    meta["examples_per_step"] = B
    return CellSpec(cfg.name, shape_id, "serve", fn,
                    (params_s, batch_s), (param_sh, batch_sh), meta)


def dien_cell(cfg: dien_lib.DIENConfig, shape_id: str, mesh: Mesh) -> CellSpec:
    info = RS_SHAPES[shape_id]
    dp = shd.dp_axes(mesh)
    params_s, param_sh = _recsys_common(
        mesh, lambda: dien_lib.init_params(jax.random.PRNGKey(0), cfg),
        table_keys=("item_table", "cate_table"),
    )
    meta = {"params": cfg.param_count(), "active_params": cfg.param_count(),
            "family": "recsys", "model": "dien", "batch": info["batch"],
            "embed_dim": cfg.embed_dim, "seq": cfg.seq_len}
    L = cfg.seq_len
    B = info["batch"] if info["kind"] != "retrieval" else info["candidates"]

    batch_s = {
        "hist_items": jax.ShapeDtypeStruct((B, L), jnp.int32),
        "hist_cates": jax.ShapeDtypeStruct((B, L), jnp.int32),
        "hist_mask": jax.ShapeDtypeStruct((B, L), jnp.float32),
        "target_item": jax.ShapeDtypeStruct((B,), jnp.int32),
        "target_cate": jax.ShapeDtypeStruct((B,), jnp.int32),
    }
    ax = dp
    batch_sh = {k: _ns(mesh, ax, None) if batch_s[k].ndim == 2 else _ns(mesh, ax)
                for k in batch_s}
    meta["examples_per_step"] = B

    if info["kind"] == "train":
        batch_s["labels"] = jax.ShapeDtypeStruct((B,), jnp.float32)
        batch_sh["labels"] = _ns(mesh, dp)
        opt_s, opt_sh = _opt_for(mesh, params_s, param_sh)
        fn = steps.dien_train_step(cfg, ADAM)
        return CellSpec(cfg.name, shape_id, "train", fn,
                        (params_s, opt_s, batch_s),
                        (param_sh, opt_sh, batch_sh), meta)
    fn = steps.dien_serve_step(cfg)
    return CellSpec(cfg.name, shape_id, info["kind"], fn,
                    (params_s, batch_s), (param_sh, batch_sh), meta)
