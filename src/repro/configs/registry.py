"""Architecture registry: --arch <id> resolves here.

Each entry: family, full production config, reduced smoke config, shape
ids, and a cell builder (configs/cells.py) that produces the dry-run /
launch specification per (shape x mesh).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

from jax.sharding import Mesh

from repro.configs import cells
from repro.configs.archs import (
    dien_cfg,
    dlrm_rm2,
    grok_1_314b,
    llama3_405b,
    llama3_2_1b,
    llama4_scout,
    meshgraphnet,
    mind_cfg,
    mistral_large_123b,
    two_tower,
)


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    arch_id: str
    family: str  # lm | gnn | recsys
    config: Any
    smoke_config: Any
    shapes: Tuple[str, ...]
    cell_builder: Callable[[Any, str, Mesh], cells.CellSpec]
    notes: str = ""


LM_SHAPE_IDS = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
GNN_SHAPE_IDS = ("full_graph_sm", "minibatch_lg", "ogb_products", "molecule")
RS_SHAPE_IDS = ("train_batch", "serve_p99", "serve_bulk", "retrieval_cand")

REGISTRY: Dict[str, ArchEntry] = {}


def _register(entry: ArchEntry):
    REGISTRY[entry.arch_id] = entry


_register(ArchEntry(
    "llama3-405b", "lm", llama3_405b.CONFIG, llama3_405b.SMOKE,
    LM_SHAPE_IDS, cells.lm_cell,
    notes="dense GQA, 128k vocab [arXiv:2407.21783]",
))
_register(ArchEntry(
    "llama3.2-1b", "lm", llama3_2_1b.CONFIG, llama3_2_1b.SMOKE,
    LM_SHAPE_IDS, cells.lm_cell,
    notes="small llama3 [hf:meta-llama/Llama-3.2-1B]",
))
_register(ArchEntry(
    "mistral-large-123b", "lm", mistral_large_123b.CONFIG,
    mistral_large_123b.SMOKE, LM_SHAPE_IDS, cells.lm_cell,
    notes="[hf:mistralai/Mistral-Large-Instruct-2407]",
))
_register(ArchEntry(
    "llama4-scout-17b-a16e", "lm", llama4_scout.CONFIG, llama4_scout.SMOKE,
    LM_SHAPE_IDS, cells.lm_cell,
    notes="MoE 16e top-1 [hf:meta-llama/Llama-4-Scout-17B-16E]",
))
_register(ArchEntry(
    "grok-1-314b", "lm", grok_1_314b.CONFIG, grok_1_314b.SMOKE,
    LM_SHAPE_IDS, cells.lm_cell,
    notes="MoE 8e top-2 [hf:xai-org/grok-1]",
))
_register(ArchEntry(
    "meshgraphnet", "gnn", meshgraphnet.CONFIG, meshgraphnet.SMOKE,
    GNN_SHAPE_IDS, cells.gnn_cell,
    notes="[arXiv:2010.03409]",
))
_register(ArchEntry(
    "mind", "recsys", mind_cfg.CONFIG, mind_cfg.SMOKE,
    RS_SHAPE_IDS, cells.mind_cell,
    notes="[arXiv:1904.08030]",
))
_register(ArchEntry(
    "dlrm-rm2", "recsys", dlrm_rm2.CONFIG, dlrm_rm2.SMOKE,
    RS_SHAPE_IDS, cells.dlrm_cell,
    notes="[arXiv:1906.00091]",
))
_register(ArchEntry(
    "two-tower-retrieval", "recsys", two_tower.CONFIG, two_tower.SMOKE,
    RS_SHAPE_IDS, cells.tt_cell,
    notes="sampled-softmax retrieval [RecSys'19]; the paper's native EBR arch",
))
_register(ArchEntry(
    "dien", "recsys", dien_cfg.CONFIG, dien_cfg.SMOKE,
    RS_SHAPE_IDS, cells.dien_cell,
    notes="[arXiv:1809.03672]",
))


def get_arch(arch_id: str) -> ArchEntry:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[arch_id]


def build_cell(arch_id: str, shape_id: str, mesh: Mesh) -> cells.CellSpec:
    entry = get_arch(arch_id)
    if shape_id not in entry.shapes:
        raise KeyError(f"{arch_id} has shapes {entry.shapes}, not {shape_id!r}")
    return entry.cell_builder(entry.config, shape_id, mesh)


def all_cells() -> Tuple[Tuple[str, str], ...]:
    out = []
    for arch_id, entry in REGISTRY.items():
        for s in entry.shapes:
            out.append((arch_id, s))
    return tuple(out)
