"""Host-side input pipeline: double-buffered prefetch with straggler
mitigation.

At fleet scale the data path is the straggler source (slow host, slow
network volume). The loader here:
  * prefetches ``depth`` batches on a background thread (compute never
    waits on a healthy producer);
  * applies a per-batch deadline: if the producer misses it, a BACKUP
    producer generates the batch from the same (step, seed) — possible
    because batches are pure functions of the step (data/synthetic.py),
    so the backup is bitwise identical and determinism survives;
  * counts timeouts for monitoring (a node whose primary keeps missing
    deadlines gets drained by the orchestrator).
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Iterator, Optional


class PrefetchLoader:
    def __init__(
        self,
        batch_fn: Callable[[int], Any],
        *,
        depth: int = 2,
        deadline_s: Optional[float] = None,
        start_step: int = 0,
    ):
        self.batch_fn = batch_fn
        self.deadline_s = deadline_s
        self.timeouts = 0
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _produce(self):
        step = self._step
        while not self._stop.is_set():
            try:
                batch = self.batch_fn(step)
            except Exception as e:  # surfaced on the consumer side
                batch = e
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[Any]:
        return self

    def __next__(self) -> Any:
        deadline = self.deadline_s
        try:
            step, batch = self._q.get(timeout=deadline) if deadline else self._q.get()
        except queue.Empty:
            # straggler path: the backup producer regenerates the batch
            # deterministically from the step index.
            self.timeouts += 1
            step = self._consumed if hasattr(self, "_consumed") else 0
            batch = self.batch_fn(step)
            self._consumed = step + 1
            return batch
        if isinstance(batch, Exception):
            raise batch
        self._consumed = step + 1
        return batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
