"""Synthetic data pipeline: deterministic, restart-safe batches per step.

Every generator is a pure function of (step, shape/config) via
jax.random.fold_in — re-running step i after a restart reproduces the
exact batch, which is what makes checkpoint/restart bitwise reproducible.

Also provides the clustered-embedding corpora used by the BEBR
benchmarks (stand-ins for the private Sogou / video-copyright datasets,
statistics matched to the paper: 256-dim / 8192-bit and 128-dim /
4096-bit float vectors with query/doc positive pairs).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _key(step: int, salt: int = 0) -> jax.Array:
    return jax.random.fold_in(jax.random.PRNGKey(20230713 + salt), step)


# ---------------------------------------------------------------------------
# Per-family train batches.
# ---------------------------------------------------------------------------


def lm_batch(step: int, batch: int, seq: int, vocab: int) -> Dict[str, jax.Array]:
    k = _key(step)
    tokens = jax.random.randint(k, (batch, seq + 1), 0, vocab, jnp.int32)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


def gnn_batch(step: int, n_nodes: int, n_edges: int, cfg) -> Dict[str, jax.Array]:
    k = _key(step, 1)
    ks = jax.random.split(k, 5)
    return {
        "node_feat": jax.random.normal(ks[0], (n_nodes, cfg.d_node_in)),
        "edge_feat": jax.random.normal(ks[1], (n_edges, cfg.d_edge_in)),
        "senders": jax.random.randint(ks[2], (n_edges,), 0, n_nodes, jnp.int32),
        "receivers": jax.random.randint(ks[3], (n_edges,), 0, n_nodes, jnp.int32),
        "edge_mask": jnp.ones((n_edges,), jnp.bool_),
        "targets": jax.random.normal(ks[4], (n_nodes, cfg.d_out)),
    }


def dlrm_batch(step: int, batch: int, cfg) -> Dict[str, jax.Array]:
    k = _key(step, 2)
    ks = jax.random.split(k, 3)
    return {
        "dense": jax.random.normal(ks[0], (batch, cfg.n_dense)),
        "sparse_ids": jax.random.randint(
            ks[1], (batch, cfg.n_sparse), 0, cfg.table_vocab, jnp.int32
        ),
        "labels": jax.random.bernoulli(ks[2], 0.25, (batch,)).astype(jnp.float32),
    }


def tt_batch(step: int, batch: int, cfg) -> Dict[str, jax.Array]:
    k = _key(step, 3)
    ks = jax.random.split(k, 3)
    return {
        "hist_ids": jax.random.randint(
            ks[0], (batch, cfg.hist_len), 0, cfg.user_vocab, jnp.int32
        ),
        "hist_mask": jnp.ones((batch, cfg.hist_len), jnp.float32),
        "pos_items": jax.random.randint(ks[1], (batch,), 0, cfg.item_vocab, jnp.int32),
        "item_logq": jnp.zeros((batch,), jnp.float32),
    }


def mind_batch(step: int, batch: int, cfg) -> Dict[str, jax.Array]:
    k = _key(step, 4)
    ks = jax.random.split(k, 3)
    return {
        "hist_ids": jax.random.randint(
            ks[0], (batch, cfg.hist_len), 0, cfg.item_vocab, jnp.int32
        ),
        "hist_mask": jnp.ones((batch, cfg.hist_len), jnp.float32),
        "pos_items": jax.random.randint(ks[1], (batch,), 0, cfg.item_vocab, jnp.int32),
        "neg_items": jax.random.randint(ks[2], (batch, 8), 0, cfg.item_vocab, jnp.int32),
    }


def dien_batch(step: int, batch: int, cfg) -> Dict[str, jax.Array]:
    k = _key(step, 5)
    ks = jax.random.split(k, 5)
    return {
        "hist_items": jax.random.randint(
            ks[0], (batch, cfg.seq_len), 0, cfg.item_vocab, jnp.int32
        ),
        "hist_cates": jax.random.randint(
            ks[1], (batch, cfg.seq_len), 0, cfg.cate_vocab, jnp.int32
        ),
        "hist_mask": jnp.ones((batch, cfg.seq_len), jnp.float32),
        "target_item": jax.random.randint(ks[2], (batch,), 0, cfg.item_vocab, jnp.int32),
        "target_cate": jax.random.randint(ks[3], (batch,), 0, cfg.cate_vocab, jnp.int32),
        "labels": jax.random.bernoulli(ks[4], 0.3, (batch,)).astype(jnp.float32),
    }


# ---------------------------------------------------------------------------
# Clustered embedding corpora for BEBR experiments.
# ---------------------------------------------------------------------------


def clustered_corpus(
    seed: int,
    n_docs: int,
    n_queries: int,
    dim: int,
    n_clusters: int = 64,
    noise: float = 0.25,
    query_noise: float = 0.15,
    spectrum: float = 0.0,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Synthetic EBR corpus with cluster structure + query/doc positives.

    Returns (doc_emb [N, dim], query_emb [Q, dim], gt [Q] index of the
    positive doc for each query). Queries are noisy views of their positive
    document — matching the paper's web-search setting where the relevant
    doc is semantically near the query in the backbone's latent space.

    ``spectrum`` > 0 applies a decaying per-axis scale 1/(1+i)^spectrum
    followed by a random rotation — the anisotropic, effectively low-rank
    geometry of real backbone embeddings (where learned binarization beats
    random-hyperplane hashing; spectrum=0 keeps the isotropic toy geometry
    where 1-bit hashing at equal bit budget is near-optimal).
    """
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_clusters, dim)).astype(np.float32)
    assign = rng.integers(0, n_clusters, n_docs)
    docs = centers[assign] + noise * rng.normal(size=(n_docs, dim)).astype(np.float32)
    gt = rng.choice(n_docs, size=n_queries, replace=False)
    queries = docs[gt] + query_noise * rng.normal(size=(n_queries, dim)).astype(np.float32)
    if spectrum > 0:
        scales = (1.0 / (1.0 + np.arange(dim)) ** spectrum).astype(np.float32)
        rot, _ = np.linalg.qr(rng.normal(size=(dim, dim)).astype(np.float32))
        docs = (docs * scales) @ rot
        queries = (queries * scales) @ rot
    docs /= np.linalg.norm(docs, axis=-1, keepdims=True) + 1e-12
    queries /= np.linalg.norm(queries, axis=-1, keepdims=True) + 1e-12
    return docs, queries, gt


def upgraded_corpus(
    seed: int,
    n_docs: int,
    n_queries: int,
    dim: int,
    n_clusters: int = 96,
    old_noise: float = 0.30,
    new_noise: float = 0.15,
    old_qnoise: float = 0.25,
    new_qnoise: float = 0.12,
    drift: float = 0.3,
    nonlinear: float = 0.3,
):
    """Paired corpora for backbone-upgrade experiments: the same items
    embedded by an OLD backbone (noisier) and a NEW backbone (cleaner,
    drifted space). Mirrors the paper's Table 4 setting where the upgraded
    model is strictly better, so compatible training can EXCEED the
    (old, old) baseline.

    Returns (old_docs, old_queries, new_docs, new_queries, gt).
    """
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_clusters, dim)).astype(np.float32)
    assign = rng.integers(0, n_clusters, n_docs)
    item_id = rng.normal(size=(n_docs, dim)).astype(np.float32)

    def unit(x):
        return x / (np.linalg.norm(x, axis=-1, keepdims=True) + 1e-12)

    # intrinsic item identity is shared; noise level models encoder quality
    old_raw = centers[assign] + old_noise * item_id
    new_raw = centers[assign] + new_noise * item_id

    gt = rng.choice(n_docs, size=n_queries, replace=False)
    qnoise_dir = rng.normal(size=(n_queries, dim)).astype(np.float32)

    old_docs = unit(old_raw)
    new_base = unit(new_raw)
    old_queries = unit(old_raw[gt] + old_qnoise * qnoise_dir)
    new_queries_base = unit(new_raw[gt] + new_qnoise * qnoise_dir)

    # the new backbone lives in a drifted space
    G = rng.normal(size=(dim, dim)).astype(np.float32) / np.sqrt(dim)
    A = rng.normal(size=(dim, dim)).astype(np.float32) / np.sqrt(dim)
    B = rng.normal(size=(dim, dim)).astype(np.float32) / np.sqrt(dim)

    def to_new_space(e):
        out = e + drift * e @ G + nonlinear * np.tanh(e @ A) @ B
        return out / (np.linalg.norm(out, axis=-1, keepdims=True) + 1e-12)

    return (old_docs, old_queries, to_new_space(new_base),
            to_new_space(new_queries_base), gt)


def backbone_upgrade(
    emb: np.ndarray, seed: int, *, strength: float = 0.4,
    nonlinear: float = 0.15,
) -> np.ndarray:
    """Simulate a backbone model upgrade: the new float space is a
    near-identity linear drift of the old one plus a small nonlinear
    component (what a finetuned v2 encoder looks like relative to v1 —
    strongly correlated, not identical, not linearly reachable)."""
    rng = np.random.default_rng(seed)
    d = emb.shape[-1]
    G = rng.normal(size=(d, d)).astype(np.float32) / np.sqrt(d)
    A = rng.normal(size=(d, d)).astype(np.float32) / np.sqrt(d)
    B = rng.normal(size=(d, d)).astype(np.float32) / np.sqrt(d)
    out = emb + strength * emb @ G + nonlinear * np.tanh(emb @ A) @ B
    return out / (np.linalg.norm(out, axis=-1, keepdims=True) + 1e-12)


def pair_batches(
    docs: np.ndarray, seed: int, batch: int, noise: float = 0.1
):
    """Infinite generator of (anchor, positive) float-embedding pairs for
    emb2emb binarizer training (two noisy views of a sampled doc)."""
    rng = np.random.default_rng(seed)
    n, d = docs.shape
    while True:
        idx = rng.integers(0, n, batch)
        base = docs[idx]
        a = base + noise * rng.normal(size=(batch, d)).astype(np.float32)
        p = base + noise * rng.normal(size=(batch, d)).astype(np.float32)
        yield jnp.asarray(a), jnp.asarray(p)
