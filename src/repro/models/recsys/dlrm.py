"""DLRM-RM2 (arXiv:1906.00091): bottom MLP + embedding bags + dot interaction
+ top MLP. Config matches the assigned shape: 13 dense, 26 sparse fields,
embed_dim 64, bot 13-512-256-64, top 512-512-256-1, dot interaction.

The interaction uses the fused Pallas kernel (kernels/dot_interact) on TPU
and the jnp reference elsewhere.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.dot_interact.ref import dot_interact_ref
from repro.models.recsys.embedding import (
    TableConfig,
    embedding_lookup,
    init_table,
    mlp_apply,
    mlp_params,
)


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-rm2"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 64
    bot_mlp: Tuple[int, ...] = (13, 512, 256, 64)
    top_mlp_hidden: Tuple[int, ...] = (512, 512, 256, 1)
    table_vocab: int = 1_000_000
    dtype: Any = jnp.float32

    @property
    def n_feat(self) -> int:
        return self.n_sparse + 1  # +1 bottom-MLP output as a feature

    @property
    def interact_dim(self) -> int:
        return self.n_feat * (self.n_feat - 1) // 2 + self.embed_dim

    def param_count(self) -> int:
        emb = self.n_sparse * self.table_vocab * self.embed_dim
        bot = sum(a * b + b for a, b in zip(self.bot_mlp[:-1], self.bot_mlp[1:]))
        top_dims = (self.interact_dim,) + self.top_mlp_hidden
        top = sum(a * b + b for a, b in zip(top_dims[:-1], top_dims[1:]))
        return emb + bot + top


def init_params(key: jax.Array, cfg: DLRMConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, cfg.n_sparse + 2)
    tables = jnp.stack(
        [
            init_table(ks[i], TableConfig(cfg.table_vocab, cfg.embed_dim), cfg.dtype)
            for i in range(cfg.n_sparse)
        ]
    )  # [F, V, D] — stacked so the table axis can shard over `model`
    top_dims = (cfg.interact_dim,) + cfg.top_mlp_hidden
    return {
        "tables": tables,
        "bot": mlp_params(ks[-2], cfg.bot_mlp, cfg.dtype),
        "top": mlp_params(ks[-1], top_dims, cfg.dtype),
    }


def forward(params, dense: jax.Array, sparse_ids: jax.Array, cfg: DLRMConfig,
            interact_fn=None) -> jax.Array:
    """dense [B, 13] f32; sparse_ids [B, 26] int32 -> logits [B]."""
    x = mlp_apply(params["bot"], dense)  # [B, D]
    # vmap over the 26 field tables: [F, V, D] x [B, F] -> [B, F, D]
    emb = jax.vmap(embedding_lookup, in_axes=(0, 1), out_axes=1)(
        params["tables"], sparse_ids
    )
    feats = jnp.concatenate([x[:, None, :], emb], axis=1)  # [B, F+1, D]
    inter = (interact_fn or dot_interact_ref)(feats)  # [B, P]
    top_in = jnp.concatenate([inter, x], axis=-1)
    return mlp_apply(params["top"], top_in)[:, 0]


def bce_loss(params, dense, sparse_ids, labels, cfg: DLRMConfig,
             interact_fn=None) -> jax.Array:
    logits = forward(params, dense, sparse_ids, cfg, interact_fn=interact_fn)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
