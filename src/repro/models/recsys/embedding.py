"""EmbeddingBag and sparse-feature plumbing for recsys models.

JAX has no nn.EmbeddingBag and no CSR sparse — per the system design this
is built from jnp.take + jax.ops.segment_sum (multi-hot bags) and plain
take (one-hot fields). Tables are row-sharded over the ``model`` mesh axis
in production (parallel/sharding.py); the lookup lowers to a collective
gather under GSPMD.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TableConfig:
    vocab: int
    dim: int
    combiner: str = "sum"  # sum | mean


def init_table(key: jax.Array, cfg: TableConfig, dtype=jnp.float32) -> jax.Array:
    scale = 1.0 / jnp.sqrt(cfg.dim)
    return (jax.random.normal(key, (cfg.vocab, cfg.dim), jnp.float32) * scale).astype(dtype)


def embedding_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """One-hot field lookup: ids [...]-> [..., dim]."""
    return jnp.take(table, ids, axis=0)


def embedding_bag(
    table: jax.Array,
    ids: jax.Array,
    segment_ids: jax.Array,
    num_bags: int,
    *,
    weights: jax.Array | None = None,
    combiner: str = "sum",
) -> jax.Array:
    """Ragged multi-hot bag lookup (torch EmbeddingBag equivalent).

    Args:
      table: [V, D].
      ids: [total] flattened indices across all bags.
      segment_ids: [total] bag id per index (sorted not required).
      num_bags: static number of bags.
      weights: optional [total] per-sample weights.
    """
    rows = jnp.take(table, ids, axis=0)  # [total, D]
    if weights is not None:
        rows = rows * weights[:, None]
    summed = jax.ops.segment_sum(rows, segment_ids, num_segments=num_bags)
    if combiner == "mean":
        counts = jax.ops.segment_sum(
            jnp.ones_like(ids, table.dtype), segment_ids, num_segments=num_bags
        )
        summed = summed / jnp.maximum(counts, 1.0)[:, None]
    return summed


def embedding_bag_fixed(
    table: jax.Array, ids: jax.Array, mask: jax.Array | None = None,
    combiner: str = "sum",
) -> jax.Array:
    """Fixed-width bags (TPU-preferred layout): ids [B, L] -> [B, D].

    Padded slots carry mask=0. This is the layout the assigned recsys
    shapes use (static shapes, no ragged metadata on device).
    """
    rows = jnp.take(table, ids, axis=0)  # [B, L, D]
    if mask is not None:
        rows = rows * mask[..., None].astype(rows.dtype)
    out = jnp.sum(rows, axis=1)
    if combiner == "mean":
        denom = (
            jnp.sum(mask, axis=1, keepdims=True).astype(rows.dtype)
            if mask is not None
            else jnp.full((ids.shape[0], 1), ids.shape[1], rows.dtype)
        )
        out = out / jnp.maximum(denom, 1.0)
    return out


def hash_bucket(ids: jax.Array, vocab: int) -> jax.Array:
    """Deterministic hashing trick for unbounded id spaces."""
    h = ids.astype(jnp.uint32)
    h = (h ^ (h >> 16)) * jnp.uint32(0x45D9F3B)
    h = (h ^ (h >> 16)) * jnp.uint32(0x45D9F3B)
    h = h ^ (h >> 16)
    return (h % jnp.uint32(vocab)).astype(jnp.int32)


def mlp_params(key, dims: Sequence[int], dtype=jnp.float32) -> list:
    ks = jax.random.split(key, len(dims) - 1)
    return [
        {
            "w": (jax.random.normal(k, (a, b), jnp.float32) * jnp.sqrt(2.0 / a)).astype(dtype),
            "b": jnp.zeros((b,), dtype),
        }
        for k, a, b in zip(ks, dims[:-1], dims[1:])
    ]


def mlp_apply(layers, x, final_act=None):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i + 1 < len(layers):
            x = jax.nn.relu(x)
        elif final_act is not None:
            x = final_act(x)
    return x
