"""Two-tower retrieval (YouTube RecSys'19): query/item MLP towers, dot
similarity, in-batch sampled softmax with logQ correction.

This is the arch most representative of the paper's setting: the item
tower's embeddings are exactly what BEBR binarizes and indexes; the
``retrieval_cand`` shape (1 query vs 1M candidates) runs through the SDC
engine (launch/serve.py) as well as the float matmul baseline.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.recsys.embedding import (
    TableConfig,
    embedding_bag_fixed,
    init_table,
    mlp_apply,
    mlp_params,
)


@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    embed_dim: int = 256
    tower_mlp: Tuple[int, ...] = (1024, 512, 256)
    user_vocab: int = 1_000_000
    item_vocab: int = 1_000_000
    hist_len: int = 32
    dtype: Any = jnp.float32

    @property
    def tower_in(self) -> int:
        return self.embed_dim  # bagged history / item id embedding

    def param_count(self) -> int:
        emb = (self.user_vocab + self.item_vocab) * self.embed_dim
        dims = (self.embed_dim,) + self.tower_mlp
        tower = sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))
        return emb + 2 * tower


def init_params(key: jax.Array, cfg: TwoTowerConfig) -> Dict[str, Any]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dims = (cfg.embed_dim,) + cfg.tower_mlp
    return {
        "user_table": init_table(k1, TableConfig(cfg.user_vocab, cfg.embed_dim), cfg.dtype),
        "item_table": init_table(k2, TableConfig(cfg.item_vocab, cfg.embed_dim), cfg.dtype),
        "q_tower": mlp_params(k3, dims, cfg.dtype),
        "i_tower": mlp_params(k4, dims, cfg.dtype),
    }


def _unit(x, eps=1e-12):
    return x * jax.lax.rsqrt(jnp.sum(x * x, -1, keepdims=True) + eps)


def query_embed(params, hist_ids: jax.Array, hist_mask: jax.Array, cfg) -> jax.Array:
    """User history bag -> query tower -> unit embedding [B, out]."""
    bag = embedding_bag_fixed(params["user_table"], hist_ids, hist_mask, "mean")
    return _unit(mlp_apply(params["q_tower"], bag))


def item_embed(params, item_ids: jax.Array, cfg) -> jax.Array:
    emb = jnp.take(params["item_table"], item_ids, axis=0)
    return _unit(mlp_apply(params["i_tower"], emb))


def sampled_softmax_loss(
    params,
    hist_ids: jax.Array,
    hist_mask: jax.Array,
    pos_items: jax.Array,
    item_logq: jax.Array,
    cfg: TwoTowerConfig,
    temperature: float = 0.05,
) -> jax.Array:
    """In-batch sampled softmax with logQ correction (Yi et al. RecSys'19)."""
    q = query_embed(params, hist_ids, hist_mask, cfg)  # [B, D]
    it = item_embed(params, pos_items, cfg)  # [B, D]
    logits = (q @ it.T) / temperature - item_logq[None, :]
    labels = jnp.arange(q.shape[0])
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def score_candidates(params, hist_ids, hist_mask, cand_ids, cfg) -> jax.Array:
    """retrieval_cand serve path: [B_q] queries x [N_c] candidates -> scores.

    Candidate embeddings are computed through the item tower; in the BEBR
    deployment they are precomputed, binarized and searched via the SDC
    engine instead (examples/serve_bebr.py) — this is the float baseline.
    """
    q = query_embed(params, hist_ids, hist_mask, cfg)
    it = item_embed(params, cand_ids, cfg)
    return q @ it.T
