"""DIEN (arXiv:1809.03672): interest extraction GRU + interest evolution
AUGRU over user behavior sequences. Assigned config: embed_dim=18,
seq_len=100, gru_dim=108 (= 6*18: concat item+cate embeddings doubled),
MLP 200-80, AUGRU interaction.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.recsys.embedding import TableConfig, init_table, mlp_params, mlp_apply


@dataclasses.dataclass(frozen=True)
class DIENConfig:
    name: str = "dien"
    embed_dim: int = 18
    seq_len: int = 100
    gru_dim: int = 108
    mlp: tuple = (200, 80)
    item_vocab: int = 500_000
    cate_vocab: int = 5_000
    dtype: Any = jnp.float32

    @property
    def beh_dim(self) -> int:
        return 2 * self.embed_dim  # item + category embeddings

    def param_count(self) -> int:
        gru = 3 * (self.beh_dim + self.gru_dim + 1) * self.gru_dim
        augru = 3 * (self.gru_dim + self.gru_dim + 1) * self.gru_dim
        att = (2 * self.gru_dim) * 36 + 36
        mlp_in = self.gru_dim + 2 * self.beh_dim
        dims = (mlp_in,) + self.mlp + (1,)
        mlp = sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))
        emb = (self.item_vocab + self.cate_vocab) * self.embed_dim
        return emb + gru + augru + att + mlp


def _init_gru(key, d_in, d_h, dtype):
    ks = jax.random.split(key, 3)
    mk = lambda k: {
        "wx": (jax.random.normal(k, (d_in, d_h), jnp.float32) / jnp.sqrt(d_in)).astype(dtype),
        "wh": (jax.random.normal(jax.random.fold_in(k, 1), (d_h, d_h), jnp.float32)
               / jnp.sqrt(d_h)).astype(dtype),
        "b": jnp.zeros((d_h,), dtype),
    }
    return {"r": mk(ks[0]), "z": mk(ks[1]), "n": mk(ks[2])}


def _gru_cell(p, x, h):
    r = jax.nn.sigmoid(x @ p["r"]["wx"] + h @ p["r"]["wh"] + p["r"]["b"])
    z = jax.nn.sigmoid(x @ p["z"]["wx"] + h @ p["z"]["wh"] + p["z"]["b"])
    n = jnp.tanh(x @ p["n"]["wx"] + (r * h) @ p["n"]["wh"] + p["n"]["b"])
    return (1 - z) * n + z * h


def _augru_cell(p, x, h, att):
    """AUGRU: attention score scales the update gate."""
    r = jax.nn.sigmoid(x @ p["r"]["wx"] + h @ p["r"]["wh"] + p["r"]["b"])
    z = jax.nn.sigmoid(x @ p["z"]["wx"] + h @ p["z"]["wh"] + p["z"]["b"])
    z = att[:, None] * z
    n = jnp.tanh(x @ p["n"]["wx"] + (r * h) @ p["n"]["wh"] + p["n"]["b"])
    return (1 - z) * h + z * n


def init_params(key: jax.Array, cfg: DIENConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)
    beh = cfg.beh_dim
    mlp_in = cfg.gru_dim + 2 * beh
    return {
        "item_table": init_table(ks[0], TableConfig(cfg.item_vocab, cfg.embed_dim), cfg.dtype),
        "cate_table": init_table(ks[1], TableConfig(cfg.cate_vocab, cfg.embed_dim), cfg.dtype),
        "gru": _init_gru(ks[2], beh, cfg.gru_dim, cfg.dtype),
        "augru": _init_gru(ks[3], cfg.gru_dim, cfg.gru_dim, cfg.dtype),
        "att": mlp_params(ks[4], (2 * cfg.gru_dim, 36, 1), cfg.dtype),
        "mlp": mlp_params(ks[5], (mlp_in,) + cfg.mlp + (1,), cfg.dtype),
        "target_proj": (jax.random.normal(jax.random.fold_in(ks[4], 7),
                        (beh, cfg.gru_dim), jnp.float32) / jnp.sqrt(beh)).astype(cfg.dtype),
    }


def _behavior_embed(params, item_ids, cate_ids):
    it = jnp.take(params["item_table"], item_ids, axis=0)
    ct = jnp.take(params["cate_table"], cate_ids, axis=0)
    return jnp.concatenate([it, ct], axis=-1)


def forward(
    params,
    hist_items: jax.Array,  # [B, L]
    hist_cates: jax.Array,  # [B, L]
    hist_mask: jax.Array,  # [B, L]
    target_item: jax.Array,  # [B]
    target_cate: jax.Array,  # [B]
    cfg: DIENConfig,
) -> jax.Array:
    """CTR logits [B]. Two-stage: GRU over behaviors, then AUGRU weighted by
    target attention."""
    B, L = hist_items.shape
    beh = _behavior_embed(params, hist_items, hist_cates)  # [B, L, 2e]
    tgt = _behavior_embed(params, target_item, target_cate)  # [B, 2e]
    mask = hist_mask.astype(beh.dtype)

    # Stage 1: interest extraction GRU (scan over time).
    def gru_step(h, xt):
        x, m = xt
        h_new = _gru_cell(params["gru"], x, h)
        h = m[:, None] * h_new + (1 - m[:, None]) * h
        return h, h

    h0 = jnp.zeros((B, cfg.gru_dim), beh.dtype)
    _, states = jax.lax.scan(gru_step, h0, (beh.swapaxes(0, 1), mask.swapaxes(0, 1)))
    states = states.swapaxes(0, 1)  # [B, L, H]

    # Target attention over extracted interests.
    tgt_h = tgt @ params["target_proj"]  # [B, H]
    att_in = jnp.concatenate(
        [states, jnp.broadcast_to(tgt_h[:, None, :], states.shape)], axis=-1
    )
    att = mlp_apply(params["att"], att_in)[..., 0]  # [B, L]
    att = jnp.where(mask > 0, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)

    # Stage 2: interest evolution AUGRU.
    def augru_step(h, xt):
        s, a, m = xt
        h_new = _augru_cell(params["augru"], s, h, a)
        return m[:, None] * h_new + (1 - m[:, None]) * h, None

    h_final, _ = jax.lax.scan(
        augru_step,
        jnp.zeros((B, cfg.gru_dim), beh.dtype),
        (states.swapaxes(0, 1), att.swapaxes(0, 1), mask.swapaxes(0, 1)),
    )

    feats = jnp.concatenate([h_final, tgt, jnp.sum(beh * mask[..., None], 1)], axis=-1)
    return mlp_apply(params["mlp"], feats)[:, 0]


def bce_loss(params, hist_items, hist_cates, hist_mask, target_item, target_cate,
             labels, cfg: DIENConfig) -> jax.Array:
    logits = forward(params, hist_items, hist_cates, hist_mask, target_item,
                     target_cate, cfg)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
