"""MIND (arXiv:1904.08030): multi-interest network with dynamic (capsule)
routing for retrieval. embed_dim=64, 4 interest capsules, 3 routing
iterations, label-aware attention for training.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.recsys.embedding import TableConfig, init_table, mlp_params, mlp_apply


@dataclasses.dataclass(frozen=True)
class MINDConfig:
    name: str = "mind"
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    item_vocab: int = 1_000_000
    hist_len: int = 50
    label_pow: float = 2.0  # label-aware attention sharpness
    dtype: Any = jnp.float32

    def param_count(self) -> int:
        return (
            self.item_vocab * self.embed_dim
            + self.embed_dim * self.embed_dim  # bilinear routing map S
            + 2 * (self.embed_dim * self.embed_dim + self.embed_dim)  # H-layer
        )


def init_params(key: jax.Array, cfg: MINDConfig) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "item_table": init_table(k1, TableConfig(cfg.item_vocab, cfg.embed_dim), cfg.dtype),
        "S": (jax.random.normal(k2, (cfg.embed_dim, cfg.embed_dim), jnp.float32)
              / jnp.sqrt(cfg.embed_dim)).astype(cfg.dtype),
        "H": mlp_params(k3, (cfg.embed_dim, cfg.embed_dim, cfg.embed_dim), cfg.dtype),
    }


def _squash(x, axis=-1, eps=1e-9):
    n2 = jnp.sum(x * x, axis=axis, keepdims=True)
    return (n2 / (1.0 + n2)) * x * jax.lax.rsqrt(n2 + eps)


def interest_capsules(
    params, hist_ids: jax.Array, hist_mask: jax.Array, cfg: MINDConfig,
    routing_logits_init: jax.Array | None = None,
) -> jax.Array:
    """B2I dynamic routing: [B, L] history -> [B, K, D] interest capsules.

    Routing logits are fixed-random-init (paper: shared, not learned) and
    iterated ``capsule_iters`` times with squash nonlinearity.
    """
    B, L = hist_ids.shape
    K = cfg.n_interests
    beh = jnp.take(params["item_table"], hist_ids, axis=0)  # [B, L, D]
    beh_mapped = beh @ params["S"]  # bilinear map
    mask = hist_mask.astype(beh.dtype)  # [B, L]

    if routing_logits_init is None:
        routing_logits_init = jnp.zeros((B, K, L), beh.dtype)
    blog = routing_logits_init

    def routing_iter(blog, _):
        w = jax.nn.softmax(blog, axis=1)  # over capsules
        w = w * mask[:, None, :]
        caps = _squash(jnp.einsum("bkl,bld->bkd", w, beh_mapped))
        blog_new = blog + jnp.einsum("bkd,bld->bkl", caps, beh_mapped)
        return blog_new, caps

    blog, caps_seq = jax.lax.scan(routing_iter, blog, None, length=cfg.capsule_iters)
    caps = caps_seq[-1]
    # H-layer (two-layer ReLU MLP) on each capsule
    return mlp_apply(params["H"], caps)


def label_aware_loss(
    params, hist_ids, hist_mask, pos_items: jax.Array, neg_items: jax.Array,
    cfg: MINDConfig,
) -> jax.Array:
    """Sampled softmax with label-aware attention over interests."""
    caps = interest_capsules(params, hist_ids, hist_mask, cfg)  # [B, K, D]
    pos = jnp.take(params["item_table"], pos_items, axis=0)  # [B, D]
    neg = jnp.take(params["item_table"], neg_items, axis=0)  # [B, Nn, D]

    att = jax.nn.softmax(
        cfg.label_pow * jnp.einsum("bkd,bd->bk", caps, pos), axis=-1
    )
    user = jnp.einsum("bk,bkd->bd", att, caps)  # [B, D]

    pos_logit = jnp.sum(user * pos, -1, keepdims=True)
    neg_logit = jnp.einsum("bd,bnd->bn", user, neg)
    logits = jnp.concatenate([pos_logit, neg_logit], axis=-1)
    return -jnp.mean(jax.nn.log_softmax(logits, axis=-1)[:, 0])


def serve_interests(params, hist_ids, hist_mask, cfg: MINDConfig) -> jax.Array:
    """Serving: emit K interest embeddings per user (each queries the index;
    BEBR binarizes them for SDC retrieval)."""
    return interest_capsules(params, hist_ids, hist_mask, cfg)
