"""MeshGraphNet (arXiv:2010.03409): encode-process-decode GNN.

Message passing uses the edge-index -> scatter formulation mandated for
TPU/JAX: gather endpoint features with jnp.take, update edges with an MLP,
aggregate back to nodes with jax.ops.segment_sum. Static shapes throughout
(padded edges carry a mask) so the same code handles full-batch graphs,
sampled mini-batches, and batched small molecules.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2
    d_node_in: int = 16
    d_edge_in: int = 8
    d_out: int = 3
    aggregator: str = "sum"
    remat: bool = True  # rematerialise each message-passing layer
    dtype: Any = jnp.float32

    def param_count(self) -> int:
        h = self.d_hidden
        mlp = lambda i, o: i * h + h + (self.mlp_layers - 2) * (h * h + h) + h * o + o
        enc = mlp(self.d_node_in, h) + mlp(self.d_edge_in, h)
        proc = self.n_layers * (mlp(3 * h, h) + mlp(2 * h, h))
        dec = mlp(h, self.d_out)
        return enc + proc + dec


def _init_mlp(key, d_in, d_h, d_out, n_layers, dtype):
    dims = [d_in] + [d_h] * (n_layers - 1) + [d_out]
    ks = jax.random.split(key, len(dims) - 1)
    return [
        {
            "w": (jax.random.normal(k, (a, b), jnp.float32) / jnp.sqrt(a)).astype(dtype),
            "b": jnp.zeros((b,), dtype),
        }
        for k, a, b in zip(ks, dims[:-1], dims[1:])
    ]


def _mlp(layers, x):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i + 1 < len(layers):
            x = jax.nn.relu(x)
    return x


def init_params(key: jax.Array, cfg: GNNConfig) -> Params:
    ks = jax.random.split(key, 4 + cfg.n_layers * 2)
    h, m = cfg.d_hidden, cfg.mlp_layers
    layers = []
    for i in range(cfg.n_layers):
        layers.append(
            {
                "edge_mlp": _init_mlp(ks[4 + 2 * i], 3 * h, h, h, m + 1, cfg.dtype),
                "node_mlp": _init_mlp(ks[5 + 2 * i], 2 * h, h, h, m + 1, cfg.dtype),
            }
        )
    return {
        "node_enc": _init_mlp(ks[0], cfg.d_node_in, h, h, m + 1, cfg.dtype),
        "edge_enc": _init_mlp(ks[1], cfg.d_edge_in, h, h, m + 1, cfg.dtype),
        "decoder": _init_mlp(ks[2], h, h, cfg.d_out, m + 1, cfg.dtype),
        "layers": layers,
    }


def forward(
    params: Params,
    node_feat: jax.Array,  # [N, d_node_in]
    edge_feat: jax.Array,  # [E, d_edge_in]
    senders: jax.Array,  # [E] int32
    receivers: jax.Array,  # [E] int32
    edge_mask: jax.Array | None = None,  # [E] bool (False = padding)
    cfg: GNNConfig = None,
    node_constrain=None,  # sharding constraint applied to node-state tensors
) -> jax.Array:
    """Returns per-node outputs [N, d_out]."""
    n_nodes = node_feat.shape[0]
    v = _mlp(params["node_enc"], node_feat)
    e = _mlp(params["edge_enc"], edge_feat)
    if edge_mask is not None:
        e = e * edge_mask[:, None].astype(e.dtype)

    def layer_fn(lp, v, e):
        # edge update: concat(e, v_s, v_r) -> MLP, residual
        vs = jnp.take(v, senders, axis=0)
        vr = jnp.take(v, receivers, axis=0)
        e_new = _mlp(lp["edge_mlp"], jnp.concatenate([e, vs, vr], axis=-1))
        if edge_mask is not None:
            e_new = e_new * edge_mask[:, None].astype(e.dtype)
        e = e + e_new
        # node update: aggregate incoming edges, concat, MLP, residual
        if cfg is not None and cfg.aggregator == "max":
            agg = jax.ops.segment_max(e, receivers, num_segments=n_nodes)
            agg = jnp.where(jnp.isfinite(agg), agg, 0.0)
        else:
            agg = jax.ops.segment_sum(e, receivers, num_segments=n_nodes)
        if node_constrain is not None:
            # force the aggregate to the node partition: GSPMD then emits
            # reduce-scatter (+ later all-gather) instead of a full-array
            # all-reduce per layer — half the wire, sharded node MLP.
            agg = node_constrain(agg)
        v = v + _mlp(lp["node_mlp"], jnp.concatenate([v, agg], axis=-1))
        if node_constrain is not None:
            v = node_constrain(v)
        return v, e

    if cfg is not None and cfg.remat:
        layer_fn = jax.checkpoint(layer_fn)
    for lp in params["layers"]:
        v, e = layer_fn(lp, v, e)

    return _mlp(params["decoder"], v)


def mse_loss(params, node_feat, edge_feat, senders, receivers, targets,
             node_mask=None, edge_mask=None, cfg: GNNConfig = None,
             node_constrain=None) -> jax.Array:
    out = forward(params, node_feat, edge_feat, senders, receivers,
                  edge_mask=edge_mask, cfg=cfg, node_constrain=node_constrain)
    err = jnp.square(out - targets)
    if node_mask is not None:
        err = err * node_mask[:, None].astype(err.dtype)
        denom = jnp.sum(node_mask) * out.shape[-1]
        return jnp.sum(err) / jnp.maximum(denom, 1.0)
    return jnp.mean(err)
