"""Neighbor sampler for sampled GNN training (GraphSAGE-style fanout).

Host-side (numpy) — sampling is data-pipeline work, the device step only
sees padded, static-shape subgraphs. Supports multi-hop fanout (e.g. the
assigned ``minibatch_lg`` shape: batch_nodes=1024, fanout 15-10) over a CSR
adjacency, with deterministic seeding per step for reproducible restarts.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray  # [N+1]
    indices: np.ndarray  # [E]

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1

    @staticmethod
    def from_edges(senders: np.ndarray, receivers: np.ndarray, n_nodes: int) -> "CSRGraph":
        order = np.argsort(senders, kind="stable")
        s, r = senders[order], receivers[order]
        indptr = np.zeros(n_nodes + 1, np.int64)
        np.add.at(indptr, s + 1, 1)
        np.cumsum(indptr, out=indptr)
        return CSRGraph(indptr=indptr, indices=r.astype(np.int64))


def sample_subgraph(
    graph: CSRGraph,
    seeds: np.ndarray,
    fanouts: List[int],
    rng: np.random.Generator,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Multi-hop uniform neighbor sampling.

    Returns (nodes, senders, receivers, edge_mask, seed_positions) where
    senders/receivers index into ``nodes`` (relabelled local ids), arrays are
    padded to the static maximum (len(seeds) * prod(cumulative fanout)).
    """
    layers = [np.unique(seeds)]
    edges_s: List[np.ndarray] = []
    edges_r: List[np.ndarray] = []
    frontier = layers[0]
    for f in fanouts:
        s_list, r_list = [], []
        for node in frontier:
            lo, hi = graph.indptr[node], graph.indptr[node + 1]
            deg = hi - lo
            if deg == 0:
                continue
            take = min(f, deg)
            picks = rng.choice(deg, size=take, replace=False)
            nbrs = graph.indices[lo + picks]
            s_list.append(nbrs)
            r_list.append(np.full(take, node, np.int64))
        if s_list:
            s = np.concatenate(s_list)
            r = np.concatenate(r_list)
        else:
            s = np.zeros(0, np.int64)
            r = np.zeros(0, np.int64)
        edges_s.append(s)
        edges_r.append(r)
        frontier = np.unique(s)
        layers.append(frontier)

    nodes = np.unique(np.concatenate(layers))
    relabel = -np.ones(graph.n_nodes, np.int64)
    relabel[nodes] = np.arange(len(nodes))

    all_s = relabel[np.concatenate(edges_s)] if edges_s else np.zeros(0, np.int64)
    all_r = relabel[np.concatenate(edges_r)] if edges_r else np.zeros(0, np.int64)

    # static-size padding
    max_edges = max(max_sampled_edges(len(seeds), fanouts), len(all_s))
    pad = max_edges - len(all_s)
    mask = np.concatenate([np.ones(len(all_s), bool), np.zeros(pad, bool)])
    all_s = np.concatenate([all_s, np.zeros(pad, np.int64)])
    all_r = np.concatenate([all_r, np.zeros(pad, np.int64)])
    return nodes, all_s.astype(np.int32), all_r.astype(np.int32), mask, relabel[seeds]


def max_sampled_edges(batch_nodes: int, fanouts: List[int]) -> int:
    """Static upper bound on sampled edge count for shape planning."""
    total, frontier = 0, batch_nodes
    for f in fanouts:
        total += frontier * f
        frontier = frontier * f
    return total
