"""LM transformer family: dense (llama3/mistral) and MoE (llama4/grok).

One config covers all five assigned LM architectures: GQA attention with
RoPE, RMSNorm, SwiGLU FFN or top-k routed MoE, tied scan-over-layers
(stacked [L, ...] parameters) so HLO size is O(1) in depth, full causal
train step + KV-cache decode step (batch-sharded or context-parallel).

Distribution is GSPMD-first: parameters carry logical axis names mapped to
PartitionSpecs by parallel/sharding.py; the train step is a plain jit with
in/out shardings, microbatched gradient accumulation, and per-layer remat.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    # MoE (n_experts = 0 => dense SwiGLU)
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    rope_theta: float = 500000.0
    dtype: Any = jnp.bfloat16
    # training-time knobs
    microbatches: int = 1
    remat: bool = True
    # activation sharding of the scan carry: None | "seq" (Megatron-SP)
    activation_sharding: Optional[str] = "seq"
    # query-chunked (flash-style online) attention; 0 = single-shot.
    attn_chunk: int = 1024
    # MoE routing-group length: tokens are routed in fixed groups of this
    # many tokens (0 = one group per batch row). Bounds the GShard one-hot
    # dispatch at [*, G, k, E, C~G*k/E] — LINEAR in sequence length,
    # instead of the O(S^2) blow-up of per-row routing at long prefill.
    moe_group: int = 0
    # KV cache dtype: jnp.bfloat16 | jnp.int8 (BEBR-style quantised serving)
    kv_cache_dtype: Any = jnp.bfloat16

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        attn = d * self.n_heads * self.head_dim + 2 * d * self.n_kv_heads * self.head_dim
        attn += self.n_heads * self.head_dim * d
        if self.is_moe:
            ffn = self.n_experts * 3 * d * f + d * self.n_experts  # experts + router
        else:
            ffn = 3 * d * f
        per_layer = attn + ffn + 2 * d
        return self.n_layers * per_layer + v * d + d  # embed (tied out) + final norm

    def active_param_count(self) -> int:
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_like = self.param_count() - self.n_layers * self.n_experts * 3 * d * f
        return dense_like + self.n_layers * self.top_k * 3 * d * f


# ---------------------------------------------------------------------------
# Primitives.
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * scale


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: [..., S, H, hd]; positions: [..., S]."""
    hd = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def _init(key, shape, dtype, scale=None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else (1.0 / jnp.sqrt(fan_in))
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def init_params(key: jax.Array, cfg: TransformerConfig) -> Params:
    """Stacked [L, ...] parameters for scan-over-layers."""
    L, d, f = cfg.n_layers, cfg.d_model, cfg.d_ff
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 12)
    layer = {
        "attn_norm": jnp.ones((L, d), cfg.dtype),
        "wq": _init(ks[0], (L, d, H * hd), cfg.dtype),
        "wk": _init(ks[1], (L, d, KV * hd), cfg.dtype),
        "wv": _init(ks[2], (L, d, KV * hd), cfg.dtype),
        "wo": _init(ks[3], (L, H * hd, d), cfg.dtype),
        "ffn_norm": jnp.ones((L, d), cfg.dtype),
    }
    if cfg.is_moe:
        layer.update(
            router=_init(ks[4], (L, d, cfg.n_experts), cfg.dtype),
            w_gate=_init(ks[5], (L, cfg.n_experts, d, f), cfg.dtype),
            w_up=_init(ks[6], (L, cfg.n_experts, d, f), cfg.dtype),
            w_down=_init(ks[7], (L, cfg.n_experts, f, d), cfg.dtype),
        )
    else:
        layer.update(
            w_gate=_init(ks[5], (L, d, f), cfg.dtype),
            w_up=_init(ks[6], (L, d, f), cfg.dtype),
            w_down=_init(ks[7], (L, f, d), cfg.dtype),
        )
    return {
        "embed": _init(ks[8], (cfg.vocab, d), cfg.dtype, scale=0.02),
        "final_norm": jnp.ones((d,), cfg.dtype),
        "layers": layer,
    }


# ---------------------------------------------------------------------------
# Attention / FFN / MoE blocks (single layer; used inside lax.scan).
# ---------------------------------------------------------------------------


def _quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-(.., token) symmetric int8: x [..., T, hd] -> (q int8, scale)."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _causal_chunk_attn(qh, kh, vh, q_offset, S_kv, chunk, dtype):
    """Query-chunked online attention (flash-style memory profile).

    qh [B, KV, G, S, hd]; kh/vh [B, KV, T, hd]. Each chunk materialises only
    [B, KV, G, C, T] logits. Causal with absolute positions (q_offset).
    """
    B, KV, G, S, hd = qh.shape
    n_chunks = S // chunk
    qc = qh.reshape(B, KV, G, n_chunks, chunk, hd).transpose(3, 0, 1, 2, 4, 5)
    kpos = jnp.arange(S_kv)

    def one(carry, args):
        i, q = args
        logits = jnp.einsum("bkgqh,bkth->bkgqt", q, kh)
        qpos = q_offset + i * chunk + jnp.arange(chunk)
        causal = kpos[None, :] <= qpos[:, None]
        logits = jnp.where(causal[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(dtype)
        ctx = jnp.einsum("bkgqt,bkth->bkgqh", probs, vh)
        return carry, ctx

    _, ctxs = jax.lax.scan(one, None, (jnp.arange(n_chunks), qc))
    # ctxs [n_chunks, B, KV, G, chunk, hd] -> [B, KV, G, S, hd]
    return ctxs.transpose(1, 2, 3, 0, 4, 5).reshape(B, KV, G, S, hd)


def _attention(lp, x, positions, cfg: TransformerConfig, mask=None, kv_cache=None):
    """x: [B, S, d]. kv_cache: optional dict with k/v [B, KV, T, hd] and
    ``length`` — decode mode appends and attends to the cache."""
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ lp["wq"]).reshape(B, S, H, hd)
    k = (x @ lp["wk"]).reshape(B, S, KV, hd)
    v = (x @ lp["wv"]).reshape(B, S, KV, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = q * (hd ** -0.5)

    if kv_cache is not None:
        # decode: S == 1; cache is [B, KV, T, hd] pre-filled to ``length``.
        quantized = "k_scale" in kv_cache
        k_new = k.transpose(0, 2, 1, 3)  # [B, KV, S, hd]
        v_new = v.transpose(0, 2, 1, 3)
        if quantized:
            kq, ks = _quantize_kv(k_new)
            vq, vs = _quantize_kv(v_new)
            ck = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["k"], kq, kv_cache["length"], axis=2)
            cv = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["v"], vq, kv_cache["length"], axis=2)
            cks = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["k_scale"], ks, kv_cache["length"], axis=2)
            cvs = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["v_scale"], vs, kv_cache["length"], axis=2)
            keys = ck.astype(q.dtype) * cks.astype(q.dtype)
            vals = cv.astype(q.dtype) * cvs.astype(q.dtype)
            new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs,
                         "length": kv_cache["length"] + S}
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["k"], k_new.astype(kv_cache["k"].dtype),
                kv_cache["length"], axis=2)
            cv = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["v"], v_new.astype(kv_cache["v"].dtype),
                kv_cache["length"], axis=2)
            keys = ck.astype(q.dtype)
            vals = cv.astype(q.dtype)
            new_cache = {"k": ck, "v": cv, "length": kv_cache["length"] + S}
        T = keys.shape[2]
        groups = H // KV
        qg = q.transpose(0, 2, 1, 3).reshape(B, KV, groups * S, hd)
        logits = jnp.einsum("bkqh,bkth->bkqt", qg, keys)
        tpos = jnp.arange(T)
        valid = tpos[None, None, None, :] <= kv_cache["length"]
        logits = jnp.where(valid, logits, -1e30)
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
        ctx = jnp.einsum("bkqt,bkth->bkqh", probs, vals)
        ctx = ctx.reshape(B, KV, groups, S, hd).transpose(0, 3, 1, 2, 4)
        ctx = ctx.reshape(B, S, H * hd)
        return ctx @ lp["wo"], new_cache

    # training / prefill: causal attention, GQA via head grouping; query
    # chunking bounds the logits working set at [.., chunk, S].
    groups = H // KV
    qh = q.transpose(0, 2, 1, 3).reshape(B, KV, groups, S, hd)
    kh = k.transpose(0, 2, 1, 3)  # [B, KV, S, hd]
    vh = v.transpose(0, 2, 1, 3)
    if cfg.attn_chunk and S > cfg.attn_chunk and S % cfg.attn_chunk == 0 and mask is None:
        ctx = _causal_chunk_attn(qh, kh, vh, 0, S, cfg.attn_chunk, x.dtype)
    else:
        logits = jnp.einsum("bkgqh,bkth->bkgqt", qh, kh)
        causal = jnp.tril(jnp.ones((S, S), bool))
        if mask is not None:
            causal = jnp.logical_and(causal, mask)
        logits = jnp.where(causal, logits, -1e30)
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(x.dtype)
        ctx = jnp.einsum("bkgqt,bkth->bkgqh", probs, vh)
    ctx = ctx.transpose(0, 3, 1, 2, 4).reshape(B, S, H * hd)
    return ctx @ lp["wo"], None


def _dense_ffn(lp, x):
    gate = jax.nn.silu(x @ lp["w_gate"])
    up = x @ lp["w_up"]
    return (gate * up) @ lp["w_down"]


def _moe_ffn(lp, x, cfg: TransformerConfig):
    """Grouped dense-dispatch top-k MoE (GShard-style einsum routing).

    Each batch row is a routing group: capacity is per-group, so the
    one-hot dispatch tensor is [B, S, k, E, C] with B shardable over dp
    (C = capacity_factor * S * k / E). Under GSPMD with experts sharded
    over ``model`` the dispatch/combine einsums lower to all-to-alls —
    the canonical EP pattern.
    """
    B0, S0, d = x.shape
    if cfg.moe_group and S0 > cfg.moe_group and S0 % cfg.moe_group == 0:
        x = x.reshape(B0 * S0 // cfg.moe_group, cfg.moe_group, d)
    B, S, _ = x.shape
    E, k = cfg.n_experts, cfg.top_k
    logits = x @ lp["router"]  # [B, S, E]
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # [B, S, k]
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    cap = max(int(cfg.capacity_factor * S * k / E), 4)
    # position of each (token, slot) within its expert's per-group buffer
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)  # [B, S, k, E]
    flat = onehot.reshape(B, S * k, E)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(B, S, k, E)
    pos = jnp.sum(pos_in_expert * onehot, -1)  # [B, S, k]
    keep = pos < cap
    gate_vals = jnp.where(keep, gate_vals, 0.0)

    # dispatch [B, S, k, E, C] one-hot -> combine via einsums
    disp = (
        jax.nn.one_hot(gate_idx, E, dtype=x.dtype)[..., None]
        * jax.nn.one_hot(
            jnp.where(keep, pos, cap), cap + 1, dtype=x.dtype
        )[..., None, :]
    )[..., :cap]  # [B, S, k, E, C]
    disp_comb = disp * gate_vals[..., None, None].astype(x.dtype)
    expert_in = jnp.einsum("bsd,bskec->becd", x, disp)  # [B, E, C, d]
    gate = jnp.einsum("becd,edf->becf", expert_in, lp["w_gate"])
    up = jnp.einsum("becd,edf->becf", expert_in, lp["w_up"])
    expert_out = jnp.einsum("becf,efd->becd", jax.nn.silu(gate) * up, lp["w_down"])
    out = jnp.einsum("becd,bskec->bsd", expert_out, disp_comb)

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(
        jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32), axis=(0, 1)
    )
    aux = E * jnp.sum(me * ce)
    return out.reshape(B0, S0, d), aux


def _layer(lp, x, positions, cfg: TransformerConfig, kv_cache=None,
           constrain=None):
    h, new_cache = _attention(
        lp, rms_norm(x, lp["attn_norm"]), positions, cfg, kv_cache=kv_cache
    )
    x = x + h
    if constrain is not None:
        # Megatron-SP: pin the residual stream to its sequence-sharded
        # layout right after each residual add — GSPMD then emits
        # reduce-scatter(+fused all-gather) pairs instead of round-trip
        # reshards of the full activation.
        x = constrain(x)
    if cfg.is_moe:
        h, aux = _moe_ffn(lp, rms_norm(x, lp["ffn_norm"]), cfg)
    else:
        h, aux = _dense_ffn(lp, rms_norm(x, lp["ffn_norm"])), 0.0
    x = x + h
    if constrain is not None:
        x = constrain(x)
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# Forward passes.
# ---------------------------------------------------------------------------


def backbone(params: Params, tokens: jax.Array, cfg: TransformerConfig,
             constrain=None) -> Tuple[jax.Array, jax.Array]:
    """Scan-over-layers trunk: tokens [B, S] -> (hidden [B, S, d], aux)."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    positions = jnp.arange(S)[None, :].astype(jnp.int32)

    def body(carry, lp):
        x, aux = carry
        inner = constrain if cfg.activation_sharding == "seq_residual" else None
        if constrain is not None and inner is None:
            x = constrain(x)
        y, a, _ = _layer(lp, x, positions, cfg, constrain=inner)
        return (y, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    return rms_norm(x, params["final_norm"]), aux / cfg.n_layers


def forward(params: Params, tokens: jax.Array, cfg: TransformerConfig,
            constrain=None) -> Tuple[jax.Array, jax.Array]:
    """Training forward: tokens [B, S] -> (logits [B, S, V], aux loss)."""
    x, aux = backbone(params, tokens, cfg, constrain=constrain)
    logits = x @ params["embed"].T.astype(cfg.dtype)
    return logits, aux


def lm_loss(params: Params, tokens: jax.Array, labels: jax.Array,
            cfg: TransformerConfig, constrain=None) -> jax.Array:
    logits, aux = forward(params, tokens, cfg, constrain=constrain)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll) + 0.01 * aux


def init_kv_cache(cfg: TransformerConfig, batch: int, max_len: int,
                  dtype=None) -> Dict[str, jax.Array]:
    """Stacked cache for scan: k/v [L, B, KV, T, hd]. int8 dtype adds
    per-token scale planes (BEBR-style quantised serving memory)."""
    dtype = cfg.kv_cache_dtype if dtype is None else dtype
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, max_len, cfg.head_dim)
    cache = {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "length": jnp.zeros((), jnp.int32),
    }
    if dtype == jnp.int8:
        sshape = shape[:-1] + (1,)
        cache["k_scale"] = jnp.zeros(sshape, jnp.float32)
        cache["v_scale"] = jnp.zeros(sshape, jnp.float32)
    return cache


def decode_step(params: Params, token: jax.Array, cache: Dict[str, jax.Array],
                cfg: TransformerConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step. token: [B] int32. Returns (logits [B, V], cache)."""
    B = token.shape[0]
    x = params["embed"][token][:, None, :].astype(cfg.dtype)  # [B, 1, d]
    pos = jnp.full((1, 1), cache["length"], jnp.int32)
    quantized = "k_scale" in cache

    def body(carry, layer_in):
        x = carry
        if quantized:
            lp, ck, cv, cks, cvs = layer_in
            lc = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs,
                  "length": cache["length"]}
        else:
            lp, ck, cv = layer_in
            lc = {"k": ck, "v": cv, "length": cache["length"]}
        y, _, new_cache = _layer(lp, x, pos, cfg, kv_cache=lc)
        if quantized:
            return y, (new_cache["k"], new_cache["v"], new_cache["k_scale"],
                       new_cache["v_scale"])
        return y, (new_cache["k"], new_cache["v"])

    if quantized:
        xs = (params["layers"], cache["k"], cache["v"], cache["k_scale"],
              cache["v_scale"])
        x, (nk, nv, nks, nvs) = jax.lax.scan(body, x, xs)
        new_cache = {"k": nk, "v": nv, "k_scale": nks, "v_scale": nvs,
                     "length": cache["length"] + 1}
    else:
        x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                             cache["v"]))
        new_cache = {"k": nk, "v": nv, "length": cache["length"] + 1}
    x = rms_norm(x, params["final_norm"])
    logits = (x @ params["embed"].T.astype(cfg.dtype))[:, 0, :]
    return logits, new_cache


def prefill(params: Params, tokens: jax.Array, cfg: TransformerConfig) -> jax.Array:
    """Prefill forward: last-position logits [B, V]. The unembed runs on
    the final position only — never materialises [B, S, V]."""
    x, _ = backbone(params, tokens, cfg)
    return x[:, -1, :] @ params["embed"].T.astype(cfg.dtype)
